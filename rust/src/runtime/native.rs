//! Native CPU training backend — the offline twin of the PJRT runtime.
//!
//! The paper's pipeline (ℓ1 sparse coding with proximal steps → debias →
//! compress → serve, arXiv:1905.07931) trains **from random weights**, so
//! it needs a runnable training backend, not just inference kernels. The
//! AOT/PJRT path (`xla_compat`) is unavailable offline; this module is a
//! pure-Rust f32 reference executor for the MLP model family that speaks
//! the exact same artifact contract the trainer already uses:
//!
//! * Artifacts are addressed as `native/<model>/<step>` paths — no files
//!   on disk; [`Manifest::native`](crate::runtime::Manifest::native)
//!   registers them with the same role-slot signatures `aot.py` emits,
//!   so `Trainer`, `spc::run`, `debias::retrain`, `pruning::run` and
//!   `mm::run` drive either backend unchanged.
//! * Forward = flatten → (matmul_nt + bias + ReLU)* → logits; loss is
//!   softmax cross-entropy; backward is hand-written. The Prox-ADAM /
//!   Prox-RMSProp / Prox-SGD update rules apply the soft-threshold
//!   proximal operator (`sparse::prox`) inside every step, exactly as
//!   the paper's Algorithms 1-2 (threshold = lr·λ, weights only).
//! * Matmuls (forward and both backward products) partition over the
//!   batch or the output axis via `util::pool::parallel_chunks` with a
//!   fixed per-element reduction order, so training is multi-threaded
//!   yet **bit-deterministic** for any `PROXCOMP_THREADS` (the same
//!   contract the serving kernels pin in `tests/property.rs`).
//!
//! The executor reconstructs the MLP from the literals themselves (2-D
//! leaves are weights, the 1-D leaf that follows is its bias), so any
//! width registered by the native manifest works without recompilation.

use std::path::{Path, PathBuf};

use crate::runtime::client::HostValue;
use crate::runtime::manifest::{Artifact, ModelEntry, ParamSpec, Role, Slot};
use crate::sparse::prox;
use crate::util::pool;
use crate::xla_compat as xla;

/// ADAM first-moment decay (paper Algorithm 1).
pub const BETA1: f32 = 0.9;
/// ADAM second-moment decay.
pub const BETA2: f32 = 0.999;
/// Optimizer epsilon.
pub const EPS: f32 = 1e-8;
/// RMSProp accumulator decay (paper Algorithm 2).
pub const RMS_RHO: f32 = 0.9;
/// SGD-momentum coefficient for the MM L-step.
pub const MM_MOMENTUM: f32 = 0.9;

/// All step names the native backend registers and executes.
pub const NATIVE_STEPS: [&str; 7] =
    ["train_prox_adam", "train_prox_rmsprop", "train_prox_sgd", "train_masked", "train_mm", "eval", "infer"];

/// True for artifact paths owned by this backend (`native/<model>/<step>`).
pub fn is_native_path(path: &Path) -> bool {
    path.starts_with("native")
}

fn parse_path(path: &Path) -> anyhow::Result<(String, String)> {
    let parts: Vec<String> = path.components().map(|c| c.as_os_str().to_string_lossy().to_string()).collect();
    anyhow::ensure!(
        parts.len() == 3 && parts[0] == "native",
        "not a native artifact path (want native/<model>/<step>): {path:?}"
    );
    Ok((parts[1].clone(), parts[2].clone()))
}

// ---------------------------------------------------------------------------
// Synthetic manifest construction (the contract with the trainer)
// ---------------------------------------------------------------------------

/// Build a native-backend MLP model entry: `input → hidden… → classes`
/// fully-connected with ReLU between layers, leaves named `fc{i}_w` /
/// `fc{i}_b` in manifest flattening order (weights prunable).
pub fn mlp_entry(
    name: &str,
    input_shape: &[usize],
    hidden: &[usize],
    num_classes: usize,
    dataset: &str,
    train_batch: usize,
    eval_batch: usize,
) -> ModelEntry {
    let mut dims = vec![input_shape.iter().product::<usize>()];
    dims.extend_from_slice(hidden);
    dims.push(num_classes);
    let mut params = Vec::new();
    for i in 1..dims.len() {
        params.push(ParamSpec::new(&format!("fc{i}_w"), "fc_w", vec![dims[i], dims[i - 1]], true));
        params.push(ParamSpec::new(&format!("fc{i}_b"), "fc_b", vec![dims[i]], false));
    }
    let num_weights: usize = params.iter().filter(|s| s.prunable).map(ParamSpec::numel).sum();
    let num_params: usize = params.iter().map(ParamSpec::numel).sum();
    let mut artifacts = std::collections::BTreeMap::new();
    for step in NATIVE_STEPS {
        let batch = if step == "eval" || step == "infer" { eval_batch } else { train_batch };
        artifacts.insert(
            step.to_string(),
            step_artifact(name, step, &params, batch, input_shape, num_classes),
        );
    }
    ModelEntry {
        name: name.to_string(),
        dataset: dataset.to_string(),
        input_shape: input_shape.to_vec(),
        num_classes,
        train_batch,
        eval_batch,
        params,
        num_weights,
        num_params,
        artifacts,
    }
}

/// The role-slot signature of one native step — the single source of
/// truth shared by the manifest builder and the executor's input parser.
pub fn step_artifact(
    model: &str,
    step: &str,
    params: &[ParamSpec],
    batch: usize,
    input_shape: &[usize],
    num_classes: usize,
) -> Artifact {
    let leaf = |role: Role| -> Vec<Slot> {
        params
            .iter()
            .map(|s| Slot { role, name: s.name.clone(), shape: s.shape.clone(), dtype: "f32".into() })
            .collect()
    };
    let scalar = |role: Role, name: &str| Slot { role, name: name.into(), shape: vec![], dtype: "f32".into() };
    let mut x_shape = vec![batch];
    x_shape.extend_from_slice(input_shape);
    let x = Slot { role: Role::X, name: "x".into(), shape: x_shape, dtype: "f32".into() };
    let y = Slot { role: Role::Y, name: "y".into(), shape: vec![batch], dtype: "i32".into() };

    let (inputs, outputs) = match step {
        "train_prox_adam" | "train_prox_rmsprop" | "train_prox_sgd" => {
            let mut inputs = leaf(Role::Param);
            inputs.extend(leaf(Role::OptM));
            inputs.extend(leaf(Role::OptV));
            inputs.push(scalar(Role::OptT, "t"));
            inputs.push(x);
            inputs.push(y);
            inputs.push(scalar(Role::Lambda, "lambda"));
            inputs.push(scalar(Role::Lr, "lr"));
            let mut outputs = leaf(Role::Param);
            outputs.extend(leaf(Role::OptM));
            outputs.extend(leaf(Role::OptV));
            outputs.push(scalar(Role::OptT, "t"));
            outputs.push(scalar(Role::Loss, "loss"));
            (inputs, outputs)
        }
        "train_masked" => {
            let mut inputs = leaf(Role::Param);
            inputs.extend(leaf(Role::OptM));
            inputs.extend(leaf(Role::OptV));
            inputs.extend(leaf(Role::Mask));
            inputs.push(scalar(Role::OptT, "t"));
            inputs.push(x);
            inputs.push(y);
            inputs.push(scalar(Role::Lr, "lr"));
            let mut outputs = leaf(Role::Param);
            outputs.extend(leaf(Role::OptM));
            outputs.extend(leaf(Role::OptV));
            outputs.push(scalar(Role::OptT, "t"));
            outputs.push(scalar(Role::Loss, "loss"));
            (inputs, outputs)
        }
        "train_mm" => {
            let mut inputs = leaf(Role::Param);
            inputs.extend(leaf(Role::OptM));
            inputs.extend(leaf(Role::Theta));
            inputs.extend(leaf(Role::Lagrange));
            inputs.push(scalar(Role::OptT, "t"));
            inputs.push(x);
            inputs.push(y);
            inputs.push(scalar(Role::Lr, "lr"));
            inputs.push(scalar(Role::Mu, "mu"));
            let mut outputs = leaf(Role::Param);
            outputs.extend(leaf(Role::OptM));
            outputs.push(scalar(Role::OptT, "t"));
            outputs.push(scalar(Role::Loss, "loss"));
            (inputs, outputs)
        }
        "eval" => {
            let mut inputs = leaf(Role::Param);
            inputs.push(x);
            inputs.push(y);
            let outputs = vec![scalar(Role::Loss, "loss"), scalar(Role::Correct, "correct")];
            (inputs, outputs)
        }
        "infer" => {
            let mut inputs = leaf(Role::Param);
            inputs.push(x);
            let outputs = vec![Slot {
                role: Role::Logits,
                name: "logits".into(),
                shape: vec![batch, num_classes],
                dtype: "f32".into(),
            }];
            (inputs, outputs)
        }
        other => panic!("unknown native step {other:?}"),
    };
    Artifact { file: PathBuf::from(format!("native/{model}/{step}")), batch, inputs, outputs }
}

// ---------------------------------------------------------------------------
// Deterministic threaded matmuls (fixed per-element reduction order)
// ---------------------------------------------------------------------------

/// `y[b,n] = x[b,k] · w[n,k]ᵀ + bias[n]`. Partitions the batch axis when
/// it can feed every lane, the output axis otherwise; either partition
/// computes each element with the same ascending-k reduction, so results
/// are bit-identical for any thread count.
pub fn fc_forward(x: &[f32], b: usize, k: usize, w: &[f32], bias: &[f32], n: usize, threads: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), b * k);
    debug_assert_eq!(w.len(), n * k);
    debug_assert_eq!(bias.len(), n);
    let mut y = vec![0.0f32; b * n];
    let ptr = pool::SharedMut::new(&mut y);
    let cell = |bi: usize, o: usize| -> f32 {
        let xrow = &x[bi * k..(bi + 1) * k];
        let wrow = &w[o * k..(o + 1) * k];
        let mut acc = bias[o];
        for kk in 0..k {
            acc += xrow[kk] * wrow[kk];
        }
        acc
    };
    if pool::batch_saturates(b, threads) {
        pool::parallel_chunks(b, threads, |r0, r1| {
            let y = unsafe { ptr.slice() };
            for bi in r0..r1 {
                for o in 0..n {
                    y[bi * n + o] = cell(bi, o);
                }
            }
        });
    } else {
        pool::parallel_chunks(n, threads, |c0, c1| {
            let y = unsafe { ptr.slice() };
            for o in c0..c1 {
                for bi in 0..b {
                    y[bi * n + o] = cell(bi, o);
                }
            }
        });
    }
    y
}

/// Weight gradient `dw[n,k] = Σ_b dy[b,n]·x[b,k]`, partitioned over the
/// output-row axis; the batch reduction runs in ascending order on one
/// thread per row, so the sum order never depends on the thread count.
pub fn fc_grad_w(dy: &[f32], b: usize, n: usize, x: &[f32], k: usize, threads: usize) -> Vec<f32> {
    debug_assert_eq!(dy.len(), b * n);
    debug_assert_eq!(x.len(), b * k);
    let mut dw = vec![0.0f32; n * k];
    let ptr = pool::SharedMut::new(&mut dw);
    pool::parallel_chunks(n, threads, |c0, c1| {
        let dw = unsafe { ptr.slice() };
        for o in c0..c1 {
            let row = &mut dw[o * k..(o + 1) * k];
            for bi in 0..b {
                let g = dy[bi * n + o];
                if g == 0.0 {
                    continue;
                }
                let xrow = &x[bi * k..(bi + 1) * k];
                for kk in 0..k {
                    row[kk] += g * xrow[kk];
                }
            }
        }
    });
    dw
}

/// Bias gradient `db[n] = Σ_b dy[b,n]` (ascending-batch reduction).
pub fn fc_grad_b(dy: &[f32], b: usize, n: usize) -> Vec<f32> {
    let mut db = vec![0.0f32; n];
    for bi in 0..b {
        for o in 0..n {
            db[o] += dy[bi * n + o];
        }
    }
    db
}

/// Input gradient `dx[b,k] = Σ_o dy[b,o]·w[o,k]`, batch- or
/// column-partitioned with a fixed ascending-o reduction per element.
pub fn fc_grad_x(dy: &[f32], b: usize, n: usize, w: &[f32], k: usize, threads: usize) -> Vec<f32> {
    debug_assert_eq!(dy.len(), b * n);
    debug_assert_eq!(w.len(), n * k);
    let mut dx = vec![0.0f32; b * k];
    let ptr = pool::SharedMut::new(&mut dx);
    let cell = |bi: usize, kk: usize| -> f32 {
        let mut acc = 0.0f32;
        for o in 0..n {
            acc += dy[bi * n + o] * w[o * k + kk];
        }
        acc
    };
    if pool::batch_saturates(b, threads) {
        pool::parallel_chunks(b, threads, |r0, r1| {
            let dx = unsafe { ptr.slice() };
            for bi in r0..r1 {
                for kk in 0..k {
                    dx[bi * k + kk] = cell(bi, kk);
                }
            }
        });
    } else {
        pool::parallel_chunks(k, threads, |c0, c1| {
            let dx = unsafe { ptr.slice() };
            for kk in c0..c1 {
                for bi in 0..b {
                    dx[bi * k + kk] = cell(bi, kk);
                }
            }
        });
    }
    dx
}

/// Mean softmax cross-entropy over the batch plus `∂loss/∂logits`
/// (`(softmax − onehot)/B`, rows processed in ascending order).
pub fn softmax_ce(logits: &[f32], labels: &[i32], b: usize, ncls: usize) -> (f32, Vec<f32>) {
    debug_assert_eq!(logits.len(), b * ncls);
    debug_assert_eq!(labels.len(), b);
    let mut loss = 0.0f32;
    let mut dlogits = vec![0.0f32; b * ncls];
    let inv_b = 1.0 / b as f32;
    for bi in 0..b {
        let row = &logits[bi * ncls..(bi + 1) * ncls];
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for &v in row {
            z += (v - m).exp();
        }
        let label = labels[bi] as usize;
        loss += -(row[label] - m) + z.ln();
        let drow = &mut dlogits[bi * ncls..(bi + 1) * ncls];
        for (j, &v) in row.iter().enumerate() {
            drow[j] = (v - m).exp() / z * inv_b;
        }
        drow[label] -= inv_b;
    }
    (loss * inv_b, dlogits)
}

// ---------------------------------------------------------------------------
// Update rules (paper Algorithms 1-2 + the debias/MM variants)
// ---------------------------------------------------------------------------

/// One Prox-ADAM step, elementwise: the bias-corrected ADAM update
/// followed by the ℓ1 proximal operator with threshold `lr·λ`. `t` is
/// the post-increment step count; pass `lambda = 0` to skip the prox
/// (biases / dense baselines — λ=0 makes it the identity anyway).
pub fn prox_adam_update(w: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], t: f32, lr: f32, lambda: f32) {
    let bc1 = 1.0 - BETA1.powf(t);
    let bc2 = 1.0 - BETA2.powf(t);
    for i in 0..w.len() {
        m[i] = BETA1 * m[i] + (1.0 - BETA1) * g[i];
        v[i] = BETA2 * v[i] + (1.0 - BETA2) * g[i] * g[i];
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        w[i] -= lr * mhat / (vhat.sqrt() + EPS);
    }
    if lambda > 0.0 {
        prox::soft_threshold_inplace(w, lr * lambda);
    }
}

/// One Prox-RMSProp step: accumulator update, scaled descent, prox.
pub fn prox_rmsprop_update(w: &mut [f32], g: &[f32], v: &mut [f32], lr: f32, lambda: f32) {
    for i in 0..w.len() {
        v[i] = RMS_RHO * v[i] + (1.0 - RMS_RHO) * g[i] * g[i];
        w[i] -= lr * g[i] / (v[i].sqrt() + EPS);
    }
    if lambda > 0.0 {
        prox::soft_threshold_inplace(w, lr * lambda);
    }
}

/// One Prox-SGD step: plain descent, prox.
pub fn prox_sgd_update(w: &mut [f32], g: &[f32], lr: f32, lambda: f32) {
    for i in 0..w.len() {
        w[i] -= lr * g[i];
    }
    if lambda > 0.0 {
        prox::soft_threshold_inplace(w, lr * lambda);
    }
}

/// One SGD-momentum step (the MM L-step optimizer).
pub fn momentum_update(w: &mut [f32], g: &[f32], m: &mut [f32], lr: f32) {
    for i in 0..w.len() {
        m[i] = MM_MOMENTUM * m[i] + g[i];
        w[i] -= lr * m[i];
    }
}

// ---------------------------------------------------------------------------
// The executor
// ---------------------------------------------------------------------------

/// Which training-family step an artifact path names.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum StepKind {
    ProxAdam,
    ProxRmsprop,
    ProxSgd,
    Masked,
    Mm,
    Eval,
    Infer,
}

impl StepKind {
    fn parse(step: &str) -> anyhow::Result<StepKind> {
        Ok(match step {
            "train_prox_adam" => StepKind::ProxAdam,
            "train_prox_rmsprop" => StepKind::ProxRmsprop,
            "train_prox_sgd" => StepKind::ProxSgd,
            "train_masked" => StepKind::Masked,
            "train_mm" => StepKind::Mm,
            "eval" => StepKind::Eval,
            "infer" => StepKind::Infer,
            other => anyhow::bail!("native backend has no step {other:?}"),
        })
    }
}

/// One decoded f32 input leaf.
struct Leaf {
    shape: Vec<usize>,
    data: Vec<f32>,
}

fn decode_f32(lit: &xla::Literal) -> anyhow::Result<Leaf> {
    let shape: Vec<usize> = lit.array_shape()?.dims().iter().map(|&d| d as usize).collect();
    Ok(Leaf { shape, data: lit.to_vec::<f32>()? })
}

fn decode_scalar(lit: &xla::Literal) -> anyhow::Result<f32> {
    let leaf = decode_f32(lit)?;
    anyhow::ensure!(leaf.data.len() == 1, "expected scalar literal, got shape {:?}", leaf.shape);
    Ok(leaf.data[0])
}

/// One FC layer's position within the flat leaf list.
struct LayerIdx {
    w: usize,
    b: usize,
    out: usize,
    inp: usize,
}

/// Pair up `(2-D weight, 1-D bias)` leaves into the MLP layer stack.
fn build_layers(leaves: &[Leaf]) -> anyhow::Result<Vec<LayerIdx>> {
    let mut layers = Vec::new();
    let mut i = 0;
    while i < leaves.len() {
        let w = &leaves[i];
        anyhow::ensure!(w.shape.len() == 2, "leaf {i}: expected 2-D weight, got shape {:?}", w.shape);
        let b = leaves.get(i + 1).ok_or_else(|| anyhow::anyhow!("weight leaf {i} has no bias leaf"))?;
        anyhow::ensure!(
            b.shape.len() == 1 && b.shape[0] == w.shape[0],
            "leaf {}: bias shape {:?} does not match weight rows {}",
            i + 1,
            b.shape,
            w.shape[0]
        );
        layers.push(LayerIdx { w: i, b: i + 1, out: w.shape[0], inp: w.shape[1] });
        i += 2;
    }
    anyhow::ensure!(!layers.is_empty(), "no parameter leaves");
    for pair in layers.windows(2) {
        anyhow::ensure!(pair[1].inp == pair[0].out, "layer widths do not chain: {} -> {}", pair[0].out, pair[1].inp);
    }
    Ok(layers)
}

/// Forward activations: `acts[0]` is the flattened input, `acts[l+1]`
/// the post-ReLU output of layer `l` (the last entry is the raw logits).
struct ForwardPass {
    acts: Vec<Vec<f32>>,
    batch: usize,
}

fn forward(layers: &[LayerIdx], leaves: &[Leaf], x: &Leaf, threads: usize) -> anyhow::Result<ForwardPass> {
    anyhow::ensure!(!x.shape.is_empty(), "input x must be batched");
    let batch = x.shape[0];
    let d0: usize = x.shape[1..].iter().product();
    anyhow::ensure!(d0 == layers[0].inp, "input example size {d0} does not match fc1 fan-in {}", layers[0].inp);
    let mut acts: Vec<Vec<f32>> = Vec::with_capacity(layers.len() + 1);
    acts.push(x.data.clone());
    for (l, layer) in layers.iter().enumerate() {
        let mut h =
            fc_forward(&acts[l], batch, layer.inp, &leaves[layer.w].data, &leaves[layer.b].data, layer.out, threads);
        if l + 1 < layers.len() {
            for v in h.iter_mut() {
                if *v < 0.0 {
                    *v = 0.0;
                }
            }
        }
        acts.push(h);
    }
    Ok(ForwardPass { acts, batch })
}

/// Backward pass from `dlogits`; returns per-leaf gradients aligned with
/// the leaf order (weight grads at weight indices, bias grads at bias
/// indices).
fn backward(layers: &[LayerIdx], leaves: &[Leaf], fwd: &ForwardPass, dlogits: Vec<f32>, threads: usize) -> Vec<Vec<f32>> {
    let b = fwd.batch;
    let mut grads: Vec<Vec<f32>> = leaves.iter().map(|_| Vec::new()).collect();
    let mut dz = dlogits;
    for l in (0..layers.len()).rev() {
        let layer = &layers[l];
        grads[layer.w] = fc_grad_w(&dz, b, layer.out, &fwd.acts[l], layer.inp, threads);
        grads[layer.b] = fc_grad_b(&dz, b, layer.out);
        if l > 0 {
            let mut dx = fc_grad_x(&dz, b, layer.out, &leaves[layer.w].data, layer.inp, threads);
            // ReLU gate: the stored activation is max(z, 0), so a zero
            // activation means a blocked gradient.
            for (d, &a) in dx.iter_mut().zip(&fwd.acts[l]) {
                if a <= 0.0 {
                    *d = 0.0;
                }
            }
            dz = dx;
        }
    }
    grads
}

/// The native executor. Stateless between calls (all training state is
/// host-side in the trainer); the struct exists as the dispatch target
/// of [`Backend::Native`](crate::runtime::client::Backend).
#[derive(Debug, Default)]
pub struct NativeBackend {
    steps_executed: u64,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend { steps_executed: 0 }
    }

    /// How many artifact executions this backend has run (visible in
    /// place of the PJRT executable-cache counter).
    pub fn steps_executed(&self) -> u64 {
        self.steps_executed
    }

    /// Execute a `native/<model>/<step>` artifact against role-ordered
    /// input literals; returns role-ordered host values, mirroring
    /// `PjRtLoadedExecutable::execute` + tuple unpacking.
    pub fn execute(&mut self, path: &Path, inputs: &[xla::Literal]) -> anyhow::Result<Vec<HostValue>> {
        let (_model, step) = parse_path(path)?;
        let kind = StepKind::parse(&step)?;
        self.steps_executed += 1;
        let threads = pool::max_threads();
        match kind {
            StepKind::Eval => eval_step(inputs, threads),
            StepKind::Infer => infer_step(inputs, threads),
            _ => train_step(kind, inputs, threads),
        }
    }
}

/// Split `inputs` per the step signature (see [`step_artifact`]); the
/// leaf count L is recovered from the literal count, which the role
/// layout determines uniquely per step.
fn leaf_count(kind: StepKind, n_inputs: usize) -> anyhow::Result<usize> {
    let (num, den) = match kind {
        StepKind::ProxAdam | StepKind::ProxRmsprop | StepKind::ProxSgd => (n_inputs as i64 - 5, 3),
        StepKind::Masked => (n_inputs as i64 - 4, 4),
        StepKind::Mm => (n_inputs as i64 - 5, 4),
        StepKind::Eval => (n_inputs as i64 - 2, 1),
        StepKind::Infer => (n_inputs as i64 - 1, 1),
    };
    anyhow::ensure!(num > 0 && num % den == 0, "native {kind:?}: {n_inputs} inputs do not fit the step signature");
    Ok((num / den) as usize)
}

fn decode_leaves(lits: &[xla::Literal]) -> anyhow::Result<Vec<Leaf>> {
    lits.iter().map(decode_f32).collect()
}

fn leaf_host_values(leaves: Vec<Leaf>) -> Vec<HostValue> {
    leaves.into_iter().map(|l| HostValue::F32 { shape: l.shape, data: l.data }).collect()
}

/// The role-ordered tail of a training-step input list (everything past
/// the parameter leaves), parsed per the step signature.
struct TrainInputs {
    opt_m: Vec<Leaf>,
    opt_v: Vec<Leaf>,
    theta: Option<Vec<Leaf>>,
    lagrange: Option<Vec<Leaf>>,
    masks: Option<Vec<Leaf>>,
    t_in: f32,
    x: Leaf,
    y: Vec<i32>,
    lambda: f32,
    lr: f32,
    mu: f32,
}

fn parse_train_inputs(kind: StepKind, nl: usize, inputs: &[xla::Literal]) -> anyhow::Result<TrainInputs> {
    match kind {
        StepKind::ProxAdam | StepKind::ProxRmsprop | StepKind::ProxSgd => Ok(TrainInputs {
            opt_m: decode_leaves(&inputs[nl..2 * nl])?,
            opt_v: decode_leaves(&inputs[2 * nl..3 * nl])?,
            theta: None,
            lagrange: None,
            masks: None,
            t_in: decode_scalar(&inputs[3 * nl])?,
            x: decode_f32(&inputs[3 * nl + 1])?,
            y: inputs[3 * nl + 2].to_vec::<i32>()?,
            lambda: decode_scalar(&inputs[3 * nl + 3])?,
            lr: decode_scalar(&inputs[3 * nl + 4])?,
            mu: 0.0,
        }),
        StepKind::Masked => Ok(TrainInputs {
            opt_m: decode_leaves(&inputs[nl..2 * nl])?,
            opt_v: decode_leaves(&inputs[2 * nl..3 * nl])?,
            theta: None,
            lagrange: None,
            masks: Some(decode_leaves(&inputs[3 * nl..4 * nl])?),
            t_in: decode_scalar(&inputs[4 * nl])?,
            x: decode_f32(&inputs[4 * nl + 1])?,
            y: inputs[4 * nl + 2].to_vec::<i32>()?,
            lambda: 0.0,
            lr: decode_scalar(&inputs[4 * nl + 3])?,
            mu: 0.0,
        }),
        StepKind::Mm => Ok(TrainInputs {
            opt_m: decode_leaves(&inputs[nl..2 * nl])?,
            opt_v: Vec::new(),
            theta: Some(decode_leaves(&inputs[2 * nl..3 * nl])?),
            lagrange: Some(decode_leaves(&inputs[3 * nl..4 * nl])?),
            masks: None,
            t_in: decode_scalar(&inputs[4 * nl])?,
            x: decode_f32(&inputs[4 * nl + 1])?,
            y: inputs[4 * nl + 2].to_vec::<i32>()?,
            lambda: 0.0,
            lr: decode_scalar(&inputs[4 * nl + 3])?,
            mu: decode_scalar(&inputs[4 * nl + 4])?,
        }),
        StepKind::Eval | StepKind::Infer => anyhow::bail!("not a training step"),
    }
}

fn train_step(kind: StepKind, inputs: &[xla::Literal], threads: usize) -> anyhow::Result<Vec<HostValue>> {
    let nl = leaf_count(kind, inputs.len())?;
    let mut params = decode_leaves(&inputs[..nl])?;
    let layers = build_layers(&params)?;
    let TrainInputs { mut opt_m, mut opt_v, theta, lagrange, masks, t_in, x, y, lambda, lr, mu } =
        parse_train_inputs(kind, nl, inputs)?;
    let batch = x.shape.first().copied().unwrap_or(0);
    anyhow::ensure!(y.len() == batch, "labels length {} != batch {batch}", y.len());

    let fwd = forward(&layers, &params, &x, threads)?;
    let ncls = layers.last().map(|l| l.out).unwrap_or(0);
    let (loss, dlogits) = softmax_ce(fwd.acts.last().unwrap(), &y, batch, ncls);
    let mut grads = backward(&layers, &params, &fwd, dlogits, threads);

    // Masked training (debias, Section 2.4): gradients gated by the 0/1
    // mask, weights re-clamped after the step so pruned entries stay
    // exactly zero even under optimizer epsilon noise.
    if let Some(masks) = &masks {
        for (g, m) in grads.iter_mut().zip(masks) {
            anyhow::ensure!(g.len() == m.data.len(), "mask/grad length mismatch");
            for (gi, &mi) in g.iter_mut().zip(&m.data) {
                *gi *= mi;
            }
        }
    }
    // MM L-step (augmented Lagrangian pull): g += μ(w − θ) − λ_mult.
    if let (Some(theta), Some(lagrange)) = (&theta, &lagrange) {
        for i in 0..params.len() {
            let (w, th, lg) = (&params[i].data, &theta[i].data, &lagrange[i].data);
            let g = &mut grads[i];
            for j in 0..g.len() {
                g[j] += mu * (w[j] - th[j]) - lg[j];
            }
        }
    }

    let t_out = t_in + 1.0;
    for (i, leaf) in params.iter_mut().enumerate() {
        // Only 2-D weight leaves are prunable; biases never see the prox.
        let leaf_lambda = if leaf.shape.len() == 2 { lambda } else { 0.0 };
        match kind {
            StepKind::ProxAdam | StepKind::Masked => {
                prox_adam_update(
                    &mut leaf.data,
                    &grads[i],
                    &mut opt_m[i].data,
                    &mut opt_v[i].data,
                    t_out,
                    lr,
                    leaf_lambda,
                );
            }
            StepKind::ProxRmsprop => {
                prox_rmsprop_update(&mut leaf.data, &grads[i], &mut opt_v[i].data, lr, leaf_lambda);
            }
            StepKind::ProxSgd => {
                prox_sgd_update(&mut leaf.data, &grads[i], lr, leaf_lambda);
            }
            StepKind::Mm => {
                momentum_update(&mut leaf.data, &grads[i], &mut opt_m[i].data, lr);
            }
            StepKind::Eval | StepKind::Infer => unreachable!(),
        }
        if let Some(masks) = &masks {
            for (w, &mi) in leaf.data.iter_mut().zip(&masks[i].data) {
                *w *= mi;
            }
        }
    }

    let mut out = leaf_host_values(params);
    out.extend(leaf_host_values(opt_m));
    if kind != StepKind::Mm {
        out.extend(leaf_host_values(opt_v));
    }
    out.push(HostValue::scalar_f32(t_out));
    out.push(HostValue::scalar_f32(loss));
    Ok(out)
}

fn eval_step(inputs: &[xla::Literal], threads: usize) -> anyhow::Result<Vec<HostValue>> {
    let nl = leaf_count(StepKind::Eval, inputs.len())?;
    let params = decode_leaves(&inputs[..nl])?;
    let layers = build_layers(&params)?;
    let x = decode_f32(&inputs[nl])?;
    let y = inputs[nl + 1].to_vec::<i32>()?;
    let fwd = forward(&layers, &params, &x, threads)?;
    let ncls = layers.last().unwrap().out;
    let (loss, _) = softmax_ce(fwd.acts.last().unwrap(), &y, fwd.batch, ncls);
    let logits = fwd.acts.last().unwrap();
    let mut correct = 0usize;
    for bi in 0..fwd.batch {
        let row = &logits[bi * ncls..(bi + 1) * ncls];
        // total_cmp: NaN logits (diverged weights) must not panic the
        // executor — every other malformed state errors, not aborts.
        let pred = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).map(|(j, _)| j).unwrap();
        if pred == y[bi] as usize {
            correct += 1;
        }
    }
    Ok(vec![HostValue::scalar_f32(loss), HostValue::scalar_f32(correct as f32)])
}

fn infer_step(inputs: &[xla::Literal], threads: usize) -> anyhow::Result<Vec<HostValue>> {
    let nl = leaf_count(StepKind::Infer, inputs.len())?;
    let params = decode_leaves(&inputs[..nl])?;
    let layers = build_layers(&params)?;
    let x = decode_f32(&inputs[nl])?;
    let fwd = forward(&layers, &params, &x, threads)?;
    let ncls = layers.last().unwrap().out;
    let logits = fwd.acts.last().unwrap().clone();
    Ok(vec![HostValue::F32 { shape: vec![fwd.batch, ncls], data: logits }])
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::client;
    use crate::util::rng::Rng;

    #[test]
    fn native_paths_recognized() {
        assert!(is_native_path(Path::new("native/mlp/train_prox_adam")));
        assert!(!is_native_path(Path::new("artifacts/mlp_infer.hlo.txt")));
        let (m, s) = parse_path(Path::new("native/mlp-s/eval")).unwrap();
        assert_eq!((m.as_str(), s.as_str()), ("mlp-s", "eval"));
        assert!(parse_path(Path::new("native/mlp")).is_err());
    }

    #[test]
    fn mlp_entry_signatures_match_trainer_contract() {
        let entry = mlp_entry("mlp", &[1, 28, 28], &[300, 100], 10, "synth-mnist", 32, 64);
        assert_eq!(entry.params.len(), 6);
        assert_eq!(entry.params[0].shape, vec![300, 784]);
        assert!(entry.params[0].prunable && !entry.params[1].prunable);
        assert_eq!(entry.num_weights, 300 * 784 + 100 * 300 + 10 * 100);
        // Prox steps: params, m, v (3L) + t + x + y + λ + lr.
        let adam = entry.artifact("train_prox_adam").unwrap();
        assert_eq!(adam.inputs.len(), 3 * 6 + 5);
        assert_eq!(adam.inputs.last().unwrap().role, Role::Lr);
        assert_eq!(adam.outputs.len(), 3 * 6 + 2);
        assert_eq!(adam.outputs.last().unwrap().role, Role::Loss);
        // Masked adds one mask leaf per param leaf, drops λ.
        let masked = entry.artifact("train_masked").unwrap();
        assert_eq!(masked.inputs.len(), 4 * 6 + 4);
        assert!(masked.inputs.iter().all(|s| s.role != Role::Lambda));
        // Infer: params + x → logits.
        let infer = entry.artifact("infer").unwrap();
        assert_eq!(infer.inputs.len(), 7);
        assert_eq!(infer.outputs[0].shape, vec![64, 10]);
    }

    #[test]
    fn fc_forward_matches_hand_computation() {
        // x = [[1, 2], [3, 4]], w = [[1, 0], [0, 1], [1, 1]], bias = [0.5, 0, -1]
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let w = [1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0];
        let bias = [0.5f32, 0.0, -1.0];
        let y = fc_forward(&x, 2, 2, &w, &bias, 3, 1);
        assert_eq!(y, vec![1.5, 2.0, 2.0, 3.5, 4.0, 6.0]);
    }

    #[test]
    fn fc_kernels_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(40);
        for (b, k, n) in [(1usize, 17, 9), (6, 13, 21), (16, 33, 5)] {
            let x = rng.normal_vec(b * k, 1.0);
            let w = rng.normal_vec(n * k, 1.0);
            let bias = rng.normal_vec(n, 1.0);
            let dy = rng.normal_vec(b * n, 1.0);
            let f1 = fc_forward(&x, b, k, &w, &bias, n, 1);
            let gw1 = fc_grad_w(&dy, b, n, &x, k, 1);
            let gx1 = fc_grad_x(&dy, b, n, &w, k, 1);
            for threads in [2usize, 4, 8] {
                assert_eq!(f1, fc_forward(&x, b, k, &w, &bias, n, threads), "fwd b={b} t={threads}");
                assert_eq!(gw1, fc_grad_w(&dy, b, n, &x, k, threads), "dw b={b} t={threads}");
                assert_eq!(gx1, fc_grad_x(&dy, b, n, &w, k, threads), "dx b={b} t={threads}");
            }
        }
    }

    #[test]
    fn softmax_ce_uniform_logits() {
        let logits = vec![0.0f32; 2 * 4];
        let (loss, d) = softmax_ce(&logits, &[1, 3], 2, 4);
        assert!((loss - (4.0f32).ln()).abs() < 1e-6);
        // Gradient rows sum to zero and the label entry is negative.
        for bi in 0..2 {
            let row = &d[bi * 4..(bi + 1) * 4];
            let s: f32 = row.iter().sum();
            assert!(s.abs() < 1e-6);
        }
        assert!(d[1] < 0.0 && d[2 * 4 - 1] < 0.0);
    }

    #[test]
    fn prox_adam_shrinks_and_zeroes() {
        // Zero gradient, positive λ: the prox must carve the small weight
        // to exact zero and shrink the big one by exactly lr·λ.
        let mut w = vec![0.5f32, 1e-4];
        let g = vec![0.0f32; 2];
        let mut m = vec![0.0f32; 2];
        let mut v = vec![0.0f32; 2];
        prox_adam_update(&mut w, &g, &mut m, &mut v, 1.0, 0.1, 1.0);
        assert!((w[0] - 0.4).abs() < 1e-6, "{}", w[0]);
        assert_eq!(w[1], 0.0);
    }

    #[test]
    fn adam_with_zero_lambda_is_plain_adam() {
        let mut w = vec![1.0f32];
        let g = vec![1.0f32];
        let mut m = vec![0.0f32];
        let mut v = vec![0.0f32];
        prox_adam_update(&mut w, &g, &mut m, &mut v, 1.0, 0.01, 0.0);
        // Bias-corrected first step moves by ≈ lr·g/|g| = lr.
        assert!((w[0] - 0.99).abs() < 1e-4, "{}", w[0]);
    }

    fn tiny_entry() -> ModelEntry {
        mlp_entry("mlp-t", &[1, 2, 2], &[3], 2, "synth-blobs", 4, 4)
    }

    fn leaf_literals(values: &[(Vec<usize>, Vec<f32>)]) -> Vec<xla::Literal> {
        values.iter().map(|(shape, data)| client::literal_f32(shape, data).unwrap()).collect()
    }

    #[test]
    fn executor_runs_one_adam_step_and_advances_t() {
        let entry = tiny_entry();
        let mut rng = Rng::new(50);
        let mut lits = Vec::new();
        // params, then zero moments, in spec order.
        let leaves: Vec<(Vec<usize>, Vec<f32>)> = entry
            .params
            .iter()
            .map(|s| (s.shape.clone(), rng.normal_vec(s.numel(), 0.5)))
            .collect();
        lits.extend(leaf_literals(&leaves));
        for _ in 0..2 {
            let zeros: Vec<(Vec<usize>, Vec<f32>)> =
                entry.params.iter().map(|s| (s.shape.clone(), vec![0.0; s.numel()])).collect();
            lits.extend(leaf_literals(&zeros));
        }
        lits.push(client::literal_f32(&[], &[0.0]).unwrap()); // t
        lits.push(client::literal_f32(&[4, 1, 2, 2], &rng.normal_vec(16, 1.0)).unwrap());
        lits.push(client::literal_i32(&[4], &[0, 1, 0, 1]).unwrap());
        lits.push(client::literal_f32(&[], &[0.5]).unwrap()); // λ
        lits.push(client::literal_f32(&[], &[0.01]).unwrap()); // lr
        let mut backend = NativeBackend::new();
        let out = backend.execute(Path::new("native/mlp-t/train_prox_adam"), &lits).unwrap();
        // params (4) + m (4) + v (4) + t + loss.
        assert_eq!(out.len(), 3 * 4 + 2);
        assert_eq!(out[out.len() - 2].scalar().unwrap(), 1.0);
        let loss = out[out.len() - 1].scalar().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        // Weight leaf changed, shape preserved.
        assert_eq!(out[0].shape(), &leaves[0].0[..]);
        assert_ne!(out[0].as_f32().unwrap(), &leaves[0].1[..]);
        assert_eq!(backend.steps_executed(), 1);
    }

    #[test]
    fn backward_matches_finite_differences() {
        // Directional-derivative check: for a random direction d,
        // (L(w+h·d) − L(w−h·d)) / 2h ≈ ⟨∇L, d⟩ — catches any index or
        // transpose slip in the hand-written backward.
        let mut rng = Rng::new(60);
        let dims = [7usize, 5, 4, 3];
        let mut leaves: Vec<Leaf> = Vec::new();
        for i in 1..dims.len() {
            leaves.push(Leaf { shape: vec![dims[i], dims[i - 1]], data: rng.normal_vec(dims[i] * dims[i - 1], 0.5) });
            leaves.push(Leaf { shape: vec![dims[i]], data: rng.normal_vec(dims[i], 0.1) });
        }
        let layers = build_layers(&leaves).unwrap();
        let batch = 6;
        let x = Leaf { shape: vec![batch, dims[0]], data: rng.normal_vec(batch * dims[0], 1.0) };
        let y: Vec<i32> = (0..batch).map(|i| (i % dims[3]) as i32).collect();

        let loss_of = |leaves: &[Leaf]| -> f32 {
            let fwd = forward(&layers, leaves, &x, 1).unwrap();
            softmax_ce(fwd.acts.last().unwrap(), &y, batch, dims[3]).0
        };
        let fwd = forward(&layers, &leaves, &x, 1).unwrap();
        let (_, dlogits) = softmax_ce(fwd.acts.last().unwrap(), &y, batch, dims[3]);
        let grads = backward(&layers, &leaves, &fwd, dlogits, 1);

        // A single direction can land on a ReLU kink (central differences
        // then pick up O(1) curvature error even with a correct backward),
        // so take 9 directions and require a supermajority to agree — a
        // transposed or misindexed gradient fails every one of them.
        let h = 1e-4f32;
        let mut ok = 0;
        for _ in 0..9 {
            let dirs: Vec<Vec<f32>> = leaves.iter().map(|l| rng.normal_vec(l.data.len(), 1.0)).collect();
            let analytic: f32 =
                grads.iter().zip(&dirs).map(|(g, d)| g.iter().zip(d).map(|(a, b)| a * b).sum::<f32>()).sum();
            let shifted = |sign: f32| -> Vec<Leaf> {
                leaves
                    .iter()
                    .zip(&dirs)
                    .map(|(l, d)| Leaf {
                        shape: l.shape.clone(),
                        data: l.data.iter().zip(d).map(|(w, di)| w + sign * h * di).collect(),
                    })
                    .collect()
            };
            let numeric = (loss_of(&shifted(1.0)) - loss_of(&shifted(-1.0))) / (2.0 * h);
            let denom = analytic.abs().max(numeric.abs()).max(0.5);
            if (analytic - numeric).abs() / denom < 0.05 {
                ok += 1;
            }
        }
        assert!(ok >= 7, "directional-derivative check failed: only {ok}/9 directions agree");
    }

    #[test]
    fn executor_rejects_malformed_inputs() {
        let mut backend = NativeBackend::new();
        let lits = vec![client::literal_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap()];
        assert!(backend.execute(Path::new("native/m/train_prox_adam"), &lits).is_err());
        assert!(backend.execute(Path::new("native/m/bogus_step"), &lits).is_err());
        assert!(backend.execute(Path::new("artifacts/m.hlo.txt"), &lits).is_err());
    }
}
