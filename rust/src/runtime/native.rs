//! Native CPU training backend — the offline twin of the PJRT runtime.
//!
//! The paper's pipeline (ℓ1 sparse coding with proximal steps → debias →
//! compress → serve, arXiv:1905.07931) trains **from random weights**, so
//! it needs a runnable training backend, not just inference kernels. The
//! AOT/PJRT path (`xla_compat`) is unavailable offline; this module is a
//! pure-Rust f32 reference executor for the MLP model family that speaks
//! the exact same artifact contract the trainer already uses:
//!
//! * Artifacts are addressed as `native/<model>/<step>` paths — no files
//!   on disk; [`Manifest::native`](crate::runtime::Manifest::native)
//!   registers them with the same role-slot signatures `aot.py` emits,
//!   so `Trainer`, `spc::run`, `debias::retrain`, `pruning::run` and
//!   `mm::run` drive either backend unchanged.
//! * Forward = `[conv → max-pool]* → flatten → (matmul_nt + bias +
//!   ReLU)* → logits`; loss is softmax cross-entropy; backward is
//!   hand-written. Conv uses the paper's im2col-as-matmul formulation
//!   (shared with EIE, Han et al. 2016): forward multiplies the unfolded
//!   input against filters flattened to `(O, C·KH·KW)` — exactly the
//!   matrix the serving engine stores CSR — weight grad = colsᵀ·dy,
//!   input grad = `col2im(dy·W)`. The Prox-ADAM / Prox-RMSProp /
//!   Prox-SGD update rules apply the soft-threshold proximal operator
//!   (`sparse::prox`) inside every step, exactly as the paper's
//!   Algorithms 1-2 (threshold = lr·λ, weight leaves only — conv
//!   filters see the prox on that same flattened view).
//! * Matmuls (forward and both backward products), im2col/col2im and the
//!   max-pool forward/backward all partition via
//!   `util::pool::parallel_chunks` with a fixed per-element reduction
//!   order (pool ties break to the first scan hit), so training is
//!   multi-threaded yet **bit-deterministic** for any `PROXCOMP_THREADS`
//!   (the same contract the serving kernels pin in `tests/property.rs`).
//!
//! The executor reconstructs the network from the literals themselves
//! (4-D leaves are conv filter banks, 2-D leaves fc weights, the 1-D
//! leaf after each is its bias; a batch-norm quadruple after a conv
//! bias switches the graph to the engine's residual `resnet` wiring —
//! same-convs, frozen-stats batch norm, save/add residual markers and
//! a global-average-pool head), so any geometry registered by the
//! native manifest works without recompilation. Batch-norm running
//! stats are stop-gradient: they skip every optimizer and move only
//! through the [`BN_MOMENTUM`] EMA after each training step.

use std::path::{Path, PathBuf};

use crate::runtime::client::HostValue;
use crate::runtime::manifest::{Artifact, ModelEntry, ParamSpec, Role, Slot};
use crate::sparse::prox;
use crate::tensor::{self, ConvSpec, Tensor};
use crate::util::pool;
use crate::xla_compat as xla;

/// ADAM first-moment decay (paper Algorithm 1).
pub const BETA1: f32 = 0.9;
/// ADAM second-moment decay.
pub const BETA2: f32 = 0.999;
/// Optimizer epsilon.
pub const EPS: f32 = 1e-8;
/// RMSProp accumulator decay (paper Algorithm 2).
pub const RMS_RHO: f32 = 0.9;
/// SGD-momentum coefficient for the MM L-step.
pub const MM_MOMENTUM: f32 = 0.9;
/// EMA momentum for batch-norm running statistics: after each training
/// step, `stat ← (1 − m)·stat + m·batch_stat`. The stats are *frozen*
/// in the gradient path (stop-gradient, zero grads) — they only move
/// through this EMA, and inference folds them as constants.
pub const BN_MOMENTUM: f32 = 0.1;

/// All step names the native backend registers and executes.
pub const NATIVE_STEPS: [&str; 7] =
    ["train_prox_adam", "train_prox_rmsprop", "train_prox_sgd", "train_masked", "train_mm", "eval", "infer"];

/// True for artifact paths owned by this backend (`native/<model>/<step>`).
pub fn is_native_path(path: &Path) -> bool {
    path.starts_with("native")
}

fn parse_path(path: &Path) -> anyhow::Result<(String, String)> {
    let parts: Vec<String> = path.components().map(|c| c.as_os_str().to_string_lossy().to_string()).collect();
    anyhow::ensure!(
        parts.len() == 3 && parts[0] == "native",
        "not a native artifact path (want native/<model>/<step>): {path:?}"
    );
    Ok((parts[1].clone(), parts[2].clone()))
}

// ---------------------------------------------------------------------------
// Synthetic manifest construction (the contract with the trainer)
// ---------------------------------------------------------------------------

/// Build a native-backend MLP model entry: `input → hidden… → classes`
/// fully-connected with ReLU between layers, leaves named `fc{i}_w` /
/// `fc{i}_b` in manifest flattening order (weights prunable).
pub fn mlp_entry(
    name: &str,
    input_shape: &[usize],
    hidden: &[usize],
    num_classes: usize,
    dataset: &str,
    train_batch: usize,
    eval_batch: usize,
) -> ModelEntry {
    let mut params = Vec::new();
    push_fc_params(&mut params, input_shape.iter().product::<usize>(), hidden, num_classes);
    entry_from_params(name, dataset, input_shape, num_classes, train_batch, eval_batch, params)
}

/// Build a native-backend conv model entry with the `lenet` stage
/// structure the serving engine wires: `[k×k conv (stride 1, pad 0) →
/// 2×2/2 max-pool]* → flatten → fc…`, leaves named `conv{i}_w` /
/// `conv{i}_b` then `fc{i}_w` / `fc{i}_b` in manifest flattening order
/// (weight leaves prunable, biases not). `convs` lists `(out_channels,
/// kernel)` per conv stage, `hidden` the fc widths before the
/// `num_classes` head; the fc1 fan-in is derived by walking the
/// conv/pool spatial geometry from `input_shape`.
pub fn lenet_entry(
    name: &str,
    input_shape: &[usize],
    convs: &[(usize, usize)],
    hidden: &[usize],
    num_classes: usize,
    dataset: &str,
    train_batch: usize,
    eval_batch: usize,
) -> ModelEntry {
    assert_eq!(input_shape.len(), 3, "conv input shape must be (C, H, W)");
    let (mut c, mut h, mut w) = (input_shape[0], input_shape[1], input_shape[2]);
    let mut params = Vec::new();
    for (i, &(o, k)) in convs.iter().enumerate() {
        params.push(ParamSpec::new(&format!("conv{}_w", i + 1), "conv_w", vec![o, c, k, k], true));
        params.push(ParamSpec::new(&format!("conv{}_b", i + 1), "conv_b", vec![o], false));
        h = tensor::out_dim(tensor::out_dim(h, k, 1, 0), POOL, POOL, 0);
        w = tensor::out_dim(tensor::out_dim(w, k, 1, 0), POOL, POOL, 0);
        c = o;
    }
    push_fc_params(&mut params, c * h * w, hidden, num_classes);
    entry_from_params(name, dataset, input_shape, num_classes, train_batch, eval_batch, params)
}

/// Build a native-backend residual conv model entry with the `resnet`
/// stage structure the serving engine wires: a 3×3 same-conv stem
/// (stride 1, pad 1) with batch norm and ReLU, then `blocks` two-conv
/// residual blocks at a constant `width`, then global average pooling
/// and a linear head. Every conv carries a batch-norm quadruple
/// (`{bn}_scale/bias/mean/var`, all 1-D of length `width`); the running
/// mean/var leaves are EMA statistics, not gradient-trained (see
/// [`BN_MOMENTUM`]). Leaf names follow the engine's resnet wiring:
/// stem `conv1`/`bn1`, block `bi` leaves `conv1-{bi}-{1,2}` /
/// `bn1-{bi}-{1,2}`, head `fc1`. Conv filters and the fc head are
/// prunable; biases and BN leaves are not.
pub fn resnet_entry(
    name: &str,
    input_shape: &[usize],
    width: usize,
    blocks: usize,
    num_classes: usize,
    dataset: &str,
    train_batch: usize,
    eval_batch: usize,
) -> ModelEntry {
    assert_eq!(input_shape.len(), 3, "conv input shape must be (C, H, W)");
    assert!(blocks >= 1, "resnet needs at least one residual block");
    let mut params = Vec::new();
    let unit = |params: &mut Vec<ParamSpec>, conv: &str, bn: &str, ci: usize| {
        params.push(ParamSpec::new(&format!("{conv}_w"), "conv_w", vec![width, ci, 3, 3], true));
        params.push(ParamSpec::new(&format!("{conv}_b"), "conv_b", vec![width], false));
        for (suffix, kind) in
            [("scale", "bn_scale"), ("bias", "bn_bias"), ("mean", "bn_mean"), ("var", "bn_var")]
        {
            params.push(ParamSpec::new(&format!("{bn}_{suffix}"), kind, vec![width], false));
        }
    };
    unit(&mut params, "conv1", "bn1", input_shape[0]);
    for bi in 1..=blocks {
        unit(&mut params, &format!("conv1-{bi}-1"), &format!("bn1-{bi}-1"), width);
        unit(&mut params, &format!("conv1-{bi}-2"), &format!("bn1-{bi}-2"), width);
    }
    params.push(ParamSpec::new("fc1_w", "fc_w", vec![num_classes, width], true));
    params.push(ParamSpec::new("fc1_b", "fc_b", vec![num_classes], false));
    entry_from_params(name, dataset, input_shape, num_classes, train_batch, eval_batch, params)
}

/// Append the `fc{i}_w` / `fc{i}_b` chain `flat → hidden… → classes`.
fn push_fc_params(params: &mut Vec<ParamSpec>, flat: usize, hidden: &[usize], num_classes: usize) {
    let mut dims = vec![flat];
    dims.extend_from_slice(hidden);
    dims.push(num_classes);
    for i in 1..dims.len() {
        params.push(ParamSpec::new(&format!("fc{i}_w"), "fc_w", vec![dims[i], dims[i - 1]], true));
        params.push(ParamSpec::new(&format!("fc{i}_b"), "fc_b", vec![dims[i]], false));
    }
}

/// Assemble a [`ModelEntry`] with every native step artifact from a
/// finished parameter spec list (shared by the mlp/lenet builders).
fn entry_from_params(
    name: &str,
    dataset: &str,
    input_shape: &[usize],
    num_classes: usize,
    train_batch: usize,
    eval_batch: usize,
    params: Vec<ParamSpec>,
) -> ModelEntry {
    let num_weights: usize = params.iter().filter(|s| s.prunable).map(ParamSpec::numel).sum();
    let num_params: usize = params.iter().map(ParamSpec::numel).sum();
    let mut artifacts = std::collections::BTreeMap::new();
    for step in NATIVE_STEPS {
        let batch = if step == "eval" || step == "infer" { eval_batch } else { train_batch };
        artifacts.insert(
            step.to_string(),
            step_artifact(name, step, &params, batch, input_shape, num_classes),
        );
    }
    ModelEntry {
        name: name.to_string(),
        dataset: dataset.to_string(),
        input_shape: input_shape.to_vec(),
        num_classes,
        train_batch,
        eval_batch,
        params,
        num_weights,
        num_params,
        artifacts,
    }
}

/// The role-slot signature of one native step — the single source of
/// truth shared by the manifest builder and the executor's input parser.
pub fn step_artifact(
    model: &str,
    step: &str,
    params: &[ParamSpec],
    batch: usize,
    input_shape: &[usize],
    num_classes: usize,
) -> Artifact {
    let leaf = |role: Role| -> Vec<Slot> {
        params
            .iter()
            .map(|s| Slot { role, name: s.name.clone(), shape: s.shape.clone(), dtype: "f32".into() })
            .collect()
    };
    let scalar = |role: Role, name: &str| Slot { role, name: name.into(), shape: vec![], dtype: "f32".into() };
    let mut x_shape = vec![batch];
    x_shape.extend_from_slice(input_shape);
    let x = Slot { role: Role::X, name: "x".into(), shape: x_shape, dtype: "f32".into() };
    let y = Slot { role: Role::Y, name: "y".into(), shape: vec![batch], dtype: "i32".into() };

    let (inputs, outputs) = match step {
        "train_prox_adam" | "train_prox_rmsprop" | "train_prox_sgd" => {
            let mut inputs = leaf(Role::Param);
            inputs.extend(leaf(Role::OptM));
            inputs.extend(leaf(Role::OptV));
            inputs.push(scalar(Role::OptT, "t"));
            inputs.push(x);
            inputs.push(y);
            inputs.push(scalar(Role::Lambda, "lambda"));
            inputs.push(scalar(Role::Lr, "lr"));
            let mut outputs = leaf(Role::Param);
            outputs.extend(leaf(Role::OptM));
            outputs.extend(leaf(Role::OptV));
            outputs.push(scalar(Role::OptT, "t"));
            outputs.push(scalar(Role::Loss, "loss"));
            (inputs, outputs)
        }
        "train_masked" => {
            let mut inputs = leaf(Role::Param);
            inputs.extend(leaf(Role::OptM));
            inputs.extend(leaf(Role::OptV));
            inputs.extend(leaf(Role::Mask));
            inputs.push(scalar(Role::OptT, "t"));
            inputs.push(x);
            inputs.push(y);
            inputs.push(scalar(Role::Lr, "lr"));
            let mut outputs = leaf(Role::Param);
            outputs.extend(leaf(Role::OptM));
            outputs.extend(leaf(Role::OptV));
            outputs.push(scalar(Role::OptT, "t"));
            outputs.push(scalar(Role::Loss, "loss"));
            (inputs, outputs)
        }
        "train_mm" => {
            let mut inputs = leaf(Role::Param);
            inputs.extend(leaf(Role::OptM));
            inputs.extend(leaf(Role::Theta));
            inputs.extend(leaf(Role::Lagrange));
            inputs.push(scalar(Role::OptT, "t"));
            inputs.push(x);
            inputs.push(y);
            inputs.push(scalar(Role::Lr, "lr"));
            inputs.push(scalar(Role::Mu, "mu"));
            let mut outputs = leaf(Role::Param);
            outputs.extend(leaf(Role::OptM));
            outputs.push(scalar(Role::OptT, "t"));
            outputs.push(scalar(Role::Loss, "loss"));
            (inputs, outputs)
        }
        "eval" => {
            let mut inputs = leaf(Role::Param);
            inputs.push(x);
            inputs.push(y);
            let outputs = vec![scalar(Role::Loss, "loss"), scalar(Role::Correct, "correct")];
            (inputs, outputs)
        }
        "infer" => {
            let mut inputs = leaf(Role::Param);
            inputs.push(x);
            let outputs = vec![Slot {
                role: Role::Logits,
                name: "logits".into(),
                shape: vec![batch, num_classes],
                dtype: "f32".into(),
            }];
            (inputs, outputs)
        }
        other => panic!("unknown native step {other:?}"),
    };
    Artifact { file: PathBuf::from(format!("native/{model}/{step}")), batch, inputs, outputs }
}

// ---------------------------------------------------------------------------
// Deterministic threaded matmuls (fixed per-element reduction order)
// ---------------------------------------------------------------------------

/// Dense 8-lane blocked dot: element `kk` accumulates into lane
/// `kk % pool::LANES`, lanes collapse via `pool::tree_reduce` — the
/// dense twin of `sparse::ops::blocked_row_dot` (same lane semantics,
/// contiguous instead of gathered loads). Fixed-size chunk windows let
/// the autovectorizer map the lanes onto whatever SIMD width exists
/// while the result stays bit-identical everywhere.
#[inline]
fn blocked_dot(a: &[f32], c: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), c.len());
    let mut acc = [0.0f32; pool::LANES];
    let mut ac = a.chunks_exact(pool::LANES);
    let mut cc = c.chunks_exact(pool::LANES);
    for (av, cv) in (&mut ac).zip(&mut cc) {
        for l in 0..pool::LANES {
            acc[l] += av[l] * cv[l];
        }
    }
    for (l, (av, cv)) in ac.remainder().iter().zip(cc.remainder()).enumerate() {
        acc[l] += av * cv;
    }
    pool::tree_reduce(acc)
}

/// `out[i] += a * x[i]` in fixed-width blocks with a scalar tail. One
/// add per element per call, so bit-identical to the plain loop — pure
/// autovectorizer-friendliness, no semantic change (see sparse::ops).
#[inline]
fn axpy_blocked(out: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(out.len(), x.len());
    let mut oc = out.chunks_exact_mut(pool::LANES);
    let mut xc = x.chunks_exact(pool::LANES);
    for (o, xv) in (&mut oc).zip(&mut xc) {
        for l in 0..pool::LANES {
            o[l] += a * xv[l];
        }
    }
    for (o, xv) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += a * xv;
    }
}

/// `y[b,n] = x[b,k] · w[n,k]ᵀ + bias[n]`. Partitions the batch axis when
/// it can feed every lane, the output axis otherwise; either partition
/// computes each element with its kernel family's fixed reduction
/// (`PROXCOMP_KERNEL=blocked` → [`blocked_dot`] plus the bias;
/// `scalar` → sequential ascending-k starting from the bias), so results
/// are bit-identical for any thread count.
pub fn fc_forward(x: &[f32], b: usize, k: usize, w: &[f32], bias: &[f32], n: usize, threads: usize) -> Vec<f32> {
    debug_assert_eq!(x.len(), b * k);
    debug_assert_eq!(w.len(), n * k);
    debug_assert_eq!(bias.len(), n);
    let blocked = pool::kernel_mode() == pool::KernelMode::Blocked;
    let mut y = vec![0.0f32; b * n];
    let ptr = pool::SharedMut::new(&mut y);
    let cell = |bi: usize, o: usize| -> f32 {
        let xrow = &x[bi * k..(bi + 1) * k];
        let wrow = &w[o * k..(o + 1) * k];
        if blocked {
            return bias[o] + blocked_dot(xrow, wrow);
        }
        let mut acc = bias[o];
        for kk in 0..k {
            acc += xrow[kk] * wrow[kk];
        }
        acc
    };
    if pool::batch_saturates(b, threads) {
        pool::parallel_chunks(b, threads, |r0, r1| {
            let y = unsafe { ptr.slice() };
            for bi in r0..r1 {
                for o in 0..n {
                    y[bi * n + o] = cell(bi, o);
                }
            }
        });
    } else {
        pool::parallel_chunks(n, threads, |c0, c1| {
            let y = unsafe { ptr.slice() };
            for o in c0..c1 {
                for bi in 0..b {
                    y[bi * n + o] = cell(bi, o);
                }
            }
        });
    }
    y
}

/// Weight gradient `dw[n,k] = Σ_b dy[b,n]·x[b,k]`, partitioned over the
/// output-row axis; the batch reduction runs in ascending order on one
/// thread per row, so the sum order never depends on the thread count.
pub fn fc_grad_w(dy: &[f32], b: usize, n: usize, x: &[f32], k: usize, threads: usize) -> Vec<f32> {
    debug_assert_eq!(dy.len(), b * n);
    debug_assert_eq!(x.len(), b * k);
    let mut dw = vec![0.0f32; n * k];
    let ptr = pool::SharedMut::new(&mut dw);
    pool::parallel_chunks(n, threads, |c0, c1| {
        let dw = unsafe { ptr.slice() };
        for o in c0..c1 {
            let row = &mut dw[o * k..(o + 1) * k];
            for bi in 0..b {
                let g = dy[bi * n + o];
                if g == 0.0 {
                    continue;
                }
                // Chunked axpy: one add per element per batch row, so
                // the ascending-batch sum order is unchanged.
                axpy_blocked(row, &x[bi * k..(bi + 1) * k], g);
            }
        }
    });
    dw
}

/// Bias gradient `db[n] = Σ_b dy[b,n]` (ascending-batch reduction).
pub fn fc_grad_b(dy: &[f32], b: usize, n: usize) -> Vec<f32> {
    let mut db = vec![0.0f32; n];
    for bi in 0..b {
        for o in 0..n {
            db[o] += dy[bi * n + o];
        }
    }
    db
}

/// Input gradient `dx[b,k] = Σ_o dy[b,o]·w[o,k]`, batch- or
/// column-partitioned with a fixed per-element reduction: blocked mode
/// puts term `o` in lane `o % pool::LANES` (the strided `w` column is a
/// gather, so the lane loop is explicit rather than chunked), scalar
/// mode sums ascending-o — either way bit-identical for any threads.
pub fn fc_grad_x(dy: &[f32], b: usize, n: usize, w: &[f32], k: usize, threads: usize) -> Vec<f32> {
    debug_assert_eq!(dy.len(), b * n);
    debug_assert_eq!(w.len(), n * k);
    let blocked = pool::kernel_mode() == pool::KernelMode::Blocked;
    let mut dx = vec![0.0f32; b * k];
    let ptr = pool::SharedMut::new(&mut dx);
    let cell = |bi: usize, kk: usize| -> f32 {
        if blocked {
            let mut acc = [0.0f32; pool::LANES];
            for o in 0..n {
                acc[o % pool::LANES] += dy[bi * n + o] * w[o * k + kk];
            }
            return pool::tree_reduce(acc);
        }
        let mut acc = 0.0f32;
        for o in 0..n {
            acc += dy[bi * n + o] * w[o * k + kk];
        }
        acc
    };
    if pool::batch_saturates(b, threads) {
        pool::parallel_chunks(b, threads, |r0, r1| {
            let dx = unsafe { ptr.slice() };
            for bi in r0..r1 {
                for kk in 0..k {
                    dx[bi * k + kk] = cell(bi, kk);
                }
            }
        });
    } else {
        pool::parallel_chunks(k, threads, |c0, c1| {
            let dx = unsafe { ptr.slice() };
            for kk in c0..c1 {
                for bi in 0..b {
                    dx[bi * k + kk] = cell(bi, kk);
                }
            }
        });
    }
    dx
}

/// Mean softmax cross-entropy over the batch plus `∂loss/∂logits`
/// (`(softmax − onehot)/B`, rows processed in ascending order).
pub fn softmax_ce(logits: &[f32], labels: &[i32], b: usize, ncls: usize) -> (f32, Vec<f32>) {
    debug_assert_eq!(logits.len(), b * ncls);
    debug_assert_eq!(labels.len(), b);
    let mut loss = 0.0f32;
    let mut dlogits = vec![0.0f32; b * ncls];
    let inv_b = 1.0 / b as f32;
    for bi in 0..b {
        let row = &logits[bi * ncls..(bi + 1) * ncls];
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for &v in row {
            z += (v - m).exp();
        }
        let label = labels[bi] as usize;
        loss += -(row[label] - m) + z.ln();
        let drow = &mut dlogits[bi * ncls..(bi + 1) * ncls];
        for (j, &v) in row.iter().enumerate() {
            drow[j] = (v - m).exp() / z * inv_b;
        }
        drow[label] -= inv_b;
    }
    (loss * inv_b, dlogits)
}

// ---------------------------------------------------------------------------
// Update rules (paper Algorithms 1-2 + the debias/MM variants)
// ---------------------------------------------------------------------------

/// One Prox-ADAM step, elementwise: the bias-corrected ADAM update
/// followed by the ℓ1 proximal operator with threshold `lr·λ`. `t` is
/// the post-increment step count; pass `lambda = 0` to skip the prox
/// (biases / dense baselines — λ=0 makes it the identity anyway).
pub fn prox_adam_update(w: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32], t: f32, lr: f32, lambda: f32) {
    let bc1 = 1.0 - BETA1.powf(t);
    let bc2 = 1.0 - BETA2.powf(t);
    for i in 0..w.len() {
        m[i] = BETA1 * m[i] + (1.0 - BETA1) * g[i];
        v[i] = BETA2 * v[i] + (1.0 - BETA2) * g[i] * g[i];
        let mhat = m[i] / bc1;
        let vhat = v[i] / bc2;
        w[i] -= lr * mhat / (vhat.sqrt() + EPS);
    }
    if lambda > 0.0 {
        prox::soft_threshold_inplace(w, lr * lambda);
    }
}

/// One Prox-RMSProp step: accumulator update, scaled descent, prox.
pub fn prox_rmsprop_update(w: &mut [f32], g: &[f32], v: &mut [f32], lr: f32, lambda: f32) {
    for i in 0..w.len() {
        v[i] = RMS_RHO * v[i] + (1.0 - RMS_RHO) * g[i] * g[i];
        w[i] -= lr * g[i] / (v[i].sqrt() + EPS);
    }
    if lambda > 0.0 {
        prox::soft_threshold_inplace(w, lr * lambda);
    }
}

/// One Prox-SGD step: plain descent, prox.
pub fn prox_sgd_update(w: &mut [f32], g: &[f32], lr: f32, lambda: f32) {
    for i in 0..w.len() {
        w[i] -= lr * g[i];
    }
    if lambda > 0.0 {
        prox::soft_threshold_inplace(w, lr * lambda);
    }
}

/// One SGD-momentum step (the MM L-step optimizer).
pub fn momentum_update(w: &mut [f32], g: &[f32], m: &mut [f32], lr: f32) {
    for i in 0..w.len() {
        m[i] = MM_MOMENTUM * m[i] + g[i];
        w[i] -= lr * m[i];
    }
}

// ---------------------------------------------------------------------------
// The executor
// ---------------------------------------------------------------------------

/// Which training-family step an artifact path names.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum StepKind {
    ProxAdam,
    ProxRmsprop,
    ProxSgd,
    Masked,
    Mm,
    Eval,
    Infer,
}

impl StepKind {
    fn parse(step: &str) -> anyhow::Result<StepKind> {
        Ok(match step {
            "train_prox_adam" => StepKind::ProxAdam,
            "train_prox_rmsprop" => StepKind::ProxRmsprop,
            "train_prox_sgd" => StepKind::ProxSgd,
            "train_masked" => StepKind::Masked,
            "train_mm" => StepKind::Mm,
            "eval" => StepKind::Eval,
            "infer" => StepKind::Infer,
            other => anyhow::bail!("native backend has no step {other:?}"),
        })
    }
}

/// One decoded f32 input leaf.
struct Leaf {
    shape: Vec<usize>,
    data: Vec<f32>,
}

fn decode_f32(lit: &xla::Literal) -> anyhow::Result<Leaf> {
    let shape: Vec<usize> = lit.array_shape()?.dims().iter().map(|&d| d as usize).collect();
    Ok(Leaf { shape, data: lit.to_vec::<f32>()? })
}

fn decode_scalar(lit: &xla::Literal) -> anyhow::Result<f32> {
    let leaf = decode_f32(lit)?;
    anyhow::ensure!(leaf.data.len() == 1, "expected scalar literal, got shape {:?}", leaf.shape);
    Ok(leaf.data[0])
}

/// Pool window/stride applied after every conv stage — the `lenet`
/// stage structure `inference::engine` wires for serving.
pub const POOL: usize = 2;

/// Conv geometry of the native stage graph (the engine's `lenet`
/// wiring: valid convolution, unit stride).
const CONV_SPEC: ConvSpec = ConvSpec { stride: 1, pad: 0 };

/// One executable stage decoded from the leaf shapes. In the `lenet`
/// family a 4-D leaf is a conv filter bank (its 1-D bias follows; a
/// 2×2 max-pool follows the conv) and a 2-D leaf a fully-connected
/// weight (ReLU after every fc but the head). When a conv's bias is
/// followed by a batch-norm quadruple (four 1-D leaves of the conv's
/// output width: scale, bias, mean, var) the leaf list describes the
/// engine's `resnet` graph instead: same-convs without pooling, explicit
/// BatchNorm/Relu stages, residual save/add markers around each two-conv
/// block and a global-average-pool before the head. All fields index the
/// flat leaf list.
#[derive(Debug, Clone, Copy)]
enum Stage {
    Conv { w: usize, b: usize, o: usize, c: usize, kh: usize, kw: usize, spec: ConvSpec, pool: bool },
    BatchNorm { scale: usize, bias: usize, mean: usize, var: usize, c: usize },
    Relu,
    SaveResidual,
    AddResidual,
    GlobalAvgPool,
    Fc { w: usize, b: usize, out: usize, inp: usize },
}

/// One `(weight, bias[, bn quadruple])` unit scanned from the leaf list.
struct LeafUnit {
    w: usize,
    conv: bool,
    bn: Option<[usize; 4]>,
}

/// Pair `(weight, bias)` leaves into the conv/pool/fc stage list — or,
/// when batch-norm quadruples are present, the residual stage graph.
fn build_stages(leaves: &[Leaf]) -> anyhow::Result<Vec<Stage>> {
    // Scan the flat leaf list into structural units first.
    let mut units: Vec<LeafUnit> = Vec::new();
    let mut i = 0;
    while i < leaves.len() {
        let w = &leaves[i];
        let b = leaves.get(i + 1).ok_or_else(|| anyhow::anyhow!("weight leaf {i} has no bias leaf"))?;
        let out = w.shape.first().copied().unwrap_or(0);
        anyhow::ensure!(
            b.shape.len() == 1 && b.shape[0] == out,
            "leaf {}: bias shape {:?} does not match weight leading dim {out}",
            i + 1,
            b.shape
        );
        let conv = match w.shape.len() {
            4 => true,
            2 => false,
            other => anyhow::bail!("leaf {i}: expected a 2-D fc or 4-D conv weight, got rank {other}"),
        };
        // A conv bias followed by four 1-D leaves of the output width is
        // a batch-norm quadruple (scale, bias, mean, var) — legacy
        // models never put 1-D leaves there (the next leaf is always the
        // next stage's 2-D/4-D weight).
        let bn = if conv
            && i + 5 < leaves.len()
            && (2..6).all(|k| leaves[i + k].shape.len() == 1 && leaves[i + k].shape[0] == out)
        {
            Some([i + 2, i + 3, i + 4, i + 5])
        } else {
            None
        };
        units.push(LeafUnit { w: i, conv, bn });
        i += if bn.is_some() { 6 } else { 2 };
    }
    anyhow::ensure!(!units.is_empty(), "no parameter leaves");
    let first_fc = units.iter().position(|u| !u.conv).unwrap_or(units.len());
    for (ui, u) in units.iter().enumerate() {
        anyhow::ensure!(!(u.conv && ui > first_fc), "leaf {}: conv leaf after an fc leaf", u.w);
    }
    anyhow::ensure!(!units.last().unwrap().conv, "model head must be fully-connected");

    let has_bn = units.iter().any(|u| u.bn.is_some());
    let mut stages = Vec::new();
    if has_bn {
        // Residual (resnet) graph: stem conv/bn/relu, then two-conv
        // residual blocks, then global-average-pool and the fc chain.
        let conv_units = &units[..first_fc];
        anyhow::ensure!(
            conv_units.iter().all(|u| u.bn.is_some()),
            "batch-norm models require a bn quadruple on every conv leaf"
        );
        anyhow::ensure!(
            conv_units.len() % 2 == 1,
            "residual graph needs an odd conv count (stem + 2·blocks), got {}",
            conv_units.len()
        );
        let stem_o = leaves[conv_units[0].w].shape[0];
        for (ui, u) in conv_units.iter().enumerate() {
            let ws = &leaves[u.w].shape;
            let (o, c, kh, kw) = (ws[0], ws[1], ws[2], ws[3]);
            anyhow::ensure!(kh == kw && kh % 2 == 1, "leaf {}: resnet convs must be odd square kernels", u.w);
            anyhow::ensure!(o == stem_o, "leaf {}: resnet conv width {o} != stem width {stem_o}", u.w);
            if ui > 0 {
                anyhow::ensure!(c == stem_o, "leaf {}: resnet conv fan-in {c} != width {stem_o}", u.w);
            }
            let [scale, bias, mean, var] = u.bn.unwrap();
            // Residual blocks start on every odd unit (stem is unit 0).
            if ui % 2 == 1 {
                stages.push(Stage::SaveResidual);
            }
            stages.push(Stage::Conv {
                w: u.w,
                b: u.w + 1,
                o,
                c,
                kh,
                kw,
                spec: ConvSpec { stride: 1, pad: (kh - 1) / 2 },
                pool: false,
            });
            stages.push(Stage::BatchNorm { scale, bias, mean, var, c: o });
            if ui == 0 || ui % 2 == 1 {
                // Stem and each block's first conv: plain ReLU. Each
                // block's second conv ReLUs inside AddResidual instead.
                stages.push(Stage::Relu);
            } else {
                stages.push(Stage::AddResidual);
            }
        }
        stages.push(Stage::GlobalAvgPool);
    } else {
        for u in &units[..first_fc] {
            let ws = &leaves[u.w].shape;
            stages.push(Stage::Conv {
                w: u.w,
                b: u.w + 1,
                o: ws[0],
                c: ws[1],
                kh: ws[2],
                kw: ws[3],
                spec: CONV_SPEC,
                pool: true,
            });
        }
    }
    for u in &units[first_fc..] {
        let ws = &leaves[u.w].shape;
        stages.push(Stage::Fc { w: u.w, b: u.w + 1, out: ws[0], inp: ws[1] });
    }
    for pair in stages.windows(2) {
        match (pair[0], pair[1]) {
            (Stage::Fc { out, .. }, Stage::Fc { inp, .. }) => {
                anyhow::ensure!(inp == out, "fc widths do not chain: {out} -> {inp}");
            }
            (Stage::Conv { o, .. }, Stage::Conv { c, .. }) => {
                anyhow::ensure!(c == o, "conv channels do not chain: {o} -> {c}");
            }
            // Conv → fc flattening is validated against x at forward time
            // (the flat width depends on the input's spatial size).
            _ => {}
        }
    }
    Ok(stages)
}

/// Head width (`build_stages` guarantees the last stage is fc).
fn head_classes(stages: &[Stage]) -> usize {
    match stages.last() {
        Some(Stage::Fc { out, .. }) => *out,
        _ => 0,
    }
}

/// Leaf indices of batch-norm running statistics (mean/var): frozen in
/// the gradient path, excluded from every optimizer and the MM pull,
/// EMA-updated instead (see [`BN_MOMENTUM`]).
fn stat_leaf_indices(stages: &[Stage]) -> std::collections::HashSet<usize> {
    stages
        .iter()
        .filter_map(|s| match s {
            Stage::BatchNorm { mean, var, .. } => Some([*mean, *var]),
            _ => None,
        })
        .flatten()
        .collect()
}

/// Per-conv-stage tensors cached by forward for the backward pass.
struct ConvCache {
    /// im2col unfold of the stage input, `(B·OH·OW, C·KH·KW)`.
    cols: Tensor,
    /// Pre-pool conv output `(B, O, OH, OW)` — the pool argmax source.
    conv_out: Tensor,
}

/// Forward activations: `acts[s]` is the input to stage `s` (NCHW for
/// conv stages, `(B, D)` flattened for fc stages); the extra last entry
/// is the raw logits. `caches[s]` holds what conv backward reuses.
struct ForwardPass {
    acts: Vec<Tensor>,
    caches: Vec<Option<ConvCache>>,
    batch: usize,
}

/// Scatter a `(B·OH·OW, O)` matmul output into NCHW — the same
/// transpose the serving engine's `conv_via_csr` applies.
fn nchw_from_rows(y: &[f32], b: usize, o: usize, oh: usize, ow: usize) -> Tensor {
    let mut out = vec![0.0f32; b * o * oh * ow];
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = (bi * oh + oy) * ow + ox;
                for oc in 0..o {
                    out[((bi * o + oc) * oh + oy) * ow + ox] = y[row * o + oc];
                }
            }
        }
    }
    Tensor::new(vec![b, o, oh, ow], out)
}

/// Inverse of [`nchw_from_rows`]: gather NCHW into `(B·OH·OW, O)` rows.
fn rows_from_nchw(t: &Tensor) -> Vec<f32> {
    let (b, o, oh, ow) = (t.shape[0], t.shape[1], t.shape[2], t.shape[3]);
    let mut out = vec![0.0f32; t.numel()];
    for bi in 0..b {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = (bi * oh + oy) * ow + ox;
                for oc in 0..o {
                    out[row * o + oc] = t.data[((bi * o + oc) * oh + oy) * ow + ox];
                }
            }
        }
    }
    out
}

fn forward(stages: &[Stage], leaves: &[Leaf], x: &Leaf, threads: usize) -> anyhow::Result<ForwardPass> {
    anyhow::ensure!(!x.shape.is_empty(), "input x must be batched");
    let batch = x.shape[0];
    let mut h = Tensor::new(x.shape.clone(), x.data.clone());
    let mut acts: Vec<Tensor> = Vec::with_capacity(stages.len() + 1);
    let mut caches: Vec<Option<ConvCache>> = Vec::with_capacity(stages.len());
    let mut residual: Option<Tensor> = None;
    let last = stages.len() - 1;
    for (s, stage) in stages.iter().enumerate() {
        match *stage {
            Stage::Conv { w: wi, b: bi, o, c, kh, kw, spec, pool } => {
                anyhow::ensure!(
                    h.rank() == 4 && h.shape[1] == c,
                    "conv stage {s} expects (B, {c}, H, W) input, got {:?}",
                    h.shape
                );
                let (ih, iw) = (h.shape[2], h.shape[3]);
                anyhow::ensure!(ih >= kh && iw >= kw, "conv stage {s}: {kh}x{kw} kernel exceeds {ih}x{iw} input");
                let oh = tensor::out_dim(ih, kh, spec.stride, spec.pad);
                let ow = tensor::out_dim(iw, kw, spec.stride, spec.pad);
                if pool {
                    anyhow::ensure!(
                        oh >= POOL && ow >= POOL,
                        "conv stage {s}: {oh}x{ow} output smaller than the {POOL}x{POOL} pool"
                    );
                }
                let cols = tensor::im2col(&h, kh, kw, spec);
                let y = fc_forward(
                    &cols.data,
                    batch * oh * ow,
                    c * kh * kw,
                    &leaves[wi].data,
                    &leaves[bi].data,
                    o,
                    threads,
                );
                let conv_out = nchw_from_rows(&y, batch, o, oh, ow);
                let next = if pool { tensor::max_pool(&conv_out, POOL, POOL) } else { conv_out.clone() };
                acts.push(std::mem::replace(&mut h, next));
                caches.push(Some(ConvCache { cols, conv_out }));
            }
            Stage::BatchNorm { scale, bias, mean, var, c } => {
                anyhow::ensure!(
                    h.rank() == 4 && h.shape[1] == c,
                    "bn stage {s} expects (B, {c}, H, W) input, got {:?}",
                    h.shape
                );
                let out = tensor::batch_norm_inference(
                    &h,
                    &leaves[scale].data,
                    &leaves[bias].data,
                    &leaves[mean].data,
                    &leaves[var].data,
                    crate::inference::engine::BN_EPS,
                );
                acts.push(std::mem::replace(&mut h, out));
                caches.push(None);
            }
            Stage::Relu => {
                let mut out = h.clone();
                for v in out.data.iter_mut() {
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                acts.push(std::mem::replace(&mut h, out));
                caches.push(None);
            }
            Stage::SaveResidual => {
                residual = Some(h.clone());
                acts.push(h.clone());
                caches.push(None);
            }
            Stage::AddResidual => {
                let r = residual
                    .take()
                    .ok_or_else(|| anyhow::anyhow!("add-residual stage {s} without a saved residual"))?;
                anyhow::ensure!(r.shape == h.shape, "residual shape {:?} != main path {:?}", r.shape, h.shape);
                let mut out = h.clone();
                for (v, &rv) in out.data.iter_mut().zip(&r.data) {
                    *v += rv;
                    if *v < 0.0 {
                        *v = 0.0;
                    }
                }
                acts.push(std::mem::replace(&mut h, out));
                caches.push(None);
            }
            Stage::GlobalAvgPool => {
                anyhow::ensure!(h.rank() == 4, "global-avg-pool stage {s} expects NCHW input, got {:?}", h.shape);
                let out = tensor::global_avg_pool(&h);
                acts.push(std::mem::replace(&mut h, out));
                caches.push(None);
            }
            Stage::Fc { w: wi, b: bi, out, inp } => {
                if h.rank() != 2 {
                    let rest: usize = h.shape[1..].iter().product();
                    h = h.reshape(vec![batch, rest]);
                }
                anyhow::ensure!(
                    h.shape[1] == inp,
                    "fc stage {s}: input size {} does not match fan-in {inp}",
                    h.shape[1]
                );
                let mut y = fc_forward(&h.data, batch, inp, &leaves[wi].data, &leaves[bi].data, out, threads);
                if s < last {
                    for v in y.iter_mut() {
                        if *v < 0.0 {
                            *v = 0.0;
                        }
                    }
                }
                acts.push(std::mem::replace(&mut h, Tensor::new(vec![batch, out], y)));
                caches.push(None);
            }
        }
    }
    acts.push(h);
    Ok(ForwardPass { acts, caches, batch })
}

/// Backward pass from `dlogits`; returns per-leaf gradients aligned with
/// the leaf order (weight grads at weight indices, bias grads at bias
/// indices). Conv gradients use the im2col formulation: weight grad =
/// colsᵀ·dy, input grad = `col2im(dy·W)`, with the max-pool gradient
/// routed by `tensor::max_pool_backward` first.
fn backward(stages: &[Stage], leaves: &[Leaf], fwd: &ForwardPass, dlogits: Vec<f32>, threads: usize) -> Vec<Vec<f32>> {
    let bsz = fwd.batch;
    let mut grads: Vec<Vec<f32>> = leaves.iter().map(|_| Vec::new()).collect();
    let mut dz = Tensor::new(vec![bsz, head_classes(stages)], dlogits);
    let mut residual_grad: Option<Tensor> = None;
    for s in (0..stages.len()).rev() {
        match stages[s] {
            Stage::Fc { w: wi, b: bi, out, inp } => {
                let input = &fwd.acts[s];
                grads[wi] = fc_grad_w(&dz.data, bsz, out, &input.data, inp, threads);
                grads[bi] = fc_grad_b(&dz.data, bsz, out);
                if s == 0 {
                    break;
                }
                let mut dx = fc_grad_x(&dz.data, bsz, out, &leaves[wi].data, inp, threads);
                if matches!(stages[s - 1], Stage::Fc { .. }) {
                    // ReLU gate: the stored activation is max(z, 0), so a
                    // zero activation means a blocked gradient. A conv
                    // stage ends in a max-pool and a global-avg-pool is
                    // linear — no gate for either.
                    for (d, &a) in dx.iter_mut().zip(&input.data) {
                        if a <= 0.0 {
                            *d = 0.0;
                        }
                    }
                }
                dz = Tensor::new(vec![bsz, inp], dx);
            }
            Stage::Conv { w: wi, b: bi, o, c, kh, kw, spec, pool } => {
                let cache = fwd.caches[s].as_ref().expect("conv stage has a forward cache");
                let (oh, ow) = (cache.conv_out.shape[2], cache.conv_out.shape[3]);
                let dy = if pool {
                    let ph = tensor::out_dim(oh, POOL, POOL, 0);
                    let pw = tensor::out_dim(ow, POOL, POOL, 0);
                    let d_pool = dz.reshape(vec![bsz, o, ph, pw]);
                    let d_conv = tensor::max_pool_backward(&cache.conv_out, &d_pool, POOL, POOL);
                    rows_from_nchw(&d_conv)
                } else {
                    rows_from_nchw(&dz.reshape(vec![bsz, o, oh, ow]))
                };
                let (rows, k) = (bsz * oh * ow, c * kh * kw);
                grads[wi] = fc_grad_w(&dy, rows, o, &cache.cols.data, k, threads);
                grads[bi] = fc_grad_b(&dy, rows, o);
                if s == 0 {
                    break;
                }
                let dcols = fc_grad_x(&dy, rows, o, &leaves[wi].data, k, threads);
                let input = &fwd.acts[s];
                let (ih, iw) = (input.shape[2], input.shape[3]);
                dz = tensor::col2im(&Tensor::new(vec![rows, k], dcols), bsz, c, ih, iw, kh, kw, spec);
            }
            Stage::BatchNorm { scale, bias, mean, var, c } => {
                // Inference-mode BN with frozen running stats is a
                // per-channel affine: dx = dy·g, dscale = Σ dy·x̂,
                // dbias = Σ dy (ascending b,h,w order — deterministic).
                // The running mean/var are stop-gradient: zero-filled
                // grads keep the leaf alignment the optimizer indexes.
                let x = &fwd.acts[s];
                let hw = x.shape[2] * x.shape[3];
                let (sv, mv, vv) = (&leaves[scale].data, &leaves[mean].data, &leaves[var].data);
                let mut dscale = vec![0.0f32; c];
                let mut dbias = vec![0.0f32; c];
                for ci in 0..c {
                    let inv = (vv[ci] + crate::inference::engine::BN_EPS).sqrt().recip();
                    let g = sv[ci] * inv;
                    for bi in 0..bsz {
                        let base = (bi * c + ci) * hw;
                        for j in base..base + hw {
                            let dyv = dz.data[j];
                            dscale[ci] += dyv * (x.data[j] - mv[ci]) * inv;
                            dbias[ci] += dyv;
                            dz.data[j] = dyv * g;
                        }
                    }
                }
                grads[scale] = dscale;
                grads[bias] = dbias;
                grads[mean] = vec![0.0; c];
                grads[var] = vec![0.0; c];
            }
            Stage::Relu => {
                let x = &fwd.acts[s];
                for (d, &a) in dz.data.iter_mut().zip(&x.data) {
                    if a <= 0.0 {
                        *d = 0.0;
                    }
                }
            }
            Stage::AddResidual => {
                // Gate through the fused ReLU (acts[s + 1] is this
                // stage's output), then branch the gradient: one copy
                // rides to the matching SaveResidual, one continues down
                // the conv path.
                let out = &fwd.acts[s + 1];
                for (d, &a) in dz.data.iter_mut().zip(&out.data) {
                    if a <= 0.0 {
                        *d = 0.0;
                    }
                }
                residual_grad = Some(dz.clone());
            }
            Stage::SaveResidual => {
                let r = residual_grad.take().expect("save-residual has a pending residual gradient");
                for (d, &g) in dz.data.iter_mut().zip(&r.data) {
                    *d += g;
                }
            }
            Stage::GlobalAvgPool => {
                let x = &fwd.acts[s];
                let (c, hh, ww) = (x.shape[1], x.shape[2], x.shape[3]);
                let inv = 1.0 / (hh * ww) as f32;
                let mut dx = vec![0.0f32; x.numel()];
                for bi in 0..bsz {
                    for ci in 0..c {
                        let g = dz.data[bi * c + ci] * inv;
                        let base = (bi * c + ci) * hh * ww;
                        for v in dx[base..base + hh * ww].iter_mut() {
                            *v = g;
                        }
                    }
                }
                dz = Tensor::new(x.shape.clone(), dx);
            }
        }
    }
    grads
}

/// The native executor. Stateless between calls (all training state is
/// host-side in the trainer); the struct exists as the dispatch target
/// of [`Backend::Native`](crate::runtime::client::Backend).
#[derive(Debug, Default)]
pub struct NativeBackend {
    steps_executed: u64,
}

impl NativeBackend {
    pub fn new() -> NativeBackend {
        NativeBackend { steps_executed: 0 }
    }

    /// How many artifact executions this backend has run (visible in
    /// place of the PJRT executable-cache counter).
    pub fn steps_executed(&self) -> u64 {
        self.steps_executed
    }

    /// Execute a `native/<model>/<step>` artifact against role-ordered
    /// input literals; returns role-ordered host values, mirroring
    /// `PjRtLoadedExecutable::execute` + tuple unpacking.
    pub fn execute(&mut self, path: &Path, inputs: &[xla::Literal]) -> anyhow::Result<Vec<HostValue>> {
        let (model, step) = parse_path(path)?;
        let kind = StepKind::parse(&step)?;
        self.steps_executed += 1;
        let threads = pool::max_threads();
        let t0 = std::time::Instant::now();
        let result = match kind {
            StepKind::Eval => eval_step(inputs, threads),
            StepKind::Infer => infer_step(inputs, threads),
            _ => train_step(kind, inputs, threads),
        };
        if crate::telemetry::trace_enabled() {
            crate::telemetry::event_label(
                "native.step",
                0,
                &format!("{model}/{step}"),
                &[
                    ("us", t0.elapsed().as_secs_f64() * 1e6),
                    ("ok", result.is_ok() as u8 as f64),
                    ("n", self.steps_executed as f64),
                ],
            );
        }
        result
    }
}

/// Split `inputs` per the step signature (see [`step_artifact`]); the
/// leaf count L is recovered from the literal count, which the role
/// layout determines uniquely per step.
fn leaf_count(kind: StepKind, n_inputs: usize) -> anyhow::Result<usize> {
    let (num, den) = match kind {
        StepKind::ProxAdam | StepKind::ProxRmsprop | StepKind::ProxSgd => (n_inputs as i64 - 5, 3),
        StepKind::Masked => (n_inputs as i64 - 4, 4),
        StepKind::Mm => (n_inputs as i64 - 5, 4),
        StepKind::Eval => (n_inputs as i64 - 2, 1),
        StepKind::Infer => (n_inputs as i64 - 1, 1),
    };
    anyhow::ensure!(num > 0 && num % den == 0, "native {kind:?}: {n_inputs} inputs do not fit the step signature");
    Ok((num / den) as usize)
}

fn decode_leaves(lits: &[xla::Literal]) -> anyhow::Result<Vec<Leaf>> {
    lits.iter().map(decode_f32).collect()
}

fn leaf_host_values(leaves: Vec<Leaf>) -> Vec<HostValue> {
    leaves.into_iter().map(|l| HostValue::F32 { shape: l.shape, data: l.data }).collect()
}

/// The role-ordered tail of a training-step input list (everything past
/// the parameter leaves), parsed per the step signature.
struct TrainInputs {
    opt_m: Vec<Leaf>,
    opt_v: Vec<Leaf>,
    theta: Option<Vec<Leaf>>,
    lagrange: Option<Vec<Leaf>>,
    masks: Option<Vec<Leaf>>,
    t_in: f32,
    x: Leaf,
    y: Vec<i32>,
    lambda: f32,
    lr: f32,
    mu: f32,
}

fn parse_train_inputs(kind: StepKind, nl: usize, inputs: &[xla::Literal]) -> anyhow::Result<TrainInputs> {
    match kind {
        StepKind::ProxAdam | StepKind::ProxRmsprop | StepKind::ProxSgd => Ok(TrainInputs {
            opt_m: decode_leaves(&inputs[nl..2 * nl])?,
            opt_v: decode_leaves(&inputs[2 * nl..3 * nl])?,
            theta: None,
            lagrange: None,
            masks: None,
            t_in: decode_scalar(&inputs[3 * nl])?,
            x: decode_f32(&inputs[3 * nl + 1])?,
            y: inputs[3 * nl + 2].to_vec::<i32>()?,
            lambda: decode_scalar(&inputs[3 * nl + 3])?,
            lr: decode_scalar(&inputs[3 * nl + 4])?,
            mu: 0.0,
        }),
        StepKind::Masked => Ok(TrainInputs {
            opt_m: decode_leaves(&inputs[nl..2 * nl])?,
            opt_v: decode_leaves(&inputs[2 * nl..3 * nl])?,
            theta: None,
            lagrange: None,
            masks: Some(decode_leaves(&inputs[3 * nl..4 * nl])?),
            t_in: decode_scalar(&inputs[4 * nl])?,
            x: decode_f32(&inputs[4 * nl + 1])?,
            y: inputs[4 * nl + 2].to_vec::<i32>()?,
            lambda: 0.0,
            lr: decode_scalar(&inputs[4 * nl + 3])?,
            mu: 0.0,
        }),
        StepKind::Mm => Ok(TrainInputs {
            opt_m: decode_leaves(&inputs[nl..2 * nl])?,
            opt_v: Vec::new(),
            theta: Some(decode_leaves(&inputs[2 * nl..3 * nl])?),
            lagrange: Some(decode_leaves(&inputs[3 * nl..4 * nl])?),
            masks: None,
            t_in: decode_scalar(&inputs[4 * nl])?,
            x: decode_f32(&inputs[4 * nl + 1])?,
            y: inputs[4 * nl + 2].to_vec::<i32>()?,
            lambda: 0.0,
            lr: decode_scalar(&inputs[4 * nl + 3])?,
            mu: decode_scalar(&inputs[4 * nl + 4])?,
        }),
        StepKind::Eval | StepKind::Infer => anyhow::bail!("not a training step"),
    }
}

fn train_step(kind: StepKind, inputs: &[xla::Literal], threads: usize) -> anyhow::Result<Vec<HostValue>> {
    let nl = leaf_count(kind, inputs.len())?;
    let mut params = decode_leaves(&inputs[..nl])?;
    let stages = build_stages(&params)?;
    let TrainInputs { mut opt_m, mut opt_v, theta, lagrange, masks, t_in, x, y, lambda, lr, mu } =
        parse_train_inputs(kind, nl, inputs)?;
    let batch = x.shape.first().copied().unwrap_or(0);
    anyhow::ensure!(y.len() == batch, "labels length {} != batch {batch}", y.len());

    let fwd = forward(&stages, &params, &x, threads)?;
    let ncls = head_classes(&stages);
    let (loss, dlogits) = softmax_ce(&fwd.acts.last().unwrap().data, &y, batch, ncls);
    let mut grads = backward(&stages, &params, &fwd, dlogits, threads);

    // Masked training (debias, Section 2.4): gradients gated by the 0/1
    // mask, weights re-clamped after the step so pruned entries stay
    // exactly zero even under optimizer epsilon noise.
    if let Some(masks) = &masks {
        for (g, m) in grads.iter_mut().zip(masks) {
            anyhow::ensure!(g.len() == m.data.len(), "mask/grad length mismatch");
            for (gi, &mi) in g.iter_mut().zip(&m.data) {
                *gi *= mi;
            }
        }
    }
    // MM L-step (augmented Lagrangian pull): g += μ(w − θ) − λ_mult.
    // BN running stats are not decision variables — no pull.
    let stat_leaves = stat_leaf_indices(&stages);
    if let (Some(theta), Some(lagrange)) = (&theta, &lagrange) {
        for i in 0..params.len() {
            if stat_leaves.contains(&i) {
                continue;
            }
            let (w, th, lg) = (&params[i].data, &theta[i].data, &lagrange[i].data);
            let g = &mut grads[i];
            for j in 0..g.len() {
                g[j] += mu * (w[j] - th[j]) - lg[j];
            }
        }
    }

    let t_out = t_in + 1.0;
    for (i, leaf) in params.iter_mut().enumerate() {
        // BN running stats bypass the optimizer entirely (EMA below).
        if stat_leaves.contains(&i) {
            continue;
        }
        // Weight leaves (2-D fc; 4-D conv, i.e. the filters on their
        // flattened (O, C·KH·KW) view — the prox is elementwise, so the
        // view is exactly the CSR matrix the engine serves) see the
        // prox; 1-D biases never do.
        let leaf_lambda = if leaf.shape.len() >= 2 { lambda } else { 0.0 };
        match kind {
            StepKind::ProxAdam | StepKind::Masked => {
                prox_adam_update(
                    &mut leaf.data,
                    &grads[i],
                    &mut opt_m[i].data,
                    &mut opt_v[i].data,
                    t_out,
                    lr,
                    leaf_lambda,
                );
            }
            StepKind::ProxRmsprop => {
                prox_rmsprop_update(&mut leaf.data, &grads[i], &mut opt_v[i].data, lr, leaf_lambda);
            }
            StepKind::ProxSgd => {
                prox_sgd_update(&mut leaf.data, &grads[i], lr, leaf_lambda);
            }
            StepKind::Mm => {
                momentum_update(&mut leaf.data, &grads[i], &mut opt_m[i].data, lr);
            }
            StepKind::Eval | StepKind::Infer => unreachable!(),
        }
        if let Some(masks) = &masks {
            for (w, &mi) in leaf.data.iter_mut().zip(&masks[i].data) {
                *w *= mi;
            }
        }
    }

    // BN running stats: EMA toward this minibatch's per-channel moments,
    // computed with the same f64 accumulation and ascending scan order
    // as `tensor::batch_norm` — deterministic for any thread count.
    for (si, stage) in stages.iter().enumerate() {
        if let Stage::BatchNorm { mean, var, c, .. } = *stage {
            let x = &fwd.acts[si];
            let hw = x.shape[2] * x.shape[3];
            let n = (batch * hw) as f64;
            for ci in 0..c {
                let (mut sum, mut sq) = (0.0f64, 0.0f64);
                for bi in 0..batch {
                    let base = (bi * c + ci) * hw;
                    for &v in &x.data[base..base + hw] {
                        sum += v as f64;
                        sq += (v as f64) * (v as f64);
                    }
                }
                let bmean = (sum / n) as f32;
                let bvar = (sq / n) as f32 - bmean * bmean;
                let m = &mut params[mean].data[ci];
                *m = (1.0 - BN_MOMENTUM) * *m + BN_MOMENTUM * bmean;
                let v = &mut params[var].data[ci];
                *v = (1.0 - BN_MOMENTUM) * *v + BN_MOMENTUM * bvar;
            }
        }
    }

    let mut out = leaf_host_values(params);
    out.extend(leaf_host_values(opt_m));
    if kind != StepKind::Mm {
        out.extend(leaf_host_values(opt_v));
    }
    out.push(HostValue::scalar_f32(t_out));
    out.push(HostValue::scalar_f32(loss));
    Ok(out)
}

fn eval_step(inputs: &[xla::Literal], threads: usize) -> anyhow::Result<Vec<HostValue>> {
    let nl = leaf_count(StepKind::Eval, inputs.len())?;
    let params = decode_leaves(&inputs[..nl])?;
    let stages = build_stages(&params)?;
    let x = decode_f32(&inputs[nl])?;
    let y = inputs[nl + 1].to_vec::<i32>()?;
    let fwd = forward(&stages, &params, &x, threads)?;
    let ncls = head_classes(&stages);
    let (loss, _) = softmax_ce(&fwd.acts.last().unwrap().data, &y, fwd.batch, ncls);
    let logits = &fwd.acts.last().unwrap().data;
    let mut correct = 0usize;
    for bi in 0..fwd.batch {
        let row = &logits[bi * ncls..(bi + 1) * ncls];
        // total_cmp: NaN logits (diverged weights) must not panic the
        // executor — every other malformed state errors, not aborts.
        let pred = row.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).map(|(j, _)| j).unwrap();
        if pred == y[bi] as usize {
            correct += 1;
        }
    }
    Ok(vec![HostValue::scalar_f32(loss), HostValue::scalar_f32(correct as f32)])
}

fn infer_step(inputs: &[xla::Literal], threads: usize) -> anyhow::Result<Vec<HostValue>> {
    let nl = leaf_count(StepKind::Infer, inputs.len())?;
    let params = decode_leaves(&inputs[..nl])?;
    let stages = build_stages(&params)?;
    let x = decode_f32(&inputs[nl])?;
    let fwd = forward(&stages, &params, &x, threads)?;
    let ncls = head_classes(&stages);
    let logits = fwd.acts.last().unwrap().data.clone();
    Ok(vec![HostValue::F32 { shape: vec![fwd.batch, ncls], data: logits }])
}

// ---------------------------------------------------------------------------
// Finite-difference gradient self-check
// ---------------------------------------------------------------------------

/// Relative tolerance one finite-difference direction must meet.
pub const FD_TOL: f32 = 0.05;
/// Random directions probed per check.
pub const FD_DIRECTIONS: usize = 9;
/// Directions that must agree for the check to pass. A single direction
/// can land on a ReLU/max-pool kink (central differences then pick up
/// O(1) curvature error even with a correct backward); a transposed or
/// misindexed gradient fails essentially every direction.
pub const FD_MIN_AGREE: usize = 7;

/// Finite-difference self-check of the executor's backward on `entry`'s
/// architecture: He-init weights, random inputs, [`FD_DIRECTIONS`]
/// random directions; the central-difference directional derivative
/// must agree with ⟨∇L, d⟩ within [`FD_TOL`] relative error on at least
/// [`FD_MIN_AGREE`] directions. Returns `(agreeing, probed)` on
/// success, errors otherwise — `proxcomp pipeline` runs this before
/// training conv models, so a broken conv backward fails the CI gate
/// instead of silently training garbage.
pub fn gradient_check(entry: &ModelEntry, seed: u64, batch: usize) -> anyhow::Result<(usize, usize)> {
    use crate::util::rng::Rng;
    let mut rng = Rng::new(seed ^ 0x6772_6164_6368_6b21); // "gradchk!" salt
    let bundle = crate::runtime::params::ParamBundle::he_init(&entry.params, seed);
    let leaves: Vec<Leaf> = bundle
        .specs
        .iter()
        .zip(&bundle.values)
        .map(|(s, v)| Leaf { shape: s.shape.clone(), data: v.clone() })
        .collect();
    let stages = build_stages(&leaves)?;
    let ncls = head_classes(&stages);
    anyhow::ensure!(ncls > 1 && batch > 0, "gradient check needs classes and a batch");
    let mut x_shape = vec![batch];
    x_shape.extend_from_slice(&entry.input_shape);
    let n_in: usize = x_shape.iter().product();
    let x = Leaf { shape: x_shape, data: rng.normal_vec(n_in, 1.0) };
    let y: Vec<i32> = (0..batch).map(|i| (i % ncls) as i32).collect();

    // Every kernel is bit-deterministic for any thread count, so the
    // 2·FD_DIRECTIONS forward passes can use the full pool for free.
    let threads = pool::max_threads();
    let loss_of = |leaves: &[Leaf]| -> anyhow::Result<f32> {
        let fwd = forward(&stages, leaves, &x, threads)?;
        Ok(softmax_ce(&fwd.acts.last().unwrap().data, &y, batch, ncls).0)
    };
    let fwd = forward(&stages, &leaves, &x, threads)?;
    let (_, dlogits) = softmax_ce(&fwd.acts.last().unwrap().data, &y, batch, ncls);
    let grads = backward(&stages, &leaves, &fwd, dlogits, threads);

    // Scale h so the perturbation norm stays ~1e-2 regardless of model
    // size (directions are unnormalized: ‖d‖ ≈ √numel).
    let numel: usize = leaves.iter().map(|l| l.data.len()).sum();
    let h = 1e-2f32 / (numel as f32).sqrt();
    let mut ok = 0;
    // BN running stats are stop-gradient (zero analytic grads, but the
    // loss *does* depend on them) — perturbing them would corrupt the
    // finite difference, so their direction entries stay zero.
    let stat_leaves = stat_leaf_indices(&stages);
    for _ in 0..FD_DIRECTIONS {
        let dirs: Vec<Vec<f32>> = leaves
            .iter()
            .enumerate()
            .map(|(i, l)| {
                if stat_leaves.contains(&i) {
                    vec![0.0; l.data.len()]
                } else {
                    rng.normal_vec(l.data.len(), 1.0)
                }
            })
            .collect();
        let analytic: f32 =
            grads.iter().zip(&dirs).map(|(g, d)| g.iter().zip(d).map(|(a, b)| a * b).sum::<f32>()).sum();
        let shifted = |sign: f32| -> Vec<Leaf> {
            leaves
                .iter()
                .zip(&dirs)
                .map(|(l, d)| Leaf {
                    shape: l.shape.clone(),
                    data: l.data.iter().zip(d).map(|(w, di)| w + sign * h * di).collect(),
                })
                .collect()
        };
        let numeric = (loss_of(&shifted(1.0))? - loss_of(&shifted(-1.0))?) / (2.0 * h);
        let denom = analytic.abs().max(numeric.abs()).max(0.5);
        if (analytic - numeric).abs() / denom < FD_TOL {
            ok += 1;
        }
    }
    anyhow::ensure!(
        ok >= FD_MIN_AGREE,
        "finite-difference gradient check failed on {}: only {ok}/{FD_DIRECTIONS} directions agree",
        entry.name
    );
    Ok((ok, FD_DIRECTIONS))
}

/// Loss + per-leaf gradients of the native graph at the given bundle's
/// parameter values on one `(x, y)` minibatch — the hook the codebook
/// fine-tune pass (`quant::finetune_codebooks`) descends: it needs raw
/// gradients at arbitrary (dequantized) weights without touching any
/// optimizer state. Gradients come back aligned with the bundle's leaf
/// order and shapes; every kernel underneath is bit-deterministic for
/// any `threads`.
pub fn loss_and_param_grads(
    bundle: &crate::runtime::params::ParamBundle,
    x_shape: &[usize],
    x: &[f32],
    y: &[i32],
    threads: usize,
) -> anyhow::Result<(f32, Vec<Vec<f32>>)> {
    let leaves: Vec<Leaf> = bundle
        .specs
        .iter()
        .zip(&bundle.values)
        .map(|(s, v)| Leaf { shape: s.shape.clone(), data: v.clone() })
        .collect();
    let stages = build_stages(&leaves)?;
    anyhow::ensure!(!x_shape.is_empty(), "x must be batched");
    let batch = x_shape[0];
    anyhow::ensure!(y.len() == batch, "labels length {} != batch {batch}", y.len());
    let x = Leaf { shape: x_shape.to_vec(), data: x.to_vec() };
    let fwd = forward(&stages, &leaves, &x, threads)?;
    let ncls = head_classes(&stages);
    let (loss, dlogits) = softmax_ce(&fwd.acts.last().unwrap().data, y, batch, ncls);
    let grads = backward(&stages, &leaves, &fwd, dlogits, threads);
    Ok((loss, grads))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::client;
    use crate::util::rng::Rng;

    #[test]
    fn native_paths_recognized() {
        assert!(is_native_path(Path::new("native/mlp/train_prox_adam")));
        assert!(!is_native_path(Path::new("artifacts/mlp_infer.hlo.txt")));
        let (m, s) = parse_path(Path::new("native/mlp-s/eval")).unwrap();
        assert_eq!((m.as_str(), s.as_str()), ("mlp-s", "eval"));
        assert!(parse_path(Path::new("native/mlp")).is_err());
    }

    #[test]
    fn mlp_entry_signatures_match_trainer_contract() {
        let entry = mlp_entry("mlp", &[1, 28, 28], &[300, 100], 10, "synth-mnist", 32, 64);
        assert_eq!(entry.params.len(), 6);
        assert_eq!(entry.params[0].shape, vec![300, 784]);
        assert!(entry.params[0].prunable && !entry.params[1].prunable);
        assert_eq!(entry.num_weights, 300 * 784 + 100 * 300 + 10 * 100);
        // Prox steps: params, m, v (3L) + t + x + y + λ + lr.
        let adam = entry.artifact("train_prox_adam").unwrap();
        assert_eq!(adam.inputs.len(), 3 * 6 + 5);
        assert_eq!(adam.inputs.last().unwrap().role, Role::Lr);
        assert_eq!(adam.outputs.len(), 3 * 6 + 2);
        assert_eq!(adam.outputs.last().unwrap().role, Role::Loss);
        // Masked adds one mask leaf per param leaf, drops λ.
        let masked = entry.artifact("train_masked").unwrap();
        assert_eq!(masked.inputs.len(), 4 * 6 + 4);
        assert!(masked.inputs.iter().all(|s| s.role != Role::Lambda));
        // Infer: params + x → logits.
        let infer = entry.artifact("infer").unwrap();
        assert_eq!(infer.inputs.len(), 7);
        assert_eq!(infer.outputs[0].shape, vec![64, 10]);
    }

    #[test]
    fn fc_forward_matches_hand_computation() {
        // x = [[1, 2], [3, 4]], w = [[1, 0], [0, 1], [1, 1]], bias = [0.5, 0, -1]
        let x = [1.0f32, 2.0, 3.0, 4.0];
        let w = [1.0f32, 0.0, 0.0, 1.0, 1.0, 1.0];
        let bias = [0.5f32, 0.0, -1.0];
        let y = fc_forward(&x, 2, 2, &w, &bias, 3, 1);
        assert_eq!(y, vec![1.5, 2.0, 2.0, 3.5, 4.0, 6.0]);
    }

    #[test]
    fn fc_kernels_bit_identical_across_thread_counts() {
        let mut rng = Rng::new(40);
        for (b, k, n) in [(1usize, 17, 9), (6, 13, 21), (16, 33, 5)] {
            let x = rng.normal_vec(b * k, 1.0);
            let w = rng.normal_vec(n * k, 1.0);
            let bias = rng.normal_vec(n, 1.0);
            let dy = rng.normal_vec(b * n, 1.0);
            let f1 = fc_forward(&x, b, k, &w, &bias, n, 1);
            let gw1 = fc_grad_w(&dy, b, n, &x, k, 1);
            let gx1 = fc_grad_x(&dy, b, n, &w, k, 1);
            for threads in [2usize, 4, 8] {
                assert_eq!(f1, fc_forward(&x, b, k, &w, &bias, n, threads), "fwd b={b} t={threads}");
                assert_eq!(gw1, fc_grad_w(&dy, b, n, &x, k, threads), "dw b={b} t={threads}");
                assert_eq!(gx1, fc_grad_x(&dy, b, n, &w, k, threads), "dx b={b} t={threads}");
            }
        }
    }

    #[test]
    fn softmax_ce_uniform_logits() {
        let logits = vec![0.0f32; 2 * 4];
        let (loss, d) = softmax_ce(&logits, &[1, 3], 2, 4);
        assert!((loss - (4.0f32).ln()).abs() < 1e-6);
        // Gradient rows sum to zero and the label entry is negative.
        for bi in 0..2 {
            let row = &d[bi * 4..(bi + 1) * 4];
            let s: f32 = row.iter().sum();
            assert!(s.abs() < 1e-6);
        }
        assert!(d[1] < 0.0 && d[2 * 4 - 1] < 0.0);
    }

    #[test]
    fn prox_adam_shrinks_and_zeroes() {
        // Zero gradient, positive λ: the prox must carve the small weight
        // to exact zero and shrink the big one by exactly lr·λ.
        let mut w = vec![0.5f32, 1e-4];
        let g = vec![0.0f32; 2];
        let mut m = vec![0.0f32; 2];
        let mut v = vec![0.0f32; 2];
        prox_adam_update(&mut w, &g, &mut m, &mut v, 1.0, 0.1, 1.0);
        assert!((w[0] - 0.4).abs() < 1e-6, "{}", w[0]);
        assert_eq!(w[1], 0.0);
    }

    #[test]
    fn adam_with_zero_lambda_is_plain_adam() {
        let mut w = vec![1.0f32];
        let g = vec![1.0f32];
        let mut m = vec![0.0f32];
        let mut v = vec![0.0f32];
        prox_adam_update(&mut w, &g, &mut m, &mut v, 1.0, 0.01, 0.0);
        // Bias-corrected first step moves by ≈ lr·g/|g| = lr.
        assert!((w[0] - 0.99).abs() < 1e-4, "{}", w[0]);
    }

    fn tiny_entry() -> ModelEntry {
        mlp_entry("mlp-t", &[1, 2, 2], &[3], 2, "synth-blobs", 4, 4)
    }

    fn leaf_literals(values: &[(Vec<usize>, Vec<f32>)]) -> Vec<xla::Literal> {
        values.iter().map(|(shape, data)| client::literal_f32(shape, data).unwrap()).collect()
    }

    #[test]
    fn executor_runs_one_adam_step_and_advances_t() {
        let entry = tiny_entry();
        let mut rng = Rng::new(50);
        let mut lits = Vec::new();
        // params, then zero moments, in spec order.
        let leaves: Vec<(Vec<usize>, Vec<f32>)> = entry
            .params
            .iter()
            .map(|s| (s.shape.clone(), rng.normal_vec(s.numel(), 0.5)))
            .collect();
        lits.extend(leaf_literals(&leaves));
        for _ in 0..2 {
            let zeros: Vec<(Vec<usize>, Vec<f32>)> =
                entry.params.iter().map(|s| (s.shape.clone(), vec![0.0; s.numel()])).collect();
            lits.extend(leaf_literals(&zeros));
        }
        lits.push(client::literal_f32(&[], &[0.0]).unwrap()); // t
        lits.push(client::literal_f32(&[4, 1, 2, 2], &rng.normal_vec(16, 1.0)).unwrap());
        lits.push(client::literal_i32(&[4], &[0, 1, 0, 1]).unwrap());
        lits.push(client::literal_f32(&[], &[0.5]).unwrap()); // λ
        lits.push(client::literal_f32(&[], &[0.01]).unwrap()); // lr
        let mut backend = NativeBackend::new();
        let out = backend.execute(Path::new("native/mlp-t/train_prox_adam"), &lits).unwrap();
        // params (4) + m (4) + v (4) + t + loss.
        assert_eq!(out.len(), 3 * 4 + 2);
        assert_eq!(out[out.len() - 2].scalar().unwrap(), 1.0);
        let loss = out[out.len() - 1].scalar().unwrap();
        assert!(loss.is_finite() && loss > 0.0);
        // Weight leaf changed, shape preserved.
        assert_eq!(out[0].shape(), &leaves[0].0[..]);
        assert_ne!(out[0].as_f32().unwrap(), &leaves[0].1[..]);
        assert_eq!(backend.steps_executed(), 1);
    }

    #[test]
    fn backward_matches_finite_differences() {
        // Directional-derivative check: for a random direction d,
        // (L(w+h·d) − L(w−h·d)) / 2h ≈ ⟨∇L, d⟩ — catches any index or
        // transpose slip in the hand-written backward.
        let mut rng = Rng::new(60);
        let dims = [7usize, 5, 4, 3];
        let mut leaves: Vec<Leaf> = Vec::new();
        for i in 1..dims.len() {
            leaves.push(Leaf { shape: vec![dims[i], dims[i - 1]], data: rng.normal_vec(dims[i] * dims[i - 1], 0.5) });
            leaves.push(Leaf { shape: vec![dims[i]], data: rng.normal_vec(dims[i], 0.1) });
        }
        let stages = build_stages(&leaves).unwrap();
        let batch = 6;
        let x = Leaf { shape: vec![batch, dims[0]], data: rng.normal_vec(batch * dims[0], 1.0) };
        let y: Vec<i32> = (0..batch).map(|i| (i % dims[3]) as i32).collect();

        let loss_of = |leaves: &[Leaf]| -> f32 {
            let fwd = forward(&stages, leaves, &x, 1).unwrap();
            softmax_ce(&fwd.acts.last().unwrap().data, &y, batch, dims[3]).0
        };
        let fwd = forward(&stages, &leaves, &x, 1).unwrap();
        let (_, dlogits) = softmax_ce(&fwd.acts.last().unwrap().data, &y, batch, dims[3]);
        let grads = backward(&stages, &leaves, &fwd, dlogits, 1);

        // A single direction can land on a ReLU kink (central differences
        // then pick up O(1) curvature error even with a correct backward),
        // so take 9 directions and require a supermajority to agree — a
        // transposed or misindexed gradient fails every one of them.
        let h = 1e-4f32;
        let mut ok = 0;
        for _ in 0..9 {
            let dirs: Vec<Vec<f32>> = leaves.iter().map(|l| rng.normal_vec(l.data.len(), 1.0)).collect();
            let analytic: f32 =
                grads.iter().zip(&dirs).map(|(g, d)| g.iter().zip(d).map(|(a, b)| a * b).sum::<f32>()).sum();
            let shifted = |sign: f32| -> Vec<Leaf> {
                leaves
                    .iter()
                    .zip(&dirs)
                    .map(|(l, d)| Leaf {
                        shape: l.shape.clone(),
                        data: l.data.iter().zip(d).map(|(w, di)| w + sign * h * di).collect(),
                    })
                    .collect()
            };
            let numeric = (loss_of(&shifted(1.0)) - loss_of(&shifted(-1.0))) / (2.0 * h);
            let denom = analytic.abs().max(numeric.abs()).max(0.5);
            if (analytic - numeric).abs() / denom < 0.05 {
                ok += 1;
            }
        }
        assert!(ok >= 7, "directional-derivative check failed: only {ok}/9 directions agree");
    }

    #[test]
    fn lenet_entry_matches_paper_geometry() {
        // Paper Table A1 LeNet-5: conv1 20@5×5, conv2 50@5×5, fc 800→500→10.
        let entry = lenet_entry(
            "lenet",
            &[1, 28, 28],
            &[(20, 5), (50, 5)],
            &[500],
            10,
            "synth-mnist",
            32,
            64,
        );
        assert_eq!(entry.params.len(), 8);
        assert_eq!(entry.params[0].shape, vec![20, 1, 5, 5]);
        assert_eq!(entry.params[0].kind, "conv_w");
        assert!(entry.params[0].prunable && !entry.params[1].prunable);
        assert_eq!(entry.params[2].shape, vec![50, 20, 5, 5]);
        // 28 → conv5 → 24 → pool → 12 → conv5 → 8 → pool → 4; 50·4·4 = 800.
        assert_eq!(entry.params[4].name, "fc1_w");
        assert_eq!(entry.params[4].shape, vec![500, 800]);
        assert_eq!(entry.params[6].shape, vec![10, 500]);
        assert_eq!(entry.num_weights, 430_500);
        // Same role-slot step signatures as the MLP family.
        let adam = entry.artifact("train_prox_adam").unwrap();
        assert_eq!(adam.inputs.len(), 3 * 8 + 5);
        assert_eq!(adam.outputs.len(), 3 * 8 + 2);
        assert!(is_native_path(&adam.file));
    }

    /// A conv net small enough for exhaustive checks: 1×6×6 input,
    /// conv 2@3×3 → 4×4 → pool → 2×2, flatten 8 → fc 2.
    fn tiny_lenet_entry() -> ModelEntry {
        lenet_entry("lenet-t", &[1, 6, 6], &[(2, 3)], &[], 2, "synth-blobs", 4, 4)
    }

    fn he_leaves(entry: &ModelEntry, seed: u64) -> Vec<Leaf> {
        let bundle = crate::runtime::params::ParamBundle::he_init(&entry.params, seed);
        bundle
            .specs
            .iter()
            .zip(&bundle.values)
            .map(|(s, v)| Leaf { shape: s.shape.clone(), data: v.clone() })
            .collect()
    }

    #[test]
    fn conv_forward_matches_dense_conv2d_and_pool() {
        // The executor's im2col-matmul conv + pool must agree with the
        // reference tensor::conv2d + tensor::max_pool pipeline.
        let entry = tiny_lenet_entry();
        let mut rng = Rng::new(71);
        let leaves = he_leaves(&entry, 7);
        let stages = build_stages(&leaves).unwrap();
        let batch = 3;
        let x = Leaf { shape: vec![batch, 1, 6, 6], data: rng.normal_vec(batch * 36, 1.0) };
        let fwd = forward(&stages, &leaves, &x, 1).unwrap();
        let xt = Tensor::new(x.shape.clone(), x.data.clone());
        let wt = Tensor::new(leaves[0].shape.clone(), leaves[0].data.clone());
        let want = tensor::max_pool(&tensor::conv2d(&xt, &wt, &leaves[1].data, CONV_SPEC), POOL, POOL);
        // acts[1] is the input to the fc stage: the pooled map, flattened.
        assert_eq!(fwd.acts[1].data.len(), want.numel());
        for (got, want) in fwd.acts[1].data.iter().zip(&want.data) {
            assert!((got - want).abs() < 1e-5, "{got} vs {want}");
        }
    }

    #[test]
    fn conv_backward_passes_gradient_check() {
        let (ok, total) = gradient_check(&tiny_lenet_entry(), 3, 5).unwrap();
        assert!(ok >= FD_MIN_AGREE, "{ok}/{total}");
        // A deeper two-conv geometry (odd maps: pool windows that do not
        // divide the input) must also pass.
        let deep = lenet_entry("lenet-t2", &[1, 11, 11], &[(3, 3), (4, 2)], &[6], 3, "synth-blobs", 4, 4);
        gradient_check(&deep, 5, 4).unwrap();
    }

    #[test]
    fn conv_forward_backward_bit_identical_across_thread_counts() {
        let entry = tiny_lenet_entry();
        let mut rng = Rng::new(83);
        let leaves = he_leaves(&entry, 11);
        let stages = build_stages(&leaves).unwrap();
        let batch = 5;
        let x = Leaf { shape: vec![batch, 1, 6, 6], data: rng.normal_vec(batch * 36, 1.0) };
        let y: Vec<i32> = (0..batch).map(|i| (i % 2) as i32).collect();
        let run = |threads: usize| {
            let fwd = forward(&stages, &leaves, &x, threads).unwrap();
            let logits = fwd.acts.last().unwrap().data.clone();
            let (_, dlogits) = softmax_ce(&logits, &y, batch, 2);
            (logits, backward(&stages, &leaves, &fwd, dlogits, threads))
        };
        let (logits1, grads1) = run(1);
        for threads in [2usize, 4, 8] {
            let (logits_t, grads_t) = run(threads);
            assert_eq!(logits1, logits_t, "conv forward diverged at t={threads}");
            assert_eq!(grads1, grads_t, "conv backward diverged at t={threads}");
        }
    }

    #[test]
    fn executor_runs_lenet_adam_step_and_applies_prox_to_filters() {
        let entry = tiny_lenet_entry();
        let mut rng = Rng::new(91);
        let mut lits = Vec::new();
        let leaves: Vec<(Vec<usize>, Vec<f32>)> = entry
            .params
            .iter()
            .map(|s| (s.shape.clone(), rng.normal_vec(s.numel(), 0.5)))
            .collect();
        lits.extend(leaf_literals(&leaves));
        for _ in 0..2 {
            let zeros: Vec<(Vec<usize>, Vec<f32>)> =
                entry.params.iter().map(|s| (s.shape.clone(), vec![0.0; s.numel()])).collect();
            lits.extend(leaf_literals(&zeros));
        }
        lits.push(client::literal_f32(&[], &[0.0]).unwrap()); // t
        lits.push(client::literal_f32(&[4, 1, 6, 6], &rng.normal_vec(4 * 36, 1.0)).unwrap());
        lits.push(client::literal_i32(&[4], &[0, 1, 0, 1]).unwrap());
        lits.push(client::literal_f32(&[], &[50.0]).unwrap()); // λ
        lits.push(client::literal_f32(&[], &[0.05]).unwrap()); // lr
        let mut backend = NativeBackend::new();
        let out = backend.execute(Path::new("native/lenet-t/train_prox_adam"), &lits).unwrap();
        // params (4 leaves) + m + v + t + loss.
        assert_eq!(out.len(), 3 * 4 + 2);
        assert_eq!(out[out.len() - 2].scalar().unwrap(), 1.0);
        assert!(out[out.len() - 1].scalar().unwrap().is_finite());
        // The prox hits the conv filters on their flattened view:
        // threshold lr·λ = 2.5 exceeds any |w₀ ± adam-step| here (weights
        // drawn at std 0.5, step ≈ lr), so every filter entry must be
        // carved to exactly zero.
        let conv_w = out[0].as_f32().unwrap();
        assert_eq!(out[0].shape(), &[2, 1, 3, 3]);
        assert_ne!(conv_w, &leaves[0].1[..]);
        assert!(conv_w.iter().all(|&v| v == 0.0), "prox missed conv filter entries: {conv_w:?}");
        // Conv bias (leaf 1) never sees the prox: no new exact zeros.
        let conv_b = out[1].as_f32().unwrap();
        assert!(conv_b.iter().all(|&v| v != 0.0), "bias was proxed: {conv_b:?}");
    }

    #[test]
    fn executor_rejects_malformed_inputs() {
        let mut backend = NativeBackend::new();
        let lits = vec![client::literal_f32(&[2, 2], &[1.0, 2.0, 3.0, 4.0]).unwrap()];
        assert!(backend.execute(Path::new("native/m/train_prox_adam"), &lits).is_err());
        assert!(backend.execute(Path::new("native/m/bogus_step"), &lits).is_err());
        assert!(backend.execute(Path::new("artifacts/m.hlo.txt"), &lits).is_err());
    }

    /// A residual net small enough for exhaustive checks: 1×6×6 input,
    /// stem + one two-conv block at width 4, GAP, fc 4→3.
    fn tiny_resnet_entry() -> ModelEntry {
        resnet_entry("resnet-t", &[1, 6, 6], 4, 1, 3, "synth-blobs", 4, 4)
    }

    #[test]
    fn resnet_entry_matches_engine_wiring_geometry() {
        let entry = tiny_resnet_entry();
        // Three conv/bn units of 6 leaves each + the fc head pair.
        assert_eq!(entry.params.len(), 20);
        assert_eq!(entry.params[0].name, "conv1_w");
        assert_eq!(entry.params[0].shape, vec![4, 1, 3, 3]);
        assert_eq!(entry.params[2].kind, "bn_scale");
        assert_eq!(entry.params[4].name, "bn1_mean");
        assert_eq!(entry.params[6].name, "conv1-1-1_w");
        assert_eq!(entry.params[6].shape, vec![4, 4, 3, 3]);
        assert_eq!(entry.params[12].name, "conv1-1-2_w");
        assert_eq!(entry.params[18].name, "fc1_w");
        assert_eq!(entry.params[18].shape, vec![3, 4]);
        assert!(entry.params[0].prunable && entry.params[18].prunable);
        assert!(!entry.params[2].prunable && !entry.params[4].prunable);
        // Prunable weights: 36 + 144 + 144 conv + 12 fc.
        assert_eq!(entry.num_weights, 336);
        let adam = entry.artifact("train_prox_adam").unwrap();
        assert_eq!(adam.inputs.len(), 3 * 20 + 5);
        // BN running stats init: unit variance, zero mean.
        let bundle = crate::runtime::params::ParamBundle::he_init(&entry.params, 1);
        assert!(bundle.values[5].iter().all(|&v| v == 1.0));
        assert!(bundle.values[4].iter().all(|&v| v == 0.0));
    }

    #[test]
    fn resnet_forward_matches_serving_engine() {
        let entry = tiny_resnet_entry();
        let mut bundle = crate::runtime::params::ParamBundle::he_init(&entry.params, 21);
        // Nudge running stats off their init so the BN affine is
        // non-trivial in both backends.
        let mut rng = Rng::new(21 ^ 0xBEEF);
        for (spec, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
            match spec.kind.as_str() {
                "bn_mean" => *v = rng.normal_vec(v.len(), 0.2),
                "bn_var" => {
                    for (x, n) in v.iter_mut().zip(rng.normal_vec(v.len(), 0.1)) {
                        *x = 1.0 + n.abs();
                    }
                }
                _ => {}
            }
        }
        let leaves: Vec<Leaf> = bundle
            .specs
            .iter()
            .zip(&bundle.values)
            .map(|(s, v)| Leaf { shape: s.shape.clone(), data: v.clone() })
            .collect();
        let stages = build_stages(&leaves).unwrap();
        let batch = 3;
        let mut xrng = Rng::new(77);
        let x = Leaf { shape: vec![batch, 1, 6, 6], data: xrng.normal_vec(batch * 36, 1.0) };
        let fwd = forward(&stages, &leaves, &x, 1).unwrap();
        let native_logits = &fwd.acts.last().unwrap().data;

        let engine =
            crate::inference::engine::Engine::builder("resnet-t").bundle(&bundle).build().unwrap();
        // Folded running stats, not batch stats: batchable at serve time.
        assert!(!engine.uses_batch_stats());
        let out = engine.forward(&Tensor::new(vec![batch, 1, 6, 6], x.data.clone())).unwrap();
        assert_eq!(out.shape, vec![batch, 3]);
        for (a, b) in native_logits.iter().zip(&out.data) {
            assert!((a - b).abs() < 1e-4, "native {a} vs engine {b}");
        }
    }

    #[test]
    fn resnet_backward_passes_gradient_check() {
        let (ok, total) = gradient_check(&tiny_resnet_entry(), 9, 4).unwrap();
        assert!(ok >= FD_MIN_AGREE, "{ok}/{total}");
    }

    #[test]
    fn resnet_forward_backward_bit_identical_across_thread_counts() {
        let entry = tiny_resnet_entry();
        let mut rng = Rng::new(87);
        let leaves = he_leaves(&entry, 13);
        let stages = build_stages(&leaves).unwrap();
        let batch = 5;
        let x = Leaf { shape: vec![batch, 1, 6, 6], data: rng.normal_vec(batch * 36, 1.0) };
        let y: Vec<i32> = (0..batch).map(|i| (i % 3) as i32).collect();
        let run = |threads: usize| {
            let fwd = forward(&stages, &leaves, &x, threads).unwrap();
            let logits = fwd.acts.last().unwrap().data.clone();
            let (_, dlogits) = softmax_ce(&logits, &y, batch, 3);
            (logits, backward(&stages, &leaves, &fwd, dlogits, threads))
        };
        let (logits1, grads1) = run(1);
        for threads in [2usize, 4, 8] {
            let (logits_t, grads_t) = run(threads);
            assert_eq!(logits1, logits_t, "resnet forward diverged at t={threads}");
            assert_eq!(grads1, grads_t, "resnet backward diverged at t={threads}");
        }
    }

    #[test]
    fn executor_resnet_step_freezes_stats_in_optimizer_and_moves_ema() {
        let entry = tiny_resnet_entry();
        let bundle = crate::runtime::params::ParamBundle::he_init(&entry.params, 15);
        let leaves: Vec<(Vec<usize>, Vec<f32>)> =
            bundle.specs.iter().zip(&bundle.values).map(|(s, v)| (s.shape.clone(), v.clone())).collect();
        let mut lits = Vec::new();
        lits.extend(leaf_literals(&leaves));
        for _ in 0..2 {
            let zeros: Vec<(Vec<usize>, Vec<f32>)> =
                entry.params.iter().map(|s| (s.shape.clone(), vec![0.0; s.numel()])).collect();
            lits.extend(leaf_literals(&zeros));
        }
        let mut rng = Rng::new(93);
        lits.push(client::literal_f32(&[], &[0.0]).unwrap()); // t
        lits.push(client::literal_f32(&[4, 1, 6, 6], &rng.normal_vec(4 * 36, 1.0)).unwrap());
        lits.push(client::literal_i32(&[4], &[0, 1, 2, 0]).unwrap());
        lits.push(client::literal_f32(&[], &[0.0]).unwrap()); // λ
        lits.push(client::literal_f32(&[], &[0.01]).unwrap()); // lr
        let mut backend = NativeBackend::new();
        let out = backend.execute(Path::new("native/resnet-t/train_prox_adam"), &lits).unwrap();
        assert_eq!(out.len(), 3 * 20 + 2);
        assert!(out[out.len() - 1].scalar().unwrap().is_finite());
        // Leaves 4/5 are bn1_mean/bn1_var: the EMA must move them off
        // their zero/unit init toward the minibatch moments…
        assert_ne!(out[4].as_f32().unwrap(), &leaves[4].1[..]);
        assert_ne!(out[5].as_f32().unwrap(), &leaves[5].1[..]);
        // …while their ADAM state stays untouched (stats skip the
        // optimizer entirely), unlike the bn scale/bias next door.
        assert!(out[20 + 4].as_f32().unwrap().iter().all(|&v| v == 0.0));
        assert!(out[20 + 5].as_f32().unwrap().iter().all(|&v| v == 0.0));
        assert!(out[20 + 2].as_f32().unwrap().iter().any(|&v| v != 0.0));
        // Conv weights and the fc head train normally.
        assert_ne!(out[0].as_f32().unwrap(), &leaves[0].1[..]);
        assert_ne!(out[18].as_f32().unwrap(), &leaves[18].1[..]);
    }
}
