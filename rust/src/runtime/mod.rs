//! Runtime layer: backend-dispatched execution + manifest + parameters.
//!
//! `client` dispatches artifact execution between the PJRT path (HLO
//! text → compile → execute, see /opt/xla-example/load_hlo) and the
//! pure-Rust `native` training backend; `manifest` is the typed contract
//! with `python/compile/aot.py` (plus the built-in native manifest);
//! `params` owns host-side model state and reproduces He initialization
//! from the manifest alone.

pub mod client;
pub mod manifest;
pub mod native;
pub mod params;

pub use client::{Backend, HostValue, Runtime};
pub use manifest::{Artifact, Manifest, ModelEntry, ParamSpec, Role, Slot};
pub use params::ParamBundle;
