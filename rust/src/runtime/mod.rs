//! Runtime layer: PJRT client + manifest + parameter bundles.
//!
//! `client` loads and executes the AOT artifacts (HLO text → compile →
//! execute, see /opt/xla-example/load_hlo); `manifest` is the typed
//! contract with `python/compile/aot.py`; `params` owns host-side model
//! state and reproduces He initialization from the manifest alone.

pub mod client;
pub mod manifest;
pub mod params;

pub use client::{HostValue, Runtime};
pub use manifest::{Artifact, Manifest, ModelEntry, ParamSpec, Role, Slot};
pub use params::ParamBundle;
