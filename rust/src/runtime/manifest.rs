//! Typed view of `artifacts/manifest.json` — the contract with `aot.py`.
//!
//! The manifest pins, for every artifact, the flat input/output role
//! lists in exact HLO `parameter(i)` order; the trainer's generic state
//! machine is driven entirely by these roles.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::{self, Json};

/// One parameter leaf of a model (order = flattening order).
#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    /// "conv_w" | "conv_b" | "fc_w" | "fc_b" | "bn_scale" | "bn_bias" |
    /// "bn_mean" | "bn_var" (the last two are running stats: not
    /// gradient-trained, EMA-updated by the native backend).
    pub kind: String,
    pub shape: Vec<usize>,
    pub prunable: bool,
    pub layer: String,
}

impl ParamSpec {
    /// Build a spec with the layer name derived from the leaf name
    /// (`fc1_w` → layer `fc1`), matching the AOT manifest convention —
    /// the one constructor the hand-built test/bench fixtures share.
    pub fn new(name: &str, kind: &str, shape: Vec<usize>, prunable: bool) -> ParamSpec {
        ParamSpec {
            name: name.into(),
            kind: kind.into(),
            shape,
            prunable,
            layer: name.trim_end_matches("_w").trim_end_matches("_b").into(),
        }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    /// Fan-in for He initialization (He et al. 2015), derived from kind.
    pub fn fan_in(&self) -> usize {
        match self.kind.as_str() {
            "conv_w" => self.shape[1] * self.shape[2] * self.shape[3],
            "fc_w" => self.shape[1],
            _ => 1,
        }
    }
}

/// Semantic role of one artifact input/output (see steps.py).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Role {
    Param,
    OptM,
    OptV,
    OptT,
    Mask,
    Theta,
    Lagrange,
    X,
    Y,
    Lambda,
    Lr,
    Mu,
    Loss,
    Correct,
    Logits,
}

impl Role {
    pub fn parse(s: &str) -> anyhow::Result<Role> {
        Ok(match s {
            "param" => Role::Param,
            "opt_m" => Role::OptM,
            "opt_v" => Role::OptV,
            "opt_t" => Role::OptT,
            "mask" => Role::Mask,
            "theta" => Role::Theta,
            "lagrange" => Role::Lagrange,
            "x" => Role::X,
            "y" => Role::Y,
            "lambda" => Role::Lambda,
            "lr" => Role::Lr,
            "mu" => Role::Mu,
            "loss" => Role::Loss,
            "correct" => Role::Correct,
            "logits" => Role::Logits,
            other => anyhow::bail!("unknown role {other:?}"),
        })
    }
}

/// One typed slot of an artifact signature.
#[derive(Debug, Clone)]
pub struct Slot {
    pub role: Role,
    pub name: String,
    pub shape: Vec<usize>,
    /// "f32" | "i32".
    pub dtype: String,
}

/// One lowered artifact (model × step).
#[derive(Debug, Clone)]
pub struct Artifact {
    pub file: PathBuf,
    pub batch: usize,
    pub inputs: Vec<Slot>,
    pub outputs: Vec<Slot>,
}

/// One model entry.
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub name: String,
    pub dataset: String,
    pub input_shape: Vec<usize>,
    pub num_classes: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub params: Vec<ParamSpec>,
    pub num_weights: usize,
    pub num_params: usize,
    pub artifacts: BTreeMap<String, Artifact>,
}

impl ModelEntry {
    pub fn artifact(&self, step: &str) -> anyhow::Result<&Artifact> {
        self.artifacts
            .get(step)
            .ok_or_else(|| anyhow::anyhow!("model {} has no artifact {step:?}", self.name))
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub models: BTreeMap<String, ModelEntry>,
}

impl Manifest {
    pub fn load(dir: &str) -> anyhow::Result<Manifest> {
        let dir = PathBuf::from(dir);
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| anyhow::anyhow!("cannot read {path:?}: {e}; run `make artifacts`"))?;
        let j = json::parse(&text)?;
        let mut models = BTreeMap::new();
        for (name, m) in j.req("models")?.as_obj().unwrap_or(&[]) {
            models.insert(name.clone(), parse_model(name, m, &dir)?);
        }
        if models.is_empty() {
            anyhow::bail!("manifest has no models");
        }
        Ok(Manifest { dir, models })
    }

    pub fn model(&self, name: &str) -> anyhow::Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "unknown model {name:?}; manifest has {:?}",
                self.models.keys().collect::<Vec<_>>()
            )
        })
    }

    /// The built-in native-backend manifest: no files on disk, artifact
    /// paths address `runtime::native` directly. Registers the MLP and
    /// LeNet model families:
    ///
    /// * `mlp` — 784→300→100→10 on `synth-mnist` (the paper-scale MLP);
    /// * `mlp-s` — 784→32→16→10 on `synth-blobs`, small enough that the
    ///   full SpC→debias→serve pipeline runs in seconds even in debug
    ///   builds (the offline e2e tests and CI smoke use it);
    /// * `lenet` — the paper's 430,500-weight LeNet-5 (conv 20@5×5 →
    ///   pool → conv 50@5×5 → pool → fc 800→500→10) on `synth-mnist`,
    ///   backing the conv rows of Table 3 / Figs. 6-8 offline;
    /// * `lenet-s` — a downscaled LeNet (conv 6@3×3 → pool → conv
    ///   12@3×3 → pool → fc 48→32→10) on the 16×16 `synth-blobs16`
    ///   set, the conv twin of `mlp-s` for e2e tests and CI smoke;
    /// * `resnet-s` — a downscaled residual net (3×3 stem conv + BN,
    ///   one 8-channel residual block with inference-mode batch norm,
    ///   global average pool, fc 8→10) on `synth-blobs16` — the
    ///   batch-norm/residual twin for multi-model serving tests.
    pub fn native() -> Manifest {
        use crate::runtime::native;
        let mut models = BTreeMap::new();
        models.insert(
            "mlp".to_string(),
            native::mlp_entry("mlp", &[1, 28, 28], &[300, 100], 10, "synth-mnist", 32, 64),
        );
        models.insert(
            "mlp-s".to_string(),
            native::mlp_entry("mlp-s", &[1, 28, 28], &[32, 16], 10, "synth-blobs", 16, 32),
        );
        models.insert(
            "lenet".to_string(),
            native::lenet_entry(
                "lenet",
                &[1, 28, 28],
                &[(20, 5), (50, 5)],
                &[500],
                10,
                "synth-mnist",
                32,
                64,
            ),
        );
        models.insert(
            "lenet-s".to_string(),
            native::lenet_entry(
                "lenet-s",
                &[1, 16, 16],
                &[(6, 3), (12, 3)],
                &[32],
                10,
                "synth-blobs16",
                16,
                32,
            ),
        );
        models.insert(
            "resnet-s".to_string(),
            native::resnet_entry("resnet-s", &[1, 16, 16], 8, 1, 10, "synth-blobs16", 16, 32),
        );
        Manifest { dir: PathBuf::from("native"), models }
    }

    /// Load the AOT manifest from `dir`, with the native manifest as the
    /// offline fallback: `dir == "native"` selects it explicitly, and a
    /// missing/unreadable manifest falls back to it when the `pjrt`
    /// feature is off (a PJRT build keeps the loud error — silently
    /// swapping backends under a real-artifact workflow would mislead).
    pub fn load_or_native(dir: &str) -> anyhow::Result<Manifest> {
        if dir == "native" {
            return Ok(Manifest::native());
        }
        match Manifest::load(dir) {
            Ok(m) => Ok(m),
            Err(e) if cfg!(not(feature = "pjrt")) => {
                crate::info!("no AOT manifest in {dir:?} ({e}); using the native CPU backend manifest");
                Ok(Manifest::native())
            }
            Err(e) => Err(e),
        }
    }
}

fn parse_model(name: &str, j: &Json, dir: &Path) -> anyhow::Result<ModelEntry> {
    let params = j
        .req("params")?
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .map(parse_param)
        .collect::<anyhow::Result<Vec<_>>>()?;
    let mut artifacts = BTreeMap::new();
    for (step, a) in j.req("artifacts")?.as_obj().unwrap_or(&[]) {
        artifacts.insert(step.clone(), parse_artifact(a, dir)?);
    }
    Ok(ModelEntry {
        name: name.to_string(),
        dataset: j.req("dataset")?.as_str().unwrap_or("").to_string(),
        input_shape: j.req("input_shape")?.as_usize_vec().unwrap_or_default(),
        num_classes: j.req("num_classes")?.as_usize().unwrap_or(0),
        train_batch: j.req("train_batch")?.as_usize().unwrap_or(0),
        eval_batch: j.req("eval_batch")?.as_usize().unwrap_or(0),
        num_weights: j.req("num_weights")?.as_usize().unwrap_or(0),
        num_params: j.req("num_params")?.as_usize().unwrap_or(0),
        params,
        artifacts,
    })
}

fn parse_param(j: &Json) -> anyhow::Result<ParamSpec> {
    Ok(ParamSpec {
        name: j.req("name")?.as_str().unwrap_or("").to_string(),
        kind: j.req("kind")?.as_str().unwrap_or("").to_string(),
        shape: j.req("shape")?.as_usize_vec().unwrap_or_default(),
        prunable: j.req("prunable")?.as_bool().unwrap_or(false),
        layer: j.req("layer")?.as_str().unwrap_or("").to_string(),
    })
}

fn parse_artifact(j: &Json, dir: &Path) -> anyhow::Result<Artifact> {
    let parse_slots = |key: &str| -> anyhow::Result<Vec<Slot>> {
        j.req(key)?
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|s| {
                Ok(Slot {
                    role: Role::parse(s.req("role")?.as_str().unwrap_or(""))?,
                    name: s.req("name")?.as_str().unwrap_or("").to_string(),
                    shape: s.req("shape")?.as_usize_vec().unwrap_or_default(),
                    dtype: s.req("dtype")?.as_str().unwrap_or("f32").to_string(),
                })
            })
            .collect()
    };
    Ok(Artifact {
        file: dir.join(j.req("file")?.as_str().unwrap_or("")),
        batch: j.req("batch")?.as_usize().unwrap_or(0),
        inputs: parse_slots("inputs")?,
        outputs: parse_slots("outputs")?,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    /// These tests run against the real generated manifest when present
    /// (integration tests in rust/tests enforce it exists).
    fn manifest() -> Option<Manifest> {
        Manifest::load("artifacts").ok()
    }

    #[test]
    fn loads_real_manifest_if_present() {
        let Some(m) = manifest() else { return };
        assert!(m.models.contains_key("lenet"));
        let lenet = m.model("lenet").unwrap();
        assert_eq!(lenet.num_weights, 430_500); // paper Table A1
        assert_eq!(lenet.input_shape, vec![1, 28, 28]);
        let art = lenet.artifact("train_prox_adam").unwrap();
        assert!(art.file.exists());
        // params, m, v, t, x, y, lambda, lr
        let n_leaves = lenet.params.len();
        assert_eq!(art.inputs.len(), 3 * n_leaves + 1 + 2 + 2);
        assert_eq!(art.inputs.last().unwrap().role, Role::Lr);
    }

    #[test]
    fn fan_in_rules() {
        let conv = ParamSpec {
            name: "c".into(),
            kind: "conv_w".into(),
            shape: vec![20, 1, 5, 5],
            prunable: true,
            layer: "c".into(),
        };
        assert_eq!(conv.fan_in(), 25);
        assert_eq!(conv.numel(), 500);
        let fc = ParamSpec {
            name: "f".into(),
            kind: "fc_w".into(),
            shape: vec![500, 800],
            prunable: true,
            layer: "f".into(),
        };
        assert_eq!(fc.fan_in(), 800);
    }

    #[test]
    fn role_parsing() {
        assert_eq!(Role::parse("param").unwrap(), Role::Param);
        assert_eq!(Role::parse("lambda").unwrap(), Role::Lambda);
        assert!(Role::parse("bogus").is_err());
    }

    #[test]
    fn missing_manifest_is_helpful() {
        let err = Manifest::load("/nonexistent_dir_xyz").unwrap_err();
        assert!(err.to_string().contains("make artifacts"));
    }

    #[test]
    fn native_manifest_registers_mlp_and_lenet_families() {
        let m = Manifest::native();
        for name in ["mlp", "mlp-s", "lenet", "lenet-s"] {
            let entry = m.model(name).unwrap();
            assert_eq!(entry.num_classes, 10);
            for step in crate::runtime::native::NATIVE_STEPS {
                let a = entry.artifact(step).unwrap();
                assert!(crate::runtime::native::is_native_path(&a.file), "{:?}", a.file);
                assert!(!a.inputs.is_empty() && !a.outputs.is_empty());
            }
        }
        // Paper-scale mlp: 784→300→100→10 prunable weights.
        assert_eq!(m.model("mlp").unwrap().num_weights, 300 * 784 + 100 * 300 + 10 * 100);
        // Paper-scale lenet: Table A1's 430,500 weights, conv leaves first.
        let lenet = m.model("lenet").unwrap();
        assert_eq!(lenet.num_weights, 430_500);
        assert_eq!(lenet.params[0].kind, "conv_w");
        assert_eq!(lenet.input_shape, vec![1, 28, 28]);
        // lenet-s: the downscaled conv twin on the 16×16 blob set.
        let small = m.model("lenet-s").unwrap();
        assert_eq!(small.input_shape, vec![1, 16, 16]);
        assert_eq!(small.dataset, "synth-blobs16");
        assert_eq!(small.num_weights, 54 + 648 + 1536 + 320);
    }

    #[test]
    fn load_or_native_explicit_and_fallback() {
        let m = Manifest::load_or_native("native").unwrap();
        assert!(m.models.contains_key("mlp-s"));
        if cfg!(not(feature = "pjrt")) {
            // Offline builds fall back instead of erroring.
            let m = Manifest::load_or_native("/nonexistent_dir_xyz").unwrap();
            assert!(m.models.contains_key("mlp"));
        }
    }
}
