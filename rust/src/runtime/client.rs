//! Runtime front-end: backend dispatch + PJRT client wrapper.
//!
//! [`Runtime`] executes artifacts through one of two [`Backend`]s:
//!
//! * **Native** — the pure-Rust f32 executor (`runtime::native`), which
//!   owns every `native/<model>/<step>` artifact. Selected automatically
//!   by [`Runtime::cpu`] when the `pjrt` feature is off, so the trainer
//!   and compression controllers run unchanged offline.
//! * **Pjrt** — load HLO-text artifacts, compile once, execute (adapted
//!   from /opt/xla-example/load_hlo: HLO *text* is the interchange
//!   format — the text parser reassigns instruction ids, sidestepping
//!   the 64-bit-id protos jax ≥ 0.5 emits that xla_extension 0.5.1
//!   rejects). Compiled executables are cached per path, so sweeps over
//!   λ/seeds reuse one compilation.
//!
//! `native/…` paths route to the native executor under *either* backend,
//! so a PJRT build can still drive the synthetic native manifest.

use std::collections::HashMap;
use std::path::Path;

use crate::runtime::native::{self, NativeBackend};
use crate::util::logger;
// Offline stand-in for the PJRT bindings; see `xla_compat` module docs.
use crate::xla_compat as xla;

/// Host-side value passed to / returned from an executable.
#[derive(Debug, Clone, PartialEq)]
pub enum HostValue {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostValue {
    pub fn scalar_f32(v: f32) -> HostValue {
        HostValue::F32 { shape: vec![], data: vec![v] }
    }

    pub fn zeros_f32(shape: Vec<usize>) -> HostValue {
        let n = shape.iter().product();
        HostValue::F32 { shape, data: vec![0.0; n] }
    }

    pub fn ones_f32(shape: Vec<usize>) -> HostValue {
        let n = shape.iter().product();
        HostValue::F32 { shape, data: vec![1.0; n] }
    }

    pub fn numel(&self) -> usize {
        match self {
            HostValue::F32 { data, .. } => data.len(),
            HostValue::I32 { data, .. } => data.len(),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostValue::F32 { shape, .. } => shape,
            HostValue::I32 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> anyhow::Result<&[f32]> {
        match self {
            HostValue::F32 { data, .. } => Ok(data),
            _ => anyhow::bail!("HostValue is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> anyhow::Result<&mut Vec<f32>> {
        match self {
            HostValue::F32 { data, .. } => Ok(data),
            _ => anyhow::bail!("HostValue is not f32"),
        }
    }

    pub fn as_i32(&self) -> anyhow::Result<&[i32]> {
        match self {
            HostValue::I32 { data, .. } => Ok(data),
            _ => anyhow::bail!("HostValue is not i32"),
        }
    }

    pub fn scalar(&self) -> anyhow::Result<f32> {
        match self {
            HostValue::F32 { data, .. } if data.len() == 1 => Ok(data[0]),
            HostValue::I32 { data, .. } if data.len() == 1 => Ok(data[0] as f32),
            _ => anyhow::bail!("HostValue is not a scalar"),
        }
    }

    fn to_literal(&self) -> anyhow::Result<xla::Literal> {
        let lit = match self {
            HostValue::F32 { shape, data } => {
                let bytes = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::F32,
                    shape,
                    bytes,
                )?
            }
            HostValue::I32 { shape, data } => {
                let bytes = unsafe {
                    std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4)
                };
                xla::Literal::create_from_shape_and_untyped_data(
                    xla::ElementType::S32,
                    shape,
                    bytes,
                )?
            }
        };
        Ok(lit)
    }

    fn from_literal(lit: &xla::Literal) -> anyhow::Result<HostValue> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match lit.ty()? {
            xla::ElementType::F32 => Ok(HostValue::F32 { shape: dims, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(HostValue::I32 { shape: dims, data: lit.to_vec::<i32>()? }),
            other => anyhow::bail!("unsupported output element type {other:?}"),
        }
    }
}

/// Build an f32 literal directly from a borrowed slice (§Perf: skips the
/// intermediate `HostValue` vector clone on the training hot path — the
/// literal constructor copies the bytes once, which is unavoidable).
pub fn literal_f32(shape: &[usize], data: &[f32]) -> anyhow::Result<xla::Literal> {
    let bytes = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::F32, shape, bytes)
}

/// As [`literal_f32`] for i32.
pub fn literal_i32(shape: &[usize], data: &[i32]) -> anyhow::Result<xla::Literal> {
    let bytes = unsafe { std::slice::from_raw_parts(data.as_ptr() as *const u8, data.len() * 4) };
    xla::Literal::create_from_shape_and_untyped_data(xla::ElementType::S32, shape, bytes)
}

/// Which device path executes compiled (non-`native/…`) artifacts.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// Pure-Rust f32 reference executor (`runtime::native`) — always
    /// available; the only backend in offline builds.
    Native,
    /// PJRT CPU runtime over compiled HLO artifacts (`pjrt` feature).
    Pjrt,
}

/// Artifact runtime: backend dispatch plus (for PJRT) a per-path
/// executable cache. The native executor is always present so
/// `native/<model>/<step>` artifacts run under either backend.
pub struct Runtime {
    backend: Backend,
    native: NativeBackend,
    client: Option<xla::PjRtClient>,
    cache: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Runtime {
    /// The default CPU runtime: PJRT when the `pjrt` feature is on,
    /// otherwise the native backend (offline builds train for real
    /// through `runtime::native` instead of erroring in the stub).
    pub fn cpu() -> anyhow::Result<Runtime> {
        if cfg!(feature = "pjrt") {
            Runtime::pjrt()
        } else {
            Ok(Runtime::native())
        }
    }

    /// The native-backend runtime (always available, any build).
    pub fn native() -> Runtime {
        Runtime {
            backend: Backend::Native,
            native: NativeBackend::new(),
            client: None,
            cache: HashMap::new(),
        }
    }

    /// The PJRT runtime; errors without the real XLA/PJRT bindings.
    pub fn pjrt() -> anyhow::Result<Runtime> {
        let client = xla::PjRtClient::cpu()?;
        logger::log(
            logger::Level::Debug,
            &format!(
                "PJRT client: platform={} devices={}",
                client.platform_name(),
                client.device_count()
            ),
        );
        Ok(Runtime {
            backend: Backend::Pjrt,
            native: NativeBackend::new(),
            client: Some(client),
            cache: HashMap::new(),
        })
    }

    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// Load + compile an HLO-text artifact (cached by path; PJRT only).
    pub fn load(&mut self, path: &Path) -> anyhow::Result<&xla::PjRtLoadedExecutable> {
        let client = self.client.as_ref().ok_or_else(|| {
            anyhow::anyhow!(
                "cannot compile {path:?}: this Runtime uses the native CPU backend \
                 (no PJRT client); rebuild with `--features pjrt` for compiled artifacts"
            )
        })?;
        let key = path.to_string_lossy().to_string();
        if !self.cache.contains_key(&key) {
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(&key)?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            logger::log(
                logger::Level::Debug,
                &format!("compiled {key} in {:.2}s", t0.elapsed().as_secs_f64()),
            );
            self.cache.insert(key.clone(), exe);
        }
        Ok(&self.cache[&key])
    }

    /// Execute an artifact with host values; returns the output tuple as
    /// host values (the AOT path lowers with `return_tuple=True`).
    pub fn execute(&mut self, path: &Path, inputs: &[HostValue]) -> anyhow::Result<Vec<HostValue>> {
        let literals = inputs
            .iter()
            .map(HostValue::to_literal)
            .collect::<anyhow::Result<Vec<_>>>()?;
        self.execute_literals(path, &literals)
    }

    /// Execute with pre-built literals (the training hot path builds them
    /// straight from borrowed state slices via [`literal_f32`]).
    /// `native/…` paths dispatch to the native executor; everything else
    /// needs the PJRT backend.
    pub fn execute_literals(
        &mut self,
        path: &Path,
        literals: &[xla::Literal],
    ) -> anyhow::Result<Vec<HostValue>> {
        if native::is_native_path(path) {
            return self.native.execute(path, literals);
        }
        if self.backend == Backend::Native {
            anyhow::bail!(
                "artifact {path:?} is a compiled HLO artifact, but this Runtime uses the \
                 native CPU backend; rebuild with `--features pjrt`, or use the native \
                 manifest (`--artifacts-dir native`, `Manifest::native()`)"
            );
        }
        let exe = self.load(path)?;
        let result = exe.execute::<xla::Literal>(literals)?;
        let tuple = result[0][0].to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        parts.iter().map(HostValue::from_literal).collect()
    }

    pub fn compiled_count(&self) -> usize {
        self.cache.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_value_constructors() {
        let z = HostValue::zeros_f32(vec![2, 3]);
        assert_eq!(z.numel(), 6);
        assert_eq!(z.as_f32().unwrap(), &[0.0; 6]);
        let o = HostValue::ones_f32(vec![4]);
        assert_eq!(o.as_f32().unwrap(), &[1.0; 4]);
        let s = HostValue::scalar_f32(2.5);
        assert_eq!(s.scalar().unwrap(), 2.5);
        assert!(z.scalar().is_err());
        assert!(z.as_i32().is_err());
    }

    #[test]
    fn literal_roundtrip_f32() {
        let v = HostValue::F32 { shape: vec![2, 2], data: vec![1.0, 2.0, 3.0, 4.0] };
        let lit = v.to_literal().unwrap();
        let back = HostValue::from_literal(&lit).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn literal_roundtrip_i32() {
        let v = HostValue::I32 { shape: vec![3], data: vec![7, -1, 0] };
        let lit = v.to_literal().unwrap();
        let back = HostValue::from_literal(&lit).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn cpu_runtime_selects_native_backend_offline() {
        if cfg!(feature = "pjrt") {
            return; // pjrt builds route Runtime::cpu() to the PJRT client
        }
        let rt = Runtime::cpu().unwrap();
        assert_eq!(rt.backend(), Backend::Native);
        assert_eq!(rt.compiled_count(), 0);
    }

    #[test]
    fn native_runtime_rejects_compiled_artifacts_with_hint() {
        let mut rt = Runtime::native();
        let err = rt.execute(Path::new("artifacts/mlp_infer.hlo.txt"), &[]).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("--features pjrt"), "{msg}");
        assert!(msg.contains("native"), "{msg}");
        assert!(rt.load(Path::new("artifacts/x.hlo.txt")).is_err());
    }

    #[test]
    fn native_runtime_routes_native_paths() {
        // A malformed native path must reach the native executor (and
        // fail there with its own diagnostics), not the PJRT error path.
        let mut rt = Runtime::native();
        let err = rt.execute(Path::new("native/mlp/bogus"), &[]).unwrap_err();
        assert!(err.to_string().contains("no step"), "{err}");
    }

    #[test]
    fn literal_roundtrip_scalar() {
        let v = HostValue::scalar_f32(3.25);
        let lit = v.to_literal().unwrap();
        let back = HostValue::from_literal(&lit).unwrap();
        assert_eq!(back.scalar().unwrap(), 3.25);
        assert_eq!(back.shape(), &[] as &[usize]);
    }
}
