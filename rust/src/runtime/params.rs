//! Parameter bundles: host-side model state, He init, compression stats.
//!
//! The coordinator owns parameters as host vectors (one per leaf, in the
//! manifest's flattening order) and materializes XLA literals per step.
//! Initialization reproduces `models/common.py::ParamBuilder` semantics
//! from the manifest spec alone — Python is never needed at runtime, and
//! multi-seed experiments (Figure 5) fork the rust PRNG.

use crate::runtime::client::HostValue;
use crate::runtime::manifest::ParamSpec;
use crate::util::rng::Rng;

/// Model parameters as host vectors, aligned with the manifest spec.
#[derive(Debug, Clone)]
pub struct ParamBundle {
    pub specs: Vec<ParamSpec>,
    pub values: Vec<Vec<f32>>,
}

impl ParamBundle {
    /// He-initialize weights (zero biases, unit BN scales and running
    /// variances, zero running means) from the spec.
    pub fn he_init(specs: &[ParamSpec], seed: u64) -> ParamBundle {
        let mut rng = Rng::new(seed ^ 0x4865_496e_6974); // "HeInit" salt
        let values = specs
            .iter()
            .map(|s| match s.kind.as_str() {
                "conv_w" | "fc_w" => rng.he_normal(s.numel(), s.fan_in()),
                "bn_scale" | "bn_var" => vec![1.0; s.numel()],
                _ => vec![0.0; s.numel()],
            })
            .collect();
        ParamBundle { specs: specs.to_vec(), values }
    }

    pub fn zeros_like(specs: &[ParamSpec]) -> ParamBundle {
        ParamBundle {
            specs: specs.to_vec(),
            values: specs.iter().map(|s| vec![0.0; s.numel()]).collect(),
        }
    }

    pub fn num_leaves(&self) -> usize {
        self.values.len()
    }

    pub fn total_params(&self) -> usize {
        self.values.iter().map(Vec::len).sum()
    }

    /// Total prunable weights (the denominator of the paper's
    /// compression rate — biases/BN excluded, per Tables A1-A4).
    pub fn total_weights(&self) -> usize {
        self.specs
            .iter()
            .filter(|s| s.prunable)
            .map(ParamSpec::numel)
            .sum()
    }

    /// Exact zeros among prunable weights.
    pub fn zero_weights(&self) -> usize {
        self.specs
            .iter()
            .zip(&self.values)
            .filter(|(s, _)| s.prunable)
            .map(|(_, v)| v.iter().filter(|&&x| x == 0.0).count())
            .sum()
    }

    /// The paper's compression rate: zeros / total prunable weights.
    pub fn compression_rate(&self) -> f64 {
        let total = self.total_weights();
        if total == 0 {
            return 0.0;
        }
        self.zero_weights() as f64 / total as f64
    }

    /// Per-layer (name, nnz, total) rows — the Tables A1-A4 payload.
    pub fn layer_stats(&self) -> Vec<(String, usize, usize)> {
        self.specs
            .iter()
            .zip(&self.values)
            .filter(|(s, _)| s.prunable)
            .map(|(s, v)| {
                let nnz = v.iter().filter(|&&x| x != 0.0).count();
                (s.layer.clone(), nnz, v.len())
            })
            .collect()
    }

    /// Convert each leaf into an f32 HostValue with its manifest shape.
    pub fn to_host_values(&self) -> Vec<HostValue> {
        self.specs
            .iter()
            .zip(&self.values)
            .map(|(s, v)| HostValue::F32 { shape: s.shape.clone(), data: v.clone() })
            .collect()
    }

    /// 0/1 masks of current nonzeros for prunable leaves (all-ones for
    /// non-prunable) — the debias/retraining mask (Section 2.4).
    pub fn nonzero_masks(&self) -> Vec<Vec<f32>> {
        self.specs
            .iter()
            .zip(&self.values)
            .map(|(s, v)| {
                if s.prunable {
                    v.iter().map(|&x| if x != 0.0 { 1.0 } else { 0.0 }).collect()
                } else {
                    vec![1.0; v.len()]
                }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lenet_like_specs() -> Vec<ParamSpec> {
        let p = |name: &str, kind: &str, shape: Vec<usize>, prunable: bool| ParamSpec {
            name: name.into(),
            kind: kind.into(),
            shape,
            prunable,
            layer: name.trim_end_matches("_w").trim_end_matches("_b").into(),
        };
        vec![
            p("conv1_w", "conv_w", vec![20, 1, 5, 5], true),
            p("conv1_b", "conv_b", vec![20], false),
            p("fc1_w", "fc_w", vec![500, 800], true),
            p("fc1_b", "fc_b", vec![500], false),
        ]
    }

    #[test]
    fn he_init_statistics() {
        let specs = lenet_like_specs();
        let b = ParamBundle::he_init(&specs, 0);
        // conv1_w: fan_in 25 → std sqrt(2/25) = 0.283
        let w = &b.values[0];
        let mean: f32 = w.iter().sum::<f32>() / w.len() as f32;
        let std: f32 =
            (w.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / w.len() as f32).sqrt();
        assert!((std - (2.0f32 / 25.0).sqrt()).abs() < 0.05, "std {std}");
        // biases zero
        assert!(b.values[1].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn deterministic_and_seed_sensitive() {
        let specs = lenet_like_specs();
        let a = ParamBundle::he_init(&specs, 5);
        let b = ParamBundle::he_init(&specs, 5);
        let c = ParamBundle::he_init(&specs, 6);
        assert_eq!(a.values, b.values);
        assert_ne!(a.values, c.values);
    }

    #[test]
    fn compression_accounting() {
        let specs = lenet_like_specs();
        let mut b = ParamBundle::he_init(&specs, 0);
        assert_eq!(b.total_weights(), 500 + 400_000);
        assert_eq!(b.total_params(), 500 + 20 + 400_000 + 500);
        // Zero half of fc1_w.
        for v in b.values[2].iter_mut().take(200_000) {
            *v = 0.0;
        }
        assert_eq!(b.zero_weights(), 200_000);
        let want = 200_000.0 / 400_500.0;
        assert!((b.compression_rate() - want).abs() < 1e-9);
        // Bias zeros never count.
        assert!(b.values[1].iter().all(|&x| x == 0.0));
    }

    #[test]
    fn masks_match_zeros() {
        let specs = lenet_like_specs();
        let mut b = ParamBundle::he_init(&specs, 0);
        b.values[0][7] = 0.0;
        let masks = b.nonzero_masks();
        assert_eq!(masks[0][7], 0.0);
        assert_eq!(masks[0][6], 1.0);
        // Non-prunable leaves get all-ones masks even though biases are 0.
        assert!(masks[1].iter().all(|&m| m == 1.0));
    }

    #[test]
    fn layer_stats_rows() {
        let specs = lenet_like_specs();
        let mut b = ParamBundle::he_init(&specs, 0);
        for v in b.values[2].iter_mut().take(100) {
            *v = 0.0;
        }
        let stats = b.layer_stats();
        assert_eq!(stats.len(), 2); // prunable leaves only
        assert_eq!(stats[0].0, "conv1");
        assert_eq!(stats[1], ("fc1".to_string(), 400_000 - 100, 400_000));
    }

    #[test]
    fn host_values_shapes() {
        let specs = lenet_like_specs();
        let b = ParamBundle::he_init(&specs, 0);
        let hv = b.to_host_values();
        assert_eq!(hv[0].shape(), &[20, 1, 5, 5]);
        assert_eq!(hv[0].numel(), 500);
    }
}
