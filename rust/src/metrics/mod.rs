//! Metric history + report writers (CSV / JSON under `reports/`).

pub mod benchcmp;

use std::io::Write;
use std::path::{Path, PathBuf};

use crate::telemetry::LayerProfile;
use crate::util::json::Json;

/// One recorded point on the training curve.
#[derive(Debug, Clone, Copy)]
pub struct StepRecord {
    pub step: usize,
    pub loss: f64,
    pub compression_rate: f64,
    /// Test accuracy if an eval ran at this point (NaN otherwise).
    pub accuracy: f64,
}

/// Append-only training history.
#[derive(Debug, Clone, Default)]
pub struct History {
    pub records: Vec<StepRecord>,
    counter: usize,
}

impl History {
    pub fn new() -> History {
        History::default()
    }

    /// Next step index (monotone across phases: train → retrain).
    pub fn next_step(&mut self) -> usize {
        self.counter += 1;
        self.counter
    }

    pub fn record_step(&mut self, step: usize, loss: f64, compression_rate: f64) {
        self.records.push(StepRecord { step, loss, compression_rate, accuracy: f64::NAN });
    }

    pub fn record_eval(&mut self, step: usize, loss: f64, compression_rate: f64, accuracy: f64) {
        self.records.push(StepRecord { step, loss, compression_rate, accuracy });
    }

    pub fn last_loss(&self) -> Option<f64> {
        self.records.last().map(|r| r.loss)
    }

    /// Write the full curve as CSV.
    pub fn write_csv(&self, path: &Path) -> anyhow::Result<()> {
        ensure_parent(path)?;
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "step,loss,compression_rate,accuracy")?;
        for r in &self.records {
            writeln!(
                f,
                "{},{:.6},{:.6},{}",
                r.step,
                r.loss,
                r.compression_rate,
                if r.accuracy.is_nan() { String::new() } else { format!("{:.6}", r.accuracy) }
            )?;
        }
        Ok(())
    }
}

/// A final run summary — what the compression controllers return and the
/// benches tabulate.
#[derive(Debug, Clone)]
pub struct RunResult {
    pub method: String,
    pub model: String,
    pub lambda: f64,
    pub seed: u64,
    pub accuracy: f64,
    pub loss: f64,
    pub compression_rate: f64,
    pub nnz: usize,
    pub total_weights: usize,
    /// (layer, nnz, total) per prunable leaf — Tables A1-A4 rows.
    pub layer_stats: Vec<(String, usize, usize)>,
    pub history: History,
    pub wall_secs: f64,
}

impl RunResult {
    /// Paper notation "0.97 (29×)": rate + size multiplier.
    pub fn times_factor(&self) -> f64 {
        if self.nnz == 0 {
            return f64::INFINITY;
        }
        self.total_weights as f64 / self.nnz as f64
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("method", Json::from(self.method.as_str()))
            .set("model", Json::from(self.model.as_str()))
            .set("lambda", Json::from(self.lambda))
            .set("seed", Json::from(self.seed as i64))
            .set("accuracy", Json::from(self.accuracy))
            .set("loss", Json::from(self.loss))
            .set("compression_rate", Json::from(self.compression_rate))
            .set("nnz", Json::from(self.nnz))
            .set("total_weights", Json::from(self.total_weights))
            .set("wall_secs", Json::from(self.wall_secs));
        let layers: Vec<Json> = self
            .layer_stats
            .iter()
            .map(|(name, nnz, total)| {
                let mut l = Json::obj();
                l.set("layer", Json::from(name.as_str()))
                    .set("nnz", Json::from(*nnz))
                    .set("total", Json::from(*total));
                l
            })
            .collect();
        j.set("layers", Json::Arr(layers));
        j
    }
}

/// Number of fixed latency-histogram buckets ([`LatencyHistogram`]).
pub const LATENCY_BUCKETS: usize = 64;
/// Geometric bucket growth: bucket `i` covers `[1.35^i, 1.35^(i+1))` µs,
/// so 64 buckets span ~1 µs … ~230 s with ≤ 35 % relative error per
/// bucket — plenty for serving percentiles.
const LATENCY_RATIO: f64 = 1.35;

/// Fixed-bucket log-spaced latency histogram. `record` touches one
/// counter in a fixed-size array — no allocation, safe on the serving
/// hot path — and percentile reads walk the 64 buckets.
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    counts: [u64; LATENCY_BUCKETS],
    total: u64,
    sum_us: f64,
    max_us: f64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        LatencyHistogram { counts: [0; LATENCY_BUCKETS], total: 0, sum_us: 0.0, max_us: 0.0 }
    }
}

impl LatencyHistogram {
    pub fn new() -> LatencyHistogram {
        LatencyHistogram::default()
    }

    /// Upper bound (µs) of bucket `i`; the last bucket is open-ended.
    fn bucket_bound(i: usize) -> f64 {
        LATENCY_RATIO.powi(i as i32 + 1)
    }

    /// Count one latency observation (µs). Non-finite or negative values
    /// land in the first bucket instead of corrupting the sums.
    pub fn record(&mut self, us: f64) {
        let us = if us.is_finite() && us > 0.0 { us } else { 0.0 };
        let mut idx = LATENCY_BUCKETS - 1;
        for i in 0..LATENCY_BUCKETS {
            if us < Self::bucket_bound(i) {
                idx = i;
                break;
            }
        }
        self.counts[idx] += 1;
        self.total += 1;
        self.sum_us += us;
        if us > self.max_us {
            self.max_us = us;
        }
    }

    /// Fold another histogram in (merging per-client load-gen shards).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.total += other.total;
        self.sum_us += other.sum_us;
        if other.max_us > self.max_us {
            self.max_us = other.max_us;
        }
    }

    /// Bucket-layout descriptor: `(bucket count, geometric ratio)`. Two
    /// histograms are mergeable iff their layouts match.
    pub fn layout(&self) -> (usize, f64) {
        (LATENCY_BUCKETS, LATENCY_RATIO)
    }

    /// [`merge`](Self::merge) guarded by a layout check: returns false
    /// (and leaves `self` untouched) when the bucket layouts differ, so
    /// fleet aggregation can fall back to its ceiling approximation
    /// instead of adding apples to oranges. In-process both layouts are
    /// the same compile-time constants, so this always merges today; the
    /// guard exists for snapshots that cross a version boundary.
    pub fn try_merge(&mut self, other: &LatencyHistogram) -> bool {
        if self.layout() != other.layout() {
            return false;
        }
        self.merge(other);
        true
    }

    /// Raw per-bucket counts (the property tests compare these
    /// bucketwise across interleavings and merge orders).
    pub fn bucket_counts(&self) -> [u64; LATENCY_BUCKETS] {
        self.counts
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean_us(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum_us / self.total as f64
        }
    }

    pub fn max_us(&self) -> f64 {
        self.max_us
    }

    /// Percentile estimate in µs: the upper bound of the bucket holding
    /// the rank-`⌈p·total⌉` observation, clamped to the observed max (so
    /// p99 of three 10 µs requests reads 10 µs, not a bucket edge).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let rank = (p.clamp(0.0, 1.0) * self.total as f64).ceil().max(1.0) as u64;
        let mut cum = 0u64;
        for i in 0..LATENCY_BUCKETS {
            cum += self.counts[i];
            if cum >= rank {
                return Self::bucket_bound(i).min(self.max_us);
            }
        }
        self.max_us
    }
}

/// Aggregate throughput/latency counters from the batched serving path
/// (`inference::server::BatchServer::stats`). Latency is measured submit
/// → completion per request (it includes the coalescing wait), forward
/// time per micro-batch, throughput over the first-submit → last-done
/// wall span. Percentiles come from a fixed-bucket [`LatencyHistogram`]
/// the worker fills — server-side numbers, not a client's view.
#[derive(Debug, Clone, Default)]
pub struct ServingStats {
    pub requests: usize,
    pub batches: usize,
    /// Largest micro-batch actually formed (≤ the configured ceiling).
    pub max_batch: usize,
    pub mean_batch: f64,
    pub mean_latency_us: f64,
    pub mean_forward_us: f64,
    pub throughput_rps: f64,
    pub p50_latency_us: f64,
    pub p90_latency_us: f64,
    pub p99_latency_us: f64,
    pub max_latency_us: f64,
    /// Per-layer kernel profiles from the serving engine (empty on the
    /// fleet aggregate — layers are a per-model concept).
    pub layers: Vec<LayerProfile>,
}

impl ServingStats {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("requests", Json::from(self.requests))
            .set("batches", Json::from(self.batches))
            .set("max_batch", Json::from(self.max_batch))
            .set("mean_batch", Json::from(self.mean_batch))
            .set("mean_latency_us", Json::from(self.mean_latency_us))
            .set("mean_forward_us", Json::from(self.mean_forward_us))
            .set("throughput_rps", Json::from(self.throughput_rps))
            .set("p50_latency_us", Json::from(self.p50_latency_us))
            .set("p90_latency_us", Json::from(self.p90_latency_us))
            .set("p99_latency_us", Json::from(self.p99_latency_us))
            .set("max_latency_us", Json::from(self.max_latency_us));
        if !self.layers.is_empty() {
            j.set("layers", Json::Arr(self.layers.iter().map(LayerProfile::to_json).collect()));
        }
        j
    }
}

/// Reports directory helper (`reports/<name>`).
pub fn report_path(name: &str) -> PathBuf {
    PathBuf::from("reports").join(name)
}

pub fn write_json_report(name: &str, j: &Json) -> anyhow::Result<PathBuf> {
    let path = report_path(name);
    ensure_parent(&path)?;
    std::fs::write(&path, j.to_string_pretty())?;
    Ok(path)
}

fn ensure_parent(path: &Path) -> anyhow::Result<()> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Unique per-test scratch dir: the pid isolates concurrent `cargo
    /// test` invocations (shared fixed paths used to collide and flake),
    /// the label isolates tests within one process.
    fn unique_test_dir(label: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("proxcomp_{label}_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        dir
    }

    #[test]
    fn history_counter_monotone() {
        let mut h = History::new();
        let a = h.next_step();
        let b = h.next_step();
        assert!(b > a);
    }

    #[test]
    fn csv_roundtrip_shape() {
        let mut h = History::new();
        h.record_step(1, 2.5, 0.0);
        h.record_eval(2, 1.5, 0.5, 0.9);
        let dir = unique_test_dir("metrics_csv");
        let path = dir.join("h.csv");
        h.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("step,"));
        assert!(lines[1].ends_with(',')); // NaN accuracy → empty field
        assert!(lines[2].contains("0.9"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn times_factor() {
        let r = RunResult {
            method: "SpC".into(),
            model: "lenet".into(),
            lambda: 1.0,
            seed: 0,
            accuracy: 0.97,
            loss: 0.1,
            compression_rate: 0.969,
            nnz: 13_333,
            total_weights: 430_500,
            layer_stats: vec![],
            history: History::new(),
            wall_secs: 1.0,
        };
        // Paper Table A1: 32×.
        assert!((r.times_factor() - 32.29).abs() < 0.1);
    }

    #[test]
    fn serving_stats_json_shape() {
        let s = ServingStats {
            requests: 64,
            batches: 8,
            max_batch: 16,
            mean_batch: 8.0,
            mean_latency_us: 120.0,
            mean_forward_us: 90.0,
            throughput_rps: 5000.0,
            p50_latency_us: 110.0,
            p90_latency_us: 200.0,
            p99_latency_us: 240.0,
            max_latency_us: 250.0,
            layers: Vec::new(),
        };
        let text = s.to_json().to_string_compact();
        assert!(text.contains("\"requests\""));
        assert!(text.contains("\"throughput_rps\""));
        assert!(text.contains("\"p99_latency_us\""));
        assert!(text.contains("64"));
    }

    #[test]
    fn json_report_writes() {
        let j = {
            let mut j = Json::obj();
            j.set("ok", Json::from(true));
            j
        };
        // Use temp cwd-independent check via direct path write.
        let dir = unique_test_dir("reports");
        let p = dir.join("r.json");
        std::fs::write(&p, j.to_string_pretty()).unwrap();
        assert!(std::fs::read_to_string(&p).unwrap().contains("true"));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn histogram_percentiles_order_and_clamp() {
        let mut h = LatencyHistogram::new();
        for us in [10.0, 12.0, 11.0, 9.0, 400.0] {
            h.record(us);
        }
        assert_eq!(h.count(), 5);
        let (p50, p99) = (h.percentile(0.5), h.percentile(0.99));
        assert!(p50 > 0.0 && p50 <= p99, "p50 {p50} p99 {p99}");
        // The clamp: no percentile exceeds the observed max.
        assert!(p99 <= h.max_us(), "p99 {p99} max {}", h.max_us());
        assert!((h.mean_us() - 88.4).abs() < 1.0, "mean {}", h.mean_us());
        // p50 lands in the ~10 µs buckets, nowhere near the 400 µs tail.
        assert!(p50 < 50.0, "p50 {p50}");
    }

    #[test]
    fn histogram_merge_matches_combined_recording() {
        let (mut a, mut b, mut both) = (LatencyHistogram::new(), LatencyHistogram::new(), LatencyHistogram::new());
        for us in [5.0, 80.0, 1500.0] {
            a.record(us);
            both.record(us);
        }
        for us in [2.0, 40_000.0] {
            b.record(us);
            both.record(us);
        }
        a.merge(&b);
        assert_eq!(a.count(), both.count());
        assert_eq!(a.max_us(), both.max_us());
        for p in [0.5, 0.9, 0.99] {
            assert_eq!(a.percentile(p), both.percentile(p), "p{p}");
        }
    }

    #[test]
    fn histogram_degenerate_inputs() {
        let mut h = LatencyHistogram::new();
        assert_eq!(h.percentile(0.99), 0.0);
        h.record(f64::NAN);
        h.record(-3.0);
        assert_eq!(h.count(), 2);
        assert_eq!(h.percentile(0.5), 0.0); // clamped to observed max (0)
    }

    /// Deterministic latency stream `i` draws from — shared by the
    /// concurrency property test's interleaved and sequential runs.
    fn latency_stream(thread: u64, n: usize) -> Vec<f64> {
        let mut state = thread.wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(1);
        (0..n)
            .map(|_| {
                // xorshift64*: cheap, deterministic, spreads across buckets.
                state ^= state >> 12;
                state ^= state << 25;
                state ^= state >> 27;
                let r = state.wrapping_mul(0x2545_F491_4F6C_DD1D);
                1.0 + (r % 1_000_000) as f64 / 10.0 // 1 µs … 100 ms
            })
            .collect()
    }

    #[test]
    fn concurrent_recording_matches_sequential_replay() {
        // The serving path records under a mutex (StatsInner); the
        // property: any interleaving of N threads' record() calls lands
        // the same per-bucket totals as a sequential replay of the same
        // observations — recording is order-independent.
        const THREADS: u64 = 8;
        const PER_THREAD: usize = 500;
        let shared = std::sync::Mutex::new(LatencyHistogram::new());
        std::thread::scope(|s| {
            for t in 0..THREADS {
                let shared = &shared;
                s.spawn(move || {
                    for us in latency_stream(t, PER_THREAD) {
                        shared.lock().unwrap().record(us);
                    }
                });
            }
        });
        let interleaved = shared.into_inner().unwrap();
        let mut sequential = LatencyHistogram::new();
        for t in 0..THREADS {
            for us in latency_stream(t, PER_THREAD) {
                sequential.record(us);
            }
        }
        assert_eq!(interleaved.bucket_counts(), sequential.bucket_counts());
        assert_eq!(interleaved.count(), sequential.count());
        assert_eq!(interleaved.max_us(), sequential.max_us());
        for p in [0.5, 0.9, 0.99] {
            assert_eq!(interleaved.percentile(p), sequential.percentile(p), "p{p}");
        }
    }

    #[test]
    fn histogram_merge_is_associative_and_commutative() {
        let histo = |t: u64| {
            let mut h = LatencyHistogram::new();
            for us in latency_stream(t, 200) {
                h.record(us);
            }
            h
        };
        let (a, b, c) = (histo(1), histo(2), histo(3));
        // (a ⊕ b) ⊕ c == a ⊕ (b ⊕ c)
        let mut left = a.clone();
        left.merge(&b);
        left.merge(&c);
        let mut bc = b.clone();
        bc.merge(&c);
        let mut right = a.clone();
        right.merge(&bc);
        assert_eq!(left.bucket_counts(), right.bucket_counts());
        assert_eq!(left.count(), right.count());
        assert_eq!(left.max_us(), right.max_us());
        assert!((left.mean_us() - right.mean_us()).abs() < 1e-9);
        // a ⊕ b == b ⊕ a
        let mut ab = a.clone();
        ab.merge(&b);
        let mut ba = b.clone();
        ba.merge(&a);
        assert_eq!(ab.bucket_counts(), ba.bucket_counts());
        assert_eq!(ab.count(), ba.count());
        assert_eq!(ab.max_us(), ba.max_us());
        for p in [0.5, 0.9, 0.99] {
            assert_eq!(ab.percentile(p), ba.percentile(p), "p{p}");
        }
    }

    #[test]
    fn try_merge_checks_layout() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        b.record(10.0);
        // Same compile-time layout: merge succeeds and folds counts.
        assert!(a.try_merge(&b));
        assert_eq!(a.count(), 1);
        assert_eq!(a.layout(), (LATENCY_BUCKETS, 1.35));
    }
}
