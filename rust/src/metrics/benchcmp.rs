//! Bench-trajectory comparison — the logic behind `proxcomp
//! bench-compare` and the CI `bench-gate` step.
//!
//! Compares a fresh `reports/bench_kernels.json` against the committed
//! `BENCH_BASELINE.json` and fails on per-group regressions. Two design
//! points keep the gate portable across machines (a committed baseline
//! is replayed on arbitrary CI runners):
//!
//! * **Calibration normalization.** Absolute µs differ wildly between
//!   runners, so each timed row is scored as `median_us / calibration`,
//!   where the calibration row ([`CALIBRATION`], the dense matmul in the
//!   dxct section) comes from the *same run*. Scores measure "how many
//!   dense matmuls does this kernel cost", which tracks kernel quality,
//!   not machine speed.
//! * **Per-group geometric means.** Individual rows are noisy at CI rep
//!   counts; the gate trips only when a whole section's geomean ratio
//!   (current score / baseline score, rows matched by section + name)
//!   exceeds `1 + max_regress`.
//!
//! Metric-only rows (no `median_us`, e.g. storage ratios) are carried in
//! the same files but never timed-gated.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::util::json::Json;

/// `(section, name)` of the calibration row every bench run must emit.
pub const CALIBRATION: (&str, &str) = ("dxct_forward", "dense_matmul_nt");

/// Default failure threshold: >25 % group-geomean regression.
pub const DEFAULT_MAX_REGRESS: f64 = 0.25;

/// One timed bench row (metric-only rows are dropped at load).
#[derive(Debug, Clone)]
pub struct BenchRow {
    pub section: String,
    pub name: String,
    pub median_us: f64,
}

/// Per-section comparison outcome.
#[derive(Debug, Clone)]
pub struct GroupDelta {
    pub section: String,
    /// Geomean of per-row `current_score / baseline_score` (1.0 = flat,
    /// above = slower than baseline).
    pub ratio: f64,
    /// Rows matched between the two runs.
    pub rows: usize,
    pub gated: bool,
}

/// Full comparison result: per-group deltas, a printable table, and the
/// gated groups that regressed past the threshold.
#[derive(Debug, Clone)]
pub struct CompareReport {
    pub groups: Vec<GroupDelta>,
    pub table: String,
    pub failures: Vec<String>,
}

impl CompareReport {
    pub fn passed(&self) -> bool {
        self.failures.is_empty()
    }
}

/// Extract timed rows from either supported file shape: a bare row array
/// (`reports/bench_kernels.json`) or a summary object with a `rows` key
/// (the committed `BENCH_*.json` wrappers). Rows with a positive finite
/// `median_us` are timed; metric-only rows are skipped. A present but
/// invalid `median_us` (NaN / zero / negative) is an error — that is the
/// partial-JSON failure mode the gate must reject, not accept.
pub fn load_rows(j: &Json) -> anyhow::Result<Vec<BenchRow>> {
    let arr = match j.get("rows") {
        Some(rows) => rows.as_arr(),
        None => j.as_arr(),
    };
    let arr = arr.ok_or_else(|| anyhow::anyhow!("bench json: expected array or {{rows: [...]}}"))?;
    let mut out = Vec::new();
    for row in arr {
        let section = row.req("section")?.as_str().unwrap_or_default().to_string();
        let name = row.req("name")?.as_str().unwrap_or_default().to_string();
        let Some(us) = row.get("median_us").and_then(|v| v.as_f64()) else {
            continue; // metric-only row
        };
        anyhow::ensure!(
            us.is_finite() && us > 0.0,
            "bench json: row {section}/{name} has invalid median_us {us}"
        );
        out.push(BenchRow { section, name, median_us: us });
    }
    Ok(out)
}

fn calibration(rows: &[BenchRow], which: &str) -> anyhow::Result<f64> {
    rows.iter()
        .find(|r| r.section == CALIBRATION.0 && r.name == CALIBRATION.1)
        .map(|r| r.median_us)
        .ok_or_else(|| {
            anyhow::anyhow!(
                "{which}: missing calibration row {}/{} — was the bench run complete?",
                CALIBRATION.0,
                CALIBRATION.1
            )
        })
}

/// Compare `current` against `baseline`. `gate` selects the sections the
/// pass/fail verdict considers (empty = every section present in both
/// runs); all matched sections still appear in the delta table.
pub fn compare(
    baseline: &[BenchRow],
    current: &[BenchRow],
    max_regress: f64,
    gate: &[String],
) -> anyhow::Result<CompareReport> {
    anyhow::ensure!(max_regress > 0.0, "max_regress must be positive");
    let cal_base = calibration(baseline, "baseline")?;
    let cal_cur = calibration(current, "current")?;

    // Per-row ratios of calibration-normalized scores, grouped by section.
    let base_by_key: BTreeMap<(&str, &str), f64> =
        baseline.iter().map(|r| ((r.section.as_str(), r.name.as_str()), r.median_us)).collect();
    let mut rows_by_section: BTreeMap<&str, Vec<(&str, f64)>> = BTreeMap::new();
    for r in current {
        if r.section == CALIBRATION.0 && r.name == CALIBRATION.1 {
            continue; // the yardstick itself is ratio 1.0 by construction
        }
        if let Some(&base_us) = base_by_key.get(&(r.section.as_str(), r.name.as_str())) {
            let ratio = (r.median_us / cal_cur) / (base_us / cal_base);
            rows_by_section.entry(r.section.as_str()).or_default().push((r.name.as_str(), ratio));
        }
    }
    anyhow::ensure!(
        !rows_by_section.is_empty(),
        "no overlapping timed rows between baseline and current"
    );

    let mut table = String::new();
    let _ = writeln!(
        table,
        "{:<24} {:<34} {:>9}  {}",
        "section", "name", "ratio", "(current/baseline, calibration-normalized)"
    );
    let mut groups = Vec::new();
    let mut failures = Vec::new();
    for (section, rows) in &rows_by_section {
        let gated = gate.is_empty() || gate.iter().any(|g| g == section);
        let log_sum: f64 = rows.iter().map(|(_, r)| r.ln()).sum();
        let geomean = (log_sum / rows.len() as f64).exp();
        for (name, ratio) in rows {
            let _ = writeln!(table, "{section:<24} {name:<34} {ratio:>8.3}x");
        }
        let verdict = if !gated {
            "ungated"
        } else if geomean > 1.0 + max_regress {
            failures.push(format!(
                "group '{section}' regressed: geomean {geomean:.3}x > {:.3}x over {} rows",
                1.0 + max_regress,
                rows.len()
            ));
            "FAIL"
        } else {
            "ok"
        };
        let _ = writeln!(
            table,
            "{:<24} {:<34} {:>8.3}x  [{} geomean, {}]",
            section,
            "(group geomean)",
            geomean,
            rows.len(),
            verdict
        );
        groups.push(GroupDelta { section: section.to_string(), ratio: geomean, rows: rows.len(), gated });
    }
    Ok(CompareReport { groups, table, failures })
}

/// Convenience: parse both files' JSON text and compare.
pub fn compare_json(
    baseline: &Json,
    current: &Json,
    max_regress: f64,
    gate: &[String],
) -> anyhow::Result<CompareReport> {
    compare(&load_rows(baseline)?, &load_rows(current)?, max_regress, gate)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rows(entries: &[(&str, &str, f64)]) -> Vec<BenchRow> {
        entries
            .iter()
            .map(|(s, n, us)| BenchRow {
                section: s.to_string(),
                name: n.to_string(),
                median_us: *us,
            })
            .collect()
    }

    fn base_fixture() -> Vec<BenchRow> {
        rows(&[
            (CALIBRATION.0, CALIBRATION.1, 1000.0),
            ("dxct_forward", "csr_90pct", 200.0),
            ("dxct_forward", "csr_97pct", 80.0),
            ("blocked_kernels", "spmv_blocked_90pct", 50.0),
            ("blocked_kernels", "spmv_blocked_97pct", 20.0),
        ])
    }

    #[test]
    fn identical_runs_pass() {
        let b = base_fixture();
        let rep = compare(&b, &b, DEFAULT_MAX_REGRESS, &[]).unwrap();
        assert!(rep.passed(), "{:?}", rep.failures);
        for g in &rep.groups {
            assert!((g.ratio - 1.0).abs() < 1e-12, "{g:?}");
        }
    }

    #[test]
    fn injected_2x_slowdown_fails() {
        let b = base_fixture();
        let mut cur = base_fixture();
        for r in &mut cur {
            if r.section == "blocked_kernels" {
                r.median_us *= 2.0; // the acceptance-criteria injection
            }
        }
        let rep = compare(&b, &cur, DEFAULT_MAX_REGRESS, &[]).unwrap();
        assert!(!rep.passed());
        assert_eq!(rep.failures.len(), 1);
        assert!(rep.failures[0].contains("blocked_kernels"), "{:?}", rep.failures);
        let g = rep.groups.iter().find(|g| g.section == "blocked_kernels").unwrap();
        assert!((g.ratio - 2.0).abs() < 1e-9);
        // The untouched group stays clean.
        assert!(rep.groups.iter().any(|g| g.section == "dxct_forward" && g.ratio < 1.25));
    }

    #[test]
    fn speedups_pass_and_machine_scale_cancels() {
        let b = base_fixture();
        // A 3x faster machine (all timings /3) with a genuine 2x kernel
        // speedup in one group: everything passes, ratios reflect only
        // the kernel change because calibration normalizes machine speed.
        let mut cur = base_fixture();
        for r in &mut cur {
            r.median_us /= 3.0;
            if r.section == "blocked_kernels" {
                r.median_us /= 2.0;
            }
        }
        let rep = compare(&b, &cur, DEFAULT_MAX_REGRESS, &[]).unwrap();
        assert!(rep.passed(), "{:?}", rep.failures);
        let g = rep.groups.iter().find(|g| g.section == "blocked_kernels").unwrap();
        assert!((g.ratio - 0.5).abs() < 1e-9, "{g:?}");
    }

    #[test]
    fn gate_filter_limits_verdict_to_selected_groups() {
        let b = base_fixture();
        let mut cur = base_fixture();
        for r in &mut cur {
            if r.section == "dxct_forward" && r.name != CALIBRATION.1 {
                r.median_us *= 4.0;
            }
        }
        // dxct_forward regresses 4x but only blocked_kernels is gated.
        let gate = vec!["blocked_kernels".to_string()];
        let rep = compare(&b, &cur, DEFAULT_MAX_REGRESS, &gate).unwrap();
        assert!(rep.passed(), "{:?}", rep.failures);
        // Same comparison with the gate off fails.
        assert!(!compare(&b, &cur, DEFAULT_MAX_REGRESS, &[]).unwrap().passed());
        // The regression still shows in the table for humans.
        assert!(rep.table.contains("4.000x"), "{}", rep.table);
    }

    #[test]
    fn missing_calibration_is_an_error() {
        let b = base_fixture();
        let cur = rows(&[("dxct_forward", "csr_90pct", 100.0)]);
        let err = compare(&b, &cur, DEFAULT_MAX_REGRESS, &[]).unwrap_err();
        assert!(err.to_string().contains("calibration"), "{err}");
    }

    #[test]
    fn load_rows_accepts_both_shapes_and_rejects_bad_timings() {
        let bare = crate::util::json::parse(
            r#"[{"section":"s","name":"a","median_us":5.0},
                {"section":"s","name":"ratio_only","bytes_ratio":3.2}]"#,
        )
        .unwrap();
        let got = load_rows(&bare).unwrap();
        assert_eq!(got.len(), 1, "metric-only row must be skipped");
        let wrapped = crate::util::json::parse(
            r#"{"pr":6,"bench":"bench_kernels","rows":[{"section":"s","name":"a","median_us":5.0}]}"#,
        )
        .unwrap();
        assert_eq!(load_rows(&wrapped).unwrap().len(), 1);
        for bad in ["0.0", "-1.0", "null"] {
            let j = crate::util::json::parse(&format!(
                r#"[{{"section":"s","name":"a","median_us":{bad}}}]"#
            ))
            .unwrap();
            // null median_us parses as a non-number → metric-only skip
            // would hide corruption, so only numeric invalids error; the
            // null case simply yields no timed rows.
            if bad == "null" {
                assert!(load_rows(&j).unwrap().is_empty());
            } else {
                assert!(load_rows(&j).is_err(), "median_us={bad} must be rejected");
            }
        }
    }
}
