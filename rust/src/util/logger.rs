//! Leveled stdout logger with elapsed-time stamps.
//!
//! Intentionally tiny: the coordinator logs progress lines that double as
//! the experiment record (EXPERIMENTS.md quotes them directly).

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(Level::Info as u8);
static START: std::sync::OnceLock<Instant> = std::sync::OnceLock::new();

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Debug = 0,
    Info = 1,
    Warn = 2,
    Error = 3,
}

pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

pub fn elapsed_secs() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

pub fn log(level: Level, msg: &str) {
    if (level as u8) < LEVEL.load(Ordering::Relaxed) {
        return;
    }
    let tag = match level {
        Level::Debug => "DBG",
        Level::Info => "INF",
        Level::Warn => "WRN",
        Level::Error => "ERR",
    };
    println!("[{:9.2}s {tag}] {msg}", elapsed_secs());
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Info, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Debug, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::logger::log($crate::util::logger::Level::Warn, &format!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elapsed_monotonic() {
        let a = elapsed_secs();
        let b = elapsed_secs();
        assert!(b >= a);
    }

    #[test]
    fn level_filtering_does_not_panic() {
        set_level(Level::Warn);
        log(Level::Debug, "hidden");
        log(Level::Error, "shown");
        set_level(Level::Info);
    }
}
