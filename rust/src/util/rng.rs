//! Deterministic PRNG for data synthesis, initialization, and shuffling.
//!
//! No `rand` crate offline, so we carry our own: SplitMix64 for seeding and
//! xoshiro256** (Blackman & Vigna) as the workhorse generator, plus
//! Box-Muller normals and the He-init helper the coordinator uses to
//! materialize parameters straight from the manifest spec.

/// xoshiro256** seeded via SplitMix64.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E3779B97F4A7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        Rng {
            s: [
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
                splitmix64(&mut sm),
            ],
        }
    }

    /// Derive an independent stream (e.g. per-example, per-layer).
    pub fn fork(&self, stream: u64) -> Rng {
        let mut sm = self.s[0] ^ stream.wrapping_mul(0xA076_1D64_78BD_642F);
        Rng::new(splitmix64(&mut sm))
    }

    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform() as f32
    }

    /// Uniform integer in [0, n). Unbiased via rejection on the top range.
    pub fn below(&mut self, n: usize) -> usize {
        assert!(n > 0);
        let n = n as u64;
        let zone = u64::MAX - (u64::MAX % n);
        loop {
            let v = self.next_u64();
            if v < zone {
                return (v % n) as usize;
            }
        }
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f64 {
        loop {
            let u1 = self.uniform();
            if u1 <= f64::EPSILON {
                continue;
            }
            let u2 = self.uniform();
            return (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
        }
    }

    /// Vector of f32 normals with the given std.
    pub fn normal_vec(&mut self, n: usize, std: f32) -> Vec<f32> {
        (0..n).map(|_| (self.normal() as f32) * std).collect()
    }

    /// He et al. 2015 initialization: std = sqrt(2 / fan_in).
    pub fn he_normal(&mut self, n: usize, fan_in: usize) -> Vec<f32> {
        assert!(fan_in > 0, "he_normal with zero fan_in");
        self.normal_vec(n, (2.0f32 / fan_in as f32).sqrt())
    }

    /// In-place Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, items: &mut [T]) {
        for i in (1..items.len()).rev() {
            items.swap(i, self.below(i + 1));
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn fork_is_independent() {
        let base = Rng::new(7);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        assert_ne!(a.next_u64(), b.next_u64());
        // Same stream id ⇒ same sequence.
        let mut c = base.fork(0);
        let mut d = base.fork(0);
        assert_eq!(c.next_u64(), d.next_u64());
    }

    #[test]
    fn uniform_in_range() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn uniform_mean_near_half() {
        let mut r = Rng::new(11);
        let n = 50_000;
        let mean: f64 = (0..n).map(|_| r.uniform()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn he_normal_std() {
        let mut r = Rng::new(13);
        let xs = r.he_normal(100_000, 800);
        let want = (2.0f32 / 800.0).sqrt();
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let std = (xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / xs.len() as f32).sqrt();
        assert!((std - want).abs() / want < 0.03, "std {std} want {want}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }
}
