//! Hand-rolled CLI argument parser (no `clap` in the offline crate set).
//!
//! Grammar: `proxcomp <subcommand> [--key value]... [--flag]...`.
//! Values parse lazily with typed getters; unknown keys are rejected at
//! `finish()` so typos fail loudly.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    kv: BTreeMap<String, String>,
    flags: Vec<String>,
    consumed: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an iterator of raw arguments (exclusive of argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(raw: I) -> anyhow::Result<Args> {
        let mut args = Args::default();
        let mut it = raw.into_iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with("--") {
                args.subcommand = it.next();
            }
        }
        while let Some(a) = it.next() {
            let key = a
                .strip_prefix("--")
                .ok_or_else(|| anyhow::anyhow!("expected --key, got {a:?}"))?
                .to_string();
            if key.is_empty() {
                anyhow::bail!("empty option name");
            }
            // `--key=value` or `--key value` or bare flag.
            if let Some((k, v)) = key.split_once('=') {
                args.kv.insert(k.to_string(), v.to_string());
            } else if it.peek().map(|n| !n.starts_with("--")).unwrap_or(false) {
                args.kv.insert(key, it.next().unwrap());
            } else {
                args.flags.push(key);
            }
        }
        Ok(args)
    }

    pub fn from_env() -> anyhow::Result<Args> {
        Args::parse(std::env::args().skip(1))
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn get_str(&self, key: &str) -> Option<String> {
        self.mark(key);
        self.kv.get(key).cloned()
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get_str(key).unwrap_or_else(|| default.to_string())
    }

    pub fn get_parsed<T: std::str::FromStr>(&self, key: &str) -> anyhow::Result<Option<T>> {
        self.mark(key);
        match self.kv.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|_| anyhow::anyhow!("--{key}: cannot parse {v:?}")),
        }
    }

    pub fn usize_or(&self, key: &str, default: usize) -> anyhow::Result<usize> {
        Ok(self.get_parsed::<usize>(key)?.unwrap_or(default))
    }

    pub fn u64_or(&self, key: &str, default: u64) -> anyhow::Result<u64> {
        Ok(self.get_parsed::<u64>(key)?.unwrap_or(default))
    }

    pub fn f32_or(&self, key: &str, default: f32) -> anyhow::Result<f32> {
        Ok(self.get_parsed::<f32>(key)?.unwrap_or(default))
    }

    pub fn f64_or(&self, key: &str, default: f64) -> anyhow::Result<f64> {
        Ok(self.get_parsed::<f64>(key)?.unwrap_or(default))
    }

    pub fn flag(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.iter().any(|f| f == key)
    }

    /// Duration option: `5s`, `250ms`, `800us`, `2m`, or a bare number
    /// of seconds (`0.5`).
    pub fn duration_or(&self, key: &str, default: std::time::Duration) -> anyhow::Result<std::time::Duration> {
        match self.get_str(key) {
            None => Ok(default),
            Some(v) => parse_duration(&v).map_err(|e| anyhow::anyhow!("--{key}: {e}")),
        }
    }

    /// Comma-separated list option.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get_str(key) {
            Some(v) => v.split(',').filter(|s| !s.is_empty()).map(String::from).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Error on any option that no getter ever looked at.
    pub fn finish(&self) -> anyhow::Result<()> {
        let consumed = self.consumed.borrow();
        let unknown: Vec<&String> = self
            .kv
            .keys()
            .chain(self.flags.iter())
            .filter(|k| !consumed.contains(k))
            .collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("unknown option(s): {unknown:?}")
        }
    }
}

/// Parse a human duration: a non-negative number plus an optional unit
/// suffix (`us`, `ms`, `s`, `m`); no suffix means seconds.
pub fn parse_duration(text: &str) -> anyhow::Result<std::time::Duration> {
    let text = text.trim();
    let (num, unit) = match text.find(|c: char| c.is_ascii_alphabetic()) {
        Some(i) => text.split_at(i),
        None => (text, "s"),
    };
    let value: f64 = num
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("cannot parse duration {text:?} (want e.g. 5s, 250ms, 2m)"))?;
    anyhow::ensure!(value.is_finite() && value >= 0.0, "duration {text:?} must be non-negative");
    let secs = match unit {
        "us" => value / 1e6,
        "ms" => value / 1e3,
        "s" => value,
        "m" => value * 60.0,
        other => anyhow::bail!("unknown duration unit {other:?} in {text:?} (use us, ms, s, or m)"),
    };
    Ok(std::time::Duration::from_secs_f64(secs))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["train", "--model", "lenet", "--steps", "500", "--verbose"]);
        assert_eq!(a.subcommand.as_deref(), Some("train"));
        assert_eq!(a.str_or("model", "mlp"), "lenet");
        assert_eq!(a.usize_or("steps", 0).unwrap(), 500);
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
        a.finish().unwrap();
    }

    #[test]
    fn key_equals_value() {
        let a = parse(&["--lr=0.01", "--lambda=1.5"]);
        assert!((a.f32_or("lr", 0.0).unwrap() - 0.01).abs() < 1e-9);
        assert!((a.f32_or("lambda", 0.0).unwrap() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn defaults() {
        let a = parse(&["train"]);
        assert_eq!(a.usize_or("steps", 100).unwrap(), 100);
        assert_eq!(a.str_or("model", "mlp"), "mlp");
    }

    #[test]
    fn list_option() {
        let a = parse(&["--models", "lenet,mlp,vgg_s"]);
        assert_eq!(a.list_or("models", &[]), vec!["lenet", "mlp", "vgg_s"]);
        let b = parse(&[]);
        assert_eq!(b.list_or("models", &["mlp"]), vec!["mlp"]);
    }

    #[test]
    fn bad_parse_errors() {
        let a = parse(&["--steps", "abc"]);
        assert!(a.usize_or("steps", 0).is_err());
    }

    #[test]
    fn unknown_option_rejected() {
        let a = parse(&["train", "--oops", "1"]);
        let _ = a.str_or("model", "mlp");
        assert!(a.finish().is_err());
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = parse(&["--shift", "-3"]);
        assert_eq!(a.get_parsed::<i64>("shift").unwrap(), Some(-3));
    }

    #[test]
    fn durations() {
        use std::time::Duration;
        assert_eq!(parse_duration("5s").unwrap(), Duration::from_secs(5));
        assert_eq!(parse_duration("250ms").unwrap(), Duration::from_millis(250));
        assert_eq!(parse_duration("800us").unwrap(), Duration::from_micros(800));
        assert_eq!(parse_duration("2m").unwrap(), Duration::from_secs(120));
        assert_eq!(parse_duration("0.5").unwrap(), Duration::from_millis(500));
        assert_eq!(parse_duration(" 10 ms ").unwrap(), Duration::from_millis(10));
        assert!(parse_duration("abc").is_err());
        assert!(parse_duration("5h").is_err());
        assert!(parse_duration("-3s").is_err());
        let a = parse(&["--duration", "3s"]);
        assert_eq!(a.duration_or("duration", Duration::ZERO).unwrap(), Duration::from_secs(3));
        assert_eq!(a.duration_or("missing", Duration::from_secs(7)).unwrap(), Duration::from_secs(7));
        a.finish().unwrap();
    }
}
