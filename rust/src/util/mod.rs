//! Substrate utilities: JSON, PRNG, CLI, logging, stats, thread helpers.
//!
//! These exist because the offline crate set contains only `xla` +
//! `anyhow`; everything else the coordinator needs is built here
//! (DESIGN.md §5).

pub mod cli;
pub mod cursor;
pub mod json;
pub mod logger;
pub mod pool;
pub mod rng;
pub mod stats;
