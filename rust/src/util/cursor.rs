//! Hardened bounded cursor — the one place untrusted lengths meet
//! allocations.
//!
//! Both untrusted parsers in this crate (checkpoint decode in
//! `checkpoint::decode` and the framed-TCP wire protocol in
//! `inference::net`) read attacker-controllable length fields and then
//! materialize buffers of that declared size. [`BoundedReader`] makes
//! the safe pattern the only expressible one:
//!
//! * every read states *what* it is reading, so truncation errors name
//!   the field that ran out ("truncated checkpoint while reading csr
//!   row pointers");
//! * every declared element count is bounded against the cursor's
//!   **remaining input bytes** *before* any allocation — a 16-byte file
//!   claiming 2⁶¹ rows is rejected by arithmetic, it never reaches the
//!   allocator;
//! * all size arithmetic goes through [`checked_mul`]/[`checked_add`],
//!   so release-build wraparound cannot sneak a huge claim past a
//!   plausibility guard.
//!
//! For streaming endpoints (the TCP frame reader cannot know its
//! remaining bytes), [`claimed_len`] is the shared declared-size-vs-cap
//! guard applied before the single bounded allocation.

/// Bounds-checked cursor over an untrusted in-memory byte buffer.
///
/// `ctx` is the error-message noun for the input as a whole
/// (`"checkpoint"`, `"frame"`, …): truncation reads as
/// "truncated {ctx} while reading {what}".
pub struct BoundedReader<'a> {
    /// Unread remainder of the input.
    buf: &'a [u8],
    /// Bytes consumed so far (error offsets, payload accounting).
    consumed: usize,
    ctx: &'static str,
}

impl<'a> BoundedReader<'a> {
    pub fn new(buf: &'a [u8], ctx: &'static str) -> BoundedReader<'a> {
        BoundedReader { buf, consumed: 0, ctx }
    }

    /// Unread bytes — the hard ceiling on any further declared size.
    pub fn remaining(&self) -> usize {
        self.buf.len()
    }

    /// Bytes consumed so far.
    pub fn consumed(&self) -> usize {
        self.consumed
    }

    pub fn is_empty(&self) -> bool {
        self.buf.is_empty()
    }

    /// The core guard: hand out the next `n` bytes, or fail with a
    /// truncation error naming `what`. No allocation ever happens
    /// before this check succeeds.
    pub fn take(&mut self, n: usize, what: &str) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(
            n <= self.buf.len(),
            "truncated {} while reading {what} ({n} bytes declared, {} remain at offset {})",
            self.ctx,
            self.buf.len(),
            self.consumed
        );
        let (head, tail) = self.buf.split_at(n);
        self.buf = tail;
        self.consumed += n;
        Ok(head)
    }

    /// Everything left (the "rest of body is payload" pattern).
    pub fn take_rest(&mut self) -> &'a [u8] {
        let rest = self.buf;
        self.consumed += rest.len();
        self.buf = &[];
        rest
    }

    /// Fail unless the input was consumed exactly.
    pub fn expect_empty(&self, what: &str) -> anyhow::Result<()> {
        anyhow::ensure!(self.buf.is_empty(), "{} has {} trailing bytes after {what}", self.ctx, self.buf.len());
        Ok(())
    }

    pub fn read_u8(&mut self, what: &str) -> anyhow::Result<u8> {
        Ok(self.take(1, what)?[0])
    }

    pub fn read_u16(&mut self, what: &str) -> anyhow::Result<u16> {
        let b = self.take(2, what)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn read_u32(&mut self, what: &str) -> anyhow::Result<u32> {
        let b = self.take(4, what)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn read_u64(&mut self, what: &str) -> anyhow::Result<u64> {
        let b = self.take(8, what)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn read_f32(&mut self, what: &str) -> anyhow::Result<f32> {
        Ok(f32::from_bits(self.read_u32(what)?))
    }

    /// A u64 length field that must index in-memory data: rejects
    /// values a `usize` cannot hold (32-bit targets) with an explicit
    /// error instead of an `as` truncation.
    pub fn read_len_u64(&mut self, what: &str) -> anyhow::Result<usize> {
        let v = self.read_u64(what)?;
        usize::try_from(v)
            .map_err(|_| anyhow::anyhow!("{} {what} {v} does not fit this platform's usize", self.ctx))
    }

    /// `n` raw bytes as an owned buffer; the allocation is bounded by
    /// `take`'s remaining-input guard.
    pub fn read_bytes(&mut self, n: usize, what: &str) -> anyhow::Result<Vec<u8>> {
        Ok(self.take(n, what)?.to_vec())
    }

    /// `n` little-endian u16s. `n × 2` is checked against the remaining
    /// input before the output vector is allocated.
    pub fn read_u16s(&mut self, n: usize, what: &str) -> anyhow::Result<Vec<u16>> {
        let bytes = self.take(checked_mul(n, 2, what)?, what)?;
        Ok(bytes.chunks_exact(2).map(|c| u16::from_le_bytes([c[0], c[1]])).collect())
    }

    /// `n` little-endian u32s, remaining-input-bounded before allocation.
    pub fn read_u32s(&mut self, n: usize, what: &str) -> anyhow::Result<Vec<u32>> {
        let bytes = self.take(checked_mul(n, 4, what)?, what)?;
        Ok(bytes.chunks_exact(4).map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }

    /// `n` little-endian f32s, remaining-input-bounded before allocation.
    pub fn read_f32s(&mut self, n: usize, what: &str) -> anyhow::Result<Vec<f32>> {
        let bytes = self.take(checked_mul(n, 4, what)?, what)?;
        Ok(bytes.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
    }
}

/// Overflow-rejecting multiply for dimension/size arithmetic on
/// untrusted values. Release builds wrap on `*`; this fails loudly.
pub fn checked_mul(a: usize, b: usize, what: &str) -> anyhow::Result<usize> {
    a.checked_mul(b).ok_or_else(|| anyhow::anyhow!("{what}: size arithmetic overflows ({a} × {b})"))
}

/// Overflow-rejecting add (the `rows + 1` row-pointer count).
pub fn checked_add(a: usize, b: usize, what: &str) -> anyhow::Result<usize> {
    a.checked_add(b).ok_or_else(|| anyhow::anyhow!("{what}: size arithmetic overflows ({a} + {b})"))
}

/// The streaming-endpoint guard: validate a declared frame/payload
/// length against a hard cap *before* the caller allocates its receive
/// buffer. Returns the length as `usize` on success.
pub fn claimed_len(len: u64, cap: usize, ctx: &str, what: &str) -> anyhow::Result<usize> {
    anyhow::ensure!(len <= cap as u64, "{ctx} {what} of {len} bytes exceeds the {cap}-byte cap");
    // Safe: `cap` is a usize, so `len <= cap` fits.
    Ok(len as usize)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scalar_reads_and_offsets() {
        let mut bytes = Vec::new();
        bytes.push(0xABu8);
        bytes.extend_from_slice(&0x1234u16.to_le_bytes());
        bytes.extend_from_slice(&0xDEADBEEFu32.to_le_bytes());
        bytes.extend_from_slice(&0x0123456789ABCDEFu64.to_le_bytes());
        bytes.extend_from_slice(&1.5f32.to_le_bytes());
        let mut r = BoundedReader::new(&bytes, "test");
        assert_eq!(r.read_u8("a").unwrap(), 0xAB);
        assert_eq!(r.read_u16("b").unwrap(), 0x1234);
        assert_eq!(r.read_u32("c").unwrap(), 0xDEADBEEF);
        assert_eq!(r.read_u64("d").unwrap(), 0x0123456789ABCDEF);
        assert_eq!(r.read_f32("e").unwrap(), 1.5);
        assert_eq!(r.consumed(), bytes.len());
        assert_eq!(r.remaining(), 0);
        r.expect_empty("the payload").unwrap();
    }

    #[test]
    fn truncation_at_every_field_boundary() {
        // A layout of one field of each width: cutting the input at
        // every possible byte offset must yield an explicit truncation
        // error naming the field that ran out — never a panic.
        let mut bytes = Vec::new();
        bytes.push(7u8);
        bytes.extend_from_slice(&1u16.to_le_bytes());
        bytes.extend_from_slice(&2u32.to_le_bytes());
        bytes.extend_from_slice(&3u64.to_le_bytes());
        bytes.extend_from_slice(&[9u8; 5]);
        let parse = |input: &[u8]| -> anyhow::Result<()> {
            let mut r = BoundedReader::new(input, "test");
            r.read_u8("tag")?;
            r.read_u16("count")?;
            r.read_u32("word")?;
            r.read_u64("length")?;
            r.read_bytes(5, "blob")?;
            Ok(())
        };
        parse(&bytes).unwrap();
        for cut in 0..bytes.len() {
            let err = parse(&bytes[..cut]).unwrap_err().to_string();
            assert!(err.contains("truncated test while reading"), "cut {cut}: {err}");
        }
    }

    #[test]
    fn element_reads_are_bounded_before_allocation() {
        // 8 bytes of input; a declared count of 2^61 u32s must fail on
        // the bound (and on the multiply), not attempt a 2^63-byte
        // allocation.
        let bytes = [0u8; 8];
        let mut r = BoundedReader::new(&bytes, "test");
        let err = r.read_u32s(1usize << 61, "giant array").unwrap_err().to_string();
        assert!(err.contains("truncated test") || err.contains("overflows"), "{err}");
        // The cursor is unchanged after a failed read.
        assert_eq!(r.remaining(), 8);
        assert_eq!(r.read_u32s(2, "pair").unwrap(), vec![0, 0]);
    }

    #[test]
    fn element_count_multiply_overflow_is_rejected() {
        let bytes = [0u8; 16];
        let mut r = BoundedReader::new(&bytes, "test");
        // usize::MAX elements × 4 bytes wraps in release; must error.
        let err = r.read_f32s(usize::MAX, "values").unwrap_err().to_string();
        assert!(err.contains("overflows"), "{err}");
        let mut r = BoundedReader::new(&bytes, "test");
        let err = r.read_u16s(usize::MAX, "values").unwrap_err().to_string();
        assert!(err.contains("overflows"), "{err}");
    }

    #[test]
    fn take_rest_and_expect_empty() {
        let bytes = [1u8, 2, 3, 4];
        let mut r = BoundedReader::new(&bytes, "test");
        r.read_u8("tag").unwrap();
        assert_eq!(r.take_rest(), &[2, 3, 4]);
        assert!(r.is_empty());
        r.expect_empty("the tail").unwrap();

        let mut r = BoundedReader::new(&bytes, "test");
        r.read_u8("tag").unwrap();
        let err = r.expect_empty("the tag").unwrap_err().to_string();
        assert!(err.contains("3 trailing bytes"), "{err}");
    }

    #[test]
    fn zero_length_reads_are_fine() {
        let mut r = BoundedReader::new(&[], "test");
        assert_eq!(r.read_bytes(0, "nothing").unwrap(), Vec::<u8>::new());
        assert_eq!(r.read_u32s(0, "nothing").unwrap(), Vec::<u32>::new());
        assert_eq!(r.take_rest(), &[] as &[u8]);
    }

    #[test]
    fn checked_arithmetic_helpers() {
        assert_eq!(checked_mul(3, 4, "x").unwrap(), 12);
        assert!(checked_mul(usize::MAX, 2, "x").is_err());
        assert_eq!(checked_add(usize::MAX - 1, 1, "x").unwrap(), usize::MAX);
        assert!(checked_add(usize::MAX, 1, "x").is_err());
    }

    #[test]
    fn claimed_len_guard() {
        assert_eq!(claimed_len(64, 1024, "frame", "payload").unwrap(), 64);
        assert_eq!(claimed_len(1024, 1024, "frame", "payload").unwrap(), 1024);
        let err = claimed_len(1 << 30, 1024, "frame", "payload").unwrap_err().to_string();
        assert!(err.contains("cap"), "{err}");
        // u64 lengths beyond usize range never reach the cast.
        assert!(claimed_len(u64::MAX, usize::MAX, "frame", "payload").is_ok() || cfg!(target_pointer_width = "32"));
    }
}
