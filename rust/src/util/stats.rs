//! Small statistics helpers for metrics and benchmark reporting.

/// Mean of a slice (0.0 for empty input).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (n-1 denominator; 0.0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// Linear-interpolated quantile, q in [0, 1].
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    let mut sorted = xs.to_vec();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q.clamp(0.0, 1.0) * (sorted.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        sorted[lo] + (pos - lo as f64) * (sorted[hi] - sorted[lo])
    }
}

pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

pub fn min(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::INFINITY, f64::min)
}

pub fn max(xs: &[f64]) -> f64 {
    xs.iter().copied().fold(f64::NEG_INFINITY, f64::max)
}

/// Summary bundle used by the bench harness.
#[derive(Debug, Clone)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub median: f64,
    pub max: f64,
}

pub fn summarize(xs: &[f64]) -> Summary {
    Summary {
        n: xs.len(),
        mean: mean(xs),
        std: std_dev(xs),
        min: if xs.is_empty() { 0.0 } else { min(xs) },
        median: if xs.is_empty() { 0.0 } else { median(xs) },
        max: if xs.is_empty() { 0.0 } else { max(xs) },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935299395).abs() < 1e-9);
    }

    #[test]
    fn quantiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_and_single() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[3.0]), 0.0);
        assert_eq!(median(&[3.0]), 3.0);
    }

    #[test]
    fn summary() {
        let s = summarize(&[1.0, 2.0, 3.0]);
        assert_eq!(s.n, 3);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
        assert_eq!(s.median, 2.0);
    }
}
