//! Minimal JSON parser + writer.
//!
//! The offline crate set has no `serde`, so the runtime's manifest loading
//! (`artifacts/manifest.json`), checkpoint metadata, and report writers sit
//! on this hand-rolled implementation. It supports the full JSON grammar
//! (objects, arrays, strings with escapes, numbers, bools, null) and
//! preserves object insertion order (important for stable report output).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    /// Object as (key, value) pairs in insertion order.
    Obj(Vec<(String, Json)>),
}

impl Json {
    // ----- constructors ---------------------------------------------------

    pub fn obj() -> Json {
        Json::Obj(Vec::new())
    }

    /// Insert/overwrite a key on an object; panics on non-objects.
    pub fn set(&mut self, key: &str, value: Json) -> &mut Self {
        match self {
            Json::Obj(pairs) => {
                if let Some(p) = pairs.iter_mut().find(|(k, _)| k == key) {
                    p.1 = value;
                } else {
                    pairs.push((key.to_string(), value));
                }
            }
            _ => panic!("Json::set on non-object"),
        }
        self
    }

    // ----- accessors ------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — for required manifest fields.
    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing required JSON key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(v) => Some(v),
            _ => None,
        }
    }

    /// Shape-style arrays: `[2, 3, 4]` → `vec![2, 3, 4]`.
    pub fn as_usize_vec(&self) -> Option<Vec<usize>> {
        self.as_arr()?
            .iter()
            .map(|j| j.as_usize())
            .collect::<Option<Vec<_>>>()
    }

    // ----- serialization --------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(0));
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None);
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_str(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(level) = indent {
                        newline_indent(out, level + 1);
                        item.write(out, Some(level + 1));
                    } else {
                        item.write(out, None);
                    }
                }
                if let Some(level) = indent {
                    newline_indent(out, level);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                if pairs.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(level) = indent {
                        newline_indent(out, level + 1);
                        write_str(out, k);
                        out.push_str(": ");
                        v.write(out, Some(level + 1));
                    } else {
                        write_str(out, k);
                        out.push(':');
                        v.write(out, None);
                    }
                }
                if let Some(level) = indent {
                    newline_indent(out, level);
                }
                out.push('}');
            }
        }
    }
}

fn newline_indent(out: &mut String, level: usize) {
    out.push('\n');
    for _ in 0..level {
        out.push_str("  ");
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() && n == n.trunc() && n.abs() < 1e15 {
        out.push_str(&format!("{}", n as i64));
    } else if n.is_finite() {
        out.push_str(&format!("{n}"));
    } else {
        // JSON has no NaN/Inf; emit null like most writers.
        out.push_str("null");
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

impl From<f64> for Json {
    fn from(v: f64) -> Self {
        Json::Num(v)
    }
}
impl From<usize> for Json {
    fn from(v: usize) -> Self {
        Json::Num(v as f64)
    }
}
impl From<i64> for Json {
    fn from(v: i64) -> Self {
        Json::Num(v as f64)
    }
}
impl From<bool> for Json {
    fn from(v: bool) -> Self {
        Json::Bool(v)
    }
}
impl From<&str> for Json {
    fn from(v: &str) -> Self {
        Json::Str(v.to_string())
    }
}
impl From<String> for Json {
    fn from(v: String) -> Self {
        Json::Str(v)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
impl From<BTreeMap<String, Json>> for Json {
    fn from(m: BTreeMap<String, Json>) -> Self {
        Json::Obj(m.into_iter().collect())
    }
}

// ---------------------------------------------------------------------------
// Parser
// ---------------------------------------------------------------------------

/// Recursive-descent nesting cap. The parser consumes untrusted input
/// (checkpoint headers are attacker-controlled bytes), so a document of
/// a few KB of `[[[[…` must fail with an error, not overflow the stack.
const MAX_DEPTH: usize = 128;

/// Parse a JSON document. Errors carry byte offsets for debuggability.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let mut p = Parser {
        bytes: input.as_bytes(),
        pos: 0,
    };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        anyhow::bail!("trailing data at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> anyhow::Result<u8> {
        let b = self
            .peek()
            .ok_or_else(|| anyhow::anyhow!("unexpected end of input at byte {}", self.pos))?;
        self.pos += 1;
        Ok(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        let got = self.bump()?;
        if got != b {
            anyhow::bail!(
                "expected {:?} at byte {}, got {:?}",
                b as char,
                self.pos - 1,
                got as char
            );
        }
        Ok(())
    }

    fn value(&mut self, depth: usize) -> anyhow::Result<Json> {
        if depth > MAX_DEPTH {
            anyhow::bail!("nesting deeper than {MAX_DEPTH} at byte {}", self.pos);
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn literal(&mut self, word: &str, value: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn object(&mut self, depth: usize) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let value = self.value(depth + 1)?;
            pairs.push((key, value));
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b'}' => return Ok(Json::Obj(pairs)),
                c => anyhow::bail!("expected ',' or '}}' at byte {}, got {:?}", self.pos - 1, c as char),
            }
        }
    }

    fn array(&mut self, depth: usize) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.bump()? {
                b',' => continue,
                b']' => return Ok(Json::Arr(items)),
                c => anyhow::bail!("expected ',' or ']' at byte {}, got {:?}", self.pos - 1, c as char),
            }
        }
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump()? {
                b'"' => return Ok(out),
                b'\\' => match self.bump()? {
                    b'"' => out.push('"'),
                    b'\\' => out.push('\\'),
                    b'/' => out.push('/'),
                    b'b' => out.push('\u{8}'),
                    b'f' => out.push('\u{c}'),
                    b'n' => out.push('\n'),
                    b'r' => out.push('\r'),
                    b't' => out.push('\t'),
                    b'u' => {
                        let cp = self.hex4()?;
                        // Handle surrogate pairs.
                        let c = if (0xD800..0xDC00).contains(&cp) {
                            self.expect(b'\\')?;
                            self.expect(b'u')?;
                            let low = self.hex4()?;
                            let combined =
                                0x10000 + ((cp - 0xD800) << 10) + (low.wrapping_sub(0xDC00));
                            char::from_u32(combined)
                        } else {
                            char::from_u32(cp)
                        };
                        out.push(c.ok_or_else(|| {
                            anyhow::anyhow!("invalid unicode escape at byte {}", self.pos)
                        })?);
                    }
                    c => anyhow::bail!("invalid escape {:?} at byte {}", c as char, self.pos - 1),
                },
                c if c < 0x20 => anyhow::bail!("raw control char in string at byte {}", self.pos - 1),
                c => {
                    // Re-assemble UTF-8 multibyte sequences.
                    if c < 0x80 {
                        out.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let len = utf8_len(c);
                        let end = start + len;
                        if end > self.bytes.len() {
                            anyhow::bail!("truncated UTF-8 at byte {start}");
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| anyhow::anyhow!("invalid UTF-8 at byte {start}"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> anyhow::Result<u32> {
        let mut cp = 0u32;
        for _ in 0..4 {
            let c = self.bump()?;
            let d = (c as char)
                .to_digit(16)
                .ok_or_else(|| anyhow::anyhow!("bad hex digit at byte {}", self.pos - 1))?;
            cp = cp * 16 + d;
        }
        Ok(cp)
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        let n: f64 = text
            .parse()
            .map_err(|_| anyhow::anyhow!("bad number {text:?} at byte {start}"))?;
        Ok(Json::Num(n))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        0xF0..=0xF7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("false").unwrap(), Json::Bool(false));
        assert_eq!(parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let j = parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(j.get("c").unwrap().as_str(), Some("x"));
        let arr = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), Some(&Json::Null));
    }

    #[test]
    fn parse_rejects_pathological_nesting() {
        // Fuzz-found: a few KB of `[[[[…` used to overflow the
        // recursive-descent stack. Depth past MAX_DEPTH must error.
        let deep_arr = "[".repeat(10_000);
        let err = parse(&deep_arr).unwrap_err().to_string();
        assert!(err.contains("nesting deeper than"), "{err}");
        let deep_obj = "{\"k\":".repeat(10_000);
        let err = parse(&deep_obj).unwrap_err().to_string();
        assert!(err.contains("nesting deeper than"), "{err}");
        // Depth at the cap still parses.
        let ok = format!("{}1{}", "[".repeat(MAX_DEPTH), "]".repeat(MAX_DEPTH));
        assert!(parse(&ok).is_ok());
    }

    #[test]
    fn parse_escapes() {
        let j = parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "a\nb\t\"q\" A 😀");
    }

    #[test]
    fn parse_unicode_passthrough() {
        let j = parse("\"héllo → 世界\"").unwrap();
        assert_eq!(j.as_str().unwrap(), "héllo → 世界");
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"models":{"lenet":{"num_weights":430500,"shapes":[[20,1,5,5],[20]],"ok":true}}}"#;
        let j = parse(src).unwrap();
        let again = parse(&j.to_string_compact()).unwrap();
        assert_eq!(j, again);
        let pretty = parse(&j.to_string_pretty()).unwrap();
        assert_eq!(j, pretty);
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("").is_err());
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("{\"a\" 1}").is_err());
        assert!(parse("tru").is_err());
        assert!(parse("1 2").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(vec![]));
        assert_eq!(parse(" [ ] ").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn builder_and_accessors() {
        let mut j = Json::obj();
        j.set("rate", Json::from(0.97))
            .set("n", Json::from(42usize))
            .set("name", Json::from("lenet"))
            .set("shape", Json::from(vec![2usize, 3, 4]));
        assert_eq!(j.get("n").unwrap().as_usize(), Some(42));
        assert_eq!(
            j.get("shape").unwrap().as_usize_vec(),
            Some(vec![2, 3, 4])
        );
        assert!(j.req("missing").is_err());
    }

    #[test]
    fn set_overwrites() {
        let mut j = Json::obj();
        j.set("k", Json::from(1usize));
        j.set("k", Json::from(2usize));
        assert_eq!(j.get("k").unwrap().as_usize(), Some(2));
        assert_eq!(j.as_obj().unwrap().len(), 1);
    }

    #[test]
    fn num_formatting() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.25).to_string_compact(), "3.25");
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }
}
