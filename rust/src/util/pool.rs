//! Scoped data-parallel helpers over `std::thread` (no rayon offline).
//!
//! The CSR kernels and data generators parallelize over contiguous row /
//! item ranges; `parallel_chunks` splits `0..n` across up to
//! `max_threads()` scoped threads and runs `f(range)` on each. Threads are
//! per-call (no persistent pool): the hot kernels amortize spawn cost over
//! millions of FLOPs, and per-call scoping keeps borrows simple and safe.

/// Wrapper asserting that threads write *disjoint index sets* through
/// this pointer (contiguous ranges in the row-partitioned kernels,
/// strided column sets in the batch-shared ones). Access goes through
/// `slice()` (a method, so closures capture the whole wrapper —
/// edition-2021 disjoint capture would otherwise capture the raw pointer
/// field, which is not `Sync`).
pub struct SharedMut<T>(*mut T, usize);

unsafe impl<T: Send> Send for SharedMut<T> {}
unsafe impl<T: Send> Sync for SharedMut<T> {}

impl<T> SharedMut<T> {
    pub fn new(data: &mut [T]) -> SharedMut<T> {
        SharedMut(data.as_mut_ptr(), data.len())
    }

    /// # Safety
    /// Callers on different threads must touch disjoint index ranges.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0, self.1)
    }
}

/// Fixed accumulator-lane width of the blocked kernels. The blocked
/// reduction semantics — element `k` of a row lands in lane `k % LANES`,
/// lanes collapse in the fixed tree of [`tree_reduce`] — are defined in
/// terms of this constant, *not* the hardware vector width, so results
/// are bit-identical on any SIMD ISA (the "across lane counts" half of
/// the determinism contract; `PROXCOMP_THREADS` is the other half).
pub const LANES: usize = 8;

/// Collapse [`LANES`] partial sums in a fixed tree order:
/// `((a0+a4)+(a2+a6)) + ((a1+a5)+(a3+a7))`. Every blocked kernel — and
/// the scalar reference emulations the property tests pin against —
/// must reduce through this exact tree for bit-equality to hold.
#[inline]
pub fn tree_reduce(acc: [f32; LANES]) -> f32 {
    let s0 = acc[0] + acc[4];
    let s1 = acc[1] + acc[5];
    let s2 = acc[2] + acc[6];
    let s3 = acc[3] + acc[7];
    (s0 + s2) + (s1 + s3)
}

/// Which kernel family the hot paths dispatch to (env override
/// `PROXCOMP_KERNEL`): the default 8-lane `Blocked` kernels, or the
/// pre-blocking `Scalar` sequential-reduction kernels kept as reference.
/// CI runs the test suite under both values (× the thread matrix) so the
/// blocked paths and their oracles stay exercised in every build.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum KernelMode {
    Blocked,
    Scalar,
}

/// Kernel family to use (env override `PROXCOMP_KERNEL=blocked|scalar`).
pub fn kernel_mode() -> KernelMode {
    match std::env::var("PROXCOMP_KERNEL") {
        Ok(v) if v.eq_ignore_ascii_case("scalar") => KernelMode::Scalar,
        _ => KernelMode::Blocked,
    }
}

/// Number of worker threads to use (env override `PROXCOMP_THREADS`).
pub fn max_threads() -> usize {
    if let Ok(v) = std::env::var("PROXCOMP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Partition policy for the `(batch × rows)` sparse kernels: partition
/// the batch dimension when it can feed every lane (contiguous output
/// rows per thread — the best write locality), otherwise partition the
/// weight-row dimension so single-sample serving requests still go wide.
/// Both partitions compute every output element with the same fixed
/// reduction order, so the choice never changes results bit-for-bit.
pub fn batch_saturates(batch: usize, threads: usize) -> bool {
    batch >= threads
}

/// Run `f` over disjoint chunks of `0..n` on up to `threads` scoped threads.
/// `f` receives `(start, end)` half-open ranges. Falls back to a single
/// inline call when `n` is small or one thread is requested.
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.min(n).max(1);
    if threads == 1 || n < 2 {
        if n > 0 {
            f(0, n);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            scope.spawn(move || f(start, end));
        }
    });
}

/// Run `f` over disjoint chunks of `0..n` with chunk boundaries chosen
/// so each thread gets roughly equal *weight* rather than equal index
/// count. `prefix` is a monotone prefix-sum with `prefix.len() == n + 1`
/// — for CSR kernels it is exactly the `ptr` array, so rows split by
/// nnz. This is EIE's per-PE load-imbalance fix: with one dense row
/// among thousands of near-empty ones, an even index split serializes
/// on the thread that drew the heavy row. The partition only moves the
/// *boundaries*; every element is still computed by exactly one thread
/// with the same per-element reduction order, so results stay
/// bit-identical to [`parallel_chunks`] for any thread count.
pub fn parallel_prefix_chunks<F>(n: usize, threads: usize, prefix: &[usize], f: F)
where
    F: Fn(usize, usize) + Sync,
{
    debug_assert_eq!(prefix.len(), n + 1);
    let threads = threads.min(n).max(1);
    let total = prefix[n] - prefix[0];
    if threads == 1 || n < 2 || total == 0 {
        if n > 0 {
            f(0, n);
        }
        return;
    }
    std::thread::scope(|scope| {
        let mut start = 0usize;
        for t in 0..threads {
            // Boundary: first index whose cumulative weight reaches the
            // t+1-th share (ceiling split so the shares cover `total`).
            let target = prefix[0] + (total * (t + 1)).div_ceil(threads);
            let end = if t + 1 == threads {
                n
            } else {
                prefix.partition_point(|&w| w < target).min(n).max(start)
            };
            if start < end {
                let f = &f;
                scope.spawn(move || f(start, end));
            }
            start = end;
        }
    });
}

/// Map `0..n` in parallel into a pre-allocated output vector, where each
/// index writes exactly one result slot. `f(i) -> T`.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    // Split the output into disjoint chunks, one per thread.
    let threads = threads.min(n).max(1);
    if threads <= 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(i);
        }
        return out;
    }
    let chunk = n.div_ceil(threads);
    let mut rest: &mut [T] = &mut out;
    std::thread::scope(|scope| {
        let mut start = 0usize;
        while start < n {
            let take = chunk.min(n - start);
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let f = &f;
            let base = start;
            scope.spawn(move || {
                for (j, slot) in head.iter_mut().enumerate() {
                    *slot = f(base + j);
                }
            });
            start += take;
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_everything_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(1000, 7, |a, b| {
            for i in a..b {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_and_single() {
        parallel_chunks(0, 4, |_, _| panic!("should not run"));
        let ran = AtomicUsize::new(0);
        parallel_chunks(1, 4, |a, b| {
            assert_eq!((a, b), (0, 1));
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn map_matches_serial() {
        let got = parallel_map(100, 5, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn map_single_thread_path() {
        let got = parallel_map(10, 1, |i| i + 1);
        assert_eq!(got, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn max_threads_positive() {
        assert!(max_threads() >= 1);
    }

    #[test]
    fn prefix_chunks_cover_everything_once() {
        // Heavily skewed weights: one huge row among near-empty ones.
        let mut prefix = vec![0usize];
        for i in 0..200 {
            let w = if i == 17 { 5000 } else { i % 3 };
            prefix.push(prefix.last().unwrap() + w);
        }
        for threads in [1usize, 2, 3, 7, 16] {
            let hits: Vec<AtomicUsize> = (0..200).map(|_| AtomicUsize::new(0)).collect();
            parallel_prefix_chunks(200, threads, &prefix, |a, b| {
                for i in a..b {
                    hits[i].fetch_add(1, Ordering::Relaxed);
                }
            });
            assert!(
                hits.iter().all(|h| h.load(Ordering::Relaxed) == 1),
                "threads={threads}: some index not covered exactly once"
            );
        }
    }

    #[test]
    fn prefix_chunks_balance_by_weight() {
        // 64 rows: rows 0..8 carry weight 100 each, the rest weight 1.
        // An even index split over 2 threads puts all the heavy rows on
        // thread 0; the weighted split must move the boundary early.
        let mut prefix = vec![0usize];
        for i in 0..64 {
            prefix.push(prefix.last().unwrap() + if i < 8 { 100 } else { 1 });
        }
        let boundary = std::sync::Mutex::new(Vec::new());
        parallel_prefix_chunks(64, 2, &prefix, |a, b| {
            boundary.lock().unwrap().push((a, b));
        });
        let mut ranges = boundary.into_inner().unwrap();
        ranges.sort();
        // First range must end well before the midpoint (weight, not
        // index, is balanced): 8 heavy rows ≈ 93% of total weight.
        assert!(ranges[0].1 <= 8, "boundary {ranges:?} ignored weights");
    }

    #[test]
    fn prefix_chunks_empty_and_degenerate() {
        parallel_prefix_chunks(0, 4, &[0], |_, _| panic!("should not run"));
        let ran = AtomicUsize::new(0);
        // All-zero weights still cover the range (single inline call).
        parallel_prefix_chunks(3, 4, &[0, 0, 0, 0], |a, b| {
            assert_eq!((a, b), (0, 3));
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn tree_reduce_is_the_documented_tree() {
        let a = [1.0f32, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
        let want = ((a[0] + a[4]) + (a[2] + a[6])) + ((a[1] + a[5]) + (a[3] + a[7]));
        assert_eq!(tree_reduce(a).to_bits(), want.to_bits());
        assert_eq!(tree_reduce([0.0; LANES]), 0.0);
    }

    #[test]
    fn kernel_mode_defaults_to_blocked() {
        // The env var is absent in the default test environment unless a
        // CI leg sets it; accept either but require a valid parse.
        let mode = kernel_mode();
        match std::env::var("PROXCOMP_KERNEL") {
            Ok(v) if v.eq_ignore_ascii_case("scalar") => assert_eq!(mode, KernelMode::Scalar),
            _ => assert_eq!(mode, KernelMode::Blocked),
        }
    }

    #[test]
    fn batch_partition_policy() {
        assert!(batch_saturates(8, 4));
        assert!(batch_saturates(4, 4));
        assert!(!batch_saturates(1, 4));
        assert!(!batch_saturates(3, 4));
    }
}
