//! Scoped data-parallel helpers over `std::thread` (no rayon offline).
//!
//! The CSR kernels and data generators parallelize over contiguous row /
//! item ranges; `parallel_chunks` splits `0..n` across up to
//! `max_threads()` scoped threads and runs `f(range)` on each. Threads are
//! per-call (no persistent pool): the hot kernels amortize spawn cost over
//! millions of FLOPs, and per-call scoping keeps borrows simple and safe.

/// Wrapper asserting that threads write *disjoint index sets* through
/// this pointer (contiguous ranges in the row-partitioned kernels,
/// strided column sets in the batch-shared ones). Access goes through
/// `slice()` (a method, so closures capture the whole wrapper —
/// edition-2021 disjoint capture would otherwise capture the raw pointer
/// field, which is not `Sync`).
pub struct SharedMut<T>(*mut T, usize);

unsafe impl<T: Send> Send for SharedMut<T> {}
unsafe impl<T: Send> Sync for SharedMut<T> {}

impl<T> SharedMut<T> {
    pub fn new(data: &mut [T]) -> SharedMut<T> {
        SharedMut(data.as_mut_ptr(), data.len())
    }

    /// # Safety
    /// Callers on different threads must touch disjoint index ranges.
    #[allow(clippy::mut_from_ref)]
    pub unsafe fn slice(&self) -> &mut [T] {
        std::slice::from_raw_parts_mut(self.0, self.1)
    }
}

/// Number of worker threads to use (env override `PROXCOMP_THREADS`).
pub fn max_threads() -> usize {
    if let Ok(v) = std::env::var("PROXCOMP_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

/// Partition policy for the `(batch × rows)` sparse kernels: partition
/// the batch dimension when it can feed every lane (contiguous output
/// rows per thread — the best write locality), otherwise partition the
/// weight-row dimension so single-sample serving requests still go wide.
/// Both partitions compute every output element with the same fixed
/// reduction order, so the choice never changes results bit-for-bit.
pub fn batch_saturates(batch: usize, threads: usize) -> bool {
    batch >= threads
}

/// Run `f` over disjoint chunks of `0..n` on up to `threads` scoped threads.
/// `f` receives `(start, end)` half-open ranges. Falls back to a single
/// inline call when `n` is small or one thread is requested.
pub fn parallel_chunks<F>(n: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.min(n).max(1);
    if threads == 1 || n < 2 {
        if n > 0 {
            f(0, n);
        }
        return;
    }
    let chunk = n.div_ceil(threads);
    std::thread::scope(|scope| {
        for t in 0..threads {
            let start = t * chunk;
            let end = ((t + 1) * chunk).min(n);
            if start >= end {
                break;
            }
            let f = &f;
            scope.spawn(move || f(start, end));
        }
    });
}

/// Map `0..n` in parallel into a pre-allocated output vector, where each
/// index writes exactly one result slot. `f(i) -> T`.
pub fn parallel_map<T, F>(n: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send + Default + Clone,
    F: Fn(usize) -> T + Sync,
{
    let mut out = vec![T::default(); n];
    // Split the output into disjoint chunks, one per thread.
    let threads = threads.min(n).max(1);
    if threads <= 1 {
        for (i, slot) in out.iter_mut().enumerate() {
            *slot = f(i);
        }
        return out;
    }
    let chunk = n.div_ceil(threads);
    let mut rest: &mut [T] = &mut out;
    std::thread::scope(|scope| {
        let mut start = 0usize;
        while start < n {
            let take = chunk.min(n - start);
            let (head, tail) = rest.split_at_mut(take);
            rest = tail;
            let f = &f;
            let base = start;
            scope.spawn(move || {
                for (j, slot) in head.iter_mut().enumerate() {
                    *slot = f(base + j);
                }
            });
            start += take;
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn chunks_cover_everything_once() {
        let hits: Vec<AtomicUsize> = (0..1000).map(|_| AtomicUsize::new(0)).collect();
        parallel_chunks(1000, 7, |a, b| {
            for i in a..b {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn empty_and_single() {
        parallel_chunks(0, 4, |_, _| panic!("should not run"));
        let ran = AtomicUsize::new(0);
        parallel_chunks(1, 4, |a, b| {
            assert_eq!((a, b), (0, 1));
            ran.fetch_add(1, Ordering::Relaxed);
        });
        assert_eq!(ran.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn map_matches_serial() {
        let got = parallel_map(100, 5, |i| i * i);
        let want: Vec<usize> = (0..100).map(|i| i * i).collect();
        assert_eq!(got, want);
    }

    #[test]
    fn map_single_thread_path() {
        let got = parallel_map(10, 1, |i| i + 1);
        assert_eq!(got, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn max_threads_positive() {
        assert!(max_threads() >= 1);
    }

    #[test]
    fn batch_partition_policy() {
        assert!(batch_saturates(8, 4));
        assert!(batch_saturates(4, 4));
        assert!(!batch_saturates(1, 4));
        assert!(!batch_saturates(3, 4));
    }
}
