//! Minibatch iteration: shuffled epochs, wrap-around, deterministic order.

use super::Dataset;
use crate::util::rng::Rng;

/// Cycling shuffled batcher. Each epoch reshuffles with a fresh stream
/// derived from the base seed, so runs are reproducible but epochs differ.
pub struct Batcher {
    order: Vec<usize>,
    pos: usize,
    epoch: u64,
    rng: Rng,
}

impl Batcher {
    pub fn new(n: usize, seed: u64) -> Batcher {
        let mut b = Batcher {
            order: (0..n).collect(),
            pos: 0,
            epoch: 0,
            rng: Rng::new(seed ^ 0xBA7C4E5),
        };
        b.rng.shuffle(&mut b.order);
        b
    }

    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Copy the next `batch` examples (with wrap-around + reshuffle at
    /// epoch boundaries) into flat NCHW image / label buffers.
    pub fn next_batch(&mut self, data: &Dataset, batch: usize) -> (Vec<f32>, Vec<i32>) {
        let sz = data.example_size();
        let mut xs = Vec::with_capacity(batch * sz);
        let mut ys = Vec::with_capacity(batch);
        for _ in 0..batch {
            if self.pos >= self.order.len() {
                self.pos = 0;
                self.epoch += 1;
                self.rng.shuffle(&mut self.order);
            }
            let idx = self.order[self.pos];
            self.pos += 1;
            xs.extend_from_slice(data.image(idx));
            ys.push(data.labels[idx]);
        }
        (xs, ys)
    }

    /// Iterate the whole dataset once in fixed batches (for eval); the
    /// last batch wraps around so every batch is full-size, and the
    /// caller weights by `n` when aggregating.
    pub fn eval_batches(data: &Dataset, batch: usize) -> Vec<(Vec<f32>, Vec<i32>, usize)> {
        let sz = data.example_size();
        let mut out = Vec::new();
        let mut i = 0;
        while i < data.n {
            let fresh = batch.min(data.n - i);
            let mut xs = Vec::with_capacity(batch * sz);
            let mut ys = Vec::with_capacity(batch);
            for j in 0..batch {
                let idx = if j < fresh { i + j } else { (i + j) % data.n };
                xs.extend_from_slice(data.image(idx));
                ys.push(data.labels[idx]);
            }
            out.push((xs, ys, fresh));
            i += fresh;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth_mnist;

    #[test]
    fn batches_have_right_shape() {
        let d = synth_mnist(25, 0);
        let mut b = Batcher::new(d.n, 1);
        let (xs, ys) = b.next_batch(&d, 8);
        assert_eq!(xs.len(), 8 * 784);
        assert_eq!(ys.len(), 8);
    }

    #[test]
    fn epoch_covers_everything() {
        let d = synth_mnist(20, 0);
        let mut b = Batcher::new(d.n, 1);
        let mut seen = vec![0usize; 20];
        for _ in 0..4 {
            let (_, ys) = b.next_batch(&d, 5);
            for y in ys {
                // label == index%10; count labels to check coverage loosely
                seen[y as usize] += 1;
            }
        }
        assert_eq!(b.epoch(), 0);
        let (_, _) = b.next_batch(&d, 5); // crosses into epoch 1
        assert_eq!(b.epoch(), 1);
        // Each label appears exactly twice in 20 balanced examples.
        assert!(seen[..10].iter().all(|&c| c == 2));
    }

    #[test]
    fn deterministic_given_seed() {
        let d = synth_mnist(30, 0);
        let mut a = Batcher::new(d.n, 9);
        let mut b = Batcher::new(d.n, 9);
        for _ in 0..5 {
            assert_eq!(a.next_batch(&d, 7).1, b.next_batch(&d, 7).1);
        }
    }

    #[test]
    fn eval_batches_cover_all_once() {
        let d = synth_mnist(23, 0);
        let batches = Batcher::eval_batches(&d, 10);
        assert_eq!(batches.len(), 3);
        let fresh_total: usize = batches.iter().map(|(_, _, f)| f).sum();
        assert_eq!(fresh_total, 23);
        // All batches padded to full size.
        for (xs, ys, _) in &batches {
            assert_eq!(xs.len(), 10 * 784);
            assert_eq!(ys.len(), 10);
        }
    }
}
