//! Offline stand-in for the `xla` (PJRT) crate.
//!
//! The runtime layer was written against the PJRT C API bindings of the
//! `xla` crate, which are not present in the offline build image. This
//! module mirrors the small slice of that API the crate uses so the whole
//! workspace compiles and tests without it:
//!
//! * [`Literal`] packing/unpacking is **fully functional host-side**
//!   (shape + element type + little-endian bytes) — the runtime's
//!   literal round-trip tests run against it for real.
//! * Anything that would touch a compiled executable or a device
//!   ([`PjRtClient::cpu`], [`PjRtLoadedExecutable::execute`], …) returns
//!   a descriptive error at runtime.
//!
//! `runtime::client` and `coordinator::trainer` import this module under
//! the name `xla` (`use crate::xla_compat as xla`), so swapping in the
//! real crate later is a two-line change per file plus the `pjrt`
//! feature (which also un-gates the artifact-driven integration tests).

use anyhow::Result;

/// XLA element types the manifest artifacts can produce. Only `F32` and
/// `S32` flow through the trainer today; the rest exist so downstream
/// matches keep an honest wildcard arm.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ElementType {
    Pred,
    S32,
    S64,
    U32,
    F16,
    F32,
    F64,
}

/// Host-native scalar types a [`Literal`] can be decoded into.
pub trait NativeType: Copy {
    const ELEMENT_TYPE: ElementType;
    fn from_le(bytes: [u8; 4]) -> Self;
}

impl NativeType for f32 {
    const ELEMENT_TYPE: ElementType = ElementType::F32;
    fn from_le(bytes: [u8; 4]) -> Self {
        f32::from_le_bytes(bytes)
    }
}

impl NativeType for i32 {
    const ELEMENT_TYPE: ElementType = ElementType::S32;
    fn from_le(bytes: [u8; 4]) -> Self {
        i32::from_le_bytes(bytes)
    }
}

/// Array shape of a literal (dimensions only; layout is dense row-major).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Host-side literal: element type + dims + raw little-endian bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Literal {
    ty: ElementType,
    dims: Vec<i64>,
    bytes: Vec<u8>,
}

impl Literal {
    pub fn create_from_shape_and_untyped_data(
        ty: ElementType,
        dims: &[usize],
        data: &[u8],
    ) -> Result<Literal> {
        Ok(Literal {
            ty,
            dims: dims.iter().map(|&d| d as i64).collect(),
            bytes: data.to_vec(),
        })
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        Ok(ArrayShape { dims: self.dims.clone() })
    }

    pub fn ty(&self) -> Result<ElementType> {
        Ok(self.ty)
    }

    /// Decode the payload as a vector of 4-byte host scalars. Errors on
    /// an element-type mismatch (as the real crate does) instead of
    /// silently reinterpreting the bytes.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        anyhow::ensure!(
            self.ty == T::ELEMENT_TYPE,
            "literal holds {:?}, requested {:?}",
            self.ty,
            T::ELEMENT_TYPE
        );
        anyhow::ensure!(
            self.bytes.len() % 4 == 0,
            "literal payload of {} bytes is not 4-byte aligned",
            self.bytes.len()
        );
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| T::from_le([c[0], c[1], c[2], c[3]]))
            .collect())
    }

    /// Destructure a tuple literal. Device executions are the only
    /// producers of tuples, so the stub never has one to destructure.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        Err(unavailable("Literal::to_tuple"))
    }
}

fn unavailable(what: &str) -> anyhow::Error {
    anyhow::anyhow!(
        "{what} needs the real XLA/PJRT runtime, which this offline build stubs out \
         because the `pjrt` cargo feature is disabled. Either rebuild with \
         `cargo build --features pjrt` (once the xla crate is vendored), or run the \
         pipeline on the native CPU backend instead — `Runtime::native()` / \
         `--artifacts-dir native` — which trains and serves offline without PJRT"
    )
}

/// PJRT client handle. The stub cannot create one.
pub struct PjRtClient {
    _priv: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "offline-stub".to_string()
    }

    pub fn device_count(&self) -> usize {
        0
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(unavailable("PjRtClient::compile"))
    }
}

/// Compiled executable handle (unreachable without a client).
pub struct PjRtLoadedExecutable {
    _priv: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// Device buffer handle (unreachable without a client).
pub struct PjRtBuffer {
    _priv: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Parsed HLO module (text form). Parsing needs the native XLA parser.
pub struct HloModuleProto {
    _priv: (),
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(unavailable("HloModuleProto::from_text_file"))
    }
}

/// XLA computation wrapper.
pub struct XlaComputation {
    _priv: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _priv: () }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_packs_and_decodes_f32() {
        let vals = [1.5f32, -2.0, 0.25];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::F32, &[3], &bytes).unwrap();
        assert_eq!(lit.ty().unwrap(), ElementType::F32);
        assert_eq!(lit.array_shape().unwrap().dims(), &[3i64]);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vals);
    }

    #[test]
    fn literal_packs_and_decodes_i32() {
        let vals = [7i32, -9];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[2], &bytes).unwrap();
        assert_eq!(lit.to_vec::<i32>().unwrap(), vals);
    }

    #[test]
    fn to_vec_rejects_type_mismatch() {
        let bytes = 7i32.to_le_bytes();
        let lit =
            Literal::create_from_shape_and_untyped_data(ElementType::S32, &[1], &bytes).unwrap();
        assert!(lit.to_vec::<f32>().is_err());
        assert_eq!(lit.to_vec::<i32>().unwrap(), vec![7]);
    }

    #[test]
    fn device_paths_error_descriptively() {
        // The stub's error must be actionable: name the `pjrt` feature
        // flag AND point at the native-backend escape hatch.
        let err = PjRtClient::cpu().err().unwrap();
        let msg = err.to_string();
        assert!(msg.contains("--features pjrt"), "{msg}");
        assert!(msg.contains("pjrt` cargo feature"), "{msg}");
        assert!(msg.contains("--artifacts-dir native"), "{msg}");
        let proto = HloModuleProto::from_text_file("missing.hlo.txt");
        assert!(proto.is_err());
    }
}
