//! End-to-end observability: structured event tracing and per-layer
//! profiling types shared by training and serving.
//!
//! The paper's claim is quantitative — ℓ1 prox training drives
//! per-layer sparsity that compressed kernels convert into speed — so
//! the repo needs to *watch* that happen, not reconstruct it from bench
//! JSON after the fact. This module provides the two substrates:
//!
//! * **Trace sink** — a process-global, lock-cheap event sink. Emitters
//!   call [`event`]/[`event_label`]; when tracing is disabled (the
//!   default) the only cost is one relaxed atomic load. When enabled
//!   (`PROXCOMP_TRACE=path` or [`enable_trace`]), events buffer in a
//!   fixed-capacity ring and flush to the path as JSONL — one object
//!   per line with a monotonic `ts_us` timestamp and a `trace_id` that
//!   follows a request admission→coalesce→forward→reply across the
//!   serving stack (`net` assigns one id per frame and threads it
//!   through `registry` and `server`).
//!
//! * **[`LayerProfile`]** — the per-layer measurement record the
//!   ROADMAP's activation-sparsity item needs: kernel family chosen,
//!   nnz/density of the stored weights, per-call wall time, and the
//!   zero fraction of the layer's *output* activations (EIE's speedup
//!   driver, PAPERS.md). `Engine::forward` accumulates these always —
//!   the accumulation is a histogram-free running sum, cheap next to
//!   the matmuls it measures — and `Engine::profile()` snapshots them.
//!
//! [`prometheus_text`] renders the METRICS wire snapshot (see
//! `inference/net.rs`) as Prometheus exposition text so ordinary
//! scrapers can ingest the same numbers.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock, PoisonError};
use std::time::Instant;

use crate::util::json::Json;

/// Environment knob: set to a file path to enable JSONL tracing
/// process-wide (read by [`init_trace_from_env`], which `proxcomp`
/// calls at startup).
pub const TRACE_ENV: &str = "PROXCOMP_TRACE";

/// Ring capacity: events buffered between flushes. Flushing is
/// amortized — one file write per `RING_CAPACITY` events.
const RING_CAPACITY: usize = 1024;

/// Fixed per-event field slots (no per-event heap allocation for the
/// numeric payload).
pub const MAX_EVENT_FIELDS: usize = 4;

static ENABLED: AtomicBool = AtomicBool::new(false);
static NEXT_TRACE_ID: AtomicU64 = AtomicU64::new(1);

/// One buffered trace event. `label` is the only allocating field and
/// is used sparingly (model ids, step names).
#[derive(Clone)]
struct Event {
    ts_us: u64,
    trace_id: u64,
    kind: &'static str,
    label: Option<String>,
    fields: [(&'static str, f64); MAX_EVENT_FIELDS],
    nfields: usize,
}

struct Sink {
    ring: Vec<Event>,
    out: BufWriter<File>,
    path: PathBuf,
    written: u64,
    dropped: u64,
}

fn sink() -> &'static Mutex<Option<Sink>> {
    static SINK: OnceLock<Mutex<Option<Sink>>> = OnceLock::new();
    SINK.get_or_init(|| Mutex::new(None))
}

fn lock_sink() -> MutexGuard<'static, Option<Sink>> {
    sink().lock().unwrap_or_else(PoisonError::into_inner)
}

fn epoch() -> Instant {
    static EPOCH: OnceLock<Instant> = OnceLock::new();
    *EPOCH.get_or_init(Instant::now)
}

/// Monotonic microseconds since the process's first telemetry call —
/// the `ts_us` every trace event carries.
pub fn now_us() -> u64 {
    epoch().elapsed().as_micros() as u64
}

/// The disabled-path check: one relaxed atomic load. Emitters may use
/// it to skip building labels/fields entirely.
#[inline]
pub fn trace_enabled() -> bool {
    ENABLED.load(Ordering::Relaxed)
}

/// A fresh trace id (monotonic, process-global) when tracing is
/// enabled; 0 when disabled, so untraced requests carry a sentinel
/// instead of burning the counter.
#[inline]
pub fn next_trace_id() -> u64 {
    if trace_enabled() {
        NEXT_TRACE_ID.fetch_add(1, Ordering::Relaxed)
    } else {
        0
    }
}

/// Read [`TRACE_ENV`] and enable tracing if it names a path. Errors
/// (unwritable path) are reported, not fatal — observability must
/// never take the service down.
pub fn init_trace_from_env() {
    if let Ok(path) = std::env::var(TRACE_ENV) {
        if !path.is_empty() {
            if let Err(e) = enable_trace(Path::new(&path)) {
                eprintln!("warning: {TRACE_ENV}={path}: {e}");
            }
        }
    }
}

/// Enable tracing to `path` (JSONL, truncated). Replaces and flushes
/// any previously-installed sink.
pub fn enable_trace(path: &Path) -> anyhow::Result<()> {
    let file = File::create(path).map_err(|e| anyhow::anyhow!("creating trace file {}: {e}", path.display()))?;
    let mut guard = lock_sink();
    if let Some(old) = guard.as_mut() {
        flush_locked(old);
    }
    *guard = Some(Sink {
        ring: Vec::with_capacity(RING_CAPACITY),
        out: BufWriter::new(file),
        path: path.to_path_buf(),
        written: 0,
        dropped: 0,
    });
    ENABLED.store(true, Ordering::SeqCst);
    Ok(())
}

/// Flush and close the sink; subsequent [`event`] calls are no-ops
/// again. Returns the number of events written over the sink's life.
pub fn disable_trace() -> u64 {
    ENABLED.store(false, Ordering::SeqCst);
    let mut guard = lock_sink();
    match guard.take() {
        Some(mut s) => {
            flush_locked(&mut s);
            let _ = s.out.flush();
            s.written
        }
        None => 0,
    }
}

/// Force-flush buffered events to the trace file (tests and graceful
/// shutdown; the ring otherwise flushes itself at capacity).
pub fn flush_trace() {
    if let Some(s) = lock_sink().as_mut() {
        flush_locked(s);
        let _ = s.out.flush();
    }
}

/// The active trace path, if tracing is enabled.
pub fn trace_path() -> Option<PathBuf> {
    lock_sink().as_ref().map(|s| s.path.clone())
}

/// Emit a trace event. Near-free when tracing is disabled. Fields past
/// [`MAX_EVENT_FIELDS`] are dropped (fixed slots, no allocation).
#[inline]
pub fn event(kind: &'static str, trace_id: u64, fields: &[(&'static str, f64)]) {
    if !trace_enabled() {
        return;
    }
    push_event(kind, trace_id, None, fields);
}

/// [`event`] with a string label (model id, step name). Allocates for
/// the label, so callers on hot paths prefer plain [`event`].
#[inline]
pub fn event_label(kind: &'static str, trace_id: u64, label: &str, fields: &[(&'static str, f64)]) {
    if !trace_enabled() {
        return;
    }
    push_event(kind, trace_id, Some(label.to_string()), fields);
}

fn push_event(kind: &'static str, trace_id: u64, label: Option<String>, fields: &[(&'static str, f64)]) {
    let ts_us = now_us();
    let mut slots = [("", 0.0f64); MAX_EVENT_FIELDS];
    let nfields = fields.len().min(MAX_EVENT_FIELDS);
    slots[..nfields].copy_from_slice(&fields[..nfields]);
    let mut guard = lock_sink();
    let Some(s) = guard.as_mut() else {
        return; // enabled flag raced a disable; drop silently
    };
    s.ring.push(Event { ts_us, trace_id, kind, label, fields: slots, nfields });
    if s.ring.len() >= RING_CAPACITY {
        flush_locked(s);
    }
}

fn flush_locked(s: &mut Sink) {
    for e in s.ring.drain(..) {
        let mut j = Json::obj();
        j.set("ts_us", Json::from(e.ts_us as usize)).set("kind", Json::from(e.kind));
        if e.trace_id != 0 {
            j.set("id", Json::from(e.trace_id as usize));
        }
        if let Some(label) = &e.label {
            j.set("label", Json::from(label.as_str()));
        }
        for (k, v) in &e.fields[..e.nfields] {
            j.set(k, Json::from(*v));
        }
        if writeln!(s.out, "{}", j.to_string_compact()).is_err() {
            s.dropped += 1;
        } else {
            s.written += 1;
        }
    }
}

// ---------------------------------------------------------------------------
// Per-layer profiling
// ---------------------------------------------------------------------------

/// Running per-layer accumulator `Engine::forward` folds into on every
/// call — sums only, so recording is O(1) beyond the one O(outputs)
/// zero-count pass.
#[derive(Debug, Default, Clone)]
pub struct LayerProfileAccum {
    /// Forward calls that executed this layer.
    pub calls: u64,
    /// Total wall time spent in this layer across those calls.
    pub total_us: u64,
    /// Zero output activations summed across calls.
    pub out_zeros: u64,
    /// Total output activations summed across calls.
    pub out_elems: u64,
}

impl LayerProfileAccum {
    pub fn record(&mut self, micros: u64, out_zeros: u64, out_elems: u64) {
        self.calls += 1;
        self.total_us += micros;
        self.out_zeros += out_zeros;
        self.out_elems += out_elems;
    }
}

/// Snapshot of one layer's profile: the static facts (kernel family,
/// stored nnz/density) joined with the runtime accumulator.
#[derive(Debug, Clone)]
pub struct LayerProfile {
    /// Layer name as reported by per-layer timings (`fc1`, `conv2`, …).
    pub name: String,
    /// Kernel family serving the layer: `dense`, `CSR`, `QCS`, or a
    /// dispatch-chosen sparse format name.
    pub format: String,
    /// Logical (rows, cols) of the layer's weight matrix view.
    pub rows: usize,
    pub cols: usize,
    /// Stored nonzeros.
    pub nnz: usize,
    /// `nnz / (rows*cols)` — the weight density the prox training drove.
    pub density: f64,
    pub calls: u64,
    pub total_us: u64,
    /// `total_us / calls` (0 before the first call).
    pub mean_us: f64,
    /// Fraction of this layer's output activations that were exactly
    /// zero — the activation-sparsity signal EIE exploits.
    pub out_zero_fraction: f64,
}

impl LayerProfile {
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("layer", Json::from(self.name.as_str()))
            .set("format", Json::from(self.format.as_str()))
            .set("rows", Json::from(self.rows))
            .set("cols", Json::from(self.cols))
            .set("nnz", Json::from(self.nnz))
            .set("density", Json::from(self.density))
            .set("calls", Json::from(self.calls as usize))
            .set("total_us", Json::from(self.total_us as usize))
            .set("mean_us", Json::from(self.mean_us))
            .set("out_zero_fraction", Json::from(self.out_zero_fraction));
        j
    }
}

/// Count exactly-zero values — the output-activation sparsity probe.
pub fn zero_count(data: &[f32]) -> u64 {
    data.iter().filter(|v| **v == 0.0).count() as u64
}

// ---------------------------------------------------------------------------
// Prometheus exposition rendering
// ---------------------------------------------------------------------------

fn prom_escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

fn prom_num(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

/// Render the METRICS JSON snapshot (`inference/net.rs`) as
/// Prometheus exposition text. Tolerant of absent keys: each section
/// renders from whatever the snapshot carries.
pub fn prometheus_text(snapshot: &Json) -> String {
    let mut out = String::new();
    let num = |j: &Json, key: &str| j.get(key).and_then(Json::as_f64);

    if let Some(serving) = snapshot.get("serving") {
        out.push_str("# TYPE proxcomp_fleet_requests_total counter\n");
        if let Some(v) = num(serving, "requests") {
            out.push_str(&format!("proxcomp_fleet_requests_total {}\n", prom_num(v)));
        }
        out.push_str("# TYPE proxcomp_fleet_latency_us gauge\n");
        for (q, key) in [("0.5", "p50_latency_us"), ("0.9", "p90_latency_us"), ("0.99", "p99_latency_us")] {
            if let Some(v) = num(serving, key) {
                out.push_str(&format!("proxcomp_fleet_latency_us{{quantile=\"{q}\"}} {}\n", prom_num(v)));
            }
        }
        if let Some(v) = num(serving, "throughput_rps") {
            out.push_str("# TYPE proxcomp_fleet_throughput_rps gauge\n");
            out.push_str(&format!("proxcomp_fleet_throughput_rps {}\n", prom_num(v)));
        }
    }
    if let Some(net) = snapshot.get("net").and_then(Json::as_obj) {
        out.push_str("# TYPE proxcomp_net_responses_total counter\n");
        for (k, v) in net {
            if let Some(v) = v.as_f64() {
                out.push_str(&format!("proxcomp_net_responses_total{{kind=\"{}\"}} {}\n", prom_escape(k), prom_num(v)));
            }
        }
    }
    if let Some(models) = snapshot.get("models").and_then(Json::as_obj) {
        out.push_str("# TYPE proxcomp_model_requests_total counter\n");
        out.push_str("# TYPE proxcomp_model_loads_total counter\n");
        out.push_str("# TYPE proxcomp_model_evictions_total counter\n");
        out.push_str("# TYPE proxcomp_model_bytes gauge\n");
        for (id, row) in models {
            let id = prom_escape(id);
            for (metric, key) in [
                ("proxcomp_model_requests_total", "requests_total"),
                ("proxcomp_model_loads_total", "loads"),
                ("proxcomp_model_evictions_total", "evictions"),
                ("proxcomp_model_bytes", "bytes"),
            ] {
                if let Some(v) = row.get(key).and_then(Json::as_f64) {
                    out.push_str(&format!("{metric}{{model=\"{id}\"}} {}\n", prom_num(v)));
                }
            }
        }
    }
    if let Some(profiles) = snapshot.get("profiles").and_then(Json::as_obj) {
        out.push_str("# TYPE proxcomp_layer_nnz gauge\n");
        out.push_str("# TYPE proxcomp_layer_density gauge\n");
        out.push_str("# TYPE proxcomp_layer_calls_total counter\n");
        out.push_str("# TYPE proxcomp_layer_mean_us gauge\n");
        out.push_str("# TYPE proxcomp_layer_out_zero_fraction gauge\n");
        for (id, layers) in profiles {
            let id = prom_escape(id);
            let Some(layers) = layers.as_arr() else { continue };
            for layer in layers {
                let Some(name) = layer.get("layer").and_then(Json::as_str) else { continue };
                let labels = format!("{{model=\"{id}\",layer=\"{}\"}}", prom_escape(name));
                for (metric, key) in [
                    ("proxcomp_layer_nnz", "nnz"),
                    ("proxcomp_layer_density", "density"),
                    ("proxcomp_layer_calls_total", "calls"),
                    ("proxcomp_layer_mean_us", "mean_us"),
                    ("proxcomp_layer_out_zero_fraction", "out_zero_fraction"),
                ] {
                    if let Some(v) = layer.get(key).and_then(Json::as_f64) {
                        out.push_str(&format!("{metric}{labels} {}\n", prom_num(v)));
                    }
                }
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    // The sink is process-global, so every test that enables tracing
    // serializes on this lock and disables before releasing it.
    fn trace_test_lock() -> &'static Mutex<()> {
        static LOCK: OnceLock<Mutex<()>> = OnceLock::new();
        LOCK.get_or_init(|| Mutex::new(()))
    }

    fn unique_path(label: &str) -> PathBuf {
        std::env::temp_dir().join(format!("proxcomp_trace_{label}_{}.jsonl", std::process::id()))
    }

    #[test]
    fn disabled_sink_is_inert() {
        let _guard = trace_test_lock().lock().unwrap_or_else(PoisonError::into_inner);
        assert!(!trace_enabled());
        assert_eq!(next_trace_id(), 0);
        // No sink: events vanish without error.
        event("test.noop", 0, &[("x", 1.0)]);
        flush_trace();
    }

    #[test]
    fn events_round_trip_as_jsonl() {
        let _guard = trace_test_lock().lock().unwrap_or_else(PoisonError::into_inner);
        let path = unique_path("roundtrip");
        enable_trace(&path).unwrap();
        assert!(trace_enabled());
        let id = next_trace_id();
        assert!(id > 0);
        event("test.plain", id, &[("batch", 4.0), ("us", 125.5)]);
        event_label("test.labeled", id, "mlp-s", &[]);
        // One field past the fixed slots is dropped, not an error.
        event("test.overflow", id, &[("a", 1.0), ("b", 2.0), ("c", 3.0), ("d", 4.0), ("e", 5.0)]);
        let written = disable_trace();
        assert!(!trace_enabled());
        assert_eq!(written, 3);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3);
        let first = crate::util::json::parse(lines[0]).unwrap();
        assert_eq!(first.get("kind").and_then(Json::as_str), Some("test.plain"));
        assert_eq!(first.get("id").and_then(Json::as_f64), Some(id as f64));
        assert_eq!(first.get("batch").and_then(Json::as_f64), Some(4.0));
        let second = crate::util::json::parse(lines[1]).unwrap();
        assert_eq!(second.get("label").and_then(Json::as_str), Some("mlp-s"));
        let third = crate::util::json::parse(lines[2]).unwrap();
        assert!(third.get("d").is_some());
        assert!(third.get("e").is_none());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn trace_ids_are_monotonic_while_enabled() {
        let _guard = trace_test_lock().lock().unwrap_or_else(PoisonError::into_inner);
        let path = unique_path("ids");
        enable_trace(&path).unwrap();
        let a = next_trace_id();
        let b = next_trace_id();
        assert!(b > a);
        disable_trace();
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn layer_profile_accum_and_json() {
        let mut acc = LayerProfileAccum::default();
        acc.record(100, 30, 100);
        acc.record(200, 50, 100);
        assert_eq!((acc.calls, acc.total_us, acc.out_zeros, acc.out_elems), (2, 300, 80, 200));
        let p = LayerProfile {
            name: "fc1".to_string(),
            format: "CSR".to_string(),
            rows: 10,
            cols: 20,
            nnz: 40,
            density: 0.2,
            calls: acc.calls,
            total_us: acc.total_us,
            mean_us: acc.total_us as f64 / acc.calls as f64,
            out_zero_fraction: acc.out_zeros as f64 / acc.out_elems as f64,
        };
        let j = p.to_json();
        assert_eq!(j.get("layer").and_then(Json::as_str), Some("fc1"));
        assert_eq!(j.get("nnz").and_then(Json::as_f64), Some(40.0));
        assert_eq!(j.get("out_zero_fraction").and_then(Json::as_f64), Some(0.4));
    }

    #[test]
    fn zero_count_counts_exact_zeros() {
        assert_eq!(zero_count(&[0.0, 1.0, -0.0, 2.0, 0.0]), 3);
        assert_eq!(zero_count(&[]), 0);
    }

    #[test]
    fn prometheus_rendering_from_snapshot() {
        let text = r#"{
            "version": 1,
            "serving": {"requests": 12, "p50_latency_us": 100.0, "p90_latency_us": 200.0,
                        "p99_latency_us": 300.0, "throughput_rps": 50.5},
            "net": {"ok_responses": 12, "overloaded": 3},
            "models": {"mlp-s": {"requests_total": 12, "loads": 1, "evictions": 0, "bytes": 4096}},
            "profiles": {"mlp-s": [{"layer": "fc1", "format": "CSR", "nnz": 40, "density": 0.2,
                                     "calls": 12, "mean_us": 80.0, "out_zero_fraction": 0.4}]}
        }"#;
        let snap = crate::util::json::parse(text).unwrap();
        let prom = prometheus_text(&snap);
        assert!(prom.contains("proxcomp_fleet_requests_total 12\n"), "{prom}");
        assert!(prom.contains("proxcomp_fleet_latency_us{quantile=\"0.99\"} 300\n"), "{prom}");
        assert!(prom.contains("proxcomp_net_responses_total{kind=\"overloaded\"} 3\n"), "{prom}");
        assert!(prom.contains("proxcomp_model_requests_total{model=\"mlp-s\"} 12\n"), "{prom}");
        assert!(prom.contains("proxcomp_layer_density{model=\"mlp-s\",layer=\"fc1\"} 0.2\n"), "{prom}");
        assert!(prom.contains("proxcomp_layer_out_zero_fraction{model=\"mlp-s\",layer=\"fc1\"} 0.4\n"), "{prom}");
    }
}
