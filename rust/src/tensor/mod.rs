//! Dense f32 tensor substrate (NCHW) for the rust-side inference engine.
//!
//! This is the "Caffe blob" analogue the compressed inference path builds
//! on: conv via im2col + matmul (so the CSR kernels drop in for compressed
//! weights — the paper's formulation), pooling, activations, softmax.
//! Deliberately f32-only and row-major; the training path runs in XLA, so
//! this module only needs forward ops.

use crate::util::pool;

/// Row-major dense tensor with an explicit shape.
#[derive(Debug, Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Tensor {
        assert_eq!(
            shape.iter().product::<usize>(),
            data.len(),
            "shape {shape:?} vs data len {}",
            data.len()
        );
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Tensor {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn numel(&self) -> usize {
        self.data.len()
    }

    pub fn rank(&self) -> usize {
        self.shape.len()
    }

    /// Reshape without copying (must preserve element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    /// 2-D accessor helpers.
    pub fn rows(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[0]
    }

    pub fn cols(&self) -> usize {
        assert_eq!(self.rank(), 2);
        self.shape[1]
    }

    pub fn at2(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.shape[1] + c]
    }
}

// ---------------------------------------------------------------------------
// Dense matmul (used by the dense inference baseline and as test reference)
// ---------------------------------------------------------------------------

/// `a (M,K) @ b' (K,N)` where `b` is stored `(N,K)` row-major — the same
/// contraction as the paper's forward `Dmat × Cmat'`, dense version.
/// Multithreaded over rows of `a`.
pub fn matmul_nt(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, k) = (a.shape[0], a.shape[1]);
    let (n, k2) = (b.shape[0], b.shape[1]);
    assert_eq!(k, k2, "matmul_nt contraction mismatch");
    let mut out = vec![0.0f32; m * n];
    let threads = pool::max_threads();
    // Parallel over row-chunks of the output; each chunk is disjoint.
    let out_ptr = pool::SharedMut::new(&mut out);
    pool::parallel_chunks(m, threads, |r0, r1| {
        let out = unsafe { out_ptr.slice() };
        for r in r0..r1 {
            let arow = &a.data[r * k..(r + 1) * k];
            for c in 0..n {
                let brow = &b.data[c * k..(c + 1) * k];
                // §Perf: 8 independent accumulators break the serial FP
                // dependence chain so the loop auto-vectorizes (a single
                // `acc +=` forces strict ordering and stays scalar).
                let mut acc = [0.0f32; 8];
                let chunks = k / 8;
                for i in 0..chunks {
                    for l in 0..8 {
                        acc[l] += arow[i * 8 + l] * brow[i * 8 + l];
                    }
                }
                let mut tail = 0.0f32;
                for i in chunks * 8..k {
                    tail += arow[i] * brow[i];
                }
                out[r * n + c] = acc.iter().sum::<f32>() + tail;
            }
        }
    });
    Tensor::new(vec![m, n], out)
}

/// `a (M,N) @ b (N,K)` plain matmul (dense version of `Dmat × Cmat`).
pub fn matmul(a: &Tensor, b: &Tensor) -> Tensor {
    let (m, n) = (a.shape[0], a.shape[1]);
    let (n2, k) = (b.shape[0], b.shape[1]);
    assert_eq!(n, n2, "matmul contraction mismatch");
    let mut out = vec![0.0f32; m * k];
    for r in 0..m {
        for j in 0..n {
            let av = a.data[r * n + j];
            if av == 0.0 {
                continue;
            }
            let brow = &b.data[j * k..(j + 1) * k];
            let orow = &mut out[r * k..(r + 1) * k];
            for i in 0..k {
                orow[i] += av * brow[i];
            }
        }
    }
    Tensor::new(vec![m, k], out)
}

// ---------------------------------------------------------------------------
// im2col convolution (NCHW, OIHW weights)
// ---------------------------------------------------------------------------

#[derive(Debug, Clone, Copy)]
pub struct ConvSpec {
    pub stride: usize,
    /// Symmetric zero padding.
    pub pad: usize,
}

/// Output spatial size for a conv/pool window.
pub fn out_dim(input: usize, k: usize, stride: usize, pad: usize) -> usize {
    (input + 2 * pad - k) / stride + 1
}

/// Unfold `x (B,C,H,W)` into the im2col matrix `(B*OH*OW, C*KH*KW)`.
///
/// Each output row is the receptive field of one output pixel; the conv
/// then becomes `im2col @ W'` with `W (O, C*KH*KW)` — exactly the
/// dense×compressed' product the paper's Figure-2 kernel computes when
/// `W` is stored CSR.
pub fn im2col(x: &Tensor, kh: usize, kw: usize, spec: ConvSpec) -> Tensor {
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let oh = out_dim(h, kh, spec.stride, spec.pad);
    let ow = out_dim(w, kw, spec.stride, spec.pad);
    let cols = c * kh * kw;
    let rows = b * oh * ow;
    let mut out = vec![0.0f32; rows * cols];
    let out_ptr = pool::SharedMut::new(&mut out);
    pool::parallel_chunks(b, pool::max_threads(), |b0, b1| {
        let out = unsafe { out_ptr.slice() };
        for bi in b0..b1 {
            for oy in 0..oh {
                for ox in 0..ow {
                    let row = (bi * oh + oy) * ow + ox;
                    let base = row * cols;
                    for ci in 0..c {
                        for ky in 0..kh {
                            let iy = (oy * spec.stride + ky) as isize - spec.pad as isize;
                            for kx in 0..kw {
                                let ix = (ox * spec.stride + kx) as isize - spec.pad as isize;
                                let col = (ci * kh + ky) * kw + kx;
                                out[base + col] = if iy >= 0
                                    && ix >= 0
                                    && (iy as usize) < h
                                    && (ix as usize) < w
                                {
                                    x.data[((bi * c + ci) * h + iy as usize) * w + ix as usize]
                                } else {
                                    0.0
                                };
                            }
                        }
                    }
                }
            }
        }
    });
    Tensor::new(vec![rows, cols], out)
}

/// Fold gradients back through [`im2col`]: the exact adjoint, i.e.
/// `⟨im2col(x), y⟩ = ⟨x, col2im(y)⟩` for every `x (B,C,H,W)` and
/// `y (B*OH*OW, C*KH*KW)` — which makes `col2im(dy·W)` the conv input
/// gradient of the im2col-as-matmul formulation the native training
/// backend uses. Partitions over the batch axis (each example's scatter
/// is independent) with a fixed in-example loop order, so results are
/// bit-identical for any thread count.
pub fn col2im(
    cols: &Tensor,
    b: usize,
    c: usize,
    h: usize,
    w: usize,
    kh: usize,
    kw: usize,
    spec: ConvSpec,
) -> Tensor {
    let oh = out_dim(h, kh, spec.stride, spec.pad);
    let ow = out_dim(w, kw, spec.stride, spec.pad);
    let ncols = c * kh * kw;
    assert_eq!(
        cols.shape,
        vec![b * oh * ow, ncols],
        "col2im: cols shape {:?} does not match (B*OH*OW, C*KH*KW) for ({b},{c},{h},{w})",
        cols.shape
    );
    let mut out = vec![0.0f32; b * c * h * w];
    let out_ptr = pool::SharedMut::new(&mut out);
    pool::parallel_chunks(b, pool::max_threads(), |b0, b1| {
        let out = unsafe { out_ptr.slice() };
        for bi in b0..b1 {
            for oy in 0..oh {
                for ox in 0..ow {
                    let row = (bi * oh + oy) * ow + ox;
                    let base = row * ncols;
                    for ci in 0..c {
                        for ky in 0..kh {
                            let iy = (oy * spec.stride + ky) as isize - spec.pad as isize;
                            if iy < 0 || iy as usize >= h {
                                continue;
                            }
                            for kx in 0..kw {
                                let ix = (ox * spec.stride + kx) as isize - spec.pad as isize;
                                if ix < 0 || ix as usize >= w {
                                    continue;
                                }
                                let col = (ci * kh + ky) * kw + kx;
                                out[((bi * c + ci) * h + iy as usize) * w + ix as usize] +=
                                    cols.data[base + col];
                            }
                        }
                    }
                }
            }
        }
    });
    Tensor::new(vec![b, c, h, w], out)
}

/// Dense conv2d: im2col + matmul_nt + bias. `w (O,C,KH,KW)`, `b (O)`.
pub fn conv2d(x: &Tensor, w: &Tensor, bias: &[f32], spec: ConvSpec) -> Tensor {
    let (batch, _c, h, wd) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (o, ci, kh, kw) = (w.shape[0], w.shape[1], w.shape[2], w.shape[3]);
    assert_eq!(bias.len(), o);
    let oh = out_dim(h, kh, spec.stride, spec.pad);
    let ow = out_dim(wd, kw, spec.stride, spec.pad);
    let cols = im2col(x, kh, kw, spec); // (B*OH*OW, C*KH*KW)
    let wmat = Tensor::new(vec![o, ci * kh * kw], w.data.clone());
    let y = matmul_nt(&cols, &wmat); // (B*OH*OW, O)
    // Transpose (B*OH*OW, O) -> (B, O, OH, OW) with bias.
    let mut out = vec![0.0f32; batch * o * oh * ow];
    for bi in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = (bi * oh + oy) * ow + ox;
                for oc in 0..o {
                    out[((bi * o + oc) * oh + oy) * ow + ox] = y.data[row * o + oc] + bias[oc];
                }
            }
        }
    }
    Tensor::new(vec![batch, o, oh, ow], out)
}

// ---------------------------------------------------------------------------
// Pooling / activations / heads
// ---------------------------------------------------------------------------

pub fn max_pool(x: &Tensor, size: usize, stride: usize) -> Tensor {
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let oh = out_dim(h, size, stride, 0);
    let ow = out_dim(w, size, stride, 0);
    let mut out = vec![f32::NEG_INFINITY; b * c * oh * ow];
    for bi in 0..b {
        for ci in 0..c {
            for oy in 0..oh {
                for ox in 0..ow {
                    let mut m = f32::NEG_INFINITY;
                    for ky in 0..size {
                        for kx in 0..size {
                            let v = x.data
                                [((bi * c + ci) * h + oy * stride + ky) * w + ox * stride + kx];
                            if v > m {
                                m = v;
                            }
                        }
                    }
                    out[((bi * c + ci) * oh + oy) * ow + ox] = m;
                }
            }
        }
    }
    Tensor::new(vec![b, c, oh, ow], out)
}

/// Max-pool backward: route each output gradient to the window position
/// that won the forward max, matching [`max_pool`]'s first-max-wins scan
/// (`ky`, `kx` ascending — the fixed tie-break that keeps training
/// deterministic). Overlapping windows accumulate in that same fixed
/// order; partitioned over the batch axis, so results are bit-identical
/// for any thread count.
pub fn max_pool_backward(x: &Tensor, dy: &Tensor, size: usize, stride: usize) -> Tensor {
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let oh = out_dim(h, size, stride, 0);
    let ow = out_dim(w, size, stride, 0);
    assert_eq!(
        dy.shape,
        vec![b, c, oh, ow],
        "max_pool_backward: dy shape {:?} does not match pooled {:?}",
        dy.shape,
        [b, c, oh, ow]
    );
    let mut dx = vec![0.0f32; x.numel()];
    let dx_ptr = pool::SharedMut::new(&mut dx);
    pool::parallel_chunks(b, pool::max_threads(), |b0, b1| {
        let dx = unsafe { dx_ptr.slice() };
        for bi in b0..b1 {
            for ci in 0..c {
                for oy in 0..oh {
                    for ox in 0..ow {
                        let mut best = f32::NEG_INFINITY;
                        let mut best_idx = 0usize;
                        for ky in 0..size {
                            for kx in 0..size {
                                let idx = ((bi * c + ci) * h + oy * stride + ky) * w
                                    + ox * stride
                                    + kx;
                                let v = x.data[idx];
                                if v > best {
                                    best = v;
                                    best_idx = idx;
                                }
                            }
                        }
                        dx[best_idx] += dy.data[((bi * c + ci) * oh + oy) * ow + ox];
                    }
                }
            }
        }
    });
    Tensor::new(x.shape.clone(), dx)
}

pub fn global_avg_pool(x: &Tensor) -> Tensor {
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let mut out = vec![0.0f32; b * c];
    for bi in 0..b {
        for ci in 0..c {
            let plane = &x.data[(bi * c + ci) * h * w..(bi * c + ci + 1) * h * w];
            out[bi * c + ci] = plane.iter().sum::<f32>() / (h * w) as f32;
        }
    }
    Tensor::new(vec![b, c], out)
}

pub fn relu_inplace(x: &mut Tensor) {
    for v in &mut x.data {
        if *v < 0.0 {
            *v = 0.0;
        }
    }
}

/// Batch-statistics batch norm (matches `models/common.py::batch_norm`).
pub fn batch_norm(x: &Tensor, scale: &[f32], bias: &[f32], eps: f32) -> Tensor {
    let (b, c, h, w) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    assert_eq!(scale.len(), c);
    let n = (b * h * w) as f32;
    let mut out = x.clone();
    for ci in 0..c {
        let mut sum = 0.0f64;
        let mut sq = 0.0f64;
        for bi in 0..b {
            for i in 0..h * w {
                let v = x.data[(bi * c + ci) * h * w + i] as f64;
                sum += v;
                sq += v * v;
            }
        }
        let mean = (sum / n as f64) as f32;
        let var = (sq / n as f64) as f32 - mean * mean;
        let inv = (var + eps).sqrt().recip();
        for bi in 0..b {
            for i in 0..h * w {
                let idx = (bi * c + ci) * h * w + i;
                out.data[idx] = (x.data[idx] - mean) * inv * scale[ci] + bias[ci];
            }
        }
    }
    out
}

/// Inference-mode batch norm: per-channel affine from *folded running
/// stats* instead of batch statistics. Each element maps through
/// `(x − mean[c]) · g[c] + bias[c]` with `g[c] = scale[c] /
/// √(var[c] + eps)` — purely elementwise in the batch dimension, so a
/// batched forward is bit-identical to per-sample forwards (the
/// property that lets `BatchServer` coalesce requests for BN models).
/// Shared by the serving engine and the native training backend so the
/// frozen-stats forward is one arithmetic everywhere.
pub fn batch_norm_inference(
    x: &Tensor,
    scale: &[f32],
    bias: &[f32],
    mean: &[f32],
    var: &[f32],
    eps: f32,
) -> Tensor {
    let (b, c, hw) = (x.shape[0], x.shape[1], x.shape[2..].iter().product::<usize>());
    assert_eq!(scale.len(), c);
    assert_eq!(bias.len(), c);
    assert_eq!(mean.len(), c);
    assert_eq!(var.len(), c);
    let mut out = x.clone();
    for ci in 0..c {
        let g = scale[ci] * (var[ci] + eps).sqrt().recip();
        for bi in 0..b {
            let plane = &mut out.data[(bi * c + ci) * hw..(bi * c + ci + 1) * hw];
            for v in plane.iter_mut() {
                *v = (*v - mean[ci]) * g + bias[ci];
            }
        }
    }
    out
}

/// Per-row softmax of a (B, N) tensor.
pub fn softmax(x: &Tensor) -> Tensor {
    let (b, n) = (x.shape[0], x.shape[1]);
    let mut out = x.clone();
    for r in 0..b {
        let row = &mut out.data[r * n..(r + 1) * n];
        let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut z = 0.0f32;
        for v in row.iter_mut() {
            *v = (*v - m).exp();
            z += *v;
        }
        for v in row.iter_mut() {
            *v /= z;
        }
    }
    out
}

/// Row argmax of a (B, N) tensor.
pub fn argmax_rows(x: &Tensor) -> Vec<usize> {
    let (b, n) = (x.shape[0], x.shape[1]);
    (0..b)
        .map(|r| {
            let row = &x.data[r * n..(r + 1) * n];
            row.iter()
                .enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i)
                .unwrap()
        })
        .collect()
}

/// Add a broadcast bias to each row of a (B, N) tensor, in place.
pub fn add_bias_rows(x: &mut Tensor, bias: &[f32]) {
    let (b, n) = (x.shape[0], x.shape[1]);
    assert_eq!(bias.len(), n);
    for r in 0..b {
        for c in 0..n {
            x.data[r * n + c] += bias[c];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn t2(rows: usize, cols: usize, data: &[f32]) -> Tensor {
        Tensor::new(vec![rows, cols], data.to_vec())
    }

    #[test]
    fn matmul_nt_small() {
        // a (2,3) @ b'(3,2) with b stored (2,3)
        let a = t2(2, 3, &[1., 2., 3., 4., 5., 6.]);
        let b = t2(2, 3, &[1., 0., 1., 0., 1., 0.]);
        let y = matmul_nt(&a, &b);
        assert_eq!(y.data, vec![4., 2., 10., 5.]);
    }

    #[test]
    fn matmul_plain_small() {
        let a = t2(2, 2, &[1., 2., 3., 4.]);
        let b = t2(2, 3, &[1., 0., 2., 0., 1., 1.]);
        let y = matmul(&a, &b);
        assert_eq!(y.data, vec![1., 2., 4., 3., 4., 10.]);
    }

    #[test]
    fn matmul_agree_with_transposed() {
        // matmul(a, b) == matmul_nt(a, b^T)
        let mut rng = crate::util::rng::Rng::new(1);
        let a = Tensor::new(vec![5, 7], rng.normal_vec(35, 1.0));
        let b = Tensor::new(vec![7, 4], rng.normal_vec(28, 1.0));
        // transpose b into (4,7)
        let mut bt = vec![0.0; 28];
        for i in 0..7 {
            for j in 0..4 {
                bt[j * 7 + i] = b.data[i * 4 + j];
            }
        }
        let y1 = matmul(&a, &b);
        let y2 = matmul_nt(&a, &Tensor::new(vec![4, 7], bt));
        for (u, v) in y1.data.iter().zip(&y2.data) {
            assert!((u - v).abs() < 1e-4);
        }
    }

    #[test]
    fn im2col_identity_kernel() {
        // 1x1 kernel, stride 1, no pad: im2col == channel-major reshuffle.
        let x = Tensor::new(vec![1, 2, 2, 2], (0..8).map(|i| i as f32).collect());
        let cols = im2col(&x, 1, 1, ConvSpec { stride: 1, pad: 0 });
        assert_eq!(cols.shape, vec![4, 2]);
        // row (oy,ox) = [c0(y,x), c1(y,x)]
        assert_eq!(cols.data, vec![0., 4., 1., 5., 2., 6., 3., 7.]);
    }

    #[test]
    fn conv2d_hand_computed() {
        // 3x3 input, 2x2 kernel of ones, valid: each output = window sum.
        let x = Tensor::new(vec![1, 1, 3, 3], (1..=9).map(|i| i as f32).collect());
        let w = Tensor::new(vec![1, 1, 2, 2], vec![1.0; 4]);
        let y = conv2d(&x, &w, &[0.0], ConvSpec { stride: 1, pad: 0 });
        assert_eq!(y.shape, vec![1, 1, 2, 2]);
        assert_eq!(y.data, vec![12., 16., 24., 28.]);
    }

    #[test]
    fn conv2d_same_padding() {
        let x = Tensor::new(vec![1, 1, 3, 3], vec![0., 0., 0., 0., 1., 0., 0., 0., 0.]);
        let w = Tensor::new(vec![1, 1, 3, 3], (1..=9).map(|i| i as f32).collect());
        let y = conv2d(&x, &w, &[0.0], ConvSpec { stride: 1, pad: 1 });
        assert_eq!(y.shape, vec![1, 1, 3, 3]);
        // Correlation (no flip) with an impulse at (1,1): out[oy][ox] =
        // w[2-oy][2-ox], i.e. the kernel reversed.
        assert_eq!(y.data, vec![9., 8., 7., 6., 5., 4., 3., 2., 1.]);
    }

    #[test]
    fn conv2d_stride() {
        let x = Tensor::new(vec![1, 1, 4, 4], (0..16).map(|i| i as f32).collect());
        let w = Tensor::new(vec![1, 1, 2, 2], vec![1., 0., 0., 0.]);
        let y = conv2d(&x, &w, &[0.0], ConvSpec { stride: 2, pad: 0 });
        assert_eq!(y.shape, vec![1, 1, 2, 2]);
        assert_eq!(y.data, vec![0., 2., 8., 10.]);
    }

    #[test]
    fn conv2d_bias() {
        let x = Tensor::new(vec![1, 1, 2, 2], vec![0.0; 4]);
        let w = Tensor::new(vec![2, 1, 1, 1], vec![1.0, 1.0]);
        let y = conv2d(&x, &w, &[3.0, -1.0], ConvSpec { stride: 1, pad: 0 });
        assert_eq!(y.data, vec![3., 3., 3., 3., -1., -1., -1., -1.]);
    }

    #[test]
    fn col2im_is_im2col_adjoint() {
        // ⟨im2col(x), y⟩ = ⟨x, col2im(y)⟩ over several geometries,
        // including stride 2, padding, and windows not dividing the input.
        let mut rng = crate::util::rng::Rng::new(9);
        for (b, c, h, w, kh, kw, stride, pad) in [
            (2usize, 3usize, 5usize, 5usize, 3usize, 3usize, 1usize, 0usize),
            (1, 2, 7, 6, 3, 2, 2, 1),
            (3, 1, 5, 5, 2, 2, 2, 0), // window does not divide the input
        ] {
            let spec = ConvSpec { stride, pad };
            let x = Tensor::new(vec![b, c, h, w], rng.normal_vec(b * c * h * w, 1.0));
            let cols = im2col(&x, kh, kw, spec);
            let y = Tensor::new(cols.shape.clone(), rng.normal_vec(cols.numel(), 1.0));
            let folded = col2im(&y, b, c, h, w, kh, kw, spec);
            let lhs: f64 =
                cols.data.iter().zip(&y.data).map(|(a, b)| (a * b) as f64).sum();
            let rhs: f64 =
                x.data.iter().zip(&folded.data).map(|(a, b)| (a * b) as f64).sum();
            assert!(
                (lhs - rhs).abs() < 1e-3 * lhs.abs().max(1.0),
                "adjoint identity failed for ({b},{c},{h},{w}) k={kh}x{kw} s={stride} p={pad}: {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn col2im_counts_window_coverage() {
        // All-ones cols fold to the per-pixel window-coverage count.
        let spec = ConvSpec { stride: 1, pad: 0 };
        let cols = Tensor::new(vec![4, 4], vec![1.0; 16]); // 1×1×3×3 input, 2×2 kernel
        let folded = col2im(&cols, 1, 1, 3, 3, 2, 2, spec);
        assert_eq!(folded.data, vec![1., 2., 1., 2., 4., 2., 1., 2., 1.]);
    }

    #[test]
    fn max_pool_backward_routes_to_argmax() {
        let x = Tensor::new(vec![1, 1, 4, 4], (0..16).map(|i| i as f32).collect());
        let dy = Tensor::new(vec![1, 1, 2, 2], vec![1., 2., 3., 4.]);
        let dx = max_pool_backward(&x, &dy, 2, 2);
        let mut want = vec![0.0f32; 16];
        // Forward maxima sit at 5, 7, 13, 15.
        want[5] = 1.0;
        want[7] = 2.0;
        want[13] = 3.0;
        want[15] = 4.0;
        assert_eq!(dx.data, want);
    }

    #[test]
    fn max_pool_backward_tie_break_matches_forward_scan() {
        // A flat window: the first element in (ky, kx) scan order wins,
        // exactly the element max_pool's `>` comparison returns.
        let x = Tensor::new(vec![1, 1, 2, 2], vec![3.0; 4]);
        let dy = Tensor::new(vec![1, 1, 1, 1], vec![5.0]);
        let dx = max_pool_backward(&x, &dy, 2, 2);
        assert_eq!(dx.data, vec![5.0, 0.0, 0.0, 0.0]);
    }

    #[test]
    fn max_pool_backward_window_not_dividing_input() {
        // 5×5 input, 2×2/2 pool → 2×2 output; the trailing row/col get no
        // gradient (forward never reads them).
        let x = Tensor::new(vec![1, 1, 5, 5], (0..25).map(|i| i as f32).collect());
        let dy = Tensor::new(vec![1, 1, 2, 2], vec![1.0; 4]);
        let dx = max_pool_backward(&x, &dy, 2, 2);
        let grads: f32 = dx.data.iter().sum();
        assert_eq!(grads, 4.0);
        assert!(dx.data[20..].iter().all(|&v| v == 0.0), "trailing row leaked gradient");
        assert_eq!(dx.data[6], 1.0); // max of window (0,0) is index (1,1)
    }

    #[test]
    fn max_pool_2x2() {
        let x = Tensor::new(vec![1, 1, 4, 4], (0..16).map(|i| i as f32).collect());
        let y = max_pool(&x, 2, 2);
        assert_eq!(y.data, vec![5., 7., 13., 15.]);
    }

    #[test]
    fn global_pool() {
        let x = Tensor::new(vec![1, 2, 2, 2], vec![1., 1., 1., 1., 2., 2., 2., 2.]);
        let y = global_avg_pool(&x);
        assert_eq!(y.data, vec![1., 2.]);
    }

    #[test]
    fn relu() {
        let mut x = Tensor::new(vec![1, 4], vec![-1., 0., 2., -0.5]);
        relu_inplace(&mut x);
        assert_eq!(x.data, vec![0., 0., 2., 0.]);
    }

    #[test]
    fn batch_norm_normalizes() {
        let mut rng = crate::util::rng::Rng::new(2);
        let x = Tensor::new(vec![4, 3, 5, 5], rng.normal_vec(300, 3.0));
        let y = batch_norm(&x, &[1.0; 3], &[0.0; 3], 1e-5);
        // Per-channel mean ≈ 0, var ≈ 1.
        for c in 0..3 {
            let mut vals = Vec::new();
            for b in 0..4 {
                vals.extend_from_slice(&y.data[(b * 3 + c) * 25..(b * 3 + c + 1) * 25]);
            }
            let mean: f32 = vals.iter().sum::<f32>() / vals.len() as f32;
            let var: f32 =
                vals.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / vals.len() as f32;
            assert!(mean.abs() < 1e-4);
            assert!((var - 1.0).abs() < 1e-2);
        }
    }

    #[test]
    fn softmax_rows_sum_to_one() {
        let x = t2(2, 3, &[1., 2., 3., -1., 0., 1.]);
        let y = softmax(&x);
        for r in 0..2 {
            let s: f32 = y.data[r * 3..(r + 1) * 3].iter().sum();
            assert!((s - 1.0).abs() < 1e-5);
        }
        assert!(y.data[2] > y.data[1] && y.data[1] > y.data[0]);
    }

    #[test]
    fn argmax() {
        let x = t2(2, 3, &[0.1, 0.9, 0.0, 5.0, -1.0, 2.0]);
        assert_eq!(argmax_rows(&x), vec![1, 0]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn out_dim_formula() {
        assert_eq!(out_dim(28, 5, 1, 0), 24);
        assert_eq!(out_dim(24, 2, 2, 0), 12);
        assert_eq!(out_dim(32, 3, 1, 1), 32);
        assert_eq!(out_dim(32, 3, 2, 1), 16);
    }
}
