//! ELLPACK (ELL) format — paper Figure 1(ii).
//!
//! Pads every row to the maximum per-row nonzero count. The paper rejects
//! it for prox-trained weights ("matrix rows have similar numbers of
//! nonzero entries" is violated by unstructured sparsity) — the
//! `padding_overhead` helper quantifies that argument and is used by the
//! format-comparison bench.

use super::csr::CsrMatrix;
use crate::tensor::Tensor;
use crate::util::pool;

#[derive(Debug, Clone, PartialEq)]
pub struct EllMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Max nonzeros per row (row stride of `data`/`indices`).
    pub width: usize,
    /// (rows × width) column indices, `u32::MAX` marks padding.
    pub indices: Vec<u32>,
    /// (rows × width) values, 0.0 in padding slots.
    pub data: Vec<f32>,
}

pub const ELL_PAD: u32 = u32::MAX;

impl EllMatrix {
    pub fn from_dense(dense: &[f32], rows: usize, cols: usize) -> EllMatrix {
        let csr = CsrMatrix::from_dense(dense, rows, cols);
        Self::from_csr(&csr)
    }

    pub fn from_csr(csr: &CsrMatrix) -> EllMatrix {
        let width = (0..csr.rows)
            .map(|r| csr.ptr[r + 1] - csr.ptr[r])
            .max()
            .unwrap_or(0);
        let mut indices = vec![ELL_PAD; csr.rows * width];
        let mut data = vec![0.0f32; csr.rows * width];
        for r in 0..csr.rows {
            for (slot, k) in (csr.ptr[r]..csr.ptr[r + 1]).enumerate() {
                indices[r * width + slot] = csr.indices[k];
                data[r * width + slot] = csr.data[k];
            }
        }
        EllMatrix { rows: csr.rows, cols: csr.cols, width, indices, data }
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for s in 0..self.width {
                let c = self.indices[r * self.width + s];
                if c != ELL_PAD {
                    out[r * self.cols + c as usize] = self.data[r * self.width + s];
                }
            }
        }
        out
    }

    pub fn nnz(&self) -> usize {
        self.indices.iter().filter(|&&c| c != ELL_PAD).count()
    }

    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 4 + self.indices.len() * 4
    }

    /// Fraction of stored slots that are padding — the waste the paper's
    /// Section 3.1 objects to for unstructured sparsity.
    pub fn padding_overhead(&self) -> f64 {
        let slots = self.rows * self.width;
        if slots == 0 {
            return 0.0;
        }
        1.0 - self.nnz() as f64 / slots as f64
    }

    /// Convert back to CSR. Slots within a row keep CSR's ascending
    /// column order (that is how `from_csr` packed them), so the result
    /// is valid without sorting.
    pub fn to_csr(&self) -> CsrMatrix {
        let mut ptr = Vec::with_capacity(self.rows + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        ptr.push(0);
        for r in 0..self.rows {
            for s in 0..self.width {
                let c = self.indices[r * self.width + s];
                if c == ELL_PAD {
                    break; // padding is always the row's tail
                }
                indices.push(c);
                data.push(self.data[r * self.width + s]);
            }
            ptr.push(indices.len());
        }
        CsrMatrix { rows: self.rows, cols: self.cols, ptr, indices, data }
    }

    /// `dmat (B, K) @ self' -> (B, N)` with `self` shaped (N, K) — the
    /// Figure-2 contraction in ELL form: every output row walks a
    /// fixed-width slot strip, the regular access pattern ELL trades its
    /// padding for.
    pub fn dxct(&self, dmat: &Tensor) -> Tensor {
        self.dxct_threads(dmat, pool::max_threads())
    }

    /// As [`EllMatrix::dxct`] with an explicit worker count. Both
    /// partitions walk each row's slot strip in ascending-slot order, so
    /// results are bit-identical for any `threads`.
    pub fn dxct_threads(&self, dmat: &Tensor, threads: usize) -> Tensor {
        let (b, k) = (dmat.shape[0], dmat.shape[1]);
        assert_eq!(k, self.cols, "ell dxct: K mismatch ({k} vs {})", self.cols);
        let n = self.rows;
        let mut out = vec![0.0f32; b * n];
        let ptr = pool::SharedMut::new(&mut out);
        if pool::batch_saturates(b, threads) {
            pool::parallel_chunks(b, threads, |b0, b1| {
                let out = unsafe { ptr.slice() };
                for bi in b0..b1 {
                    let xrow = &dmat.data[bi * k..(bi + 1) * k];
                    let orow = &mut out[bi * n..(bi + 1) * n];
                    for r in 0..n {
                        let mut acc = 0.0f32;
                        for s in 0..self.width {
                            let c = self.indices[r * self.width + s];
                            if c == ELL_PAD {
                                break;
                            }
                            acc += self.data[r * self.width + s] * xrow[c as usize];
                        }
                        orow[r] = acc;
                    }
                }
            });
        } else {
            // Row partition: single-sample serving still goes wide.
            pool::parallel_chunks(n, threads, |r0, r1| {
                let out = unsafe { ptr.slice() };
                for bi in 0..b {
                    let xrow = &dmat.data[bi * k..(bi + 1) * k];
                    for r in r0..r1 {
                        let mut acc = 0.0f32;
                        for s in 0..self.width {
                            let c = self.indices[r * self.width + s];
                            if c == ELL_PAD {
                                break;
                            }
                            acc += self.data[r * self.width + s] * xrow[c as usize];
                        }
                        out[bi * n + r] = acc;
                    }
                }
            });
        }
        Tensor::new(vec![b, n], out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_matrix() -> (Vec<f32>, usize, usize) {
        #[rustfmt::skip]
        let dense = vec![
            1., 7., 0., 0.,
            0., 2., 8., 0.,
            5., 0., 3., 9.,
            0., 6., 0., 4.,
        ];
        (dense, 4, 4)
    }

    #[test]
    fn figure1_ell_layout() {
        let (dense, r, c) = paper_matrix();
        let m = EllMatrix::from_dense(&dense, r, c);
        assert_eq!(m.width, 3);
        // Paper Figure 1(ii), * = padding.
        assert_eq!(m.data[0..3], [1., 7., 0.]);
        assert_eq!(m.indices[0..2], [0, 1]);
        assert_eq!(m.indices[2], ELL_PAD);
        assert_eq!(m.data[6..9], [5., 3., 9.]);
        assert_eq!(m.indices[6..9], [0, 2, 3]);
    }

    #[test]
    fn roundtrip() {
        let (dense, r, c) = paper_matrix();
        assert_eq!(EllMatrix::from_dense(&dense, r, c).to_dense(), dense);
    }

    #[test]
    fn skewed_rows_waste_storage() {
        // One dense row forces every row to its width: the paper's
        // argument against ELL for unstructured prox sparsity.
        let mut dense = vec![0.0f32; 10 * 100];
        for c in 0..100 {
            dense[c] = 1.0; // row 0 fully dense
        }
        dense[5 * 100 + 3] = 2.0; // row 5: single nonzero
        let m = EllMatrix::from_dense(&dense, 10, 100);
        assert_eq!(m.width, 100);
        assert!(m.padding_overhead() > 0.85);
        let csr = CsrMatrix::from_dense(&dense, 10, 100);
        assert!(m.storage_bytes() > 5 * csr.storage_bytes());
    }

    #[test]
    fn empty() {
        let m = EllMatrix::from_dense(&vec![0.0; 6], 2, 3);
        assert_eq!(m.width, 0);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.to_dense(), vec![0.0; 6]);
    }

    #[test]
    fn random_roundtrip() {
        let mut rng = crate::util::rng::Rng::new(6);
        for _ in 0..10 {
            let rows = 1 + rng.below(15);
            let cols = 1 + rng.below(15);
            let mut dense = vec![0.0f32; rows * cols];
            for v in &mut dense {
                if rng.uniform() < 0.3 {
                    *v = rng.normal() as f32;
                }
            }
            assert_eq!(EllMatrix::from_dense(&dense, rows, cols).to_dense(), dense);
        }
    }

    #[test]
    fn csr_roundtrip() {
        let mut rng = crate::util::rng::Rng::new(7);
        for _ in 0..10 {
            let rows = 1 + rng.below(15);
            let cols = 1 + rng.below(15);
            let mut dense = vec![0.0f32; rows * cols];
            for v in &mut dense {
                if rng.uniform() < 0.3 {
                    *v = rng.normal() as f32;
                }
            }
            let csr = CsrMatrix::from_dense(&dense, rows, cols);
            let back = EllMatrix::from_csr(&csr).to_csr();
            back.validate().unwrap();
            assert_eq!(back, csr);
        }
    }

    #[test]
    fn dxct_matches_dense() {
        use crate::tensor::{matmul_nt, Tensor};
        let mut rng = crate::util::rng::Rng::new(8);
        for &(b, n, k) in &[(1usize, 5usize, 9usize), (6, 30, 40), (3, 17, 11)] {
            let mut dense = vec![0.0f32; n * k];
            for v in &mut dense {
                if rng.uniform() < 0.3 {
                    *v = rng.normal() as f32;
                }
            }
            let ell = EllMatrix::from_dense(&dense, n, k);
            let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
            let got = ell.dxct(&d);
            let want = matmul_nt(&d, &Tensor::new(vec![n, k], dense));
            for (g, w) in got.data.iter().zip(&want.data) {
                assert!((g - w).abs() < 1e-4, "{g} vs {w}");
            }
        }
    }
}
