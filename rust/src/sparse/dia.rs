//! Diagonal (DIA) format — paper Figure 1(i).
//!
//! Stores whole diagonals; "suitable for the case when nonzero values are
//! at a small number of diagonals" (banded systems), which prox-trained
//! weight matrices are not — the comparison test quantifies the blow-up.
//! The format-dispatch layer (`sparse::dispatch`) still selects DIA when a
//! matrix *is* banded, so it carries its own `dxct` kernel and CSR
//! conversions.

use super::csr::CsrMatrix;
use crate::tensor::Tensor;
use crate::util::pool;

#[derive(Debug, Clone, PartialEq)]
pub struct DiaMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Diagonal offsets (col - row), ascending.
    pub offsets: Vec<i64>,
    /// (num_diags × rows) values; slot (d, r) = element (r, r + offset_d),
    /// 0.0 where the diagonal leaves the matrix.
    pub data: Vec<f32>,
}

impl DiaMatrix {
    pub fn from_dense(dense: &[f32], rows: usize, cols: usize) -> DiaMatrix {
        assert_eq!(dense.len(), rows * cols);
        let mut offsets: Vec<i64> = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if dense[r * cols + c] != 0.0 {
                    let off = c as i64 - r as i64;
                    if let Err(pos) = offsets.binary_search(&off) {
                        offsets.insert(pos, off);
                    }
                }
            }
        }
        let mut data = vec![0.0f32; offsets.len() * rows];
        for (d, &off) in offsets.iter().enumerate() {
            for r in 0..rows {
                let c = r as i64 + off;
                if c >= 0 && (c as usize) < cols {
                    data[d * rows + r] = dense[r * cols + c as usize];
                }
            }
        }
        DiaMatrix { rows, cols, offsets, data }
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for (d, &off) in self.offsets.iter().enumerate() {
            for r in 0..self.rows {
                let c = r as i64 + off;
                if c >= 0 && (c as usize) < self.cols {
                    out[r * self.cols + c as usize] = self.data[d * self.rows + r];
                }
            }
        }
        out
    }

    pub fn num_diagonals(&self) -> usize {
        self.offsets.len()
    }

    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 4 + self.offsets.len() * 8
    }

    /// Stored nonzeros (padding slots hold exact zeros and do not count).
    pub fn nnz(&self) -> usize {
        self.data.iter().filter(|&&v| v != 0.0).count()
    }

    /// Build from CSR without materializing the dense matrix.
    pub fn from_csr(csr: &CsrMatrix) -> DiaMatrix {
        let mut offsets: Vec<i64> = Vec::new();
        for r in 0..csr.rows {
            for (c, _) in csr.row(r) {
                let off = c as i64 - r as i64;
                if let Err(pos) = offsets.binary_search(&off) {
                    offsets.insert(pos, off);
                }
            }
        }
        let mut data = vec![0.0f32; offsets.len() * csr.rows];
        for r in 0..csr.rows {
            for (c, v) in csr.row(r) {
                let off = c as i64 - r as i64;
                let d = offsets.binary_search(&off).expect("offset collected above");
                data[d * csr.rows + r] = v;
            }
        }
        DiaMatrix { rows: csr.rows, cols: csr.cols, offsets, data }
    }

    /// Convert to CSR, dropping the padding zeros. Offsets are ascending,
    /// so per-row columns come out strictly increasing (valid CSR).
    pub fn to_csr(&self) -> CsrMatrix {
        let mut ptr = Vec::with_capacity(self.rows + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        ptr.push(0);
        for r in 0..self.rows {
            for (d, &off) in self.offsets.iter().enumerate() {
                let c = r as i64 + off;
                if c < 0 || c as usize >= self.cols {
                    continue;
                }
                let v = self.data[d * self.rows + r];
                if v != 0.0 {
                    indices.push(c as u32);
                    data.push(v);
                }
            }
            ptr.push(indices.len());
        }
        CsrMatrix { rows: self.rows, cols: self.cols, ptr, indices, data }
    }

    /// `dmat (B, K) @ self' -> (B, N)` with `self` shaped (N, K) — the
    /// Figure-2 contraction in DIA form. Each diagonal contributes a
    /// shifted elementwise product, which keeps both operands on
    /// unit-stride walks (the reason DIA wins on banded matrices).
    pub fn dxct(&self, dmat: &Tensor) -> Tensor {
        self.dxct_threads(dmat, pool::max_threads())
    }

    /// As [`DiaMatrix::dxct`] with an explicit worker count (the serving
    /// path and the thread-sweep bench pass it directly). Every output
    /// element accumulates its diagonals in ascending-offset order
    /// whichever dimension is partitioned, so results are bit-identical
    /// for any `threads`.
    pub fn dxct_threads(&self, dmat: &Tensor, threads: usize) -> Tensor {
        let (b, k) = (dmat.shape[0], dmat.shape[1]);
        assert_eq!(k, self.cols, "dia dxct: K mismatch ({k} vs {})", self.cols);
        let n = self.rows;
        let mut out = vec![0.0f32; b * n];
        let ptr = pool::SharedMut::new(&mut out);
        if pool::batch_saturates(b, threads) {
            pool::parallel_chunks(b, threads, |b0, b1| {
                let out = unsafe { ptr.slice() };
                for bi in b0..b1 {
                    let xrow = &dmat.data[bi * k..(bi + 1) * k];
                    let orow = &mut out[bi * n..(bi + 1) * n];
                    for (d, &off) in self.offsets.iter().enumerate() {
                        let diag = &self.data[d * n..(d + 1) * n];
                        // Rows r where column c = r + off stays inside [0, k).
                        let r_lo = (-off).max(0) as usize;
                        let r_hi = n.min((k as i64 - off).max(0) as usize);
                        for r in r_lo..r_hi {
                            orow[r] += diag[r] * xrow[(r as i64 + off) as usize];
                        }
                    }
                }
            });
        } else {
            // Diagonal-row partition: single-sample serving still goes
            // wide. Each thread owns output rows [r0, r1) for every batch
            // row, walking diagonals *outer* — each diagonal's valid span
            // clamped to the owned range — so the inner loops keep the
            // unit-stride, branch-free walks DIA exists for. Per output
            // element the diagonals still accumulate in ascending order,
            // exactly as in the batch-partitioned arm: bit-identical.
            pool::parallel_chunks(n, threads, |r0, r1| {
                let out = unsafe { ptr.slice() };
                for bi in 0..b {
                    let xrow = &dmat.data[bi * k..(bi + 1) * k];
                    let base = bi * n;
                    for (d, &off) in self.offsets.iter().enumerate() {
                        let diag = &self.data[d * n..(d + 1) * n];
                        let lo = r0.max((-off).max(0) as usize);
                        let hi = r1.min(n.min((k as i64 - off).max(0) as usize));
                        for r in lo..hi {
                            out[base + r] += diag[r] * xrow[(r as i64 + off) as usize];
                        }
                    }
                }
            });
        }
        Tensor::new(vec![b, n], out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csr::CsrMatrix;

    fn paper_matrix() -> (Vec<f32>, usize, usize) {
        #[rustfmt::skip]
        let dense = vec![
            1., 7., 0., 0.,
            0., 2., 8., 0.,
            5., 0., 3., 9.,
            0., 6., 0., 4.,
        ];
        (dense, 4, 4)
    }

    #[test]
    fn figure1_dia_layout() {
        let (dense, r, c) = paper_matrix();
        let m = DiaMatrix::from_dense(&dense, r, c);
        // Paper Figure 1(i): offsets = [-2, 0, 1].
        assert_eq!(m.offsets, vec![-2, 0, 1]);
        // Diagonal 0 (main): [1, 2, 3, 4].
        assert_eq!(&m.data[4..8], &[1., 2., 3., 4.]);
        // Diagonal -2: [*, *, 5, 6] (padding stored as 0).
        assert_eq!(&m.data[0..4], &[0., 0., 5., 6.]);
        // Diagonal +1: [7, 8, 9, *].
        assert_eq!(&m.data[8..12], &[7., 8., 9., 0.]);
    }

    #[test]
    fn roundtrip() {
        let (dense, r, c) = paper_matrix();
        assert_eq!(DiaMatrix::from_dense(&dense, r, c).to_dense(), dense);
    }

    #[test]
    fn banded_is_compact() {
        // Tridiagonal 50×50: 3 diagonals, storage ≈ 3 rows worth.
        let n = 50;
        let mut dense = vec![0.0f32; n * n];
        for i in 0..n {
            dense[i * n + i] = 2.0;
            if i + 1 < n {
                dense[i * n + i + 1] = -1.0;
                dense[(i + 1) * n + i] = -1.0;
            }
        }
        let m = DiaMatrix::from_dense(&dense, n, n);
        assert_eq!(m.num_diagonals(), 3);
        let csr = CsrMatrix::from_dense(&dense, n, n);
        assert!(m.storage_bytes() < csr.storage_bytes());
    }

    #[test]
    fn unstructured_blows_up() {
        // Random scatter activates many diagonals: the paper's reason to
        // reject DIA for sparse-coded weights.
        let mut rng = crate::util::rng::Rng::new(7);
        let n = 40;
        let mut dense = vec![0.0f32; n * n];
        for _ in 0..60 {
            let idx = rng.below(n * n);
            dense[idx] = 1.0;
        }
        let m = DiaMatrix::from_dense(&dense, n, n);
        assert!(m.num_diagonals() > 30);
        let csr = CsrMatrix::from_dense(&dense, n, n);
        assert!(m.storage_bytes() > 3 * csr.storage_bytes());
    }

    #[test]
    fn random_roundtrip() {
        let mut rng = crate::util::rng::Rng::new(8);
        for _ in 0..10 {
            let rows = 1 + rng.below(12);
            let cols = 1 + rng.below(12);
            let mut dense = vec![0.0f32; rows * cols];
            for v in &mut dense {
                if rng.uniform() < 0.3 {
                    *v = rng.normal() as f32;
                }
            }
            assert_eq!(DiaMatrix::from_dense(&dense, rows, cols).to_dense(), dense);
        }
    }

    #[test]
    fn csr_conversions_roundtrip() {
        let mut rng = crate::util::rng::Rng::new(9);
        for _ in 0..10 {
            let rows = 1 + rng.below(15);
            let cols = 1 + rng.below(15);
            let mut dense = vec![0.0f32; rows * cols];
            for v in &mut dense {
                if rng.uniform() < 0.25 {
                    *v = rng.normal() as f32;
                }
            }
            let csr = CsrMatrix::from_dense(&dense, rows, cols);
            let dia = DiaMatrix::from_csr(&csr);
            assert_eq!(dia, DiaMatrix::from_dense(&dense, rows, cols));
            let back = dia.to_csr();
            back.validate().unwrap();
            assert_eq!(back, csr);
            assert_eq!(dia.nnz(), csr.nnz());
        }
    }

    #[test]
    fn dxct_matches_dense_including_rectangular() {
        use crate::tensor::{matmul_nt, Tensor};
        let mut rng = crate::util::rng::Rng::new(10);
        for &(b, n, k) in &[(1usize, 6usize, 6usize), (5, 12, 7), (4, 7, 12), (3, 20, 20)] {
            let mut dense = vec![0.0f32; n * k];
            for v in &mut dense {
                if rng.uniform() < 0.3 {
                    *v = rng.normal() as f32;
                }
            }
            let dia = DiaMatrix::from_dense(&dense, n, k);
            let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
            let got = dia.dxct(&d);
            let want = matmul_nt(&d, &Tensor::new(vec![n, k], dense));
            for (g, w) in got.data.iter().zip(&want.data) {
                assert!((g - w).abs() < 1e-4, "{g} vs {w}");
            }
        }
    }
}
