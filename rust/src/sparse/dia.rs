//! Diagonal (DIA) format — paper Figure 1(i).
//!
//! Stores whole diagonals; "suitable for the case when nonzero values are
//! at a small number of diagonals" (banded systems), which prox-trained
//! weight matrices are not — the comparison test quantifies the blow-up.

#[derive(Debug, Clone, PartialEq)]
pub struct DiaMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Diagonal offsets (col - row), ascending.
    pub offsets: Vec<i64>,
    /// (num_diags × rows) values; slot (d, r) = element (r, r + offset_d),
    /// 0.0 where the diagonal leaves the matrix.
    pub data: Vec<f32>,
}

impl DiaMatrix {
    pub fn from_dense(dense: &[f32], rows: usize, cols: usize) -> DiaMatrix {
        assert_eq!(dense.len(), rows * cols);
        let mut offsets: Vec<i64> = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                if dense[r * cols + c] != 0.0 {
                    let off = c as i64 - r as i64;
                    if let Err(pos) = offsets.binary_search(&off) {
                        offsets.insert(pos, off);
                    }
                }
            }
        }
        let mut data = vec![0.0f32; offsets.len() * rows];
        for (d, &off) in offsets.iter().enumerate() {
            for r in 0..rows {
                let c = r as i64 + off;
                if c >= 0 && (c as usize) < cols {
                    data[d * rows + r] = dense[r * cols + c as usize];
                }
            }
        }
        DiaMatrix { rows, cols, offsets, data }
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for (d, &off) in self.offsets.iter().enumerate() {
            for r in 0..self.rows {
                let c = r as i64 + off;
                if c >= 0 && (c as usize) < self.cols {
                    out[r * self.cols + c as usize] = self.data[d * self.rows + r];
                }
            }
        }
        out
    }

    pub fn num_diagonals(&self) -> usize {
        self.offsets.len()
    }

    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 4 + self.offsets.len() * 8
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::csr::CsrMatrix;

    fn paper_matrix() -> (Vec<f32>, usize, usize) {
        #[rustfmt::skip]
        let dense = vec![
            1., 7., 0., 0.,
            0., 2., 8., 0.,
            5., 0., 3., 9.,
            0., 6., 0., 4.,
        ];
        (dense, 4, 4)
    }

    #[test]
    fn figure1_dia_layout() {
        let (dense, r, c) = paper_matrix();
        let m = DiaMatrix::from_dense(&dense, r, c);
        // Paper Figure 1(i): offsets = [-2, 0, 1].
        assert_eq!(m.offsets, vec![-2, 0, 1]);
        // Diagonal 0 (main): [1, 2, 3, 4].
        assert_eq!(&m.data[4..8], &[1., 2., 3., 4.]);
        // Diagonal -2: [*, *, 5, 6] (padding stored as 0).
        assert_eq!(&m.data[0..4], &[0., 0., 5., 6.]);
        // Diagonal +1: [7, 8, 9, *].
        assert_eq!(&m.data[8..12], &[7., 8., 9., 0.]);
    }

    #[test]
    fn roundtrip() {
        let (dense, r, c) = paper_matrix();
        assert_eq!(DiaMatrix::from_dense(&dense, r, c).to_dense(), dense);
    }

    #[test]
    fn banded_is_compact() {
        // Tridiagonal 50×50: 3 diagonals, storage ≈ 3 rows worth.
        let n = 50;
        let mut dense = vec![0.0f32; n * n];
        for i in 0..n {
            dense[i * n + i] = 2.0;
            if i + 1 < n {
                dense[i * n + i + 1] = -1.0;
                dense[(i + 1) * n + i] = -1.0;
            }
        }
        let m = DiaMatrix::from_dense(&dense, n, n);
        assert_eq!(m.num_diagonals(), 3);
        let csr = CsrMatrix::from_dense(&dense, n, n);
        assert!(m.storage_bytes() < csr.storage_bytes());
    }

    #[test]
    fn unstructured_blows_up() {
        // Random scatter activates many diagonals: the paper's reason to
        // reject DIA for sparse-coded weights.
        let mut rng = crate::util::rng::Rng::new(7);
        let n = 40;
        let mut dense = vec![0.0f32; n * n];
        for _ in 0..60 {
            let idx = rng.below(n * n);
            dense[idx] = 1.0;
        }
        let m = DiaMatrix::from_dense(&dense, n, n);
        assert!(m.num_diagonals() > 30);
        let csr = CsrMatrix::from_dense(&dense, n, n);
        assert!(m.storage_bytes() > 3 * csr.storage_bytes());
    }

    #[test]
    fn random_roundtrip() {
        let mut rng = crate::util::rng::Rng::new(8);
        for _ in 0..10 {
            let rows = 1 + rng.below(12);
            let cols = 1 + rng.below(12);
            let mut dense = vec![0.0f32; rows * cols];
            for v in &mut dense {
                if rng.uniform() < 0.3 {
                    *v = rng.normal() as f32;
                }
            }
            assert_eq!(DiaMatrix::from_dense(&dense, rows, cols).to_dense(), dense);
        }
    }
}
