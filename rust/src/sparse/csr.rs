//! Compressed Sparse Row format — the paper's production format.
//!
//! Matches the paper's Figure 1(iii): `ptr` holds the index where each row
//! begins (`rows + 1` entries), `indices` the column of each nonzero, and
//! `data` the values, row-major. "This format can store variable numbers
//! of nonzeros in rows efficiently" — and it is what the ViennaCL
//! `compressed_matrix` class the paper adapted stores.

/// CSR matrix over f32.
#[derive(Debug, Clone, PartialEq)]
pub struct CsrMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Row pointers, len == rows + 1 (`Cmat_row_ptrs` in the paper kernel).
    pub ptr: Vec<usize>,
    /// Column index per nonzero (`Cmat_col_indices`).
    pub indices: Vec<u32>,
    /// Nonzero values (`Cmat_elements`).
    pub data: Vec<f32>,
}

impl CsrMatrix {
    /// Build from a dense row-major matrix, dropping exact zeros.
    pub fn from_dense(dense: &[f32], rows: usize, cols: usize) -> CsrMatrix {
        assert_eq!(dense.len(), rows * cols);
        let mut ptr = Vec::with_capacity(rows + 1);
        let mut indices = Vec::new();
        let mut data = Vec::new();
        ptr.push(0);
        for r in 0..rows {
            for c in 0..cols {
                let v = dense[r * cols + c];
                if v != 0.0 {
                    indices.push(c as u32);
                    data.push(v);
                }
            }
            ptr.push(indices.len());
        }
        CsrMatrix { rows, cols, ptr, indices, data }
    }

    /// Expand back to a dense row-major matrix.
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for k in self.ptr[r]..self.ptr[r + 1] {
                out[r * self.cols + self.indices[k] as usize] = self.data[k];
            }
        }
        out
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    /// Fraction of entries that are zero (the paper's "compression rate").
    pub fn compression_rate(&self) -> f64 {
        let total = self.rows * self.cols;
        if total == 0 {
            return 0.0;
        }
        1.0 - self.nnz() as f64 / total as f64
    }

    /// Storage footprint in bytes: values (f32) + column indices (u32) +
    /// row pointers (u32 on device) — the quantity behind the paper's
    /// Table-3 "Model Size" column.
    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 4 + self.indices.len() * 4 + self.ptr.len() * 4
    }

    /// Nonzeros of one row as (col, value) pairs.
    pub fn row(&self, r: usize) -> impl Iterator<Item = (usize, f32)> + '_ {
        let lo = self.ptr[r];
        let hi = self.ptr[r + 1];
        self.indices[lo..hi]
            .iter()
            .zip(&self.data[lo..hi])
            .map(|(&c, &v)| (c as usize, v))
    }

    /// Transpose (CSR -> CSR of the transposed matrix). The operation
    /// ViennaCL lacked ("the transpose operation for compressed sparse
    /// matrices (C') is not available") — counting sort over columns.
    pub fn transpose(&self) -> CsrMatrix {
        let mut counts = vec![0usize; self.cols + 1];
        for &c in &self.indices {
            counts[c as usize + 1] += 1;
        }
        for i in 1..counts.len() {
            counts[i] += counts[i - 1];
        }
        let ptr = counts.clone();
        let mut cursor = counts;
        let mut indices = vec![0u32; self.nnz()];
        let mut data = vec![0.0f32; self.nnz()];
        for r in 0..self.rows {
            for k in self.ptr[r]..self.ptr[r + 1] {
                let c = self.indices[k] as usize;
                let dst = cursor[c];
                cursor[c] += 1;
                indices[dst] = r as u32;
                data[dst] = self.data[k];
            }
        }
        CsrMatrix { rows: self.cols, cols: self.rows, ptr, indices, data }
    }

    /// Validate structural invariants (used by checkpoint loading).
    pub fn validate(&self) -> anyhow::Result<()> {
        if self.ptr.len() != self.rows + 1 {
            anyhow::bail!("ptr len {} != rows+1 {}", self.ptr.len(), self.rows + 1);
        }
        if self.ptr[0] != 0 || *self.ptr.last().unwrap() != self.data.len() {
            anyhow::bail!("ptr endpoints invalid");
        }
        if self.indices.len() != self.data.len() {
            anyhow::bail!("indices/data length mismatch");
        }
        for w in self.ptr.windows(2) {
            if w[1] < w[0] {
                anyhow::bail!("ptr not monotone");
            }
        }
        for r in 0..self.rows {
            let row = &self.indices[self.ptr[r]..self.ptr[r + 1]];
            for pair in row.windows(2) {
                if pair[1] <= pair[0] {
                    anyhow::bail!("row {r} columns not strictly increasing");
                }
            }
            if let Some(&last) = row.last() {
                if last as usize >= self.cols {
                    anyhow::bail!("row {r} column {} out of bounds", last);
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The paper's Figure-1 example matrix.
    pub fn paper_matrix() -> (Vec<f32>, usize, usize) {
        #[rustfmt::skip]
        let dense = vec![
            1., 7., 0., 0.,
            0., 2., 8., 0.,
            5., 0., 3., 9.,
            0., 6., 0., 4.,
        ];
        (dense, 4, 4)
    }

    #[test]
    fn figure1_csr_layout() {
        let (dense, r, c) = paper_matrix();
        let m = CsrMatrix::from_dense(&dense, r, c);
        // Paper Figure 1(iii): ptr = [0 2 4 7 9]
        assert_eq!(m.ptr, vec![0, 2, 4, 7, 9]);
        assert_eq!(m.indices, vec![0, 1, 1, 2, 0, 2, 3, 1, 3]);
        assert_eq!(m.data, vec![1., 7., 2., 8., 5., 3., 9., 6., 4.]);
    }

    #[test]
    fn roundtrip() {
        let (dense, r, c) = paper_matrix();
        let m = CsrMatrix::from_dense(&dense, r, c);
        assert_eq!(m.to_dense(), dense);
        m.validate().unwrap();
    }

    #[test]
    fn random_roundtrip() {
        let mut rng = crate::util::rng::Rng::new(3);
        for _ in 0..20 {
            let rows = 1 + rng.below(30);
            let cols = 1 + rng.below(30);
            let mut dense = vec![0.0f32; rows * cols];
            for v in &mut dense {
                if rng.uniform() < 0.2 {
                    *v = rng.normal() as f32;
                }
            }
            let m = CsrMatrix::from_dense(&dense, rows, cols);
            assert_eq!(m.to_dense(), dense);
            m.validate().unwrap();
            assert_eq!(m.nnz(), dense.iter().filter(|&&v| v != 0.0).count());
        }
    }

    #[test]
    fn compression_rate() {
        let (dense, r, c) = paper_matrix();
        let m = CsrMatrix::from_dense(&dense, r, c);
        assert!((m.compression_rate() - (16.0 - 9.0) / 16.0).abs() < 1e-12);
    }

    #[test]
    fn empty_matrix() {
        let m = CsrMatrix::from_dense(&vec![0.0; 12], 3, 4);
        assert_eq!(m.nnz(), 0);
        assert_eq!(m.to_dense(), vec![0.0; 12]);
        assert_eq!(m.compression_rate(), 1.0);
        m.validate().unwrap();
    }

    #[test]
    fn transpose_matches_dense() {
        let (dense, r, c) = paper_matrix();
        let m = CsrMatrix::from_dense(&dense, r, c);
        let t = m.transpose();
        t.validate().unwrap();
        let mut want = vec![0.0f32; 16];
        for i in 0..4 {
            for j in 0..4 {
                want[j * 4 + i] = dense[i * 4 + j];
            }
        }
        assert_eq!(t.to_dense(), want);
    }

    #[test]
    fn transpose_twice_is_identity() {
        let mut rng = crate::util::rng::Rng::new(4);
        let mut dense = vec![0.0f32; 15 * 9];
        for v in &mut dense {
            if rng.uniform() < 0.3 {
                *v = rng.normal() as f32;
            }
        }
        let m = CsrMatrix::from_dense(&dense, 15, 9);
        assert_eq!(m.transpose().transpose(), m);
    }

    #[test]
    fn row_iterator() {
        let (dense, r, c) = paper_matrix();
        let m = CsrMatrix::from_dense(&dense, r, c);
        let row2: Vec<(usize, f32)> = m.row(2).collect();
        assert_eq!(row2, vec![(0, 5.0), (2, 3.0), (3, 9.0)]);
    }

    #[test]
    fn storage_smaller_than_dense_when_sparse() {
        let mut dense = vec![0.0f32; 100 * 100];
        dense[5] = 1.0;
        dense[9999] = 2.0;
        let m = CsrMatrix::from_dense(&dense, 100, 100);
        assert!(m.storage_bytes() < 100 * 100 * 4);
    }

    #[test]
    fn validate_catches_corruption() {
        let (dense, r, c) = paper_matrix();
        let mut m = CsrMatrix::from_dense(&dense, r, c);
        m.indices[0] = 99; // out of bounds column
        assert!(m.validate().is_err());
    }
}
