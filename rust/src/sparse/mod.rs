//! Compressed sparse matrix substrate — the paper's Section 3.
//!
//! Implements every format the paper compares in Figure 1 (DIA, ELL, CSR,
//! COO), the two dense×compressed kernels it contributes (Figures 2-3),
//! and the elementwise proximal operator (Figure 4), as multithreaded
//! cache-blocked CPU kernels. CSR is the production format (the paper's
//! conclusion); DIA/ELL/COO exist for the format-comparison study and as
//! conversion targets with round-trip tests.

pub mod blockell;
pub mod coo;
pub mod csr;
pub mod dia;
pub mod ell;
pub mod ops;
pub mod prox;

pub use blockell::BlockEllMatrix;
pub use coo::CooMatrix;
pub use csr::CsrMatrix;
pub use dia::DiaMatrix;
pub use ell::EllMatrix;
