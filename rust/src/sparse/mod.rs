//! Compressed sparse matrix substrate — the paper's Section 3.
//!
//! Implements every format the paper compares in Figure 1 (DIA, ELL, CSR,
//! COO), the two dense×compressed kernels it contributes (Figures 2-3),
//! and the elementwise proximal operator (Figure 4), as multithreaded
//! cache-blocked CPU kernels. CSR is the production format for
//! unstructured sparsity (the paper's conclusion); every format carries
//! its own `dxct` kernel and CSR conversions, and `dispatch` picks the
//! best format per weight matrix with a storage cost model.

pub mod blockell;
pub mod coo;
pub mod csr;
pub mod dia;
pub mod dispatch;
pub mod ell;
pub mod ops;
pub mod prox;

pub use blockell::BlockEllMatrix;
pub use coo::CooMatrix;
pub use csr::CsrMatrix;
pub use dia::DiaMatrix;
pub use dispatch::{analyze, select_format, DynSparseMatrix, SparseFormat, SparseKernel, Structure};
pub use ell::EllMatrix;
