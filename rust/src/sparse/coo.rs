//! Coordinate (COO) format — paper Figure 1(iv).
//!
//! Stores an explicit row index per nonzero; the paper notes "the extra
//! storage required by COO for the row indices appears to be less
//! economical than CSR" for embedded targets, which the `storage_bytes`
//! comparison test below confirms.

use super::csr::CsrMatrix;
use crate::tensor::Tensor;
use crate::util::pool;

#[derive(Debug, Clone, PartialEq)]
pub struct CooMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Row index per nonzero — **row-major sorted** (ascending, ties in
    /// column order). Both constructors emit this order and the
    /// row-partitioned kernel relies on it to binary-search its span.
    pub row: Vec<u32>,
    pub indices: Vec<u32>,
    pub data: Vec<f32>,
}

impl CooMatrix {
    pub fn from_dense(dense: &[f32], rows: usize, cols: usize) -> CooMatrix {
        assert_eq!(dense.len(), rows * cols);
        let mut row = Vec::new();
        let mut indices = Vec::new();
        let mut data = Vec::new();
        for r in 0..rows {
            for c in 0..cols {
                let v = dense[r * cols + c];
                if v != 0.0 {
                    row.push(r as u32);
                    indices.push(c as u32);
                    data.push(v);
                }
            }
        }
        CooMatrix { rows, cols, row, indices, data }
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for i in 0..self.data.len() {
            out[self.row[i] as usize * self.cols + self.indices[i] as usize] = self.data[i];
        }
        out
    }

    pub fn nnz(&self) -> usize {
        self.data.len()
    }

    pub fn storage_bytes(&self) -> usize {
        self.data.len() * 4 + self.indices.len() * 4 + self.row.len() * 4
    }

    pub fn from_csr(csr: &CsrMatrix) -> CooMatrix {
        let mut row = Vec::with_capacity(csr.nnz());
        for r in 0..csr.rows {
            for _ in csr.ptr[r]..csr.ptr[r + 1] {
                row.push(r as u32);
            }
        }
        CooMatrix {
            rows: csr.rows,
            cols: csr.cols,
            row,
            indices: csr.indices.clone(),
            data: csr.data.clone(),
        }
    }

    /// `dmat (B, K) @ self' -> (B, N)` with `self` shaped (N, K) — the
    /// Figure-2 contraction in COO form: one streamed pass over the
    /// triplets per batch row, scattering into the output row.
    pub fn dxct(&self, dmat: &Tensor) -> Tensor {
        self.dxct_threads(dmat, pool::max_threads())
    }

    /// As [`CooMatrix::dxct`] with an explicit worker count. The triplets
    /// are row-major sorted (every constructor emits them that way), so a
    /// row-partitioned thread owns the contiguous triplet span its output
    /// rows cover — found by binary search — and each output element sees
    /// its contributions in triplet order whichever dimension is
    /// partitioned: results are bit-identical for any `threads`. The
    /// fields are `pub`, so a hand-built unsorted matrix is possible;
    /// the row partition checks the invariant (one cheap sequential scan,
    /// skipped when the batch arm runs) and falls back to the
    /// order-agnostic batch arm rather than mis-spanning the searches.
    pub fn dxct_threads(&self, dmat: &Tensor, threads: usize) -> Tensor {
        let (b, k) = (dmat.shape[0], dmat.shape[1]);
        assert_eq!(k, self.cols, "coo dxct: K mismatch ({k} vs {})", self.cols);
        let n = self.rows;
        let mut out = vec![0.0f32; b * n];
        let ptr = pool::SharedMut::new(&mut out);
        if pool::batch_saturates(b, threads) || !self.row.windows(2).all(|w| w[0] <= w[1]) {
            pool::parallel_chunks(b, threads, |b0, b1| {
                let out = unsafe { ptr.slice() };
                for bi in b0..b1 {
                    let xrow = &dmat.data[bi * k..(bi + 1) * k];
                    let orow = &mut out[bi * n..(bi + 1) * n];
                    for i in 0..self.data.len() {
                        orow[self.row[i] as usize] += self.data[i] * xrow[self.indices[i] as usize];
                    }
                }
            });
        } else {
            // Row partition: single-sample serving still goes wide.
            pool::parallel_chunks(n, threads, |r0, r1| {
                let out = unsafe { ptr.slice() };
                let lo = self.row.partition_point(|&r| (r as usize) < r0);
                let hi = self.row.partition_point(|&r| (r as usize) < r1);
                for bi in 0..b {
                    let xrow = &dmat.data[bi * k..(bi + 1) * k];
                    for i in lo..hi {
                        out[bi * n + self.row[i] as usize] +=
                            self.data[i] * xrow[self.indices[i] as usize];
                    }
                }
            });
        }
        Tensor::new(vec![b, n], out)
    }

    /// COO (sorted row-major, as produced here) -> CSR.
    pub fn to_csr(&self) -> CsrMatrix {
        let mut ptr = vec![0usize; self.rows + 1];
        for &r in &self.row {
            ptr[r as usize + 1] += 1;
        }
        for i in 1..ptr.len() {
            ptr[i] += ptr[i - 1];
        }
        CsrMatrix {
            rows: self.rows,
            cols: self.cols,
            ptr,
            indices: self.indices.clone(),
            data: self.data.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_matrix() -> (Vec<f32>, usize, usize) {
        #[rustfmt::skip]
        let dense = vec![
            1., 7., 0., 0.,
            0., 2., 8., 0.,
            5., 0., 3., 9.,
            0., 6., 0., 4.,
        ];
        (dense, 4, 4)
    }

    #[test]
    fn figure1_coo_layout() {
        let (dense, r, c) = paper_matrix();
        let m = CooMatrix::from_dense(&dense, r, c);
        // Paper Figure 1(iv).
        assert_eq!(m.row, vec![0, 0, 1, 1, 2, 2, 2, 3, 3]);
        assert_eq!(m.indices, vec![0, 1, 1, 2, 0, 2, 3, 1, 3]);
        assert_eq!(m.data, vec![1., 7., 2., 8., 5., 3., 9., 6., 4.]);
    }

    #[test]
    fn roundtrip_dense() {
        let (dense, r, c) = paper_matrix();
        let m = CooMatrix::from_dense(&dense, r, c);
        assert_eq!(m.to_dense(), dense);
    }

    #[test]
    fn csr_coo_conversions() {
        let (dense, r, c) = paper_matrix();
        let csr = CsrMatrix::from_dense(&dense, r, c);
        let coo = CooMatrix::from_csr(&csr);
        assert_eq!(coo, CooMatrix::from_dense(&dense, r, c));
        assert_eq!(coo.to_csr(), csr);
    }

    #[test]
    fn coo_less_economical_than_csr() {
        // The paper's Section 3.1 argument, checked numerically: for the
        // usual case nnz > rows + 1, COO stores more than CSR.
        let (dense, r, c) = paper_matrix();
        let csr = CsrMatrix::from_dense(&dense, r, c);
        let coo = CooMatrix::from_dense(&dense, r, c);
        assert!(coo.storage_bytes() > csr.storage_bytes());
    }

    #[test]
    fn dxct_matches_dense() {
        use crate::tensor::{matmul_nt, Tensor};
        let mut rng = crate::util::rng::Rng::new(6);
        for &(b, n, k) in &[(1usize, 4usize, 4usize), (5, 25, 35), (2, 13, 8)] {
            let mut dense = vec![0.0f32; n * k];
            for v in &mut dense {
                if rng.uniform() < 0.3 {
                    *v = rng.normal() as f32;
                }
            }
            let coo = CooMatrix::from_dense(&dense, n, k);
            let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
            let got = coo.dxct(&d);
            let want = matmul_nt(&d, &Tensor::new(vec![n, k], dense));
            for (g, w) in got.data.iter().zip(&want.data) {
                assert!((g - w).abs() < 1e-4, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn random_roundtrip() {
        let mut rng = crate::util::rng::Rng::new(5);
        for _ in 0..10 {
            let rows = 1 + rng.below(20);
            let cols = 1 + rng.below(20);
            let mut dense = vec![0.0f32; rows * cols];
            for v in &mut dense {
                if rng.uniform() < 0.25 {
                    *v = rng.normal() as f32;
                }
            }
            let m = CooMatrix::from_dense(&dense, rows, cols);
            assert_eq!(m.to_dense(), dense);
            assert_eq!(m.to_csr().to_dense(), dense);
        }
    }
}
