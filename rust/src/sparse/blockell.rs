//! Block-ELL (fixed-slot BSR) — the TPU-honest compressed format.
//!
//! Rust mirror of `python/compile/kernels/spmm.py::dense_to_blockell`:
//! nonzero (bh × bw) tiles in an ELL-like layout with a fixed number of
//! slots per block-row. Used by DESIGN.md §3's hardware-adaptation story:
//! at block granularity the per-row population concentrates (the
//! `row_population_stats` helper quantifies this on prox-trained weights),
//! so ELL padding — fatal at element level — is cheap at block level,
//! and static shapes suit the MXU.

use super::csr::CsrMatrix;
use crate::tensor::Tensor;
use crate::util::pool;

#[derive(Debug, Clone, PartialEq)]
pub struct BlockEllMatrix {
    /// Logical dense shape (rows = N outputs, cols = K inputs).
    pub rows: usize,
    pub cols: usize,
    pub bh: usize,
    pub bw: usize,
    /// Slots per block-row.
    pub max_blocks: usize,
    /// (n_block_rows × max_blocks) block-column index, -1 = padding.
    pub col_idx: Vec<i32>,
    /// (n_block_rows × max_blocks × bh × bw) tile values.
    pub values: Vec<f32>,
}

impl BlockEllMatrix {
    pub fn n_block_rows(&self) -> usize {
        self.rows / self.bh
    }

    pub fn n_block_cols(&self) -> usize {
        self.cols / self.bw
    }

    /// Pack a dense (rows, cols) matrix. Panics unless tileable.
    pub fn from_dense(dense: &[f32], rows: usize, cols: usize, bh: usize, bw: usize) -> Self {
        assert_eq!(dense.len(), rows * cols);
        assert!(rows % bh == 0 && cols % bw == 0, "({rows},{cols}) not tileable by ({bh},{bw})");
        let n_br = rows / bh;
        let n_bc = cols / bw;
        // Find nonzero blocks per block-row.
        let mut block_cols: Vec<Vec<usize>> = vec![Vec::new(); n_br];
        for i in 0..n_br {
            for j in 0..n_bc {
                let mut nz = false;
                'scan: for y in 0..bh {
                    for x in 0..bw {
                        if dense[(i * bh + y) * cols + j * bw + x] != 0.0 {
                            nz = true;
                            break 'scan;
                        }
                    }
                }
                if nz {
                    block_cols[i].push(j);
                }
            }
        }
        let max_blocks = block_cols.iter().map(Vec::len).max().unwrap_or(0).max(1);
        let mut col_idx = vec![-1i32; n_br * max_blocks];
        let mut values = vec![0.0f32; n_br * max_blocks * bh * bw];
        for i in 0..n_br {
            for (s, &j) in block_cols[i].iter().enumerate() {
                col_idx[i * max_blocks + s] = j as i32;
                for y in 0..bh {
                    for x in 0..bw {
                        values[((i * max_blocks + s) * bh + y) * bw + x] =
                            dense[(i * bh + y) * cols + j * bw + x];
                    }
                }
            }
        }
        BlockEllMatrix { rows, cols, bh, bw, max_blocks, col_idx, values }
    }

    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        let n_br = self.n_block_rows();
        for i in 0..n_br {
            for s in 0..self.max_blocks {
                let j = self.col_idx[i * self.max_blocks + s];
                if j < 0 {
                    continue;
                }
                let j = j as usize;
                for y in 0..self.bh {
                    for x in 0..self.bw {
                        out[(i * self.bh + y) * self.cols + j * self.bw + x] =
                            self.values[((i * self.max_blocks + s) * self.bh + y) * self.bw + x];
                    }
                }
            }
        }
        out
    }

    /// Nonzero blocks / total blocks.
    pub fn block_density(&self) -> f64 {
        let nz = self.col_idx.iter().filter(|&&c| c >= 0).count();
        nz as f64 / (self.n_block_rows() * self.n_block_cols()) as f64
    }

    /// Fraction of allocated slots that are padding.
    pub fn padding_overhead(&self) -> f64 {
        let slots = self.n_block_rows() * self.max_blocks;
        let nz = self.col_idx.iter().filter(|&&c| c >= 0).count();
        1.0 - nz as f64 / slots as f64
    }

    pub fn storage_bytes(&self) -> usize {
        self.values.len() * 4 + self.col_idx.len() * 4
    }

    /// Stored nonzeros (padding tiles hold exact zeros and do not count).
    pub fn nnz(&self) -> usize {
        self.values.iter().filter(|&&v| v != 0.0).count()
    }

    /// Build from CSR (via the dense view — block packing needs the full
    /// tile contents anyway, so there is nothing cheaper to walk).
    pub fn from_csr(csr: &CsrMatrix, bh: usize, bw: usize) -> BlockEllMatrix {
        BlockEllMatrix::from_dense(&csr.to_dense(), csr.rows, csr.cols, bh, bw)
    }

    /// Convert to CSR, dropping block padding and explicit zeros.
    pub fn to_csr(&self) -> CsrMatrix {
        CsrMatrix::from_dense(&self.to_dense(), self.rows, self.cols)
    }

    /// (min, mean, max) nonzero blocks per block-row — evidence for the
    /// "block rows concentrate" claim in DESIGN.md §3.
    pub fn row_population_stats(&self) -> (usize, f64, usize) {
        let n_br = self.n_block_rows();
        let counts: Vec<usize> = (0..n_br)
            .map(|i| {
                (0..self.max_blocks)
                    .filter(|&s| self.col_idx[i * self.max_blocks + s] >= 0)
                    .count()
            })
            .collect();
        let min = counts.iter().copied().min().unwrap_or(0);
        let max = counts.iter().copied().max().unwrap_or(0);
        let mean = counts.iter().sum::<usize>() as f64 / n_br.max(1) as f64;
        (min, mean, max)
    }

    /// `dmat (B, K) @ self' -> (B, N)`: the rust mirror of the Pallas
    /// Block-ELL kernel (gather nonzero tiles, dense tile matmul).
    pub fn dxct(&self, dmat: &Tensor) -> Tensor {
        self.dxct_threads(dmat, pool::max_threads())
    }

    /// As [`BlockEllMatrix::dxct`] with an explicit worker count. The
    /// kernel partitions *block rows* (independent of the batch size, so
    /// single-sample serving already goes wide) and accumulates each
    /// output element's tiles in ascending-slot order: results are
    /// bit-identical for any `threads`.
    pub fn dxct_threads(&self, dmat: &Tensor, threads: usize) -> Tensor {
        let (b, k) = (dmat.shape[0], dmat.shape[1]);
        assert_eq!(k, self.cols);
        let n = self.rows;
        let n_br = self.n_block_rows();
        let mut out = vec![0.0f32; b * n];
        let ptr = pool::SharedMut::new(&mut out);
        pool::parallel_chunks(n_br, threads, |i0, i1| {
            let out = unsafe { ptr.slice() };
            for i in i0..i1 {
                for s in 0..self.max_blocks {
                    let j = self.col_idx[i * self.max_blocks + s];
                    if j < 0 {
                        continue;
                    }
                    let j = j as usize;
                    let tile = &self.values
                        [(i * self.max_blocks + s) * self.bh * self.bw
                            ..(i * self.max_blocks + s + 1) * self.bh * self.bw];
                    for r in 0..b {
                        let xs = &dmat.data[r * k + j * self.bw..r * k + (j + 1) * self.bw];
                        for y in 0..self.bh {
                            let wrow = &tile[y * self.bw..(y + 1) * self.bw];
                            let mut acc = 0.0f32;
                            for x in 0..self.bw {
                                acc += xs[x] * wrow[x];
                            }
                            out[r * n + i * self.bh + y] += acc;
                        }
                    }
                }
            }
        });
        Tensor::new(vec![b, n], out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::matmul_nt;
    use crate::util::rng::Rng;

    fn block_sparse(rng: &mut Rng, rows: usize, cols: usize, bh: usize, bw: usize, keep: f64) -> Vec<f32> {
        let mut dense = vec![0.0f32; rows * cols];
        for i in 0..rows / bh {
            for j in 0..cols / bw {
                if rng.uniform() < keep {
                    for y in 0..bh {
                        for x in 0..bw {
                            dense[(i * bh + y) * cols + j * bw + x] = rng.normal() as f32;
                        }
                    }
                }
            }
        }
        dense
    }

    #[test]
    fn roundtrip() {
        let mut rng = Rng::new(30);
        let dense = block_sparse(&mut rng, 32, 64, 8, 16, 0.4);
        let m = BlockEllMatrix::from_dense(&dense, 32, 64, 8, 16);
        assert_eq!(m.to_dense(), dense);
    }

    #[test]
    fn matmul_matches_dense() {
        let mut rng = Rng::new(31);
        let dense = block_sparse(&mut rng, 32, 64, 8, 16, 0.5);
        let m = BlockEllMatrix::from_dense(&dense, 32, 64, 8, 16);
        let d = Tensor::new(vec![10, 64], rng.normal_vec(640, 1.0));
        let got = m.dxct(&d);
        let want = matmul_nt(&d, &Tensor::new(vec![32, 64], dense));
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-3);
        }
    }

    #[test]
    fn all_zero() {
        let m = BlockEllMatrix::from_dense(&vec![0.0; 16 * 32], 16, 32, 8, 16, );
        assert_eq!(m.block_density(), 0.0);
        let d = Tensor::new(vec![2, 32], vec![1.0; 64]);
        assert_eq!(m.dxct(&d).data, vec![0.0; 32]);
    }

    #[test]
    fn unstructured_sparsity_block_stats() {
        // Element-level 90% sparsity at random: almost every block is
        // nonzero (the reason element-CSR ≠ block format in storage), but
        // per-block-row populations are tightly concentrated — the
        // property that makes Block-ELL padding cheap.
        let mut rng = Rng::new(32);
        let (rows, cols) = (128, 256);
        let mut dense = vec![0.0f32; rows * cols];
        for v in &mut dense {
            if rng.uniform() < 0.1 {
                *v = rng.normal() as f32;
            }
        }
        let m = BlockEllMatrix::from_dense(&dense, rows, cols, 8, 16, );
        let (min, mean, max) = m.row_population_stats();
        assert!(max - min <= m.n_block_cols() / 2, "min {min} mean {mean} max {max}");
        assert!(m.padding_overhead() < 0.3);
    }

    #[test]
    fn storage_beats_dense_for_block_sparse() {
        let mut rng = Rng::new(33);
        let dense = block_sparse(&mut rng, 64, 128, 8, 16, 0.1);
        let m = BlockEllMatrix::from_dense(&dense, 64, 128, 8, 16);
        assert!(m.storage_bytes() < 64 * 128 * 4);
    }

    #[test]
    #[should_panic]
    fn untileable_panics() {
        BlockEllMatrix::from_dense(&vec![0.0; 30], 5, 6, 2, 4);
    }

    #[test]
    fn csr_roundtrip() {
        let mut rng = Rng::new(34);
        let dense = block_sparse(&mut rng, 32, 64, 8, 16, 0.4);
        let csr = crate::sparse::CsrMatrix::from_dense(&dense, 32, 64);
        let bell = BlockEllMatrix::from_csr(&csr, 8, 16);
        assert_eq!(bell, BlockEllMatrix::from_dense(&dense, 32, 64, 8, 16));
        let back = bell.to_csr();
        back.validate().unwrap();
        assert_eq!(back, csr);
        assert_eq!(bell.nnz(), csr.nnz());
    }
}
