//! The proximal operator (soft thresholding) — paper Figure 4.
//!
//! CPU port of the elementwise OpenCL kernel, in both formulations:
//! the sign·max closed form (Section 2.2) and the paper's min/max clip
//! form (Figure 4); the tests pin their equivalence. Used host-side by
//! the Pru baseline's magnitude thresholding and by checkpoint
//! sparsification; the training-path prox runs inside the XLA artifacts
//! (the L1 Pallas kernel).

use crate::util::pool;

/// `sgn(z) * max(|z| - thresh, 0)` elementwise, in place.
pub fn soft_threshold_inplace(xs: &mut [f32], thresh: f32) {
    for v in xs.iter_mut() {
        let a = v.abs() - thresh;
        *v = if a > 0.0 { a * v.signum() } else { 0.0 };
    }
}

/// The paper's Figure-4 formulation: `min(max(z - t, 0), z + t)`.
pub fn soft_threshold_clip(xs: &mut [f32], thresh: f32) {
    for v in xs.iter_mut() {
        *v = (*v - thresh).max(0.0).min(*v + thresh);
    }
}

/// Below this size, thread-spawn cost exceeds the elementwise work
/// (§Perf measurement: 400k-element vectors ran *slower* parallel).
pub const PARALLEL_MIN_ELEMS: usize = 1 << 21;

/// Parallel variant for large parameter vectors (falls back to the
/// serial kernel below `PARALLEL_MIN_ELEMS` — see §Perf).
pub fn soft_threshold_parallel(xs: &mut [f32], thresh: f32) {
    let n = xs.len();
    if n < PARALLEL_MIN_ELEMS {
        return soft_threshold_inplace(xs, thresh);
    }
    let ptr = pool::SharedMut::new(xs);
    pool::parallel_chunks(n, pool::max_threads(), |a, b| {
        let xs = unsafe { ptr.slice() };
        soft_threshold_inplace(&mut xs[a..b], thresh);
    });
}

/// Hard threshold (magnitude pruning, Han et al. 2015 — the Pru
/// baseline): zero out entries with `|z| <= thresh`, *without* shrinking
/// the survivors. Returns the number of zeroed entries.
pub fn hard_threshold_inplace(xs: &mut [f32], thresh: f32) -> usize {
    let mut zeroed = 0;
    for v in xs.iter_mut() {
        if v.abs() <= thresh && *v != 0.0 {
            *v = 0.0;
            zeroed += 1;
        }
    }
    zeroed
}

/// Magnitude quantile: the |value| below which `frac` of entries fall.
/// Used to pick Pru thresholds for a target compression rate.
pub fn magnitude_quantile(xs: &[f32], frac: f64) -> f32 {
    if xs.is_empty() {
        return 0.0;
    }
    let mut mags: Vec<f32> = xs.iter().map(|v| v.abs()).collect();
    mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let idx = ((frac.clamp(0.0, 1.0)) * (mags.len() - 1) as f64).round() as usize;
    mags[idx]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn soft_threshold_formula() {
        let mut xs = vec![0.5, -0.5, 0.1, -0.1, 0.0, 2.0];
        soft_threshold_inplace(&mut xs, 0.3);
        let want = [0.2f32, -0.2, 0.0, 0.0, 0.0, 1.7];
        for (g, w) in xs.iter().zip(&want) {
            assert!((g - w).abs() < 1e-6, "{g} vs {w}");
        }
        // Band interior maps to EXACT zero, not merely small.
        assert_eq!(xs[2], 0.0);
        assert_eq!(xs[3], 0.0);
    }

    #[test]
    fn clip_form_equivalent() {
        let mut rng = Rng::new(20);
        let xs: Vec<f32> = rng.normal_vec(1000, 1.0);
        for &t in &[0.0, 0.1, 0.5, 2.0] {
            let mut a = xs.clone();
            let mut b = xs.clone();
            soft_threshold_inplace(&mut a, t);
            soft_threshold_clip(&mut b, t);
            for (u, v) in a.iter().zip(&b) {
                assert!((u - v).abs() < 1e-6, "t={t}: {u} vs {v}");
            }
        }
    }

    #[test]
    fn parallel_matches_serial() {
        let mut rng = Rng::new(21);
        let xs: Vec<f32> = rng.normal_vec(100_000, 1.0);
        let mut a = xs.clone();
        let mut b = xs;
        soft_threshold_inplace(&mut a, 0.4);
        soft_threshold_parallel(&mut b, 0.4);
        assert_eq!(a, b);
    }

    #[test]
    fn nonexpansive() {
        let mut rng = Rng::new(22);
        let a: Vec<f32> = rng.normal_vec(500, 1.0);
        let b: Vec<f32> = rng.normal_vec(500, 1.0);
        let mut pa = a.clone();
        let mut pb = b.clone();
        soft_threshold_inplace(&mut pa, 0.3);
        soft_threshold_inplace(&mut pb, 0.3);
        let d_in: f32 = a.iter().zip(&b).map(|(x, y)| (x - y) * (x - y)).sum();
        let d_out: f32 = pa.iter().zip(&pb).map(|(x, y)| (x - y) * (x - y)).sum();
        assert!(d_out <= d_in + 1e-4);
    }

    #[test]
    fn hard_threshold_keeps_magnitudes() {
        let mut xs = vec![0.5, -0.05, 0.2, -0.9];
        let zeroed = hard_threshold_inplace(&mut xs, 0.1);
        assert_eq!(zeroed, 1);
        assert_eq!(xs, vec![0.5, 0.0, 0.2, -0.9]); // survivors NOT shrunk
    }

    #[test]
    fn soft_vs_hard_bias() {
        // Soft thresholding biases survivors toward zero (the estimation
        // bias debiasing removes); hard thresholding does not.
        let mut soft = vec![1.0f32, -1.0];
        let mut hard = vec![1.0f32, -1.0];
        soft_threshold_inplace(&mut soft, 0.3);
        hard_threshold_inplace(&mut hard, 0.3);
        assert_eq!(soft, vec![0.7, -0.7]);
        assert_eq!(hard, vec![1.0, -1.0]);
    }

    #[test]
    fn quantile_threshold_hits_target_rate() {
        let mut rng = Rng::new(23);
        let mut xs: Vec<f32> = rng.normal_vec(10_000, 1.0);
        let t = magnitude_quantile(&xs, 0.9);
        hard_threshold_inplace(&mut xs, t);
        let zeros = xs.iter().filter(|&&v| v == 0.0).count();
        let rate = zeros as f64 / xs.len() as f64;
        assert!((rate - 0.9).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn empty_input() {
        let mut xs: Vec<f32> = vec![];
        soft_threshold_inplace(&mut xs, 0.5);
        assert_eq!(hard_threshold_inplace(&mut xs, 0.5), 0);
        assert_eq!(magnitude_quantile(&xs, 0.5), 0.0);
    }
}
