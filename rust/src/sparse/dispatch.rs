//! Per-layer sparse-format dispatch — pick the best compressed format
//! for each weight matrix instead of hard-coding CSR.
//!
//! The paper settles on CSR because prox-trained weights are usually
//! unstructured (Section 3.1), but EIE (Han et al. 2016) and Deep
//! Compression (Han et al. 2015) both show that the *choice* of format
//! per layer dominates inference throughput once sparsity varies across
//! layers. This module closes that gap for the substrate:
//!
//! * [`analyze`] measures the structure of a dense matrix: how full its
//!   occupied diagonals are (DIA's friend), how uniform its row
//!   populations are (ELL vs CSR), and how its nonzeros tile into
//!   Block-ELL blocks.
//! * [`select_format`] turns the measured counts into a choice via a
//!   storage cost model. At the sparsity levels the paper operates at (90-97%)
//!   the SpMM kernels are bandwidth-bound (see `device`'s roofline), so
//!   bytes streamed per multiply is the honest proxy for kernel time:
//!   the cheapest-to-store format is the fastest-to-multiply one.
//! * [`DynSparseMatrix`] stores a matrix in the chosen format behind one
//!   object ([`SparseKernel`] keeps the five formats interchangeable as
//!   trait objects), with `dxct` dispatching to the format's kernel.
//!
//! `inference::engine` routes per-layer weights through this module in
//! `WeightMode::Auto`, and `compress::mm` reports the deployed format of
//! every compressed leaf.

use super::blockell::BlockEllMatrix;
use super::coo::CooMatrix;
use super::csr::CsrMatrix;
use super::dia::DiaMatrix;
use super::ell::EllMatrix;
use super::ops;
use crate::tensor::Tensor;

/// Default Block-ELL tile, matching the Pallas kernel's MXU-friendly
/// shape (`python/compile/kernels/spmm.py`).
pub const BLOCK_H: usize = 8;
pub const BLOCK_W: usize = 16;

/// The storage formats of the substrate: the paper's Figure-1 element
/// formats + Block-ELL, plus the quantized-CSR deployment format
/// (`quant::QcsMatrix` — codebook codes instead of f32 values).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SparseFormat {
    Dia,
    Ell,
    Csr,
    Coo,
    BlockEll,
    /// Quantized CSR. Never auto-selected by [`select_format`]: it is
    /// *lossy*, so only an explicit quantization request (CLI /
    /// `WeightMode::Quantized` / checkpoint v2) deploys it.
    Qcs,
}

impl SparseFormat {
    pub fn name(&self) -> &'static str {
        match self {
            SparseFormat::Dia => "DIA",
            SparseFormat::Ell => "ELL",
            SparseFormat::Csr => "CSR",
            SparseFormat::Coo => "COO",
            SparseFormat::BlockEll => "BlockELL",
            SparseFormat::Qcs => "QCS",
        }
    }
}

/// Structure measurements of a dense matrix. The raw counts (`nnz`,
/// `num_diags`, `max_row_nnz`, `block`) drive the byte cost model in
/// [`format_bytes`]; the `*_fill` ratios are human-readable summaries of
/// the same counts for logs, benches, and heuristic tuning — they do not
/// enter the selection themselves.
#[derive(Debug, Clone, Copy)]
pub struct Structure {
    /// Total nonzeros (counted in the same pass as the other stats).
    pub nnz: usize,
    /// Distinct occupied diagonals.
    pub num_diags: usize,
    /// Diagonal-band score: nnz / (num_diags · rows) — 1.0 means every
    /// occupied diagonal is full (a banded matrix). Reporting only.
    pub diag_fill: f64,
    /// Widest row (ELL's padded width).
    pub max_row_nnz: usize,
    /// Row-uniformity score: mean row nnz / max row nnz — 1.0 means
    /// perfectly uniform rows (no ELL padding). Reporting only.
    pub row_fill: f64,
    /// Block-density stats when the matrix tiles by `BLOCK_H`×`BLOCK_W`.
    pub block: Option<BlockStats>,
}

/// Block-level population for the Block-ELL candidate.
#[derive(Debug, Clone, Copy)]
pub struct BlockStats {
    /// Widest block-row (Block-ELL's slot count — the cost driver).
    pub max_blocks_per_row: usize,
    /// Nonzero blocks in the whole matrix (reporting only).
    pub nnz_blocks: usize,
}

/// Measure the structure of a dense (rows × cols) matrix in one pass.
pub fn analyze(dense: &[f32], rows: usize, cols: usize) -> Structure {
    assert_eq!(dense.len(), rows * cols);
    // Diagonal occupancy: offset = col - row, shifted to [0, rows+cols).
    let mut diag_hit = vec![false; rows + cols];
    let mut nnz = 0usize;
    let mut max_row_nnz = 0usize;
    for r in 0..rows {
        let mut row_nnz = 0usize;
        for c in 0..cols {
            if dense[r * cols + c] != 0.0 {
                row_nnz += 1;
                diag_hit[c + rows - r - 1] = true;
            }
        }
        nnz += row_nnz;
        max_row_nnz = max_row_nnz.max(row_nnz);
    }
    let num_diags = diag_hit.iter().filter(|&&h| h).count();
    let diag_fill = if num_diags == 0 {
        0.0
    } else {
        nnz as f64 / (num_diags * rows) as f64
    };
    let row_fill = if max_row_nnz == 0 {
        0.0
    } else {
        nnz as f64 / (rows * max_row_nnz) as f64
    };

    let block = if rows % BLOCK_H == 0 && cols % BLOCK_W == 0 && rows > 0 && cols > 0 {
        let n_br = rows / BLOCK_H;
        let n_bc = cols / BLOCK_W;
        let mut max_blocks_per_row = 0usize;
        let mut nnz_blocks = 0usize;
        for i in 0..n_br {
            let mut blocks = 0usize;
            for j in 0..n_bc {
                'tile: for y in 0..BLOCK_H {
                    for x in 0..BLOCK_W {
                        if dense[(i * BLOCK_H + y) * cols + j * BLOCK_W + x] != 0.0 {
                            blocks += 1;
                            break 'tile;
                        }
                    }
                }
            }
            nnz_blocks += blocks;
            max_blocks_per_row = max_blocks_per_row.max(blocks);
        }
        Some(BlockStats { max_blocks_per_row, nnz_blocks })
    } else {
        None
    };

    Structure { nnz, num_diags, diag_fill, max_row_nnz, row_fill, block }
}

/// Estimated storage bytes per candidate format — the cost model.
/// Mirrors each format's `storage_bytes()` exactly (values f32, indices
/// u32, DIA offsets i64), so the chooser's prediction is the real bill.
pub fn format_bytes(rows: usize, _cols: usize, nnz: usize, s: &Structure) -> [(SparseFormat, usize); 5] {
    let csr = nnz * 8 + (rows + 1) * 4;
    let coo = nnz * 12;
    let dia = s.num_diags * rows * 4 + s.num_diags * 8;
    let ell = rows * s.max_row_nnz * 8;
    let bell = match s.block {
        // One i32 column index per slot + a full (padded) tile of values.
        Some(b) => (rows / BLOCK_H) * b.max_blocks_per_row.max(1) * (BLOCK_H * BLOCK_W * 4 + 4),
        None => usize::MAX,
    };
    [
        (SparseFormat::Csr, csr),
        (SparseFormat::Dia, dia),
        (SparseFormat::Ell, ell),
        (SparseFormat::BlockEll, bell),
        (SparseFormat::Coo, coo),
    ]
}

/// Choose the format for a (rows × cols) matrix with `nnz` nonzeros and
/// the measured `structure`: high diagonal-band score → DIA, uniform row
/// populations → ELL, dense blocks → Block-ELL, everything else (the
/// paper's unstructured common case) → CSR. Ties break toward CSR, the
/// production format. COO is never auto-selected: it only undercuts CSR
/// when nnz < rows + 1 (the row-index tax beats row pointers solely on
/// near-empty matrices, where the few bytes saved cannot pay for its
/// scatter-form kernel), so it stays a conversion/interchange format.
pub fn select_format(rows: usize, cols: usize, nnz: usize, structure: &Structure) -> SparseFormat {
    if nnz == 0 {
        return SparseFormat::Csr;
    }
    let mut best = SparseFormat::Csr;
    let mut best_bytes = usize::MAX;
    // Candidate order encodes the tie-break preference.
    for (fmt, bytes) in format_bytes(rows, cols, nnz, structure) {
        if fmt != SparseFormat::Coo && bytes < best_bytes {
            best = fmt;
            best_bytes = bytes;
        }
    }
    best
}

/// Object-safe kernel surface every storage format implements — the
/// trait-object layer over the five concrete matrix types.
pub trait SparseKernel {
    fn rows(&self) -> usize;
    fn cols(&self) -> usize;
    fn nnz(&self) -> usize;
    fn storage_bytes(&self) -> usize;
    fn to_dense(&self) -> Vec<f32>;
    /// `dmat (B, K) @ self' -> (B, N)` — the paper's Figure-2 forward
    /// contraction, in this format's native kernel.
    fn dxct(&self, dmat: &Tensor) -> Tensor;
    /// As [`SparseKernel::dxct`] with an explicit worker-thread count
    /// (the serving path and thread-sweep benches drive this directly;
    /// `dxct` uses `pool::max_threads()`). Every format keeps a fixed
    /// per-output-element reduction order, so any count is bit-identical.
    fn dxct_threads(&self, dmat: &Tensor, threads: usize) -> Tensor;
    fn format(&self) -> SparseFormat;
}

impl SparseKernel for CsrMatrix {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn nnz(&self) -> usize {
        CsrMatrix::nnz(self)
    }
    fn storage_bytes(&self) -> usize {
        CsrMatrix::storage_bytes(self)
    }
    fn to_dense(&self) -> Vec<f32> {
        CsrMatrix::to_dense(self)
    }
    fn dxct(&self, dmat: &Tensor) -> Tensor {
        ops::dxct(dmat, self)
    }
    fn dxct_threads(&self, dmat: &Tensor, threads: usize) -> Tensor {
        ops::dxct_threads(dmat, self, threads)
    }
    fn format(&self) -> SparseFormat {
        SparseFormat::Csr
    }
}

impl SparseKernel for DiaMatrix {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn nnz(&self) -> usize {
        DiaMatrix::nnz(self)
    }
    fn storage_bytes(&self) -> usize {
        DiaMatrix::storage_bytes(self)
    }
    fn to_dense(&self) -> Vec<f32> {
        DiaMatrix::to_dense(self)
    }
    fn dxct(&self, dmat: &Tensor) -> Tensor {
        DiaMatrix::dxct(self, dmat)
    }
    fn dxct_threads(&self, dmat: &Tensor, threads: usize) -> Tensor {
        DiaMatrix::dxct_threads(self, dmat, threads)
    }
    fn format(&self) -> SparseFormat {
        SparseFormat::Dia
    }
}

impl SparseKernel for EllMatrix {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn nnz(&self) -> usize {
        EllMatrix::nnz(self)
    }
    fn storage_bytes(&self) -> usize {
        EllMatrix::storage_bytes(self)
    }
    fn to_dense(&self) -> Vec<f32> {
        EllMatrix::to_dense(self)
    }
    fn dxct(&self, dmat: &Tensor) -> Tensor {
        EllMatrix::dxct(self, dmat)
    }
    fn dxct_threads(&self, dmat: &Tensor, threads: usize) -> Tensor {
        EllMatrix::dxct_threads(self, dmat, threads)
    }
    fn format(&self) -> SparseFormat {
        SparseFormat::Ell
    }
}

impl SparseKernel for CooMatrix {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn nnz(&self) -> usize {
        CooMatrix::nnz(self)
    }
    fn storage_bytes(&self) -> usize {
        CooMatrix::storage_bytes(self)
    }
    fn to_dense(&self) -> Vec<f32> {
        CooMatrix::to_dense(self)
    }
    fn dxct(&self, dmat: &Tensor) -> Tensor {
        CooMatrix::dxct(self, dmat)
    }
    fn dxct_threads(&self, dmat: &Tensor, threads: usize) -> Tensor {
        CooMatrix::dxct_threads(self, dmat, threads)
    }
    fn format(&self) -> SparseFormat {
        SparseFormat::Coo
    }
}

impl SparseKernel for BlockEllMatrix {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn nnz(&self) -> usize {
        BlockEllMatrix::nnz(self)
    }
    fn storage_bytes(&self) -> usize {
        BlockEllMatrix::storage_bytes(self)
    }
    fn to_dense(&self) -> Vec<f32> {
        BlockEllMatrix::to_dense(self)
    }
    fn dxct(&self, dmat: &Tensor) -> Tensor {
        BlockEllMatrix::dxct(self, dmat)
    }
    fn dxct_threads(&self, dmat: &Tensor, threads: usize) -> Tensor {
        BlockEllMatrix::dxct_threads(self, dmat, threads)
    }
    fn format(&self) -> SparseFormat {
        SparseFormat::BlockEll
    }
}

/// A weight matrix stored in whichever format [`select_format`] chose.
/// A clonable enum rather than a `Box<dyn SparseKernel>` so the engine's
/// `WeightStore` stays `Clone`; [`DynSparseMatrix::kernel`] exposes the
/// trait-object view when one is wanted.
#[derive(Debug, Clone)]
pub enum DynSparseMatrix {
    Dia(DiaMatrix),
    Ell(EllMatrix),
    Csr(CsrMatrix),
    Coo(CooMatrix),
    BlockEll(BlockEllMatrix),
    Qcs(crate::quant::QcsMatrix),
}

impl DynSparseMatrix {
    /// Analyze + choose + pack in one step.
    pub fn from_dense(dense: &[f32], rows: usize, cols: usize) -> DynSparseMatrix {
        let s = analyze(dense, rows, cols);
        Self::from_dense_as(select_format(rows, cols, s.nnz, &s), dense, rows, cols)
    }

    /// Pack into an explicitly requested format.
    pub fn from_dense_as(
        format: SparseFormat,
        dense: &[f32],
        rows: usize,
        cols: usize,
    ) -> DynSparseMatrix {
        match format {
            SparseFormat::Dia => DynSparseMatrix::Dia(DiaMatrix::from_dense(dense, rows, cols)),
            SparseFormat::Ell => DynSparseMatrix::Ell(EllMatrix::from_dense(dense, rows, cols)),
            SparseFormat::Csr => DynSparseMatrix::Csr(CsrMatrix::from_dense(dense, rows, cols)),
            SparseFormat::Coo => DynSparseMatrix::Coo(CooMatrix::from_dense(dense, rows, cols)),
            SparseFormat::BlockEll => DynSparseMatrix::BlockEll(BlockEllMatrix::from_dense(
                dense, rows, cols, BLOCK_H, BLOCK_W,
            )),
            // Lossy (values collapse onto a default-config codebook) —
            // callers wanting a specific codebook build QcsMatrix directly.
            SparseFormat::Qcs => DynSparseMatrix::Qcs(crate::quant::QcsMatrix::from_dense(
                dense,
                rows,
                cols,
                &crate::quant::QuantConfig::default(),
            )),
        }
    }

    /// The trait-object view of the stored matrix.
    pub fn kernel(&self) -> &dyn SparseKernel {
        match self {
            DynSparseMatrix::Dia(m) => m,
            DynSparseMatrix::Ell(m) => m,
            DynSparseMatrix::Csr(m) => m,
            DynSparseMatrix::Coo(m) => m,
            DynSparseMatrix::BlockEll(m) => m,
            DynSparseMatrix::Qcs(m) => m,
        }
    }

    pub fn format(&self) -> SparseFormat {
        self.kernel().format()
    }

    pub fn rows(&self) -> usize {
        self.kernel().rows()
    }

    pub fn cols(&self) -> usize {
        self.kernel().cols()
    }

    pub fn nnz(&self) -> usize {
        self.kernel().nnz()
    }

    pub fn storage_bytes(&self) -> usize {
        self.kernel().storage_bytes()
    }

    pub fn to_dense(&self) -> Vec<f32> {
        self.kernel().to_dense()
    }

    pub fn dxct(&self, dmat: &Tensor) -> Tensor {
        self.kernel().dxct(dmat)
    }

    /// As [`DynSparseMatrix::dxct`] with an explicit worker count.
    pub fn dxct_threads(&self, dmat: &Tensor, threads: usize) -> Tensor {
        self.kernel().dxct_threads(dmat, threads)
    }
}

impl SparseKernel for DynSparseMatrix {
    fn rows(&self) -> usize {
        self.kernel().rows()
    }
    fn cols(&self) -> usize {
        self.kernel().cols()
    }
    fn nnz(&self) -> usize {
        self.kernel().nnz()
    }
    fn storage_bytes(&self) -> usize {
        self.kernel().storage_bytes()
    }
    fn to_dense(&self) -> Vec<f32> {
        self.kernel().to_dense()
    }
    fn dxct(&self, dmat: &Tensor) -> Tensor {
        self.kernel().dxct(dmat)
    }
    fn dxct_threads(&self, dmat: &Tensor, threads: usize) -> Tensor {
        self.kernel().dxct_threads(dmat, threads)
    }
    fn format(&self) -> SparseFormat {
        self.kernel().format()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Tridiagonal (banded) matrix.
    pub fn banded(n: usize) -> Vec<f32> {
        let mut dense = vec![0.0f32; n * n];
        for i in 0..n {
            dense[i * n + i] = 2.0;
            if i + 1 < n {
                dense[i * n + i + 1] = -1.0;
                dense[(i + 1) * n + i] = -1.0;
            }
        }
        dense
    }

    /// Exactly `per_row` nonzeros per row at scattered columns.
    pub fn uniform_rows(rng: &mut Rng, rows: usize, cols: usize, per_row: usize) -> Vec<f32> {
        let mut dense = vec![0.0f32; rows * cols];
        for r in 0..rows {
            let mut placed = 0;
            while placed < per_row {
                let c = rng.below(cols);
                if dense[r * cols + c] == 0.0 {
                    dense[r * cols + c] = rng.normal() as f32 + 3.0; // never exactly 0
                    placed += 1;
                }
            }
        }
        dense
    }

    /// One dense row, a single nonzero everywhere else (max skew).
    pub fn skewed_rows(rows: usize, cols: usize) -> Vec<f32> {
        let mut dense = vec![0.0f32; rows * cols];
        for c in 0..cols {
            dense[c] = 1.0;
        }
        for r in 1..rows {
            dense[r * cols + (r % cols)] = 2.0;
        }
        dense
    }

    /// Exactly `blocks_per_row` dense BLOCK_H×BLOCK_W tiles per block-row.
    pub fn block_sparse(rng: &mut Rng, rows: usize, cols: usize, blocks_per_row: usize) -> Vec<f32> {
        let mut dense = vec![0.0f32; rows * cols];
        let n_bc = cols / BLOCK_W;
        for i in 0..rows / BLOCK_H {
            for s in 0..blocks_per_row {
                let j = (i * 7 + s * 3) % n_bc; // deterministic scatter
                for y in 0..BLOCK_H {
                    for x in 0..BLOCK_W {
                        dense[(i * BLOCK_H + y) * cols + j * BLOCK_W + x] =
                            rng.normal() as f32 + 3.0;
                    }
                }
            }
        }
        dense
    }

    fn choose(dense: &[f32], rows: usize, cols: usize) -> SparseFormat {
        let s = analyze(dense, rows, cols);
        select_format(rows, cols, s.nnz, &s)
    }

    #[test]
    fn banded_selects_dia() {
        assert_eq!(choose(&banded(64), 64, 64), SparseFormat::Dia);
    }

    #[test]
    fn uniform_rows_select_ell() {
        let mut rng = Rng::new(50);
        let dense = uniform_rows(&mut rng, 64, 96, 6);
        assert_eq!(choose(&dense, 64, 96), SparseFormat::Ell);
    }

    #[test]
    fn skewed_rows_select_csr() {
        // cols = 100 is not BLOCK_W-tileable, so the candidates are the
        // paper's four element formats; skew kills ELL and DIA.
        let dense = skewed_rows(32, 100);
        assert_eq!(choose(&dense, 32, 100), SparseFormat::Csr);
    }

    #[test]
    fn block_sparse_selects_blockell() {
        let mut rng = Rng::new(51);
        let dense = block_sparse(&mut rng, 64, 128, 2);
        assert_eq!(choose(&dense, 64, 128), SparseFormat::BlockEll);
    }

    #[test]
    fn empty_matrix_selects_csr() {
        assert_eq!(choose(&vec![0.0; 64], 8, 8), SparseFormat::Csr);
    }

    #[test]
    fn cost_model_matches_real_storage() {
        // The chooser's byte estimates must equal the packed matrices'
        // actual storage_bytes() — otherwise the model drifts.
        let mut rng = Rng::new(52);
        for dense in [
            banded(64),
            uniform_rows(&mut rng, 64, 96, 6),
            block_sparse(&mut rng, 64, 128, 2),
        ] {
            let rows = 64;
            let cols = dense.len() / rows;
            let s = analyze(&dense, rows, cols);
            for (fmt, predicted) in format_bytes(rows, cols, s.nnz, &s) {
                if predicted == usize::MAX {
                    continue;
                }
                let m = DynSparseMatrix::from_dense_as(fmt, &dense, rows, cols);
                assert_eq!(m.storage_bytes(), predicted, "{} on {rows}x{cols}", fmt.name());
            }
        }
    }

    #[test]
    fn qcs_is_explicit_only_and_smaller_than_csr() {
        // The lossy quantized format never wins the auto selection…
        let mut rng = Rng::new(55);
        let dense = uniform_rows(&mut rng, 64, 96, 6);
        assert_ne!(choose(&dense, 64, 96), SparseFormat::Qcs);
        // …but an explicit request packs it, reports it, and undercuts
        // CSR storage (codes + narrow indices vs f32 + u32).
        let m = DynSparseMatrix::from_dense_as(SparseFormat::Qcs, &dense, 64, 96);
        assert_eq!(m.format(), SparseFormat::Qcs);
        assert_eq!(m.nnz(), 64 * 6);
        let csr = DynSparseMatrix::from_dense_as(SparseFormat::Csr, &dense, 64, 96);
        assert!(m.storage_bytes() < csr.storage_bytes());
        // Lossy: the dense round-trip preserves the pattern, not values.
        let back = m.to_dense();
        for (b, d) in back.iter().zip(&dense) {
            assert_eq!(*b == 0.0, *d == 0.0);
        }
    }

    #[test]
    fn dyn_matrix_roundtrips_and_reports() {
        let mut rng = Rng::new(53);
        let dense = uniform_rows(&mut rng, 32, 48, 4);
        let m = DynSparseMatrix::from_dense(&dense, 32, 48);
        assert_eq!(m.to_dense(), dense);
        assert_eq!((m.rows(), m.cols()), (32, 48));
        assert_eq!(m.nnz(), 32 * 4);
        assert!(m.storage_bytes() > 0);
        // Trait-object view agrees with the enum surface.
        let k: &dyn SparseKernel = m.kernel();
        assert_eq!(k.format(), m.format());
        assert_eq!(k.nnz(), m.nnz());
    }

    #[test]
    fn explicit_formats_all_roundtrip() {
        let mut rng = Rng::new(54);
        let dense = block_sparse(&mut rng, 32, 64, 2);
        for fmt in [
            SparseFormat::Dia,
            SparseFormat::Ell,
            SparseFormat::Csr,
            SparseFormat::Coo,
            SparseFormat::BlockEll,
        ] {
            let m = DynSparseMatrix::from_dense_as(fmt, &dense, 32, 64);
            assert_eq!(m.format(), fmt);
            assert_eq!(m.to_dense(), dense, "{}", fmt.name());
        }
    }
}
