//! The paper's dense×compressed kernels (Figures 2-3) as CPU kernels.
//!
//! * `dxct` — `result = Dmat @ Cmat'` (forward pass). One inner product
//!   per (row, col) output element, enumerating the nonzeros of `Cmat`
//!   row `col` — a direct port of the Figure-2 OpenCL kernel with the
//!   thread-group/row split replaced by a thread-per-row-chunk split.
//! * `dxc` — `result = Dmat @ Cmat` (backward pass). As in the paper the
//!   access pattern is the transpose-unfriendly one; the CPU port walks
//!   `Cmat` rows and scatters into the output (row-major accumulation),
//!   which is the cache-friendly CPU equivalent.
//! * `cxd` — `Cmat @ Dmat` for completeness (the ViennaCL op the paper
//!   worked around).
//!
//! §Blocked reduction contract. The serving-path kernels (`dxct`,
//! `spmv`) dispatch on [`pool::kernel_mode`]: the default `Blocked`
//! family accumulates each output element into [`pool::LANES`] = 8
//! independent lanes — nonzero `q` of a CSR row lands in lane
//! `q % LANES` — collapsed by the fixed tree of [`pool::tree_reduce`].
//! Eight independent accumulators break the FMA latency chain of the
//! sequential dot (the autovectorizer maps them onto whatever SIMD width
//! the target has), and because the lane assignment and tree are defined
//! by the *constant* `LANES`, results are bit-identical on any hardware
//! vector width, any `PROXCOMP_THREADS`, and any batch split. The
//! pre-blocking sequential kernels are kept verbatim (`*_scalar_*`) as
//! the `PROXCOMP_KERNEL=scalar` family and as property-test oracles.
//!
//! §Skew. Blocked CSR paths partition rows by *nnz* via
//! [`pool::parallel_prefix_chunks`] (`csr.ptr` is the prefix sum) — EIE's
//! per-PE load-imbalance fix — which only moves thread boundaries and
//! never changes per-element reduction order.
//!
//! The scatter kernels (`dxc`, `cxd`) add exactly one contribution per
//! output element per nonzero, so chunking their contiguous axpys
//! ([`axpy_blocked`]) cannot reorder any element's additions: those
//! kernels are blocked unconditionally, with bits unchanged from the
//! pre-blocking implementation.

use super::csr::CsrMatrix;
use crate::tensor::Tensor;
use crate::util::pool::{self, KernelMode, LANES};

/// Transpose a (r, c) row-major buffer into (c, r).
fn transpose_buf(src: &[f32], r: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; r * c];
    // Block the transpose for cache locality.
    const TB: usize = 32;
    for i0 in (0..r).step_by(TB) {
        for j0 in (0..c).step_by(TB) {
            for i in i0..(i0 + TB).min(r) {
                for j in j0..(j0 + TB).min(c) {
                    out[j * r + i] = src[i * c + j];
                }
            }
        }
    }
    out
}

/// Gathered 8-lane dot of one CSR row against a dense vector: nonzero
/// `q` accumulates into lane `q % LANES` (remainder elements continue
/// the lane sequence at lane 0), lanes collapse via the fixed tree.
/// This function *defines* the blocked per-element semantics — every
/// blocked kernel (CSR, QCS, batch SpMM plane) must match it bit-exactly.
#[inline]
pub fn blocked_row_dot(dvec: &[f32], indices: &[u32], data: &[f32]) -> f32 {
    debug_assert_eq!(indices.len(), data.len());
    let mut acc = [0.0f32; LANES];
    let mut ic = indices.chunks_exact(LANES);
    let mut vc = data.chunks_exact(LANES);
    for (iv, vv) in (&mut ic).zip(&mut vc) {
        for l in 0..LANES {
            acc[l] += vv[l] * dvec[iv[l] as usize];
        }
    }
    for (l, (i, v)) in ic.remainder().iter().zip(vc.remainder()).enumerate() {
        acc[l] += v * dvec[*i as usize];
    }
    pool::tree_reduce(acc)
}

/// `out[i] += a * x[i]` over a contiguous slice, in fixed-width blocks
/// with a scalar tail. One add per element per call, so bit-identical to
/// the plain loop — this is purely an autovectorizer-friendliness shape
/// (fixed-size `[f32; LANES]` windows, no bounds checks in the body).
#[inline]
fn axpy_blocked(out: &mut [f32], x: &[f32], a: f32) {
    debug_assert_eq!(out.len(), x.len());
    let mut oc = out.chunks_exact_mut(LANES);
    let mut xc = x.chunks_exact(LANES);
    for (o, xv) in (&mut oc).zip(&mut xc) {
        for l in 0..LANES {
            o[l] += a * xv[l];
        }
    }
    for (o, xv) in oc.into_remainder().iter_mut().zip(xc.remainder()) {
        *o += a * xv;
    }
}

/// Forward: `dmat (B, K) @ csr' -> (B, N)` with `csr` shaped (N, K).
/// Paper Figure 2: "the column memory access of Cmat' equals the row
/// access of Cmat", so each output column walks one CSR row.
///
/// §Perf: for multi-row batches the kernel runs in *column-major SpMM*
/// form — transpose D to (K, B) once, then each CSR nonzero performs a
/// contiguous length-B axpy into a lane plane. Small batches use the
/// gathered [`blocked_row_dot`]. Both paths realize the same blocked
/// per-element reduction, so any batch split is bit-identical.
pub fn dxct(dmat: &Tensor, csr: &CsrMatrix) -> Tensor {
    dxct_threads(dmat, csr, pool::max_threads())
}

/// As [`dxct`] with an explicit worker count. Dispatches on
/// [`pool::kernel_mode`]: `Blocked` (default) runs the 8-lane kernels,
/// `Scalar` the pre-blocking sequential reference. Within either family
/// every output element keeps a fixed reduction order, so results are
/// bit-identical for any `threads` and any batch split (the serving-path
/// guarantee) — only switching families changes bits.
pub fn dxct_threads(dmat: &Tensor, csr: &CsrMatrix, threads: usize) -> Tensor {
    match pool::kernel_mode() {
        KernelMode::Blocked => dxct_blocked_threads(dmat, csr, threads),
        KernelMode::Scalar => dxct_seq_threads(dmat, csr, threads),
    }
}

/// Pre-blocking dxct (sequential per-element reduction): the
/// `PROXCOMP_KERNEL=scalar` family. Body unchanged from before the
/// blocked rewrite so benches compare against the true "before".
fn dxct_seq_threads(dmat: &Tensor, csr: &CsrMatrix, threads: usize) -> Tensor {
    let (b, k) = (dmat.shape[0], dmat.shape[1]);
    assert_eq!(k, csr.cols, "dxct: K mismatch ({k} vs {})", csr.cols);
    let n = csr.rows;
    if b < SPMM_MIN_BATCH {
        return dxct_scalar_threads(dmat, csr, threads);
    }
    let dt = transpose_buf(&dmat.data, b, k); // (K, B)
    let mut out_t = vec![0.0f32; n * b]; // (N, B)
    let ptr = pool::SharedMut::new(&mut out_t);
    pool::parallel_chunks(n, threads, |c0, c1| {
        let out_t = unsafe { ptr.slice() };
        for col in c0..c1 {
            let orow = &mut out_t[col * b..(col + 1) * b];
            for idx in csr.ptr[col]..csr.ptr[col + 1] {
                let j = csr.indices[idx] as usize;
                let v = csr.data[idx];
                let drow = &dt[j * b..(j + 1) * b];
                for (o, d) in orow.iter_mut().zip(drow) {
                    *o += v * d;
                }
            }
        }
    });
    Tensor::new(vec![b, n], transpose_buf(&out_t, n, b))
}

/// Blocked dxct: gathered 8-lane row dots for small batches, lane-plane
/// SpMM above [`SPMM_MIN_BATCH`]. Rows partition by nnz.
fn dxct_blocked_threads(dmat: &Tensor, csr: &CsrMatrix, threads: usize) -> Tensor {
    let (b, k) = (dmat.shape[0], dmat.shape[1]);
    assert_eq!(k, csr.cols, "dxct: K mismatch ({k} vs {})", csr.cols);
    let n = csr.rows;
    if b >= SPMM_MIN_BATCH {
        return dxct_blocked_spmm_threads(dmat, csr, threads);
    }
    let mut out = vec![0.0f32; b * n];
    let out_ptr = pool::SharedMut::new(&mut out);
    if pool::batch_saturates(b, threads) {
        // Threads own batch rows; each walks every CSR row, so the
        // per-thread weight is uniform and a plain index split is fair.
        pool::parallel_chunks(b, threads, |r0, r1| {
            let out = unsafe { out_ptr.slice() };
            for row in r0..r1 {
                let drow = &dmat.data[row * k..(row + 1) * k];
                let orow = &mut out[row * n..(row + 1) * n];
                for col in 0..n {
                    let (lo, hi) = (csr.ptr[col], csr.ptr[col + 1]);
                    orow[col] = blocked_row_dot(drow, &csr.indices[lo..hi], &csr.data[lo..hi]);
                }
            }
        });
    } else {
        // Output-column partition (serving batches): columns map to CSR
        // rows, so split by nnz — the skewed-row case this exists for.
        pool::parallel_prefix_chunks(n, threads, &csr.ptr, |c0, c1| {
            let out = unsafe { out_ptr.slice() };
            for row in 0..b {
                let drow = &dmat.data[row * k..(row + 1) * k];
                for col in c0..c1 {
                    let (lo, hi) = (csr.ptr[col], csr.ptr[col + 1]);
                    out[row * n + col] =
                        blocked_row_dot(drow, &csr.indices[lo..hi], &csr.data[lo..hi]);
                }
            }
        });
    }
    Tensor::new(vec![b, n], out)
}

/// Blocked column-major SpMM: per CSR row keep an 8×B accumulator plane
/// (L1-resident for serving batch sizes); nonzero `q` axpys into plane
/// row `q % LANES`, then every batch element tree-reduces its lane
/// column. Per output element this sums exactly the lane partials of
/// [`blocked_row_dot`] in the same order — bit-identical to the
/// small-batch path, which is what keeps batch coalescing transparent.
fn dxct_blocked_spmm_threads(dmat: &Tensor, csr: &CsrMatrix, threads: usize) -> Tensor {
    let (b, k) = (dmat.shape[0], dmat.shape[1]);
    let n = csr.rows;
    let dt = transpose_buf(&dmat.data, b, k); // (K, B)
    let mut out_t = vec![0.0f32; n * b]; // (N, B)
    let ptr = pool::SharedMut::new(&mut out_t);
    pool::parallel_prefix_chunks(n, threads, &csr.ptr, |c0, c1| {
        let out_t = unsafe { ptr.slice() };
        let mut plane = vec![0.0f32; LANES * b];
        for col in c0..c1 {
            let (lo, hi) = (csr.ptr[col], csr.ptr[col + 1]);
            for (q, idx) in (lo..hi).enumerate() {
                let j = csr.indices[idx] as usize;
                let prow = &mut plane[(q % LANES) * b..(q % LANES + 1) * b];
                axpy_blocked(prow, &dt[j * b..(j + 1) * b], csr.data[idx]);
            }
            let orow = &mut out_t[col * b..(col + 1) * b];
            for (bi, o) in orow.iter_mut().enumerate() {
                let mut acc = [0.0f32; LANES];
                for (l, a) in acc.iter_mut().enumerate() {
                    *a = plane[l * b + bi];
                }
                *o = pool::tree_reduce(acc);
            }
            plane.fill(0.0);
        }
    });
    Tensor::new(vec![b, n], transpose_buf(&out_t, n, b))
}

/// Minimum batch for the column-major SpMM path (transposes amortize).
pub const SPMM_MIN_BATCH: usize = 8;

/// Scalar-form dxct: the direct port of the Figure-2 OpenCL kernel (one
/// inner product per output element, sequential ascending-index
/// accumulation). The `PROXCOMP_KERNEL=scalar` small-batch path, the
/// property-test oracle, and the §Perf "before" reference in
/// `bench_kernels`.
pub fn dxct_scalar(dmat: &Tensor, csr: &CsrMatrix) -> Tensor {
    dxct_scalar_threads(dmat, csr, pool::max_threads())
}

/// As [`dxct_scalar`] with an explicit worker count: batch-partitioned
/// when the batch saturates the lanes, output-row-partitioned otherwise
/// (single-sample serving). Bit-identical either way.
pub fn dxct_scalar_threads(dmat: &Tensor, csr: &CsrMatrix, threads: usize) -> Tensor {
    let (b, k) = (dmat.shape[0], dmat.shape[1]);
    assert_eq!(k, csr.cols, "dxct: K mismatch ({k} vs {})", csr.cols);
    let n = csr.rows;
    let mut out = vec![0.0f32; b * n];
    let out_ptr = pool::SharedMut::new(&mut out);
    if pool::batch_saturates(b, threads) {
        pool::parallel_chunks(b, threads, |r0, r1| {
            let out = unsafe { out_ptr.slice() };
            for row in r0..r1 {
                let drow = &dmat.data[row * k..(row + 1) * k];
                let orow = &mut out[row * n..(row + 1) * n];
                for col in 0..n {
                    let lo = csr.ptr[col];
                    let hi = csr.ptr[col + 1];
                    let mut acc = 0.0f32;
                    for idx in lo..hi {
                        // Coalesced walk over the CSR row: indices/data are
                        // consecutive, exactly as in the OpenCL kernel.
                        acc += drow[csr.indices[idx] as usize] * csr.data[idx];
                    }
                    orow[col] = acc;
                }
            }
        });
    } else {
        // Output-column partition (each output column walks one CSR row,
        // so columns are independent): serving batches still go wide.
        pool::parallel_chunks(n, threads, |c0, c1| {
            let out = unsafe { out_ptr.slice() };
            for row in 0..b {
                let drow = &dmat.data[row * k..(row + 1) * k];
                for col in c0..c1 {
                    let mut acc = 0.0f32;
                    for idx in csr.ptr[col]..csr.ptr[col + 1] {
                        acc += drow[csr.indices[idx] as usize] * csr.data[idx];
                    }
                    out[row * n + col] = acc;
                }
            }
        });
    }
    Tensor::new(vec![b, n], out)
}

/// Backward: `dmat (B, N) @ csr -> (B, K)` with `csr` shaped (N, K).
/// Paper Figure 3. The OpenCL kernel suffers un-coalesced columnwise
/// walks; on CPU we instead iterate CSR rows (j) and scatter
/// `dmat[row, j] * csr_row_j` into the output row — sequential reads of
/// the CSR arrays and sequential writes within the output row.
pub fn dxc(dmat: &Tensor, csr: &CsrMatrix) -> Tensor {
    dxc_threads(dmat, csr, pool::max_threads())
}

/// As [`dxc`] with an explicit worker count (bit-identical for any
/// `threads` — each output element's contributions arrive in ascending-j
/// order on every path). A scatter kernel: one add per element per
/// nonzero, so the blocked axpy shape changes no bits (see module docs)
/// and there is no kernel-mode dispatch here.
pub fn dxc_threads(dmat: &Tensor, csr: &CsrMatrix, threads: usize) -> Tensor {
    let (b, n) = (dmat.shape[0], dmat.shape[1]);
    assert_eq!(n, csr.rows, "dxc: N mismatch ({n} vs {})", csr.rows);
    let k = csr.cols;
    if b < SPMM_MIN_BATCH {
        return dxc_scalar_threads(dmat, csr, threads);
    }
    // §Perf column-major form (see dxct): gt (N, B), out_t (K, B);
    // each nonzero (j → cidx, v) does out_t[cidx] += v · gt[j], a
    // contiguous length-B axpy. Parallelism over K needs a transposed
    // *scatter*, so instead parallelize over batch-column blocks: every
    // thread owns a disjoint slice of the B dimension across all of
    // out_t, walking the whole CSR once per thread.
    let gt = transpose_buf(&dmat.data, b, n); // (N, B)
    let mut out_t = vec![0.0f32; k * b]; // (K, B)
    let threads = threads.min(b / 4).max(1);
    let ptr = pool::SharedMut::new(&mut out_t);
    pool::parallel_chunks(b, threads, |b0, b1| {
        let out_t = unsafe { ptr.slice() };
        for j in 0..n {
            let grow = &gt[j * b..(j + 1) * b];
            for idx in csr.ptr[j]..csr.ptr[j + 1] {
                let cidx = csr.indices[idx] as usize;
                let v = csr.data[idx];
                axpy_blocked(&mut out_t[cidx * b + b0..cidx * b + b1], &grow[b0..b1], v);
            }
        }
    });
    Tensor::new(vec![b, k], transpose_buf(&out_t, k, b))
}

/// Scalar-form dxc (direct Figure-3 port; small-batch fallback and
/// §Perf "before" reference).
pub fn dxc_scalar(dmat: &Tensor, csr: &CsrMatrix) -> Tensor {
    dxc_scalar_threads(dmat, csr, pool::max_threads())
}

/// As [`dxc_scalar`] with an explicit worker count: threads own batch
/// rows and scatter CSR rows into them, using `min(b, threads)` lanes
/// (inline at b = 1). A transposed column-*gather* arm could go wider
/// for tiny batches, but its counting-sort transpose is serial O(nnz)
/// per call — as much wall-clock as the whole scatter — so without a
/// cached transpose it never pays; and dxc is the backward-pass op, not
/// the serving path, so b = 1 stays serial by design. Each output
/// element accumulates in ascending-j order, bit-identical for any
/// `threads`.
pub fn dxc_scalar_threads(dmat: &Tensor, csr: &CsrMatrix, threads: usize) -> Tensor {
    let (b, n) = (dmat.shape[0], dmat.shape[1]);
    assert_eq!(n, csr.rows, "dxc: N mismatch ({n} vs {})", csr.rows);
    let k = csr.cols;
    let mut out = vec![0.0f32; b * k];
    let out_ptr = pool::SharedMut::new(&mut out);
    pool::parallel_chunks(b, threads, |r0, r1| {
        let out = unsafe { out_ptr.slice() };
        for row in r0..r1 {
            let drow = &dmat.data[row * n..(row + 1) * n];
            let orow = &mut out[row * k..(row + 1) * k];
            for j in 0..n {
                let dv = drow[j];
                if dv == 0.0 {
                    continue;
                }
                for idx in csr.ptr[j]..csr.ptr[j + 1] {
                    orow[csr.indices[idx] as usize] += dv * csr.data[idx];
                }
            }
        }
    });
    Tensor::new(vec![b, k], out)
}

/// `csr (N, K) @ dmat (K, M) -> (N, M)` — the C×D op ViennaCL provides;
/// kept for the `(C×D')' == D×C'` equivalence tests and format benches.
pub fn cxd(csr: &CsrMatrix, dmat: &Tensor) -> Tensor {
    cxd_threads(csr, dmat, pool::max_threads())
}

/// As [`cxd`] with an explicit worker count. Output-row independent, so
/// any count is bit-identical; rows split by nnz (a thread's work is
/// proportional to its rows' nonzeros) and the per-nonzero axpy uses the
/// blocked shape — both bit-preserving (see module docs), so no
/// kernel-mode dispatch.
pub fn cxd_threads(csr: &CsrMatrix, dmat: &Tensor, threads: usize) -> Tensor {
    let (k, m) = (dmat.shape[0], dmat.shape[1]);
    assert_eq!(k, csr.cols, "cxd: K mismatch");
    let n = csr.rows;
    let mut out = vec![0.0f32; n * m];
    let out_ptr = pool::SharedMut::new(&mut out);
    pool::parallel_prefix_chunks(n, threads, &csr.ptr, |r0, r1| {
        let out = unsafe { out_ptr.slice() };
        for row in r0..r1 {
            let orow = &mut out[row * m..(row + 1) * m];
            for idx in csr.ptr[row]..csr.ptr[row + 1] {
                let col = csr.indices[idx] as usize;
                let drow = &dmat.data[col * m..(col + 1) * m];
                axpy_blocked(orow, drow, csr.data[idx]);
            }
        }
    });
    Tensor::new(vec![n, m], out)
}

/// Sparse matrix-vector product `csr (N, K) @ x (K) -> (N)` — used by the
/// format-comparison bench (Bell & Garland's canonical SpMV).
pub fn spmv(csr: &CsrMatrix, x: &[f32]) -> Vec<f32> {
    spmv_threads(csr, x, pool::max_threads())
}

/// As [`spmv`] with an explicit worker count. Dispatches on
/// [`pool::kernel_mode`] like [`dxct_threads`]; within either family
/// output rows are independent and each row keeps its fixed reduction
/// order — bit-identical for any `threads`. The blocked row dot here is
/// the same [`blocked_row_dot`] as dxct's B = 1 path, so
/// `spmv(csr, x) == dxct(x as (1, K), csr)` bit-exactly in both modes.
pub fn spmv_threads(csr: &CsrMatrix, x: &[f32], threads: usize) -> Vec<f32> {
    if pool::kernel_mode() == KernelMode::Scalar {
        return spmv_scalar_threads(csr, x, threads);
    }
    assert_eq!(x.len(), csr.cols);
    let mut out = vec![0.0f32; csr.rows];
    let out_ptr = pool::SharedMut::new(&mut out);
    pool::parallel_prefix_chunks(csr.rows, threads, &csr.ptr, |r0, r1| {
        let out = unsafe { out_ptr.slice() };
        for r in r0..r1 {
            let (lo, hi) = (csr.ptr[r], csr.ptr[r + 1]);
            out[r] = blocked_row_dot(x, &csr.indices[lo..hi], &csr.data[lo..hi]);
        }
    });
    out
}

/// Pre-blocking SpMV (sequential ascending-index row dots): the
/// `PROXCOMP_KERNEL=scalar` family and the bench "before" reference.
pub fn spmv_scalar_threads(csr: &CsrMatrix, x: &[f32], threads: usize) -> Vec<f32> {
    assert_eq!(x.len(), csr.cols);
    let mut out = vec![0.0f32; csr.rows];
    let out_ptr = pool::SharedMut::new(&mut out);
    pool::parallel_chunks(csr.rows, threads, |r0, r1| {
        let out = unsafe { out_ptr.slice() };
        for r in r0..r1 {
            let mut acc = 0.0f32;
            for idx in csr.ptr[r]..csr.ptr[r + 1] {
                acc += csr.data[idx] * x[csr.indices[idx] as usize];
            }
            out[r] = acc;
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_nt};
    use crate::util::rng::Rng;

    fn random_sparse(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> (Vec<f32>, CsrMatrix) {
        let mut dense = vec![0.0f32; rows * cols];
        for v in &mut dense {
            if rng.uniform() < density {
                *v = rng.normal() as f32;
            }
        }
        let csr = CsrMatrix::from_dense(&dense, rows, cols);
        (dense, csr)
    }

    #[test]
    fn dxct_matches_dense() {
        let mut rng = Rng::new(10);
        for &(b, n, k) in &[(1, 1, 1), (3, 5, 7), (16, 50, 80), (4, 500, 800)] {
            let (wd, csr) = random_sparse(&mut rng, n, k, 0.2);
            let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
            let got = dxct(&d, &csr);
            let want = matmul_nt(&d, &Tensor::new(vec![n, k], wd));
            for (g, w) in got.data.iter().zip(&want.data) {
                assert!((g - w).abs() < 1e-3, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn dxc_matches_dense() {
        let mut rng = Rng::new(11);
        for &(b, n, k) in &[(1, 1, 1), (3, 5, 7), (16, 50, 80), (4, 500, 800)] {
            let (wd, csr) = random_sparse(&mut rng, n, k, 0.2);
            let g = Tensor::new(vec![b, n], rng.normal_vec(b * n, 1.0));
            let got = dxc(&g, &csr);
            let want = matmul(&g, &Tensor::new(vec![n, k], wd));
            for (a, w) in got.data.iter().zip(&want.data) {
                assert!((a - w).abs() < 1e-3, "{a} vs {w}");
            }
        }
    }

    #[test]
    fn cxd_matches_dense() {
        let mut rng = Rng::new(12);
        let (wd, csr) = random_sparse(&mut rng, 20, 30, 0.25);
        let d = Tensor::new(vec![30, 8], rng.normal_vec(240, 1.0));
        let got = cxd(&csr, &d);
        let want = matmul(&Tensor::new(vec![20, 30], wd), &d);
        for (a, w) in got.data.iter().zip(&want.data) {
            assert!((a - w).abs() < 1e-3);
        }
    }

    #[test]
    fn paper_workaround_identity() {
        // (C×D')' == D×C' — the ViennaCL workaround the paper describes in
        // Section 3.2; our dxct must equal the transpose composition.
        let mut rng = Rng::new(13);
        let (_, csr) = random_sparse(&mut rng, 12, 18, 0.3);
        let d = Tensor::new(vec![6, 18], rng.normal_vec(108, 1.0));
        // D×C'
        let direct = dxct(&d, &csr);
        // C×D': cxd with D transposed -> (12, 6), then transpose -> (6, 12)
        let mut dt = vec![0.0f32; 18 * 6];
        for i in 0..6 {
            for j in 0..18 {
                dt[j * 6 + i] = d.data[i * 18 + j];
            }
        }
        let cxdt = cxd(&csr, &Tensor::new(vec![18, 6], dt));
        for i in 0..6 {
            for j in 0..12 {
                let a = direct.data[i * 12 + j];
                let b = cxdt.data[j * 6 + i];
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn identity_weight() {
        // W = I (N=K): dxct(d, I) == d and dxc(d, I) == d. Exact in both
        // kernel modes: single-nonzero rows reduce without rounding.
        let n = 9;
        let mut dense = vec![0.0f32; n * n];
        for i in 0..n {
            dense[i * n + i] = 1.0;
        }
        let csr = CsrMatrix::from_dense(&dense, n, n);
        let mut rng = Rng::new(14);
        let d = Tensor::new(vec![4, n], rng.normal_vec(4 * n, 1.0));
        assert_eq!(dxct(&d, &csr).data, d.data);
        assert_eq!(dxc(&d, &csr).data, d.data);
    }

    #[test]
    fn empty_rows_give_zero_columns() {
        let dense = vec![0.0f32; 3 * 4]; // all-zero W (3,4)
        let csr = CsrMatrix::from_dense(&dense, 3, 4);
        let d = Tensor::new(vec![2, 4], vec![1.0; 8]);
        assert_eq!(dxct(&d, &csr).data, vec![0.0; 6]);
    }

    #[test]
    fn spmv_matches() {
        let mut rng = Rng::new(15);
        let (wd, csr) = random_sparse(&mut rng, 25, 40, 0.2);
        let x: Vec<f32> = rng.normal_vec(40, 1.0);
        let got = spmv(&csr, &x);
        for r in 0..25 {
            let want: f32 = (0..40).map(|c| wd[r * 40 + c] * x[c]).sum();
            assert!((got[r] - want).abs() < 1e-4);
        }
    }

    #[test]
    fn blocked_row_dot_matches_lane_emulation() {
        // Pin blocked_row_dot to the documented semantics with an
        // independent re-implementation: lane q % LANES, fixed tree.
        let mut rng = Rng::new(16);
        for nnz in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 100] {
            let dvec: Vec<f32> = rng.normal_vec(128, 1.0);
            let indices: Vec<u32> = (0..nnz).map(|_| (rng.uniform() * 128.0) as u32).collect();
            let data: Vec<f32> = rng.normal_vec(nnz, 1.0);
            let mut acc = [0.0f32; LANES];
            for (q, (i, v)) in indices.iter().zip(&data).enumerate() {
                acc[q % LANES] += v * dvec[*i as usize];
            }
            let want = pool::tree_reduce(acc);
            let got = blocked_row_dot(&dvec, &indices, &data);
            assert_eq!(got.to_bits(), want.to_bits(), "nnz={nnz}");
        }
    }

    #[test]
    fn spmv_equals_dxct_single_row_bitwise() {
        // The serving-path identity promised in the docs, in whichever
        // kernel mode the environment selects.
        let mut rng = Rng::new(17);
        let (_, csr) = random_sparse(&mut rng, 64, 96, 0.1);
        let x: Vec<f32> = rng.normal_vec(96, 1.0);
        let via_spmv = spmv(&csr, &x);
        let via_dxct = dxct(&Tensor::new(vec![1, 96], x), &csr);
        for (a, b) in via_spmv.iter().zip(&via_dxct.data) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
