//! The paper's dense×compressed kernels (Figures 2-3) as CPU kernels.
//!
//! * `dxct` — `result = Dmat @ Cmat'` (forward pass). One inner product
//!   per (row, col) output element, enumerating the nonzeros of `Cmat`
//!   row `col` — a direct port of the Figure-2 OpenCL kernel with the
//!   thread-group/row split replaced by a thread-per-row-chunk split.
//! * `dxc` — `result = Dmat @ Cmat` (backward pass). As in the paper the
//!   access pattern is the transpose-unfriendly one; the CPU port walks
//!   `Cmat` rows and scatters into the output (row-major accumulation),
//!   which is the cache-friendly CPU equivalent.
//! * `cxd` — `Cmat @ Dmat` for completeness (the ViennaCL op the paper
//!   worked around).
//!
//! All kernels parallelize over disjoint output chunks. The partition
//! axis adapts to the shape (`pool::batch_saturates`): multi-row batches
//! split the batch, single-sample serving requests split the weight-row
//! dimension — and every output element keeps a fixed reduction order,
//! so results are bit-identical for any `PROXCOMP_THREADS` setting.

use super::csr::CsrMatrix;
use crate::tensor::Tensor;
use crate::util::pool;

/// Transpose a (r, c) row-major buffer into (c, r).
fn transpose_buf(src: &[f32], r: usize, c: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; r * c];
    // Block the transpose for cache locality.
    const TB: usize = 32;
    for i0 in (0..r).step_by(TB) {
        for j0 in (0..c).step_by(TB) {
            for i in i0..(i0 + TB).min(r) {
                for j in j0..(j0 + TB).min(c) {
                    out[j * r + i] = src[i * c + j];
                }
            }
        }
    }
    out
}

/// Forward: `dmat (B, K) @ csr' -> (B, N)` with `csr` shaped (N, K).
/// Paper Figure 2: "the column memory access of Cmat' equals the row
/// access of Cmat", so each output column walks one CSR row.
///
/// §Perf: for multi-row batches the kernel runs in *column-major SpMM*
/// form — transpose D to (K, B) once, then each CSR nonzero performs a
/// contiguous length-B axpy (`out_t[col] += v · dt[j]`). This walks the
/// CSR arrays exactly once (the scalar form re-walked them per batch
/// row: B× the index traffic) and the unit-stride inner loop
/// auto-vectorizes. Scalar fallback below `SPMM_MIN_BATCH`.
pub fn dxct(dmat: &Tensor, csr: &CsrMatrix) -> Tensor {
    dxct_threads(dmat, csr, pool::max_threads())
}

/// As [`dxct`] with an explicit worker count. Every output element
/// accumulates its CSR row in ascending-index order on both the scalar
/// and the column-major path, so results are bit-identical for any
/// `threads` (and for any batch split — the serving-path guarantee).
pub fn dxct_threads(dmat: &Tensor, csr: &CsrMatrix, threads: usize) -> Tensor {
    let (b, k) = (dmat.shape[0], dmat.shape[1]);
    assert_eq!(k, csr.cols, "dxct: K mismatch ({k} vs {})", csr.cols);
    let n = csr.rows;
    if b < SPMM_MIN_BATCH {
        return dxct_scalar_threads(dmat, csr, threads);
    }
    let dt = transpose_buf(&dmat.data, b, k); // (K, B)
    let mut out_t = vec![0.0f32; n * b]; // (N, B)
    let ptr = pool::SharedMut::new(&mut out_t);
    pool::parallel_chunks(n, threads, |c0, c1| {
        let out_t = unsafe { ptr.slice() };
        for col in c0..c1 {
            let orow = &mut out_t[col * b..(col + 1) * b];
            for idx in csr.ptr[col]..csr.ptr[col + 1] {
                let j = csr.indices[idx] as usize;
                let v = csr.data[idx];
                let drow = &dt[j * b..(j + 1) * b];
                for (o, d) in orow.iter_mut().zip(drow) {
                    *o += v * d;
                }
            }
        }
    });
    Tensor::new(vec![b, n], transpose_buf(&out_t, n, b))
}

/// Minimum batch for the column-major SpMM path (transposes amortize).
pub const SPMM_MIN_BATCH: usize = 8;

/// Scalar-form dxct: the direct port of the Figure-2 OpenCL kernel (one
/// inner product per output element). Used for small batches and as the
/// §Perf "before" reference in `bench_kernels`.
pub fn dxct_scalar(dmat: &Tensor, csr: &CsrMatrix) -> Tensor {
    dxct_scalar_threads(dmat, csr, pool::max_threads())
}

/// As [`dxct_scalar`] with an explicit worker count: batch-partitioned
/// when the batch saturates the lanes, output-row-partitioned otherwise
/// (single-sample serving). Bit-identical either way.
pub fn dxct_scalar_threads(dmat: &Tensor, csr: &CsrMatrix, threads: usize) -> Tensor {
    let (b, k) = (dmat.shape[0], dmat.shape[1]);
    assert_eq!(k, csr.cols, "dxct: K mismatch ({k} vs {})", csr.cols);
    let n = csr.rows;
    let mut out = vec![0.0f32; b * n];
    let out_ptr = pool::SharedMut::new(&mut out);
    if pool::batch_saturates(b, threads) {
        pool::parallel_chunks(b, threads, |r0, r1| {
            let out = unsafe { out_ptr.slice() };
            for row in r0..r1 {
                let drow = &dmat.data[row * k..(row + 1) * k];
                let orow = &mut out[row * n..(row + 1) * n];
                for col in 0..n {
                    let lo = csr.ptr[col];
                    let hi = csr.ptr[col + 1];
                    let mut acc = 0.0f32;
                    for idx in lo..hi {
                        // Coalesced walk over the CSR row: indices/data are
                        // consecutive, exactly as in the OpenCL kernel.
                        acc += drow[csr.indices[idx] as usize] * csr.data[idx];
                    }
                    orow[col] = acc;
                }
            }
        });
    } else {
        // Output-column partition (each output column walks one CSR row,
        // so columns are independent): serving batches still go wide.
        pool::parallel_chunks(n, threads, |c0, c1| {
            let out = unsafe { out_ptr.slice() };
            for row in 0..b {
                let drow = &dmat.data[row * k..(row + 1) * k];
                for col in c0..c1 {
                    let mut acc = 0.0f32;
                    for idx in csr.ptr[col]..csr.ptr[col + 1] {
                        acc += drow[csr.indices[idx] as usize] * csr.data[idx];
                    }
                    out[row * n + col] = acc;
                }
            }
        });
    }
    Tensor::new(vec![b, n], out)
}

/// Backward: `dmat (B, N) @ csr -> (B, K)` with `csr` shaped (N, K).
/// Paper Figure 3. The OpenCL kernel suffers un-coalesced columnwise
/// walks; on CPU we instead iterate CSR rows (j) and scatter
/// `dmat[row, j] * csr_row_j` into the output row — sequential reads of
/// the CSR arrays and sequential writes within the output row.
pub fn dxc(dmat: &Tensor, csr: &CsrMatrix) -> Tensor {
    dxc_threads(dmat, csr, pool::max_threads())
}

/// As [`dxc`] with an explicit worker count (bit-identical for any
/// `threads` — each output element's contributions arrive in ascending-j
/// order on every path).
pub fn dxc_threads(dmat: &Tensor, csr: &CsrMatrix, threads: usize) -> Tensor {
    let (b, n) = (dmat.shape[0], dmat.shape[1]);
    assert_eq!(n, csr.rows, "dxc: N mismatch ({n} vs {})", csr.rows);
    let k = csr.cols;
    if b < SPMM_MIN_BATCH {
        return dxc_scalar_threads(dmat, csr, threads);
    }
    // §Perf column-major form (see dxct): gt (N, B), out_t (K, B);
    // each nonzero (j → cidx, v) does out_t[cidx] += v · gt[j], a
    // contiguous length-B axpy. Parallelism over K needs a transposed
    // *scatter*, so instead parallelize over batch-column blocks: every
    // thread owns a disjoint slice of the B dimension across all of
    // out_t, walking the whole CSR once per thread.
    let gt = transpose_buf(&dmat.data, b, n); // (N, B)
    let mut out_t = vec![0.0f32; k * b]; // (K, B)
    let threads = threads.min(b / 4).max(1);
    let ptr = pool::SharedMut::new(&mut out_t);
    pool::parallel_chunks(b, threads, |b0, b1| {
        let out_t = unsafe { ptr.slice() };
        for j in 0..n {
            let grow = &gt[j * b..(j + 1) * b];
            for idx in csr.ptr[j]..csr.ptr[j + 1] {
                let cidx = csr.indices[idx] as usize;
                let v = csr.data[idx];
                let orow = &mut out_t[cidx * b + b0..cidx * b + b1];
                for (o, g) in orow.iter_mut().zip(&grow[b0..b1]) {
                    *o += v * g;
                }
            }
        }
    });
    Tensor::new(vec![b, k], transpose_buf(&out_t, k, b))
}

/// Scalar-form dxc (direct Figure-3 port; small-batch fallback and
/// §Perf "before" reference).
pub fn dxc_scalar(dmat: &Tensor, csr: &CsrMatrix) -> Tensor {
    dxc_scalar_threads(dmat, csr, pool::max_threads())
}

/// As [`dxc_scalar`] with an explicit worker count: threads own batch
/// rows and scatter CSR rows into them, using `min(b, threads)` lanes
/// (inline at b = 1). A transposed column-*gather* arm could go wider
/// for tiny batches, but its counting-sort transpose is serial O(nnz)
/// per call — as much wall-clock as the whole scatter — so without a
/// cached transpose it never pays; and dxc is the backward-pass op, not
/// the serving path, so b = 1 stays serial by design. Each output
/// element accumulates in ascending-j order, bit-identical for any
/// `threads`.
pub fn dxc_scalar_threads(dmat: &Tensor, csr: &CsrMatrix, threads: usize) -> Tensor {
    let (b, n) = (dmat.shape[0], dmat.shape[1]);
    assert_eq!(n, csr.rows, "dxc: N mismatch ({n} vs {})", csr.rows);
    let k = csr.cols;
    let mut out = vec![0.0f32; b * k];
    let out_ptr = pool::SharedMut::new(&mut out);
    pool::parallel_chunks(b, threads, |r0, r1| {
        let out = unsafe { out_ptr.slice() };
        for row in r0..r1 {
            let drow = &dmat.data[row * n..(row + 1) * n];
            let orow = &mut out[row * k..(row + 1) * k];
            for j in 0..n {
                let dv = drow[j];
                if dv == 0.0 {
                    continue;
                }
                for idx in csr.ptr[j]..csr.ptr[j + 1] {
                    orow[csr.indices[idx] as usize] += dv * csr.data[idx];
                }
            }
        }
    });
    Tensor::new(vec![b, k], out)
}

/// `csr (N, K) @ dmat (K, M) -> (N, M)` — the C×D op ViennaCL provides;
/// kept for the `(C×D')' == D×C'` equivalence tests and format benches.
pub fn cxd(csr: &CsrMatrix, dmat: &Tensor) -> Tensor {
    cxd_threads(csr, dmat, pool::max_threads())
}

/// As [`cxd`] with an explicit worker count (already row-partitioned —
/// the op is output-row independent — so any count is bit-identical).
pub fn cxd_threads(csr: &CsrMatrix, dmat: &Tensor, threads: usize) -> Tensor {
    let (k, m) = (dmat.shape[0], dmat.shape[1]);
    assert_eq!(k, csr.cols, "cxd: K mismatch");
    let n = csr.rows;
    let mut out = vec![0.0f32; n * m];
    let out_ptr = pool::SharedMut::new(&mut out);
    pool::parallel_chunks(n, threads, |r0, r1| {
        let out = unsafe { out_ptr.slice() };
        for row in r0..r1 {
            let orow = &mut out[row * m..(row + 1) * m];
            for idx in csr.ptr[row]..csr.ptr[row + 1] {
                let col = csr.indices[idx] as usize;
                let v = csr.data[idx];
                let drow = &dmat.data[col * m..(col + 1) * m];
                for j in 0..m {
                    orow[j] += v * drow[j];
                }
            }
        }
    });
    Tensor::new(vec![n, m], out)
}

/// Sparse matrix-vector product `csr (N, K) @ x (K) -> (N)` — used by the
/// format-comparison bench (Bell & Garland's canonical SpMV).
pub fn spmv(csr: &CsrMatrix, x: &[f32]) -> Vec<f32> {
    spmv_threads(csr, x, pool::max_threads())
}

/// As [`spmv`] with an explicit worker count: output rows are
/// independent, so the kernel row-partitions and each row accumulates in
/// ascending-index order — bit-identical for any `threads`.
pub fn spmv_threads(csr: &CsrMatrix, x: &[f32], threads: usize) -> Vec<f32> {
    assert_eq!(x.len(), csr.cols);
    let mut out = vec![0.0f32; csr.rows];
    let out_ptr = pool::SharedMut::new(&mut out);
    pool::parallel_chunks(csr.rows, threads, |r0, r1| {
        let out = unsafe { out_ptr.slice() };
        for r in r0..r1 {
            let mut acc = 0.0f32;
            for idx in csr.ptr[r]..csr.ptr[r + 1] {
                acc += csr.data[idx] * x[csr.indices[idx] as usize];
            }
            out[r] = acc;
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{matmul, matmul_nt};
    use crate::util::rng::Rng;

    fn random_sparse(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> (Vec<f32>, CsrMatrix) {
        let mut dense = vec![0.0f32; rows * cols];
        for v in &mut dense {
            if rng.uniform() < density {
                *v = rng.normal() as f32;
            }
        }
        let csr = CsrMatrix::from_dense(&dense, rows, cols);
        (dense, csr)
    }

    #[test]
    fn dxct_matches_dense() {
        let mut rng = Rng::new(10);
        for &(b, n, k) in &[(1, 1, 1), (3, 5, 7), (16, 50, 80), (4, 500, 800)] {
            let (wd, csr) = random_sparse(&mut rng, n, k, 0.2);
            let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
            let got = dxct(&d, &csr);
            let want = matmul_nt(&d, &Tensor::new(vec![n, k], wd));
            for (g, w) in got.data.iter().zip(&want.data) {
                assert!((g - w).abs() < 1e-3, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn dxc_matches_dense() {
        let mut rng = Rng::new(11);
        for &(b, n, k) in &[(1, 1, 1), (3, 5, 7), (16, 50, 80), (4, 500, 800)] {
            let (wd, csr) = random_sparse(&mut rng, n, k, 0.2);
            let g = Tensor::new(vec![b, n], rng.normal_vec(b * n, 1.0));
            let got = dxc(&g, &csr);
            let want = matmul(&g, &Tensor::new(vec![n, k], wd));
            for (a, w) in got.data.iter().zip(&want.data) {
                assert!((a - w).abs() < 1e-3, "{a} vs {w}");
            }
        }
    }

    #[test]
    fn cxd_matches_dense() {
        let mut rng = Rng::new(12);
        let (wd, csr) = random_sparse(&mut rng, 20, 30, 0.25);
        let d = Tensor::new(vec![30, 8], rng.normal_vec(240, 1.0));
        let got = cxd(&csr, &d);
        let want = matmul(&Tensor::new(vec![20, 30], wd), &d);
        for (a, w) in got.data.iter().zip(&want.data) {
            assert!((a - w).abs() < 1e-3);
        }
    }

    #[test]
    fn paper_workaround_identity() {
        // (C×D')' == D×C' — the ViennaCL workaround the paper describes in
        // Section 3.2; our dxct must equal the transpose composition.
        let mut rng = Rng::new(13);
        let (_, csr) = random_sparse(&mut rng, 12, 18, 0.3);
        let d = Tensor::new(vec![6, 18], rng.normal_vec(108, 1.0));
        // D×C'
        let direct = dxct(&d, &csr);
        // C×D': cxd with D transposed -> (12, 6), then transpose -> (6, 12)
        let mut dt = vec![0.0f32; 18 * 6];
        for i in 0..6 {
            for j in 0..18 {
                dt[j * 6 + i] = d.data[i * 18 + j];
            }
        }
        let cxdt = cxd(&csr, &Tensor::new(vec![18, 6], dt));
        for i in 0..6 {
            for j in 0..12 {
                let a = direct.data[i * 12 + j];
                let b = cxdt.data[j * 6 + i];
                assert!((a - b).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn identity_weight() {
        // W = I (N=K): dxct(d, I) == d and dxc(d, I) == d.
        let n = 9;
        let mut dense = vec![0.0f32; n * n];
        for i in 0..n {
            dense[i * n + i] = 1.0;
        }
        let csr = CsrMatrix::from_dense(&dense, n, n);
        let mut rng = Rng::new(14);
        let d = Tensor::new(vec![4, n], rng.normal_vec(4 * n, 1.0));
        assert_eq!(dxct(&d, &csr).data, d.data);
        assert_eq!(dxc(&d, &csr).data, d.data);
    }

    #[test]
    fn empty_rows_give_zero_columns() {
        let dense = vec![0.0f32; 3 * 4]; // all-zero W (3,4)
        let csr = CsrMatrix::from_dense(&dense, 3, 4);
        let d = Tensor::new(vec![2, 4], vec![1.0; 8]);
        assert_eq!(dxct(&d, &csr).data, vec![0.0; 6]);
    }

    #[test]
    fn spmv_matches() {
        let mut rng = Rng::new(15);
        let (wd, csr) = random_sparse(&mut rng, 25, 40, 0.2);
        let x: Vec<f32> = rng.normal_vec(40, 1.0);
        let got = spmv(&csr, &x);
        for r in 0..25 {
            let want: f32 = (0..40).map(|c| wd[r * 40 + c] * x[c]).sum();
            assert!((got[r] - want).abs() < 1e-4);
        }
    }
}
