//! Compression controllers — the paper's methods and baselines.
//!
//! * `spc` — **SpC**: sparse coding with proximal optimizers (the
//!   paper's contribution, Sections 2.1-2.3).
//! * `debias` — retraining with frozen zeros (Section 2.4); used as
//!   SpC(Retrain) and as Pru's retraining phase.
//! * `pruning` — **Pru**: magnitude pruning + retraining (Han et al.
//!   2015).
//! * `mm` — **MM**: learning-compression via the method of multipliers
//!   (Carreira-Perpiñán & Idelbayev 2018).
//!
//! Each controller drives a `Trainer` through artifact steps and returns
//! a `RunResult` with accuracy / compression-rate / per-layer stats.

pub mod debias;
pub mod mm;
pub mod pruning;
pub mod spc;

use crate::coordinator::Trainer;
use crate::metrics::RunResult;
use crate::runtime::Runtime;

/// Assemble a `RunResult` from the trainer's current state.
pub fn finish_run(
    rt: &mut Runtime,
    trainer: &mut Trainer,
    method: &str,
    lambda: f64,
    t0: std::time::Instant,
) -> anyhow::Result<RunResult> {
    let eval = trainer.evaluate(rt)?;
    let rate = trainer.state.params.compression_rate();
    let total = trainer.state.params.total_weights();
    let nnz = total - trainer.state.params.zero_weights();
    let step = trainer.history.next_step();
    trainer.history.record_eval(step, eval.loss, rate, eval.accuracy);
    Ok(RunResult {
        method: method.to_string(),
        model: trainer.entry.name.clone(),
        lambda,
        seed: trainer.seed(),
        accuracy: eval.accuracy,
        loss: eval.loss,
        compression_rate: rate,
        nnz,
        total_weights: total,
        layer_stats: trainer.state.params.layer_stats(),
        history: std::mem::take(&mut trainer.history),
        wall_secs: t0.elapsed().as_secs_f64(),
    })
}
