//! Pru — magnitude pruning with retraining (Han et al. 2015).
//!
//! The baseline the paper compares against in Figures 6-7 / Table 1:
//! (1) train the dense reference model, (2) remove connections whose
//! weight magnitude falls below a threshold (chosen here as the global
//! magnitude quantile hitting `pru_target_rate`), (3) optionally retrain
//! the survivors (`Pru(Retrain)`).

use crate::compress::{debias, finish_run};
use crate::config::RunConfig;
use crate::coordinator::{trainer::StepScalars, Trainer};
use crate::info;
use crate::metrics::RunResult;
use crate::runtime::{Manifest, Runtime};
use crate::sparse::prox::{hard_threshold_inplace, magnitude_quantile};

/// Run Pru end to end. `cfg.steps` trains the dense model; the threshold
/// targets `cfg.pru_target_rate`; `cfg.retrain_steps > 0` = Pru(Retrain).
pub fn run(rt: &mut Runtime, manifest: &Manifest, cfg: &RunConfig) -> anyhow::Result<RunResult> {
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(manifest, cfg)?;
    info!(
        "[Pru] {} dense-train {} steps, target rate {}",
        cfg.model, cfg.steps, cfg.pru_target_rate
    );
    // Phase 1: dense training (λ=0 ⇒ the prox is the identity).
    let scalars = StepScalars { lambda: 0.0, lr: cfg.lr, mu: 0.0 };
    trainer.run_steps(rt, cfg.optimizer.step_name(), cfg.steps, scalars, super::spc::RECORD_EVERY)?;

    // Phase 2: magnitude pruning at the global quantile.
    prune_to_rate(&mut trainer, cfg.pru_target_rate);
    let rate = trainer.state.params.compression_rate();
    info!("[Pru] pruned to rate {rate:.4}");

    // Phase 3: optional retraining of the survivors.
    let mut method = "Pru".to_string();
    if cfg.retrain_steps > 0 {
        debias::retrain(rt, &mut trainer, cfg.retrain_steps, cfg.retrain_lr)?;
        method = "Pru(Retrain)".to_string();
    }
    let result = finish_run(rt, &mut trainer, &method, cfg.pru_target_rate, t0)?;
    info!(
        "[Pru] done: acc {:.4} rate {:.4} in {:.1}s",
        result.accuracy, result.compression_rate, result.wall_secs
    );
    Ok(result)
}

/// Hard-threshold all prunable leaves at the global magnitude quantile
/// that achieves `target_rate` zeros.
pub fn prune_to_rate(trainer: &mut Trainer, target_rate: f64) {
    let params = &mut trainer.state.params;
    // Pool all prunable magnitudes for a global threshold (Han et al. use
    // a per-layer quality parameter; global quantile reaches the same
    // target rate without per-layer tuning).
    let mut pooled: Vec<f32> = Vec::new();
    for (spec, values) in params.specs.iter().zip(&params.values) {
        if spec.prunable {
            pooled.extend_from_slice(values);
        }
    }
    let thresh = magnitude_quantile(&pooled, target_rate);
    for (spec, values) in params.specs.iter().zip(params.values.iter_mut()) {
        if spec.prunable {
            hard_threshold_inplace(values, thresh);
        }
    }
}
