//! SpC — sparse coding with proximal optimizers (the paper's method).
//!
//! Training **starts from random weights** (no pre-trained model — the
//! paper's headline advantage over Pru/MM) and applies the proximal
//! operator inside every update via the Prox-ADAM / Prox-RMSProp
//! artifacts. Optionally followed by debiasing (SpC(Retrain)).

use crate::compress::{debias, finish_run};
use crate::config::RunConfig;
use crate::coordinator::{trainer::StepScalars, Trainer};
use crate::info;
use crate::metrics::RunResult;
use crate::runtime::{Manifest, Runtime};

/// Steps between history records during training.
pub const RECORD_EVERY: usize = 10;

/// Run SpC end to end per `cfg`; `cfg.retrain_steps > 0` adds debiasing.
pub fn run(rt: &mut Runtime, manifest: &Manifest, cfg: &RunConfig) -> anyhow::Result<RunResult> {
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(manifest, cfg)?;
    let step_name = cfg.optimizer.step_name();
    info!(
        "[SpC] {} λ={} lr={} steps={} seed={} ({})",
        cfg.model, cfg.lambda, cfg.lr, cfg.steps, cfg.seed, step_name
    );
    let scalars = StepScalars { lambda: cfg.lambda, lr: cfg.lr, mu: 0.0 };
    run_with_evals(rt, &mut trainer, step_name, cfg.steps, scalars, cfg.eval_every)?;

    let mut method = "SpC".to_string();
    if cfg.retrain_steps > 0 {
        debias::retrain(rt, &mut trainer, cfg.retrain_steps, cfg.retrain_lr)?;
        method = "SpC(Retrain)".to_string();
    }
    let result = finish_run(rt, &mut trainer, &method, cfg.lambda as f64, t0)?;
    info!(
        "[SpC] done: acc {:.4} rate {:.4} ({:.0}×) in {:.1}s",
        result.accuracy,
        result.compression_rate,
        result.times_factor(),
        result.wall_secs
    );
    Ok(result)
}

/// Train with periodic full evaluations recorded into history (the
/// Figure-8 convergence curves need both loss and test accuracy).
pub fn run_with_evals(
    rt: &mut Runtime,
    trainer: &mut Trainer,
    step_name: &str,
    steps: usize,
    scalars: StepScalars,
    eval_every: usize,
) -> anyhow::Result<()> {
    let mut done = 0;
    while done < steps {
        let chunk = if eval_every > 0 {
            eval_every.min(steps - done)
        } else {
            steps - done
        };
        let loss = trainer.run_steps(rt, step_name, chunk, scalars, RECORD_EVERY)?;
        done += chunk;
        if eval_every > 0 {
            let eval = trainer.evaluate(rt)?;
            let rate = trainer.state.params.compression_rate();
            let step = trainer.history.next_step();
            trainer.history.record_eval(step, eval.loss, rate, eval.accuracy);
            info!(
                "  step {done}/{steps}: loss {loss:.4} acc {:.4} rate {:.4}",
                eval.accuracy, rate
            );
        }
    }
    Ok(())
}
