//! Debiasing / retraining (paper Section 2.4).
//!
//! "Train the weights again without any regularization, starting from the
//! previously trained weight values, while excluding the zero-valued
//! weights from training." Implemented with the `train_masked` artifact:
//! 0/1 masks freeze pruned weights at exactly zero; the optimizer is a
//! fresh ADAM (moments reset — the sparse phase's moments belong to a
//! different objective).

use crate::coordinator::{trainer::StepScalars, Trainer};
use crate::info;
use crate::runtime::Runtime;

/// Retrain the surviving weights for `steps` steps at `lr`.
pub fn retrain(
    rt: &mut Runtime,
    trainer: &mut Trainer,
    steps: usize,
    lr: f32,
) -> anyhow::Result<()> {
    let rate_before = trainer.state.params.compression_rate();
    trainer.state.masks = Some(trainer.state.params.nonzero_masks());
    trainer.state.reset_optimizer();
    info!("[debias] retraining {steps} steps at lr {lr} (rate {rate_before:.4})");
    let scalars = StepScalars { lambda: 0.0, lr, mu: 0.0 };
    trainer.run_steps(rt, "train_masked", steps, scalars, super::spc::RECORD_EVERY)?;
    // Invariant: masked training never resurrects zeros.
    let rate_after = trainer.state.params.compression_rate();
    anyhow::ensure!(
        rate_after >= rate_before - 1e-12,
        "debias resurrected zeros: {rate_before} -> {rate_after}"
    );
    Ok(())
}
