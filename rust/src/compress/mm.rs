//! MM — learning-compression via the method of multipliers
//! (Carreira-Perpiñán & Idelbayev 2018), the paper's Section 4.4 baseline.
//!
//! Solves  min L(w) + α·Ψ(θ)  s.t.  w = θ  via the augmented Lagrangian
//! `L(w) + μ/2‖w−θ‖² − λᵀ(w−θ) + α·Ψ(θ)` (paper Eq. 3-4), alternating:
//!
//! * **L-step** — minimize over `w`: SGD-momentum steps on
//!   `L(w) + μ/2‖w−θ−λ/μ‖²` (the `train_mm` artifact; the quadratic pull
//!   is differentiated in-graph).
//! * **C-step** — minimize over `θ`, closed form. Two Ψ choices, as in
//!   Carreira-Perpiñán & Idelbayev 2018: the **ℓ0-constraint** form
//!   (`‖θ‖₀ ≤ κ` ⇒ θ = top-κ magnitudes of `w − λ/μ`, the reference
//!   paper's *pruning* formulation and our default — it pins the final
//!   compression rate exactly, like Table 2's fixed rates) and the
//!   **ℓ1-penalty** form (`θ = prox_{(α/μ)‖·‖₁}(w − λ/μ)`, selected by
//!   `MmPenalty::L1`).
//! * **multiplier ascent** — `λ ← λ − μ(w − θ)`, then `μ ← μ·growth`.
//!
//! As in the paper's comparison: MM **requires a pre-trained model** (we
//! train one dense first, mirroring "MM is allowed to start from the
//! state-of-the-art pretrained models"), needs ~2× the training memory
//! (w, ∇L, θ, λ live simultaneously), compresses only every
//! `compress_every` steps, and its convergence is sensitive to the μ
//! schedule — all three claimed drawbacks are observable in this
//! implementation and exercised by the Figure-8/Table-2 bench.

use crate::compress::finish_run;
use crate::config::RunConfig;
use crate::coordinator::{trainer::StepScalars, Trainer};
use crate::info;
use crate::metrics::RunResult;
use crate::runtime::{Manifest, ParamBundle, Runtime};
use crate::sparse::dispatch::{DynSparseMatrix, SparseFormat};
use crate::sparse::prox::{magnitude_quantile, soft_threshold_inplace};

/// C-step regularizer choice (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MmPenalty {
    /// ‖θ‖₀ ≤ κ with κ from `cfg.pru_target_rate` — the reference
    /// pruning-LC formulation (default).
    L0,
    /// α‖θ‖₁ with α = `cfg.lambda`.
    L1,
}

/// ADAM rate for the pretraining phase (fixed; `cfg.lr` is the L-step's).
pub const PRETRAIN_ADAM_LR: f32 = 1e-3;

/// Run the MM baseline. `cfg.steps` is split: the first `steps/2` train
/// the dense (pretrained) model, the rest run the MM loop; α = cfg.lambda.
pub fn run(rt: &mut Runtime, manifest: &Manifest, cfg: &RunConfig) -> anyhow::Result<RunResult> {
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(manifest, cfg)?;
    let pretrain_steps = cfg.steps / 2;
    let mm_steps = cfg.steps - pretrain_steps;
    info!(
        "[MM] {}: pretrain {} steps, MM {} steps (μ0={} ×{} every {})",
        cfg.model, pretrain_steps, mm_steps, cfg.mm_mu0, cfg.mm_mu_growth, cfg.mm_compress_every
    );

    // MM needs a pretrained model (paper Table 2, "Pretrained Model:
    // Required") — train one dense with plain ADAM (λ=0). The pretrain
    // rate is the standard ADAM 1e-3, independent of `cfg.lr`, which is
    // the SGD-momentum rate of the L-step.
    let scalars = StepScalars { lambda: 0.0, lr: PRETRAIN_ADAM_LR, mu: 0.0 };
    trainer.run_steps(rt, "train_prox_adam", pretrain_steps, scalars, super::spc::RECORD_EVERY)?;

    run_mm_phase(rt, &mut trainer, cfg, mm_steps, cfg.eval_every)?;

    // Deployment storage: each compressed leaf in the format the
    // dispatch cost model picks for its structure (usually CSR for MM's
    // unstructured ℓ0 projections — logged so exceptions are visible).
    for (layer, fmt, bytes) in deployed_formats(&trainer.state.params) {
        info!("[MM] deploy {layer}: {} ({:.1} KB)", fmt.name(), bytes as f64 / 1024.0);
    }

    let result = finish_run(rt, &mut trainer, "MM", cfg.lambda as f64, t0)?;
    info!(
        "[MM] done: acc {:.4} rate {:.4} in {:.1}s",
        result.accuracy, result.compression_rate, result.wall_secs
    );
    Ok(result)
}

/// Per-leaf (layer, chosen format, storage bytes) for the deployed MM
/// iterate — the compressed model's storage plan, via `sparse::dispatch`.
pub fn deployed_formats(params: &ParamBundle) -> Vec<(String, SparseFormat, usize)> {
    params
        .specs
        .iter()
        .zip(&params.values)
        .filter(|(s, _)| s.prunable)
        .filter_map(|(s, v)| {
            let (rows, cols) = crate::checkpoint::matrix_view(s)?; // not 2-D-viewable → skip
            if rows == 0 {
                return None;
            }
            let m = DynSparseMatrix::from_dense(v, rows, cols);
            Some((s.layer.clone(), m.format(), m.storage_bytes()))
        })
        .collect()
}

/// The MM loop proper, starting from the trainer's current (pretrained)
/// parameters. Exposed separately so benches can time it against SpC.
pub fn run_mm_phase(
    rt: &mut Runtime,
    trainer: &mut Trainer,
    cfg: &RunConfig,
    steps: usize,
    eval_every: usize,
) -> anyhow::Result<()> {
    run_mm_phase_with(rt, trainer, cfg, steps, eval_every, MmPenalty::L0)
}

/// As `run_mm_phase` but with an explicit C-step penalty choice.
pub fn run_mm_phase_with(
    rt: &mut Runtime,
    trainer: &mut Trainer,
    cfg: &RunConfig,
    steps: usize,
    eval_every: usize,
    penalty: MmPenalty,
) -> anyhow::Result<()> {
    let alpha = cfg.lambda;
    let target_rate = cfg.pru_target_rate;
    let mut mu = cfg.mm_mu0;

    // θ ← C-step(w), λ ← 0: initialization.
    let mut theta = trainer.state.params.clone();
    c_step(&mut theta, &trainer.state.params, None, alpha, mu, penalty, target_rate);
    trainer.state.theta = Some(theta);
    trainer.state.lagrange = Some(ParamBundle::zeros_like(&trainer.state.params.specs));
    // Fresh momentum for the L-step optimizer (reuses the opt_m slot).
    trainer.state.reset_optimizer();

    let mut done = 0;
    while done < steps {
        let chunk = cfg.mm_compress_every.min(steps - done);
        // L-step rate decays with μ (the LC reference schedule): the
        // quadratic term's curvature is μ, so a fixed lr diverges once
        // lr·μ ≳ 1 — exactly the μ-schedule sensitivity the paper
        // criticizes MM for (Section 4.4, benefit #3).
        let lr = cfg.lr / (1.0 + cfg.lr * mu);
        let scalars = StepScalars { lambda: 0.0, lr, mu };
        let loss = trainer.run_steps(rt, "train_mm", chunk, scalars, super::spc::RECORD_EVERY)?;
        done += chunk;

        // C-step + multiplier ascent + μ schedule (every compress_every).
        let params = trainer.state.params.clone();
        let lag = trainer.state.lagrange.as_ref().unwrap().clone();
        let theta = trainer.state.theta.as_mut().unwrap();
        c_step(theta, &params, Some(&lag), alpha, mu, penalty, target_rate);
        {
            let lag = trainer.state.lagrange.as_mut().unwrap();
            for i in 0..params.values.len() {
                if !params.specs[i].prunable {
                    continue;
                }
                let th = &trainer.state.theta.as_ref().unwrap().values[i];
                for j in 0..lag.values[i].len() {
                    lag.values[i][j] -= mu * (params.values[i][j] - th[j]);
                }
            }
        }
        mu *= cfg.mm_mu_growth;
        // μ changed ⇒ the L-step objective changed; stale momentum from
        // the previous subproblem destabilizes the next one.
        trainer.state.reset_optimizer();

        if eval_every > 0 {
            // Report the *compressed* iterate θ (what MM would deploy).
            let dense = std::mem::replace(&mut trainer.state.params, trainer.state.theta.clone().unwrap());
            let eval = trainer.evaluate(rt)?;
            let rate = trainer.state.params.compression_rate();
            trainer.state.params = dense;
            let step = trainer.history.next_step();
            trainer.history.record_eval(step, eval.loss, rate, eval.accuracy);
            info!(
                "  MM step {done}/{steps}: loss {loss:.4} θ-acc {:.4} θ-rate {:.4} μ {mu:.3e}",
                eval.accuracy, rate
            );
        }
    }

    // Deploy the compressed iterate: w ← θ (at convergence w ≈ θ).
    trainer.state.params = trainer.state.theta.take().unwrap();
    trainer.state.lagrange = None;
    Ok(())
}

/// C-step on prunable leaves; non-prunable leaves copy w (no Ψ cost).
///
/// θ_base = w − λ/μ, then either the ℓ1 prox (soft threshold α/μ) or the
/// ℓ0 projection (keep the global top-κ magnitudes; κ from target_rate).
fn c_step(
    theta: &mut ParamBundle,
    w: &ParamBundle,
    lag: Option<&ParamBundle>,
    alpha: f32,
    mu: f32,
    penalty: MmPenalty,
    target_rate: f64,
) {
    // θ_base = w − λ/μ.
    for i in 0..w.values.len() {
        let wv = &w.values[i];
        let tv = &mut theta.values[i];
        if !w.specs[i].prunable {
            tv.copy_from_slice(wv);
            continue;
        }
        match lag {
            Some(l) => {
                let lv = &l.values[i];
                for j in 0..wv.len() {
                    tv[j] = wv[j] - lv[j] / mu;
                }
            }
            None => tv.copy_from_slice(wv),
        }
    }
    match penalty {
        MmPenalty::L1 => {
            for i in 0..w.values.len() {
                if w.specs[i].prunable {
                    soft_threshold_inplace(&mut theta.values[i], alpha / mu);
                }
            }
        }
        MmPenalty::L0 => {
            // Global top-κ projection across all prunable leaves.
            let mut pooled: Vec<f32> = Vec::new();
            for i in 0..w.values.len() {
                if w.specs[i].prunable {
                    pooled.extend_from_slice(&theta.values[i]);
                }
            }
            // Strict `<`: the element AT the quantile survives, so κ is
            // hit exactly for distinct magnitudes.
            let thresh = magnitude_quantile(&pooled, target_rate);
            for i in 0..w.values.len() {
                if w.specs[i].prunable {
                    for v in theta.values[i].iter_mut() {
                        if v.abs() < thresh {
                            *v = 0.0;
                        }
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamSpec;

    fn bundle(vals: Vec<f32>, prunable: bool) -> ParamBundle {
        let spec = ParamSpec {
            name: "w".into(),
            kind: "fc_w".into(),
            shape: vec![vals.len()],
            prunable,
            layer: "fc".into(),
        };
        ParamBundle { specs: vec![spec], values: vec![vals] }
    }

    #[test]
    fn c_step_l1_soft_thresholds() {
        let w = bundle(vec![1.0, -0.05, 0.3], true);
        let mut theta = bundle(vec![0.0; 3], true);
        // α/μ = 0.1
        c_step(&mut theta, &w, None, 0.1, 1.0, MmPenalty::L1, 0.0);
        let got = &theta.values[0];
        assert!((got[0] - 0.9).abs() < 1e-6);
        assert_eq!(got[1], 0.0);
        assert!((got[2] - 0.2).abs() < 1e-6);
    }

    #[test]
    fn c_step_with_multipliers_shifts() {
        let w = bundle(vec![1.0], true);
        let lag = bundle(vec![0.5], true);
        let mut theta = bundle(vec![0.0], true);
        // w − λ/μ = 1 − 0.5/1 = 0.5; prox_{0.1}(0.5) = 0.4
        c_step(&mut theta, &w, Some(&lag), 0.1, 1.0, MmPenalty::L1, 0.0);
        assert!((theta.values[0][0] - 0.4).abs() < 1e-6);
    }

    #[test]
    fn c_step_nonprunable_copies() {
        let w = bundle(vec![0.01, -0.02], false);
        let mut theta = bundle(vec![9.0, 9.0], false);
        c_step(&mut theta, &w, None, 100.0, 1.0, MmPenalty::L1, 0.0);
        assert_eq!(theta.values[0], vec![0.01, -0.02]); // no shrink
    }

    #[test]
    fn higher_mu_shrinks_less_l1() {
        // α/μ decreases as μ grows: the ℓ1 C-step anneals its shrinkage.
        let w = bundle(vec![0.5], true);
        let mut t1 = bundle(vec![0.0], true);
        let mut t2 = bundle(vec![0.0], true);
        c_step(&mut t1, &w, None, 0.2, 1.0, MmPenalty::L1, 0.0); // thresh 0.2
        c_step(&mut t2, &w, None, 0.2, 10.0, MmPenalty::L1, 0.0); // thresh 0.02
        assert!(t2.values[0][0] > t1.values[0][0]);
    }

    #[test]
    fn deployed_formats_reports_prunable_2d_leaves() {
        let spec2d = ParamSpec {
            name: "fc1_w".into(),
            kind: "fc_w".into(),
            shape: vec![8, 16],
            prunable: true,
            layer: "fc1".into(),
        };
        let bias = ParamSpec {
            name: "fc1_b".into(),
            kind: "fc_b".into(),
            shape: vec![8],
            prunable: false,
            layer: "fc1".into(),
        };
        let mut w = vec![0.0f32; 8 * 16];
        w[3] = 1.0;
        w[40] = -2.0;
        let params = ParamBundle {
            specs: vec![spec2d, bias],
            values: vec![w, vec![0.0; 8]],
        };
        let report = deployed_formats(&params);
        assert_eq!(report.len(), 1, "bias leaves are skipped");
        let (layer, fmt, bytes) = &report[0];
        assert_eq!(layer, "fc1");
        // Unstructured scatter → the paper's production format.
        assert_eq!(*fmt, SparseFormat::Csr);
        assert!(*bytes > 0 && *bytes < 8 * 16 * 4);
    }

    #[test]
    fn c_step_l0_hits_target_rate_without_shrinking() {
        let w = bundle(vec![0.5, -0.1, 0.05, 0.9, -0.02, 0.3, 0.01, -0.7], true);
        let mut theta = bundle(vec![0.0; 8], true);
        c_step(&mut theta, &w, None, 0.0, 1.0, MmPenalty::L0, 0.5);
        let got = &theta.values[0];
        let zeros = got.iter().filter(|&&v| v == 0.0).count();
        assert_eq!(zeros, 4, "{got:?}");
        // Survivors keep their exact magnitudes (projection, not prox).
        assert_eq!(got[0], 0.5);
        assert_eq!(got[3], 0.9);
        assert_eq!(got[5], 0.3); // the element at the quantile survives
        assert_eq!(got[7], -0.7);
    }
}
