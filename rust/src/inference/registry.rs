//! Multi-model fleet registry: route requests by model id across
//! per-model [`BatchServer`] pools, under one byte-accounted memory
//! budget.
//!
//! The paper targets embedded deployments, where the interesting serving
//! problem is rarely one model — it is a *fleet* of compressed models
//! (per-task heads, A/B variants, quantized and sparse flavours of the
//! same net) sharing a device whose memory cannot hold all of them at
//! once. Compression is exactly what makes that viable: a 30×-compressed
//! checkpoint is cheap to keep warm and cheap to re-deploy. The
//! [`ModelRegistry`] leans on that:
//!
//! - Models are registered as [`ModelSpec`]s — an id, a deterministic
//!   [`EngineFactory`] that (re)builds the engine from its checkpoint,
//!   and the coalescing [`BatchConfig`] for its pool.
//! - Loading is **lazy**: the first request for a model invokes its
//!   factory, accounts the engine's exact byte footprint
//!   (`Engine::model_size_bytes`), and spins up a [`BatchServer`].
//! - A non-zero [`RegistryConfig::memory_budget_bytes`] caps the sum of
//!   resident-model bytes. Loading past the budget evicts the
//!   least-recently-used *other* model first (the model just touched is
//!   never its own victim); a single model larger than the whole budget
//!   still serves — the budget bounds the fleet, not one model.
//! - Eviction is **graceful**: the victim's pool is drained
//!   ([`BatchServer::shutdown`] answers everything already queued), so
//!   an eviction in the middle of a traffic burst drops zero requests.
//!   A submitter that raced the eviction simply re-resolves, which
//!   hot-reloads the model through its factory — deterministically, so
//!   logits before eviction and after reload are bit-identical.
//! - [`ModelRegistry::add_model`] / [`ModelRegistry::remove_model`] are
//!   atomic with respect to in-flight traffic: the registry lock covers
//!   only map surgery; draining happens outside it.
//!
//! Stats semantics: per-model [`crate::metrics::ServingStats`] snapshots
//! come from the *current* server incarnation; request/batch counts from
//! evicted incarnations are retired into running totals (so
//! `requests_total` never goes backwards), but latency percentiles reset
//! on reload — they describe the live pool, which is what an operator
//! watches. The aggregate roll-up sums counts and computes percentile
//! fields from the **bucketwise-merged** latency histogram of resident
//! pools — true fleet percentiles, since every pool shares one bucket
//! layout. Only if a layout mismatch ever appears does it fall back to
//! the old per-pool max ceiling.

use std::collections::BTreeMap;
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};

use crate::inference::server::{BatchConfig, BatchServer, Pending};
use crate::inference::Engine;
use crate::metrics::{LatencyHistogram, ServingStats};
use crate::telemetry;
use crate::util::json::Json;

/// Builds (or rebuilds, after eviction) a model's engine. Factories must
/// be deterministic — a hot-reloaded model is expected to answer
/// bit-identically to its pre-eviction incarnation — and cheap enough to
/// call on a request path (they gate the *first* request after a load,
/// not every request).
pub type EngineFactory = Arc<dyn Fn() -> anyhow::Result<Arc<Engine>> + Send + Sync>;

/// Everything the registry needs to serve one model.
pub struct ModelSpec {
    /// Routing key carried by wire-v2 `INFER_MODEL` frames. At most 255
    /// bytes (the wire encodes its length in one byte).
    pub id: String,
    pub factory: EngineFactory,
    /// Coalescing knobs for this model's pool (the batch-statistics pin
    /// in [`BatchServer::start`] still applies on top).
    pub batch: BatchConfig,
}

impl ModelSpec {
    pub fn new(id: &str, factory: EngineFactory, batch: BatchConfig) -> ModelSpec {
        ModelSpec { id: id.to_string(), factory, batch }
    }
}

/// Registry-wide knobs.
#[derive(Debug, Clone, Default)]
pub struct RegistryConfig {
    /// Ceiling on the summed byte footprint of resident engines; 0 means
    /// unlimited. Enforced by LRU eviction at load time.
    pub memory_budget_bytes: usize,
    /// Where versionless (wire-v1 `INFER`) requests route. When unset
    /// and exactly one model is registered, that model is the default.
    pub default_model: Option<String>,
}

/// Why a submission was refused. The wire front-end maps these onto its
/// error taxonomy (`unknown-model` is recoverable; the rest follow the
/// single-model semantics).
#[derive(Debug)]
pub enum SubmitError {
    /// No registered model under this id (`"(default)"` when a
    /// versionless request arrived and no default is configured).
    UnknownModel(String),
    /// The model's factory failed — checkpoint missing, decode error.
    LoadFailed(String),
    /// The registry is shutting down.
    ShuttingDown,
    /// The resolved pool refused the sample (wrong sample length, or a
    /// shutdown race that outlasted the retry budget).
    Rejected(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::UnknownModel(id) => write!(f, "unknown model {id:?}"),
            SubmitError::LoadFailed(msg) => write!(f, "model load failed: {msg}"),
            SubmitError::ShuttingDown => write!(f, "registry is shutting down"),
            SubmitError::Rejected(msg) => write!(f, "request rejected: {msg}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Per-model bookkeeping. `server` is `Some` while resident; counts from
/// evicted incarnations accumulate in the `retired_*` fields.
struct ModelState {
    spec: ModelSpec,
    server: Option<Arc<BatchServer>>,
    bytes: usize,
    last_used: u64,
    loads: u64,
    evictions: u64,
    retired_requests: usize,
    retired_batches: usize,
}

struct Inner {
    /// BTreeMap so ids iterate in a stable order (stats JSON, victim
    /// scans) regardless of insertion history.
    models: BTreeMap<String, ModelState>,
    /// Logical LRU clock: bumped per successful resolve, copied into the
    /// touched model's `last_used`.
    clock: u64,
    resident_bytes: usize,
    shutting_down: bool,
}

/// A detached victim: map surgery already done under the lock, draining
/// still owed (outside it).
type DrainTicket = (String, Arc<BatchServer>);

/// Multi-model serving registry. All methods take `&self`; share it with
/// connection handlers via `Arc`.
pub struct ModelRegistry {
    cfg: RegistryConfig,
    inner: Mutex<Inner>,
}

impl ModelRegistry {
    pub fn new(cfg: RegistryConfig) -> ModelRegistry {
        ModelRegistry {
            cfg,
            inner: Mutex::new(Inner {
                models: BTreeMap::new(),
                clock: 0,
                resident_bytes: 0,
                shutting_down: false,
            }),
        }
    }

    /// Build a registry and register `specs` in order.
    pub fn with_models(cfg: RegistryConfig, specs: Vec<ModelSpec>) -> anyhow::Result<ModelRegistry> {
        let reg = ModelRegistry::new(cfg);
        for spec in specs {
            reg.add_model(spec)?;
        }
        Ok(reg)
    }

    /// Wrap one already-built engine as a single-model registry — the
    /// adapter the single-model `NetServer::start` front-end uses.
    pub fn single(id: &str, engine: Arc<Engine>, batch: BatchConfig) -> ModelRegistry {
        let reg = ModelRegistry::new(RegistryConfig {
            memory_budget_bytes: 0,
            default_model: Some(id.to_string()),
        });
        reg.add_model(ModelSpec::new(id, Arc::new(move || Ok(Arc::clone(&engine))), batch))
            .expect("a fresh registry accepts its first model");
        reg
    }

    /// Recover the inner lock from poisoning: registry state is counters
    /// and maps — worst case a half-applied bookkeeping update, never
    /// unsafety — and serving must outlive one panicking handler.
    fn lock(&self) -> MutexGuard<'_, Inner> {
        self.inner.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Register a model (lazily loaded on first use). Fails on duplicate
    /// ids, empty or over-long (> 255 byte) ids, and empty input shapes.
    pub fn add_model(&self, spec: ModelSpec) -> anyhow::Result<()> {
        anyhow::ensure!(!spec.id.is_empty(), "model id must be non-empty");
        anyhow::ensure!(
            spec.id.len() <= u8::MAX as usize,
            "model id {:?} is {} bytes; the wire caps ids at 255",
            spec.id,
            spec.id.len()
        );
        anyhow::ensure!(
            spec.batch.sample_len() > 0,
            "model {:?} has an empty input shape {:?}",
            spec.id,
            spec.batch.input_shape
        );
        let mut guard = self.lock();
        anyhow::ensure!(!guard.shutting_down, "registry is shutting down");
        anyhow::ensure!(
            !guard.models.contains_key(&spec.id),
            "model {:?} is already registered",
            spec.id
        );
        let id = spec.id.clone();
        guard.models.insert(
            id,
            ModelState {
                spec,
                server: None,
                bytes: 0,
                last_used: 0,
                loads: 0,
                evictions: 0,
                retired_requests: 0,
                retired_batches: 0,
            },
        );
        Ok(())
    }

    /// Deregister a model. Its pool (if resident) is drained — queued
    /// requests are still answered — and its stats disappear with it.
    pub fn remove_model(&self, id: &str) -> anyhow::Result<()> {
        let state = {
            let mut guard = self.lock();
            let inner = &mut *guard;
            let state = inner
                .models
                .remove(id)
                .ok_or_else(|| anyhow::anyhow!("unknown model {id:?}"))?;
            if state.server.is_some() {
                inner.resident_bytes = inner.resident_bytes.saturating_sub(state.bytes);
            }
            state
        };
        if let Some(server) = state.server {
            server.shutdown();
        }
        Ok(())
    }

    /// Evict a model's resident engine without deregistering it (the
    /// next request reloads through the factory). Returns whether it was
    /// resident; errors on unknown ids.
    pub fn evict(&self, id: &str) -> anyhow::Result<bool> {
        let victim = {
            let mut guard = self.lock();
            let inner = &mut *guard;
            anyhow::ensure!(inner.models.contains_key(id), "unknown model {id:?}");
            if inner.models[id].server.is_some() {
                Some(Self::detach(inner, id))
            } else {
                None
            }
        };
        match victim {
            Some(v) => {
                self.drain(vec![v]);
                Ok(true)
            }
            None => Ok(false),
        }
    }

    /// Take a resident model's server out of the map (under the lock);
    /// the caller owes [`ModelRegistry::drain`] on the returned ticket.
    fn detach(inner: &mut Inner, id: &str) -> DrainTicket {
        let state = inner.models.get_mut(id).expect("detach only on present models");
        let server = state.server.take().expect("detach only on resident models");
        inner.resident_bytes = inner.resident_bytes.saturating_sub(state.bytes);
        state.evictions += 1;
        if telemetry::trace_enabled() {
            telemetry::event_label("registry.evict", 0, id, &[("bytes", state.bytes as f64)]);
        }
        (id.to_string(), server)
    }

    /// Drain detached victims outside the lock: shutdown answers every
    /// queued request, then the incarnation's counts are retired.
    fn drain(&self, victims: Vec<DrainTicket>) {
        for (id, server) in victims {
            server.shutdown();
            let s = server.stats();
            let mut guard = self.lock();
            if let Some(state) = guard.models.get_mut(&id) {
                state.retired_requests += s.requests;
                state.retired_batches += s.batches;
            }
        }
    }

    /// The id versionless requests route to: the configured default, or
    /// the only model when exactly one is registered.
    fn default_id(&self, inner: &Inner) -> Option<String> {
        self.cfg.default_model.clone().or_else(|| {
            if inner.models.len() == 1 {
                inner.models.keys().next().cloned()
            } else {
                None
            }
        })
    }

    pub fn default_model(&self) -> Option<String> {
        let guard = self.lock();
        self.default_id(&guard)
    }

    /// Registered ids in stable (sorted) order.
    pub fn model_ids(&self) -> Vec<String> {
        self.lock().models.keys().cloned().collect()
    }

    /// Ids currently holding a resident engine.
    pub fn resident_models(&self) -> Vec<String> {
        self.lock()
            .models
            .iter()
            .filter(|(_, st)| st.server.is_some())
            .map(|(id, _)| id.clone())
            .collect()
    }

    /// Floats per sample for a model (`None` resolves the default) —
    /// available without loading, from the registered batch config.
    pub fn sample_len(&self, id: Option<&str>) -> Result<usize, SubmitError> {
        let guard = self.lock();
        let id = match id {
            Some(s) => s.to_string(),
            None => self
                .default_id(&guard)
                .ok_or_else(|| SubmitError::UnknownModel("(default)".to_string()))?,
        };
        guard
            .models
            .get(&id)
            .map(|st| st.spec.batch.sample_len())
            .ok_or(SubmitError::UnknownModel(id))
    }

    /// Largest per-sample float count across registered models — the
    /// wire front-end sizes its frame cap from this.
    pub fn max_sample_len(&self) -> usize {
        self.lock().models.values().map(|st| st.spec.batch.sample_len()).max().unwrap_or(0)
    }

    /// Summed byte footprint of resident engines.
    pub fn resident_bytes(&self) -> usize {
        self.lock().resident_bytes
    }

    /// Resolve an id to its (possibly freshly loaded) pool and bump the
    /// LRU clock. Returns drain tickets for any models the load evicted.
    fn resolve(&self, id: Option<&str>) -> Result<(Arc<BatchServer>, Vec<DrainTicket>), SubmitError> {
        let mut guard = self.lock();
        let inner = &mut *guard;
        if inner.shutting_down {
            return Err(SubmitError::ShuttingDown);
        }
        let id = match id {
            Some(s) => s.to_string(),
            None => self
                .default_id(inner)
                .ok_or_else(|| SubmitError::UnknownModel("(default)".to_string()))?,
        };
        if !inner.models.contains_key(&id) {
            return Err(SubmitError::UnknownModel(id));
        }
        let mut victims = Vec::new();
        if inner.models[&id].server.is_none() {
            // Lazy (re)load. The factory runs under the registry lock:
            // concurrent first requests load once, and add/remove stay
            // atomic against the load. Engines are compressed — loads
            // are short next to the traffic they unblock.
            let state = inner.models.get_mut(&id).expect("checked above");
            let engine = (state.spec.factory)()
                .map_err(|e| SubmitError::LoadFailed(format!("model {id:?}: {e:#}")))?;
            let bytes = engine.model_size_bytes();
            let server = Arc::new(BatchServer::start(engine, state.spec.batch.clone()));
            state.server = Some(server);
            state.bytes = bytes;
            state.loads += 1;
            inner.resident_bytes += bytes;
            if telemetry::trace_enabled() {
                telemetry::event_label("registry.load", 0, &id, &[("bytes", bytes as f64)]);
            }
            // Enforce the budget by evicting LRU residents — never the
            // model just loaded, so one oversized model still serves.
            while self.cfg.memory_budget_bytes > 0
                && inner.resident_bytes > self.cfg.memory_budget_bytes
            {
                let victim = inner
                    .models
                    .iter()
                    .filter(|(vid, st)| vid.as_str() != id && st.server.is_some())
                    .min_by_key(|(_, st)| st.last_used)
                    .map(|(vid, _)| vid.clone());
                match victim {
                    Some(vid) => victims.push(Self::detach(inner, &vid)),
                    None => break,
                }
            }
        }
        inner.clock += 1;
        let clock = inner.clock;
        let state = inner.models.get_mut(&id).expect("checked above");
        state.last_used = clock;
        let server = Arc::clone(state.server.as_ref().expect("loaded above"));
        Ok((server, victims))
    }

    /// Queue one sample for `id` (`None` routes to the default model),
    /// lazily loading and budget-evicting as needed. A submitter that
    /// catches a pool mid-eviction re-resolves — which hot-reloads the
    /// model — so evictions never drop requests.
    pub fn submit(&self, id: Option<&str>, sample: &[f32]) -> Result<Pending, SubmitError> {
        self.submit_traced(id, sample, telemetry::next_trace_id())
    }

    /// [`submit`](Self::submit) with a caller-supplied trace id, so the
    /// wire front-end's per-frame id follows the request through the
    /// resolved pool's admission/coalesce/reply events.
    pub fn submit_traced(
        &self,
        id: Option<&str>,
        sample: &[f32],
        trace_id: u64,
    ) -> Result<Pending, SubmitError> {
        let mut last_err: Option<anyhow::Error> = None;
        for _ in 0..4 {
            let (server, victims) = self.resolve(id)?;
            self.drain(victims);
            match server.submit_traced(sample, trace_id) {
                Ok(pending) => return Ok(pending),
                // Either a wrong-length sample (re-resolving returns the
                // same live pool and the same error) or an eviction race
                // (re-resolving reloads); the bounded loop serves both.
                Err(e) => last_err = Some(e),
            }
        }
        Err(SubmitError::Rejected(
            last_err.map(|e| e.to_string()).unwrap_or_else(|| "no pool accepted the request".into()),
        ))
    }

    /// Submit and block for the logits — the in-process convenience path
    /// (tests, benchmarks).
    pub fn infer(&self, id: Option<&str>, sample: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.submit(id, sample).map_err(|e| anyhow::anyhow!("{e}"))?.wait()
    }

    /// Per-model counters: residency, byte footprint, load/eviction
    /// counts, lifetime request totals, and the live incarnation's
    /// serving snapshot (zeros while evicted).
    pub fn stats_json(&self) -> Json {
        // Snapshot (id, server?) pairs under the lock, read pool stats
        // outside it (stats() takes the pool's own mutex).
        let rows: Vec<(String, Option<Arc<BatchServer>>, usize, u64, u64, usize, usize)> = {
            let guard = self.lock();
            guard
                .models
                .iter()
                .map(|(id, st)| {
                    (
                        id.clone(),
                        st.server.clone(),
                        st.bytes,
                        st.loads,
                        st.evictions,
                        st.retired_requests,
                        st.retired_batches,
                    )
                })
                .collect()
        };
        let mut j = Json::obj();
        for (id, server, bytes, loads, evictions, retired_req, _retired_batches) in rows {
            let serving = server.as_ref().map(|s| s.stats()).unwrap_or_default();
            let mut m = Json::obj();
            m.set("resident", Json::from(server.is_some()))
                .set("bytes", Json::from(bytes))
                .set("loads", Json::from(loads as usize))
                .set("evictions", Json::from(evictions as usize))
                .set("requests_total", Json::from(retired_req + serving.requests))
                .set("serving", serving.to_json());
            j.set(&id, m);
        }
        j
    }

    /// Fleet roll-up in the single-model `ServingStats` shape: counts
    /// (including retired incarnations) sum; `mean_*` weight by resident
    /// request/batch counts; percentile fields come from the
    /// bucketwise-merged latency histogram across resident pools — true
    /// fleet percentiles, not a per-pool max. Only if a pool ever
    /// reports an incompatible bucket layout (impossible in-process
    /// today; defensive against a future serialization path) do
    /// percentiles fall back to the old per-pool max ceiling. The
    /// `layers` field stays empty — per-layer profiles are a per-model
    /// concept; see [`ModelRegistry::profiles_json`].
    pub fn aggregate_stats(&self) -> ServingStats {
        let rows: Vec<(Option<Arc<BatchServer>>, usize, usize)> = {
            let guard = self.lock();
            guard
                .models
                .values()
                .map(|st| (st.server.clone(), st.retired_requests, st.retired_batches))
                .collect()
        };
        let mut agg = ServingStats::default();
        let (mut lat_weight, mut fwd_weight) = (0.0f64, 0.0f64);
        let mut merged = LatencyHistogram::default();
        let mut merged_ok = true;
        for (server, retired_req, retired_batches) in rows {
            agg.requests += retired_req;
            agg.batches += retired_batches;
            let Some(server) = server else { continue };
            let s = server.stats();
            agg.requests += s.requests;
            agg.batches += s.batches;
            agg.max_batch = agg.max_batch.max(s.max_batch);
            agg.mean_latency_us += s.mean_latency_us * s.requests as f64;
            lat_weight += s.requests as f64;
            agg.mean_forward_us += s.mean_forward_us * s.batches as f64;
            fwd_weight += s.batches as f64;
            agg.throughput_rps += s.throughput_rps;
            merged_ok &= merged.try_merge(&server.latency_histogram());
            agg.p50_latency_us = agg.p50_latency_us.max(s.p50_latency_us);
            agg.p90_latency_us = agg.p90_latency_us.max(s.p90_latency_us);
            agg.p99_latency_us = agg.p99_latency_us.max(s.p99_latency_us);
            agg.max_latency_us = agg.max_latency_us.max(s.max_latency_us);
        }
        if merged_ok && merged.count() > 0 {
            agg.p50_latency_us = merged.percentile(0.50);
            agg.p90_latency_us = merged.percentile(0.90);
            agg.p99_latency_us = merged.percentile(0.99);
            agg.max_latency_us = merged.max_us();
        }
        if lat_weight > 0.0 {
            agg.mean_latency_us /= lat_weight;
        }
        if fwd_weight > 0.0 {
            agg.mean_forward_us /= fwd_weight;
        }
        if agg.batches > 0 {
            agg.mean_batch = agg.requests as f64 / agg.batches as f64;
        }
        agg
    }

    /// Per-layer profiles of every *resident* model, keyed by model id:
    /// `{id: [LayerProfile…]}`. Evicted models are omitted — their
    /// accumulators left with the engine.
    pub fn profiles_json(&self) -> Json {
        let rows: Vec<(String, Arc<BatchServer>)> = {
            let guard = self.lock();
            guard
                .models
                .iter()
                .filter_map(|(id, st)| st.server.clone().map(|s| (id.clone(), s)))
                .collect()
        };
        let mut j = Json::obj();
        for (id, server) in rows {
            let layers: Vec<Json> =
                server.engine().profile().iter().map(|p| p.to_json()).collect();
            j.set(&id, Json::Arr(layers));
        }
        j
    }

    /// Stop routing, drain every resident pool (queued requests are
    /// answered), and leave the registry refusing new work.
    pub fn shutdown(&self) {
        let victims: Vec<DrainTicket> = {
            let mut guard = self.lock();
            let inner = &mut *guard;
            inner.shutting_down = true;
            let ids: Vec<String> = inner
                .models
                .iter()
                .filter(|(_, st)| st.server.is_some())
                .map(|(id, _)| id.clone())
                .collect();
            ids.iter().map(|id| Self::detach(inner, id)).collect()
        };
        self.drain(victims);
    }
}

impl Drop for ModelRegistry {
    fn drop(&mut self) {
        self.shutdown();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::WeightMode;
    use crate::runtime::{ParamBundle, ParamSpec};
    use crate::sparse::prox;
    use crate::tensor::Tensor;
    use crate::util::rng::Rng;
    use std::time::Duration;

    /// Deterministic tiny MLP engine: same (width, seed) → bit-identical
    /// weights, which is the factory contract hot-reload relies on.
    fn tiny_engine(width: usize, seed: u64) -> Arc<Engine> {
        let specs = vec![
            ParamSpec::new("fc1_w", "fc_w", vec![width, 64], true),
            ParamSpec::new("fc1_b", "fc_b", vec![width], false),
            ParamSpec::new("fc2_w", "fc_w", vec![8, width], true),
            ParamSpec::new("fc2_b", "fc_b", vec![8], false),
        ];
        let mut bundle = ParamBundle::he_init(&specs, seed);
        for (s, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
            if s.prunable {
                prox::soft_threshold_inplace(v, 0.05);
            }
        }
        Arc::new(Engine::builder("mlp").bundle(&bundle).mode(WeightMode::Csr).build().unwrap())
    }

    fn spec(id: &str, width: usize, seed: u64) -> ModelSpec {
        ModelSpec::new(
            id,
            Arc::new(move || Ok(tiny_engine(width, seed))),
            BatchConfig::new(4, Duration::from_millis(1), (1, 8, 8)),
        )
    }

    fn sample(seed: u64) -> Vec<f32> {
        Rng::new(seed).normal_vec(64, 1.0)
    }

    #[test]
    fn routes_by_id_and_default() {
        let reg = ModelRegistry::with_models(
            RegistryConfig { memory_budget_bytes: 0, default_model: Some("a".into()) },
            vec![spec("a", 16, 1), spec("b", 16, 2)],
        )
        .unwrap();
        let x = sample(10);
        let ya = reg.infer(Some("a"), &x).unwrap();
        let yb = reg.infer(Some("b"), &x).unwrap();
        assert_ne!(ya, yb, "different seeds must serve different logits");
        // Versionless requests land on the default.
        assert_eq!(reg.infer(None, &x).unwrap(), ya);
        // And the engines agree with a direct forward.
        let direct = tiny_engine(16, 1)
            .forward(&Tensor::new(vec![1, 1, 8, 8], x.clone()))
            .unwrap();
        assert_eq!(ya, direct.data);
    }

    #[test]
    fn single_model_registry_defaults_without_config() {
        let reg = ModelRegistry::with_models(RegistryConfig::default(), vec![spec("only", 16, 3)])
            .unwrap();
        assert_eq!(reg.default_model().as_deref(), Some("only"));
        assert_eq!(reg.infer(None, &sample(11)).unwrap().len(), 8);
    }

    #[test]
    fn unknown_model_and_missing_default_are_typed() {
        let reg = ModelRegistry::with_models(
            RegistryConfig::default(),
            vec![spec("a", 16, 1), spec("b", 16, 2)],
        )
        .unwrap();
        let x = sample(12);
        assert!(matches!(reg.submit(Some("ghost"), &x), Err(SubmitError::UnknownModel(_))));
        // Two models, no configured default: versionless has nowhere to go.
        assert!(matches!(reg.submit(None, &x), Err(SubmitError::UnknownModel(_))));
    }

    #[test]
    fn lazy_load_and_lru_eviction_under_budget() {
        let bytes = tiny_engine(16, 1).model_size_bytes();
        assert!(bytes > 0);
        // Budget fits exactly two of the three identical-size models.
        let reg = ModelRegistry::with_models(
            RegistryConfig { memory_budget_bytes: 2 * bytes, default_model: None },
            vec![spec("a", 16, 1), spec("b", 16, 2), spec("c", 16, 3)],
        )
        .unwrap();
        assert!(reg.resident_models().is_empty(), "loading is lazy");
        let x = sample(13);
        reg.infer(Some("a"), &x).unwrap();
        reg.infer(Some("b"), &x).unwrap();
        assert_eq!(reg.resident_models(), vec!["a".to_string(), "b".to_string()]);
        // Loading c exceeds the budget → evict the LRU resident (a).
        reg.infer(Some("c"), &x).unwrap();
        assert_eq!(reg.resident_models(), vec!["b".to_string(), "c".to_string()]);
        // Touch b, then reload a: the LRU victim is now c.
        reg.infer(Some("b"), &x).unwrap();
        reg.infer(Some("a"), &x).unwrap();
        assert_eq!(reg.resident_models(), vec!["a".to_string(), "b".to_string()]);
        assert!(reg.resident_bytes() <= 2 * bytes);
    }

    #[test]
    fn eviction_then_hot_reload_is_bit_identical() {
        let reg =
            ModelRegistry::with_models(RegistryConfig::default(), vec![spec("m", 24, 7)]).unwrap();
        let x = sample(14);
        let before = reg.infer(Some("m"), &x).unwrap();
        assert!(reg.evict("m").unwrap());
        assert!(reg.resident_models().is_empty());
        // Next request lazily reloads through the deterministic factory.
        let after = reg.infer(Some("m"), &x).unwrap();
        assert_eq!(before, after);
        // Counters saw both incarnations.
        let stats = reg.stats_json().to_string_compact();
        assert!(stats.contains("\"loads\": 2") || stats.contains("\"loads\":2"), "{stats}");
        assert!(stats.contains("\"requests_total\": 2") || stats.contains("\"requests_total\":2"), "{stats}");
    }

    #[test]
    fn eviction_mid_traffic_drops_nothing() {
        let reg =
            ModelRegistry::with_models(RegistryConfig::default(), vec![spec("m", 16, 5)]).unwrap();
        let x = sample(15);
        let want = reg.infer(Some("m"), &x).unwrap();
        // Hammer the model from four threads while the main thread
        // evicts it repeatedly: every request must come back with the
        // same logits — reload races surface as Rejected/dropped errors.
        std::thread::scope(|scope| {
            for _ in 0..4 {
                scope.spawn(|| {
                    for _ in 0..50 {
                        let got = reg.infer(Some("m"), &x).unwrap();
                        assert_eq!(got, want);
                    }
                });
            }
            for _ in 0..10 {
                reg.evict("m").unwrap();
                std::thread::sleep(Duration::from_millis(1));
            }
        });
    }

    #[test]
    fn add_remove_while_serving() {
        let reg = ModelRegistry::with_models(
            RegistryConfig { memory_budget_bytes: 0, default_model: Some("a".into()) },
            vec![spec("a", 16, 1)],
        )
        .unwrap();
        let x = sample(16);
        reg.infer(Some("a"), &x).unwrap();
        reg.add_model(spec("late", 16, 9)).unwrap();
        assert_eq!(reg.infer(Some("late"), &x).unwrap().len(), 8);
        // Duplicate and malformed registrations are refused.
        assert!(reg.add_model(spec("late", 16, 9)).is_err());
        assert!(reg
            .add_model(ModelSpec::new(
                "",
                Arc::new(|| Ok(tiny_engine(16, 1))),
                BatchConfig::new(1, Duration::from_millis(1), (1, 8, 8)),
            ))
            .is_err());
        reg.remove_model("late").unwrap();
        assert!(matches!(reg.submit(Some("late"), &x), Err(SubmitError::UnknownModel(_))));
        assert!(reg.remove_model("late").is_err());
        // The surviving model is untouched.
        reg.infer(Some("a"), &x).unwrap();
    }

    #[test]
    fn load_failure_is_reported_not_cached() {
        use std::sync::atomic::{AtomicUsize, Ordering};
        let attempts = Arc::new(AtomicUsize::new(0));
        let attempts2 = Arc::clone(&attempts);
        let flaky: EngineFactory = Arc::new(move || {
            // First attempt fails (checkpoint not there yet), later ones
            // succeed — the registry must retry the factory per request.
            if attempts2.fetch_add(1, Ordering::SeqCst) == 0 {
                anyhow::bail!("checkpoint missing")
            }
            Ok(tiny_engine(16, 4))
        });
        let reg = ModelRegistry::with_models(
            RegistryConfig::default(),
            vec![ModelSpec::new(
                "m",
                flaky,
                BatchConfig::new(2, Duration::from_millis(1), (1, 8, 8)),
            )],
        )
        .unwrap();
        let x = sample(17);
        match reg.submit(Some("m"), &x) {
            Err(SubmitError::LoadFailed(msg)) => assert!(msg.contains("checkpoint missing"), "{msg}"),
            other => panic!("expected LoadFailed, got {:?}", other.map(|_| ())),
        }
        reg.infer(Some("m"), &x).unwrap();
        assert_eq!(attempts.load(Ordering::SeqCst), 2);
    }

    #[test]
    fn aggregate_and_shutdown() {
        let reg = ModelRegistry::with_models(
            RegistryConfig::default(),
            vec![spec("a", 16, 1), spec("b", 16, 2)],
        )
        .unwrap();
        let x = sample(18);
        for _ in 0..3 {
            reg.infer(Some("a"), &x).unwrap();
        }
        reg.infer(Some("b"), &x).unwrap();
        let agg = reg.aggregate_stats();
        assert_eq!(agg.requests, 4);
        assert!(agg.batches >= 2);
        assert!(agg.mean_latency_us > 0.0);
        // Percentiles come from the merged histogram: ordered, positive,
        // and bounded by the slowest recorded request.
        assert!(agg.p50_latency_us > 0.0);
        assert!(agg.p50_latency_us <= agg.p99_latency_us);
        assert!(agg.p99_latency_us <= agg.max_latency_us);
        // Per-layer profiles are exposed per resident model.
        let profiles = reg.profiles_json();
        let a_layers = profiles.get("a").and_then(|p| p.as_arr()).unwrap();
        assert!(!a_layers.is_empty());
        reg.shutdown();
        assert!(matches!(reg.submit(Some("a"), &x), Err(SubmitError::ShuttingDown)));
        // Retired counts survive shutdown in the roll-up.
        assert_eq!(reg.aggregate_stats().requests, 4);
    }
}
