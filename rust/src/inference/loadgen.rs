//! Closed-loop load generator for the framed-TCP serving front-end
//! (`inference::net`) — the measurement half of the EIE-style "saturate
//! the device with a request stream" story.
//!
//! `run` drives `clients` concurrent synthetic clients against a served
//! engine fleet for a fixed wall-clock duration. Each client is
//! *closed-loop*: it keeps exactly one request in flight (send → wait →
//! send), so total concurrency equals the client count and the measured
//! throughput at a high client count is the server's saturation
//! throughput — more offered load at that point only grows latency, not
//! completions.
//!
//! A run targets one or more models ([`LoadTarget`]): single-target runs
//! send versionless wire-v1 `INFER` frames, and a mixed-fleet run names
//! each model with wire-v2 `INFER_MODEL` frames, cycling targets
//! round-robin per request (offset by client index, so the instantaneous
//! mix stays even).
//!
//! `overloaded` is backpressure, not failure: each client retries the
//! same sample with exponential backoff up to
//! [`LoadConfig::retry_budget`] times before giving up and counting the
//! error. Retries are reported separately — a healthy saturated run
//! shows retries, not `overloaded` errors.
//!
//! Every client draws its samples from a deterministic per-client stream
//! (`Rng::new(seed).fork(client_index)`). When a target carries a
//! `verify` engine, each OK response is bit-compared (`f32::to_bits`)
//! against a local `Engine::forward` of the same sample — the
//! over-the-wire determinism contract: serving through accept loop,
//! model routing, batch coalescing, and frame encode/decode must not
//! perturb a single bit of the logits.
//!
//! The report combines the client-side view (latency histogram,
//! per-error-code counts, per-model tallies, achieved throughput) with
//! the server's own STATS response, so server-reported percentiles land
//! in the same JSON artifact CI uploads.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::inference::net::{ErrorCode, NetClient};
use crate::inference::Engine;
use crate::metrics::LatencyHistogram;
use crate::tensor::Tensor;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// One model a load run drives traffic at.
#[derive(Clone)]
pub struct LoadTarget {
    /// `Some(id)` sends wire-v2 `INFER_MODEL` frames for that model;
    /// `None` sends versionless v1 `INFER` (the server's default model).
    pub model: Option<String>,
    /// Per-sample input shape (C, H, W) — must match the served model.
    pub input_shape: (usize, usize, usize),
    /// Local twin of the served engine for bit-exactness checking;
    /// `None` skips verification (pure throughput mode).
    pub verify: Option<Arc<Engine>>,
}

impl LoadTarget {
    pub fn new(model: Option<&str>, input_shape: (usize, usize, usize), verify: Option<Arc<Engine>>) -> LoadTarget {
        LoadTarget { model: model.map(str::to_string), input_shape, verify }
    }

    pub fn sample_len(&self) -> usize {
        let (c, h, w) = self.input_shape;
        c * h * w
    }

    /// Display name for reports.
    fn label(&self) -> &str {
        self.model.as_deref().unwrap_or("(default)")
    }
}

/// Knobs for one load-generation run.
#[derive(Clone)]
pub struct LoadConfig {
    /// Server address (`host:port`).
    pub addr: String,
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Wall-clock run length.
    pub duration: Duration,
    /// Models to drive, cycled round-robin per request. One target with
    /// `model: None` reproduces the single-model v1 behaviour.
    pub targets: Vec<LoadTarget>,
    /// Base seed; client `i` uses the forked stream `i`.
    pub seed: u64,
    /// How long each client retries its initial connect (covers the
    /// serve-process startup race in scripts and CI).
    pub connect_timeout: Duration,
    /// How many times a client re-sends a sample answered `overloaded`
    /// before counting it as an error. 0 disables retries.
    pub retry_budget: u32,
    /// Backoff before retry `n` is `retry_base << n` (exponential).
    pub retry_base: Duration,
    /// Fetch the server's STATS JSON into the report after the run.
    pub fetch_server_stats: bool,
}

/// What one client accumulated for one target.
#[derive(Default, Clone)]
struct TargetTally {
    ok: u64,
    verified: u64,
    mismatches: u64,
    retries: u64,
    /// Per-[`ErrorCode`] counts for this target, indexed by `code as u8 - 1`.
    errors: [u64; 7],
    /// Wall-clock time this target's requests spent sleeping in retry
    /// backoff (measured, not nominal).
    backoff_us: u64,
}

/// What one client accumulated; merged across clients into [`LoadReport`].
struct ClientOutcome {
    per_target: Vec<TargetTally>,
    /// Per-[`ErrorCode`] counts, indexed by `code as u8 - 1`.
    errors: [u64; 7],
    transport_errors: u64,
    latency: LatencyHistogram,
}

impl ClientOutcome {
    fn new(targets: usize) -> ClientOutcome {
        ClientOutcome {
            per_target: vec![TargetTally::default(); targets],
            errors: [0; 7],
            transport_errors: 0,
            latency: LatencyHistogram::new(),
        }
    }
}

/// Per-model slice of an aggregated load report.
pub struct ModelReport {
    /// The target's model id (`None` for versionless v1 traffic).
    pub model: Option<String>,
    pub ok: u64,
    pub verified: u64,
    pub mismatches: u64,
    pub retries: u64,
    /// Per-[`ErrorCode`] counts for this target, indexed by `code as u8 - 1`.
    pub errors: [u64; 7],
    /// Total wall-clock time this target's requests spent in retry
    /// backoff sleeps.
    pub backoff_us: u64,
}

impl ModelReport {
    pub fn error_count(&self, code: ErrorCode) -> u64 {
        self.errors[code as u8 as usize - 1]
    }
}

/// Aggregated result of a load run.
pub struct LoadReport {
    pub addr: String,
    pub clients: usize,
    pub elapsed_secs: f64,
    pub ok: u64,
    pub errors: [u64; 7],
    pub transport_errors: u64,
    /// `overloaded` responses absorbed by backoff-and-retry (not errors).
    pub retries: u64,
    /// Total wall-clock time clients spent sleeping in retry backoff —
    /// the cost the retry policy paid to absorb `overloaded` responses.
    pub backoff_us: u64,
    pub throughput_rps: f64,
    pub mean_latency_us: f64,
    pub p50_latency_us: f64,
    pub p90_latency_us: f64,
    pub p99_latency_us: f64,
    pub max_latency_us: f64,
    pub verified: u64,
    pub mismatches: u64,
    /// One row per target, in `LoadConfig::targets` order.
    pub per_model: Vec<ModelReport>,
    /// The server's own STATS response (`{"serving": ..., "net": ...,
    /// "models": ...}`), when fetched — server-side percentiles and
    /// per-model registry counters live in here.
    pub server_stats: Option<Json>,
}

impl LoadReport {
    pub fn error_count(&self, code: ErrorCode) -> u64 {
        self.errors[code as u8 as usize - 1]
    }

    pub fn total_errors(&self) -> u64 {
        self.errors.iter().sum()
    }

    pub fn to_json(&self) -> Json {
        let mut errors = Json::obj();
        for code in ErrorCode::all() {
            errors.set(code.name(), Json::from(self.error_count(code) as usize));
        }
        let mut latency = Json::obj();
        latency
            .set("mean_us", Json::from(self.mean_latency_us))
            .set("p50_us", Json::from(self.p50_latency_us))
            .set("p90_us", Json::from(self.p90_latency_us))
            .set("p99_us", Json::from(self.p99_latency_us))
            .set("max_us", Json::from(self.max_latency_us));
        let mut verify = Json::obj();
        verify
            .set("checked", Json::from(self.verified as usize))
            .set("mismatches", Json::from(self.mismatches as usize));
        let per_model: Vec<Json> = self
            .per_model
            .iter()
            .map(|m| {
                let mut errs = Json::obj();
                for code in ErrorCode::all() {
                    errs.set(code.name(), Json::from(m.error_count(code) as usize));
                }
                let mut row = Json::obj();
                row.set("model", Json::from(m.model.as_deref().unwrap_or("(default)")))
                    .set("requests_ok", Json::from(m.ok as usize))
                    .set("verified", Json::from(m.verified as usize))
                    .set("mismatches", Json::from(m.mismatches as usize))
                    .set("retries", Json::from(m.retries as usize))
                    .set("backoff_us", Json::from(m.backoff_us as usize))
                    .set("errors", errs);
                row
            })
            .collect();
        let mut j = Json::obj();
        j.set("addr", Json::from(self.addr.as_str()))
            .set("clients", Json::from(self.clients))
            .set("elapsed_secs", Json::from(self.elapsed_secs))
            .set("requests_ok", Json::from(self.ok as usize))
            .set("errors", errors)
            .set("transport_errors", Json::from(self.transport_errors as usize))
            .set("retries", Json::from(self.retries as usize))
            .set("backoff_us", Json::from(self.backoff_us as usize))
            .set("throughput_rps", Json::from(self.throughput_rps))
            .set("latency", latency)
            .set("verify", verify)
            .set("per_model", Json::Arr(per_model))
            .set("server", self.server_stats.clone().unwrap_or(Json::Null));
        j
    }
}

/// Run one closed-loop load test. Transport failures and server-reported
/// errors are counted, not fatal — the report carries them; only failing
/// to reach the server at all (every client) errors out.
pub fn run(cfg: &LoadConfig) -> anyhow::Result<LoadReport> {
    anyhow::ensure!(cfg.clients >= 1, "loadgen needs at least one client");
    anyhow::ensure!(!cfg.targets.is_empty(), "loadgen needs at least one target model");
    for t in &cfg.targets {
        anyhow::ensure!(t.sample_len() > 0, "loadgen target {} has an empty input shape", t.label());
    }
    let deadline = Instant::now() + cfg.duration;
    let t0 = Instant::now();
    let outcomes: Vec<ClientOutcome> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.clients).map(|i| s.spawn(move || client_loop(cfg, i as u64, deadline))).collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap_or_else(|_| ClientOutcome::new(cfg.targets.len())))
            .collect()
    });
    let elapsed_secs = t0.elapsed().as_secs_f64();

    let mut total = ClientOutcome::new(cfg.targets.len());
    for o in &outcomes {
        for (t, c) in total.per_target.iter_mut().zip(o.per_target.iter()) {
            t.ok += c.ok;
            t.verified += c.verified;
            t.mismatches += c.mismatches;
            t.retries += c.retries;
            t.backoff_us += c.backoff_us;
            for (te, ce) in t.errors.iter_mut().zip(c.errors.iter()) {
                *te += ce;
            }
        }
        for (t, e) in total.errors.iter_mut().zip(o.errors.iter()) {
            *t += e;
        }
        total.transport_errors += o.transport_errors;
        total.latency.merge(&o.latency);
    }
    let ok: u64 = total.per_target.iter().map(|t| t.ok).sum();
    let verified: u64 = total.per_target.iter().map(|t| t.verified).sum();
    let mismatches: u64 = total.per_target.iter().map(|t| t.mismatches).sum();
    let retries: u64 = total.per_target.iter().map(|t| t.retries).sum();
    let backoff_us: u64 = total.per_target.iter().map(|t| t.backoff_us).sum();
    anyhow::ensure!(
        ok + total.errors.iter().sum::<u64>() > 0,
        "no client completed a single request against {} ({} transport errors)",
        cfg.addr,
        total.transport_errors
    );

    let server_stats = if cfg.fetch_server_stats {
        let mut client = NetClient::connect(&cfg.addr, cfg.connect_timeout)?;
        Some(json::parse(&client.stats_json()?)?)
    } else {
        None
    };

    Ok(LoadReport {
        addr: cfg.addr.clone(),
        clients: cfg.clients,
        elapsed_secs,
        ok,
        errors: total.errors,
        transport_errors: total.transport_errors,
        retries,
        backoff_us,
        throughput_rps: if elapsed_secs > 0.0 { ok as f64 / elapsed_secs } else { 0.0 },
        mean_latency_us: total.latency.mean_us(),
        p50_latency_us: total.latency.percentile(0.50),
        p90_latency_us: total.latency.percentile(0.90),
        p99_latency_us: total.latency.percentile(0.99),
        max_latency_us: total.latency.max_us(),
        verified,
        mismatches,
        per_model: cfg
            .targets
            .iter()
            .zip(total.per_target.iter())
            .map(|(t, c)| ModelReport {
                model: t.model.clone(),
                ok: c.ok,
                verified: c.verified,
                mismatches: c.mismatches,
                retries: c.retries,
                errors: c.errors,
                backoff_us: c.backoff_us,
            })
            .collect(),
        server_stats,
    })
}

fn client_loop(cfg: &LoadConfig, index: u64, deadline: Instant) -> ClientOutcome {
    let mut out = ClientOutcome::new(cfg.targets.len());
    let mut client = match NetClient::connect(&cfg.addr, cfg.connect_timeout) {
        Ok(c) => c,
        Err(_) => {
            out.transport_errors += 1;
            return out;
        }
    };
    let mut rng = Rng::new(cfg.seed).fork(index);
    let mut request_no = 0usize;
    while Instant::now() < deadline {
        // Round-robin over targets, offset by client index so the
        // instantaneous mix across clients stays even.
        let ti = (request_no + index as usize) % cfg.targets.len();
        request_no += 1;
        let target = &cfg.targets[ti];
        let (c, h, w) = target.input_shape;
        let sample = rng.normal_vec(target.sample_len(), 1.0);
        let mut attempt = 0u32;
        loop {
            let sent = Instant::now();
            let resp = match &target.model {
                Some(id) => client.infer_model(id, &sample),
                None => client.infer(&sample),
            };
            match resp {
                Ok(Ok(logits)) => {
                    out.latency.record(sent.elapsed().as_secs_f64() * 1e6);
                    let tally = &mut out.per_target[ti];
                    tally.ok += 1;
                    if let Some(engine) = &target.verify {
                        tally.verified += 1;
                        let x = Tensor::new(vec![1, c, h, w], sample.clone());
                        let want = match engine.forward(&x) {
                            Ok(t) => t.data,
                            Err(_) => {
                                tally.mismatches += 1;
                                break;
                            }
                        };
                        let same = want.len() == logits.len()
                            && want.iter().zip(logits.iter()).all(|(a, b)| a.to_bits() == b.to_bits());
                        if !same {
                            tally.mismatches += 1;
                        }
                    }
                    break;
                }
                // Backpressure: re-send the same sample after an
                // exponential backoff, burning one retry from the
                // budget. Only past the budget does it count as an
                // error — transient saturation is expected at the
                // loads this harness exists to generate.
                Ok(Err((ErrorCode::Overloaded, _))) if attempt < cfg.retry_budget => {
                    out.per_target[ti].retries += 1;
                    let t_sleep = Instant::now();
                    std::thread::sleep(cfg.retry_base * (1u32 << attempt.min(10)));
                    out.per_target[ti].backoff_us += t_sleep.elapsed().as_micros() as u64;
                    attempt += 1;
                }
                Ok(Err((code, _msg))) => {
                    out.errors[code as u8 as usize - 1] += 1;
                    out.per_target[ti].errors[code as u8 as usize - 1] += 1;
                    // The server is draining — no more work will land.
                    if code == ErrorCode::ShuttingDown {
                        return out;
                    }
                    break;
                }
                Err(_) => {
                    out.transport_errors += 1;
                    return out;
                }
            }
        }
    }
    out
}
