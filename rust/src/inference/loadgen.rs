//! Closed-loop load generator for the framed-TCP serving front-end
//! (`inference::net`) — the measurement half of the EIE-style "saturate
//! the device with a request stream" story.
//!
//! `run` drives `clients` concurrent synthetic clients against a served
//! engine for a fixed wall-clock duration. Each client is *closed-loop*:
//! it keeps exactly one request in flight (send → wait → send), so total
//! concurrency equals the client count and the measured throughput at a
//! high client count is the server's saturation throughput — more offered
//! load at that point only grows latency, not completions.
//!
//! Every client draws its samples from a deterministic per-client stream
//! (`Rng::new(seed).fork(client_index)`). When `verify` carries an
//! engine, each OK response is bit-compared (`f32::to_bits`) against a
//! local `Engine::forward` of the same sample — the over-the-wire
//! determinism contract: serving through accept loop, batch coalescing,
//! and frame encode/decode must not perturb a single bit of the logits.
//!
//! The report combines the client-side view (latency histogram,
//! per-error-code counts, achieved throughput) with the server's own
//! STATS response, so server-reported percentiles land in the same JSON
//! artifact CI uploads.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::inference::net::{ErrorCode, NetClient};
use crate::inference::Engine;
use crate::metrics::LatencyHistogram;
use crate::tensor::Tensor;
use crate::util::json::{self, Json};
use crate::util::rng::Rng;

/// Knobs for one load-generation run.
#[derive(Clone)]
pub struct LoadConfig {
    /// Server address (`host:port`).
    pub addr: String,
    /// Concurrent closed-loop clients.
    pub clients: usize,
    /// Wall-clock run length.
    pub duration: Duration,
    /// Per-sample input shape (C, H, W) — must match the served model.
    pub input_shape: (usize, usize, usize),
    /// Base seed; client `i` uses the forked stream `i`.
    pub seed: u64,
    /// How long each client retries its initial connect (covers the
    /// serve-process startup race in scripts and CI).
    pub connect_timeout: Duration,
    /// Local twin of the served engine for bit-exactness checking;
    /// `None` skips verification (pure throughput mode).
    pub verify: Option<Arc<Engine>>,
    /// Fetch the server's STATS JSON into the report after the run.
    pub fetch_server_stats: bool,
}

impl LoadConfig {
    pub fn sample_len(&self) -> usize {
        let (c, h, w) = self.input_shape;
        c * h * w
    }
}

/// What one client accumulated; merged across clients into [`LoadReport`].
#[derive(Default)]
struct ClientOutcome {
    ok: u64,
    /// Per-[`ErrorCode`] counts, indexed by `code as u8 - 1`.
    errors: [u64; 6],
    transport_errors: u64,
    latency: LatencyHistogram,
    verified: u64,
    mismatches: u64,
}

/// Aggregated result of a load run.
pub struct LoadReport {
    pub addr: String,
    pub clients: usize,
    pub elapsed_secs: f64,
    pub ok: u64,
    pub errors: [u64; 6],
    pub transport_errors: u64,
    pub throughput_rps: f64,
    pub mean_latency_us: f64,
    pub p50_latency_us: f64,
    pub p90_latency_us: f64,
    pub p99_latency_us: f64,
    pub max_latency_us: f64,
    pub verified: u64,
    pub mismatches: u64,
    /// The server's own STATS response (`{"serving": ..., "net": ...}`),
    /// when fetched — server-side percentiles live in here.
    pub server_stats: Option<Json>,
}

impl LoadReport {
    pub fn error_count(&self, code: ErrorCode) -> u64 {
        self.errors[code as u8 as usize - 1]
    }

    pub fn total_errors(&self) -> u64 {
        self.errors.iter().sum()
    }

    pub fn to_json(&self) -> Json {
        let mut errors = Json::obj();
        for code in ErrorCode::all() {
            errors.set(code.name(), Json::from(self.error_count(code) as usize));
        }
        let mut latency = Json::obj();
        latency
            .set("mean_us", Json::from(self.mean_latency_us))
            .set("p50_us", Json::from(self.p50_latency_us))
            .set("p90_us", Json::from(self.p90_latency_us))
            .set("p99_us", Json::from(self.p99_latency_us))
            .set("max_us", Json::from(self.max_latency_us));
        let mut verify = Json::obj();
        verify
            .set("checked", Json::from(self.verified as usize))
            .set("mismatches", Json::from(self.mismatches as usize));
        let mut j = Json::obj();
        j.set("addr", Json::from(self.addr.as_str()))
            .set("clients", Json::from(self.clients))
            .set("elapsed_secs", Json::from(self.elapsed_secs))
            .set("requests_ok", Json::from(self.ok as usize))
            .set("errors", errors)
            .set("transport_errors", Json::from(self.transport_errors as usize))
            .set("throughput_rps", Json::from(self.throughput_rps))
            .set("latency", latency)
            .set("verify", verify)
            .set("server", self.server_stats.clone().unwrap_or(Json::Null));
        j
    }
}

/// Run one closed-loop load test. Transport failures and server-reported
/// errors are counted, not fatal — the report carries them; only failing
/// to reach the server at all (every client) errors out.
pub fn run(cfg: &LoadConfig) -> anyhow::Result<LoadReport> {
    anyhow::ensure!(cfg.clients >= 1, "loadgen needs at least one client");
    anyhow::ensure!(cfg.sample_len() > 0, "loadgen input shape is empty");
    let deadline = Instant::now() + cfg.duration;
    let t0 = Instant::now();
    let outcomes: Vec<ClientOutcome> = std::thread::scope(|s| {
        let handles: Vec<_> = (0..cfg.clients).map(|i| s.spawn(move || client_loop(cfg, i as u64, deadline))).collect();
        handles.into_iter().map(|h| h.join().unwrap_or_default()).collect()
    });
    let elapsed_secs = t0.elapsed().as_secs_f64();

    let mut total = ClientOutcome::default();
    for o in &outcomes {
        total.ok += o.ok;
        for (t, e) in total.errors.iter_mut().zip(o.errors.iter()) {
            *t += e;
        }
        total.transport_errors += o.transport_errors;
        total.latency.merge(&o.latency);
        total.verified += o.verified;
        total.mismatches += o.mismatches;
    }
    anyhow::ensure!(
        total.ok + total.errors.iter().sum::<u64>() > 0,
        "no client completed a single request against {} ({} transport errors)",
        cfg.addr,
        total.transport_errors
    );

    let server_stats = if cfg.fetch_server_stats {
        let mut client = NetClient::connect(&cfg.addr, cfg.connect_timeout)?;
        Some(json::parse(&client.stats_json()?)?)
    } else {
        None
    };

    Ok(LoadReport {
        addr: cfg.addr.clone(),
        clients: cfg.clients,
        elapsed_secs,
        ok: total.ok,
        errors: total.errors,
        transport_errors: total.transport_errors,
        throughput_rps: if elapsed_secs > 0.0 { total.ok as f64 / elapsed_secs } else { 0.0 },
        mean_latency_us: total.latency.mean_us(),
        p50_latency_us: total.latency.percentile(0.50),
        p90_latency_us: total.latency.percentile(0.90),
        p99_latency_us: total.latency.percentile(0.99),
        max_latency_us: total.latency.max_us(),
        verified: total.verified,
        mismatches: total.mismatches,
        server_stats,
    })
}

fn client_loop(cfg: &LoadConfig, index: u64, deadline: Instant) -> ClientOutcome {
    let mut out = ClientOutcome::default();
    let mut client = match NetClient::connect(&cfg.addr, cfg.connect_timeout) {
        Ok(c) => c,
        Err(_) => {
            out.transport_errors += 1;
            return out;
        }
    };
    let mut rng = Rng::new(cfg.seed).fork(index);
    let (c, h, w) = cfg.input_shape;
    while Instant::now() < deadline {
        let sample = rng.normal_vec(cfg.sample_len(), 1.0);
        let sent = Instant::now();
        match client.infer(&sample) {
            Ok(Ok(logits)) => {
                out.latency.record(sent.elapsed().as_secs_f64() * 1e6);
                out.ok += 1;
                if let Some(engine) = &cfg.verify {
                    out.verified += 1;
                    let x = Tensor::new(vec![1, c, h, w], sample);
                    let want = match engine.forward(&x) {
                        Ok(t) => t.data,
                        Err(_) => {
                            out.mismatches += 1;
                            continue;
                        }
                    };
                    let same = want.len() == logits.len()
                        && want.iter().zip(logits.iter()).all(|(a, b)| a.to_bits() == b.to_bits());
                    if !same {
                        out.mismatches += 1;
                    }
                }
            }
            Ok(Err((code, _msg))) => {
                out.errors[code as u8 as usize - 1] += 1;
                match code {
                    // Backpressure: the server told this client to back
                    // off; yield briefly so the retry isn't a busy spin.
                    ErrorCode::Overloaded => std::thread::sleep(Duration::from_micros(200)),
                    // The server is draining — no more work will land.
                    ErrorCode::ShuttingDown => return out,
                    _ => {}
                }
            }
            Err(_) => {
                out.transport_errors += 1;
                return out;
            }
        }
    }
    out
}
