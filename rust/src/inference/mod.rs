//! Compressed inference engine — the "embedded device" execution path.
//!
//! Runs a trained model forward entirely in Rust with weights stored
//! dense, CSR (the paper's deployment scenario, Section 4.5),
//! dispatch-chosen per layer, or codebook-quantized (`quant::QcsMatrix`).
//! [`Engine::builder`] is the one construction surface: pick a source
//! (param bundle, quantized bundle, or checkpoint path) and a
//! [`WeightMode`], then `build()`. Fully-connected layers multiply
//! activations against the compressed weights with the Figure-2
//! `dense×compressed'` kernel; conv layers run im2col and then the same
//! kernel against the (O, I·KH·KW) view. Per-layer timings feed the
//! Table-3 bench and the device cost model.
//!
//! `server` adds the batched serving front-end: a [`BatchServer`]
//! coalesces single-sample requests into micro-batches over one shared
//! [`Engine`] and reports throughput/latency via `metrics::ServingStats`.
//!
//! `registry` scales that to a fleet: a [`ModelRegistry`] routes
//! requests by model id across per-model batch pools, lazily loads
//! engines through deterministic factories, and evicts
//! least-recently-used models under a byte-accounted memory budget —
//! draining, never dropping.
//!
//! `net` puts the registry on the wire: a framed-TCP front-end
//! ([`NetServer`]/[`NetClient`]) with bounded admission (explicit
//! `overloaded` backpressure), per-request deadlines, a hardened frame
//! decoder, model-routed v2 `INFER_MODEL` frames (v1 `INFER` routes to
//! the default model), and graceful drain-then-close shutdown. `loadgen`
//! is its closed-loop measurement harness (`proxcomp loadtest`).

pub mod engine;
pub mod loadgen;
pub mod net;
pub mod registry;
pub mod server;

pub use engine::{Engine, EngineBuilder, LayerTiming, WeightMode, WeightStore};
pub use net::{ErrorCode, NetClient, NetConfig, NetServer};
pub use registry::{EngineFactory, ModelRegistry, ModelSpec, RegistryConfig};
pub use server::{BatchConfig, BatchServer, Pending, WaitOutcome};
