//! Layer-graph forward execution with dense or CSR weights.
//!
//! The graphs mirror `python/compile/models/*.py` exactly (the
//! integration tests assert logits parity against the XLA `infer`
//! artifacts). Architectures are reconstructed from the checkpoint /
//! manifest parameter spec — layer kinds and names drive the wiring, so
//! any width scaling flows through automatically.

use std::collections::HashMap;
use std::sync::{Mutex, PoisonError};
use std::time::Instant;

use crate::quant::{QcsMatrix, QuantConfig, QuantizedModel};
use crate::runtime::{ParamBundle, ParamSpec};
use crate::sparse::{ops, CsrMatrix, DynSparseMatrix};
use crate::telemetry::{self, LayerProfile, LayerProfileAccum};
use crate::tensor::{self, ConvSpec, Tensor};

/// Batch-norm epsilon shared by the engine's BN layers and the native
/// training backend — one value so trained running stats serve exactly.
pub const BN_EPS: f32 = 1e-5;

/// How the engine stores prunable weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WeightMode {
    /// Dense reference path.
    Dense,
    /// Fixed CSR everywhere — the paper's deployment format.
    Csr,
    /// Per-layer format dispatch (`sparse::dispatch::select_format`).
    Auto,
    /// Codebook-quantized CSR (`quant::QcsMatrix`) — lossy: each leaf's
    /// nonzeros collapse onto a per-leaf k-means codebook
    /// (`QuantConfig::default()`; use `Engine::builder(..).quantized(..)`
    /// to serve an already-quantized model's exact codebooks).
    Quantized,
}

/// A weight matrix in the engine: dense (reference path), CSR (the
/// paper's compressed path), dispatch-chosen per layer, or
/// codebook-quantized CSR. All are (N, K) row-major views.
#[derive(Debug, Clone)]
pub enum WeightStore {
    Dense(Tensor),
    Csr(CsrMatrix),
    Auto(DynSparseMatrix),
    Quantized(QcsMatrix),
}

impl WeightStore {
    fn matmul_nt(&self, x: &Tensor) -> Tensor {
        match self {
            WeightStore::Dense(w) => tensor::matmul_nt(x, w),
            WeightStore::Csr(w) => ops::dxct(x, w),
            WeightStore::Auto(w) => w.dxct(x),
            WeightStore::Quantized(w) => w.dxct(x),
        }
    }

    pub fn storage_bytes(&self) -> usize {
        match self {
            WeightStore::Dense(w) => w.numel() * 4,
            WeightStore::Csr(w) => w.storage_bytes(),
            WeightStore::Auto(w) => w.storage_bytes(),
            WeightStore::Quantized(w) => w.storage_bytes(),
        }
    }

    pub fn nnz(&self) -> usize {
        match self {
            WeightStore::Dense(w) => w.data.iter().filter(|&&v| v != 0.0).count(),
            WeightStore::Csr(w) => w.nnz(),
            WeightStore::Auto(w) => w.nnz(),
            WeightStore::Quantized(w) => w.nnz(),
        }
    }

    pub fn logical_shape(&self) -> (usize, usize) {
        match self {
            WeightStore::Dense(w) => (w.shape[0], w.shape[1]),
            WeightStore::Csr(w) => (w.rows, w.cols),
            WeightStore::Auto(w) => (w.rows(), w.cols()),
            WeightStore::Quantized(w) => (w.rows, w.cols),
        }
    }

    /// Human-readable storage format ("dense", "CSR", "QCS", …).
    pub fn format_name(&self) -> &'static str {
        match self {
            WeightStore::Dense(_) => "dense",
            WeightStore::Csr(_) => "CSR",
            WeightStore::Auto(w) => w.format().name(),
            WeightStore::Quantized(_) => "QCS",
        }
    }
}

/// One executable layer.
#[derive(Debug, Clone)]
enum Layer {
    /// Conv (weights as (O, I·KH·KW) matrix for im2col) + bias + conv geometry.
    Conv { name: String, w: WeightStore, bias: Vec<f32>, ci: usize, kh: usize, kw: usize, spec: ConvSpec, relu: bool },
    Fc { name: String, w: WeightStore, bias: Vec<f32>, relu: bool },
    MaxPool { size: usize, stride: usize },
    GlobalAvgPool,
    Flatten,
    Relu,
    /// Batch-statistics normalization: mean/var computed from the batch
    /// at forward time. Couples samples across the batch, so serving
    /// pins `max_batch = 1` (see [`Engine::uses_batch_stats`]).
    BatchNorm { scale: Vec<f32>, bias: Vec<f32> },
    /// Inference-mode batch norm: folded *running* stats, purely
    /// elementwise — batch-composition independent, so it coalesces
    /// freely in the batch server.
    BatchNormInference { scale: Vec<f32>, bias: Vec<f32>, mean: Vec<f32>, var: Vec<f32> },
    /// Residual block marker ops.
    SaveResidual,
    AddResidual { relu: bool },
    /// Projection conv applied to the saved residual (stride-2 shortcut).
    ProjectResidual { w: WeightStore, bias: Vec<f32>, ci: usize, spec: ConvSpec },
}

/// Per-layer timing record.
#[derive(Debug, Clone)]
pub struct LayerTiming {
    pub name: String,
    pub micros: f64,
}

/// The engine: an ordered layer list + metadata.
pub struct Engine {
    pub model: String,
    pub sparse: bool,
    layers: Vec<Layer>,
    pub num_classes: usize,
    /// Per-layer profile accumulators (one slot per layer, weight layers
    /// and shape ops alike), folded once per forward under one brief
    /// lock — interior-mutable because `forward` takes `&self`.
    profiles: Mutex<Vec<LayerProfileAccum>>,
}

/// What an [`EngineBuilder`] deploys from.
enum EngineSource<'a> {
    None,
    Bundle(&'a ParamBundle),
    Quantized(&'a QuantizedModel),
    Checkpoint(std::path::PathBuf),
}

/// The one way to construct an [`Engine`]: pick a source (parameter
/// bundle, quantized model, or checkpoint path) and a [`WeightMode`],
/// then `build()`.
///
/// ```text
/// Engine::builder("lenet-s").bundle(&params).build()?                  // CSR (default)
/// Engine::builder("mlp-s").bundle(&params).mode(WeightMode::Auto).build()?
/// Engine::builder("mlp-s").quantized(&qm).build()?                     // bit-faithful codebooks
/// Engine::builder("").checkpoint("runs/lenet-s.pxcp").build()?         // model id from meta
/// ```
///
/// A quantized source always serves its stored codebooks bit-faithfully
/// (no re-clustering); `mode` then governs only the non-quantized
/// prunable leaves. A checkpoint source auto-detects v2 quantized
/// payloads and serves them the same way; an empty `model` falls back
/// to the checkpoint's `meta.model` field.
pub struct EngineBuilder<'a> {
    model: String,
    mode: WeightMode,
    source: EngineSource<'a>,
}

impl<'a> EngineBuilder<'a> {
    /// Deploy from an in-memory parameter bundle.
    pub fn bundle(mut self, bundle: &'a ParamBundle) -> Self {
        self.source = EngineSource::Bundle(bundle);
        self
    }

    /// Deploy an already-quantized model bit-faithfully: quantized
    /// leaves keep their stored codebooks/codes.
    pub fn quantized(mut self, qm: &'a QuantizedModel) -> Self {
        self.source = EngineSource::Quantized(qm);
        self
    }

    /// Deploy from an on-disk checkpoint (v1 dense/CSR or v2 quantized,
    /// auto-detected).
    pub fn checkpoint(mut self, path: impl Into<std::path::PathBuf>) -> Self {
        self.source = EngineSource::Checkpoint(path.into());
        self
    }

    /// Storage mode for prunable weights (default [`WeightMode::Csr`],
    /// the paper's deployment format).
    pub fn mode(mut self, mode: WeightMode) -> Self {
        self.mode = mode;
        self
    }

    pub fn build(self) -> anyhow::Result<Engine> {
        match self.source {
            EngineSource::None => anyhow::bail!(
                "EngineBuilder needs a source: call .bundle(), .quantized(), or .checkpoint()"
            ),
            EngineSource::Bundle(bundle) => Engine::construct(&self.model, bundle, self.mode, None),
            EngineSource::Quantized(qm) => {
                let bundle = qm.to_bundle();
                let map = qm.qcs_by_name();
                Engine::construct(&self.model, &bundle, self.mode, Some(&map))
            }
            EngineSource::Checkpoint(path) => {
                let ck = crate::checkpoint::load(&path)?;
                let model = if self.model.is_empty() {
                    ck.meta
                        .get("model")
                        .and_then(|j| j.as_str())
                        .map(str::to_string)
                        .ok_or_else(|| {
                            anyhow::anyhow!(
                                "checkpoint {} carries no meta.model; pass the model id to Engine::builder",
                                path.display()
                            )
                        })?
                } else {
                    self.model
                };
                if ck.is_quantized() {
                    let qm = ck.to_quantized_model();
                    let bundle = qm.to_bundle();
                    let map = qm.qcs_by_name();
                    Engine::construct(&model, &bundle, self.mode, Some(&map))
                } else {
                    Engine::construct(&model, &ck.params, self.mode, None)
                }
            }
        }
    }
}

impl Engine {
    /// Start building an engine for `model`. An empty model id is only
    /// valid with a checkpoint source (the id then comes from the
    /// checkpoint's metadata).
    pub fn builder<'a>(model: &str) -> EngineBuilder<'a> {
        EngineBuilder { model: model.to_string(), mode: WeightMode::Csr, source: EngineSource::None }
    }

    /// Build from a parameter bundle. `sparse = true` stores prunable
    /// weights CSR (compressed deployment); `false` keeps dense.
    #[deprecated(note = "use Engine::builder(model).bundle(b).mode(..).build()")]
    pub fn from_bundle(model: &str, bundle: &ParamBundle, sparse: bool) -> anyhow::Result<Engine> {
        let mode = if sparse { WeightMode::Csr } else { WeightMode::Dense };
        Engine::builder(model).bundle(bundle).mode(mode).build()
    }

    /// Build with an explicit weight-storage mode.
    #[deprecated(note = "use Engine::builder(model).bundle(b).mode(mode).build()")]
    pub fn from_bundle_mode(
        model: &str,
        bundle: &ParamBundle,
        mode: WeightMode,
    ) -> anyhow::Result<Engine> {
        Engine::builder(model).bundle(bundle).mode(mode).build()
    }

    /// Serve an already-quantized model bit-faithfully.
    #[deprecated(note = "use Engine::builder(model).quantized(qm).build()")]
    pub fn from_quantized(model: &str, qm: &QuantizedModel) -> anyhow::Result<Engine> {
        Engine::builder(model).quantized(qm).build()
    }

    fn construct(
        model: &str,
        bundle: &ParamBundle,
        mode: WeightMode,
        qcs: Option<&HashMap<String, QcsMatrix>>,
    ) -> anyhow::Result<Engine> {
        let sparse = mode != WeightMode::Dense;
        let leaves: HashMap<&str, (usize, &ParamSpec)> = bundle
            .specs
            .iter()
            .enumerate()
            .map(|(i, s)| (s.name.as_str(), (i, s)))
            .collect();
        let value = |name: &str| -> anyhow::Result<(&ParamSpec, &Vec<f32>)> {
            let (i, s) = leaves
                .get(name)
                .ok_or_else(|| anyhow::anyhow!("missing leaf {name}"))?;
            Ok((s, &bundle.values[*i]))
        };
        let store = |name: &str| -> anyhow::Result<WeightStore> {
            let (s, v) = value(name)?;
            // Weight leaves must view as a matrix; a crafted checkpoint
            // header with a 1-D/3-D weight shape is rejected here
            // explicitly instead of flowing a zero-sized view into CSR
            // construction.
            let (rows, cols) = crate::checkpoint::matrix_view(s).ok_or_else(|| {
                anyhow::anyhow!(
                    "weight leaf {} has non-matrix shape {:?} (rank must be 2 or 4)",
                    s.name,
                    s.shape
                )
            })?;
            if s.prunable {
                if let Some(q) = qcs.and_then(|m| m.get(name)) {
                    return Ok(WeightStore::Quantized(q.clone()));
                }
            }
            Ok(match mode {
                WeightMode::Csr if s.prunable => {
                    WeightStore::Csr(CsrMatrix::from_dense(v, rows, cols))
                }
                WeightMode::Auto if s.prunable => {
                    WeightStore::Auto(DynSparseMatrix::from_dense(v, rows, cols))
                }
                WeightMode::Quantized if s.prunable => WeightStore::Quantized(
                    QcsMatrix::from_dense(v, rows, cols, &QuantConfig::default()),
                ),
                _ => WeightStore::Dense(Tensor::new(vec![rows, cols], v.clone())),
            })
        };

        let mut layers = Vec::new();
        let conv = |layers: &mut Vec<Layer>, name: &str, stride: usize, pad: usize, relu: bool| -> anyhow::Result<()> {
            let (s, _) = value(&format!("{name}_w"))?;
            let (_, b) = value(&format!("{name}_b"))?;
            layers.push(Layer::Conv {
                name: name.to_string(),
                w: store(&format!("{name}_w"))?,
                bias: b.clone(),
                ci: s.shape[1],
                kh: s.shape[2],
                kw: s.shape[3],
                spec: ConvSpec { stride, pad },
                relu,
            });
            Ok(())
        };
        let fc = |layers: &mut Vec<Layer>, name: &str, relu: bool| -> anyhow::Result<()> {
            let (_, b) = value(&format!("{name}_b"))?;
            layers.push(Layer::Fc {
                name: name.to_string(),
                w: store(&format!("{name}_w"))?,
                bias: b.clone(),
                relu,
            });
            Ok(())
        };
        let bn = |layers: &mut Vec<Layer>, name: &str| -> anyhow::Result<()> {
            let (_, s) = value(&format!("{name}_scale"))?;
            let (_, b) = value(&format!("{name}_bias"))?;
            // With running stats in the bundle (natively trained
            // checkpoints) deploy inference-mode BN: folded stats,
            // elementwise, batch-coalescing safe. Scale/bias-only
            // bundles keep the legacy batch-statistics layer.
            if leaves.contains_key(format!("{name}_mean").as_str())
                && leaves.contains_key(format!("{name}_var").as_str())
            {
                let (_, mean) = value(&format!("{name}_mean"))?;
                let (_, var) = value(&format!("{name}_var"))?;
                layers.push(Layer::BatchNormInference {
                    scale: s.clone(),
                    bias: b.clone(),
                    mean: mean.clone(),
                    var: var.clone(),
                });
            } else {
                layers.push(Layer::BatchNorm { scale: s.clone(), bias: b.clone() });
            }
            Ok(())
        };

        match model {
            // The MLP family ("mlp", "mlp-s", …): any widths and depth,
            // wiring derived from the fc{i}_w leaves (ReLU everywhere
            // but the head) — the native manifest registers the sized
            // variants; widths flow in through the bundle spec.
            m if m.starts_with("mlp") => {
                layers.push(Layer::Flatten);
                let mut i = 1;
                while leaves.contains_key(format!("fc{}_w", i + 1).as_str()) {
                    fc(&mut layers, &format!("fc{i}"), true)?;
                    i += 1;
                }
                fc(&mut layers, &format!("fc{i}"), false)?;
            }
            // The LeNet family ("lenet", "lenet-s", …): any number of
            // conv{i} stages (each followed by a 2×2 max-pool) then the
            // fc{i} chain, wiring derived from the leaf names — the same
            // stage structure the native training backend executes, so
            // natively trained conv checkpoints serve unchanged.
            m if m.starts_with("lenet") => {
                let mut i = 1;
                while leaves.contains_key(format!("conv{i}_w").as_str()) {
                    conv(&mut layers, &format!("conv{i}"), 1, 0, false)?;
                    layers.push(Layer::MaxPool { size: 2, stride: 2 });
                    i += 1;
                }
                layers.push(Layer::Flatten);
                let mut i = 1;
                while leaves.contains_key(format!("fc{}_w", i + 1).as_str()) {
                    fc(&mut layers, &format!("fc{i}"), true)?;
                    i += 1;
                }
                fc(&mut layers, &format!("fc{i}"), false)?;
            }
            "alexnet_s" => {
                conv(&mut layers, "conv1", 1, 2, true)?;
                layers.push(Layer::MaxPool { size: 2, stride: 2 });
                conv(&mut layers, "conv2", 1, 2, true)?;
                layers.push(Layer::MaxPool { size: 2, stride: 2 });
                conv(&mut layers, "conv3", 1, 1, true)?;
                conv(&mut layers, "conv4", 1, 1, true)?;
                conv(&mut layers, "conv5", 1, 1, true)?;
                layers.push(Layer::MaxPool { size: 2, stride: 2 });
                layers.push(Layer::Flatten);
                fc(&mut layers, "fc1", true)?;
                fc(&mut layers, "fc2", true)?;
                fc(&mut layers, "fc3", false)?;
            }
            "vgg_s" => {
                // Reconstruct stage structure from the leaf names conv{s}-{i}.
                let mut stage = 1;
                loop {
                    let mut i = 1;
                    let mut any = false;
                    while leaves.contains_key(format!("conv{stage}-{i}_w").as_str()) {
                        conv(&mut layers, &format!("conv{stage}-{i}"), 1, 1, true)?;
                        any = true;
                        i += 1;
                    }
                    if !any {
                        break;
                    }
                    layers.push(Layer::MaxPool { size: 2, stride: 2 });
                    stage += 1;
                }
                layers.push(Layer::Flatten);
                fc(&mut layers, "fc1", true)?;
                fc(&mut layers, "fc2", true)?;
                fc(&mut layers, "fc3", false)?;
            }
            // The ResNet family ("resnet_s", "resnet-s", …): stem conv +
            // BN, then residual blocks reconstructed from the
            // conv{stage}-{block}-{idx} leaf names, global average pool,
            // FC head.
            m if m.starts_with("resnet") => {
                conv(&mut layers, "conv1", 1, 1, false)?;
                bn(&mut layers, "bn1")?;
                layers.push(Layer::Relu);
                let mut si = 1;
                while leaves.contains_key(format!("conv{si}-1-1_w").as_str()) {
                    let mut bi = 1;
                    while leaves.contains_key(format!("conv{si}-{bi}-1_w").as_str()) {
                        let stride = if bi == 1 && si > 1 { 2 } else { 1 };
                        layers.push(Layer::SaveResidual);
                        conv(&mut layers, &format!("conv{si}-{bi}-1"), stride, 1, false)?;
                        bn(&mut layers, &format!("bn{si}-{bi}-1"))?;
                        layers.push(Layer::Relu);
                        conv(&mut layers, &format!("conv{si}-{bi}-2"), 1, 1, false)?;
                        bn(&mut layers, &format!("bn{si}-{bi}-2"))?;
                        if leaves.contains_key(format!("conv{si}-{bi}-proj_w").as_str()) {
                            let (ps, _) = value(&format!("conv{si}-{bi}-proj_w"))?;
                            let (_, pb) = value(&format!("conv{si}-{bi}-proj_b"))?;
                            layers.push(Layer::ProjectResidual {
                                w: store(&format!("conv{si}-{bi}-proj_w"))?,
                                bias: pb.clone(),
                                ci: ps.shape[1],
                                spec: ConvSpec { stride, pad: 0 },
                            });
                        }
                        layers.push(Layer::AddResidual { relu: true });
                        bi += 1;
                    }
                    si += 1;
                }
                layers.push(Layer::GlobalAvgPool);
                fc(&mut layers, "fc1", false)?;
            }
            other => anyhow::bail!("engine does not know model {other:?}"),
        }

        let num_classes = match layers.iter().rev().find_map(|l| match l {
            Layer::Fc { w, .. } => Some(w.logical_shape().0),
            _ => None,
        }) {
            Some(n) => n,
            None => anyhow::bail!("no FC head found"),
        };
        let profiles = Mutex::new(vec![LayerProfileAccum::default(); layers.len()]);
        Ok(Engine { model: model.to_string(), sparse, layers, num_classes, profiles })
    }

    /// True when the forward pass mixes information *across* the batch
    /// (batch-statistics `BatchNorm`): per-sample logits then depend on
    /// batch composition, so the serving path must not coalesce
    /// requests for this engine (`BatchServer` checks this and pins its
    /// micro-batch size to 1). Inference-mode BN (folded running stats,
    /// the path natively trained resnet checkpoints deploy through) is
    /// elementwise and does *not* trip this.
    pub fn uses_batch_stats(&self) -> bool {
        self.layers.iter().any(|l| matches!(l, Layer::BatchNorm { .. }))
    }

    /// (layer name, storage format) per weight layer — shows what the
    /// dispatch chose in `WeightMode::Auto` (all "CSR"/"dense" otherwise).
    pub fn layer_formats(&self) -> Vec<(String, &'static str)> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                Layer::Conv { name, w, .. } | Layer::Fc { name, w, .. } => {
                    Some((name.clone(), w.format_name()))
                }
                Layer::ProjectResidual { w, .. } => Some(("proj".to_string(), w.format_name())),
                _ => None,
            })
            .collect()
    }

    /// Per-weight-layer deployment report: (layer name, storage format,
    /// stored bytes, nnz) — the pipeline's per-leaf size-breakdown
    /// table; the bytes are the *stored* representation (quantized
    /// bytes under `WeightMode::Quantized`), summing to
    /// [`Engine::model_size_bytes`] minus bias/BN payloads.
    pub fn layer_storage(&self) -> Vec<(String, &'static str, usize, usize)> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                Layer::Conv { name, w, .. } | Layer::Fc { name, w, .. } => {
                    Some((name.clone(), w.format_name(), w.storage_bytes(), w.nnz()))
                }
                Layer::ProjectResidual { w, .. } => {
                    Some(("proj".to_string(), w.format_name(), w.storage_bytes(), w.nnz()))
                }
                _ => None,
            })
            .collect()
    }

    /// Total weight storage (paper Table 3 "Model Size").
    pub fn model_size_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                Layer::Conv { w, bias, .. } | Layer::Fc { w, bias, .. } => {
                    w.storage_bytes() + bias.len() * 4
                }
                Layer::ProjectResidual { w, bias, .. } => w.storage_bytes() + bias.len() * 4,
                Layer::BatchNorm { scale, bias } => (scale.len() + bias.len()) * 4,
                Layer::BatchNormInference { scale, bias, mean, var } => {
                    (scale.len() + bias.len() + mean.len() + var.len()) * 4
                }
                _ => 0,
            })
            .sum()
    }

    /// Forward pass; returns (logits, per-layer timings).
    pub fn forward_timed(&self, x: &Tensor) -> anyhow::Result<(Tensor, Vec<LayerTiming>)> {
        let t_forward = Instant::now();
        let mut h = x.clone();
        let mut residual: Option<Tensor> = None;
        let mut timings = Vec::new();
        // Accumulated locally, folded into `self.profiles` under one
        // lock after the pass (no per-layer locking on the hot path).
        let mut profile_rows: Vec<(u64, u64, u64)> = Vec::with_capacity(self.layers.len());
        for layer in &self.layers {
            let t0 = Instant::now();
            let name;
            match layer {
                Layer::Conv { name: n, w, bias, ci, kh, kw, spec, relu } => {
                    name = n.clone();
                    h = conv_via_csr(&h, w, bias, *ci, *kh, *kw, *spec)?;
                    if *relu {
                        tensor::relu_inplace(&mut h);
                    }
                }
                Layer::Fc { name: n, w, bias, relu } => {
                    name = n.clone();
                    let mut y = w.matmul_nt(&h);
                    tensor::add_bias_rows(&mut y, bias);
                    if *relu {
                        tensor::relu_inplace(&mut y);
                    }
                    h = y;
                }
                Layer::MaxPool { size, stride } => {
                    name = "maxpool".into();
                    h = tensor::max_pool(&h, *size, *stride);
                }
                Layer::GlobalAvgPool => {
                    name = "avgpool".into();
                    h = tensor::global_avg_pool(&h);
                }
                Layer::Flatten => {
                    name = "flatten".into();
                    let b = h.shape[0];
                    let rest: usize = h.shape[1..].iter().product();
                    h = h.reshape(vec![b, rest]);
                }
                Layer::Relu => {
                    name = "relu".into();
                    tensor::relu_inplace(&mut h);
                }
                Layer::BatchNorm { scale, bias } => {
                    name = "bn".into();
                    h = tensor::batch_norm(&h, scale, bias, BN_EPS);
                }
                Layer::BatchNormInference { scale, bias, mean, var } => {
                    name = "bn".into();
                    h = tensor::batch_norm_inference(&h, scale, bias, mean, var, BN_EPS);
                }
                Layer::SaveResidual => {
                    name = "save".into();
                    residual = Some(h.clone());
                }
                Layer::ProjectResidual { w, bias, ci, spec } => {
                    name = "proj".into();
                    let r = residual
                        .take()
                        .ok_or_else(|| anyhow::anyhow!("proj without residual"))?;
                    residual = Some(conv_via_csr(&r, w, bias, *ci, 1, 1, *spec)?);
                }
                Layer::AddResidual { relu } => {
                    name = "add".into();
                    let r = residual
                        .take()
                        .ok_or_else(|| anyhow::anyhow!("add without residual"))?;
                    anyhow::ensure!(r.shape == h.shape, "residual shape {:?} vs {:?}", r.shape, h.shape);
                    for (a, b) in h.data.iter_mut().zip(&r.data) {
                        *a += b;
                    }
                    if *relu {
                        tensor::relu_inplace(&mut h);
                    }
                }
            }
            let micros = t0.elapsed().as_secs_f64() * 1e6;
            profile_rows.push((micros as u64, telemetry::zero_count(&h.data), h.data.len() as u64));
            timings.push(LayerTiming { name, micros });
        }
        {
            let mut acc = self.profiles.lock().unwrap_or_else(PoisonError::into_inner);
            for (slot, (us, zeros, elems)) in acc.iter_mut().zip(profile_rows) {
                slot.record(us, zeros, elems);
            }
        }
        if telemetry::trace_enabled() {
            telemetry::event_label(
                "engine.forward",
                0,
                &self.model,
                &[("batch", x.shape.first().copied().unwrap_or(0) as f64),
                    ("us", t_forward.elapsed().as_secs_f64() * 1e6)],
            );
        }
        Ok((h, timings))
    }

    pub fn forward(&self, x: &Tensor) -> anyhow::Result<Tensor> {
        Ok(self.forward_timed(x)?.0)
    }

    /// Snapshot the per-layer profiles accumulated by every forward
    /// since construction (or the last [`Engine::reset_profile`]):
    /// kernel family, stored nnz/density, per-call wall time, and the
    /// output-activation zero fraction — the measurement substrate for
    /// an activation-sparsity-aware kernel crossover. Weight layers
    /// carry their graph names; shape/activation ops are suffixed with
    /// their layer index so every row labels uniquely.
    pub fn profile(&self) -> Vec<LayerProfile> {
        let acc = self.profiles.lock().unwrap_or_else(PoisonError::into_inner).clone();
        self.layers
            .iter()
            .zip(acc)
            .enumerate()
            .map(|(idx, (layer, a))| {
                let (name, w): (String, Option<&WeightStore>) = match layer {
                    Layer::Conv { name, w, .. } | Layer::Fc { name, w, .. } => (name.clone(), Some(w)),
                    Layer::ProjectResidual { w, .. } => (format!("proj@{idx}"), Some(w)),
                    Layer::MaxPool { .. } => (format!("maxpool@{idx}"), None),
                    Layer::GlobalAvgPool => (format!("avgpool@{idx}"), None),
                    Layer::Flatten => (format!("flatten@{idx}"), None),
                    Layer::Relu => (format!("relu@{idx}"), None),
                    Layer::BatchNorm { .. } | Layer::BatchNormInference { .. } => (format!("bn@{idx}"), None),
                    Layer::SaveResidual => (format!("save@{idx}"), None),
                    Layer::AddResidual { .. } => (format!("add@{idx}"), None),
                };
                let (rows, cols, nnz, format) = match w {
                    Some(w) => {
                        let (r, c) = w.logical_shape();
                        (r, c, w.nnz(), w.format_name().to_string())
                    }
                    None => (0, 0, 0, "op".to_string()),
                };
                LayerProfile {
                    name,
                    format,
                    rows,
                    cols,
                    nnz,
                    density: if rows * cols > 0 { nnz as f64 / (rows * cols) as f64 } else { 0.0 },
                    calls: a.calls,
                    total_us: a.total_us,
                    mean_us: if a.calls > 0 { a.total_us as f64 / a.calls as f64 } else { 0.0 },
                    out_zero_fraction: if a.out_elems > 0 { a.out_zeros as f64 / a.out_elems as f64 } else { 0.0 },
                }
            })
            .collect()
    }

    /// Zero the profile accumulators (bench isolation between runs).
    pub fn reset_profile(&self) {
        for slot in self.profiles.lock().unwrap_or_else(PoisonError::into_inner).iter_mut() {
            *slot = LayerProfileAccum::default();
        }
    }

    /// Per-weight-layer work profile for the device cost model: walks the
    /// graph tracking spatial shape, counting FLOPs against *stored
    /// nonzeros* (compressed kernels skip zeros) and bytes as weight
    /// storage + activation traffic.
    pub fn work_profile(
        &self,
        batch: usize,
        c: usize,
        h: usize,
        w: usize,
    ) -> Vec<crate::device::LayerWork> {
        let b = batch as f64;
        let (mut ch, mut hh, mut ww) = (c, h, w);
        // A dense kernel cannot skip zeros: effective multiplies = nnz
        // only on the compressed path, full numel on the dense path.
        let eff_elems = |ws: &WeightStore| {
            if self.sparse {
                ws.nnz() as f64
            } else {
                let (r, c) = ws.logical_shape();
                (r * c) as f64
            }
        };
        let mut out = Vec::new();
        for layer in &self.layers {
            match layer {
                Layer::Conv { name, w: ws, bias, kh, kw, spec, .. } => {
                    let o = ws.logical_shape().0;
                    let oh = tensor::out_dim(hh, *kh, spec.stride, spec.pad);
                    let ow = tensor::out_dim(ww, *kw, spec.stride, spec.pad);
                    let positions = (oh * ow) as f64;
                    let flops = 2.0 * b * positions * eff_elems(ws);
                    let bytes = ws.storage_bytes() as f64
                        + bias.len() as f64 * 4.0
                        + 4.0 * b * (ch * hh * ww + o * oh * ow) as f64;
                    out.push(crate::device::LayerWork { name: name.clone(), flops, bytes });
                    ch = o;
                    hh = oh;
                    ww = ow;
                }
                Layer::ProjectResidual { w: ws, bias, spec, .. } => {
                    let oh = tensor::out_dim(hh, 1, spec.stride, spec.pad).max(1);
                    let positions = (oh * oh) as f64;
                    let flops = 2.0 * b * positions * eff_elems(ws);
                    let bytes = ws.storage_bytes() as f64 + bias.len() as f64 * 4.0;
                    out.push(crate::device::LayerWork { name: "proj".into(), flops, bytes });
                }
                Layer::Fc { name, w: ws, bias, .. } => {
                    let (n, k) = ws.logical_shape();
                    let flops = 2.0 * b * eff_elems(ws);
                    let bytes = ws.storage_bytes() as f64
                        + bias.len() as f64 * 4.0
                        + 4.0 * b * (k + n) as f64;
                    out.push(crate::device::LayerWork { name: name.clone(), flops, bytes });
                }
                Layer::MaxPool { size, stride } => {
                    hh = tensor::out_dim(hh, *size, *stride, 0);
                    ww = tensor::out_dim(ww, *size, *stride, 0);
                }
                Layer::GlobalAvgPool => {
                    hh = 1;
                    ww = 1;
                }
                Layer::Flatten => {}
                _ => {}
            }
        }
        out
    }

    /// Accuracy over a dataset, batched.
    pub fn accuracy(&self, data: &crate::data::Dataset, batch: usize) -> anyhow::Result<f64> {
        let mut correct = 0usize;
        let mut i = 0;
        while i < data.n {
            let take = batch.min(data.n - i);
            let mut xs = Vec::with_capacity(take * data.example_size());
            for j in 0..take {
                xs.extend_from_slice(data.image(i + j));
            }
            let x = Tensor::new(vec![take, data.c, data.h, data.w], xs);
            let logits = self.forward(&x)?;
            for (j, pred) in tensor::argmax_rows(&logits).into_iter().enumerate() {
                if pred == data.labels[i + j] as usize {
                    correct += 1;
                }
            }
            i += take;
        }
        Ok(correct as f64 / data.n as f64)
    }
}

/// Conv through the CSR path: im2col then `Dmat × Cmat'` (paper Fig. 2).
/// Exercised directly by the parity tests below — the engine's conv
/// stages route every format through this one function.
fn conv_via_csr(
    x: &Tensor,
    w: &WeightStore,
    bias: &[f32],
    _ci: usize,
    kh: usize,
    kw: usize,
    spec: ConvSpec,
) -> anyhow::Result<Tensor> {
    let (batch, _c, hdim, wdim) = (x.shape[0], x.shape[1], x.shape[2], x.shape[3]);
    let (o, _k) = w.logical_shape();
    let oh = tensor::out_dim(hdim, kh, spec.stride, spec.pad);
    let ow = tensor::out_dim(wdim, kw, spec.stride, spec.pad);
    let cols = tensor::im2col(x, kh, kw, spec); // (B*OH*OW, C*KH*KW)
    let y = w.matmul_nt(&cols); // (B*OH*OW, O)
    // Back to NCHW with bias.
    let mut out = vec![0.0f32; batch * o * oh * ow];
    for bi in 0..batch {
        for oy in 0..oh {
            for ox in 0..ow {
                let row = (bi * oh + oy) * ow + ox;
                for oc in 0..o {
                    out[((bi * o + oc) * oh + oy) * ow + ox] = y.data[row * o + oc] + bias[oc];
                }
            }
        }
    }
    Ok(Tensor::new(vec![batch, o, oh, ow], out))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::ParamBundle;
    use crate::sparse::dispatch::SparseFormat;
    use crate::sparse::prox;
    use crate::util::rng::Rng;

    /// Randomly sparsified conv weights at `rate` zero fraction, as both
    /// the 4-D tensor and the (O, C·KH·KW) im2col matrix view.
    fn sparse_conv_w(
        rng: &mut Rng,
        o: usize,
        c: usize,
        kh: usize,
        kw: usize,
        rate: f64,
    ) -> (Tensor, Vec<f32>) {
        let mut flat = rng.normal_vec(o * c * kh * kw, 0.5);
        let t = prox::magnitude_quantile(&flat, rate);
        prox::hard_threshold_inplace(&mut flat, t);
        (Tensor::new(vec![o, c, kh, kw], flat.clone()), flat)
    }

    fn assert_close(got: &Tensor, want: &Tensor, what: &str) {
        assert_eq!(got.shape, want.shape, "{what}: shape");
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-4 * w.abs().max(1.0), "{what}: {g} vs {w}");
        }
    }

    #[test]
    fn conv_via_csr_matches_dense_conv2d_across_all_formats() {
        // Every storage format the dispatch can choose must produce the
        // same conv output as the dense tensor::conv2d reference on the
        // same randomly sparsified weights.
        // Geometry chosen so the (O, C·KH·KW) = (8, 16) matrix is
        // tileable by the Block-ELL 8×16 block (its packer asserts it).
        let mut rng = Rng::new(17);
        let (o, c, kh, kw) = (8usize, 4usize, 2usize, 2usize);
        let (w4, flat) = sparse_conv_w(&mut rng, o, c, kh, kw, 0.7);
        let bias: Vec<f32> = rng.normal_vec(o, 0.3);
        let x = Tensor::new(vec![2, c, 8, 8], rng.normal_vec(2 * c * 64, 1.0));
        let spec = ConvSpec { stride: 1, pad: 0 };
        let want = tensor::conv2d(&x, &w4, &bias, spec);
        let k = c * kh * kw;
        let stores = [
            ("dense", WeightStore::Dense(Tensor::new(vec![o, k], flat.clone()))),
            ("CSR", WeightStore::Csr(CsrMatrix::from_dense(&flat, o, k))),
            ("auto", WeightStore::Auto(DynSparseMatrix::from_dense(&flat, o, k))),
        ];
        for (name, store) in &stores {
            let got = conv_via_csr(&x, store, &bias, c, kh, kw, spec).unwrap();
            assert_close(&got, &want, name);
        }
        for fmt in [
            SparseFormat::Csr,
            SparseFormat::Coo,
            SparseFormat::Ell,
            SparseFormat::Dia,
            SparseFormat::BlockEll,
        ] {
            let store = WeightStore::Auto(DynSparseMatrix::from_dense_as(fmt, &flat, o, k));
            let got = conv_via_csr(&x, &store, &bias, c, kh, kw, spec).unwrap();
            assert_close(&got, &want, fmt.name());
        }
    }

    #[test]
    fn conv_via_csr_edge_geometries() {
        // Stride 2 / pad 0, pad 1, a 1×1 kernel, and a window that does
        // not divide the input — all against the dense reference.
        let mut rng = Rng::new(29);
        for (b, c, h, w, o, kh, kw, stride, pad) in [
            (1usize, 2usize, 7usize, 7usize, 3usize, 3usize, 3usize, 2usize, 0usize),
            (2, 1, 6, 5, 2, 3, 3, 1, 1),
            (1, 3, 4, 4, 4, 1, 1, 1, 0),
            (2, 2, 7, 7, 3, 2, 2, 2, 0), // out 3×3: window not dividing input
        ] {
            let (w4, flat) = sparse_conv_w(&mut rng, o, c, kh, kw, 0.5);
            let bias: Vec<f32> = rng.normal_vec(o, 0.3);
            let x = Tensor::new(vec![b, c, h, w], rng.normal_vec(b * c * h * w, 1.0));
            let spec = ConvSpec { stride, pad };
            let want = tensor::conv2d(&x, &w4, &bias, spec);
            let store = WeightStore::Csr(CsrMatrix::from_dense(&flat, o, c * kh * kw));
            let got = conv_via_csr(&x, &store, &bias, c, kh, kw, spec).unwrap();
            assert_close(&got, &want, &format!("s={stride} p={pad} {h}x{w}"));
        }
    }

    /// A lenet-s-shaped bundle small enough for forward tests: input
    /// (1,10,10) → conv 2@3×3 → pool → conv 3@3×3 → pool → fc 3→4→2.
    fn lenet_family_bundle(seed: u64) -> ParamBundle {
        let p = |name: &str, kind: &str, shape: Vec<usize>, prunable: bool| {
            crate::runtime::ParamSpec::new(name, kind, shape, prunable)
        };
        let specs = vec![
            p("conv1_w", "conv_w", vec![2, 1, 3, 3], true),
            p("conv1_b", "conv_b", vec![2], false),
            p("conv2_w", "conv_w", vec![3, 2, 3, 3], true),
            p("conv2_b", "conv_b", vec![3], false),
            p("fc1_w", "fc_w", vec![4, 3], true),
            p("fc1_b", "fc_b", vec![4], false),
            p("fc2_w", "fc_w", vec![2, 4], true),
            p("fc2_b", "fc_b", vec![2], false),
        ];
        ParamBundle::he_init(&specs, seed)
    }

    #[test]
    fn engine_wires_lenet_family_by_name_prefix() {
        let bundle = lenet_family_bundle(3);
        for name in ["lenet", "lenet-s", "lenet-custom"] {
            let engine = Engine::builder(name).bundle(&bundle).mode(WeightMode::Dense).build().unwrap();
            assert_eq!(engine.num_classes, 2);
            // conv1, conv2, fc1, fc2 weight layers reported in order.
            let formats = engine.layer_formats();
            let names: Vec<&str> = formats.iter().map(|(n, _)| n.as_str()).collect();
            assert_eq!(names, ["conv1", "conv2", "fc1", "fc2"]);
            let x = Tensor::new(vec![2, 1, 10, 10], vec![0.25; 200]);
            let logits = engine.forward(&x).unwrap();
            assert_eq!(logits.shape, vec![2, 2]);
        }
    }

    #[test]
    fn engine_sparse_modes_agree_with_dense_on_conv_net() {
        let mut bundle = lenet_family_bundle(5);
        // Sparsify the prunable leaves so CSR/dispatch have zeros to skip.
        for (spec, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
            if spec.prunable {
                let t = prox::magnitude_quantile(v, 0.5);
                prox::hard_threshold_inplace(v, t);
            }
        }
        let mut rng = Rng::new(41);
        let x = Tensor::new(vec![3, 1, 10, 10], rng.normal_vec(300, 1.0));
        let dense = Engine::builder("lenet-s").bundle(&bundle).mode(WeightMode::Dense).build().unwrap();
        let want = dense.forward(&x).unwrap();
        for mode in [WeightMode::Csr, WeightMode::Auto] {
            let engine = Engine::builder("lenet-s").bundle(&bundle).mode(mode).build().unwrap();
            let got = engine.forward(&x).unwrap();
            assert_close(&got, &want, &format!("{mode:?}"));
            assert!(engine.model_size_bytes() > 0);
        }
    }

    /// A sparse MLP bundle big enough that every prunable leaf clears
    /// the quantization nnz floor (fc 100→32→10 at ~70 % zeros).
    fn sparse_mlp_bundle(seed: u64) -> ParamBundle {
        let p = |name: &str, kind: &str, shape: Vec<usize>, prunable: bool| {
            crate::runtime::ParamSpec::new(name, kind, shape, prunable)
        };
        let specs = vec![
            p("fc1_w", "fc_w", vec![32, 100], true),
            p("fc1_b", "fc_b", vec![32], false),
            p("fc2_w", "fc_w", vec![10, 32], true),
            p("fc2_b", "fc_b", vec![10], false),
        ];
        let mut bundle = ParamBundle::he_init(&specs, seed);
        for (spec, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
            if spec.prunable {
                let t = prox::magnitude_quantile(v, 0.7);
                prox::hard_threshold_inplace(v, t);
            }
        }
        bundle
    }

    #[test]
    fn quantized_mode_deploys_qcs_and_shrinks_model_size() {
        let bundle = sparse_mlp_bundle(6);
        let mut rng = Rng::new(43);
        let x = Tensor::new(vec![3, 1, 10, 10], rng.normal_vec(300, 1.0));
        let csr = Engine::builder("mlp-s").bundle(&bundle).build().unwrap();
        let quant = Engine::builder("mlp-s").bundle(&bundle).mode(WeightMode::Quantized).build().unwrap();
        assert!(quant.layer_formats().iter().all(|(_, f)| *f == "QCS"), "{:?}", quant.layer_formats());
        assert!(
            quant.model_size_bytes() < csr.model_size_bytes(),
            "quantized {} >= CSR {}",
            quant.model_size_bytes(),
            csr.model_size_bytes()
        );
        // Lossy but structurally sound: logits exist and nnz is preserved.
        let logits = quant.forward(&x).unwrap();
        assert_eq!(logits.shape, vec![3, 10]);
        let sizes = quant.layer_storage();
        assert_eq!(sizes.len(), 2);
        assert!(sizes.iter().all(|(_, f, bytes, _)| *f == "QCS" && *bytes > 0));
    }

    #[test]
    fn profile_reports_sparsity_calls_and_activation_zeros() {
        let bundle = sparse_mlp_bundle(9);
        let engine = Engine::builder("mlp-s").bundle(&bundle).build().unwrap();
        let mut rng = Rng::new(41);
        for _ in 0..3 {
            let x = Tensor::new(vec![2, 1, 10, 10], rng.normal_vec(200, 1.0));
            engine.forward(&x).unwrap();
        }
        let profile = engine.profile();
        // Every layer slot reports; weight layers carry their graph names.
        let fc1 = profile.iter().find(|p| p.name == "fc1").expect("fc1 row");
        let storage = engine.layer_storage();
        let (_, _, _, fc1_nnz) = storage.iter().find(|(n, ..)| n == "fc1").unwrap().clone();
        assert_eq!(fc1.format, "CSR");
        assert_eq!(fc1.nnz, fc1_nnz, "profile nnz must equal stored nnz");
        assert_eq!((fc1.rows, fc1.cols), (32, 100));
        assert!((fc1.density - fc1.nnz as f64 / 3200.0).abs() < 1e-12);
        assert_eq!(fc1.calls, 3);
        // fc1 is ReLU-capped: its output has zeros a sparsity-aware
        // next-layer kernel could skip.
        assert!(fc1.out_zero_fraction > 0.0 && fc1.out_zero_fraction < 1.0, "{}", fc1.out_zero_fraction);
        // The logits head has no ReLU: zero outputs are measure-zero.
        let fc2 = profile.iter().find(|p| p.name == "fc2").expect("fc2 row");
        assert_eq!(fc2.out_zero_fraction, 0.0);
        // Non-weight ops report as `op` rows with indexed names.
        assert!(profile.iter().any(|p| p.format == "op" && p.name.contains('@')));
        engine.reset_profile();
        assert!(engine.profile().iter().all(|p| p.calls == 0));
    }

    #[test]
    fn from_quantized_serves_codebooks_bit_exactly() {
        // Serving a QuantizedModel must equal serving the dequantized
        // bundle through CSR bit-for-bit: the QCS kernel walks the same
        // nonzeros in the same ascending-index reduction order, only
        // loading values through the codebook.
        let bundle = sparse_mlp_bundle(7);
        let (qm, reports) = crate::quant::quantize_bundle(&bundle, &crate::quant::QuantConfig::default());
        assert!(reports.iter().any(|r| r.quantized), "nothing quantized");
        let qeng = Engine::builder("mlp-s").quantized(&qm).build().unwrap();
        let deq = qm.to_bundle();
        let ceng = Engine::builder("mlp-s").bundle(&deq).build().unwrap();
        let mut rng = Rng::new(47);
        for b in [1usize, 4] {
            let x = Tensor::new(vec![b, 1, 10, 10], rng.normal_vec(b * 100, 1.0));
            assert_eq!(
                qeng.forward(&x).unwrap().data,
                ceng.forward(&x).unwrap().data,
                "b={b}: quantized serving diverges from dequantized CSR"
            );
        }
        assert!(qeng.model_size_bytes() < ceng.model_size_bytes());
    }

    #[test]
    fn builder_requires_a_source() {
        let err = Engine::builder("mlp-s").build().unwrap_err().to_string();
        assert!(err.contains("needs a source"), "{err}");
    }

    #[test]
    fn builder_checkpoint_source_roundtrips() {
        let bundle = sparse_mlp_bundle(11);
        let dir = std::env::temp_dir().join("proxcomp_engine_builder_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("mlp.pxcp");
        let mut meta = crate::util::json::Json::obj();
        meta.set("model", crate::util::json::Json::from("mlp-s"));
        crate::checkpoint::save(&path, &bundle, &meta).unwrap();
        // Empty model id: the builder takes it from the checkpoint meta.
        let from_ck = Engine::builder("").checkpoint(&path).build().unwrap();
        assert_eq!(from_ck.model, "mlp-s");
        let from_bundle = Engine::builder("mlp-s").bundle(&bundle).build().unwrap();
        let x = Tensor::new(vec![2, 1, 10, 10], Rng::new(13).normal_vec(200, 1.0));
        assert_eq!(from_ck.forward(&x).unwrap().data, from_bundle.forward(&x).unwrap().data);
        // Quantized checkpoints auto-detect and serve their codebooks.
        let cfg = crate::quant::QuantConfig { min_quant_nnz: 8, ..crate::quant::QuantConfig::default() };
        let (qm, _) = crate::quant::quantize_bundle(&bundle, &cfg);
        let qpath = dir.join("mlp_quant.pxcp");
        crate::checkpoint::save_quantized(&qpath, &qm, &meta).unwrap();
        let qck = Engine::builder("").checkpoint(&qpath).build().unwrap();
        let qmem = Engine::builder("mlp-s").quantized(&qm).build().unwrap();
        assert_eq!(qck.forward(&x).unwrap().data, qmem.forward(&x).unwrap().data);
    }

    #[test]
    #[allow(deprecated)]
    fn deprecated_constructors_delegate_to_builder() {
        let bundle = sparse_mlp_bundle(12);
        let x = Tensor::new(vec![1, 1, 10, 10], Rng::new(14).normal_vec(100, 1.0));
        let want = Engine::builder("mlp-s").bundle(&bundle).build().unwrap().forward(&x).unwrap();
        let shim = Engine::from_bundle("mlp-s", &bundle, true).unwrap().forward(&x).unwrap();
        assert_eq!(want.data, shim.data);
        let shim = Engine::from_bundle_mode("mlp-s", &bundle, WeightMode::Csr).unwrap().forward(&x).unwrap();
        assert_eq!(want.data, shim.data);
    }

    /// A tiny resnet-family bundle: stem conv + BN, one residual block,
    /// FC head. `with_stats` adds bn running mean/var leaves (the
    /// natively trained layout ⇒ inference-mode BN).
    fn resnet_family_bundle(seed: u64, with_stats: bool) -> ParamBundle {
        let p = |name: &str, kind: &str, shape: Vec<usize>, prunable: bool| {
            crate::runtime::ParamSpec::new(name, kind, shape, prunable)
        };
        let mut specs = Vec::new();
        for (conv, bn, ci) in [("conv1", "bn1", 1usize), ("conv1-1-1", "bn1-1-1", 4), ("conv1-1-2", "bn1-1-2", 4)] {
            specs.push(p(&format!("{conv}_w"), "conv_w", vec![4, ci, 3, 3], true));
            specs.push(p(&format!("{conv}_b"), "conv_b", vec![4], false));
            specs.push(p(&format!("{bn}_scale"), "bn_scale", vec![4], false));
            specs.push(p(&format!("{bn}_bias"), "bn_bias", vec![4], false));
            if with_stats {
                specs.push(p(&format!("{bn}_mean"), "bn_mean", vec![4], false));
                specs.push(p(&format!("{bn}_var"), "bn_var", vec![4], false));
            }
        }
        specs.push(p("fc1_w", "fc_w", vec![2, 4], true));
        specs.push(p("fc1_b", "fc_b", vec![2], false));
        let mut bundle = ParamBundle::he_init(&specs, seed);
        if with_stats {
            // Nudge the stats off their init so the folded affine is
            // nontrivial in the parity check.
            let mut rng = Rng::new(seed ^ 0xBEEF);
            for (s, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
                if s.kind == "bn_mean" {
                    *v = rng.normal_vec(v.len(), 0.2);
                } else if s.kind == "bn_var" {
                    for x in v.iter_mut() {
                        *x = 1.0 + rng.normal_vec(1, 0.1)[0].abs();
                    }
                }
            }
        }
        bundle
    }

    #[test]
    fn bn_layers_pick_inference_mode_when_stats_present() {
        let frozen = resnet_family_bundle(21, true);
        let engine = Engine::builder("resnet-s").bundle(&frozen).build().unwrap();
        assert!(
            !engine.uses_batch_stats(),
            "running-stats BN must not pin serving to max_batch=1"
        );
        // Batched forward is bit-identical to per-sample forwards:
        // inference BN is elementwise, nothing crosses the batch.
        let mut rng = Rng::new(22);
        let samples: Vec<Vec<f32>> = (0..3).map(|_| rng.normal_vec(64, 1.0)).collect();
        let batched = engine
            .forward(&Tensor::new(vec![3, 1, 8, 8], samples.concat()))
            .unwrap();
        for (i, s) in samples.iter().enumerate() {
            let one = engine.forward(&Tensor::new(vec![1, 1, 8, 8], s.clone())).unwrap();
            for (a, b) in one.data.iter().zip(&batched.data[i * 2..(i + 1) * 2]) {
                assert_eq!(a.to_bits(), b.to_bits(), "sample {i} diverged under batching");
            }
        }
        // Legacy scale/bias-only bundles still use batch statistics.
        let legacy = resnet_family_bundle(21, false);
        let engine = Engine::builder("resnet-s").bundle(&legacy).build().unwrap();
        assert!(engine.uses_batch_stats());
    }
}
