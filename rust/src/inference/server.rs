//! Batched multi-threaded serving: queue single-sample requests,
//! coalesce them into micro-batches, run them through a shared
//! [`Engine`].
//!
//! The paper's end goal is fast inference of compressed models on small
//! parallel devices, and EIE (Han et al., 2016) shows the throughput win
//! comes from keeping the compressed format *and* saturating all lanes.
//! [`BatchServer`] supplies the serving half of that: a worker thread
//! drains a request queue into micro-batches (bounded by
//! [`BatchConfig::max_batch`] and [`BatchConfig::max_wait`]) and runs one
//! forward per batch — inside which every sparse kernel partitions its
//! work across `PROXCOMP_THREADS` lanes (`util::pool`), row-wise when
//! the batch alone cannot feed them.
//!
//! Coalescing is only sound because the kernels make it so: every output
//! row is computed with a fixed per-row reduction order, so a sample's
//! logits are bit-identical whether it was served alone or inside any
//! micro-batch (`tests/property.rs::prop_batch_server_matches_per_sample_forward`).
//! The one exception is models whose forward uses *batch statistics*
//! (legacy batch-norm bundles without running-stat leaves): their logits
//! depend on batch composition, so [`BatchServer::start`] pins
//! `max_batch` to 1 for them (`Engine::uses_batch_stats`) instead of
//! trusting the caller. Checkpoints carrying folded running stats wire
//! inference-mode BN, which is elementwise — `resnet-s` trained by the
//! native backend coalesces like any other model.
//!
//! Failure isolation: one bad batch must never take the server down. A
//! forward that returns an error — or panics, or hands back a tensor
//! whose shape cannot be fanned out row-per-request — answers *every*
//! request in that batch with an error and the worker moves on to the
//! next batch. The stats mutex is recovered if poisoned, so a panic
//! mid-batch cannot cascade into `stats()`/`shutdown()` panics.
//!
//! Throughput and latency counters (including fixed-bucket latency
//! percentiles) are surfaced as [`crate::metrics::ServingStats`] via
//! [`BatchServer::stats`].

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::inference::Engine;
use crate::metrics::{LatencyHistogram, ServingStats};
use crate::telemetry;
use crate::tensor::Tensor;

/// Coalescing knobs for a [`BatchServer`].
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Micro-batch ceiling: a forward never sees more samples than this.
    pub max_batch: usize,
    /// How long the worker holds an open batch waiting for more samples
    /// once the first one arrives (the latency the server may add to buy
    /// throughput).
    pub max_wait: Duration,
    /// Per-sample input shape (C, H, W); every request carries C·H·W
    /// floats and the engine sees `(batch, C, H, W)` tensors.
    pub input_shape: (usize, usize, usize),
}

impl BatchConfig {
    pub fn new(max_batch: usize, max_wait: Duration, input_shape: (usize, usize, usize)) -> Self {
        BatchConfig { max_batch: max_batch.max(1), max_wait, input_shape }
    }

    /// Floats per sample (C·H·W) — also the wire protocol's frame size
    /// contract (`inference::net`).
    pub fn sample_len(&self) -> usize {
        let (c, h, w) = self.input_shape;
        c * h * w
    }
}

/// One queued request: the flattened sample plus the channel its logits
/// travel back on. Errors cross the channel as strings (`anyhow::Error`
/// is not `Clone`, and one failed batch answers many requests).
struct Request {
    data: Vec<f32>,
    submitted: Instant,
    /// Telemetry trace id following the request admission→coalesce→
    /// forward→reply (0 when tracing is disabled).
    trace_id: u64,
    resp: Sender<Result<Vec<f32>, String>>,
}

/// Handle to an in-flight request returned by [`BatchServer::submit`].
pub struct Pending {
    rx: Receiver<Result<Vec<f32>, String>>,
}

/// What became of a request waited on with a deadline
/// ([`Pending::wait_outcome`]). The network front-end maps these onto
/// its wire error taxonomy.
pub enum WaitOutcome {
    /// The worker answered: per-request logits, or the engine/batch
    /// error fanned back to every member of the failed batch.
    Ready(Result<Vec<f32>, String>),
    /// The deadline elapsed first. The request may still complete later;
    /// its answer is discarded when this handle drops.
    TimedOut,
    /// The server dropped the request without answering (shutdown race).
    Dropped,
}

impl Pending {
    /// Block until the request's logits arrive.
    pub fn wait(self) -> anyhow::Result<Vec<f32>> {
        match self.rx.recv() {
            Ok(Ok(logits)) => Ok(logits),
            Ok(Err(e)) => Err(anyhow::anyhow!(e)),
            Err(_) => Err(anyhow::anyhow!("batch server dropped the request")),
        }
    }

    /// Block until the logits arrive or `timeout` elapses — the
    /// per-request deadline primitive the wire front-end builds on.
    pub fn wait_outcome(self, timeout: Duration) -> WaitOutcome {
        match self.rx.recv_timeout(timeout) {
            Ok(r) => WaitOutcome::Ready(r),
            Err(RecvTimeoutError::Timeout) => WaitOutcome::TimedOut,
            Err(RecvTimeoutError::Disconnected) => WaitOutcome::Dropped,
        }
    }
}

/// Counters the worker accumulates per batch. Only the worker writes
/// (the channel is FIFO, so the first request it drains carries the
/// process-wide first submit stamp): the mutex is touched once per
/// batch, never on the submit hot path, so contention is negligible
/// next to a forward. Latency lands in a fixed-bucket histogram —
/// recording is a counter bump, no allocation.
#[derive(Default)]
struct StatsInner {
    requests: usize,
    batches: usize,
    max_batch: usize,
    latency: LatencyHistogram,
    total_forward_us: f64,
    first_submit: Option<Instant>,
    last_done: Option<Instant>,
}

/// Lock the stats mutex, recovering from poisoning. A panic while the
/// guard was held can at worst leave the counters of one batch half
/// applied — stale numbers, never unsafety — so recovering beats turning
/// one panic into a panic in every later `stats()`/`shutdown()` caller.
fn lock_stats(stats: &Mutex<StatsInner>) -> MutexGuard<'_, StatsInner> {
    stats.lock().unwrap_or_else(PoisonError::into_inner)
}

/// A serving front-end over one shared [`Engine`]: callers submit single
/// samples from any thread; a worker coalesces them into micro-batches
/// and fans the per-row logits back out. All methods take `&self` (the
/// sender/worker handles live behind mutexes), so a `BatchServer` can be
/// shared across connection-handler threads via `Arc` and still shut
/// down gracefully.
pub struct BatchServer {
    cfg: BatchConfig,
    engine: Arc<Engine>,
    tx: Mutex<Option<Sender<Request>>>,
    worker: Mutex<Option<JoinHandle<()>>>,
    stats: Arc<Mutex<StatsInner>>,
}

impl BatchServer {
    /// Spawn the coalescing worker around a shared engine. For engines
    /// whose forward uses batch statistics (`Engine::uses_batch_stats`,
    /// legacy BN bundles without running stats) the micro-batch size is
    /// pinned to 1 — coalescing would silently change per-sample logits.
    /// Inference-mode BN folds running stats per element, so those
    /// engines keep the configured ceiling.
    pub fn start(engine: Arc<Engine>, cfg: BatchConfig) -> BatchServer {
        let mut cfg = cfg;
        if engine.uses_batch_stats() {
            cfg.max_batch = 1;
        }
        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let worker = {
            let stats = Arc::clone(&stats);
            let engine = Arc::clone(&engine);
            let cfg = cfg.clone();
            std::thread::spawn(move || worker_loop(engine, cfg, rx, stats))
        };
        BatchServer { cfg, engine, tx: Mutex::new(Some(tx)), worker: Mutex::new(Some(worker)), stats }
    }

    /// The coalescing configuration actually in effect (after any
    /// batch-statistics pin).
    pub fn config(&self) -> &BatchConfig {
        &self.cfg
    }

    /// Queue one flattened sample; returns a [`Pending`] to wait on.
    /// Fails fast when the sample length does not match `input_shape`.
    pub fn submit(&self, sample: &[f32]) -> anyhow::Result<Pending> {
        self.submit_traced(sample, telemetry::next_trace_id())
    }

    /// [`submit`](Self::submit) with a caller-supplied trace id, so a
    /// front-end that already stamped the request (e.g. the TCP server)
    /// keeps one id across admission, coalescing, forward, and reply.
    pub fn submit_traced(&self, sample: &[f32], trace_id: u64) -> anyhow::Result<Pending> {
        anyhow::ensure!(
            sample.len() == self.cfg.sample_len(),
            "sample has {} values, input shape {:?} needs {}",
            sample.len(),
            self.cfg.input_shape,
            self.cfg.sample_len()
        );
        if telemetry::trace_enabled() {
            telemetry::event_label("server.admit", trace_id, &self.engine.model, &[]);
        }
        let (rtx, rrx) = channel();
        let req = Request { data: sample.to_vec(), submitted: Instant::now(), trace_id, resp: rtx };
        self.tx
            .lock()
            .unwrap_or_else(PoisonError::into_inner)
            .as_ref()
            .and_then(|tx| tx.send(req).ok())
            .ok_or_else(|| anyhow::anyhow!("batch server is shut down"))?;
        Ok(Pending { rx: rrx })
    }

    /// Submit one sample and block until its logits arrive.
    pub fn infer(&self, sample: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.submit(sample)?.wait()
    }

    /// Throughput/latency counters accumulated so far.
    pub fn stats(&self) -> ServingStats {
        let s = lock_stats(&self.stats);
        let wall_secs = match (s.first_submit, s.last_done) {
            (Some(first), Some(last)) => last.duration_since(first).as_secs_f64(),
            _ => 0.0,
        };
        ServingStats {
            requests: s.requests,
            batches: s.batches,
            max_batch: s.max_batch,
            mean_batch: if s.batches == 0 { 0.0 } else { s.requests as f64 / s.batches as f64 },
            mean_latency_us: s.latency.mean_us(),
            mean_forward_us: if s.batches == 0 { 0.0 } else { s.total_forward_us / s.batches as f64 },
            throughput_rps: if wall_secs > 0.0 { s.requests as f64 / wall_secs } else { 0.0 },
            p50_latency_us: s.latency.percentile(0.50),
            p90_latency_us: s.latency.percentile(0.90),
            p99_latency_us: s.latency.percentile(0.99),
            max_latency_us: s.latency.max_us(),
            layers: self.engine.profile(),
        }
    }

    /// Snapshot of the raw latency histogram, for fleet-level merging
    /// (the registry adds resident servers' buckets together to get true
    /// aggregate percentiles).
    pub fn latency_histogram(&self) -> LatencyHistogram {
        lock_stats(&self.stats).latency.clone()
    }

    /// The engine this server batches onto (for per-layer profiles).
    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Stop accepting requests, drain the queue, and join the worker
    /// (also runs on drop). In-flight requests are still answered.
    pub fn shutdown(&self) {
        self.tx.lock().unwrap_or_else(PoisonError::into_inner).take();
        let worker = self.worker.lock().unwrap_or_else(PoisonError::into_inner).take();
        if let Some(worker) = worker {
            let _ = worker.join();
        }
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Render a caught panic payload (the `&str`/`String` cases cover every
/// `panic!`/`assert!` in the kernel code).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

fn worker_loop(engine: Arc<Engine>, cfg: BatchConfig, rx: Receiver<Request>, stats: Arc<Mutex<StatsInner>>) {
    let (c, h, w) = cfg.input_shape;
    let sample_len = cfg.sample_len();
    loop {
        // Block for the batch's first sample; a closed channel (server
        // dropped) after the queue drains ends the worker.
        let first = match rx.recv() {
            Ok(req) => req,
            Err(_) => return,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        let m = batch.len();
        let first_submitted = batch[0].submitted;
        if telemetry::trace_enabled() {
            for req in &batch {
                telemetry::event("server.coalesce", req.trace_id, &[("batch", m as f64)]);
            }
        }
        let mut xs = Vec::with_capacity(m * sample_len);
        for req in &batch {
            xs.extend_from_slice(&req.data);
        }
        let x = Tensor::new(vec![m, c, h, w], xs);
        let t0 = Instant::now();
        // A panicking forward (dimension assert deep in a kernel, say)
        // must not kill the worker: every queued request would silently
        // hang up. The kernels spawn per-call scoped threads (no
        // persistent pool state), so unwinding here is clean; convert
        // the panic into the same fan-out path as an engine error.
        let result = catch_unwind(AssertUnwindSafe(|| engine.forward(&x)))
            .unwrap_or_else(|p| Err(anyhow::anyhow!("engine forward panicked: {}", panic_message(p.as_ref()))));
        let forward_us = t0.elapsed().as_secs_f64() * 1e6;
        let done = Instant::now();

        // Record the batch *before* fanning responses out, so a caller
        // that queries `stats()` right after its `wait()` returns always
        // sees its own request counted.
        {
            let mut s = lock_stats(&stats);
            s.first_submit.get_or_insert(first_submitted);
            s.requests += m;
            s.batches += 1;
            s.max_batch = s.max_batch.max(m);
            for req in &batch {
                s.latency.record(done.duration_since(req.submitted).as_secs_f64() * 1e6);
            }
            s.total_forward_us += forward_us;
            s.last_done = Some(done);
        }
        if telemetry::trace_enabled() {
            for req in &batch {
                let latency_us = done.duration_since(req.submitted).as_secs_f64() * 1e6;
                telemetry::event(
                    "server.reply",
                    req.trace_id,
                    &[("latency_us", latency_us), ("forward_us", forward_us), ("batch", m as f64)],
                );
            }
        }

        // Fan out. The per-sample row length is only trustworthy when
        // the engine really returned one row per batched sample; a short
        // or non-divisible output used to panic the slicing below and
        // drop every queued request on the floor.
        let fan_error = |batch: Vec<Request>, msg: String| {
            for req in batch.into_iter() {
                let _ = req.resp.send(Err(msg.clone()));
            }
        };
        match result {
            Ok(logits) => {
                let rows_ok = logits.shape.first() == Some(&m);
                let per = logits.data.len() / m;
                if rows_ok && per > 0 && logits.data.len() == m * per {
                    for (i, req) in batch.into_iter().enumerate() {
                        let row = logits.data[i * per..(i + 1) * per].to_vec();
                        let _ = req.resp.send(Ok(row));
                    }
                } else {
                    fan_error(
                        batch,
                        format!(
                            "engine forward returned a malformed batch: shape {:?} ({} values) for {m} samples",
                            logits.shape,
                            logits.data.len()
                        ),
                    );
                }
            }
            Err(e) => fan_error(batch, format!("engine forward failed: {e}")),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::WeightMode;
    use crate::runtime::{ParamBundle, ParamSpec};
    use crate::sparse::prox;
    use crate::util::rng::Rng;

    fn tiny_mlp_engine(seed: u64) -> Engine {
        let specs = vec![
            ParamSpec::new("fc1_w", "fc_w", vec![32, 784], true),
            ParamSpec::new("fc1_b", "fc_b", vec![32], false),
            ParamSpec::new("fc2_w", "fc_w", vec![16, 32], true),
            ParamSpec::new("fc2_b", "fc_b", vec![16], false),
            ParamSpec::new("fc3_w", "fc_w", vec![10, 16], true),
            ParamSpec::new("fc3_b", "fc_b", vec![10], false),
        ];
        let mut bundle = ParamBundle::he_init(&specs, seed);
        for (s, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
            if s.prunable {
                prox::soft_threshold_inplace(v, 0.05);
            }
        }
        Engine::builder("mlp").bundle(&bundle).mode(WeightMode::Csr).build().unwrap()
    }

    #[test]
    fn serves_single_requests() {
        let engine = Arc::new(tiny_mlp_engine(1));
        // An FC-only model has no batch-statistics layers: coalescing is
        // sound and `start` keeps the configured ceiling.
        assert!(!engine.uses_batch_stats());
        let server = BatchServer::start(
            Arc::clone(&engine),
            BatchConfig::new(4, Duration::from_millis(1), (1, 28, 28)),
        );
        let mut rng = Rng::new(2);
        let sample = rng.normal_vec(784, 1.0);
        let logits = server.infer(&sample).unwrap();
        assert_eq!(logits.len(), 10);
        let x = Tensor::new(vec![1, 1, 28, 28], sample);
        assert_eq!(logits, engine.forward(&x).unwrap().data);
        let stats = server.stats();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.batches, 1);
    }

    #[test]
    fn coalesces_up_to_max_batch() {
        let engine = Arc::new(tiny_mlp_engine(3));
        let server = BatchServer::start(
            Arc::clone(&engine),
            BatchConfig::new(4, Duration::from_millis(200), (1, 28, 28)),
        );
        let mut rng = Rng::new(4);
        let pendings: Vec<(Vec<f32>, Pending)> = (0..9)
            .map(|_| {
                let s = rng.normal_vec(784, 1.0);
                let p = server.submit(&s).unwrap();
                (s, p)
            })
            .collect();
        for (sample, pending) in pendings {
            let got = pending.wait().unwrap();
            let x = Tensor::new(vec![1, 1, 28, 28], sample);
            assert_eq!(got, engine.forward(&x).unwrap().data);
        }
        let stats = server.stats();
        assert_eq!(stats.requests, 9);
        assert!(stats.max_batch <= 4);
        // 9 requests through batches of ≤ 4 need at least 3 forwards.
        assert!(stats.batches >= 3, "batches {}", stats.batches);
        assert!(stats.throughput_rps > 0.0);
    }

    #[test]
    fn rejects_wrong_sample_length() {
        let engine = Arc::new(tiny_mlp_engine(5));
        let server =
            BatchServer::start(engine, BatchConfig::new(2, Duration::from_millis(1), (1, 28, 28)));
        assert!(server.submit(&[0.0; 7]).is_err());
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let engine = Arc::new(tiny_mlp_engine(6));
        let server =
            BatchServer::start(engine, BatchConfig::new(2, Duration::from_millis(1), (1, 28, 28)));
        server.shutdown();
        assert!(server.submit(&[0.0; 784]).is_err());
    }

    #[test]
    fn engine_failure_fans_out_to_all_requesters_and_server_survives() {
        // The configured input shape lies about the model: 8-float
        // samples pass submit's length check but blow up inside the
        // engine (784-column first layer). Every requester in the batch
        // must get the error back — not a hung/dropped channel — and the
        // worker must survive to serve the next batch.
        let engine = Arc::new(tiny_mlp_engine(7));
        let server = BatchServer::start(
            Arc::clone(&engine),
            BatchConfig::new(4, Duration::from_millis(5), (1, 1, 8)),
        );
        for round in 0..2 {
            let pendings: Vec<Pending> = (0..3).map(|_| server.submit(&[0.5; 8]).unwrap()).collect();
            for p in pendings {
                let err = p.wait().unwrap_err().to_string();
                assert!(err.contains("engine forward"), "round {round}: unexpected error {err:?}");
            }
        }
        // The worker processed both batches and the stats lock is fine.
        let stats = server.stats();
        assert_eq!(stats.requests, 6);
        assert!(stats.batches >= 2);
        server.shutdown(); // must not panic either
    }

    #[test]
    fn stats_survive_a_poisoned_lock() {
        let engine = Arc::new(tiny_mlp_engine(8));
        let server = BatchServer::start(
            Arc::clone(&engine),
            BatchConfig::new(2, Duration::from_millis(1), (1, 28, 28)),
        );
        let sample = Rng::new(9).normal_vec(784, 1.0);
        server.infer(&sample).unwrap();
        // Poison the stats mutex the way a panicking worker would have
        // before the recovery fix: panic while holding the guard.
        {
            let stats = Arc::clone(&server.stats);
            let _ = std::thread::spawn(move || {
                let _guard = stats.lock().unwrap();
                panic!("simulated worker panic while holding the stats lock");
            })
            .join();
        }
        // Both the read side and the worker's write side must recover.
        assert_eq!(server.stats().requests, 1);
        server.infer(&sample).unwrap();
        assert_eq!(server.stats().requests, 2);
    }

    #[test]
    fn shutdown_drains_in_flight_requests() {
        // Five requests sit in the queue (the long max_wait holds the
        // batch open); shutdown must answer all of them, not drop them.
        let engine = Arc::new(tiny_mlp_engine(10));
        let server = BatchServer::start(
            Arc::clone(&engine),
            BatchConfig::new(8, Duration::from_millis(300), (1, 28, 28)),
        );
        let mut rng = Rng::new(11);
        let pendings: Vec<Pending> =
            (0..5).map(|_| server.submit(&rng.normal_vec(784, 1.0)).unwrap()).collect();
        server.shutdown();
        for p in pendings {
            assert_eq!(p.wait().unwrap().len(), 10);
        }
        assert_eq!(server.stats().requests, 5);
    }

    #[test]
    fn latency_percentiles_populated() {
        let engine = Arc::new(tiny_mlp_engine(12));
        let server = BatchServer::start(
            Arc::clone(&engine),
            BatchConfig::new(4, Duration::from_millis(1), (1, 28, 28)),
        );
        let mut rng = Rng::new(13);
        for _ in 0..10 {
            server.infer(&rng.normal_vec(784, 1.0)).unwrap();
        }
        let stats = server.stats();
        assert_eq!(stats.requests, 10);
        assert!(stats.p50_latency_us > 0.0, "{stats:?}");
        assert!(stats.p90_latency_us >= stats.p50_latency_us);
        assert!(stats.p99_latency_us >= stats.p90_latency_us);
        assert!(stats.max_latency_us >= stats.p99_latency_us);
    }

    #[test]
    fn wait_outcome_timeout_and_ready() {
        let engine = Arc::new(tiny_mlp_engine(14));
        let server = BatchServer::start(
            Arc::clone(&engine),
            BatchConfig::new(8, Duration::from_millis(400), (1, 28, 28)),
        );
        let mut rng = Rng::new(15);
        // The worker holds the batch open for 400 ms, so a 10 ms
        // deadline fires first.
        let p = server.submit(&rng.normal_vec(784, 1.0)).unwrap();
        assert!(matches!(p.wait_outcome(Duration::from_millis(10)), WaitOutcome::TimedOut));
        // And a generous deadline sees the answer.
        let p = server.submit(&rng.normal_vec(784, 1.0)).unwrap();
        match p.wait_outcome(Duration::from_secs(10)) {
            WaitOutcome::Ready(Ok(logits)) => assert_eq!(logits.len(), 10),
            other => panic!("expected Ready(Ok), got {}", describe(&other)),
        }
    }

    fn describe(o: &WaitOutcome) -> &'static str {
        match o {
            WaitOutcome::Ready(Ok(_)) => "Ready(Ok)",
            WaitOutcome::Ready(Err(_)) => "Ready(Err)",
            WaitOutcome::TimedOut => "TimedOut",
            WaitOutcome::Dropped => "Dropped",
        }
    }
}
