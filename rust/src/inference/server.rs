//! Batched multi-threaded serving: queue single-sample requests,
//! coalesce them into micro-batches, run them through a shared
//! [`Engine`].
//!
//! The paper's end goal is fast inference of compressed models on small
//! parallel devices, and EIE (Han et al., 2016) shows the throughput win
//! comes from keeping the compressed format *and* saturating all lanes.
//! [`BatchServer`] supplies the serving half of that: a worker thread
//! drains a request queue into micro-batches (bounded by
//! [`BatchConfig::max_batch`] and [`BatchConfig::max_wait`]) and runs one
//! forward per batch — inside which every sparse kernel partitions its
//! work across `PROXCOMP_THREADS` lanes (`util::pool`), row-wise when
//! the batch alone cannot feed them.
//!
//! Coalescing is only sound because the kernels make it so: every output
//! row is computed with a fixed per-row reduction order, so a sample's
//! logits are bit-identical whether it was served alone or inside any
//! micro-batch (`tests/property.rs::prop_batch_server_matches_per_sample_forward`).
//! The one exception is models whose forward uses *batch statistics*
//! (the `resnet_s` batch-norm path): their logits depend on batch
//! composition, so [`BatchServer::start`] pins `max_batch` to 1 for them
//! (`Engine::uses_batch_stats`) instead of trusting the caller.
//!
//! Throughput and latency counters are surfaced as
//! [`crate::metrics::ServingStats`] via [`BatchServer::stats`].

use std::sync::mpsc::{channel, Receiver, RecvTimeoutError, Sender};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::inference::Engine;
use crate::metrics::ServingStats;
use crate::tensor::Tensor;

/// Coalescing knobs for a [`BatchServer`].
#[derive(Debug, Clone)]
pub struct BatchConfig {
    /// Micro-batch ceiling: a forward never sees more samples than this.
    pub max_batch: usize,
    /// How long the worker holds an open batch waiting for more samples
    /// once the first one arrives (the latency the server may add to buy
    /// throughput).
    pub max_wait: Duration,
    /// Per-sample input shape (C, H, W); every request carries C·H·W
    /// floats and the engine sees `(batch, C, H, W)` tensors.
    pub input_shape: (usize, usize, usize),
}

impl BatchConfig {
    pub fn new(max_batch: usize, max_wait: Duration, input_shape: (usize, usize, usize)) -> Self {
        BatchConfig { max_batch: max_batch.max(1), max_wait, input_shape }
    }

    fn sample_len(&self) -> usize {
        let (c, h, w) = self.input_shape;
        c * h * w
    }
}

/// One queued request: the flattened sample plus the channel its logits
/// travel back on. Errors cross the channel as strings (`anyhow::Error`
/// is not `Clone`, and one failed batch answers many requests).
struct Request {
    data: Vec<f32>,
    submitted: Instant,
    resp: Sender<Result<Vec<f32>, String>>,
}

/// Handle to an in-flight request returned by [`BatchServer::submit`].
pub struct Pending {
    rx: Receiver<Result<Vec<f32>, String>>,
}

impl Pending {
    /// Block until the request's logits arrive.
    pub fn wait(self) -> anyhow::Result<Vec<f32>> {
        match self.rx.recv() {
            Ok(Ok(logits)) => Ok(logits),
            Ok(Err(e)) => Err(anyhow::anyhow!(e)),
            Err(_) => Err(anyhow::anyhow!("batch server dropped the request")),
        }
    }
}

/// Counters the worker accumulates per batch. Only the worker writes
/// (the channel is FIFO, so the first request it drains carries the
/// process-wide first submit stamp): the mutex is touched once per
/// batch, never on the submit hot path, so contention is negligible
/// next to a forward.
#[derive(Default)]
struct StatsInner {
    requests: usize,
    batches: usize,
    max_batch: usize,
    total_latency_us: f64,
    total_forward_us: f64,
    first_submit: Option<Instant>,
    last_done: Option<Instant>,
}

/// A serving front-end over one shared [`Engine`]: callers submit single
/// samples from any thread; a worker coalesces them into micro-batches
/// and fans the per-row logits back out.
pub struct BatchServer {
    cfg: BatchConfig,
    tx: Option<Sender<Request>>,
    worker: Option<JoinHandle<()>>,
    stats: Arc<Mutex<StatsInner>>,
}

impl BatchServer {
    /// Spawn the coalescing worker around a shared engine. For engines
    /// whose forward uses batch statistics (`Engine::uses_batch_stats`,
    /// the `resnet_s` batch-norm path) the micro-batch size is pinned to
    /// 1 — coalescing would silently change per-sample logits.
    pub fn start(engine: Arc<Engine>, cfg: BatchConfig) -> BatchServer {
        let mut cfg = cfg;
        if engine.uses_batch_stats() {
            cfg.max_batch = 1;
        }
        let (tx, rx) = channel::<Request>();
        let stats = Arc::new(Mutex::new(StatsInner::default()));
        let worker = {
            let stats = Arc::clone(&stats);
            let cfg = cfg.clone();
            std::thread::spawn(move || worker_loop(engine, cfg, rx, stats))
        };
        BatchServer { cfg, tx: Some(tx), worker: Some(worker), stats }
    }

    /// Queue one flattened sample; returns a [`Pending`] to wait on.
    /// Fails fast when the sample length does not match `input_shape`.
    pub fn submit(&self, sample: &[f32]) -> anyhow::Result<Pending> {
        anyhow::ensure!(
            sample.len() == self.cfg.sample_len(),
            "sample has {} values, input shape {:?} needs {}",
            sample.len(),
            self.cfg.input_shape,
            self.cfg.sample_len()
        );
        let (rtx, rrx) = channel();
        let req = Request { data: sample.to_vec(), submitted: Instant::now(), resp: rtx };
        self.tx
            .as_ref()
            .and_then(|tx| tx.send(req).ok())
            .ok_or_else(|| anyhow::anyhow!("batch server is shut down"))?;
        Ok(Pending { rx: rrx })
    }

    /// Submit one sample and block until its logits arrive.
    pub fn infer(&self, sample: &[f32]) -> anyhow::Result<Vec<f32>> {
        self.submit(sample)?.wait()
    }

    /// Throughput/latency counters accumulated so far.
    pub fn stats(&self) -> ServingStats {
        let s = self.stats.lock().unwrap();
        let wall_secs = match (s.first_submit, s.last_done) {
            (Some(first), Some(last)) => last.duration_since(first).as_secs_f64(),
            _ => 0.0,
        };
        ServingStats {
            requests: s.requests,
            batches: s.batches,
            max_batch: s.max_batch,
            mean_batch: if s.batches == 0 { 0.0 } else { s.requests as f64 / s.batches as f64 },
            mean_latency_us: if s.requests == 0 {
                0.0
            } else {
                s.total_latency_us / s.requests as f64
            },
            mean_forward_us: if s.batches == 0 { 0.0 } else { s.total_forward_us / s.batches as f64 },
            throughput_rps: if wall_secs > 0.0 { s.requests as f64 / wall_secs } else { 0.0 },
        }
    }

    /// Stop accepting requests, drain the queue, and join the worker
    /// (also runs on drop). In-flight requests are still answered.
    pub fn shutdown(&mut self) {
        self.tx.take();
        if let Some(worker) = self.worker.take() {
            let _ = worker.join();
        }
    }
}

impl Drop for BatchServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn worker_loop(
    engine: Arc<Engine>,
    cfg: BatchConfig,
    rx: Receiver<Request>,
    stats: Arc<Mutex<StatsInner>>,
) {
    let (c, h, w) = cfg.input_shape;
    let sample_len = cfg.sample_len();
    loop {
        // Block for the batch's first sample; a closed channel (server
        // dropped) after the queue drains ends the worker.
        let first = match rx.recv() {
            Ok(req) => req,
            Err(_) => return,
        };
        let mut batch = vec![first];
        let deadline = Instant::now() + cfg.max_wait;
        while batch.len() < cfg.max_batch {
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            match rx.recv_timeout(deadline - now) {
                Ok(req) => batch.push(req),
                Err(RecvTimeoutError::Timeout) | Err(RecvTimeoutError::Disconnected) => break,
            }
        }

        let m = batch.len();
        let first_submitted = batch[0].submitted;
        let mut xs = Vec::with_capacity(m * sample_len);
        for req in &batch {
            xs.extend_from_slice(&req.data);
        }
        let x = Tensor::new(vec![m, c, h, w], xs);
        let t0 = Instant::now();
        let result = engine.forward(&x);
        let forward_us = t0.elapsed().as_secs_f64() * 1e6;
        let done = Instant::now();

        // Record the batch *before* fanning responses out, so a caller
        // that queries `stats()` right after its `wait()` returns always
        // sees its own request counted.
        let latency_us: f64 = batch
            .iter()
            .map(|req| done.duration_since(req.submitted).as_secs_f64() * 1e6)
            .sum();
        {
            let mut s = stats.lock().unwrap();
            s.first_submit.get_or_insert(first_submitted);
            s.requests += m;
            s.batches += 1;
            s.max_batch = s.max_batch.max(m);
            s.total_latency_us += latency_us;
            s.total_forward_us += forward_us;
            s.last_done = Some(done);
        }

        match result {
            Ok(logits) => {
                let per = logits.data.len() / m;
                for (i, req) in batch.into_iter().enumerate() {
                    let row = logits.data[i * per..(i + 1) * per].to_vec();
                    let _ = req.resp.send(Ok(row));
                }
            }
            Err(e) => {
                let msg = format!("engine forward failed: {e}");
                for req in batch.into_iter() {
                    let _ = req.resp.send(Err(msg.clone()));
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::inference::WeightMode;
    use crate::runtime::{ParamBundle, ParamSpec};
    use crate::sparse::prox;
    use crate::util::rng::Rng;

    fn tiny_mlp_engine(seed: u64) -> Engine {
        let specs = vec![
            ParamSpec::new("fc1_w", "fc_w", vec![32, 784], true),
            ParamSpec::new("fc1_b", "fc_b", vec![32], false),
            ParamSpec::new("fc2_w", "fc_w", vec![16, 32], true),
            ParamSpec::new("fc2_b", "fc_b", vec![16], false),
            ParamSpec::new("fc3_w", "fc_w", vec![10, 16], true),
            ParamSpec::new("fc3_b", "fc_b", vec![10], false),
        ];
        let mut bundle = ParamBundle::he_init(&specs, seed);
        for (s, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
            if s.prunable {
                prox::soft_threshold_inplace(v, 0.05);
            }
        }
        Engine::from_bundle_mode("mlp", &bundle, WeightMode::Csr).unwrap()
    }

    #[test]
    fn serves_single_requests() {
        let engine = Arc::new(tiny_mlp_engine(1));
        // An FC-only model has no batch-statistics layers: coalescing is
        // sound and `start` keeps the configured ceiling.
        assert!(!engine.uses_batch_stats());
        let server = BatchServer::start(
            Arc::clone(&engine),
            BatchConfig::new(4, Duration::from_millis(1), (1, 28, 28)),
        );
        let mut rng = Rng::new(2);
        let sample = rng.normal_vec(784, 1.0);
        let logits = server.infer(&sample).unwrap();
        assert_eq!(logits.len(), 10);
        let x = Tensor::new(vec![1, 1, 28, 28], sample);
        assert_eq!(logits, engine.forward(&x).unwrap().data);
        let stats = server.stats();
        assert_eq!(stats.requests, 1);
        assert_eq!(stats.batches, 1);
    }

    #[test]
    fn coalesces_up_to_max_batch() {
        let engine = Arc::new(tiny_mlp_engine(3));
        let server = BatchServer::start(
            Arc::clone(&engine),
            BatchConfig::new(4, Duration::from_millis(200), (1, 28, 28)),
        );
        let mut rng = Rng::new(4);
        let pendings: Vec<(Vec<f32>, Pending)> = (0..9)
            .map(|_| {
                let s = rng.normal_vec(784, 1.0);
                let p = server.submit(&s).unwrap();
                (s, p)
            })
            .collect();
        for (sample, pending) in pendings {
            let got = pending.wait().unwrap();
            let x = Tensor::new(vec![1, 1, 28, 28], sample);
            assert_eq!(got, engine.forward(&x).unwrap().data);
        }
        let stats = server.stats();
        assert_eq!(stats.requests, 9);
        assert!(stats.max_batch <= 4);
        // 9 requests through batches of ≤ 4 need at least 3 forwards.
        assert!(stats.batches >= 3, "batches {}", stats.batches);
        assert!(stats.throughput_rps > 0.0);
    }

    #[test]
    fn rejects_wrong_sample_length() {
        let engine = Arc::new(tiny_mlp_engine(5));
        let server =
            BatchServer::start(engine, BatchConfig::new(2, Duration::from_millis(1), (1, 28, 28)));
        assert!(server.submit(&[0.0; 7]).is_err());
    }

    #[test]
    fn shutdown_rejects_new_requests() {
        let engine = Arc::new(tiny_mlp_engine(6));
        let mut server =
            BatchServer::start(engine, BatchConfig::new(2, Duration::from_millis(1), (1, 28, 28)));
        server.shutdown();
        assert!(server.submit(&[0.0; 784]).is_err());
    }
}
