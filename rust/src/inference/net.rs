//! Framed-TCP wire protocol over a [`ModelRegistry`] — the network
//! serving front-end.
//!
//! EIE's (Han et al., 2016) throughput story only counts if a request
//! *stream* can reach the compressed engine; in-process coalescing alone
//! gates nothing. [`NetServer`] listens on a TCP socket, decodes
//! length-prefixed frames with the same hardened, bounds-checked
//! discipline as checkpoint loading (explicit errors for every malformed
//! byte, hard caps before any allocation), applies admission control
//! (bounded in-flight requests — when full the caller gets an explicit
//! `overloaded` rejection instead of unbounded queueing), enforces a
//! per-request deadline, routes each request to its model's
//! [`crate::inference::BatchServer`] pool, and drains in-flight requests
//! before closing on graceful shutdown.
//!
//! # Wire format
//!
//! Every message (either direction) is one frame:
//!
//! ```text
//! frame    := len:u32le  payload                  (len = payload bytes, > 0)
//! request  := opcode:u8  body
//! response := status:u8  body
//! ```
//!
//! Request opcodes:
//!
//! | op | name        | body                                          |
//! |----|-------------|-----------------------------------------------|
//! | 1  | INFER       | `sample_len` f32 LE values (v1: default model)|
//! | 2  | STATS       | empty → JSON body (serving + net + per-model) |
//! | 3  | SHUTDOWN    | empty → begins graceful shutdown              |
//! | 4  | PING        | empty → empty OK                              |
//! | 5  | INFER_MODEL | `id_len:u8  id:utf-8  sample f32 LE` (v2)     |
//! | 6  | METRICS     | empty or `[0]` → versioned metrics JSON; `[1]` → Prometheus text |
//!
//! `INFER_MODEL` is the model-routed v2 of `INFER`: the body leads with
//! a one-byte id length and the UTF-8 model id, then the sample floats.
//! Plain `INFER` stays fully supported and routes to the registry's
//! default model, so v1 clients keep working against a fleet server
//! unchanged. Requests naming an unregistered id are answered
//! `unknown-model` — recoverable, the connection stays open.
//!
//! Response status 0 is OK (body: logits f32 LE for INFER/INFER_MODEL,
//! JSON for STATS, empty otherwise); nonzero is an [`ErrorCode`] with a
//! UTF-8 message body. Connections are persistent: a client may pipeline
//! many INFER frames over one socket. Recoverable request errors
//! (wrong-length, overloaded, deadline-exceeded, engine-error,
//! unknown-model) keep the connection open; protocol violations
//! (bad-frame) close it, because a mis-framed stream can never be
//! re-synchronized.
//!
//! Determinism contract: the server is a transparent transport. Logits
//! that cross the wire are the bytes `Engine::forward` produced —
//! `proxcomp loadtest` (and `tests/serving_net.rs`) verify bit-equality
//! against a local engine on every response.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::inference::registry::{ModelRegistry, SubmitError};
use crate::inference::server::WaitOutcome;
use crate::inference::{BatchConfig, Engine};
use crate::metrics::ServingStats;
use crate::telemetry;
use crate::util::cursor::{self, BoundedReader};
use crate::util::json::Json;

/// Absolute frame-size cap (either direction): no peer can make the
/// other allocate more than this from a length prefix.
pub const MAX_FRAME_BYTES: usize = 1 << 20;

/// Request opcodes (first payload byte).
pub const OP_INFER: u8 = 1;
pub const OP_STATS: u8 = 2;
pub const OP_SHUTDOWN: u8 = 3;
pub const OP_PING: u8 = 4;
/// Model-routed inference (wire v2): `id_len:u8 | id utf-8 | sample`.
pub const OP_INFER_MODEL: u8 = 5;
/// Metrics export: empty or `[METRICS_FORMAT_JSON]` body answers the
/// versioned metrics JSON snapshot (serving roll-up, wire counters,
/// per-model registry table, per-layer profiles);
/// `[METRICS_FORMAT_PROMETHEUS]` answers Prometheus text exposition.
pub const OP_METRICS: u8 = 6;

/// METRICS body byte selecting the JSON snapshot (also the default for
/// an empty body).
pub const METRICS_FORMAT_JSON: u8 = 0;
/// METRICS body byte selecting Prometheus text exposition format.
pub const METRICS_FORMAT_PROMETHEUS: u8 = 1;

/// Version stamp carried in the METRICS JSON snapshot (`"version"` key);
/// bumped whenever the snapshot's shape changes incompatibly.
pub const METRICS_VERSION: u64 = 1;

/// The serving error taxonomy — every non-OK response status byte.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum ErrorCode {
    /// Unparseable or oversized frame / unknown opcode. The stream can
    /// no longer be trusted; the server closes the connection.
    BadFrame = 1,
    /// INFER body length ≠ `sample_len × 4` bytes. Recoverable.
    WrongLength = 2,
    /// Admission control rejected the request: `max_inflight` requests
    /// are already in flight. Back off and retry. Recoverable.
    Overloaded = 3,
    /// The engine failed (or panicked) on the batch containing this
    /// request. Recoverable.
    EngineError = 4,
    /// The server is draining; no new work is admitted.
    ShuttingDown = 5,
    /// The per-request deadline elapsed before the batch completed.
    DeadlineExceeded = 6,
    /// INFER_MODEL named a model the registry does not know (or a v1
    /// INFER arrived with no default model configured). Recoverable —
    /// the client may go on to name a registered model.
    UnknownModel = 7,
}

impl ErrorCode {
    pub fn name(self) -> &'static str {
        match self {
            ErrorCode::BadFrame => "bad-frame",
            ErrorCode::WrongLength => "wrong-length",
            ErrorCode::Overloaded => "overloaded",
            ErrorCode::EngineError => "engine-error",
            ErrorCode::ShuttingDown => "shutting-down",
            ErrorCode::DeadlineExceeded => "deadline-exceeded",
            ErrorCode::UnknownModel => "unknown-model",
        }
    }

    pub fn from_u8(b: u8) -> Option<ErrorCode> {
        match b {
            1 => Some(ErrorCode::BadFrame),
            2 => Some(ErrorCode::WrongLength),
            3 => Some(ErrorCode::Overloaded),
            4 => Some(ErrorCode::EngineError),
            5 => Some(ErrorCode::ShuttingDown),
            6 => Some(ErrorCode::DeadlineExceeded),
            7 => Some(ErrorCode::UnknownModel),
            _ => None,
        }
    }

    /// All codes, for table-driven reporting.
    pub fn all() -> [ErrorCode; 7] {
        [
            ErrorCode::BadFrame,
            ErrorCode::WrongLength,
            ErrorCode::Overloaded,
            ErrorCode::EngineError,
            ErrorCode::ShuttingDown,
            ErrorCode::DeadlineExceeded,
            ErrorCode::UnknownModel,
        ]
    }
}

/// Network front-end knobs.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Bind address (`127.0.0.1:0` picks an ephemeral port).
    pub addr: String,
    /// Concurrent-connection ceiling; excess accepts are answered with
    /// an `overloaded` frame and closed.
    pub max_conns: usize,
    /// Admission cap: requests admitted (submitted to the batch queue)
    /// but not yet answered. The bounded queue that replaces unbounded
    /// buffering — beyond it, requests are rejected `overloaded`.
    pub max_inflight: usize,
    /// Per-request deadline, measured admission → response. A request
    /// that misses it is answered `deadline-exceeded` (its eventual
    /// engine result, if any, is discarded).
    pub request_timeout: Duration,
    /// How long a peer may stall mid-frame (bytes of a frame started but
    /// not finished) before the connection is dropped as bad.
    pub frame_stall: Duration,
}

impl Default for NetConfig {
    fn default() -> NetConfig {
        NetConfig {
            addr: "127.0.0.1:7733".to_string(),
            max_conns: 128,
            max_inflight: 256,
            request_timeout: Duration::from_secs(5),
            frame_stall: Duration::from_secs(10),
        }
    }
}

/// Wire-level counters, reported next to [`ServingStats`] by STATS.
#[derive(Debug, Clone, Default)]
pub struct NetCounters {
    pub accepted_conns: u64,
    pub rejected_conns: u64,
    pub ok_responses: u64,
    pub bad_frame: u64,
    pub wrong_length: u64,
    pub overloaded: u64,
    pub engine_error: u64,
    pub shutting_down: u64,
    pub deadline_exceeded: u64,
    pub unknown_model: u64,
}

impl NetCounters {
    fn count(&mut self, code: ErrorCode) {
        match code {
            ErrorCode::BadFrame => self.bad_frame += 1,
            ErrorCode::WrongLength => self.wrong_length += 1,
            ErrorCode::Overloaded => self.overloaded += 1,
            ErrorCode::EngineError => self.engine_error += 1,
            ErrorCode::ShuttingDown => self.shutting_down += 1,
            ErrorCode::DeadlineExceeded => self.deadline_exceeded += 1,
            ErrorCode::UnknownModel => self.unknown_model += 1,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("accepted_conns", Json::from(self.accepted_conns as usize))
            .set("rejected_conns", Json::from(self.rejected_conns as usize))
            .set("ok_responses", Json::from(self.ok_responses as usize))
            .set("bad_frame", Json::from(self.bad_frame as usize))
            .set("wrong_length", Json::from(self.wrong_length as usize))
            .set("overloaded", Json::from(self.overloaded as usize))
            .set("engine_error", Json::from(self.engine_error as usize))
            .set("shutting_down", Json::from(self.shutting_down as usize))
            .set("deadline_exceeded", Json::from(self.deadline_exceeded as usize))
            .set("unknown_model", Json::from(self.unknown_model as usize));
        j
    }
}

/// Shared state between the accept loop, connection handlers, and the
/// owning [`NetServer`] handle.
struct Shared {
    registry: Arc<ModelRegistry>,
    cfg: NetConfig,
    shutting_down: AtomicBool,
    inflight: AtomicUsize,
    conns: AtomicUsize,
    counters: Mutex<NetCounters>,
}

impl Shared {
    fn counters(&self) -> std::sync::MutexGuard<'_, NetCounters> {
        self.counters.lock().unwrap_or_else(PoisonError::into_inner)
    }

    /// Request-frame cap: opcode byte + id-length byte + a maximal id +
    /// the largest registered model's sample, with floor room for
    /// control frames. (Responses are bounded by the engine's output
    /// size, checked against [`MAX_FRAME_BYTES`] on write.)
    fn request_cap(&self) -> usize {
        (2 + u8::MAX as usize + self.registry.max_sample_len() * 4).clamp(64, MAX_FRAME_BYTES)
    }

    /// The STATS body: aggregate serving roll-up, wire counters, and
    /// the per-model registry table.
    fn stats_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("serving", self.registry.aggregate_stats().to_json())
            .set("net", self.counters().clone().to_json())
            .set("models", self.registry.stats_json());
        j
    }

    /// The METRICS body: the STATS snapshot plus a version stamp and the
    /// per-layer profiles of resident models. This is the shape
    /// [`crate::telemetry::prometheus_text`] renders from.
    fn metrics_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("version", Json::from(METRICS_VERSION as usize))
            .set("serving", self.registry.aggregate_stats().to_json())
            .set("net", self.counters().clone().to_json())
            .set("models", self.registry.stats_json())
            .set("profiles", self.registry.profiles_json());
        j
    }
}

/// RAII admission permit: released even if the handler errors mid-reply.
struct InflightPermit<'a>(&'a Shared);

impl Drop for InflightPermit<'_> {
    fn drop(&mut self) {
        self.0.inflight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// The framed-TCP serving front-end. `start` binds and spawns the accept
/// loop; `shutdown` (also on drop) stops accepting, drains every
/// in-flight request, and joins all threads.
pub struct NetServer {
    addr: SocketAddr,
    shared: Arc<Shared>,
    accept: Option<JoinHandle<()>>,
    handlers: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `cfg.addr` and serve one `engine` through a [`BatchServer`]
    /// built from `batch_cfg` — the single-model front-end, now a thin
    /// wrapper over a one-entry [`ModelRegistry`] whose default model is
    /// the engine itself.
    pub fn start(engine: Arc<Engine>, batch_cfg: BatchConfig, cfg: NetConfig) -> anyhow::Result<NetServer> {
        anyhow::ensure!(batch_cfg.sample_len() > 0, "batch config has an empty input shape");
        let id = engine.model.clone();
        let registry = Arc::new(ModelRegistry::single(&id, engine, batch_cfg));
        NetServer::start_registry(registry, cfg)
    }

    /// Bind `cfg.addr` and serve every model in `registry`. v1 `INFER`
    /// frames route to the registry's default model; v2 `INFER_MODEL`
    /// frames route by id.
    pub fn start_registry(registry: Arc<ModelRegistry>, cfg: NetConfig) -> anyhow::Result<NetServer> {
        anyhow::ensure!(cfg.max_inflight >= 1, "max_inflight must be at least 1");
        anyhow::ensure!(cfg.max_conns >= 1, "max_conns must be at least 1");
        anyhow::ensure!(!registry.model_ids().is_empty(), "registry has no models to serve");
        let listener = TcpListener::bind(&cfg.addr).map_err(|e| anyhow::anyhow!("binding {}: {e}", cfg.addr))?;
        let addr = listener.local_addr().map_err(|e| anyhow::anyhow!("local_addr: {e}"))?;
        let shared = Arc::new(Shared {
            registry,
            cfg,
            shutting_down: AtomicBool::new(false),
            inflight: AtomicUsize::new(0),
            conns: AtomicUsize::new(0),
            counters: Mutex::new(NetCounters::default()),
        });
        let handlers: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let accept = {
            let shared = Arc::clone(&shared);
            let handlers = Arc::clone(&handlers);
            std::thread::spawn(move || accept_loop(listener, shared, handlers))
        };
        Ok(NetServer { addr, shared, accept: Some(accept), handlers })
    }

    /// The actually-bound address (resolves `:0` ephemeral ports).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once a SHUTDOWN frame arrived or [`NetServer::shutdown`] ran.
    pub fn shutdown_requested(&self) -> bool {
        self.shared.shutting_down.load(Ordering::SeqCst)
    }

    /// Block until a client requests shutdown (the `proxcomp serve`
    /// foreground wait).
    pub fn wait_shutdown_requested(&self) {
        while !self.shutdown_requested() {
            std::thread::sleep(Duration::from_millis(50));
        }
    }

    /// Server-side serving stats (percentiles included) — the fleet
    /// aggregate when multiple models are registered.
    pub fn stats(&self) -> ServingStats {
        self.shared.registry.aggregate_stats()
    }

    /// The registry this front-end routes into (per-model stats,
    /// add/remove/evict while serving).
    pub fn registry(&self) -> &Arc<ModelRegistry> {
        &self.shared.registry
    }

    /// Wire-level counters.
    pub fn net_counters(&self) -> NetCounters {
        self.shared.counters().clone()
    }

    /// The STATS response body: `{"serving": ..., "net": ..., "models": ...}`.
    pub fn stats_json(&self) -> Json {
        self.shared.stats_json()
    }

    /// Graceful shutdown: stop accepting, let every connection finish
    /// its in-flight request (new frames are answered `shutting-down`),
    /// then drain and join the batch worker. Idempotent.
    pub fn shutdown(&mut self) {
        self.shared.shutting_down.store(true, Ordering::SeqCst);
        // Wake the blocking accept with a throwaway connection.
        let _ = TcpStream::connect_timeout(&self.addr, Duration::from_millis(500));
        if let Some(accept) = self.accept.take() {
            let _ = accept.join();
        }
        let handles: Vec<JoinHandle<()>> =
            std::mem::take(&mut *self.handlers.lock().unwrap_or_else(PoisonError::into_inner));
        for h in handles {
            let _ = h.join();
        }
        self.shared.registry.shutdown();
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        self.shutdown();
    }
}

fn accept_loop(listener: TcpListener, shared: Arc<Shared>, handlers: Arc<Mutex<Vec<JoinHandle<()>>>>) {
    loop {
        let (stream, _) = match listener.accept() {
            Ok(pair) => pair,
            Err(_) => {
                if shared.shutting_down.load(Ordering::SeqCst) {
                    return;
                }
                continue;
            }
        };
        if shared.shutting_down.load(Ordering::SeqCst) {
            let mut stream = stream;
            let _ = write_frame(&mut stream, ErrorCode::ShuttingDown as u8, b"server is shutting down");
            return;
        }
        let conns = shared.conns.load(Ordering::SeqCst);
        if conns >= shared.cfg.max_conns {
            let mut stream = stream;
            shared.counters().rejected_conns += 1;
            let _ = write_frame(
                &mut stream,
                ErrorCode::Overloaded as u8,
                format!("{conns} connections open (cap {})", shared.cfg.max_conns).as_bytes(),
            );
            continue;
        }
        shared.conns.fetch_add(1, Ordering::SeqCst);
        shared.counters().accepted_conns += 1;
        let handle = {
            let shared = Arc::clone(&shared);
            std::thread::spawn(move || handle_conn(stream, shared))
        };
        let mut guard = handlers.lock().unwrap_or_else(PoisonError::into_inner);
        guard.retain(|h| !h.is_finished());
        guard.push(handle);
    }
}

/// Decrement the connection count when a handler exits, however it exits.
struct ConnGuard<'a>(&'a Shared);

impl Drop for ConnGuard<'_> {
    fn drop(&mut self) {
        self.0.conns.fetch_sub(1, Ordering::SeqCst);
    }
}

fn handle_conn(mut stream: TcpStream, shared: Arc<Shared>) {
    let _guard = ConnGuard(&shared);
    let _ = stream.set_nodelay(true);
    // The read timeout is a poll interval: between frames it lets the
    // handler notice shutdown; mid-frame it feeds the stall clock.
    let _ = stream.set_read_timeout(Some(Duration::from_millis(50)));
    loop {
        match read_frame(&mut stream, shared.request_cap(), &shared.shutting_down, shared.cfg.frame_stall) {
            Ok(payload) => {
                if !handle_request(&payload, &mut stream, &shared) {
                    return;
                }
            }
            Err(FrameErr::Closed) => return,
            Err(FrameErr::ShuttingDown) => {
                let _ = write_error(&mut stream, ErrorCode::ShuttingDown, "server is shutting down", &shared);
                return;
            }
            Err(FrameErr::Bad(msg)) => {
                let _ = write_error(&mut stream, ErrorCode::BadFrame, &msg, &shared);
                return;
            }
        }
    }
}

/// Serve one decoded request frame. Returns false when the connection
/// should close (protocol violation, shutdown, or write failure).
fn handle_request(payload: &[u8], stream: &mut TcpStream, shared: &Shared) -> bool {
    // `read_frame` already rejected empty payloads; split `op | body`
    // on the shared bounded cursor anyway so there is no bare indexing
    // into untrusted bytes.
    let mut r = BoundedReader::new(payload, "frame");
    let op = match r.read_u8("opcode") {
        Ok(op) => op,
        Err(_) => {
            let _ = write_error(stream, ErrorCode::BadFrame, "empty request frame", shared);
            return false;
        }
    };
    let body = r.take_rest();
    match op {
        OP_INFER => handle_infer(None, body, stream, shared),
        OP_INFER_MODEL => match parse_infer_model_body(body) {
            Ok((id, sample)) => handle_infer(Some(id), sample, stream, shared),
            Err(msg) => {
                // A malformed id header means the frame layout itself is
                // wrong — protocol violation, close like any bad frame.
                let _ = write_error(stream, ErrorCode::BadFrame, &msg, shared);
                false
            }
        },
        OP_STATS => {
            if !body.is_empty() {
                let _ = write_error(stream, ErrorCode::BadFrame, "STATS takes no body", shared);
                return false;
            }
            write_ok(stream, shared.stats_json().to_string_pretty().as_bytes(), shared)
        }
        OP_METRICS => match body {
            [] | [METRICS_FORMAT_JSON] => {
                write_ok(stream, shared.metrics_json().to_string_pretty().as_bytes(), shared)
            }
            [METRICS_FORMAT_PROMETHEUS] => {
                let text = telemetry::prometheus_text(&shared.metrics_json());
                write_ok(stream, text.as_bytes(), shared)
            }
            _ => {
                let _ = write_error(
                    stream,
                    ErrorCode::BadFrame,
                    "METRICS body must be empty, [0] (JSON), or [1] (Prometheus)",
                    shared,
                );
                false
            }
        },
        OP_PING => {
            if !body.is_empty() {
                let _ = write_error(stream, ErrorCode::BadFrame, "PING takes no body", shared);
                return false;
            }
            write_ok(stream, &[], shared)
        }
        OP_SHUTDOWN => {
            shared.shutting_down.store(true, Ordering::SeqCst);
            let _ = write_ok(stream, &[], shared);
            false
        }
        other => {
            let _ = write_error(stream, ErrorCode::BadFrame, &format!("unknown opcode {other}"), shared);
            false
        }
    }
}

/// Split an INFER_MODEL body into `(model_id, sample_bytes)` on the
/// shared bounded cursor. Errors are frame-layout violations (the
/// caller answers `bad-frame`). Public because the `fuzz/` body target
/// drives it directly.
pub fn parse_infer_model_body(body: &[u8]) -> Result<(&str, &[u8]), String> {
    let mut r = BoundedReader::new(body, "INFER_MODEL body");
    let id_len = r
        .read_u8("id length")
        .map_err(|_| "INFER_MODEL body is empty (wants id_len | id | sample)".to_string())?
        as usize;
    if id_len == 0 {
        return Err("INFER_MODEL id length is 0".to_string());
    }
    let id_bytes = r.take(id_len, "model id").map_err(|_| {
        format!("INFER_MODEL id length {id_len} exceeds the remaining {} body bytes", body.len() - 1)
    })?;
    let id = std::str::from_utf8(id_bytes).map_err(|_| "INFER_MODEL id is not UTF-8".to_string())?;
    Ok((id, r.take_rest()))
}

/// Serve one inference request: `model` is `None` for v1 INFER (routes
/// to the default model) or the id carried by a v2 INFER_MODEL frame.
fn handle_infer(model: Option<&str>, body: &[u8], stream: &mut TcpStream, shared: &Shared) -> bool {
    if shared.shutting_down.load(Ordering::SeqCst) {
        let _ = write_error(stream, ErrorCode::ShuttingDown, "server is shutting down", shared);
        return false;
    }
    // Resolve the per-model sample length first: naming an unregistered
    // model is a recoverable request error, not a connection fault.
    let sample_len = match shared.registry.sample_len(model) {
        Ok(n) => n,
        Err(e) => return write_error(stream, ErrorCode::UnknownModel, &format!("{e}"), shared),
    };
    let want = sample_len * 4;
    if body.len() != want {
        let target = model.map(|m| format!("model {m:?}")).unwrap_or_else(|| "the model".to_string());
        return write_error(
            stream,
            ErrorCode::WrongLength,
            &format!("INFER body is {} bytes; {target} wants {sample_len} f32s = {want} bytes", body.len()),
            shared,
        );
    }
    // Admission control: a bounded in-flight window instead of an
    // unbounded queue. `fetch_add` first so two racing requests can't
    // both sneak under the cap.
    let prev = shared.inflight.fetch_add(1, Ordering::SeqCst);
    if prev >= shared.cfg.max_inflight {
        shared.inflight.fetch_sub(1, Ordering::SeqCst);
        return write_error(
            stream,
            ErrorCode::Overloaded,
            &format!("{prev} requests in flight (cap {}); retry later", shared.cfg.max_inflight),
            shared,
        );
    }
    let _permit = InflightPermit(shared);
    // One trace id per admitted frame, threaded through the registry
    // into the pool so admit/coalesce/reply events share it.
    let trace_id = telemetry::next_trace_id();
    if telemetry::trace_enabled() {
        telemetry::event_label(
            "net.request",
            trace_id,
            model.unwrap_or("(default)"),
            &[("bytes", body.len() as f64)],
        );
    }
    let mut sample = Vec::with_capacity(sample_len);
    for c in body.chunks_exact(4) {
        sample.push(f32::from_le_bytes([c[0], c[1], c[2], c[3]]));
    }
    let pending = match shared.registry.submit_traced(model, &sample, trace_id) {
        Ok(p) => p,
        // A model can disappear (remove_model) between the length check
        // and the submit — still recoverable for the connection.
        Err(e @ SubmitError::UnknownModel(_)) => {
            return write_error(stream, ErrorCode::UnknownModel, &format!("{e}"), shared)
        }
        Err(e @ SubmitError::LoadFailed(_)) | Err(e @ SubmitError::Rejected(_)) => {
            return write_error(stream, ErrorCode::EngineError, &format!("{e}"), shared)
        }
        Err(e @ SubmitError::ShuttingDown) => {
            let _ = write_error(stream, ErrorCode::ShuttingDown, &format!("{e}"), shared);
            return false;
        }
    };
    let outcome = pending.wait_outcome(shared.cfg.request_timeout);
    if telemetry::trace_enabled() {
        let status = match &outcome {
            WaitOutcome::Ready(Ok(_)) => 0u8,
            WaitOutcome::Ready(Err(_)) | WaitOutcome::Dropped => ErrorCode::EngineError as u8,
            WaitOutcome::TimedOut => ErrorCode::DeadlineExceeded as u8,
        };
        telemetry::event("net.reply", trace_id, &[("status", status as f64)]);
    }
    match outcome {
        WaitOutcome::Ready(Ok(logits)) => {
            let mut out = Vec::with_capacity(logits.len() * 4);
            for v in &logits {
                out.extend_from_slice(&v.to_le_bytes());
            }
            write_ok(stream, &out, shared)
        }
        WaitOutcome::Ready(Err(msg)) => write_error(stream, ErrorCode::EngineError, &msg, shared),
        WaitOutcome::TimedOut => write_error(
            stream,
            ErrorCode::DeadlineExceeded,
            &format!("no answer within {:?}", shared.cfg.request_timeout),
            shared,
        ),
        WaitOutcome::Dropped => write_error(stream, ErrorCode::EngineError, "server dropped the request", shared),
    }
}

fn write_ok(stream: &mut TcpStream, body: &[u8], shared: &Shared) -> bool {
    shared.counters().ok_responses += 1;
    write_frame(stream, 0, body).is_ok()
}

fn write_error(stream: &mut TcpStream, code: ErrorCode, msg: &str, shared: &Shared) -> bool {
    shared.counters().count(code);
    write_frame(stream, code as u8, msg.as_bytes()).is_ok()
}

fn write_frame(stream: &mut impl Write, status: u8, body: &[u8]) -> std::io::Result<()> {
    debug_assert!(body.len() < MAX_FRAME_BYTES, "oversized response frame");
    let mut out = Vec::with_capacity(5 + body.len());
    out.extend_from_slice(&((body.len() as u32 + 1).to_le_bytes()));
    out.push(status);
    out.extend_from_slice(body);
    stream.write_all(&out)?;
    stream.flush()
}

/// Why a frame read ended without a frame. Public so the `fuzz/` wire
/// target can pattern-match [`decode_frame`] outcomes.
#[derive(Debug)]
pub enum FrameErr {
    /// Hardened-decoding rejection: oversized/empty/truncated/stalled
    /// frame. The byte stream can no longer be re-synchronized.
    Bad(String),
    /// Clean EOF at a frame boundary, or a hard I/O error.
    Closed,
    /// Idle at a frame boundary while the server is draining.
    ShuttingDown,
}

/// Read one length-prefixed frame with checkpoint-style hardening: the
/// length is validated against `cap` *before* any allocation, truncation
/// anywhere is an explicit error, and a peer that stalls mid-frame for
/// longer than `stall` is rejected rather than pinning the handler.
fn read_frame(stream: &mut impl Read, cap: usize, shutting: &AtomicBool, stall: Duration) -> Result<Vec<u8>, FrameErr> {
    let mut header = [0u8; 4];
    read_full(stream, &mut header, true, shutting, stall)?;
    let len = frame_payload_len(header, cap)?;
    let mut payload = vec![0u8; len];
    read_full(stream, &mut payload, false, shutting, stall)?;
    Ok(payload)
}

/// Validate a frame's length prefix against `cap` — the shared
/// declared-size-before-allocation guard, used by both the streaming
/// reader and the pure [`decode_frame`] twin.
fn frame_payload_len(header: [u8; 4], cap: usize) -> Result<usize, FrameErr> {
    let len = u32::from_le_bytes(header);
    if len == 0 {
        return Err(FrameErr::Bad("empty frame (length prefix 0)".to_string()));
    }
    cursor::claimed_len(u64::from(len), cap, "frame", "payload").map_err(|e| FrameErr::Bad(e.to_string()))
}

/// Decode one frame from an in-memory byte buffer — the pure twin of
/// the streaming [`read_frame`] loop, built on the shared
/// [`BoundedReader`] and driven directly by the `fuzz/` wire target.
/// Returns the first frame's payload; bytes past it are ignored (on a
/// stream they would belong to the next frame).
pub fn decode_frame(bytes: &[u8], cap: usize) -> Result<Vec<u8>, FrameErr> {
    let mut r = BoundedReader::new(bytes, "frame");
    if r.is_empty() {
        // EOF at a frame boundary: the stream analogue is a clean close.
        return Err(FrameErr::Closed);
    }
    let header: [u8; 4] = match r.take(4, "length prefix") {
        Ok(b) => [b[0], b[1], b[2], b[3]],
        Err(e) => return Err(FrameErr::Bad(e.to_string())),
    };
    let len = frame_payload_len(header, cap)?;
    r.read_bytes(len, "payload").map_err(|e| FrameErr::Bad(format!("peer closed mid-frame: {e}")))
}

/// Fill `buf`, treating read-timeout ticks as poll points. `idle_ok`
/// marks a frame boundary: there (and only there, before the first
/// byte) a clean EOF is `Closed` and a shutdown flag ends the wait.
/// Once any byte of a frame has arrived, the peer owes the rest within
/// `stall` or the stream is declared bad.
fn read_full(
    stream: &mut impl Read,
    buf: &mut [u8],
    idle_ok: bool,
    shutting: &AtomicBool,
    stall: Duration,
) -> Result<(), FrameErr> {
    let mut got = 0usize;
    let mut started: Option<Instant> = if idle_ok { None } else { Some(Instant::now()) };
    while got < buf.len() {
        match stream.read(&mut buf[got..]) {
            Ok(0) => {
                return Err(if got == 0 && idle_ok {
                    FrameErr::Closed
                } else {
                    FrameErr::Bad(format!("peer closed mid-frame ({got}/{} bytes)", buf.len()))
                });
            }
            Ok(n) => {
                got += n;
                started.get_or_insert_with(Instant::now);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock || e.kind() == std::io::ErrorKind::TimedOut => {
                match started {
                    None => {
                        if shutting.load(Ordering::SeqCst) {
                            return Err(FrameErr::ShuttingDown);
                        }
                    }
                    Some(t0) => {
                        if t0.elapsed() > stall {
                            return Err(FrameErr::Bad(format!(
                                "peer stalled mid-frame ({got}/{} bytes after {stall:?})",
                                buf.len()
                            )));
                        }
                    }
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return Err(FrameErr::Closed),
        }
    }
    Ok(())
}

/// Build an INFER_MODEL body: `id_len:u8 | id utf-8 | sample f32 LE`.
/// Fails on ids the one-byte length cannot carry.
pub fn encode_infer_model_body(model: &str, sample: &[f32]) -> anyhow::Result<Vec<u8>> {
    anyhow::ensure!(!model.is_empty(), "model id must be non-empty");
    anyhow::ensure!(model.len() <= u8::MAX as usize, "model id {:?} is {} bytes; the wire caps ids at 255", model, model.len());
    let mut body = Vec::with_capacity(1 + model.len() + sample.len() * 4);
    body.push(model.len() as u8);
    body.extend_from_slice(model.as_bytes());
    for v in sample {
        body.extend_from_slice(&v.to_le_bytes());
    }
    Ok(body)
}

/// Blocking client for the frame protocol — what `proxcomp loadtest`
/// drives and what remote integrations copy.
pub struct NetClient {
    stream: TcpStream,
}

impl NetClient {
    /// Connect, retrying until `timeout` (covers the serve-process
    /// startup race in scripts and CI).
    pub fn connect(addr: &str, timeout: Duration) -> anyhow::Result<NetClient> {
        let deadline = Instant::now() + timeout;
        loop {
            match TcpStream::connect(addr) {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    return Ok(NetClient { stream });
                }
                Err(e) => {
                    if Instant::now() >= deadline {
                        return Err(anyhow::anyhow!("connecting to {addr}: {e}"));
                    }
                    std::thread::sleep(Duration::from_millis(25));
                }
            }
        }
    }

    /// Send one raw request frame without waiting for the response
    /// (split send/recv is what lets tests hold a request in flight).
    pub fn send_request(&mut self, opcode: u8, body: &[u8]) -> anyhow::Result<()> {
        let mut payload = Vec::with_capacity(1 + body.len());
        payload.push(opcode);
        payload.extend_from_slice(body);
        let mut out = Vec::with_capacity(4 + payload.len());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        out.extend_from_slice(&payload);
        self.stream.write_all(&out).map_err(|e| anyhow::anyhow!("send: {e}"))?;
        self.stream.flush().map_err(|e| anyhow::anyhow!("flush: {e}"))?;
        Ok(())
    }

    /// Read one response frame: `(status, body)`.
    pub fn recv_response(&mut self) -> anyhow::Result<(u8, Vec<u8>)> {
        let mut header = [0u8; 4];
        self.stream.read_exact(&mut header).map_err(|e| anyhow::anyhow!("recv header: {e}"))?;
        let len = u32::from_le_bytes(header);
        anyhow::ensure!(len >= 1, "empty response frame");
        let len = cursor::claimed_len(u64::from(len), MAX_FRAME_BYTES, "response frame", "payload")?;
        let mut payload = vec![0u8; len];
        self.stream.read_exact(&mut payload).map_err(|e| anyhow::anyhow!("recv body: {e}"))?;
        let body = payload.split_off(1);
        Ok((payload[0], body))
    }

    pub fn send_infer(&mut self, sample: &[f32]) -> anyhow::Result<()> {
        let mut body = Vec::with_capacity(sample.len() * 4);
        for v in sample {
            body.extend_from_slice(&v.to_le_bytes());
        }
        self.send_request(OP_INFER, &body)
    }

    /// Send a model-routed (wire v2) INFER_MODEL frame without waiting.
    pub fn send_infer_model(&mut self, model: &str, sample: &[f32]) -> anyhow::Result<()> {
        self.send_request(OP_INFER_MODEL, &encode_infer_model_body(model, sample)?)
    }

    /// Decode the response to an INFER/INFER_MODEL round trip.
    #[allow(clippy::type_complexity)]
    fn recv_infer_response(&mut self) -> anyhow::Result<Result<Vec<f32>, (ErrorCode, String)>> {
        let (status, body) = self.recv_response()?;
        if status == 0 {
            anyhow::ensure!(body.len() % 4 == 0, "OK INFER body of {} bytes is not whole f32s", body.len());
            let logits =
                body.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect::<Vec<f32>>();
            Ok(Ok(logits))
        } else {
            let code =
                ErrorCode::from_u8(status).ok_or_else(|| anyhow::anyhow!("unknown response status byte {status}"))?;
            Ok(Err((code, String::from_utf8_lossy(&body).into_owned())))
        }
    }

    /// One round trip: `Ok(Ok(logits))`, or `Ok(Err((code, message)))`
    /// for a server-reported error; `Err` only for transport failures.
    #[allow(clippy::type_complexity)]
    pub fn infer(&mut self, sample: &[f32]) -> anyhow::Result<Result<Vec<f32>, (ErrorCode, String)>> {
        self.send_infer(sample)?;
        self.recv_infer_response()
    }

    /// One model-routed round trip (wire v2). `unknown-model` comes back
    /// through the `Ok(Err(..))` arm like any recoverable request error.
    #[allow(clippy::type_complexity)]
    pub fn infer_model(&mut self, model: &str, sample: &[f32]) -> anyhow::Result<Result<Vec<f32>, (ErrorCode, String)>> {
        self.send_infer_model(model, sample)?;
        self.recv_infer_response()
    }

    /// Fetch the server's stats JSON text (`{"serving": ..., "net": ...}`).
    pub fn stats_json(&mut self) -> anyhow::Result<String> {
        self.send_request(OP_STATS, &[])?;
        let (status, body) = self.recv_response()?;
        anyhow::ensure!(status == 0, "STATS answered with status {status}");
        Ok(String::from_utf8_lossy(&body).into_owned())
    }

    /// Fetch the versioned METRICS JSON snapshot (stats + per-layer
    /// profiles): `{"version": 1, "serving": ..., "net": ...,
    /// "models": ..., "profiles": ...}`.
    pub fn metrics_json(&mut self) -> anyhow::Result<String> {
        self.send_request(OP_METRICS, &[METRICS_FORMAT_JSON])?;
        let (status, body) = self.recv_response()?;
        anyhow::ensure!(status == 0, "METRICS answered with status {status}");
        Ok(String::from_utf8_lossy(&body).into_owned())
    }

    /// Fetch the METRICS snapshot rendered as Prometheus text
    /// exposition format.
    pub fn metrics_prometheus(&mut self) -> anyhow::Result<String> {
        self.send_request(OP_METRICS, &[METRICS_FORMAT_PROMETHEUS])?;
        let (status, body) = self.recv_response()?;
        anyhow::ensure!(status == 0, "METRICS answered with status {status}");
        Ok(String::from_utf8_lossy(&body).into_owned())
    }

    pub fn ping(&mut self) -> anyhow::Result<()> {
        self.send_request(OP_PING, &[])?;
        let (status, _) = self.recv_response()?;
        anyhow::ensure!(status == 0, "PING answered with status {status}");
        Ok(())
    }

    /// Ask the server to drain and exit (graceful remote shutdown).
    pub fn shutdown_server(&mut self) -> anyhow::Result<()> {
        self.send_request(OP_SHUTDOWN, &[])?;
        let (status, _) = self.recv_response()?;
        anyhow::ensure!(status == 0, "SHUTDOWN answered with status {status}");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn no_shutdown() -> AtomicBool {
        AtomicBool::new(false)
    }

    fn frame_bytes(payload: &[u8]) -> Vec<u8> {
        let mut out = (payload.len() as u32).to_le_bytes().to_vec();
        out.extend_from_slice(payload);
        out
    }

    #[test]
    fn error_code_roundtrip_and_names() {
        for code in ErrorCode::all() {
            assert_eq!(ErrorCode::from_u8(code as u8), Some(code));
            assert!(!code.name().is_empty());
        }
        assert_eq!(ErrorCode::from_u8(0), None);
        assert_eq!(ErrorCode::from_u8(99), None);
    }

    #[test]
    fn read_frame_roundtrip() {
        let flag = no_shutdown();
        let bytes = frame_bytes(&[OP_PING]);
        let mut cur = Cursor::new(bytes);
        let got = read_frame(&mut cur, 64, &flag, Duration::from_secs(1)).ok().unwrap();
        assert_eq!(got, vec![OP_PING]);
    }

    #[test]
    fn read_frame_rejects_empty_and_oversized() {
        let flag = no_shutdown();
        let mut cur = Cursor::new(0u32.to_le_bytes().to_vec());
        assert!(matches!(read_frame(&mut cur, 64, &flag, Duration::from_secs(1)), Err(FrameErr::Bad(_))));
        // A 1 GiB length prefix must be rejected before any allocation.
        let mut cur = Cursor::new((1u32 << 30).to_le_bytes().to_vec());
        match read_frame(&mut cur, 64, &flag, Duration::from_secs(1)) {
            Err(FrameErr::Bad(msg)) => assert!(msg.contains("cap"), "{msg}"),
            _ => panic!("oversized frame accepted"),
        }
    }

    #[test]
    fn read_frame_truncation_is_bad_not_silent() {
        let flag = no_shutdown();
        // Header promises 8 bytes, stream ends after 3.
        let mut bytes = 8u32.to_le_bytes().to_vec();
        bytes.extend_from_slice(&[1, 2, 3]);
        let mut cur = Cursor::new(bytes);
        match read_frame(&mut cur, 64, &flag, Duration::from_secs(1)) {
            Err(FrameErr::Bad(msg)) => assert!(msg.contains("mid-frame"), "{msg}"),
            _ => panic!("truncated frame accepted"),
        }
        // EOF at a frame boundary is a clean close, not an error.
        let mut cur = Cursor::new(Vec::new());
        assert!(matches!(read_frame(&mut cur, 64, &flag, Duration::from_secs(1)), Err(FrameErr::Closed)));
    }

    #[test]
    fn write_frame_shape() {
        let mut out = Vec::new();
        write_frame(&mut out, 0, &[0xAA, 0xBB]).unwrap();
        assert_eq!(out, vec![3, 0, 0, 0, 0, 0xAA, 0xBB]);
        let mut out = Vec::new();
        write_frame(&mut out, ErrorCode::Overloaded as u8, b"x").unwrap();
        assert_eq!(out[4], ErrorCode::Overloaded as u8);
    }

    #[test]
    fn infer_model_body_roundtrip() {
        let sample = [1.0f32, -2.5];
        let body = encode_infer_model_body("lenet-s", &sample).unwrap();
        // id_len | id | floats, byte-exact.
        assert_eq!(body[0], 7);
        assert_eq!(&body[1..8], b"lenet-s");
        assert_eq!(body.len(), 1 + 7 + 8);
        let (id, raw) = parse_infer_model_body(&body).unwrap();
        assert_eq!(id, "lenet-s");
        let floats: Vec<f32> =
            raw.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
        assert_eq!(floats, sample);
    }

    #[test]
    fn infer_model_body_rejects_malformed() {
        // Encoder-side: empty and over-long ids cannot be framed.
        assert!(encode_infer_model_body("", &[]).is_err());
        assert!(encode_infer_model_body(&"m".repeat(256), &[]).is_err());
        // Decoder-side: empty body, zero id length, id longer than the
        // body, and non-UTF-8 ids are all layout violations.
        assert!(parse_infer_model_body(&[]).is_err());
        assert!(parse_infer_model_body(&[0]).is_err());
        assert!(parse_infer_model_body(&[5, b'a', b'b']).is_err());
        assert!(parse_infer_model_body(&[2, 0xFF, 0xFE]).is_err());
        // An id with no sample bytes parses (the length check happens
        // at the routing layer, against the resolved model).
        let (id, rest) = parse_infer_model_body(&[2, b'o', b'k']).unwrap();
        assert_eq!((id, rest.len()), ("ok", 0));
    }

    #[test]
    fn infer_model_body_id_length_extremes() {
        // 255 is the largest id u8 can frame: encode and parse byte-exact.
        let id = "m".repeat(255);
        let body = encode_infer_model_body(&id, &[0.5f32]).unwrap();
        assert_eq!(body[0], 255);
        let (got, raw) = parse_infer_model_body(&body).unwrap();
        assert_eq!((got, raw.len()), (id.as_str(), 4));
        // A 255 length prefix with a body one byte short is truncation,
        // not a read past the slice.
        let mut short = vec![255u8];
        short.extend_from_slice(&vec![b'x'; 254]);
        assert!(parse_infer_model_body(&short).is_err());
    }

    #[test]
    fn decode_frame_matches_streaming_reader() {
        // The pure twin agrees with read_frame on the good path...
        let bytes = frame_bytes(&[OP_PING]);
        assert_eq!(decode_frame(&bytes, 64).unwrap(), vec![OP_PING]);
        // ...ignores bytes past the first frame (the next frame's turf)...
        let mut two = frame_bytes(&[OP_PING]);
        two.extend_from_slice(&frame_bytes(&[OP_STATS]));
        assert_eq!(decode_frame(&two, 64).unwrap(), vec![OP_PING]);
        // ...and mirrors its error taxonomy.
        assert!(matches!(decode_frame(&[], 64), Err(FrameErr::Closed)));
        assert!(matches!(decode_frame(&[1, 0], 64), Err(FrameErr::Bad(_))));
        assert!(matches!(decode_frame(&0u32.to_le_bytes(), 64), Err(FrameErr::Bad(_))));
        match decode_frame(&(1u32 << 30).to_le_bytes(), 64) {
            Err(FrameErr::Bad(msg)) => assert!(msg.contains("cap"), "{msg}"),
            other => panic!("oversized frame accepted: {other:?}"),
        }
        let mut truncated = 8u32.to_le_bytes().to_vec();
        truncated.extend_from_slice(&[1, 2, 3]);
        match decode_frame(&truncated, 64) {
            Err(FrameErr::Bad(msg)) => assert!(msg.contains("mid-frame"), "{msg}"),
            other => panic!("truncated frame accepted: {other:?}"),
        }
    }

    #[test]
    fn decode_frame_accepts_payload_exactly_at_cap() {
        let cap = 64usize;
        let payload = vec![0xABu8; cap];
        assert_eq!(decode_frame(&frame_bytes(&payload), cap).unwrap(), payload);
        let over = vec![0xABu8; cap + 1];
        assert!(matches!(decode_frame(&frame_bytes(&over), cap), Err(FrameErr::Bad(_))));
    }
}
