//! proxcomp CLI — the L3 leader entrypoint.
//!
//! ```text
//! proxcomp train    --model lenet --method spc --lambda 1.2 --steps 600 \
//!                   [--retrain-steps 200]
//! proxcomp sweep    --model lenet --lambdas 0.5,1.0,2.0 [--method spc]
//! proxcomp seeds    --model lenet --seeds 0,1,2 --optimizer rmsprop
//! proxcomp pipeline [--model mlp-s|lenet-s] [--steps 200] [--quantize]
//! proxcomp quantize --checkpoint ckpt.pxcp [--out q.pxcp] [--codebook-size 16]
//! proxcomp infer    --checkpoint ckpt.pxcp [--sparse|--quantized] [--batch 64]
//! proxcomp report   --checkpoint ckpt.pxcp        # layer table + size
//! proxcomp serve    --models mlp-s,lenet-s --addr 127.0.0.1:7733  # framed-TCP fleet
//! proxcomp loadtest --mix mlp-s,lenet-s --clients 100 --duration 10s
//! proxcomp stats    --addr 127.0.0.1:7733 [--format json|prom] [--stop-server]
//! proxcomp bench-compare --baseline BENCH_BASELINE.json \
//!                   --current reports/bench_kernels.json  # CI perf gate
//! proxcomp info                                   # manifest summary
//! ```
//!
//! Every subcommand shares the manifest + runtime (PJRT when built with
//! the `pjrt` feature, the native CPU backend otherwise); results land
//! in `reports/` as JSON/CSV.

use anyhow::Result;
use proxcomp::checkpoint;
use proxcomp::config::RunConfig;
use proxcomp::coordinator::sweep;
use proxcomp::data;
use proxcomp::inference::Engine;
use proxcomp::info;
use proxcomp::metrics::{self, RunResult};
use proxcomp::runtime::{Manifest, Runtime};
use proxcomp::util::cli::Args;
use proxcomp::util::json::Json;
use proxcomp::util::logger;

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn run() -> Result<()> {
    let args = Args::from_env()?;
    if args.flag("verbose") {
        logger::set_level(logger::Level::Debug);
    }
    // PROXCOMP_TRACE=path enables JSONL event tracing for any subcommand.
    proxcomp::telemetry::init_trace_from_env();
    let sub = args.subcommand.clone().unwrap_or_else(|| "help".into());
    let result = match sub.as_str() {
        "train" => cmd_train(&args),
        "sweep" => cmd_sweep(&args),
        "seeds" => cmd_seeds(&args),
        "pipeline" => cmd_pipeline(&args),
        "quantize" => cmd_quantize(&args),
        "infer" => cmd_infer(&args),
        "report" => cmd_report(&args),
        "serve" => cmd_serve(&args),
        "loadtest" => cmd_loadtest(&args),
        "stats" => cmd_stats(&args),
        "bench-compare" => cmd_bench_compare(&args),
        "info" => cmd_info(&args),
        _ => {
            print_help();
            Ok(())
        }
    };
    // Flush any env-enabled trace so the JSONL is complete on exit.
    let written = proxcomp::telemetry::disable_trace();
    if written > 0 {
        info!("trace: {written} events written");
    }
    result
}

fn load_config(args: &Args) -> Result<RunConfig> {
    let mut cfg = match args.get_str("config") {
        Some(path) => RunConfig::from_json_file(&path)?,
        None => RunConfig::default(),
    };
    cfg.apply_args(args)?;
    cfg.validate()?;
    Ok(cfg)
}

fn print_result(r: &RunResult) {
    println!("\n== {} on {} (λ={}, seed={}) ==", r.method, r.model, r.lambda, r.seed);
    println!("  test accuracy    : {:.4}", r.accuracy);
    println!("  test loss        : {:.4}", r.loss);
    println!(
        "  compression rate : {:.4} ({:.0}×), nnz {} / {}",
        r.compression_rate,
        r.times_factor(),
        r.nnz,
        r.total_weights
    );
    println!("  wall time        : {:.1}s", r.wall_secs);
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    args.finish()?;
    let manifest = Manifest::load_or_native(&cfg.artifacts_dir)?;
    let mut rt = Runtime::cpu()?;
    let result = sweep::run_method(&mut rt, &manifest, &cfg)?;
    print_result(&result);
    result.history.write_csv(&metrics::report_path(&format!(
        "train_{}_{}_{}.csv",
        result.model, result.method, cfg.seed
    )))?;
    let p = metrics::write_json_report(
        &format!("train_{}_{}_{}.json", result.model, result.method, cfg.seed),
        &result.to_json(),
    )?;
    info!("wrote {}", p.display());
    Ok(())
}

fn cmd_sweep(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let lambdas: Vec<f32> = args
        .list_or("lambdas", &["0.25", "0.5", "1.0", "2.0", "4.0"])
        .iter()
        .map(|s| s.parse::<f32>().map_err(|_| anyhow::anyhow!("bad lambda {s:?}")))
        .collect::<Result<Vec<_>>>()?;
    args.finish()?;
    let manifest = Manifest::load_or_native(&cfg.artifacts_dir)?;
    let mut rt = Runtime::cpu()?;
    let results = sweep::lambda_sweep(&mut rt, &manifest, &cfg, &lambdas)?;
    println!("\nλ        accuracy  rate     nnz");
    for r in &results {
        println!("{:<8} {:.4}    {:.4}   {}", r.lambda, r.accuracy, r.compression_rate, r.nnz);
    }
    let arr = Json::Arr(results.iter().map(|r| r.to_json()).collect());
    let p = metrics::write_json_report(
        &format!("sweep_{}_{}.json", cfg.model, cfg.method.name()),
        &arr,
    )?;
    info!("wrote {}", p.display());
    Ok(())
}

fn cmd_seeds(args: &Args) -> Result<()> {
    let cfg = load_config(args)?;
    let seeds: Vec<u64> = args
        .list_or("seeds", &["0", "1", "2", "3"])
        .iter()
        .map(|s| s.parse::<u64>().map_err(|_| anyhow::anyhow!("bad seed {s:?}")))
        .collect::<Result<Vec<_>>>()?;
    args.finish()?;
    let manifest = Manifest::load_or_native(&cfg.artifacts_dir)?;
    let mut rt = Runtime::cpu()?;
    let results = sweep::seed_sweep(&mut rt, &manifest, &cfg, &seeds)?;
    println!("\nseed   accuracy  rate");
    for r in &results {
        println!("{:<6} {:.4}    {:.4}", r.seed, r.accuracy, r.compression_rate);
    }
    let accs: Vec<f64> = results.iter().map(|r| r.accuracy).collect();
    let rates: Vec<f64> = results.iter().map(|r| r.compression_rate).collect();
    println!(
        "acc  mean {:.4} std {:.4} | rate mean {:.4} std {:.4}",
        proxcomp::util::stats::mean(&accs),
        proxcomp::util::stats::std_dev(&accs),
        proxcomp::util::stats::mean(&rates),
        proxcomp::util::stats::std_dev(&rates)
    );
    let arr = Json::Arr(results.iter().map(|r| r.to_json()).collect());
    metrics::write_json_report(
        &format!("seeds_{}_{}.json", cfg.model, cfg.optimizer.step_name()),
        &arr,
    )?;
    Ok(())
}

/// Offline SpC→debias→compress→serve smoke over the native backend —
/// the CI `e2e-pipeline` gate, for both the MLP and the LeNet (conv)
/// families. Exits nonzero unless (1) a conv model's backward passes
/// the finite-difference gradient check, (2) the final eval loss beats
/// the untrained eval loss, (3) the deployed engine's per-layer format
/// report is non-empty, and (4) the compression factor exceeds 1× —
/// the paper pipeline's minimum liveness bar. With `--quantize` the
/// deployment stage additionally codebook-quantizes the debiased model
/// (optional `--finetune-steps` trained-quantization pass), serves it
/// through the QCS engine, and extends the gate: quantized accuracy
/// must stay within `--quant-tolerance` of the debiased accuracy and
/// the quantized checkpoint must be strictly smaller than the CSR one.
fn cmd_pipeline(args: &Args) -> Result<()> {
    use proxcomp::compress::{self, debias};
    use proxcomp::coordinator::{trainer::StepScalars, Trainer};
    use proxcomp::inference::{BatchConfig, BatchServer, WeightMode};
    use proxcomp::quant;
    use proxcomp::runtime::native;
    use std::sync::Arc;
    use std::time::Duration;

    // Pipeline defaults are tuned per model family — fast everywhere
    // (seconds in release), visible sparsity, and debias headroom; the
    // conv family trains a little longer at a gentler λ so the small
    // filter banks keep live channels. A `--config` file replaces these
    // defaults wholesale (standard load_config semantics); CLI flags
    // override either base.
    let mut cfg = match args.get_str("config") {
        Some(path) => RunConfig::from_json_file(&path)?,
        None => {
            let model = args.str_or("model", "mlp-s");
            let conv = model.starts_with("lenet");
            RunConfig {
                steps: if conv { 240 } else { 200 },
                retrain_steps: 80,
                lambda: if conv { 0.4 } else { 0.5 },
                lr: 2e-3,
                retrain_lr: 1e-3,
                train_examples: 2048,
                test_examples: 512,
                eval_every: 0,
                artifacts_dir: "native".into(),
                model,
                ..RunConfig::default()
            }
        }
    };
    let quantize = args.flag("quantize");
    let codebook_size = args.usize_or("codebook-size", 16)?;
    anyhow::ensure!(
        (1..=256).contains(&codebook_size),
        "--codebook-size must be in 1..=256 (codes are at most 8 bits), got {codebook_size}"
    );
    let finetune_steps = args.usize_or("finetune-steps", 0)?;
    let finetune_lr = args.f32_or("finetune-lr", 1e-4)?;
    let quant_tol = args.f64_or("quant-tolerance", 0.05)?;
    let telemetry_out = args.get_str("telemetry-out");
    cfg.apply_args(args)?;
    cfg.validate()?;
    args.finish()?;
    let telemetry_path = match &telemetry_out {
        Some(p) => std::path::PathBuf::from(p),
        None => metrics::report_path(&format!("pipeline_{}_telemetry.jsonl", cfg.model)),
    };

    let manifest = Manifest::load_or_native(&cfg.artifacts_dir)?;
    let mut rt = Runtime::native();
    let t0 = std::time::Instant::now();

    // Conv preflight: the hand-written conv/pool backward must agree
    // with central finite differences before we trust it to train —
    // part of the gate, not a warning.
    let entry = manifest.model(&cfg.model)?;
    if entry.params.iter().any(|s| s.kind == "conv_w") {
        let (ok, total) = native::gradient_check(entry, cfg.seed, 4)?;
        println!("[pipeline] conv gradient check: {ok}/{total} directions agree");
    }

    let mut trainer = Trainer::new(&manifest, &cfg)?;

    let eval0 = trainer.evaluate(&mut rt)?;
    println!("[pipeline] untrained: loss {:.4} acc {:.4}", eval0.loss, eval0.accuracy);

    let scalars = StepScalars { lambda: cfg.lambda, lr: cfg.lr, mu: 0.0 };
    compress::spc::run_with_evals(
        &mut rt,
        &mut trainer,
        cfg.optimizer.step_name(),
        cfg.steps,
        scalars,
        cfg.eval_every,
    )?;
    let eval_sparse = trainer.evaluate(&mut rt)?;
    let rate_sparse = trainer.state.params.compression_rate();
    println!(
        "[pipeline] after SpC ({} steps, λ={}): loss {:.4} acc {:.4} rate {:.4}",
        cfg.steps, cfg.lambda, eval_sparse.loss, eval_sparse.accuracy, rate_sparse
    );

    if cfg.retrain_steps > 0 {
        debias::retrain(&mut rt, &mut trainer, cfg.retrain_steps, cfg.retrain_lr)?;
        let eval_debias = trainer.evaluate(&mut rt)?;
        println!(
            "[pipeline] after debias ({} steps): loss {:.4} acc {:.4} (Δacc {:+.4})",
            cfg.retrain_steps,
            eval_debias.loss,
            eval_debias.accuracy,
            eval_debias.accuracy - eval_sparse.accuracy
        );
    }

    let method = if cfg.retrain_steps > 0 { "SpC(Retrain)" } else { "SpC" };
    let result = compress::finish_run(&mut rt, &mut trainer, method, cfg.lambda as f64, t0)?;
    print_result(&result);

    // Compressed deployment: dispatch-chosen formats (or the codebook-
    // quantized QCS engine under --quantize) + batched serving.
    let qcfg = quant::QuantConfig { codebook_size, ..quant::QuantConfig::default() };
    let quant_model = if quantize {
        let (mut qm, reports) = quant::quantize_bundle(&trainer.state.params, &qcfg);
        for r in reports.iter().filter(|r| r.quantized) {
            println!(
                "[pipeline] quantized {:<10} k={:<3} rmse {:.5} max|err| {:.5}",
                r.name, r.codebook_len, r.stats.rmse, r.stats.max_abs_err
            );
        }
        if finetune_steps > 0 {
            let rep = quant::finetune_codebooks(
                &mut qm,
                &trainer.train_data,
                finetune_steps,
                32,
                finetune_lr,
                cfg.seed,
            )?;
            println!(
                "[pipeline] codebook fine-tune ({} steps, lr {}): loss {:.4} -> {:.4}",
                rep.steps, finetune_lr, rep.loss_first, rep.loss_last
            );
        }
        Some(qm)
    } else {
        None
    };
    let engine = Arc::new(match &quant_model {
        Some(qm) => Engine::builder(&cfg.model).quantized(qm).build()?,
        None => Engine::builder(&cfg.model)
            .bundle(&trainer.state.params)
            .mode(WeightMode::Auto)
            .build()?,
    });
    let formats = engine.layer_formats();
    let formats_text =
        formats.iter().map(|(l, f)| format!("{l}={f}")).collect::<Vec<_>>().join(" ");
    println!("[pipeline] deployed formats: {formats_text}");
    print_leaf_sizes(&trainer.state.params, &engine);
    let (c, h, w) = (trainer.test_data.c, trainer.test_data.h, trainer.test_data.w);
    let server =
        BatchServer::start(Arc::clone(&engine), BatchConfig::new(8, Duration::from_millis(10), (c, h, w)));
    let pending: Vec<_> = (0..16)
        .map(|i| {
            let sample = trainer.test_data.image(i % trainer.test_data.n).to_vec();
            server.submit(&sample).map(|p| (sample, p))
        })
        .collect::<Result<Vec<_>>>()?;
    for (sample, p) in pending {
        let got = p.wait()?;
        let x = proxcomp::tensor::Tensor::new(vec![1, c, h, w], sample);
        anyhow::ensure!(got == engine.forward(&x)?.data, "served logits diverge from engine forward");
    }
    let stats = server.stats();
    println!(
        "[pipeline] served {} requests in {} batches (parity with engine forward verified)",
        stats.requests, stats.batches
    );

    // Training-side telemetry JSONL: the per-step loss/compression curve
    // plus the deployed per-layer formats/densities, uploaded by CI.
    let (n_steps, n_layers) = write_training_telemetry(&telemetry_path, &result, &engine)?;
    println!(
        "[pipeline] wrote {} ({n_steps} step records, {n_layers} layer rows)",
        telemetry_path.display()
    );

    // The CI gate.
    anyhow::ensure!(
        result.loss < eval0.loss,
        "final eval loss {:.4} did not improve on untrained {:.4}",
        result.loss,
        eval0.loss
    );
    anyhow::ensure!(!formats.is_empty(), "deployed layer_formats report is empty");
    anyhow::ensure!(
        result.times_factor() > 1.0,
        "compression factor {:.2}× is not > 1",
        result.times_factor()
    );

    // The quantization gate: checkpoint both representations, then
    // require strict size improvement over CSR and accuracy within
    // tolerance of the debiased f32 model.
    if let Some(qm) = &quant_model {
        let mut meta = Json::obj();
        meta.set("model", Json::from(cfg.model.as_str()))
            .set("dataset", Json::from(trainer.entry.dataset.as_str()))
            .set("method", Json::from(method))
            .set("codebook_size", Json::from(codebook_size));
        let csr_path = metrics::report_path(&format!("pipeline_{}.pxcp", cfg.model));
        let q_path = metrics::report_path(&format!("pipeline_{}_quant.pxcp", cfg.model));
        let csr_bytes = checkpoint::save(&csr_path, &trainer.state.params, &meta)?;
        let q_bytes = checkpoint::save_quantized(&q_path, qm, &meta)?;
        let quant_acc = engine.accuracy(&trainer.test_data, 64)?;
        println!(
            "[pipeline] quantized: acc {:.4} (debiased {:.4}, tol {quant_tol}), \
             checkpoint {} KB vs CSR {} KB ({:.2}×)",
            quant_acc,
            result.accuracy,
            q_bytes / 1024,
            csr_bytes / 1024,
            csr_bytes as f64 / q_bytes.max(1) as f64
        );
        anyhow::ensure!(
            q_bytes < csr_bytes,
            "quantized checkpoint ({q_bytes} B) is not strictly smaller than CSR ({csr_bytes} B)"
        );
        anyhow::ensure!(
            quant_acc >= result.accuracy - quant_tol,
            "quantized accuracy {quant_acc:.4} dropped more than {quant_tol} below debiased {:.4}",
            result.accuracy
        );
    }
    println!(
        "[pipeline] OK: loss {:.4} → {:.4}, acc {:.4}, compression {:.1}× ({:.1}s)",
        eval0.loss,
        result.loss,
        result.accuracy,
        result.times_factor(),
        t0.elapsed().as_secs_f64()
    );
    Ok(())
}

/// Per-leaf size breakdown for the pipeline report: dense f32 bytes,
/// CSR bytes, and the engine's actually-deployed format/bytes (QCS
/// under `--quantize`), so the final report shows *where* the
/// compression lives instead of only the aggregate ratio.
fn print_leaf_sizes(params: &proxcomp::runtime::ParamBundle, engine: &Engine) {
    use proxcomp::sparse::CsrMatrix;
    let mut base = std::collections::HashMap::new();
    for (spec, v) in params.specs.iter().zip(&params.values) {
        if let Some((rows, cols)) = checkpoint::matrix_view(spec) {
            if spec.prunable && rows > 0 {
                let csr = CsrMatrix::from_dense(v, rows, cols);
                base.insert(spec.layer.clone(), (v.len() * 4, csr.storage_bytes()));
            }
        }
    }
    println!("[pipeline] per-leaf storage (dense → CSR → deployed):");
    let (mut td, mut tc, mut ts) = (0usize, 0usize, 0usize);
    for (name, fmt, bytes, nnz) in engine.layer_storage() {
        let (dense_b, csr_b) = base.get(&name).copied().unwrap_or((0, 0));
        td += dense_b;
        tc += csr_b;
        ts += bytes;
        println!(
            "  {name:<12} {dense_b:>10} B {csr_b:>10} B {:>10} B  ({fmt}, nnz {nnz})",
            bytes
        );
    }
    println!("  {:<12} {td:>10} B {tc:>10} B {ts:>10} B", "total");
}

/// Training-side telemetry JSONL: one `train.step` line per recorded
/// training step (loss, compression rate, accuracy when an eval ran),
/// one `deploy.layer` line per engine layer (deployed format, nnz,
/// density), and a closing `train.final` summary — the artifact CI
/// uploads next to the pipeline logs. Returns (step records, layer rows).
fn write_training_telemetry(
    path: &std::path::Path,
    result: &RunResult,
    engine: &Engine,
) -> Result<(usize, usize)> {
    use std::io::Write;
    if let Some(parent) = path.parent() {
        if !parent.as_os_str().is_empty() {
            std::fs::create_dir_all(parent)?;
        }
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    for r in &result.history.records {
        let mut j = Json::obj();
        j.set("kind", Json::from("train.step"))
            .set("step", Json::from(r.step))
            .set("loss", Json::from(r.loss))
            .set("compression_rate", Json::from(r.compression_rate))
            .set("accuracy", Json::from(r.accuracy));
        writeln!(f, "{}", j.to_string_compact())?;
    }
    let profiles = engine.profile();
    for p in &profiles {
        let mut j = Json::obj();
        j.set("kind", Json::from("deploy.layer"))
            .set("layer", Json::from(p.name.as_str()))
            .set("format", Json::from(p.format.as_str()))
            .set("nnz", Json::from(p.nnz))
            .set("density", Json::from(p.density));
        writeln!(f, "{}", j.to_string_compact())?;
    }
    let mut j = Json::obj();
    j.set("kind", Json::from("train.final"))
        .set("model", Json::from(result.model.as_str()))
        .set("method", Json::from(result.method.as_str()))
        .set("loss", Json::from(result.loss))
        .set("accuracy", Json::from(result.accuracy))
        .set("compression_rate", Json::from(result.compression_rate));
    writeln!(f, "{}", j.to_string_compact())?;
    f.flush()?;
    Ok((result.history.records.len(), profiles.len()))
}

/// Codebook-quantize a trained checkpoint (Deep Compression stage):
/// per-leaf k-means codebooks over the surviving nonzeros, optional
/// trained-quantization fine-tune on the native backend, a checkpoint-v2
/// quantized artifact, and a per-leaf size/error report.
fn cmd_quantize(args: &Args) -> Result<()> {
    use proxcomp::quant;
    let path = args
        .get_str("checkpoint")
        .ok_or_else(|| anyhow::anyhow!("--checkpoint required"))?;
    let out = args
        .str_or("out", &format!("{}_quant.pxcp", path.trim_end_matches(".pxcp")));
    let codebook_size = args.usize_or("codebook-size", 16)?;
    anyhow::ensure!(
        (1..=256).contains(&codebook_size),
        "--codebook-size must be in 1..=256 (codes are at most 8 bits), got {codebook_size}"
    );
    let finetune_steps = args.usize_or("finetune-steps", 0)?;
    let finetune_lr = args.f32_or("finetune-lr", 1e-4)?;
    let batch = args.usize_or("batch", 32)?;
    let examples = args.usize_or("examples", 1024)?;
    let seed = args.u64_or("seed", 0)?;
    args.finish()?;

    let ck = checkpoint::load(std::path::Path::new(&path))?;
    let model = ck.meta.get("model").and_then(Json::as_str).map(str::to_string);
    let dataset_name =
        ck.meta.get("dataset").and_then(Json::as_str).unwrap_or("synth-mnist").to_string();
    let qcfg = quant::QuantConfig { codebook_size, ..quant::QuantConfig::default() };
    let (mut qm, reports) = quant::quantize_bundle(&ck.params, &qcfg);

    println!("checkpoint: {path} ({} KB payload)", ck.payload_bytes / 1024);
    println!("\nleaf             nnz / total         k   rmse      dense B    CSR B      stored B");
    for r in &reports {
        println!(
            "{:<16} {:>9} / {:<9} {:>3}   {:<9.5} {:>9} {:>9} {:>9}{}",
            r.name,
            r.nnz,
            r.total,
            if r.quantized { r.codebook_len.to_string() } else { "-".into() },
            r.stats.rmse,
            r.dense_bytes,
            r.csr_bytes,
            r.stored_bytes,
            if r.quantized { "" } else { "  (kept f32)" }
        );
    }

    // Trained quantization (per-code gradient descent on the centroids)
    // needs the native backend's graph families.
    if finetune_steps > 0 {
        let native_family = model
            .as_deref()
            .map(|m| m.starts_with("mlp") || m.starts_with("lenet") || m.starts_with("resnet"))
            .unwrap_or(false);
        if native_family {
            let data = data::generate(&dataset_name, examples, seed)?;
            let rep = quant::finetune_codebooks(&mut qm, &data, finetune_steps, batch, finetune_lr, seed)?;
            println!(
                "\ncodebook fine-tune: {} steps at lr {finetune_lr}, loss {:.4} -> {:.4}",
                rep.steps, rep.loss_first, rep.loss_last
            );
        } else {
            println!("\n[skip] codebook fine-tune needs a native model family (mlp*/lenet*/resnet*)");
        }
    }

    // Accuracy before/after quantization when the checkpoint names an
    // engine-servable model.
    if let Some(model) = &model {
        let dataset = data::generate(&dataset_name, examples, seed ^ 0x7E57_DA7A)?;
        use proxcomp::inference::WeightMode;
        let base = Engine::builder(model).bundle(&ck.params).mode(WeightMode::Csr).build()?;
        let qeng = Engine::builder(model).quantized(&qm).build()?;
        let acc_f32 = base.accuracy(&dataset, 64)?;
        let acc_q = qeng.accuracy(&dataset, 64)?;
        println!(
            "\naccuracy over {} examples: f32/CSR {:.4} ({} KB) -> quantized {:.4} ({} KB)",
            dataset.n,
            acc_f32,
            base.model_size_bytes() / 1024,
            acc_q,
            qeng.model_size_bytes() / 1024
        );
    }

    let bytes = checkpoint::save_quantized(std::path::Path::new(&out), &qm, &ck.meta)?;
    println!(
        "\nwrote {out}: {} KB payload ({:.2}× vs input checkpoint)",
        bytes / 1024,
        ck.payload_bytes as f64 / bytes.max(1) as f64
    );
    Ok(())
}

fn cmd_infer(args: &Args) -> Result<()> {
    let path = args
        .get_str("checkpoint")
        .ok_or_else(|| anyhow::anyhow!("--checkpoint required"))?;
    let sparse = args.flag("sparse");
    let quantized = args.flag("quantized");
    let batch = args.usize_or("batch", 64)?;
    let examples = args.usize_or("examples", 512)?;
    args.finish()?;
    let ck = checkpoint::load(std::path::Path::new(&path))?;
    let model = ck
        .meta
        .get("model")
        .and_then(Json::as_str)
        .ok_or_else(|| anyhow::anyhow!("checkpoint meta lacks model name"))?
        .to_string();
    let dataset_name = ck
        .meta
        .get("dataset")
        .and_then(Json::as_str)
        .unwrap_or("synth-mnist")
        .to_string();
    let engine = if quantized {
        anyhow::ensure!(
            ck.is_quantized(),
            "--quantized needs a quantized (v2) checkpoint; run `proxcomp quantize` first"
        );
        Engine::builder(&model).quantized(&ck.to_quantized_model()).build()?
    } else {
        use proxcomp::inference::WeightMode;
        let mode = if sparse { WeightMode::Csr } else { WeightMode::Dense };
        Engine::builder(&model).bundle(&ck.params).mode(mode).build()?
    };
    let dataset = data::generate(&dataset_name, examples, 0x7E57_DA7A)?;
    info!(
        "engine: {model} ({}), model size {} KB",
        if quantized {
            "QCS"
        } else if sparse {
            "CSR"
        } else {
            "dense"
        },
        engine.model_size_bytes() / 1024
    );
    let t0 = std::time::Instant::now();
    let acc = engine.accuracy(&dataset, batch)?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "accuracy {acc:.4} over {} examples in {dt:.2}s ({:.1} ex/s)",
        dataset.n,
        dataset.n as f64 / dt
    );
    Ok(())
}

fn cmd_report(args: &Args) -> Result<()> {
    let path = args
        .get_str("checkpoint")
        .ok_or_else(|| anyhow::anyhow!("--checkpoint required"))?;
    args.finish()?;
    let ck = checkpoint::load(std::path::Path::new(&path))?;
    println!("checkpoint: {path}");
    println!("meta: {}", ck.meta.to_string_compact());
    println!("payload: {} KB", ck.payload_bytes / 1024);
    println!("\nlayer            nnz / total        rate");
    for (layer, nnz, total) in ck.params.layer_stats() {
        let rate = 1.0 - nnz as f64 / total as f64;
        let factor = if nnz > 0 { total as f64 / nnz as f64 } else { f64::INFINITY };
        println!("{layer:<16} {nnz:>9} / {total:<9} {:.2}% ({factor:.0}×)", rate * 100.0);
    }
    let p = &ck.params;
    println!(
        "\ntotal: {} / {} = {:.2}% compression",
        p.total_weights() - p.zero_weights(),
        p.total_weights(),
        p.compression_rate() * 100.0
    );
    Ok(())
}

/// Deterministic synthetic serving engine: He-init the manifest model's
/// parameters from `seed`, soft-threshold prune the prunable leaves, and
/// deploy CSR. Both `serve` and `loadtest --model/--seed` rebuild this
/// *identical* engine independently, which is what makes the over-the-wire
/// bit-exactness check possible without shipping artifacts around.
fn synthetic_engine(model: &str, seed: u64, prune: f32) -> Result<(Engine, (usize, usize, usize))> {
    use proxcomp::inference::WeightMode;
    use proxcomp::runtime::ParamBundle;
    use proxcomp::sparse::prox;
    let manifest = Manifest::native();
    let entry = manifest.model(model)?;
    let shape = model_input_shape(&entry.input_shape)?;
    let mut bundle = ParamBundle::he_init(&entry.params, seed);
    for (s, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
        if s.prunable {
            prox::soft_threshold_inplace(v, prune);
        }
    }
    let engine = Engine::builder(model).bundle(&bundle).mode(WeightMode::Csr).build()?;
    Ok((engine, shape))
}

fn model_input_shape(shape: &[usize]) -> Result<(usize, usize, usize)> {
    anyhow::ensure!(shape.len() == 3, "model input shape {shape:?} is not (C, H, W)");
    Ok((shape[0], shape[1], shape[2]))
}

/// Serve synthetic compressed engines over the framed-TCP protocol
/// (`inference::net`) until a client sends a SHUTDOWN frame, then drain
/// in-flight requests and print/write the final serving stats.
///
/// `--models a,b,c` serves a fleet through a `ModelRegistry` (the first
/// id is the v1-protocol default; clients route with v2 `INFER_MODEL`
/// frames); `--model x` is shorthand for a single-model fleet. With
/// `--memory-budget N` (bytes), engines load lazily on first request and
/// the least-recently-used model is drained and evicted when the
/// resident set would exceed the budget.
fn cmd_serve(args: &Args) -> Result<()> {
    use proxcomp::inference::{
        BatchConfig, EngineFactory, ModelRegistry, ModelSpec, NetConfig, NetServer, RegistryConfig,
    };
    use std::sync::Arc;
    use std::time::Duration;
    let models_arg = args.get_str("models");
    let model = args.str_or("model", "lenet-s");
    let seed = args.u64_or("seed", 1)?;
    let prune = args.f32_or("prune", 0.05)?;
    let addr = args.str_or("addr", "127.0.0.1:7733");
    let max_batch = args.usize_or("max-batch", 8)?;
    let max_wait = args.duration_or("max-wait", Duration::from_millis(2))?;
    let max_conns = args.usize_or("max-conns", 256)?;
    let max_inflight = args.usize_or("max-inflight", 512)?;
    let request_timeout = args.duration_or("request-timeout", Duration::from_secs(5))?;
    let memory_budget = args.usize_or("memory-budget", 0)?;
    let stats_out = args.get_str("stats-out");
    let trace = args.get_str("trace");
    args.finish()?;

    if let Some(path) = &trace {
        proxcomp::telemetry::enable_trace(std::path::Path::new(path))?;
        println!("[serve] tracing events to {path}");
    }

    let ids: Vec<String> = match &models_arg {
        Some(list) => {
            list.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect()
        }
        None => vec![model.clone()],
    };
    anyhow::ensure!(!ids.is_empty(), "--models needs at least one model id");
    let registry = Arc::new(ModelRegistry::new(RegistryConfig {
        memory_budget_bytes: memory_budget,
        default_model: Some(ids[0].clone()),
    }));
    let manifest = Manifest::native();
    for id in &ids {
        let shape = model_input_shape(&manifest.model(id)?.input_shape)?;
        let id2 = id.clone();
        let factory: EngineFactory = Arc::new(move || {
            let (engine, _) = synthetic_engine(&id2, seed, prune)?;
            Ok(Arc::new(engine))
        });
        registry.add_model(ModelSpec::new(
            id,
            factory,
            BatchConfig::new(max_batch, max_wait, shape),
        ))?;
    }
    let net_cfg = NetConfig { addr, max_conns, max_inflight, request_timeout, ..NetConfig::default() };
    let mut server = NetServer::start_registry(Arc::clone(&registry), net_cfg)?;
    println!(
        "[serve] {} (seed {seed}, prune {prune}, default {}) on {} — max_batch {max_batch}, \
         max_inflight {max_inflight}, memory budget {}; a SHUTDOWN frame \
         (`loadtest --stop-server`) drains and exits",
        ids.join(", "),
        ids[0],
        server.local_addr(),
        if memory_budget == 0 { "unlimited".to_string() } else { format!("{memory_budget} B") }
    );
    server.wait_shutdown_requested();
    server.shutdown();
    let stats = server.stats();
    println!(
        "[serve] drained: {} requests in {} batches, {:.1} req/s, p50 {:.0}µs p99 {:.0}µs max {:.0}µs",
        stats.requests,
        stats.batches,
        stats.throughput_rps,
        stats.p50_latency_us,
        stats.p99_latency_us,
        stats.max_latency_us
    );
    let models_json = registry.stats_json();
    if let Some(rows) = models_json.as_obj() {
        for (id, row) in rows {
            let n = |k: &str| row.get(k).and_then(Json::as_f64).unwrap_or(0.0);
            println!(
                "  model {id:<12} requests {} loads {} evictions {}",
                n("requests_total") as u64,
                n("loads") as u64,
                n("evictions") as u64
            );
        }
    }
    if let Some(path) = stats_out {
        std::fs::write(&path, server.stats_json().to_string_pretty())
            .map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
        println!("[serve] wrote {path}");
    }
    Ok(())
}

/// Closed-loop load test against a live `proxcomp serve`: hundreds of
/// concurrent synthetic clients, p50/p99 latency, saturation throughput,
/// per-error-code counts, and (unless `--no-verify`) a bit-exactness
/// check of every served response against a local twin engine. Exits
/// nonzero on any bit mismatch — the determinism contract over the wire.
///
/// `--mix a,b,c` drives a multi-model fleet: each client round-robins
/// v2 `INFER_MODEL` requests across the listed models (each verified
/// against its own local twin); without `--mix` it sends v1 `INFER`
/// frames to the server's default model. `overloaded` responses are
/// retried in place with exponential backoff up to `--retries` per
/// request (reported as retries, not errors).
fn cmd_loadtest(args: &Args) -> Result<()> {
    use proxcomp::inference::loadgen::{self, LoadConfig, LoadTarget};
    use proxcomp::inference::{ErrorCode, NetClient};
    use std::sync::Arc;
    use std::time::Duration;
    let addr = args.str_or("addr", "127.0.0.1:7733");
    let mix = args.get_str("mix");
    let model = args.str_or("model", "lenet-s");
    let seed = args.u64_or("seed", 1)?;
    let prune = args.f32_or("prune", 0.05)?;
    let clients = args.usize_or("clients", 100)?;
    let duration = args.duration_or("duration", Duration::from_secs(10))?;
    let load_seed = args.u64_or("load-seed", 42)?;
    let connect_timeout = args.duration_or("connect-timeout", Duration::from_secs(10))?;
    let retries = args.usize_or("retries", 8)? as u32;
    let no_verify = args.flag("no-verify");
    let stop_server = args.flag("stop-server");
    let out = args.get_str("out");
    args.finish()?;

    let manifest = Manifest::native();
    let target_for = |id: &str, routed: bool| -> Result<LoadTarget> {
        let (verify, shape) = if no_verify {
            (None, model_input_shape(&manifest.model(id)?.input_shape)?)
        } else {
            let (engine, shape) = synthetic_engine(id, seed, prune)?;
            (Some(Arc::new(engine)), shape)
        };
        Ok(LoadTarget::new(if routed { Some(id) } else { None }, shape, verify))
    };
    let (targets, label) = match &mix {
        Some(list) => {
            let ids: Vec<&str> =
                list.split(',').map(str::trim).filter(|s| !s.is_empty()).collect();
            anyhow::ensure!(!ids.is_empty(), "--mix needs at least one model id");
            let targets =
                ids.iter().map(|id| target_for(id, true)).collect::<Result<Vec<_>>>()?;
            (targets, "mix".to_string())
        }
        None => (vec![target_for(&model, false)?], model.clone()),
    };
    let cfg = LoadConfig {
        addr: addr.clone(),
        clients,
        duration,
        targets,
        seed: load_seed,
        connect_timeout,
        retry_budget: retries,
        retry_base: Duration::from_micros(200),
        fetch_server_stats: true,
    };
    println!(
        "[loadtest] {clients} closed-loop clients × {:.1}s against {addr} ({} target(s), \
         retry budget {retries})",
        duration.as_secs_f64(),
        cfg.targets.len()
    );
    let report = loadgen::run(&cfg)?;
    println!(
        "  ok {} in {:.1}s -> saturation throughput {:.1} req/s ({} overloaded retries, \
         {:.1}ms total backoff)",
        report.ok,
        report.elapsed_secs,
        report.throughput_rps,
        report.retries,
        report.backoff_us as f64 / 1e3
    );
    for m in &report.per_model {
        let errs = ErrorCode::all()
            .iter()
            .filter(|c| m.error_count(**c) > 0)
            .map(|c| format!("{} {}", c.name(), m.error_count(*c)))
            .collect::<Vec<_>>()
            .join(", ");
        println!(
            "  model {:<12} ok {} verified {} mismatches {} retries {} backoff {:.1}ms{}",
            m.model.as_deref().unwrap_or("(default)"),
            m.ok,
            m.verified,
            m.mismatches,
            m.retries,
            m.backoff_us as f64 / 1e3,
            if errs.is_empty() { String::new() } else { format!(" errors [{errs}]") }
        );
    }
    println!(
        "  latency  mean {:.0}µs  p50 {:.0}µs  p90 {:.0}µs  p99 {:.0}µs  max {:.0}µs",
        report.mean_latency_us,
        report.p50_latency_us,
        report.p90_latency_us,
        report.p99_latency_us,
        report.max_latency_us
    );
    if report.total_errors() > 0 || report.transport_errors > 0 {
        let codes = ErrorCode::all()
            .iter()
            .filter(|c| report.error_count(**c) > 0)
            .map(|c| format!("{} {}", c.name(), report.error_count(*c)))
            .collect::<Vec<_>>()
            .join(", ");
        println!("  errors   {codes} (transport {})", report.transport_errors);
    }
    if report.verified > 0 {
        println!(
            "  verify   {} responses bit-compared against local Engine::forward, {} mismatches",
            report.verified, report.mismatches
        );
    }
    let json = report.to_json();
    match out {
        Some(path) => {
            std::fs::write(&path, json.to_string_pretty()).map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
            println!("  wrote {path}");
        }
        None => {
            let p = metrics::write_json_report(&format!("loadtest_{label}.json"), &json)?;
            println!("  wrote {}", p.display());
        }
    }
    if stop_server {
        NetClient::connect(&addr, Duration::from_secs(5))?.shutdown_server()?;
        println!("  sent SHUTDOWN; server is draining");
    }
    anyhow::ensure!(
        report.mismatches == 0,
        "{} of {} verified responses were not bit-identical to local Engine::forward",
        report.mismatches,
        report.verified
    );
    Ok(())
}

/// Scrape a live `proxcomp serve` through the METRICS opcode: the
/// versioned JSON snapshot (serving roll-up + wire counters + per-model
/// registry table + per-layer profiles) or Prometheus text exposition.
/// `--stop-server` sends SHUTDOWN after the scrape — the CI pattern is
/// loadtest (no stop) → stats --out snapshot.json --stop-server, so the
/// scrape still sees the live counters.
fn cmd_stats(args: &Args) -> Result<()> {
    use proxcomp::inference::NetClient;
    use std::time::Duration;
    let addr = args.str_or("addr", "127.0.0.1:7733");
    let format = args.str_or("format", "json");
    let out = args.get_str("out");
    let stop_server = args.flag("stop-server");
    let connect_timeout = args.duration_or("connect-timeout", Duration::from_secs(5))?;
    args.finish()?;

    let mut client = NetClient::connect(&addr, connect_timeout)?;
    let body = match format.as_str() {
        "json" => client.metrics_json()?,
        "prom" | "prometheus" => client.metrics_prometheus()?,
        other => anyhow::bail!("--format must be json or prom, got {other:?}"),
    };
    match &out {
        Some(path) => {
            std::fs::write(path, &body).map_err(|e| anyhow::anyhow!("writing {path}: {e}"))?;
            println!("[stats] wrote {path} ({} bytes, {format})", body.len());
        }
        None => {
            print!("{body}");
            if !body.ends_with('\n') {
                println!();
            }
        }
    }
    if stop_server {
        client.shutdown_server()?;
        println!("[stats] sent SHUTDOWN; server is draining");
    }
    Ok(())
}

/// CI bench-gate: compare a fresh `reports/bench_kernels.json` against
/// the committed `BENCH_BASELINE.json`, print (and optionally write) the
/// calibration-normalized delta table, and exit nonzero when any gated
/// group's geomean regresses past `--max-regress` (default 25 %). See
/// `metrics::benchcmp` for the comparison semantics.
fn cmd_bench_compare(args: &Args) -> Result<()> {
    use proxcomp::metrics::benchcmp;
    let baseline = args.str_or("baseline", "BENCH_BASELINE.json");
    let current = args.str_or("current", "reports/bench_kernels.json");
    let max_regress = args.f64_or("max-regress", benchcmp::DEFAULT_MAX_REGRESS)?;
    let gate = args.list_or("gate", &[]);
    let out = args.get_str("out");
    args.finish()?;
    let read = |p: &str| -> Result<Json> {
        let text =
            std::fs::read_to_string(p).map_err(|e| anyhow::anyhow!("reading {p}: {e}"))?;
        proxcomp::util::json::parse(&text).map_err(|e| anyhow::anyhow!("parsing {p}: {e}"))
    };
    let rep = benchcmp::compare_json(&read(&baseline)?, &read(&current)?, max_regress, &gate)?;
    print!("{}", rep.table);
    if let Some(out) = out {
        std::fs::write(&out, &rep.table)?;
        println!("[bench-compare] wrote {out}");
    }
    if !rep.passed() {
        for f in &rep.failures {
            eprintln!("[bench-compare] {f}");
        }
        anyhow::bail!(
            "bench gate failed: {} group(s) regressed more than {:.0}% vs {baseline}",
            rep.failures.len(),
            max_regress * 100.0
        );
    }
    println!(
        "[bench-compare] OK: {} gated group(s) within {:.0}% of {baseline}",
        rep.groups.iter().filter(|g| g.gated).count(),
        max_regress * 100.0
    );
    Ok(())
}

fn cmd_info(args: &Args) -> Result<()> {
    let dir = args.str_or("artifacts-dir", "artifacts");
    args.finish()?;
    let manifest = Manifest::load_or_native(&dir)?;
    println!("manifest: {}/manifest.json", dir);
    for (name, m) in &manifest.models {
        println!(
            "\n{name}: {} → {} classes, {} leaves, {} weights ({} params), dataset {}",
            m.input_shape.iter().map(|d| d.to_string()).collect::<Vec<_>>().join("×"),
            m.num_classes,
            m.params.len(),
            m.num_weights,
            m.num_params,
            m.dataset
        );
        for (step, a) in &m.artifacts {
            println!(
                "  {step:<20} batch {:<4} {} inputs, {} outputs",
                a.batch,
                a.inputs.len(),
                a.outputs.len()
            );
        }
    }
    Ok(())
}

fn print_help() {
    println!(
        "proxcomp — compressed learning of DNNs (Lee & Lee 2019 reproduction)

USAGE: proxcomp <subcommand> [options]

SUBCOMMANDS
  train    run one compression method end to end
           --model mlp|lenet|alexnet_s|vgg_s|resnet_s
           --method spc|pru|mm|ref   --optimizer adam|rmsprop|sgd
           --lambda F --lr F --steps N --retrain-steps N --seed N
  sweep    λ-grid sweep           --lambdas 0.5,1.0,2.0
  seeds    multi-seed variance    --seeds 0,1,2,3
  pipeline offline SpC→debias→compress→serve smoke on the native CPU
           backend; --model mlp-s (default), mlp, lenet-s or lenet —
           conv models run a finite-difference gradient preflight
           (exits nonzero if the gradient check or loss improvement
           fails, the deployed format report is empty, or compression
           ≤ 1×). --quantize adds the Deep-Compression stage: codebook
           quantization (--codebook-size 16, --finetune-steps 0,
           --finetune-lr 1e-4), QCS serving, and two extra gates —
           quantized accuracy within --quant-tolerance (0.05) of the
           debiased model and a strictly smaller checkpoint than CSR.
           --telemetry-out F writes the training telemetry JSONL
           (default reports/pipeline_<model>_telemetry.jsonl)
  quantize codebook-quantize a trained checkpoint to format v2
           --checkpoint F [--out F] [--codebook-size 16]
           [--finetune-steps N --finetune-lr F] [--examples N]
  infer    run a checkpoint through the rust inference engine
           --checkpoint F [--sparse | --quantized] [--batch N]
  report   layer-wise compression table for a checkpoint
  serve    framed-TCP multi-model inference fleet over ModelRegistry
           (see README \"Multi-model serving\" for the wire format +
           error taxonomy)
           --models mlp-s,lenet-s,resnet-s (first id is the v1 default;
           --model x is shorthand for one model) --seed 1 --prune 0.05
           --addr 127.0.0.1:7733 --max-batch 8 --max-wait 2ms
           --max-conns 256 --max-inflight 512 --request-timeout 5s
           --memory-budget N (bytes; 0 = unlimited — lazy-loads engines
           and LRU-evicts over budget) [--stats-out F] [--trace F]
           (--trace writes JSONL trace events; PROXCOMP_TRACE=path does
           the same for any subcommand)
           runs until a client sends SHUTDOWN, then drains in-flight
           requests and reports per-model + aggregate serving stats
  loadtest closed-loop load generator against a live serve
           --addr 127.0.0.1:7733 --clients 100 --duration 10s
           --mix mlp-s,lenet-s,resnet-s (v2 model-routed round-robin) or
           --model lenet-s (v1 default-model frames)
           --seed 1 --prune 0.05 (must match serve so the bit-exactness
           verify can rebuild the same engines; --no-verify skips it)
           --retries 8 (per-request overloaded retry budget with
           exponential backoff) [--out F] [--stop-server]
           reports p50/p99 latency, saturation throughput, retries,
           total backoff time, and per-model + per-error-code counts;
           exits nonzero on any bit mismatch
  stats    scrape a live serve through the METRICS opcode
           --addr 127.0.0.1:7733 --format json|prom [--out F]
           [--stop-server] — JSON is the versioned snapshot (serving,
           net, per-model, per-layer profiles); prom is Prometheus text
  bench-compare  CI perf gate: compare a bench_kernels JSON against the
           committed baseline (calibration-normalized per-group geomean)
           --baseline BENCH_BASELINE.json --current reports/bench_kernels.json
           [--max-regress 0.25] [--gate sec1,sec2] [--out delta.txt]
  info     manifest summary

Shared: --config run.json --artifacts-dir artifacts --verbose"
    );
}
