//! Compressed checkpoints: the on-disk model format.
//!
//! Binary layout:
//!
//! ```text
//! magic "PXCP" | u32 version | u64 header_len | header JSON (UTF-8)
//! then per leaf, in spec order:
//!   u8 encoding (0 = dense, 1 = CSR)
//!   dense: u64 n, then n × f32 (LE)
//!   csr:   u64 rows, u64 cols, u64 nnz,
//!          (rows+1) × u32 ptr, nnz × u32 indices, nnz × f32 data
//! ```
//!
//! Prunable 2-D-viewable leaves whose zero fraction exceeds
//! `CSR_THRESHOLD` are stored CSR (conv weights view as (O, I·KH·KW),
//! exactly the im2col layout the inference engine multiplies against);
//! everything else is dense. `model_size_bytes` on the result is the
//! paper's Table-3 "Model Size" quantity.

use std::io::{Read, Write};
use std::path::Path;

use crate::runtime::{ParamBundle, ParamSpec};
use crate::sparse::CsrMatrix;
use crate::util::json::{self, Json};

const MAGIC: &[u8; 4] = b"PXCP";
const VERSION: u32 = 1;
/// Store CSR when at least this fraction of a leaf is zero (below this
/// the index overhead exceeds the dense payload).
pub const CSR_THRESHOLD: f64 = 0.5;

/// Loaded checkpoint: parameters + metadata.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub params: ParamBundle,
    pub meta: Json,
    /// Bytes of the serialized parameter payload (excl. header).
    pub payload_bytes: usize,
}

/// Serialize a bundle; `meta` carries run provenance (model, method, λ…).
pub fn save(path: &Path, params: &ParamBundle, meta: &Json) -> anyhow::Result<usize> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    f.write_all(MAGIC)?;
    f.write_all(&VERSION.to_le_bytes())?;

    // Header: spec + meta (everything needed to reload without a manifest).
    let mut header = Json::obj();
    header.set("meta", meta.clone());
    let specs: Vec<Json> = params
        .specs
        .iter()
        .map(|s| {
            let mut j = Json::obj();
            j.set("name", Json::from(s.name.as_str()))
                .set("kind", Json::from(s.kind.as_str()))
                .set("shape", Json::from(s.shape.clone()))
                .set("prunable", Json::from(s.prunable))
                .set("layer", Json::from(s.layer.as_str()));
            j
        })
        .collect();
    header.set("specs", Json::Arr(specs));
    let header_text = header.to_string_compact();
    f.write_all(&(header_text.len() as u64).to_le_bytes())?;
    f.write_all(header_text.as_bytes())?;

    let mut payload = 0usize;
    for (spec, values) in params.specs.iter().zip(&params.values) {
        let zero_frac =
            values.iter().filter(|&&v| v == 0.0).count() as f64 / values.len().max(1) as f64;
        let (rows, cols) = matrix_view(spec);
        if spec.prunable && zero_frac >= CSR_THRESHOLD && rows > 0 {
            let csr = CsrMatrix::from_dense(values, rows, cols);
            f.write_all(&[1u8])?;
            f.write_all(&(csr.rows as u64).to_le_bytes())?;
            f.write_all(&(csr.cols as u64).to_le_bytes())?;
            f.write_all(&(csr.nnz() as u64).to_le_bytes())?;
            for &p in &csr.ptr {
                f.write_all(&(p as u32).to_le_bytes())?;
            }
            for &i in &csr.indices {
                f.write_all(&i.to_le_bytes())?;
            }
            for &v in &csr.data {
                f.write_all(&v.to_le_bytes())?;
            }
            payload += 1 + 24 + csr.storage_bytes();
        } else {
            f.write_all(&[0u8])?;
            f.write_all(&(values.len() as u64).to_le_bytes())?;
            for &v in values {
                f.write_all(&v.to_le_bytes())?;
            }
            payload += 1 + 8 + values.len() * 4;
        }
    }
    f.flush()?;
    Ok(payload)
}

/// Load a checkpoint back into a dense `ParamBundle`.
pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
    let mut f = std::io::BufReader::new(std::fs::File::open(path)?);
    let mut magic = [0u8; 4];
    f.read_exact(&mut magic)?;
    anyhow::ensure!(&magic == MAGIC, "not a proxcomp checkpoint (bad magic)");
    let version = read_u32(&mut f)?;
    anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
    let header_len = read_u64(&mut f)? as usize;
    let mut header_bytes = vec![0u8; header_len];
    f.read_exact(&mut header_bytes)?;
    let header = json::parse(std::str::from_utf8(&header_bytes)?)?;
    let meta = header.req("meta")?.clone();
    let specs: Vec<ParamSpec> = header
        .req("specs")?
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|j| {
            Ok(ParamSpec {
                name: j.req("name")?.as_str().unwrap_or("").to_string(),
                kind: j.req("kind")?.as_str().unwrap_or("").to_string(),
                shape: j.req("shape")?.as_usize_vec().unwrap_or_default(),
                prunable: j.req("prunable")?.as_bool().unwrap_or(false),
                layer: j.req("layer")?.as_str().unwrap_or("").to_string(),
            })
        })
        .collect::<anyhow::Result<Vec<_>>>()?;

    let mut values = Vec::with_capacity(specs.len());
    let mut payload = 0usize;
    for spec in &specs {
        let mut enc = [0u8; 1];
        f.read_exact(&mut enc)?;
        match enc[0] {
            0 => {
                let n = read_u64(&mut f)? as usize;
                anyhow::ensure!(n == spec.numel(), "dense leaf size mismatch for {}", spec.name);
                let mut data = vec![0.0f32; n];
                read_f32s(&mut f, &mut data)?;
                payload += 1 + 8 + n * 4;
                values.push(data);
            }
            1 => {
                let rows = read_u64(&mut f)? as usize;
                let cols = read_u64(&mut f)? as usize;
                let nnz = read_u64(&mut f)? as usize;
                anyhow::ensure!(rows * cols == spec.numel(), "csr leaf shape mismatch for {}", spec.name);
                let mut ptr = vec![0u32; rows + 1];
                read_u32s(&mut f, &mut ptr)?;
                let mut indices = vec![0u32; nnz];
                read_u32s(&mut f, &mut indices)?;
                let mut data = vec![0.0f32; nnz];
                read_f32s(&mut f, &mut data)?;
                let csr = CsrMatrix {
                    rows,
                    cols,
                    ptr: ptr.iter().map(|&p| p as usize).collect(),
                    indices,
                    data,
                };
                csr.validate()?;
                payload += 1 + 24 + csr.storage_bytes();
                values.push(csr.to_dense());
            }
            other => anyhow::bail!("unknown leaf encoding {other}"),
        }
    }
    Ok(Checkpoint {
        params: ParamBundle { specs, values },
        meta,
        payload_bytes: payload,
    })
}

/// 2-D view used for CSR storage: fc (N, K); conv (O, I·KH·KW).
pub fn matrix_view(spec: &ParamSpec) -> (usize, usize) {
    match spec.shape.len() {
        2 => (spec.shape[0], spec.shape[1]),
        4 => (spec.shape[0], spec.shape[1] * spec.shape[2] * spec.shape[3]),
        _ => (0, 0),
    }
}

fn read_u32(f: &mut impl Read) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    f.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u64(f: &mut impl Read) -> anyhow::Result<u64> {
    let mut b = [0u8; 8];
    f.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u32s(f: &mut impl Read, out: &mut [u32]) -> anyhow::Result<()> {
    let mut bytes = vec![0u8; out.len() * 4];
    f.read_exact(&mut bytes)?;
    for (i, chunk) in bytes.chunks_exact(4).enumerate() {
        out[i] = u32::from_le_bytes(chunk.try_into().unwrap());
    }
    Ok(())
}

fn read_f32s(f: &mut impl Read, out: &mut [f32]) -> anyhow::Result<()> {
    let mut bytes = vec![0u8; out.len() * 4];
    f.read_exact(&mut bytes)?;
    for (i, chunk) in bytes.chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes(chunk.try_into().unwrap());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn test_bundle(sparse: bool) -> ParamBundle {
        let mut rng = crate::util::rng::Rng::new(40);
        let specs = vec![
            ParamSpec {
                name: "conv1_w".into(),
                kind: "conv_w".into(),
                shape: vec![4, 2, 3, 3],
                prunable: true,
                layer: "conv1".into(),
            },
            ParamSpec {
                name: "conv1_b".into(),
                kind: "conv_b".into(),
                shape: vec![4],
                prunable: false,
                layer: "conv1".into(),
            },
            ParamSpec {
                name: "fc1_w".into(),
                kind: "fc_w".into(),
                shape: vec![10, 72],
                prunable: true,
                layer: "fc1".into(),
            },
        ];
        let mut values: Vec<Vec<f32>> = specs
            .iter()
            .map(|s| rng.normal_vec(s.numel(), 1.0))
            .collect();
        if sparse {
            for v in values[2].iter_mut() {
                if v.abs() < 1.5 {
                    *v = 0.0;
                }
            }
        }
        ParamBundle { specs, values }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("proxcomp_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn dense_roundtrip() {
        let b = test_bundle(false);
        let path = tmp("dense.pxcp");
        let mut meta = Json::obj();
        meta.set("model", Json::from("test"));
        save(&path, &b, &meta).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.params.values, b.values);
        assert_eq!(ck.meta.get("model").unwrap().as_str(), Some("test"));
        assert_eq!(ck.params.specs.len(), 3);
        assert_eq!(ck.params.specs[0].shape, vec![4, 2, 3, 3]);
    }

    #[test]
    fn sparse_roundtrip_uses_csr() {
        let b = test_bundle(true);
        let path = tmp("sparse.pxcp");
        let bytes = save(&path, &b, &Json::obj()).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.params.values, b.values);
        // fc1_w (~87% zeros) stored CSR ⇒ payload much smaller than dense.
        let dense_bytes: usize = b.values.iter().map(|v| v.len() * 4).sum();
        assert!(bytes < dense_bytes, "{bytes} vs {dense_bytes}");
        assert_eq!(ck.payload_bytes, bytes);
    }

    #[test]
    fn compression_reduces_file_size() {
        let dense = test_bundle(false);
        let sparse = test_bundle(true);
        let pd = tmp("size_dense.pxcp");
        let ps = tmp("size_sparse.pxcp");
        save(&pd, &dense, &Json::obj()).unwrap();
        save(&ps, &sparse, &Json::obj()).unwrap();
        let sd = std::fs::metadata(&pd).unwrap().len();
        let ss = std::fs::metadata(&ps).unwrap().len();
        assert!(ss < sd, "sparse file {ss} >= dense file {sd}");
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.pxcp");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(load(&path).is_err());
    }

    #[test]
    fn matrix_views() {
        let b = test_bundle(false);
        assert_eq!(matrix_view(&b.specs[0]), (4, 18));
        assert_eq!(matrix_view(&b.specs[1]), (0, 0)); // 1-D → no CSR view
        assert_eq!(matrix_view(&b.specs[2]), (10, 72));
    }
}
