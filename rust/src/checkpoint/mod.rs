//! Compressed checkpoints: the on-disk model format.
//!
//! Binary layout (format v2; v1 files still load):
//!
//! ```text
//! magic "PXCP" | u32 version | u64 header_len | header JSON (UTF-8)
//! then per leaf, in spec order:
//!   u8 encoding (0 = dense, 1 = CSR, 2 = quantized CSR)
//!   dense: u64 n, then n × f32 (LE)
//!   csr:   u64 rows, u64 cols, u64 nnz,
//!          (rows+1) × u32 ptr, nnz × u32 indices, nnz × f32 data
//!   qcs:   u64 rows, u64 cols, u64 nnz,
//!          u16 codebook_len, u8 code_bits (4|8), u8 index_bytes (2|4),
//!          codebook_len × f32 codebook, (rows+1) × u32 ptr,
//!          nnz × (u16|u32) indices, packed codes (⌈nnz/2⌉ or nnz bytes)
//! ```
//!
//! Prunable 2-D-viewable leaves whose zero fraction exceeds
//! `CSR_THRESHOLD` are stored CSR (conv weights view as (O, I·KH·KW),
//! exactly the im2col layout the inference engine multiplies against);
//! everything else is dense. [`save_quantized`] additionally persists
//! codebook-quantized leaves (`quant::QcsMatrix`) under tag 2 — the
//! Deep-Compression artifact `proxcomp quantize` emits.
//! `model_size_bytes` on the result is the paper's Table-3 "Model Size"
//! quantity.
//!
//! Loading is defensive: decoding runs entirely on
//! [`crate::util::cursor::BoundedReader`], the shared hardened cursor,
//! so every header-declared size is bounded against the remaining input
//! *before* any allocation, all dimension arithmetic is
//! overflow-checked, and bad magic, unknown versions, truncated
//! payloads, and ptr/nnz inconsistencies all fail with explicit errors
//! (the corrupt-bytes unit tests below and the `fuzz/` targets pin
//! this).

use std::io::Write;
use std::path::Path;

use crate::quant::{QuantLeaf, QuantizedModel};
use crate::runtime::{ParamBundle, ParamSpec};
use crate::sparse::CsrMatrix;
use crate::util::cursor::{self, BoundedReader};
use crate::util::json::{self, Json};

const MAGIC: &[u8; 4] = b"PXCP";
/// Newest format this build reads (the loader accepts `1..=VERSION`).
/// Writers stamp the *lowest* version whose features they use: plain
/// dense/CSR checkpoints stay v1 so pre-quantization readers keep
/// loading them; only quantized (tag-2) leaves require v2.
const VERSION: u32 = 2;
/// Sanity cap on the header JSON (a corrupt length field must not OOM).
const MAX_HEADER_LEN: usize = 16 << 20;
/// Per-leaf element cap for decoding. Sparse leaves are expanded to a
/// dense `rows × cols` buffer on load, so a kilobyte file declaring a
/// terabyte shape would OOM *after* passing every byte-level bound;
/// this caps the expansion at 2²⁸ elements (1 GiB of f32 per leaf) —
/// an order of magnitude above the largest Deep-Compression-era layer
/// (VGG-16 fc6, ~102 M weights).
const MAX_DECODE_NUMEL: usize = 1 << 28;
/// Store CSR when at least this fraction of a leaf is zero (below this
/// the index overhead exceeds the dense payload).
pub const CSR_THRESHOLD: f64 = 0.5;

/// Loaded checkpoint: parameters + metadata.
#[derive(Debug, Clone)]
pub struct Checkpoint {
    pub params: ParamBundle,
    pub meta: Json,
    /// Bytes of the serialized parameter payload (excl. header).
    pub payload_bytes: usize,
    /// Per-leaf quantized representation for tag-2 leaves (aligned with
    /// `params.specs`; `None` for dense/CSR leaves). `params.values`
    /// always holds the dequantized dense view, so every existing
    /// consumer works unchanged.
    pub quantized: Vec<Option<crate::quant::QcsMatrix>>,
}

impl Checkpoint {
    /// True when any leaf was stored codebook-quantized (a v2 artifact
    /// from `proxcomp quantize` / `pipeline --quantize`).
    pub fn is_quantized(&self) -> bool {
        self.quantized.iter().any(Option::is_some)
    }

    /// Reassemble the quantized model for bit-faithful serving
    /// (`Engine::builder(..).quantized(..)`): tag-2 leaves keep their stored
    /// codebooks, everything else rides along as dense f32.
    pub fn to_quantized_model(&self) -> QuantizedModel {
        let leaves = self
            .quantized
            .iter()
            .zip(&self.params.values)
            .map(|(q, v)| match q {
                Some(m) => QuantLeaf::Qcs(m.clone()),
                None => QuantLeaf::Dense(v.clone()),
            })
            .collect();
        QuantizedModel { specs: self.params.specs.clone(), leaves }
    }
}

fn write_header(f: &mut impl Write, version: u32, specs: &[ParamSpec], meta: &Json) -> anyhow::Result<()> {
    f.write_all(MAGIC)?;
    f.write_all(&version.to_le_bytes())?;
    // Header: spec + meta (everything needed to reload without a manifest).
    let mut header = Json::obj();
    header.set("meta", meta.clone());
    let spec_arr: Vec<Json> = specs
        .iter()
        .map(|s| {
            let mut j = Json::obj();
            j.set("name", Json::from(s.name.as_str()))
                .set("kind", Json::from(s.kind.as_str()))
                .set("shape", Json::from(s.shape.clone()))
                .set("prunable", Json::from(s.prunable))
                .set("layer", Json::from(s.layer.as_str()));
            j
        })
        .collect();
    header.set("specs", Json::Arr(spec_arr));
    let header_text = header.to_string_compact();
    f.write_all(&(header_text.len() as u64).to_le_bytes())?;
    f.write_all(header_text.as_bytes())?;
    Ok(())
}

/// Write one f32 leaf with the dense/CSR encoding choice; returns its
/// payload bytes.
fn write_f32_leaf(f: &mut impl Write, spec: &ParamSpec, values: &[f32]) -> anyhow::Result<usize> {
    let zero_frac =
        values.iter().filter(|&&v| v == 0.0).count() as f64 / values.len().max(1) as f64;
    let csr_view = if spec.prunable && zero_frac >= CSR_THRESHOLD {
        matrix_view(spec).filter(|&(rows, _)| rows > 0)
    } else {
        None
    };
    if let Some((rows, cols)) = csr_view {
        let csr = CsrMatrix::from_dense(values, rows, cols);
        f.write_all(&[1u8])?;
        f.write_all(&(csr.rows as u64).to_le_bytes())?;
        f.write_all(&(csr.cols as u64).to_le_bytes())?;
        f.write_all(&(csr.nnz() as u64).to_le_bytes())?;
        for &p in &csr.ptr {
            f.write_all(&(p as u32).to_le_bytes())?;
        }
        for &i in &csr.indices {
            f.write_all(&i.to_le_bytes())?;
        }
        for &v in &csr.data {
            f.write_all(&v.to_le_bytes())?;
        }
        Ok(1 + 24 + csr.storage_bytes())
    } else {
        f.write_all(&[0u8])?;
        f.write_all(&(values.len() as u64).to_le_bytes())?;
        for &v in values {
            f.write_all(&v.to_le_bytes())?;
        }
        Ok(1 + 8 + values.len() * 4)
    }
}

/// Write one quantized-CSR leaf (tag 2); returns its payload bytes.
fn write_qcs_leaf(f: &mut impl Write, q: &crate::quant::QcsMatrix) -> anyhow::Result<usize> {
    f.write_all(&[2u8])?;
    f.write_all(&(q.rows as u64).to_le_bytes())?;
    f.write_all(&(q.cols as u64).to_le_bytes())?;
    f.write_all(&(q.nnz() as u64).to_le_bytes())?;
    f.write_all(&(q.codebook().len() as u16).to_le_bytes())?;
    f.write_all(&[q.code_bits() as u8])?;
    f.write_all(&[q.index_bytes() as u8])?;
    for &c in q.codebook() {
        f.write_all(&c.to_le_bytes())?;
    }
    for &p in &q.ptr {
        f.write_all(&(p as u32).to_le_bytes())?;
    }
    // Indices re-serialize through the accessor view; codes stream
    // verbatim (`code_bytes` is already the file's pack format).
    let nnz = q.nnz();
    if q.index_bytes() == 2 {
        for k in 0..nnz {
            f.write_all(&(q.index_at(k) as u16).to_le_bytes())?;
        }
    } else {
        for k in 0..nnz {
            f.write_all(&(q.index_at(k) as u32).to_le_bytes())?;
        }
    }
    f.write_all(q.code_bytes())?;
    Ok(1 + 24 + 4 + q.storage_bytes())
}

/// Serialize a bundle; `meta` carries run provenance (model, method, λ…).
pub fn save(path: &Path, params: &ParamBundle, meta: &Json) -> anyhow::Result<usize> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    // Dense/CSR-only payloads are byte-identical to the v1 layout, so
    // stamp v1 and stay loadable by pre-quantization readers.
    write_header(&mut f, 1, &params.specs, meta)?;
    let mut payload = 0usize;
    for (spec, values) in params.specs.iter().zip(&params.values) {
        payload += write_f32_leaf(&mut f, spec, values)?;
    }
    f.flush()?;
    Ok(payload)
}

/// Serialize a quantized model: tag-2 quantized-CSR for its quantized
/// leaves, the usual dense/CSR choice for the f32 rest. Returns payload
/// bytes — the quantized Table-3 "Model Size".
pub fn save_quantized(path: &Path, qm: &QuantizedModel, meta: &Json) -> anyhow::Result<usize> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    // Tag-2 leaves need v2; an all-f32 quantized model degenerates to
    // the v1 layout, so keep it readable by pre-quantization builds.
    let version = if qm.leaves.iter().any(|l| matches!(l, QuantLeaf::Qcs(_))) { VERSION } else { 1 };
    write_header(&mut f, version, &qm.specs, meta)?;
    let mut payload = 0usize;
    for (spec, leaf) in qm.specs.iter().zip(&qm.leaves) {
        payload += match leaf {
            QuantLeaf::Dense(v) => write_f32_leaf(&mut f, spec, v)?,
            QuantLeaf::Qcs(q) => write_qcs_leaf(&mut f, q)?,
        };
    }
    f.flush()?;
    Ok(payload)
}

/// Load a checkpoint back into a dense `ParamBundle` (+ the stored
/// quantized leaves when present).
pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
    decode(&std::fs::read(path)?)
}

/// Decode a checkpoint from raw bytes — the untrusted-input core that
/// [`load`] wraps and the `fuzz/` targets drive directly. Every
/// declared size is bounded by the remaining input before allocation;
/// every dimension product is overflow-checked.
pub fn decode(bytes: &[u8]) -> anyhow::Result<Checkpoint> {
    let mut r = BoundedReader::new(bytes, "checkpoint");
    let magic = r.take(4, "magic")?;
    anyhow::ensure!(magic == &MAGIC[..], "not a proxcomp checkpoint (bad magic {magic:02x?})");
    let version = r.read_u32("version")?;
    anyhow::ensure!(
        (1..=VERSION).contains(&version),
        "unsupported checkpoint version {version} (this build reads 1..={VERSION})"
    );
    let header_len = r.read_u64("header length")?;
    anyhow::ensure!(
        header_len <= MAX_HEADER_LEN as u64,
        "implausible header length {header_len} (corrupt checkpoint?)"
    );
    let header_bytes = r.take(header_len as usize, "header")?;
    let header = json::parse(std::str::from_utf8(header_bytes)?)?;
    let meta = header.req("meta")?.clone();
    let specs: Vec<ParamSpec> = header
        .req("specs")?
        .as_arr()
        .unwrap_or(&[])
        .iter()
        .map(|j| {
            Ok(ParamSpec {
                name: j.req("name")?.as_str().unwrap_or("").to_string(),
                kind: j.req("kind")?.as_str().unwrap_or("").to_string(),
                shape: j.req("shape")?.as_usize_vec().unwrap_or_default(),
                prunable: j.req("prunable")?.as_bool().unwrap_or(false),
                layer: j.req("layer")?.as_str().unwrap_or("").to_string(),
            })
        })
        .collect::<anyhow::Result<Vec<_>>>()?;
    // Per-spec element counts with overflow-checked shape products: the
    // shape is header-declared, so a crafted `[2^32, 2^32]` must fail
    // here, not wrap to something small inside a later size guard.
    let mut cells = Vec::with_capacity(specs.len());
    for spec in &specs {
        let mut n = 1usize;
        for &d in &spec.shape {
            n = cursor::checked_mul(n, d, &format!("leaf {} shape {:?}", spec.name, spec.shape))?;
        }
        cells.push(n);
    }

    let mut values = Vec::with_capacity(specs.len());
    let mut quantized: Vec<Option<crate::quant::QcsMatrix>> = Vec::with_capacity(specs.len());
    let mut payload = 0usize;
    for (spec, &numel) in specs.iter().zip(&cells) {
        match r.read_u8("leaf encoding tag")? {
            0 => {
                let n = r.read_len_u64("dense leaf length")?;
                anyhow::ensure!(n == numel, "dense leaf size mismatch for {}", spec.name);
                let data = r.read_f32s(n, "dense leaf values")?;
                payload += 1 + 8 + n * 4;
                values.push(data);
                quantized.push(None);
            }
            1 => {
                let (rows, cols, nnz, nnz32) = read_sparse_dims(&mut r, spec, numel, "csr")?;
                let ptr_len = cursor::checked_add(rows, 1, "csr row-pointer count")?;
                let ptr = r.read_u32s(ptr_len, "csr row pointers")?;
                anyhow::ensure!(
                    ptr.last().copied() == Some(nnz32),
                    "csr leaf {}: ptr/nnz inconsistency (last ptr {} != nnz {nnz})",
                    spec.name,
                    ptr.last().copied().unwrap_or(0)
                );
                let indices = r.read_u32s(nnz, "csr column indices")?;
                let data = r.read_f32s(nnz, "csr values")?;
                let csr = CsrMatrix {
                    rows,
                    cols,
                    ptr: ptr.iter().map(|&p| p as usize).collect(),
                    indices,
                    data,
                };
                csr.validate()?;
                payload += 1 + 24 + csr.storage_bytes();
                values.push(csr.to_dense());
                quantized.push(None);
            }
            2 => {
                let (rows, cols, nnz, nnz32) = read_sparse_dims(&mut r, spec, numel, "qcs")?;
                let k = r.read_u16("qcs codebook length")? as usize;
                let small = r.take(2, "qcs packing descriptor")?;
                let (code_bits, idx_bytes) = (small[0] as usize, small[1] as usize);
                anyhow::ensure!(
                    (code_bits == 4 || code_bits == 8) && (idx_bytes == 2 || idx_bytes == 4),
                    "qcs leaf {}: bad packing descriptor (code_bits {code_bits}, index_bytes {idx_bytes})",
                    spec.name
                );
                anyhow::ensure!(
                    k <= 256 && (code_bits == 8 || k <= 16),
                    "qcs leaf {}: codebook length {k} does not fit {code_bits}-bit codes",
                    spec.name
                );
                let codebook = r.read_f32s(k, "qcs codebook")?;
                let ptr_len = cursor::checked_add(rows, 1, "qcs row-pointer count")?;
                let ptr = r.read_u32s(ptr_len, "qcs row pointers")?;
                anyhow::ensure!(
                    ptr.last().copied() == Some(nnz32),
                    "qcs leaf {}: ptr/nnz inconsistency (last ptr {} != nnz {nnz})",
                    spec.name,
                    ptr.last().copied().unwrap_or(0)
                );
                let indices: Vec<u32> = if idx_bytes == 2 {
                    r.read_u16s(nnz, "qcs column indices")?.into_iter().map(|i| i as u32).collect()
                } else {
                    r.read_u32s(nnz, "qcs column indices")?
                };
                let codes: Vec<u8> = if code_bits == 4 {
                    let packed = r.take(nnz.div_ceil(2), "qcs packed codes")?;
                    (0..nnz).map(|j| (packed[j / 2] >> ((j % 2) * 4)) & 0xF).collect()
                } else {
                    r.read_bytes(nnz, "qcs codes")?
                };
                let q = crate::quant::QcsMatrix::from_parts(
                    rows,
                    cols,
                    ptr.iter().map(|&p| p as usize).collect(),
                    codebook,
                    indices,
                    codes,
                )?;
                payload += 1 + 24 + 4 + q.storage_bytes();
                values.push(q.to_dense());
                quantized.push(Some(q));
            }
            other => anyhow::bail!("unknown leaf encoding {other}"),
        }
    }
    r.expect_empty("the last leaf")?;
    Ok(Checkpoint {
        params: ParamBundle { specs, values },
        meta,
        payload_bytes: payload,
        quantized,
    })
}

/// Shared CSR/QCS dimension header: `rows | cols | nnz`, every value
/// validated with checked arithmetic against the header-declared spec
/// *before* anything downstream allocates. Returns
/// `(rows, cols, nnz, nnz_as_u32)`.
fn read_sparse_dims(
    r: &mut BoundedReader<'_>,
    spec: &ParamSpec,
    numel: usize,
    kind: &str,
) -> anyhow::Result<(usize, usize, usize, u32)> {
    let rows = r.read_len_u64(&format!("{kind} rows"))?;
    let cols = r.read_len_u64(&format!("{kind} cols"))?;
    let nnz = r.read_len_u64(&format!("{kind} nnz"))?;
    // Sparse leaves must view as a matrix: reject non-2-D/4-D specs
    // explicitly instead of letting a zero-sized fallback view slide
    // into CSR construction.
    let (vr, vc) = matrix_view(spec).ok_or_else(|| {
        anyhow::anyhow!(
            "{kind} leaf {}: spec shape {:?} has no 2-D matrix view (rank must be 2 or 4)",
            spec.name,
            spec.shape
        )
    })?;
    anyhow::ensure!(
        rows == vr && cols == vc,
        "{kind} leaf {}: declared {rows}×{cols} does not match the spec's {vr}×{vc} view",
        spec.name
    );
    let cells = cursor::checked_mul(rows, cols, &format!("{kind} leaf {} dimensions", spec.name))?;
    anyhow::ensure!(cells == numel, "{kind} leaf shape mismatch for {}", spec.name);
    anyhow::ensure!(nnz <= cells, "{kind} leaf {}: nnz {nnz} exceeds {rows}×{cols}", spec.name);
    // The on-disk row pointers are u32: an nnz the encoding cannot even
    // represent must fail here, not silently truncate in a comparison.
    let nnz32 = u32::try_from(nnz).map_err(|_| {
        anyhow::anyhow!("{kind} leaf {}: nnz {nnz} does not fit the u32 row-pointer encoding", spec.name)
    })?;
    anyhow::ensure!(
        cells <= MAX_DECODE_NUMEL,
        "{kind} leaf {}: {rows}×{cols} is implausibly large to expand (cap {MAX_DECODE_NUMEL} elements)",
        spec.name
    );
    Ok((rows, cols, nnz, nnz32))
}

/// 2-D view used for CSR storage: fc (N, K); conv (O, I·KH·KW).
/// `None` for shapes with no matrix view (rank ≠ 2/4, or a 4-D fan-in
/// product that overflows) — callers must reject or fall back to dense
/// explicitly.
pub fn matrix_view(spec: &ParamSpec) -> Option<(usize, usize)> {
    match spec.shape.len() {
        2 => Some((spec.shape[0], spec.shape[1])),
        4 => {
            let fan = spec.shape[1].checked_mul(spec.shape[2])?.checked_mul(spec.shape[3])?;
            Some((spec.shape[0], fan))
        }
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{quantize_bundle, QuantConfig};

    fn test_bundle(sparse: bool) -> ParamBundle {
        let mut rng = crate::util::rng::Rng::new(40);
        let specs = vec![
            ParamSpec {
                name: "conv1_w".into(),
                kind: "conv_w".into(),
                shape: vec![4, 2, 3, 3],
                prunable: true,
                layer: "conv1".into(),
            },
            ParamSpec {
                name: "conv1_b".into(),
                kind: "conv_b".into(),
                shape: vec![4],
                prunable: false,
                layer: "conv1".into(),
            },
            ParamSpec {
                name: "fc1_w".into(),
                kind: "fc_w".into(),
                shape: vec![10, 72],
                prunable: true,
                layer: "fc1".into(),
            },
        ];
        let mut values: Vec<Vec<f32>> = specs
            .iter()
            .map(|s| rng.normal_vec(s.numel(), 1.0))
            .collect();
        if sparse {
            for v in values[2].iter_mut() {
                if v.abs() < 1.5 {
                    *v = 0.0;
                }
            }
        }
        ParamBundle { specs, values }
    }

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("proxcomp_ckpt_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn dense_roundtrip() {
        let b = test_bundle(false);
        let path = tmp("dense.pxcp");
        let mut meta = Json::obj();
        meta.set("model", Json::from("test"));
        save(&path, &b, &meta).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.params.values, b.values);
        assert_eq!(ck.meta.get("model").unwrap().as_str(), Some("test"));
        assert_eq!(ck.params.specs.len(), 3);
        assert_eq!(ck.params.specs[0].shape, vec![4, 2, 3, 3]);
        assert!(!ck.is_quantized());
    }

    #[test]
    fn sparse_roundtrip_uses_csr() {
        let b = test_bundle(true);
        let path = tmp("sparse.pxcp");
        let bytes = save(&path, &b, &Json::obj()).unwrap();
        let ck = load(&path).unwrap();
        assert_eq!(ck.params.values, b.values);
        // fc1_w (~87% zeros) stored CSR ⇒ payload much smaller than dense.
        let dense_bytes: usize = b.values.iter().map(|v| v.len() * 4).sum();
        assert!(bytes < dense_bytes, "{bytes} vs {dense_bytes}");
        assert_eq!(ck.payload_bytes, bytes);
    }

    #[test]
    fn compression_reduces_file_size() {
        let dense = test_bundle(false);
        let sparse = test_bundle(true);
        let pd = tmp("size_dense.pxcp");
        let ps = tmp("size_sparse.pxcp");
        save(&pd, &dense, &Json::obj()).unwrap();
        save(&ps, &sparse, &Json::obj()).unwrap();
        let sd = std::fs::metadata(&pd).unwrap().len();
        let ss = std::fs::metadata(&ps).unwrap().len();
        assert!(ss < sd, "sparse file {ss} >= dense file {sd}");
    }

    #[test]
    fn quantized_roundtrip_is_bit_faithful_and_smaller() {
        let b = test_bundle(true);
        // Lower the nnz floor so the 72-col fc leaf quantizes in-test.
        let cfg = QuantConfig { min_quant_nnz: 8, ..QuantConfig::default() };
        let (qm, _) = quantize_bundle(&b, &cfg);
        let pq = tmp("quant.pxcp");
        let pc = tmp("quant_ref.pxcp");
        let q_bytes = save_quantized(&pq, &qm, &Json::obj()).unwrap();
        let c_bytes = save(&pc, &b, &Json::obj()).unwrap();
        assert!(q_bytes < c_bytes, "quantized {q_bytes} >= csr {c_bytes}");
        let ck = load(&pq).unwrap();
        assert!(ck.is_quantized());
        assert_eq!(ck.payload_bytes, q_bytes);
        // Dequantized dense view matches the in-memory quantized model…
        assert_eq!(ck.params.values, qm.to_bundle().values);
        // …and the stored QcsMatrix round-trips exactly (codebook, codes,
        // pattern), so serving after reload is bit-identical.
        let back = ck.to_quantized_model();
        for (a, b) in qm.leaves.iter().zip(&back.leaves) {
            match (a, b) {
                (crate::quant::QuantLeaf::Qcs(x), crate::quant::QuantLeaf::Qcs(y)) => {
                    assert_eq!(x, y)
                }
                (crate::quant::QuantLeaf::Dense(x), crate::quant::QuantLeaf::Dense(y)) => {
                    assert_eq!(x, y)
                }
                _ => panic!("leaf encoding changed across the roundtrip"),
            }
        }
    }

    #[test]
    fn writers_stamp_lowest_sufficient_version() {
        // Dense/CSR-only payloads are v1-layout bytes, so they must
        // stay stamped v1 for pre-quantization readers; only a tag-2
        // (quantized) leaf escalates the file to v2.
        let b = test_bundle(true);
        let p1 = tmp("ver_f32.pxcp");
        save(&p1, &b, &Json::obj()).unwrap();
        let bytes = std::fs::read(&p1).unwrap();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), 1);
        let cfg = QuantConfig { min_quant_nnz: 8, ..QuantConfig::default() };
        let (qm, _) = quantize_bundle(&b, &cfg);
        let p2 = tmp("ver_quant.pxcp");
        save_quantized(&p2, &qm, &Json::obj()).unwrap();
        let bytes = std::fs::read(&p2).unwrap();
        assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), 2);
    }

    #[test]
    fn rejects_garbage() {
        let path = tmp("garbage.pxcp");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("bad magic"), "{err}");
    }

    #[test]
    fn rejects_unknown_version() {
        let path = tmp("version99.pxcp");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&99u32.to_le_bytes());
        bytes.extend_from_slice(&0u64.to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("unsupported checkpoint version 99"), "{err}");
    }

    #[test]
    fn rejects_truncated_payload() {
        let b = test_bundle(true);
        let path = tmp("trunc.pxcp");
        save(&path, &b, &Json::obj()).unwrap();
        let full = std::fs::read(&path).unwrap();
        // Cut mid-payload (keep the header intact) at several depths.
        for keep in [full.len() - 1, full.len() - 100, full.len() * 3 / 4] {
            let tp = tmp("trunc_cut.pxcp");
            std::fs::write(&tp, &full[..keep]).unwrap();
            let err = load(&tp).unwrap_err().to_string();
            assert!(err.contains("truncated checkpoint"), "keep {keep}: {err}");
        }
    }

    #[test]
    fn rejects_implausible_header_length() {
        let path = tmp("badheader.pxcp");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&(u64::MAX).to_le_bytes());
        std::fs::write(&path, bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("implausible header length"), "{err}");
    }

    #[test]
    fn rejects_ptr_nnz_inconsistency() {
        // Hand-built v2 checkpoint: one CSR leaf whose last row pointer
        // disagrees with the declared nnz.
        let path = tmp("badptr.pxcp");
        let header = r#"{"meta":{},"specs":[{"name":"fc1_w","kind":"fc_w","shape":[2,3],"prunable":true,"layer":"fc1"}]}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.push(1u8); // CSR tag
        bytes.extend_from_slice(&2u64.to_le_bytes()); // rows
        bytes.extend_from_slice(&3u64.to_le_bytes()); // cols
        bytes.extend_from_slice(&2u64.to_le_bytes()); // nnz = 2
        for p in [0u32, 1, 3] {
            bytes.extend_from_slice(&p.to_le_bytes()); // last ptr 3 != nnz 2
        }
        for i in [0u32, 2] {
            bytes.extend_from_slice(&i.to_le_bytes());
        }
        for v in [1.0f32, 2.0] {
            bytes.extend_from_slice(&v.to_le_bytes());
        }
        std::fs::write(&path, bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("ptr/nnz inconsistency"), "{err}");
    }

    #[test]
    fn rejects_oversized_nnz() {
        let path = tmp("badnnz.pxcp");
        let header = r#"{"meta":{},"specs":[{"name":"fc1_w","kind":"fc_w","shape":[2,3],"prunable":true,"layer":"fc1"}]}"#;
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.push(1u8);
        bytes.extend_from_slice(&2u64.to_le_bytes());
        bytes.extend_from_slice(&3u64.to_le_bytes());
        bytes.extend_from_slice(&100u64.to_le_bytes()); // nnz 100 > 6
        std::fs::write(&path, bytes).unwrap();
        let err = load(&path).unwrap_err().to_string();
        assert!(err.contains("exceeds"), "{err}");
    }

    #[test]
    fn matrix_views() {
        let b = test_bundle(false);
        assert_eq!(matrix_view(&b.specs[0]), Some((4, 18)));
        assert_eq!(matrix_view(&b.specs[1]), None); // 1-D → no CSR view
        assert_eq!(matrix_view(&b.specs[2]), Some((10, 72)));
        // A 4-D fan-in product that overflows has no view either.
        let huge = ParamSpec {
            name: "conv_x".into(),
            kind: "conv_w".into(),
            shape: vec![2, usize::MAX, 2, 2],
            prunable: true,
            layer: "conv_x".into(),
        };
        assert_eq!(matrix_view(&huge), None);
    }

    /// Header + one-leaf body builder for hand-crafted corrupt files.
    fn crafted(shape: &str, body: &[u8]) -> Vec<u8> {
        let header = format!(
            r#"{{"meta":{{}},"specs":[{{"name":"fc1_w","kind":"fc_w","shape":{shape},"prunable":true,"layer":"fc1"}}]}}"#
        );
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&VERSION.to_le_bytes());
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(body);
        bytes
    }

    // --- fuzz-found regression pins -------------------------------------
    // Each test below is a minimized corrupt-bytes reproducer (also
    // committed under fuzz/corpus/) that crashed or mis-validated on the
    // pre-cursor decoder; the bounded-cursor rewrite must answer each
    // with an explicit error — never an allocation abort or a wrap.

    #[test]
    fn rejects_header_declared_sizes_beyond_file() {
        // A legitimate-looking 1 M × 16 CSR leaf whose row-pointer array
        // alone would be 4 MB — but the file ends right after the dims.
        // The old decoder allocated `vec![0u32; rows + 1]` first and hit
        // EOF later; the bounded cursor must reject on arithmetic alone.
        let mut body = vec![1u8];
        body.extend_from_slice(&(1u64 << 20).to_le_bytes()); // rows
        body.extend_from_slice(&16u64.to_le_bytes()); // cols
        body.extend_from_slice(&0u64.to_le_bytes()); // nnz
        let bytes = crafted("[1048576,16]", &body);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("truncated checkpoint while reading csr row pointers"), "{err}");
    }

    #[test]
    fn rejects_wrapping_dimension_products() {
        // rows = 2^63 + 3, cols = 2: the unchecked release-build product
        // wraps to 6 and used to sail past `rows * cols == numel` on a
        // [2,3] spec — after which `rows + 1` row pointers aborts the
        // allocator. Both multiplies must be checked now.
        let mut body = vec![1u8];
        body.extend_from_slice(&((1u64 << 63) + 3).to_le_bytes()); // rows
        body.extend_from_slice(&2u64.to_le_bytes()); // cols
        body.extend_from_slice(&6u64.to_le_bytes()); // nnz
        let bytes = crafted("[2,3]", &body);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(
            err.contains("does not match the spec's") || err.contains("overflows"),
            "{err}"
        );
    }

    #[test]
    fn rejects_shape_product_overflow() {
        // The spec shape itself is attacker-controlled JSON: [2^32, 2^32]
        // must fail in the checked shape-product pass, not wrap to 0.
        let bytes = crafted("[4294967296,4294967296]", &[1u8]);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("overflows"), "{err}");
    }

    #[test]
    fn rejects_nnz_beyond_u32_encoding() {
        // nnz = 2^32 passes `nnz <= rows×cols` on a 65536² spec, then
        // `nnz as u32` silently truncated to 0 and matched an all-zero
        // ptr array. Must be rejected by `u32::try_from` instead.
        let mut body = vec![1u8];
        body.extend_from_slice(&65536u64.to_le_bytes()); // rows
        body.extend_from_slice(&65536u64.to_le_bytes()); // cols
        body.extend_from_slice(&(1u64 << 32).to_le_bytes()); // nnz
        let bytes = crafted("[65536,65536]", &body);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("does not fit the u32 row-pointer encoding"), "{err}");
    }

    #[test]
    fn rejects_implausibly_large_sparse_expansion() {
        // 65536×65536 with a tiny nnz passes every byte-level bound (the
        // file really does hold one row pointer per row) — but expanding
        // it to dense would allocate 16 GiB. The numel cap must refuse.
        let mut body = vec![1u8];
        body.extend_from_slice(&65536u64.to_le_bytes()); // rows
        body.extend_from_slice(&65536u64.to_le_bytes()); // cols
        body.extend_from_slice(&0u64.to_le_bytes()); // nnz
        let bytes = crafted("[65536,65536]", &body);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("implausibly large to expand"), "{err}");
    }

    #[test]
    fn rejects_sparse_leaf_on_non_matrix_spec() {
        // A 1-D [6] spec has no matrix view; the old loader accepted a
        // 2×3 CSR leaf for it because 2×3 == numel — routing a spec the
        // engine would later view as (0,0) into CSR construction.
        let mut body = vec![1u8];
        body.extend_from_slice(&2u64.to_le_bytes()); // rows
        body.extend_from_slice(&3u64.to_le_bytes()); // cols
        body.extend_from_slice(&2u64.to_le_bytes()); // nnz
        for p in [0u32, 1, 2] {
            body.extend_from_slice(&p.to_le_bytes());
        }
        for i in [0u32, 2] {
            body.extend_from_slice(&i.to_le_bytes());
        }
        for v in [1.0f32, 2.0] {
            body.extend_from_slice(&v.to_le_bytes());
        }
        let bytes = crafted("[6]", &body);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("no 2-D matrix view"), "{err}");
    }

    #[test]
    fn rejects_trailing_garbage() {
        let b = test_bundle(true);
        let path = tmp("trailing.pxcp");
        save(&path, &b, &Json::obj()).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes.extend_from_slice(&[0u8; 4]);
        let err = decode(&bytes).unwrap_err().to_string();
        assert!(err.contains("trailing bytes"), "{err}");
    }

    #[test]
    fn decode_matches_load() {
        let b = test_bundle(true);
        let path = tmp("decode_twin.pxcp");
        save(&path, &b, &Json::obj()).unwrap();
        let via_load = load(&path).unwrap();
        let via_decode = decode(&std::fs::read(&path).unwrap()).unwrap();
        assert_eq!(via_load.params.values, via_decode.params.values);
        assert_eq!(via_load.payload_bytes, via_decode.payload_bytes);
    }
}
