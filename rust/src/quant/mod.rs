//! Codebook quantization subsystem — the Deep Compression stage on top
//! of the SpC→debias→compress pipeline.
//!
//! The paper's compressed CSR matrices stop at f32 values with u32
//! indices; Deep Compression (Han et al. 2016a) shows *trained*
//! codebook quantization on top of pruned sparse weights buys a further
//! 3–4× model-size reduction, and EIE (Han et al. 2016b) shows the
//! 4-bit-code + codebook representation is also what makes compressed
//! inference bandwidth-efficient. This module supplies the whole stage:
//!
//! * [`codebook`] — deterministic k-means codebooks per leaf with a
//!   reported quantization error.
//! * [`qcs`] — [`QcsMatrix`], quantized CSR (packed codes + narrowed
//!   indices) with bit-deterministic `dxct`/`spmv` serving kernels,
//!   registered in `sparse::dispatch` as [`SparseFormat::Qcs`] and in
//!   the engine as `WeightMode::Quantized`.
//! * [`quantize_bundle`] — bundle-level policy: prunable matrix leaves
//!   with enough nonzeros go quantized, biases and small leaves stay
//!   f32 (Deep Compression quantizes weights only).
//! * [`finetune_codebooks`] — the "trained quantization" step on the
//!   native backend: per-code gradient accumulation updates centroids
//!   while codes stay fixed.
//!
//! `checkpoint` (format v2) persists quantized leaves, `proxcomp
//! quantize` drives the stage from the CLI, and `proxcomp pipeline
//! --quantize` gates on quantized accuracy + strict size improvement.

pub mod codebook;
pub mod qcs;

pub use codebook::{kmeans_codebook, QuantConfig, QuantStats};
pub use qcs::QcsMatrix;

use crate::data::{Batcher, Dataset};
use crate::runtime::{native, ParamBundle, ParamSpec};
use crate::sparse::CsrMatrix;
use crate::util::pool;

/// One leaf of a quantized model: quantized-CSR for the big prunable
/// matrices, plain f32 for everything else (biases, BN, small leaves —
/// the checkpoint still stores sparse f32 leaves CSR).
#[derive(Debug, Clone)]
pub enum QuantLeaf {
    Dense(Vec<f32>),
    Qcs(QcsMatrix),
}

/// A model with codebook-quantized prunable leaves — what checkpoint v2
/// persists and `Engine::builder(..).quantized(..)` serves.
#[derive(Debug, Clone)]
pub struct QuantizedModel {
    pub specs: Vec<ParamSpec>,
    pub leaves: Vec<QuantLeaf>,
}

/// Per-leaf quantization report: the size ladder (dense → CSR →
/// quantized) plus the codebook error, printed by the CLI and checked
/// by the pipeline gate.
#[derive(Debug, Clone)]
pub struct LeafReport {
    pub name: String,
    pub quantized: bool,
    pub nnz: usize,
    pub total: usize,
    pub dense_bytes: usize,
    pub csr_bytes: usize,
    /// Stored bytes of this leaf in the quantized model (equals
    /// `csr_bytes`-or-`dense_bytes` when the leaf stayed f32).
    pub stored_bytes: usize,
    pub codebook_len: usize,
    pub stats: QuantStats,
}

impl QuantizedModel {
    /// Dequantize back to a dense [`ParamBundle`] (every quantized value
    /// becomes its centroid) — the fine-tune pass and the engine's
    /// fallback leaves go through this.
    pub fn to_bundle(&self) -> ParamBundle {
        let values = self
            .leaves
            .iter()
            .map(|l| match l {
                QuantLeaf::Dense(v) => v.clone(),
                QuantLeaf::Qcs(q) => q.to_dense(),
            })
            .collect();
        ParamBundle { specs: self.specs.clone(), values }
    }

    /// The quantized leaves by spec name (the engine's store override).
    pub fn qcs_by_name(&self) -> std::collections::HashMap<String, QcsMatrix> {
        self.specs
            .iter()
            .zip(&self.leaves)
            .filter_map(|(s, l)| match l {
                QuantLeaf::Qcs(q) => Some((s.name.clone(), q.clone())),
                QuantLeaf::Dense(_) => None,
            })
            .collect()
    }
}

/// Quantize a trained bundle per the Deep Compression policy: each
/// prunable 2-D-viewable leaf with at least `cfg.min_quant_nnz`
/// nonzeros gets a per-leaf k-means codebook and a [`QcsMatrix`];
/// biases, BN parameters, and small leaves stay f32. Returns the model
/// and per-leaf reports (stored bytes account CSR fallback for sparse
/// f32 leaves, mirroring what checkpoint v2 actually writes).
pub fn quantize_bundle(bundle: &ParamBundle, cfg: &QuantConfig) -> (QuantizedModel, Vec<LeafReport>) {
    let mut leaves = Vec::with_capacity(bundle.specs.len());
    let mut reports = Vec::with_capacity(bundle.specs.len());
    for (spec, values) in bundle.specs.iter().zip(&bundle.values) {
        let (rows, cols) = crate::checkpoint::matrix_view(spec).unwrap_or((0, 0));
        let nnz = values.iter().filter(|&&v| v != 0.0).count();
        let dense_bytes = values.len() * 4;
        let viewable = spec.prunable && rows > 0;
        let csr_bytes = if viewable {
            CsrMatrix::from_dense(values, rows, cols).storage_bytes()
        } else {
            dense_bytes
        };
        if viewable && nnz >= cfg.min_quant_nnz {
            let (q, stats) = QcsMatrix::from_csr(&CsrMatrix::from_dense(values, rows, cols), cfg);
            reports.push(LeafReport {
                name: spec.name.clone(),
                quantized: true,
                nnz,
                total: values.len(),
                dense_bytes,
                csr_bytes,
                stored_bytes: q.storage_bytes(),
                codebook_len: q.codebook().len(),
                stats,
            });
            leaves.push(QuantLeaf::Qcs(q));
        } else {
            // Stays f32; checkpoint v2 still stores it CSR when sparse
            // enough (the same threshold `checkpoint::save` applies).
            let stored = if viewable && sparse_enough(nnz, values.len()) {
                csr_bytes
            } else {
                dense_bytes
            };
            reports.push(LeafReport {
                name: spec.name.clone(),
                quantized: false,
                nnz,
                total: values.len(),
                dense_bytes,
                csr_bytes,
                stored_bytes: stored,
                codebook_len: 0,
                stats: QuantStats::default(),
            });
            leaves.push(QuantLeaf::Dense(values.clone()));
        }
    }
    (QuantizedModel { specs: bundle.specs.clone(), leaves }, reports)
}

fn sparse_enough(nnz: usize, total: usize) -> bool {
    let zero_frac = 1.0 - nnz as f64 / total.max(1) as f64;
    zero_frac >= crate::checkpoint::CSR_THRESHOLD
}

/// Outcome of the codebook fine-tune pass.
#[derive(Debug, Clone, Copy)]
pub struct FinetuneReport {
    pub steps: usize,
    pub loss_first: f32,
    pub loss_last: f32,
}

/// Trained quantization (Deep Compression Figure 3): run minibatches
/// through the native backend at the *dequantized* weights, accumulate
/// each leaf's gradient per code (ascending CSR-entry order — bit-
/// deterministic), and descend the centroids. Codes and the sparsity
/// pattern never change, so the model stays exactly representable by
/// its codebooks. Only the native model families (mlp/lenet stage
/// graphs) can be fine-tuned — callers gate on the model name.
pub fn finetune_codebooks(
    qm: &mut QuantizedModel,
    data: &Dataset,
    steps: usize,
    batch: usize,
    lr: f32,
    seed: u64,
) -> anyhow::Result<FinetuneReport> {
    anyhow::ensure!(batch > 0 && batch <= data.n, "bad fine-tune batch {batch} (n = {})", data.n);
    let threads = pool::max_threads();
    let mut batcher = Batcher::new(data.n, seed ^ 0x71F1_4E70);
    let mut x_shape = vec![batch];
    x_shape.extend_from_slice(&[data.c, data.h, data.w]);
    let (mut loss_first, mut loss_last) = (0.0f32, 0.0f32);
    for step in 0..steps {
        let (xs, ys) = batcher.next_batch(data, batch);
        let bundle = qm.to_bundle();
        let (loss, grads) = native::loss_and_param_grads(&bundle, &x_shape, &xs, &ys, threads)?;
        if step == 0 {
            loss_first = loss;
        }
        loss_last = loss;
        for (leaf, grad) in qm.leaves.iter_mut().zip(&grads) {
            if let QuantLeaf::Qcs(q) = leaf {
                let cols = q.cols;
                let mut gsum = vec![0.0f32; q.codebook().len()];
                q.for_each_entry(|r, c, code| {
                    gsum[code] += grad[r * cols + c];
                });
                let cb: Vec<f32> =
                    q.codebook().iter().zip(&gsum).map(|(c, g)| c - lr * g).collect();
                q.set_codebook(cb);
            }
        }
    }
    Ok(FinetuneReport { steps, loss_first, loss_last })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::prox;
    use crate::util::rng::Rng;

    fn sparse_bundle(seed: u64) -> ParamBundle {
        let p = |name: &str, kind: &str, shape: Vec<usize>, prunable: bool| {
            ParamSpec::new(name, kind, shape, prunable)
        };
        let specs = vec![
            p("fc1_w", "fc_w", vec![32, 64], true),
            p("fc1_b", "fc_b", vec![32], false),
            p("fc2_w", "fc_w", vec![4, 32], true), // small: stays f32
            p("fc2_b", "fc_b", vec![4], false),
        ];
        let mut bundle = ParamBundle::he_init(&specs, seed);
        let mut rng = Rng::new(seed);
        bundle.values[1] = rng.normal_vec(32, 0.1);
        for (spec, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
            if spec.prunable {
                let t = prox::magnitude_quantile(v, 0.8);
                prox::hard_threshold_inplace(v, t);
            }
        }
        bundle
    }

    #[test]
    fn policy_quantizes_big_prunable_leaves_only() {
        let bundle = sparse_bundle(1);
        let cfg = QuantConfig::default();
        let (qm, reports) = quantize_bundle(&bundle, &cfg);
        assert!(matches!(qm.leaves[0], QuantLeaf::Qcs(_)), "fc1_w should quantize");
        assert!(matches!(qm.leaves[1], QuantLeaf::Dense(_)), "bias must stay f32");
        // fc2_w has 4·32·0.2 ≈ 26 nonzeros < min_quant_nnz → stays f32.
        assert!(matches!(qm.leaves[2], QuantLeaf::Dense(_)), "small leaf must stay f32");
        assert!(reports[0].quantized && !reports[1].quantized && !reports[2].quantized);
        assert!(reports[0].stored_bytes < reports[0].csr_bytes);
        assert!(reports[0].csr_bytes < reports[0].dense_bytes);
    }

    #[test]
    fn dequantized_bundle_matches_reported_error() {
        let bundle = sparse_bundle(2);
        let (qm, reports) = quantize_bundle(&bundle, &QuantConfig::default());
        let back = qm.to_bundle();
        assert_eq!(back.specs.len(), bundle.specs.len());
        for ((rep, orig), deq) in reports.iter().zip(&bundle.values).zip(&back.values) {
            if !rep.quantized {
                assert_eq!(orig, deq, "{}: f32 leaves must round-trip exactly", rep.name);
                continue;
            }
            let max_err = orig
                .iter()
                .zip(deq)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(
                max_err <= rep.stats.max_abs_err + 1e-7,
                "{}: actual {} > reported {}",
                rep.name,
                max_err,
                rep.stats.max_abs_err
            );
            // Sparsity pattern preserved exactly.
            for (a, b) in orig.iter().zip(deq) {
                assert_eq!(*a == 0.0, *b == 0.0);
            }
        }
    }

    #[test]
    fn qcs_by_name_maps_quantized_leaves() {
        let bundle = sparse_bundle(3);
        let (qm, _) = quantize_bundle(&bundle, &QuantConfig::default());
        let map = qm.qcs_by_name();
        assert!(map.contains_key("fc1_w"));
        assert!(!map.contains_key("fc1_b"));
        assert!(!map.contains_key("fc2_w"));
    }
}
