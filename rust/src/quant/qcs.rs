//! Quantized-CSR (`QcsMatrix`) — the EIE-style deployment format.
//!
//! CSR with the f32 values replaced by codes into a per-matrix codebook
//! (EIE, Han et al. 2016b stores exactly this: 4-bit codes + a shared
//! 16-entry table), and the u32 column indices narrowed to u16 whenever
//! the column count fits. At the paper's 90–97 % sparsity this is what
//! makes compressed inference bandwidth-efficient: a nonzero costs
//! 2.5 bytes (u16 index + packed 4-bit code) instead of CSR's 8.
//!
//! The `dxct`/`spmv` kernels mirror `sparse::ops`, including the
//! blocked-reduction contract: under the default `PROXCOMP_KERNEL=blocked`
//! family, nonzero `q` of a row accumulates into lane `q % pool::LANES`
//! and lanes collapse through `pool::tree_reduce` — the *same* lane
//! semantics as the CSR kernels, so a QCS matrix multiplies bit-identically
//! to its dequantized CSR under either kernel family. Rows partition by
//! nnz (`pool::parallel_prefix_chunks`); partitioning and thread count
//! never change bits (the serving guarantee the property tests pin).

use super::codebook::{kmeans_codebook, QuantConfig, QuantStats};
use crate::sparse::dispatch::{SparseFormat, SparseKernel};
use crate::sparse::CsrMatrix;
use crate::tensor::Tensor;
use crate::util::pool::{self, KernelMode, LANES};

/// Column indices, narrowed to u16 when `cols` fits.
#[derive(Debug, Clone, PartialEq)]
enum QcsIndices {
    U16(Vec<u16>),
    U32(Vec<u32>),
}

/// Codebook codes: 4-bit packed (two per byte, low nibble first) when
/// the codebook has ≤ 16 entries, one byte per code otherwise.
#[derive(Debug, Clone, PartialEq)]
enum QcsCodes {
    U4(Vec<u8>),
    U8(Vec<u8>),
}

/// A sparse matrix stored as quantized CSR.
#[derive(Debug, Clone, PartialEq)]
pub struct QcsMatrix {
    pub rows: usize,
    pub cols: usize,
    /// Row pointers, len == rows + 1 (CSR layout).
    pub ptr: Vec<usize>,
    /// Centroids the codes index. Ascending-sorted at construction
    /// (k-means emits a sorted codebook); a fine-tune pass moves
    /// centroids independently, so ordering may drift afterwards —
    /// nothing here relies on it (codes are fixed assignments, not
    /// nearest-neighbour lookups).
    codebook: Vec<f32>,
    indices: QcsIndices,
    codes: QcsCodes,
    nnz: usize,
}

impl QcsMatrix {
    /// Quantize a CSR matrix: k-means codebook over its nonzeros, codes
    /// + narrowed indices. Returns the matrix and its quantization error.
    pub fn from_csr(csr: &CsrMatrix, cfg: &QuantConfig) -> (QcsMatrix, QuantStats) {
        let (codebook, codes, stats) =
            kmeans_codebook(&csr.data, cfg.codebook_size, cfg.max_iters, cfg.seed);
        let m = Self::pack(csr.rows, csr.cols, csr.ptr.clone(), codebook, csr.indices.clone(), codes);
        (m, stats)
    }

    /// Quantize a dense row-major matrix (zeros dropped first).
    pub fn from_dense(dense: &[f32], rows: usize, cols: usize, cfg: &QuantConfig) -> QcsMatrix {
        Self::from_csr(&CsrMatrix::from_dense(dense, rows, cols), cfg).0
    }

    /// Assemble from raw parts (the checkpoint loader's entrypoint),
    /// validating structural invariants. `indices` are u32 (narrowed
    /// internally when `cols` fits), `codes` one u8 per nonzero.
    pub fn from_parts(
        rows: usize,
        cols: usize,
        ptr: Vec<usize>,
        codebook: Vec<f32>,
        indices: Vec<u32>,
        codes: Vec<u8>,
    ) -> anyhow::Result<QcsMatrix> {
        anyhow::ensure!(!codebook.is_empty() || indices.is_empty(), "nonzeros but empty codebook");
        anyhow::ensure!(codebook.len() <= 256, "codebook too large: {}", codebook.len());
        anyhow::ensure!(codes.len() == indices.len(), "codes/indices length mismatch");
        for &c in &codes {
            anyhow::ensure!((c as usize) < codebook.len().max(1), "code {c} out of codebook range");
        }
        // Bound-check before the u16 narrowing so corrupt wide indices
        // cannot alias into range.
        for &i in &indices {
            anyhow::ensure!((i as usize) < cols, "column index {i} out of bounds for {cols} cols");
        }
        let m = Self::pack(rows, cols, ptr, codebook, indices, codes);
        m.validate()?;
        Ok(m)
    }

    fn pack(
        rows: usize,
        cols: usize,
        ptr: Vec<usize>,
        codebook: Vec<f32>,
        indices: Vec<u32>,
        codes: Vec<u8>,
    ) -> QcsMatrix {
        let nnz = indices.len();
        let indices = if cols <= u16::MAX as usize + 1 {
            QcsIndices::U16(indices.into_iter().map(|i| i as u16).collect())
        } else {
            QcsIndices::U32(indices)
        };
        let codes = if codebook.len() <= 16 {
            let mut packed = vec![0u8; nnz.div_ceil(2)];
            for (k, &c) in codes.iter().enumerate() {
                packed[k / 2] |= (c & 0xF) << ((k % 2) * 4);
            }
            QcsCodes::U4(packed)
        } else {
            QcsCodes::U8(codes)
        };
        QcsMatrix { rows, cols, ptr, codebook, indices, codes, nnz }
    }

    pub fn nnz(&self) -> usize {
        self.nnz
    }

    pub fn codebook(&self) -> &[f32] {
        &self.codebook
    }

    /// Replace the codebook (the fine-tune pass updates centroids in
    /// place; codes are untouched). Length must match. The replacement
    /// need not be sorted — see the `codebook` field doc.
    pub fn set_codebook(&mut self, codebook: Vec<f32>) {
        assert_eq!(codebook.len(), self.codebook.len(), "codebook length changed");
        self.codebook = codebook;
    }

    /// Bits per stored code (4 when the codebook fits 16 entries).
    pub fn code_bits(&self) -> usize {
        match self.codes {
            QcsCodes::U4(_) => 4,
            QcsCodes::U8(_) => 8,
        }
    }

    /// Bytes per stored column index (2 when `cols` fits u16).
    pub fn index_bytes(&self) -> usize {
        match self.indices {
            QcsIndices::U16(_) => 2,
            QcsIndices::U32(_) => 4,
        }
    }

    /// Column index of the k-th nonzero.
    #[inline]
    pub fn index_at(&self, k: usize) -> usize {
        match &self.indices {
            QcsIndices::U16(v) => v[k] as usize,
            QcsIndices::U32(v) => v[k] as usize,
        }
    }

    /// The stored code bytes exactly as serialized: nibble-packed
    /// (⌈nnz/2⌉ bytes, low nibble first) under 4-bit codes, one byte
    /// per code otherwise — the checkpoint writer streams this buffer
    /// verbatim so the pack format lives in one place.
    pub fn code_bytes(&self) -> &[u8] {
        match &self.codes {
            QcsCodes::U4(v) | QcsCodes::U8(v) => v,
        }
    }

    /// Code of the k-th nonzero.
    #[inline]
    pub fn code_at(&self, k: usize) -> usize {
        match &self.codes {
            QcsCodes::U4(v) => ((v[k / 2] >> ((k % 2) * 4)) & 0xF) as usize,
            QcsCodes::U8(v) => v[k] as usize,
        }
    }

    /// Dequantized value of the k-th nonzero.
    #[inline]
    pub fn value_at(&self, k: usize) -> f32 {
        self.codebook[self.code_at(k)]
    }

    /// Visit every stored entry as `(row, col, code)` in CSR order —
    /// the codebook fine-tune pass accumulates per-code gradients here.
    pub fn for_each_entry(&self, mut f: impl FnMut(usize, usize, usize)) {
        for r in 0..self.rows {
            for k in self.ptr[r]..self.ptr[r + 1] {
                f(r, self.index_at(k), self.code_at(k));
            }
        }
    }

    /// Expand to dense row-major (every value is a codebook centroid).
    pub fn to_dense(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.rows * self.cols];
        for r in 0..self.rows {
            for k in self.ptr[r]..self.ptr[r + 1] {
                out[r * self.cols + self.index_at(k)] = self.value_at(k);
            }
        }
        out
    }

    /// Widen back to plain CSR (dequantized values).
    pub fn to_csr(&self) -> CsrMatrix {
        let mut indices = Vec::with_capacity(self.nnz);
        let mut data = Vec::with_capacity(self.nnz);
        for k in 0..self.nnz {
            indices.push(self.index_at(k) as u32);
            data.push(self.value_at(k));
        }
        CsrMatrix { rows: self.rows, cols: self.cols, ptr: self.ptr.clone(), indices, data }
    }

    /// Storage footprint in bytes, matching the checkpoint-v2 payload
    /// layout: packed codes + narrowed indices + u32 row pointers + the
    /// f32 codebook — the quantized Table-3 "Model Size" quantity.
    pub fn storage_bytes(&self) -> usize {
        let codes = match &self.codes {
            QcsCodes::U4(v) => v.len(),
            QcsCodes::U8(v) => v.len(),
        };
        codes + self.nnz * self.index_bytes() + self.ptr.len() * 4 + self.codebook.len() * 4
    }

    /// Structural invariants (checkpoint loading runs this).
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.ptr.len() == self.rows + 1,
            "ptr len {} != rows+1 {}",
            self.ptr.len(),
            self.rows + 1
        );
        anyhow::ensure!(
            self.ptr[0] == 0 && *self.ptr.last().unwrap() == self.nnz,
            "ptr/nnz inconsistency: ptr spans {}..{} but nnz is {}",
            self.ptr[0],
            self.ptr.last().unwrap(),
            self.nnz
        );
        for w in self.ptr.windows(2) {
            anyhow::ensure!(w[1] >= w[0], "ptr not monotone");
        }
        for r in 0..self.rows {
            let mut prev: Option<usize> = None;
            for k in self.ptr[r]..self.ptr[r + 1] {
                let c = self.index_at(k);
                anyhow::ensure!(c < self.cols, "row {r} column {c} out of bounds");
                if let Some(p) = prev {
                    anyhow::ensure!(c > p, "row {r} columns not strictly increasing");
                }
                prev = Some(c);
            }
        }
        Ok(())
    }

    /// Gathered blocked dot of stored row range `lo..hi` against a dense
    /// vector: the `q`-th nonzero of the row lands in lane `q % LANES`,
    /// lanes collapse through the fixed tree — exactly the semantics of
    /// `sparse::ops::blocked_row_dot`, so QCS results stay bit-identical
    /// to the dequantized-CSR kernel in blocked mode. Eight independent
    /// accumulators also break the FMA latency chain around the codebook
    /// lookup, which is the perf point of the rewrite.
    #[inline]
    fn blocked_row_dot(&self, dvec: &[f32], lo: usize, hi: usize) -> f32 {
        let mut acc = [0.0f32; LANES];
        for (q, idx) in (lo..hi).enumerate() {
            acc[q % LANES] += self.value_at(idx) * dvec[self.index_at(idx)];
        }
        pool::tree_reduce(acc)
    }

    /// Sequential (pre-blocking) row dot — the `PROXCOMP_KERNEL=scalar`
    /// family and the bench "before" reference.
    #[inline]
    fn scalar_row_dot(&self, dvec: &[f32], lo: usize, hi: usize) -> f32 {
        let mut acc = 0.0f32;
        for idx in lo..hi {
            acc += self.value_at(idx) * dvec[self.index_at(idx)];
        }
        acc
    }

    /// Forward contraction `dmat (B, K) @ self' -> (B, N)` — the paper's
    /// Figure-2 kernel with the value load replaced by a codebook lookup.
    pub fn dxct(&self, dmat: &Tensor) -> Tensor {
        self.dxct_threads(dmat, pool::max_threads())
    }

    /// As [`QcsMatrix::dxct`] with an explicit worker count. Dispatches
    /// on [`pool::kernel_mode`] like the CSR kernels. Both partitions
    /// (batch rows when the batch saturates the lanes, output columns —
    /// split by nnz in blocked mode — otherwise) compute every output
    /// element with the family's fixed per-element reduction order, so
    /// results are bit-identical for any `threads`.
    pub fn dxct_threads(&self, dmat: &Tensor, threads: usize) -> Tensor {
        let (b, k) = (dmat.shape[0], dmat.shape[1]);
        assert_eq!(k, self.cols, "qcs dxct: K mismatch ({k} vs {})", self.cols);
        let n = self.rows;
        let blocked = pool::kernel_mode() == KernelMode::Blocked;
        let mut out = vec![0.0f32; b * n];
        let out_ptr = pool::SharedMut::new(&mut out);
        let cell = |drow: &[f32], col: usize| -> f32 {
            let (lo, hi) = (self.ptr[col], self.ptr[col + 1]);
            if blocked {
                self.blocked_row_dot(drow, lo, hi)
            } else {
                self.scalar_row_dot(drow, lo, hi)
            }
        };
        if pool::batch_saturates(b, threads) {
            pool::parallel_chunks(b, threads, |r0, r1| {
                let out = unsafe { out_ptr.slice() };
                for row in r0..r1 {
                    let drow = &dmat.data[row * k..(row + 1) * k];
                    let orow = &mut out[row * n..(row + 1) * n];
                    for (col, o) in orow.iter_mut().enumerate() {
                        *o = cell(drow, col);
                    }
                }
            });
        } else {
            // Serving batches: columns map to stored rows, so blocked
            // mode splits them by nnz (skewed-row load balance).
            let run = |c0: usize, c1: usize| {
                let out = unsafe { out_ptr.slice() };
                for row in 0..b {
                    let drow = &dmat.data[row * k..(row + 1) * k];
                    for col in c0..c1 {
                        out[row * n + col] = cell(drow, col);
                    }
                }
            };
            if blocked {
                pool::parallel_prefix_chunks(n, threads, &self.ptr, run);
            } else {
                pool::parallel_chunks(n, threads, run);
            }
        }
        Tensor::new(vec![b, n], out)
    }

    /// Sparse matrix-vector product `self (N, K) @ x (K) -> (N)` — the
    /// B = 1 serving kernel (EIE's operating point).
    pub fn spmv(&self, x: &[f32]) -> Vec<f32> {
        self.spmv_threads(x, pool::max_threads())
    }

    /// As [`QcsMatrix::spmv`] with an explicit worker count. Output rows
    /// are independent and each keeps its family's fixed reduction
    /// order — bit-identical for any `threads`, and bit-identical to
    /// [`QcsMatrix::dxct`] of the same vector as a (1, K) batch.
    pub fn spmv_threads(&self, x: &[f32], threads: usize) -> Vec<f32> {
        assert_eq!(x.len(), self.cols);
        let blocked = pool::kernel_mode() == KernelMode::Blocked;
        let mut out = vec![0.0f32; self.rows];
        let out_ptr = pool::SharedMut::new(&mut out);
        let run = |r0: usize, r1: usize| {
            let out = unsafe { out_ptr.slice() };
            for r in r0..r1 {
                let (lo, hi) = (self.ptr[r], self.ptr[r + 1]);
                out[r] = if blocked {
                    self.blocked_row_dot(x, lo, hi)
                } else {
                    self.scalar_row_dot(x, lo, hi)
                };
            }
        };
        if blocked {
            pool::parallel_prefix_chunks(self.rows, threads, &self.ptr, run);
        } else {
            pool::parallel_chunks(self.rows, threads, run);
        }
        out
    }
}

impl SparseKernel for QcsMatrix {
    fn rows(&self) -> usize {
        self.rows
    }
    fn cols(&self) -> usize {
        self.cols
    }
    fn nnz(&self) -> usize {
        QcsMatrix::nnz(self)
    }
    fn storage_bytes(&self) -> usize {
        QcsMatrix::storage_bytes(self)
    }
    fn to_dense(&self) -> Vec<f32> {
        QcsMatrix::to_dense(self)
    }
    fn dxct(&self, dmat: &Tensor) -> Tensor {
        QcsMatrix::dxct(self, dmat)
    }
    fn dxct_threads(&self, dmat: &Tensor, threads: usize) -> Tensor {
        QcsMatrix::dxct_threads(self, dmat, threads)
    }
    fn format(&self) -> SparseFormat {
        SparseFormat::Qcs
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::prox;
    use crate::util::rng::Rng;

    fn sparse_dense(rng: &mut Rng, rows: usize, cols: usize, rate: f64) -> Vec<f32> {
        let mut dense = rng.normal_vec(rows * cols, 0.1);
        let t = prox::magnitude_quantile(&dense, rate);
        prox::hard_threshold_inplace(&mut dense, t);
        dense
    }

    #[test]
    fn preserves_sparsity_pattern_and_codebook_values() {
        let mut rng = Rng::new(21);
        let dense = sparse_dense(&mut rng, 40, 60, 0.9);
        let cfg = QuantConfig::default();
        let q = QcsMatrix::from_dense(&dense, 40, 60, &cfg);
        let back = q.to_dense();
        assert_eq!(back.len(), dense.len());
        for (b, d) in back.iter().zip(&dense) {
            // Exact zeros stay exact zeros; nonzeros become centroids.
            assert_eq!(*b == 0.0, *d == 0.0);
            if *b != 0.0 {
                assert!(q.codebook().contains(b));
            }
        }
        assert_eq!(q.nnz(), dense.iter().filter(|&&v| v != 0.0).count());
        q.validate().unwrap();
    }

    #[test]
    fn narrow_packing_chosen_when_it_fits() {
        let mut rng = Rng::new(22);
        let dense = sparse_dense(&mut rng, 20, 50, 0.8);
        let q16 = QcsMatrix::from_dense(&dense, 20, 50, &QuantConfig::default());
        assert_eq!(q16.code_bits(), 4);
        assert_eq!(q16.index_bytes(), 2);
        let q256 = QcsMatrix::from_dense(
            &dense,
            20,
            50,
            &QuantConfig { codebook_size: 64, ..QuantConfig::default() },
        );
        assert_eq!(q256.code_bits(), 8);
    }

    #[test]
    fn smaller_than_csr_at_paper_sparsity() {
        let mut rng = Rng::new(23);
        let dense = sparse_dense(&mut rng, 200, 300, 0.95);
        let csr = CsrMatrix::from_dense(&dense, 200, 300);
        let q = QcsMatrix::from_csr(&csr, &QuantConfig::default()).0;
        assert!(
            q.storage_bytes() * 2 < csr.storage_bytes(),
            "qcs {} vs csr {}",
            q.storage_bytes(),
            csr.storage_bytes()
        );
    }

    #[test]
    fn dxct_matches_dequantized_csr() {
        let mut rng = Rng::new(24);
        for &(b, n, k) in &[(1usize, 7usize, 9usize), (3, 20, 30), (16, 50, 80)] {
            let dense = sparse_dense(&mut rng, n, k, 0.8);
            let q = QcsMatrix::from_dense(&dense, n, k, &QuantConfig::default());
            let csr = q.to_csr();
            let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
            let got = q.dxct(&d);
            let want = crate::sparse::ops::dxct_scalar(&d, &csr);
            assert_eq!(got.shape, want.shape);
            for (g, w) in got.data.iter().zip(&want.data) {
                assert!((g - w).abs() < 1e-4, "{g} vs {w}");
            }
        }
    }

    #[test]
    fn spmv_matches_dxct_row() {
        let mut rng = Rng::new(25);
        let dense = sparse_dense(&mut rng, 30, 40, 0.85);
        let q = QcsMatrix::from_dense(&dense, 30, 40, &QuantConfig::default());
        let x: Vec<f32> = rng.normal_vec(40, 1.0);
        let got = q.spmv(&x);
        let via_dxct = q.dxct(&Tensor::new(vec![1, 40], x));
        assert_eq!(got, via_dxct.data);
    }

    #[test]
    fn from_parts_rejects_corruption() {
        let mut rng = Rng::new(26);
        let dense = sparse_dense(&mut rng, 8, 10, 0.7);
        let csr = CsrMatrix::from_dense(&dense, 8, 10);
        let cb = vec![-0.1f32, 0.1];
        let codes = vec![0u8; csr.nnz()];
        let idx: Vec<u32> = csr.indices.clone();
        // Valid baseline.
        QcsMatrix::from_parts(8, 10, csr.ptr.clone(), cb.clone(), idx.clone(), codes.clone())
            .unwrap();
        // ptr/nnz inconsistency.
        let mut bad_ptr = csr.ptr.clone();
        *bad_ptr.last_mut().unwrap() += 1;
        assert!(QcsMatrix::from_parts(8, 10, bad_ptr, cb.clone(), idx.clone(), codes.clone())
            .is_err());
        // Out-of-range code.
        let mut bad_codes = codes.clone();
        bad_codes[0] = 7;
        assert!(QcsMatrix::from_parts(8, 10, csr.ptr.clone(), cb.clone(), idx.clone(), bad_codes)
            .is_err());
        // Out-of-bounds column.
        let mut bad_idx = idx;
        bad_idx[0] = 99;
        assert!(QcsMatrix::from_parts(8, 10, csr.ptr.clone(), cb, bad_idx, codes).is_err());
    }

    #[test]
    fn empty_matrix_works() {
        let q = QcsMatrix::from_dense(&vec![0.0; 12], 3, 4, &QuantConfig::default());
        assert_eq!(q.nnz(), 0);
        assert_eq!(q.to_dense(), vec![0.0; 12]);
        q.validate().unwrap();
        let y = q.dxct(&Tensor::new(vec![2, 4], vec![1.0; 8]));
        assert_eq!(y.data, vec![0.0; 6]);
    }
}
