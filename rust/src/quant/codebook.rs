//! Trained codebook quantization of weight values (Deep Compression,
//! Han et al. 2016a, Section 3).
//!
//! The nonzeros of a prox-trained sparse weight matrix are clustered
//! with 1-D k-means; each nonzero is then stored as a small *code* into
//! the shared per-leaf codebook of centroids. With the paper's 90–97 %
//! sparsity this stacks a further ~3–4× on top of CSR: a 4-bit code +
//! u16 column index replaces a 4-byte f32 + 4-byte u32 pair.
//!
//! Everything here is bit-deterministic: the k-means++ seeding draws
//! from [`crate::util::rng::Rng`] with a caller-provided seed, Lloyd
//! assignment ties break toward the lower centroid index, and the
//! centroid means accumulate in ascending value order in f64.

use crate::util::rng::Rng;

/// Knobs for leaf quantization, shared by the CLI, the engine's
/// `WeightMode::Quantized`, and `quantize_bundle`.
#[derive(Debug, Clone, Copy)]
pub struct QuantConfig {
    /// Codebook entries per leaf (≤ 16 packs 4-bit codes, ≤ 256 8-bit).
    pub codebook_size: usize,
    /// Lloyd iteration cap (convergence usually lands well before it).
    pub max_iters: usize,
    /// Seed for the deterministic k-means++ initialization.
    pub seed: u64,
    /// Leaves with fewer nonzeros than this stay f32 (the codebook
    /// overhead and accuracy risk cannot pay on tiny filter banks).
    pub min_quant_nnz: usize,
}

impl Default for QuantConfig {
    fn default() -> QuantConfig {
        QuantConfig { codebook_size: 16, max_iters: 25, seed: 0xC0DE_B00C, min_quant_nnz: 64 }
    }
}

/// Reported quantization error of one leaf — the quantity the
/// dequantize-roundtrip invariant tests check against.
#[derive(Debug, Clone, Copy, Default)]
pub struct QuantStats {
    /// Root-mean-square |w − centroid(code(w))| over the quantized values.
    pub rmse: f64,
    /// Worst-case absolute error.
    pub max_abs_err: f32,
}

/// Cluster `values` into at most `k` centroids (ascending order) and
/// assign each value its nearest centroid's code. Returns
/// `(centroids, codes, stats)`; `codes[i]` indexes `centroids`.
///
/// When the values hold ≤ `k` distinct numbers the centroids are exactly
/// those numbers (zero error — the 1-cluster / near-constant leaves
/// degrade to lossless). `k` is clamped to 256 (codes are stored u8).
pub fn kmeans_codebook(values: &[f32], k: usize, max_iters: usize, seed: u64) -> (Vec<f32>, Vec<u8>, QuantStats) {
    assert!(k >= 1, "codebook needs at least one entry");
    let k = k.min(256);
    if values.is_empty() {
        return (Vec::new(), Vec::new(), QuantStats::default());
    }

    // Distinct-value shortcut: exact representation, error 0.
    let mut distinct: Vec<f32> = values.to_vec();
    distinct.sort_by(f32::total_cmp);
    distinct.dedup();
    let mut centroids = if distinct.len() <= k {
        distinct
    } else {
        let mut c = kmeanspp_init(values, k, seed);
        lloyd(values, &mut c, max_iters);
        c
    };
    centroids.sort_by(f32::total_cmp);
    centroids.dedup();

    let codes: Vec<u8> = values.iter().map(|&v| nearest(&centroids, v) as u8).collect();
    let mut sq = 0.0f64;
    let mut max_abs = 0.0f32;
    for (&v, &c) in values.iter().zip(&codes) {
        let e = (v - centroids[c as usize]).abs();
        sq += (e as f64) * (e as f64);
        max_abs = max_abs.max(e);
    }
    let stats = QuantStats { rmse: (sq / values.len() as f64).sqrt(), max_abs_err: max_abs };
    (centroids, codes, stats)
}

/// k-means++ seeding (Arthur & Vassilvitskii 2007), deterministic via
/// the crate Rng: each next centroid is drawn with probability
/// proportional to its squared distance to the nearest chosen one.
fn kmeanspp_init(values: &[f32], k: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x6B6D_6561_6E73); // "kmeans" salt
    let n = values.len();
    let mut centroids = Vec::with_capacity(k);
    centroids.push(values[rng.below(n)]);
    let mut d2: Vec<f64> = values.iter().map(|&v| sqdist(v, centroids[0])).collect();
    while centroids.len() < k {
        let total: f64 = d2.iter().sum();
        if total <= 0.0 {
            break; // all values already covered exactly
        }
        let mut target = rng.uniform() * total;
        let mut pick = n - 1;
        for (i, &d) in d2.iter().enumerate() {
            if target < d {
                pick = i;
                break;
            }
            target -= d;
        }
        let c = values[pick];
        centroids.push(c);
        for (d, &v) in d2.iter_mut().zip(values) {
            *d = d.min(sqdist(v, c));
        }
    }
    centroids
}

/// Lloyd iterations over sorted-centroid nearest assignment; empty
/// clusters keep their previous centroid. Stops on convergence.
fn lloyd(values: &[f32], centroids: &mut Vec<f32>, max_iters: usize) {
    for _ in 0..max_iters {
        centroids.sort_by(f32::total_cmp);
        let mut sums = vec![0.0f64; centroids.len()];
        let mut counts = vec![0usize; centroids.len()];
        for &v in values {
            let c = nearest(centroids, v);
            sums[c] += v as f64;
            counts[c] += 1;
        }
        let mut moved = 0.0f64;
        for i in 0..centroids.len() {
            if counts[i] > 0 {
                let next = (sums[i] / counts[i] as f64) as f32;
                moved = moved.max((next - centroids[i]).abs() as f64);
                centroids[i] = next;
            }
        }
        if moved < 1e-7 {
            break;
        }
    }
    centroids.sort_by(f32::total_cmp);
}

fn sqdist(a: f32, b: f32) -> f64 {
    let d = (a - b) as f64;
    d * d
}

/// Index of the nearest centroid in an ascending-sorted codebook; ties
/// break toward the lower index (bit-deterministic).
pub fn nearest(centroids: &[f32], v: f32) -> usize {
    debug_assert!(!centroids.is_empty());
    let mut i = centroids.partition_point(|&c| c < v);
    if i == centroids.len() {
        i = centroids.len() - 1;
    }
    if i > 0 && (v - centroids[i - 1]).abs() <= (centroids[i] - v).abs() {
        i -= 1;
    }
    i
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_when_few_distinct_values() {
        let values = vec![1.0f32, -2.0, 1.0, 3.5, -2.0, 3.5, 1.0];
        let (cb, codes, stats) = kmeans_codebook(&values, 16, 25, 0);
        assert_eq!(cb, vec![-2.0, 1.0, 3.5]);
        for (&v, &c) in values.iter().zip(&codes) {
            assert_eq!(cb[c as usize], v);
        }
        assert_eq!(stats.rmse, 0.0);
        assert_eq!(stats.max_abs_err, 0.0);
    }

    #[test]
    fn one_cluster_codebook_is_usable() {
        let mut rng = Rng::new(3);
        let values = rng.normal_vec(500, 1.0);
        let (cb, codes, stats) = kmeans_codebook(&values, 1, 25, 0);
        assert_eq!(cb.len(), 1);
        assert!(codes.iter().all(|&c| c == 0));
        // The single centroid converges to the mean; error is bounded by
        // the value spread.
        let spread = values.iter().copied().fold(0.0f32, |m, v| m.max(v.abs()));
        assert!(stats.max_abs_err <= 2.0 * spread);
    }

    #[test]
    fn deterministic_per_seed() {
        let mut rng = Rng::new(5);
        let values = rng.normal_vec(2000, 0.3);
        let (a_cb, a_codes, _) = kmeans_codebook(&values, 16, 25, 7);
        let (b_cb, b_codes, _) = kmeans_codebook(&values, 16, 25, 7);
        assert_eq!(a_cb, b_cb);
        assert_eq!(a_codes, b_codes);
    }

    #[test]
    fn reported_error_matches_actual_assignment() {
        let mut rng = Rng::new(9);
        let values = rng.normal_vec(3000, 0.1);
        let (cb, codes, stats) = kmeans_codebook(&values, 8, 25, 1);
        let mut sq = 0.0f64;
        let mut max_abs = 0.0f32;
        for (&v, &c) in values.iter().zip(&codes) {
            let e = (v - cb[c as usize]).abs();
            sq += (e as f64) * (e as f64);
            max_abs = max_abs.max(e);
        }
        assert!(((sq / values.len() as f64).sqrt() - stats.rmse).abs() < 1e-12);
        assert_eq!(max_abs, stats.max_abs_err);
        // Each code must be the *nearest* centroid, not just a valid one.
        for (&v, &c) in values.iter().zip(&codes) {
            assert_eq!(c as usize, nearest(&cb, v));
        }
    }

    #[test]
    fn more_clusters_reduce_error() {
        let mut rng = Rng::new(11);
        let values = rng.normal_vec(4000, 0.2);
        let (_, _, s2) = kmeans_codebook(&values, 2, 25, 0);
        let (_, _, s16) = kmeans_codebook(&values, 16, 25, 0);
        let (_, _, s64) = kmeans_codebook(&values, 64, 25, 0);
        assert!(s16.rmse < s2.rmse, "{} vs {}", s16.rmse, s2.rmse);
        assert!(s64.rmse < s16.rmse, "{} vs {}", s64.rmse, s16.rmse);
    }

    #[test]
    fn nearest_tie_breaks_low() {
        let cb = vec![-1.0f32, 1.0];
        assert_eq!(nearest(&cb, 0.0), 0); // equidistant → lower index
        assert_eq!(nearest(&cb, 0.1), 1);
        assert_eq!(nearest(&cb, -5.0), 0);
        assert_eq!(nearest(&cb, 5.0), 1);
    }
}
