//! Training coordinator — the L3 leader loop.
//!
//! `trainer::Trainer` owns model state + data and drives the AOT training
//! artifacts step by step; `sweep` provides the λ-grid and multi-seed
//! drivers behind Figures 5-7.

pub mod sweep;
pub mod trainer;

pub use trainer::{EvalResult, StepState, Trainer};
