//! The training loop: role-driven execution of AOT train/eval artifacts.
//!
//! All training state lives host-side in `StepState` (parameter bundle +
//! optimizer moments + optional masks/θ/λ for the baselines); each step
//! assembles the artifact's input list by role, executes on PJRT, and
//! scatters outputs back by role. The same machinery drives every step
//! kind (`train_prox_*`, `train_masked`, `train_mm`) because the manifest
//! describes the signature.

use crate::config::RunConfig;
use crate::data::{self, Batcher, Dataset};
use crate::metrics::History;
use crate::runtime::client;
use crate::runtime::{HostValue, Manifest, ModelEntry, ParamBundle, Role, Runtime};
use crate::util::logger;
// Offline stand-in for the PJRT bindings; see `xla_compat` module docs.
use crate::xla_compat as xla;

/// Host-side training state, role-addressable.
#[derive(Debug, Clone)]
pub struct StepState {
    pub params: ParamBundle,
    pub opt_m: ParamBundle,
    pub opt_v: ParamBundle,
    pub t: f32,
    /// Debias/retrain masks (one per leaf), set by the compression
    /// controllers before masked training.
    pub masks: Option<Vec<Vec<f32>>>,
    /// MM auxiliaries (θ, Lagrange multipliers).
    pub theta: Option<ParamBundle>,
    pub lagrange: Option<ParamBundle>,
}

impl StepState {
    pub fn fresh(entry: &ModelEntry, seed: u64) -> StepState {
        StepState {
            params: ParamBundle::he_init(&entry.params, seed),
            opt_m: ParamBundle::zeros_like(&entry.params),
            opt_v: ParamBundle::zeros_like(&entry.params),
            t: 0.0,
            masks: None,
            theta: None,
            lagrange: None,
        }
    }

    /// Reset optimizer moments (used between phases, e.g. before debias).
    pub fn reset_optimizer(&mut self) {
        self.opt_m = ParamBundle::zeros_like(&self.params.specs);
        self.opt_v = ParamBundle::zeros_like(&self.params.specs);
        self.t = 0.0;
    }
}

/// Scalar knobs consumed by the step artifacts.
#[derive(Debug, Clone, Copy)]
pub struct StepScalars {
    pub lambda: f32,
    pub lr: f32,
    pub mu: f32,
}

/// Evaluation result over the test set.
#[derive(Debug, Clone, Copy)]
pub struct EvalResult {
    pub loss: f64,
    pub accuracy: f64,
    pub n: usize,
}

/// Trainer: model entry + datasets + state + history.
pub struct Trainer {
    pub entry: ModelEntry,
    pub state: StepState,
    pub train_data: Dataset,
    pub test_data: Dataset,
    pub history: History,
    batcher: Batcher,
    seed: u64,
}

impl Trainer {
    pub fn new(manifest: &Manifest, cfg: &RunConfig) -> anyhow::Result<Trainer> {
        let entry = manifest.model(&cfg.model)?.clone();
        let train_data = data::generate(&entry.dataset, cfg.train_examples, cfg.seed)?;
        // Disjoint test stream: same textures/templates, different examples.
        let test_data = data::generate(&entry.dataset, cfg.test_examples, cfg.seed ^ 0x7E57_DA7A)?;
        let batcher = Batcher::new(train_data.n, cfg.seed);
        Ok(Trainer {
            state: StepState::fresh(&entry, cfg.seed),
            entry,
            train_data,
            test_data,
            history: History::new(),
            batcher,
            seed: cfg.seed,
        })
    }

    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Run one training step of `step_name` on the next minibatch;
    /// returns the minibatch loss.
    pub fn step(
        &mut self,
        rt: &mut Runtime,
        step_name: &str,
        scalars: StepScalars,
    ) -> anyhow::Result<f32> {
        // Disjoint borrows: `entry` is read-only metadata, `state` is
        // mutated after execution (avoids cloning the Artifact per step —
        // a measurable §Perf cost on the small-model hot path).
        let Trainer { entry, state, train_data, batcher, .. } = self;
        let artifact = entry.artifact(step_name)?;
        let (xs, ys) = batcher.next_batch(train_data, artifact.batch);
        let x_shape = batch_shape(entry, artifact.batch);

        // Assemble input literals by role directly from borrowed state
        // slices (§Perf: no intermediate HostValue vector clones).
        let mut inputs: Vec<xla::Literal> = Vec::with_capacity(artifact.inputs.len());
        let (mut ip, mut im, mut iv, mut imask, mut ith, mut ilag) = (0, 0, 0, 0, 0, 0);
        for slot in &artifact.inputs {
            let lit = match slot.role {
                Role::Param => {
                    let i = next(&mut ip);
                    leaf_literal(&state.params, i)?
                }
                Role::OptM => {
                    let i = next(&mut im);
                    leaf_literal(&state.opt_m, i)?
                }
                Role::OptV => {
                    let i = next(&mut iv);
                    leaf_literal(&state.opt_v, i)?
                }
                Role::OptT => client::literal_f32(&[], &[state.t])?,
                Role::Mask => {
                    let i = next(&mut imask);
                    let masks = state
                        .masks
                        .as_ref()
                        .ok_or_else(|| anyhow::anyhow!("masked step without masks set"))?;
                    client::literal_f32(&slot.shape, &masks[i])?
                }
                Role::Theta => {
                    let i = next(&mut ith);
                    let th = state
                        .theta
                        .as_ref()
                        .ok_or_else(|| anyhow::anyhow!("MM step without theta set"))?;
                    leaf_literal(th, i)?
                }
                Role::Lagrange => {
                    let i = next(&mut ilag);
                    let lg = state
                        .lagrange
                        .as_ref()
                        .ok_or_else(|| anyhow::anyhow!("MM step without lagrange set"))?;
                    leaf_literal(lg, i)?
                }
                Role::X => client::literal_f32(&x_shape, &xs)?,
                Role::Y => client::literal_i32(&[artifact.batch], &ys)?,
                Role::Lambda => client::literal_f32(&[], &[scalars.lambda])?,
                Role::Lr => client::literal_f32(&[], &[scalars.lr])?,
                Role::Mu => client::literal_f32(&[], &[scalars.mu])?,
                other => anyhow::bail!("unexpected input role {other:?}"),
            };
            inputs.push(lit);
        }

        let outputs = rt.execute_literals(&artifact.file, &inputs)?;
        anyhow::ensure!(
            outputs.len() == artifact.outputs.len(),
            "artifact returned {} outputs, manifest says {}",
            outputs.len(),
            artifact.outputs.len()
        );

        // Scatter outputs back into state by role.
        let (mut op, mut om, mut ov) = (0, 0, 0);
        let mut loss = f32::NAN;
        for (slot, value) in artifact.outputs.iter().zip(outputs) {
            match slot.role {
                Role::Param => {
                    let i = next(&mut op);
                    state.params.values[i] = value.as_f32()?.to_vec();
                }
                Role::OptM => {
                    let i = next(&mut om);
                    state.opt_m.values[i] = value.as_f32()?.to_vec();
                }
                Role::OptV => {
                    let i = next(&mut ov);
                    state.opt_v.values[i] = value.as_f32()?.to_vec();
                }
                Role::OptT => state.t = value.scalar()?,
                Role::Loss => loss = value.scalar()?,
                other => anyhow::bail!("unexpected output role {other:?}"),
            }
        }
        anyhow::ensure!(loss.is_finite(), "non-finite loss {loss} (diverged?)");
        Ok(loss)
    }

    /// Run `n` steps, recording history every `record_every` (0 = never).
    pub fn run_steps(
        &mut self,
        rt: &mut Runtime,
        step_name: &str,
        n: usize,
        scalars: StepScalars,
        record_every: usize,
    ) -> anyhow::Result<f32> {
        let mut last = 0.0;
        for k in 0..n {
            last = self.step(rt, step_name, scalars)?;
            if record_every > 0 && (k + 1) % record_every == 0 {
                let rate = self.state.params.compression_rate();
                let step = self.history.next_step();
                self.history.record_step(step, last as f64, rate);
                logger::log(
                    logger::Level::Debug,
                    &format!("step {k}: loss {last:.4} rate {rate:.4}"),
                );
            }
        }
        Ok(last)
    }

    /// Exact test-set evaluation via the `infer` artifact (argmax +
    /// cross-entropy computed host-side on the fresh portion of each
    /// batch, so wrap-around padding never biases the metric).
    pub fn evaluate(&mut self, rt: &mut Runtime) -> anyhow::Result<EvalResult> {
        let artifact = self.entry.artifact("infer")?.clone();
        let param_values = self.state.params.to_host_values();
        let x_shape = batch_shape(&self.entry, artifact.batch);
        let mut correct = 0usize;
        let mut loss_sum = 0.0f64;
        let mut n = 0usize;
        for (xs, ys, fresh) in Batcher::eval_batches(&self.test_data, artifact.batch) {
            let mut inputs = param_values.clone();
            inputs.push(HostValue::F32 { shape: x_shape.clone(), data: xs });
            let out = rt.execute(&artifact.file, &inputs)?;
            let logits = out[0].as_f32()?;
            let ncls = self.entry.num_classes;
            for i in 0..fresh {
                let row = &logits[i * ncls..(i + 1) * ncls];
                // log-softmax CE for this example
                let m = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                let z: f32 = row.iter().map(|v| (v - m).exp()).sum();
                let label = ys[i] as usize;
                loss_sum += (-(row[label] - m) + z.ln()) as f64;
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                    .map(|(j, _)| j)
                    .unwrap();
                if pred == label {
                    correct += 1;
                }
            }
            n += fresh;
        }
        Ok(EvalResult {
            loss: loss_sum / n as f64,
            accuracy: correct as f64 / n as f64,
            n,
        })
    }
}

fn next(cursor: &mut usize) -> usize {
    let i = *cursor;
    *cursor += 1;
    i
}

fn leaf_literal(bundle: &ParamBundle, i: usize) -> anyhow::Result<xla::Literal> {
    client::literal_f32(&bundle.specs[i].shape, &bundle.values[i])
}

fn batch_shape(entry: &ModelEntry, batch: usize) -> Vec<usize> {
    let mut s = vec![batch];
    s.extend_from_slice(&entry.input_shape);
    s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fresh_state_shapes() {
        // Pure-state test (no artifacts needed).
        let spec = crate::runtime::ParamSpec {
            name: "w".into(),
            kind: "fc_w".into(),
            shape: vec![4, 3],
            prunable: true,
            layer: "fc".into(),
        };
        let entry = ModelEntry {
            name: "t".into(),
            dataset: "synth-mnist".into(),
            input_shape: vec![1, 28, 28],
            num_classes: 10,
            train_batch: 8,
            eval_batch: 8,
            params: vec![spec],
            num_weights: 12,
            num_params: 12,
            artifacts: Default::default(),
        };
        let st = StepState::fresh(&entry, 0);
        assert_eq!(st.params.values[0].len(), 12);
        assert_eq!(st.opt_m.values[0], vec![0.0; 12]);
        assert_eq!(st.t, 0.0);
        assert!(st.masks.is_none());
    }

    #[test]
    fn reset_optimizer_clears_moments() {
        let spec = crate::runtime::ParamSpec {
            name: "w".into(),
            kind: "fc_w".into(),
            shape: vec![2, 2],
            prunable: true,
            layer: "fc".into(),
        };
        let entry = ModelEntry {
            name: "t".into(),
            dataset: "synth-mnist".into(),
            input_shape: vec![1, 28, 28],
            num_classes: 10,
            train_batch: 8,
            eval_batch: 8,
            params: vec![spec],
            num_weights: 4,
            num_params: 4,
            artifacts: Default::default(),
        };
        let mut st = StepState::fresh(&entry, 0);
        st.opt_m.values[0][0] = 3.0;
        st.t = 10.0;
        st.reset_optimizer();
        assert_eq!(st.opt_m.values[0][0], 0.0);
        assert_eq!(st.t, 0.0);
    }
}
