//! Sweep drivers: λ grids (Figure 6) and multi-seed variance (Figure 5).
//!
//! Sweeps share one `Runtime` so each artifact compiles once; λ and the
//! seed are runtime inputs, not compile-time constants.

use crate::compress;
use crate::config::{Method, RunConfig};
use crate::info;
use crate::metrics::RunResult;
use crate::runtime::{Manifest, Runtime};

/// Run one configured method end to end.
pub fn run_method(rt: &mut Runtime, manifest: &Manifest, cfg: &RunConfig) -> anyhow::Result<RunResult> {
    cfg.validate()?;
    match cfg.method {
        Method::SpC => compress::spc::run(rt, manifest, cfg),
        Method::Pru => compress::pruning::run(rt, manifest, cfg),
        Method::MM => compress::mm::run(rt, manifest, cfg),
        Method::Reference => {
            // Reference model = SpC with λ=0 (plain Prox-ADAM degenerates
            // to ADAM) and no retraining.
            let mut c = cfg.clone();
            c.lambda = 0.0;
            c.retrain_steps = 0;
            let mut r = compress::spc::run(rt, manifest, &c)?;
            r.method = "Ref".into();
            Ok(r)
        }
    }
}

/// λ-grid sweep (Figure 6): one result per λ, same seed.
pub fn lambda_sweep(
    rt: &mut Runtime,
    manifest: &Manifest,
    base: &RunConfig,
    lambdas: &[f32],
) -> anyhow::Result<Vec<RunResult>> {
    let mut out = Vec::with_capacity(lambdas.len());
    for &lam in lambdas {
        let mut cfg = base.clone();
        cfg.lambda = lam;
        cfg.pru_target_rate = cfg.pru_target_rate.min(0.995);
        info!("[sweep] λ = {lam}");
        out.push(run_method(rt, manifest, &cfg)?);
    }
    Ok(out)
}

/// Multi-seed variance study (Figure 5): one result per seed.
pub fn seed_sweep(
    rt: &mut Runtime,
    manifest: &Manifest,
    base: &RunConfig,
    seeds: &[u64],
) -> anyhow::Result<Vec<RunResult>> {
    let mut out = Vec::with_capacity(seeds.len());
    for &seed in seeds {
        let mut cfg = base.clone();
        cfg.seed = seed;
        info!("[sweep] seed = {seed}");
        out.push(run_method(rt, manifest, &cfg)?);
    }
    Ok(out)
}

/// Pru rate sweep (Figure 6b): one result per target compression rate.
pub fn pru_rate_sweep(
    rt: &mut Runtime,
    manifest: &Manifest,
    base: &RunConfig,
    rates: &[f64],
) -> anyhow::Result<Vec<RunResult>> {
    let mut out = Vec::with_capacity(rates.len());
    for &rate in rates {
        let mut cfg = base.clone();
        cfg.method = Method::Pru;
        cfg.pru_target_rate = rate;
        info!("[sweep] pru target rate = {rate}");
        out.push(run_method(rt, manifest, &cfg)?);
    }
    Ok(out)
}
