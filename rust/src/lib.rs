//! # proxcomp — compressed learning of deep neural networks
//!
//! Reproduction of Lee & Lee, *"Compressed Learning of Deep Neural
//! Networks for OpenCL-Capable Embedded Systems"* (Applied Sciences 9(8),
//! 2019) as a three-layer Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — training coordinator, compression controllers
//!   (SpC / Pru / MM / debias), compressed sparse matrix substrate (DIA /
//!   ELL / CSR / COO + the paper's dense×compressed kernels), compressed
//!   inference engine, embedded-device cost model, checkpoints, metrics,
//!   CLI.
//! * **L2 (python/compile)** — JAX model zoo + Prox-RMSProp / Prox-ADAM /
//!   masked / MM training graphs, AOT-lowered to HLO text once at build
//!   time (`make artifacts`).
//! * **L1 (python/compile/kernels)** — Pallas kernels (prox
//!   soft-threshold, dense×compressed matmuls) that lower *into* the L2
//!   artifacts.
//!
//! At runtime only this crate runs: it loads `artifacts/*.hlo.txt` via
//! the PJRT C API (`xla` crate) and drives everything from Rust. See
//! DESIGN.md for the paper↔module map and EXPERIMENTS.md for results.

pub mod checkpoint;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod device;
pub mod inference;
pub mod metrics;
pub mod quant;
pub mod runtime;
pub mod sparse;
pub mod telemetry;
pub mod tensor;
pub mod util;
pub mod xla_compat;
