//! Typed run configuration: model, optimizer, compression, dataset, run.
//!
//! Configs load from JSON files (`--config run.json`) with CLI overrides
//! (`--model lenet --lambda 1.2 ...`); `validate()` catches inconsistent
//! combinations before any artifact is compiled.

use crate::util::cli::Args;
use crate::util::json::{self, Json};

/// Which compression method drives training (paper Section 4 notation).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Method {
    /// Sparse coding with proximal optimizers (the paper's contribution).
    SpC,
    /// Magnitude pruning + retraining (Han et al. 2015).
    Pru,
    /// Learning-compression via method of multipliers (CP & Idelbayev 2018).
    MM,
    /// No compression — the reference model.
    Reference,
}

impl Method {
    pub fn parse(s: &str) -> anyhow::Result<Method> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "spc" => Method::SpC,
            "pru" | "prune" | "pruning" => Method::Pru,
            "mm" => Method::MM,
            "ref" | "reference" | "none" => Method::Reference,
            other => anyhow::bail!("unknown method {other:?} (spc|pru|mm|ref)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Method::SpC => "SpC",
            Method::Pru => "Pru",
            Method::MM => "MM",
            Method::Reference => "Ref",
        }
    }
}

/// Which proximal optimizer (paper Algorithms 1-2).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Optimizer {
    ProxAdam,
    ProxRmsprop,
    ProxSgd,
}

impl Optimizer {
    pub fn parse(s: &str) -> anyhow::Result<Optimizer> {
        Ok(match s.to_ascii_lowercase().as_str() {
            "prox-adam" | "prox_adam" | "adam" => Optimizer::ProxAdam,
            "prox-rmsprop" | "prox_rmsprop" | "rmsprop" => Optimizer::ProxRmsprop,
            "prox-sgd" | "prox_sgd" | "sgd" => Optimizer::ProxSgd,
            other => anyhow::bail!("unknown optimizer {other:?}"),
        })
    }

    /// Artifact step name in the manifest.
    pub fn step_name(&self) -> &'static str {
        match self {
            Optimizer::ProxAdam => "train_prox_adam",
            Optimizer::ProxRmsprop => "train_prox_rmsprop",
            Optimizer::ProxSgd => "train_prox_sgd",
        }
    }
}

/// Full run configuration.
#[derive(Debug, Clone)]
pub struct RunConfig {
    pub model: String,
    pub method: Method,
    pub optimizer: Optimizer,
    /// ℓ1 regularization weight λ (the compression knob).
    pub lambda: f32,
    pub lr: f32,
    pub steps: usize,
    /// Debias / retraining steps after the sparse phase (0 = off).
    pub retrain_steps: usize,
    pub retrain_lr: f32,
    pub seed: u64,
    pub train_examples: usize,
    pub test_examples: usize,
    /// Evaluate every `eval_every` steps (0 = only at the end).
    pub eval_every: usize,
    /// MM hyperparameters (paper Table 2).
    pub mm_mu0: f32,
    pub mm_mu_growth: f32,
    pub mm_compress_every: usize,
    /// Pru: target compression rate for threshold selection.
    pub pru_target_rate: f64,
    pub artifacts_dir: String,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            model: "lenet".into(),
            method: Method::SpC,
            optimizer: Optimizer::ProxAdam,
            lambda: 1.0,
            lr: 1e-3,
            steps: 600,
            retrain_steps: 0,
            retrain_lr: 1e-4,
            seed: 0,
            train_examples: 4096,
            test_examples: 1024,
            eval_every: 0,
            mm_mu0: 9.76e-5,
            mm_mu_growth: 1.1,
            mm_compress_every: 200,
            pru_target_rate: 0.9,
            artifacts_dir: "artifacts".into(),
        }
    }
}

impl RunConfig {
    /// Apply CLI overrides on top of this config.
    pub fn apply_args(&mut self, args: &Args) -> anyhow::Result<()> {
        if let Some(m) = args.get_str("model") {
            self.model = m;
        }
        if let Some(m) = args.get_str("method") {
            self.method = Method::parse(&m)?;
        }
        if let Some(o) = args.get_str("optimizer") {
            self.optimizer = Optimizer::parse(&o)?;
        }
        self.lambda = args.f32_or("lambda", self.lambda)?;
        self.lr = args.f32_or("lr", self.lr)?;
        self.steps = args.usize_or("steps", self.steps)?;
        self.retrain_steps = args.usize_or("retrain-steps", self.retrain_steps)?;
        self.retrain_lr = args.f32_or("retrain-lr", self.retrain_lr)?;
        self.seed = args.u64_or("seed", self.seed)?;
        self.train_examples = args.usize_or("train-examples", self.train_examples)?;
        self.test_examples = args.usize_or("test-examples", self.test_examples)?;
        self.eval_every = args.usize_or("eval-every", self.eval_every)?;
        self.mm_mu0 = args.f32_or("mm-mu0", self.mm_mu0)?;
        self.mm_mu_growth = args.f32_or("mm-mu-growth", self.mm_mu_growth)?;
        self.mm_compress_every = args.usize_or("mm-compress-every", self.mm_compress_every)?;
        self.pru_target_rate = args.f64_or("pru-target-rate", self.pru_target_rate)?;
        if let Some(d) = args.get_str("artifacts-dir") {
            self.artifacts_dir = d;
        }
        Ok(())
    }

    /// Load a JSON config file (all keys optional).
    pub fn from_json_file(path: &str) -> anyhow::Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        let j = json::parse(&text)?;
        let mut c = RunConfig::default();
        if let Some(v) = j.get("model").and_then(Json::as_str) {
            c.model = v.to_string();
        }
        if let Some(v) = j.get("method").and_then(Json::as_str) {
            c.method = Method::parse(v)?;
        }
        if let Some(v) = j.get("optimizer").and_then(Json::as_str) {
            c.optimizer = Optimizer::parse(v)?;
        }
        let f32_of = |key: &str, d: f32| j.get(key).and_then(Json::as_f64).map(|v| v as f32).unwrap_or(d);
        let usize_of = |key: &str, d: usize| j.get(key).and_then(Json::as_usize).unwrap_or(d);
        c.lambda = f32_of("lambda", c.lambda);
        c.lr = f32_of("lr", c.lr);
        c.steps = usize_of("steps", c.steps);
        c.retrain_steps = usize_of("retrain_steps", c.retrain_steps);
        c.retrain_lr = f32_of("retrain_lr", c.retrain_lr);
        c.seed = j.get("seed").and_then(Json::as_i64).map(|v| v as u64).unwrap_or(c.seed);
        c.train_examples = usize_of("train_examples", c.train_examples);
        c.test_examples = usize_of("test_examples", c.test_examples);
        c.eval_every = usize_of("eval_every", c.eval_every);
        c.mm_mu0 = f32_of("mm_mu0", c.mm_mu0);
        c.mm_mu_growth = f32_of("mm_mu_growth", c.mm_mu_growth);
        c.mm_compress_every = usize_of("mm_compress_every", c.mm_compress_every);
        c.pru_target_rate = j.get("pru_target_rate").and_then(Json::as_f64).unwrap_or(c.pru_target_rate);
        if let Some(v) = j.get("artifacts_dir").and_then(Json::as_str) {
            c.artifacts_dir = v.to_string();
        }
        Ok(c)
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        if self.lambda < 0.0 {
            anyhow::bail!("lambda must be >= 0, got {}", self.lambda);
        }
        if self.lr <= 0.0 {
            anyhow::bail!("lr must be > 0");
        }
        if self.steps == 0 {
            anyhow::bail!("steps must be > 0");
        }
        if self.method == Method::MM && self.mm_mu0 <= 0.0 {
            anyhow::bail!("MM requires mm_mu0 > 0");
        }
        if !(0.0..1.0).contains(&self.pru_target_rate) {
            anyhow::bail!("pru_target_rate must be in [0,1)");
        }
        if self.train_examples == 0 || self.test_examples == 0 {
            anyhow::bail!("need nonzero train/test examples");
        }
        Ok(())
    }

    /// Serialize for run records.
    pub fn to_json(&self) -> Json {
        let mut j = Json::obj();
        j.set("model", Json::from(self.model.as_str()))
            .set("method", Json::from(self.method.name()))
            .set("optimizer", Json::from(self.optimizer.step_name()))
            .set("lambda", Json::from(self.lambda as f64))
            .set("lr", Json::from(self.lr as f64))
            .set("steps", Json::from(self.steps))
            .set("retrain_steps", Json::from(self.retrain_steps))
            .set("seed", Json::from(self.seed as i64))
            .set("train_examples", Json::from(self.train_examples))
            .set("test_examples", Json::from(self.test_examples));
        j
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn method_parsing() {
        assert_eq!(Method::parse("spc").unwrap(), Method::SpC);
        assert_eq!(Method::parse("Pru").unwrap(), Method::Pru);
        assert_eq!(Method::parse("MM").unwrap(), Method::MM);
        assert_eq!(Method::parse("ref").unwrap(), Method::Reference);
        assert!(Method::parse("magic").is_err());
    }

    #[test]
    fn optimizer_step_names() {
        assert_eq!(Optimizer::parse("adam").unwrap().step_name(), "train_prox_adam");
        assert_eq!(Optimizer::parse("rmsprop").unwrap().step_name(), "train_prox_rmsprop");
    }

    #[test]
    fn cli_overrides() {
        let args = Args::parse(
            ["--model", "mlp", "--lambda", "2.5", "--steps", "42", "--method", "pru"]
                .iter()
                .map(|s| s.to_string()),
        )
        .unwrap();
        let mut c = RunConfig::default();
        c.apply_args(&args).unwrap();
        assert_eq!(c.model, "mlp");
        assert_eq!(c.method, Method::Pru);
        assert!((c.lambda - 2.5).abs() < 1e-9);
        assert_eq!(c.steps, 42);
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut c = RunConfig::default();
        c.lambda = -1.0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.steps = 0;
        assert!(c.validate().is_err());
        let mut c = RunConfig::default();
        c.pru_target_rate = 1.5;
        assert!(c.validate().is_err());
    }

    #[test]
    fn json_file_roundtrip() {
        let dir = std::env::temp_dir().join("proxcomp_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.json");
        std::fs::write(
            &path,
            r#"{"model": "vgg_s", "method": "mm", "lambda": 0.5, "steps": 99, "seed": 7}"#,
        )
        .unwrap();
        let c = RunConfig::from_json_file(path.to_str().unwrap()).unwrap();
        assert_eq!(c.model, "vgg_s");
        assert_eq!(c.method, Method::MM);
        assert_eq!(c.steps, 99);
        assert_eq!(c.seed, 7);
        // untouched keys keep defaults
        assert_eq!(c.test_examples, RunConfig::default().test_examples);
    }
}
