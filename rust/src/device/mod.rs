//! Embedded-device simulator: roofline cost model for Table 3.
//!
//! The paper measures Lenet-5 inference on an ARM Mali-T860 (embedded,
//! OpenCL 1.2) and an NVIDIA GTX 1080 Ti. Neither GPU exists on this
//! testbed (DESIGN.md §4), so we model each device as a roofline:
//!
//! ```text
//! t_layer = max(flops / (peak_flops · eff), bytes / (peak_bw · eff))
//!           + launch_overhead
//! ```
//!
//! with a *sparse efficiency* discount on the compressed path capturing
//! what the paper observed ("the compressed convolution filters have
//! irregular nonzero patterns for which full GPU acceleration is
//! difficult") — sparse kernels run far below peak. The model's point is
//! Table 3's *shape*: at ~97% sparsity the op is bandwidth-bound, so
//! compressed inference wins by ~1.2-2×, not by the 30× parameter
//! reduction. Parameters are public datasheet numbers.

use crate::inference::Engine;

/// Roofline parameters for one device.
#[derive(Debug, Clone, Copy)]
pub struct DeviceModel {
    pub name: &'static str,
    /// Peak f32 throughput (FLOP/s).
    pub peak_flops: f64,
    /// Peak memory bandwidth (bytes/s).
    pub peak_bw: f64,
    /// Fraction of peak a tuned *dense* kernel reaches.
    pub dense_eff: f64,
    /// Fraction of peak a *sparse* (CSR) kernel reaches — low, per the
    /// paper's own observation about irregular access.
    pub sparse_eff: f64,
    /// Fixed per-kernel-launch overhead (seconds).
    pub launch_overhead: f64,
}

/// ARM Mali-T860 MP4 (the paper's embedded target): ~23.8 GFLOPS fp32,
/// LPDDR3 ~12.8 GB/s shared with the CPU. `sparse_eff` is *calibrated*
/// against the paper's own Table-3 measurement: 1.20× total speedup at
/// their Table-A1 layer densities implies the CSR kernels ran at ~12% of
/// the dense kernels' pace (0.55 × 0.124 ≈ 0.068 of peak) — the paper's
/// "full GPU acceleration is difficult" observation made quantitative.
pub const MALI_T860: DeviceModel = DeviceModel {
    name: "ARM Mali-T860",
    peak_flops: 23.8e9,
    peak_bw: 12.8e9,
    dense_eff: 0.55,
    sparse_eff: 0.068,
    launch_overhead: 120e-6,
};

/// NVIDIA GTX 1080 Ti: ~11.3 TFLOPS fp32, 484 GB/s GDDR5X. `sparse_eff`
/// calibrated to the paper's measured 1.98× Table-3 speedup at their
/// layer densities (≈20% of the dense pace; see MALI_T860 docs).
pub const GTX_1080TI: DeviceModel = DeviceModel {
    name: "NVIDIA GTX 1080 Ti",
    peak_flops: 11.3e12,
    peak_bw: 484e9,
    dense_eff: 0.6,
    sparse_eff: 0.12,
    launch_overhead: 8e-6,
};

/// Generic laptop-class CPU reference (for sanity checks vs. measured —
/// CPUs tolerate irregular access far better than GPUs, hence the much
/// higher sparse efficiency; our measured rust-engine speedups confirm).
pub const CPU_REF: DeviceModel = DeviceModel {
    name: "generic CPU",
    peak_flops: 150e9,
    peak_bw: 40e9,
    dense_eff: 0.4,
    sparse_eff: 0.15,
    launch_overhead: 1e-6,
};

/// Cost of one layer evaluation.
#[derive(Debug, Clone)]
pub struct LayerCost {
    pub name: String,
    pub flops: f64,
    pub bytes: f64,
    pub seconds: f64,
    pub bound: &'static str,
}

impl DeviceModel {
    /// Roofline time for one kernel with the given work.
    ///
    /// Irregular (CSR) access mostly wastes *ALU utilization/occupancy*
    /// (divergent lanes, gather latency), not raw DRAM bandwidth — the
    /// streaming parts of the kernel (activations, CSR arrays) remain
    /// coalesced. So `sparse_eff` discounts the compute term only.
    pub fn kernel_time(&self, flops: f64, bytes: f64, sparse: bool) -> (f64, &'static str) {
        let comp_eff = if sparse { self.sparse_eff } else { self.dense_eff };
        let t_comp = flops / (self.peak_flops * comp_eff);
        let t_mem = bytes / (self.peak_bw * self.dense_eff);
        let t = t_comp.max(t_mem) + self.launch_overhead;
        (t, if t_comp >= t_mem { "compute" } else { "memory" })
    }

    /// Estimate total inference time for an engine's weight layers at a
    /// given batch size, from per-layer FLOP and byte counts.
    pub fn estimate_engine(&self, engine: &Engine, work: &[LayerWork]) -> Vec<LayerCost> {
        work.iter()
            .map(|w| {
                let (seconds, bound) = self.kernel_time(w.flops, w.bytes, engine.sparse);
                LayerCost {
                    name: w.name.clone(),
                    flops: w.flops,
                    bytes: w.bytes,
                    seconds,
                    bound,
                }
            })
            .collect()
    }
}

/// Work description of one weight layer. Produced by
/// `Engine::work_profile` (FLOPs = 2·B·positions·nnz; bytes = weight
/// storage touched + activations in/out). The weight bytes are the
/// *stored* representation — f32 CSR on the paper's deployment path,
/// quantized-CSR (packed codes + narrowed indices + codebook) under
/// `WeightMode::Quantized` — so Table-3-style projections reflect the
/// format actually streamed from memory, not an f32 assumption.
#[derive(Debug, Clone)]
pub struct LayerWork {
    pub name: String,
    pub flops: f64,
    pub bytes: f64,
}

/// Table-3 style summary: dense vs compressed on one device.
#[derive(Debug, Clone)]
pub struct SpeedupEstimate {
    pub device: &'static str,
    pub dense_seconds: f64,
    pub sparse_seconds: f64,
}

impl SpeedupEstimate {
    pub fn speedup(&self) -> f64 {
        self.dense_seconds / self.sparse_seconds
    }
}

/// Estimate the paper's Table 3 for a pair of engines (dense + sparse)
/// with identical architecture.
pub fn estimate_speedup(
    device: &DeviceModel,
    dense: &Engine,
    sparse: &Engine,
    dense_work: &[LayerWork],
    sparse_work: &[LayerWork],
) -> SpeedupEstimate {
    let d: f64 = device
        .estimate_engine(dense, dense_work)
        .iter()
        .map(|c| c.seconds)
        .sum();
    let s: f64 = device
        .estimate_engine(sparse, sparse_work)
        .iter()
        .map(|c| c.seconds)
        .sum();
    SpeedupEstimate { device: device.name, dense_seconds: d, sparse_seconds: s }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roofline_picks_binding_constraint() {
        let d = MALI_T860;
        // Huge flops, tiny bytes → compute bound.
        let (t1, b1) = d.kernel_time(1e12, 1e3, false);
        assert_eq!(b1, "compute");
        // Tiny flops, huge bytes → memory bound.
        let (t2, b2) = d.kernel_time(1e3, 1e12, false);
        assert_eq!(b2, "memory");
        assert!(t1 > 0.0 && t2 > 0.0);
    }

    #[test]
    fn sparse_efficiency_penalty() {
        let d = MALI_T860;
        let (td, _) = d.kernel_time(1e9, 1e6, false);
        let (ts, _) = d.kernel_time(1e9, 1e6, true);
        assert!(ts > td, "sparse kernels run below dense efficiency");
    }

    #[test]
    fn embedded_much_slower_than_desktop() {
        // Table 3's 506,067 ms vs 8,572 ms gap in shape: Mali ≫ 1080 Ti.
        let flops = 1e9;
        let bytes = 1e7;
        let (tm, _) = MALI_T860.kernel_time(flops, bytes, false);
        let (tg, _) = GTX_1080TI.kernel_time(flops, bytes, false);
        assert!(tm / tg > 20.0, "mali/gtx ratio {}", tm / tg);
    }

    #[test]
    fn roofline_consumes_stored_quantized_bytes() {
        // Table-3-style projection must reflect the *stored* weight
        // format: the same sparse model deployed quantized streams
        // fewer weight bytes per layer than CSR (same nnz, same FLOPs),
        // so its roofline estimate is never slower on any device.
        use crate::inference::{Engine, WeightMode};
        use crate::runtime::{ParamBundle, ParamSpec};
        use crate::sparse::prox;

        let specs = vec![
            ParamSpec::new("fc1_w", "fc_w", vec![128, 400], true),
            ParamSpec::new("fc1_b", "fc_b", vec![128], false),
            ParamSpec::new("fc2_w", "fc_w", vec![10, 128], true),
            ParamSpec::new("fc2_b", "fc_b", vec![10], false),
        ];
        let mut bundle = ParamBundle::he_init(&specs, 2);
        for (spec, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
            if spec.prunable {
                let t = prox::magnitude_quantile(v, 0.9);
                prox::hard_threshold_inplace(v, t);
            }
        }
        let csr = Engine::builder("mlp-s").bundle(&bundle).mode(WeightMode::Csr).build().unwrap();
        let quant =
            Engine::builder("mlp-s").bundle(&bundle).mode(WeightMode::Quantized).build().unwrap();
        let wc = csr.work_profile(1, 1, 20, 20);
        let wq = quant.work_profile(1, 1, 20, 20);
        assert_eq!(wc.len(), wq.len());
        let (mut bc, mut bq) = (0.0f64, 0.0f64);
        for (c, q) in wc.iter().zip(&wq) {
            assert_eq!(c.flops, q.flops, "{}: nnz-driven FLOPs must not change", c.name);
            assert!(q.bytes < c.bytes, "{}: quantized bytes {} >= CSR {}", c.name, q.bytes, c.bytes);
            bc += c.bytes;
            bq += q.bytes;
        }
        assert!(bq < bc);
        for d in [MALI_T860, GTX_1080TI, CPU_REF] {
            let tc: f64 = d.estimate_engine(&csr, &wc).iter().map(|l| l.seconds).sum();
            let tq: f64 = d.estimate_engine(&quant, &wq).iter().map(|l| l.seconds).sum();
            assert!(tq <= tc + 1e-15, "{}: quantized projection {tq} slower than CSR {tc}", d.name);
        }
    }

    #[test]
    fn sparsity_wins_modestly_at_table3_operating_point() {
        // LeNet fc1 at 97% sparsity, batch 64 (the Table-3 regime): the
        // ~30× FLOP reduction is mostly eaten by the ~27× lower sparse
        // kernel efficiency, leaving the paper's modest 1.1-2× win.
        let batch = 64.0;
        let dense_flops = 2.0 * batch * 400_000.0;
        let sparse_flops = 2.0 * batch * 13_000.0;
        let dense_bytes = 400_000.0 * 4.0 + batch * (800.0 + 500.0) * 4.0;
        let sparse_bytes = 13_000.0 * 8.0 + batch * (800.0 + 500.0) * 4.0;
        for d in [MALI_T860, GTX_1080TI] {
            let (td, _) = d.kernel_time(dense_flops, dense_bytes, false);
            let (ts, _) = d.kernel_time(sparse_flops, sparse_bytes, true);
            let speedup = td / ts;
            assert!(
                speedup > 1.0 && speedup < 4.0,
                "{}: speedup {speedup}",
                d.name
            );
        }
    }
}
