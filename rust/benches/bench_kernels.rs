//! Kernel micro-benchmarks (§Perf substrate): the rust CSR kernels, the
//! dense baselines, the Block-ELL kernel, prox, im2col — plus the
//! Figure-1 storage-format comparison on realistic prox-trained-style
//! weight matrices.
//!
//! This is the harness the L3 performance pass iterates against
//! (EXPERIMENTS.md §Perf). Sizes mirror the hot layers: LeNet fc1
//! (500×800) and a VGG-ish conv-as-matmul (128×1152).

#[path = "common.rs"]
mod common;

use proxcomp::sparse::dispatch::{self, DynSparseMatrix, SparseFormat};
use proxcomp::sparse::{ops, prox, BlockEllMatrix, CooMatrix, CsrMatrix, DiaMatrix, EllMatrix};
use proxcomp::tensor::{self, ConvSpec, Tensor};
use proxcomp::util::rng::Rng;

fn sparse_matrix(rng: &mut Rng, n: usize, k: usize, rate: f64) -> (Vec<f32>, CsrMatrix) {
    let mut dense = rng.normal_vec(n * k, 0.05);
    let t = prox::magnitude_quantile(&dense, rate);
    prox::hard_threshold_inplace(&mut dense, t);
    let csr = CsrMatrix::from_dense(&dense, n, k);
    (dense, csr)
}

fn gflops(flops: f64, us: f64) -> f64 {
    flops / (us * 1e-6) / 1e9
}

fn main() -> anyhow::Result<()> {
    let mut rng = Rng::new(0);
    let reps = common::reps(20);
    let mut json = common::BenchJson::new();

    common::section(&format!("kernel micro-benchmarks (median of {reps} reps)"));

    // --- D×C' and D×C at LeNet-fc1 shape across sparsity levels
    let (b, n, k) = (128, 500, 800);
    let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
    let g = Tensor::new(vec![b, n], rng.normal_vec(b * n, 1.0));
    println!("\nD×C' forward (B={b}, N={n}, K={k}) — paper Figure 2 kernel:");
    println!("{:<22} {:>10} {:>10} {:>9}", "variant", "µs", "GFLOP/s", "vs dense");
    let dense_w = Tensor::new(vec![n, k], rng.normal_vec(n * k, 1.0));
    let dense_us = common::time_median_us(reps, || {
        tensor::matmul_nt(&d, &dense_w);
    });
    let dense_flops = 2.0 * (b * n * k) as f64;
    println!("{:<22} {:>10.0} {:>10.1} {:>9}", "dense matmul_nt", dense_us, gflops(dense_flops, dense_us), "1.00×");
    json.row("dxct_forward", "dense_matmul_nt", dense_us, "gflops", gflops(dense_flops, dense_us));
    for rate in [0.5, 0.9, 0.97] {
        let (_, csr) = sparse_matrix(&mut rng, n, k, rate);
        // §Perf before/after: scalar (Figure-2 port) vs column-major SpMM.
        let us_scalar = common::time_median_us(reps, || {
            ops::dxct_scalar(&d, &csr);
        });
        let us = common::time_median_us(reps, || {
            ops::dxct(&d, &csr);
        });
        let flops = 2.0 * (b * csr.nnz()) as f64;
        json.row("dxct_forward", &format!("csr_dxct_{:.0}pct", rate * 100.0), us, "gflops", gflops(flops, us));
        println!(
            "{:<22} {:>10.0} {:>10.1} {:>8.2}×   (scalar form: {:.0} µs, SpMM {:.1}× faster)",
            format!("CSR dxct @ {:.0}%", rate * 100.0),
            us,
            gflops(flops, us),
            dense_us / us,
            us_scalar,
            us_scalar / us,
        );
    }

    println!("\nD×C backward (B={b}, N={n}, K={k}) — paper Figure 3 kernel:");
    for rate in [0.9, 0.97] {
        let (_, csr) = sparse_matrix(&mut rng, n, k, rate);
        let us_scalar = common::time_median_us(reps, || {
            ops::dxc_scalar(&g, &csr);
        });
        let us = common::time_median_us(reps, || {
            ops::dxc(&g, &csr);
        });
        println!(
            "  CSR dxc @ {:>3.0}%: {:>8.0} µs ({:.2}× vs dense fwd; scalar form {:.0} µs, SpMM {:.1}× faster)",
            rate * 100.0,
            us,
            dense_us / us,
            us_scalar,
            us_scalar / us
        );
    }

    // --- Block-ELL kernel (the TPU-format mirror)
    println!("\nBlock-ELL dxct (block 8×16):");
    for rate in [0.9, 0.97] {
        let (dense, _) = sparse_matrix(&mut rng, 512, 768, rate);
        let bell = BlockEllMatrix::from_dense(&dense, 512, 768, 8, 16);
        let d2 = Tensor::new(vec![64, 768], rng.normal_vec(64 * 768, 1.0));
        let us = common::time_median_us(reps, || {
            bell.dxct(&d2);
        });
        println!(
            "  @ {:>3.0}% element-sparsity: {:>8.0} µs (block density {:.2}, pad overhead {:.2})",
            rate * 100.0,
            us,
            bell.block_density(),
            bell.padding_overhead()
        );
    }

    // --- prox kernel
    println!("\nprox soft-threshold (400k elements — LeNet fc1):");
    let xs = rng.normal_vec(400_000, 0.05);
    for (name, parallel) in [("serial", false), ("parallel", true)] {
        let mut buf = xs.clone();
        let us = common::time_median_us(reps, || {
            if parallel {
                prox::soft_threshold_parallel(&mut buf, 0.01);
            } else {
                prox::soft_threshold_inplace(&mut buf, 0.01);
            }
        });
        println!("  {name:<9} {us:>8.1} µs ({:.1} Gelem/s)", 400_000.0 / us / 1e3);
        json.row("prox_soft_threshold", name, us, "gelem_per_s", 400_000.0 / us / 1e3);
    }

    // --- conv training kernels (the native-backend LeNet path): im2col,
    // forward matmul (dense + CSR), both backward products, col2im and
    // the max-pool pair — all at the LeNet conv2 shape.
    common::section("conv kernels: LeNet conv2 (20→50 ch, 5×5, 12×12 input, B=64)");
    {
        use proxcomp::runtime::native;
        let spec = ConvSpec { stride: 1, pad: 0 };
        let (bsz, ci, o, k) = (64usize, 20usize, 50usize, 5usize);
        let (oh, ow) = (8usize, 8usize);
        let (rows, kk) = (bsz * oh * ow, ci * k * k);
        let threads = proxcomp::util::pool::max_threads();
        let x = Tensor::new(vec![bsz, ci, 12, 12], rng.normal_vec(bsz * ci * 144, 1.0));
        let w = Tensor::new(vec![o, ci, k, k], rng.normal_vec(o * kk, 0.1));
        let bias = vec![0.0f32; o];

        let us = common::time_median_us(reps, || {
            tensor::conv2d(&x, &w, &bias, spec);
        });
        println!("{:<34} {:>10.0} µs", "dense conv2d (im2col+matmul_nt)", us);
        json.row("conv_kernels", "dense_conv2d_fwd", us, "gflops", gflops(2.0 * (rows * o * kk) as f64, us));

        let us_im2col = common::time_median_us(reps, || {
            tensor::im2col(&x, k, k, spec);
        });
        println!("{:<34} {:>10.0} µs", "im2col unfold", us_im2col);
        json.row("conv_kernels", "im2col", us_im2col, "gelem_per_s", (rows * kk) as f64 / us_im2col / 1e3);

        let cols = tensor::im2col(&x, k, k, spec);
        let us_fwd = common::time_median_us(reps, || {
            native::fc_forward(&cols.data, rows, kk, &w.data, &bias, o, threads);
        });
        println!("{:<34} {:>10.0} µs", "native conv fwd matmul", us_fwd);
        let fwd_flops = 2.0 * (rows * o * kk) as f64;
        json.row("conv_kernels", "native_conv_fwd_matmul", us_fwd, "gflops", gflops(fwd_flops, us_fwd));

        // Compressed forward: the same contraction with 90%-sparse CSR
        // filters — what the serving engine runs after SpC.
        let (_, csr) = sparse_matrix(&mut rng, o, kk, 0.9);
        let us_csr = common::time_median_us(reps, || {
            ops::dxct(&cols, &csr);
        });
        println!(
            "{:<34} {:>10.0} µs ({:.2}× vs dense fwd)",
            "CSR conv fwd @ 90%", us_csr, us_fwd / us_csr
        );
        let csr_flops = 2.0 * (rows * csr.nnz()) as f64;
        json.row("conv_kernels", "csr_conv_fwd_90pct", us_csr, "gflops", gflops(csr_flops, us_csr));

        let dy = rng.normal_vec(rows * o, 1.0);
        let us_gw = common::time_median_us(reps, || {
            native::fc_grad_w(&dy, rows, o, &cols.data, kk, threads);
        });
        println!("{:<34} {:>10.0} µs", "conv weight grad (colsᵀ·dy)", us_gw);
        json.row("conv_kernels", "conv_grad_w", us_gw, "gflops", gflops(2.0 * (rows * o * kk) as f64, us_gw));

        let us_gx = common::time_median_us(reps, || {
            let dcols = native::fc_grad_x(&dy, rows, o, &w.data, kk, threads);
            tensor::col2im(&Tensor::new(vec![rows, kk], dcols), bsz, ci, 12, 12, k, k, spec);
        });
        println!("{:<34} {:>10.0} µs", "conv input grad (dy·W + col2im)", us_gx);
        json.row("conv_kernels", "conv_grad_x_col2im", us_gx, "gflops", gflops(2.0 * (rows * o * kk) as f64, us_gx));

        let conv_out = Tensor::new(vec![bsz, o, oh, ow], rng.normal_vec(bsz * o * oh * ow, 1.0));
        let us_pool = common::time_median_us(reps, || {
            tensor::max_pool(&conv_out, 2, 2);
        });
        let d_pool = Tensor::new(vec![bsz, o, oh / 2, ow / 2], rng.normal_vec(bsz * o * 16, 1.0));
        let us_poolb = common::time_median_us(reps, || {
            tensor::max_pool_backward(&conv_out, &d_pool, 2, 2);
        });
        println!("{:<34} {:>10.0} µs / {:>6.0} µs bwd", "max-pool 2×2 fwd/bwd", us_pool, us_poolb);
        json.row("conv_kernels", "max_pool_fwd", us_pool, "gelem_per_s", conv_out.numel() as f64 / us_pool / 1e3);
        json.row("conv_kernels", "max_pool_bwd", us_poolb, "gelem_per_s", conv_out.numel() as f64 / us_poolb / 1e3);
    }

    // --- format dispatch vs fixed CSR on structured matrices
    common::section("dispatch vs fixed-CSR: structure-matched formats (B=128)");
    let (rows, cols) = (512, 768);
    let d3 = Tensor::new(vec![128, cols], rng.normal_vec(128 * cols, 1.0));
    let mut banded = vec![0.0f32; rows * cols];
    for r in 0..rows {
        for off in -2i64..=2 {
            let c = r as i64 + off;
            if c >= 0 && (c as usize) < cols {
                banded[r * cols + c as usize] = rng.normal() as f32 + 2.0;
            }
        }
    }
    let mut uniform = vec![0.0f32; rows * cols];
    for r in 0..rows {
        let mut placed = 0;
        while placed < 24 {
            let c = rng.below(cols);
            if uniform[r * cols + c] == 0.0 {
                uniform[r * cols + c] = rng.normal() as f32 + 2.0;
                placed += 1;
            }
        }
    }
    let (skewed, _) = sparse_matrix(&mut rng, rows, cols, 0.97);
    let mut blocky = vec![0.0f32; rows * cols];
    let n_bc = cols / dispatch::BLOCK_W;
    for i in 0..rows / dispatch::BLOCK_H {
        for s in 0..3usize {
            let j = (i * 11 + s * 5) % n_bc;
            for y in 0..dispatch::BLOCK_H {
                for x in 0..dispatch::BLOCK_W {
                    blocky[(i * dispatch::BLOCK_H + y) * cols + j * dispatch::BLOCK_W + x] =
                        rng.normal() as f32 + 2.0;
                }
            }
        }
    }
    println!(
        "{:<22} {:>9} {:>10} {:>10} {:>9} {:>11}",
        "matrix structure", "chosen", "CSR µs", "auto µs", "speedup", "bytes ratio"
    );
    for (name, dense) in [
        ("banded (5 diags)", &banded),
        ("uniform rows (24)", &uniform),
        ("unstructured 97%", &skewed),
        ("block-sparse 8×16", &blocky),
    ] {
        let csr = CsrMatrix::from_dense(dense, rows, cols);
        let auto = DynSparseMatrix::from_dense(dense, rows, cols);
        let us_csr = common::time_median_us(reps, || {
            ops::dxct(&d3, &csr);
        });
        let us_auto = common::time_median_us(reps, || {
            auto.dxct(&d3);
        });
        println!(
            "{:<22} {:>9} {:>10.0} {:>10.0} {:>8.2}× {:>10.2}×",
            name,
            auto.format().name(),
            us_csr,
            us_auto,
            us_csr / us_auto,
            csr.storage_bytes() as f64 / auto.storage_bytes() as f64,
        );
    }

    // --- thread sweep: every format's kernel at the serving shape (B=1).
    // Fixtures are big enough (4096×4096 at 90–97% sparsity) that the
    // parallel partitions amortize the scoped-thread spawn cost; the
    // acceptance shape is parallel (≥4 threads) beating the 1-thread run
    // (the sequential PR-1 behaviour at B=1).
    common::section("thread sweep: dxct at serving shape B=1 (90–97% sparsity fixtures)");
    {
        let (rows, cols) = (4096usize, 4096usize);
        let thread_counts = [1usize, 2, 4, 8];
        // Banded fixtures for DIA at exact target sparsities.
        let banded_at = |rng: &mut Rng, density: f64| {
            let diags = ((cols as f64 * density).round() as usize).max(1);
            let mut dense = vec![0.0f32; rows * cols];
            let half = diags as i64 / 2;
            for r in 0..rows {
                for off in -half..(diags as i64 - half) {
                    let c = r as i64 + off;
                    if c >= 0 && (c as usize) < cols {
                        dense[r * cols + c as usize] = rng.normal() as f32 + 2.0;
                    }
                }
            }
            dense
        };
        // Uniform-row fixtures for ELL.
        let uniform_at = |rng: &mut Rng, density: f64| {
            let per_row = ((cols as f64 * density).round() as usize).max(1);
            let mut dense = vec![0.0f32; rows * cols];
            for r in 0..rows {
                let mut placed = 0;
                while placed < per_row {
                    let c = rng.below(cols);
                    if dense[r * cols + c] == 0.0 {
                        dense[r * cols + c] = rng.normal() as f32 + 2.0;
                        placed += 1;
                    }
                }
            }
            dense
        };
        // Dense-tile fixtures for Block-ELL.
        let blocks_at = |rng: &mut Rng, density: f64| {
            let n_bc = cols / dispatch::BLOCK_W;
            let per_row = ((n_bc as f64 * density).round() as usize).max(1);
            let mut dense = vec![0.0f32; rows * cols];
            for i in 0..rows / dispatch::BLOCK_H {
                for s in 0..per_row {
                    let j = (i * 13 + s * 7) % n_bc;
                    for y in 0..dispatch::BLOCK_H {
                        for x in 0..dispatch::BLOCK_W {
                            dense[(i * dispatch::BLOCK_H + y) * cols + j * dispatch::BLOCK_W + x] =
                                rng.normal() as f32 + 2.0;
                        }
                    }
                }
            }
            dense
        };
        let unstructured_at = |rng: &mut Rng, density: f64| {
            let mut dense = rng.normal_vec(rows * cols, 0.05);
            let t = prox::magnitude_quantile(&dense, 1.0 - density);
            prox::hard_threshold_inplace(&mut dense, t);
            dense
        };
        // Heavy-tailed fixture (EIE's load-imbalance case): ~100 dense
        // rows at the front carry half the nonzeros; an equal-row-count
        // partition serializes on whichever thread draws them, the
        // nnz-prefix partition keeps speedup near the thread count.
        let skewed_rows_at = |rng: &mut Rng, heavy_rows: usize, heavy_d: f64, tail_d: f64| {
            let mut dense = vec![0.0f32; rows * cols];
            for r in 0..rows {
                let density = if r < heavy_rows { heavy_d } else { tail_d };
                let per_row = ((cols as f64 * density).round() as usize).max(1);
                let mut placed = 0;
                while placed < per_row {
                    let c = rng.below(cols);
                    if dense[r * cols + c] == 0.0 {
                        dense[r * cols + c] = rng.normal() as f32 + 2.0;
                        placed += 1;
                    }
                }
            }
            dense
        };
        let d1 = Tensor::new(vec![1, cols], rng.normal_vec(cols, 1.0));
        println!(
            "{:<26} {:>9} {:>9} {:>9} {:>9} {:>11}",
            "fixture → format", "t=1 µs", "t=2 µs", "t=4 µs", "t=8 µs", "t=4 speedup"
        );
        let mut sweep: Vec<(String, DynSparseMatrix)> = Vec::new();
        for density in [0.10f64, 0.03] {
            let pct = 100.0 - density * 100.0;
            let dia = banded_at(&mut rng, density);
            sweep.push((
                format!("banded {pct:.0}% → DIA"),
                DynSparseMatrix::from_dense_as(SparseFormat::Dia, &dia, rows, cols),
            ));
            let ell = uniform_at(&mut rng, density);
            sweep.push((
                format!("uniform {pct:.0}% → ELL"),
                DynSparseMatrix::from_dense_as(SparseFormat::Ell, &ell, rows, cols),
            ));
            let bell = blocks_at(&mut rng, density);
            sweep.push((
                format!("blocks {pct:.0}% → BlockELL"),
                DynSparseMatrix::from_dense_as(SparseFormat::BlockEll, &bell, rows, cols),
            ));
            let unstructured = unstructured_at(&mut rng, density);
            sweep.push((
                format!("random {pct:.0}% → CSR"),
                DynSparseMatrix::from_dense_as(SparseFormat::Csr, &unstructured, rows, cols),
            ));
            sweep.push((
                format!("random {pct:.0}% → COO"),
                DynSparseMatrix::from_dense_as(SparseFormat::Coo, &unstructured, rows, cols),
            ));
        }
        let skewed_dense = skewed_rows_at(&mut rng, 100, 0.5, 0.0125);
        sweep.push((
            "skewed 97% → CSR".to_string(),
            DynSparseMatrix::from_dense_as(SparseFormat::Csr, &skewed_dense, rows, cols),
        ));
        for (name, m) in &sweep {
            let us: Vec<f64> = thread_counts
                .iter()
                .map(|&t| {
                    common::time_median_us(reps, || {
                        m.dxct_threads(&d1, t);
                    })
                })
                .collect();
            println!(
                "{:<26} {:>9.0} {:>9.0} {:>9.0} {:>9.0} {:>10.2}×",
                name,
                us[0],
                us[1],
                us[2],
                us[3],
                us[0] / us[2]
            );
            json.row("thread_sweep_b1", name, us[2], "t4_speedup", us[0] / us[2]);
        }
    }

    // --- blocked vs scalar kernel families at a single thread: the
    // 8-lane accumulator rewrite against the pre-blocking sequential
    // kernels (kept as `*_scalar_*`), at the serving shape on the
    // 90–97% fixtures. This group seeds the `bench-compare` gate.
    common::section("blocked vs scalar kernels: t=1, 4096×4096 @ 90–97% sparsity");
    {
        use proxcomp::quant::{QcsMatrix, QuantConfig};
        use proxcomp::util::pool::{kernel_mode, KernelMode};
        anyhow::ensure!(
            kernel_mode() == KernelMode::Blocked,
            "bench_kernels needs the default PROXCOMP_KERNEL so the blocked_kernels \
             group measures the blocked family — unset PROXCOMP_KERNEL=scalar"
        );
        let (rows, cols) = (4096usize, 4096usize);
        let x: Vec<f32> = rng.normal_vec(cols, 1.0);
        let d1 = Tensor::new(vec![1, cols], rng.normal_vec(cols, 1.0));
        println!("{:<26} {:>11} {:>12} {:>9}", "kernel (t=1)", "scalar µs", "blocked µs", "speedup");
        for rate in [0.9, 0.97] {
            let pct = rate * 100.0;
            let (_, csr) = sparse_matrix(&mut rng, rows, cols, rate);
            let us_s = common::time_median_us(reps, || {
                ops::spmv_scalar_threads(&csr, &x, 1);
            });
            let us_b = common::time_median_us(reps, || {
                ops::spmv_threads(&csr, &x, 1);
            });
            println!(
                "{:<26} {:>11.0} {:>12.0} {:>8.2}×",
                format!("CSR spmv @ {pct:.0}%"),
                us_s,
                us_b,
                us_s / us_b
            );
            json.row("blocked_kernels", &format!("csr_spmv_b1_{pct:.0}pct"), us_b, "speedup_vs_scalar", us_s / us_b);
            let us_ds = common::time_median_us(reps, || {
                ops::dxct_scalar_threads(&d1, &csr, 1);
            });
            let us_db = common::time_median_us(reps, || {
                ops::dxct_threads(&d1, &csr, 1);
            });
            println!(
                "{:<26} {:>11.0} {:>12.0} {:>8.2}×",
                format!("CSR dxct B=1 @ {pct:.0}%"),
                us_ds,
                us_db,
                us_ds / us_db
            );
            json.row("blocked_kernels", &format!("csr_dxct_b1_{pct:.0}pct"), us_db, "speedup_vs_scalar", us_ds / us_db);
        }
        // QCS spmv under both families via the env knob (read per call).
        let (_, csr97) = sparse_matrix(&mut rng, rows, cols, 0.97);
        let (qcs, _) = QcsMatrix::from_csr(&csr97, &QuantConfig::default());
        std::env::set_var("PROXCOMP_KERNEL", "scalar");
        let us_qs = common::time_median_us(reps, || {
            qcs.spmv_threads(&x, 1);
        });
        std::env::remove_var("PROXCOMP_KERNEL");
        let us_qb = common::time_median_us(reps, || {
            qcs.spmv_threads(&x, 1);
        });
        println!(
            "{:<26} {:>11.1} {:>12.1} {:>8.2}×",
            "QCS spmv @ 97%",
            us_qs,
            us_qb,
            us_qs / us_qb
        );
        json.row("blocked_kernels", "qcs_spmv_b1_97pct", us_qb, "speedup_vs_scalar", us_qs / us_qb);
    }

    // --- batch sweep: request coalescing payoff on the CSR serving path
    common::section("batch sweep: CSR dxct, 97% sparse 4096×4096, max threads");
    {
        let (rows, cols) = (4096usize, 4096usize);
        let (_, csr97) = sparse_matrix(&mut rng, rows, cols, 0.97);
        println!("{:<10} {:>10} {:>14} {:>14}", "batch", "µs", "samples/s", "µs/sample");
        for b in [1usize, 4, 16, 64] {
            let db = Tensor::new(vec![b, cols], rng.normal_vec(b * cols, 1.0));
            let us = common::time_median_us(reps, || {
                ops::dxct(&db, &csr97);
            });
            println!("{:<10} {:>10.0} {:>14.0} {:>14.1}", b, us, b as f64 / (us * 1e-6), us / b as f64);
        }
    }

    // --- quantized serving kernels: QcsMatrix vs CSR at the paper's
    // sparsity operating points (the PR-5 perf-trajectory group).
    common::section("quant kernels: QCS vs CSR dxct/spmv, 500×800 @ 90–97% sparsity");
    {
        use proxcomp::quant::{QcsMatrix, QuantConfig};
        let (n, k) = (500usize, 800usize);
        let d128 = Tensor::new(vec![128, k], rng.normal_vec(128 * k, 1.0));
        let x1: Vec<f32> = rng.normal_vec(k, 1.0);
        println!(
            "{:<26} {:>10} {:>10} {:>9} {:>12}",
            "kernel", "CSR µs", "QCS µs", "speedup", "bytes ratio"
        );
        for rate in [0.9, 0.97] {
            let pct = rate * 100.0;
            let (_, csr) = sparse_matrix(&mut rng, n, k, rate);
            let (qcs, stats) = QcsMatrix::from_csr(&csr, &QuantConfig::default());
            let bytes_ratio = csr.storage_bytes() as f64 / qcs.storage_bytes() as f64;
            let flops = 2.0 * (128 * csr.nnz()) as f64;

            let us_csr = common::time_median_us(reps, || {
                ops::dxct(&d128, &csr);
            });
            let us_qcs = common::time_median_us(reps, || {
                qcs.dxct(&d128);
            });
            println!(
                "{:<26} {:>10.0} {:>10.0} {:>8.2}× {:>11.2}×   (rmse {:.5})",
                format!("dxct B=128 @ {pct:.0}%"),
                us_csr,
                us_qcs,
                us_csr / us_qcs,
                bytes_ratio,
                stats.rmse
            );
            json.row("quant_kernels", &format!("csr_dxct_b128_{pct:.0}pct"), us_csr, "gflops", gflops(flops, us_csr));
            json.row("quant_kernels", &format!("qcs_dxct_b128_{pct:.0}pct"), us_qcs, "gflops", gflops(flops, us_qcs));
            json.metric("quant_kernels", &format!("qcs_bytes_ratio_{pct:.0}pct"), "csr_over_qcs_bytes", bytes_ratio);

            let us_csr1 = common::time_median_us(reps, || {
                ops::spmv(&csr, &x1);
            });
            let us_qcs1 = common::time_median_us(reps, || {
                qcs.spmv(&x1);
            });
            println!(
                "{:<26} {:>10.1} {:>10.1} {:>8.2}×",
                format!("spmv  B=1   @ {pct:.0}%"),
                us_csr1,
                us_qcs1,
                us_csr1 / us_qcs1
            );
            json.row("quant_kernels", &format!("csr_spmv_b1_{pct:.0}pct"), us_csr1, "gflops", gflops(2.0 * csr.nnz() as f64, us_csr1));
            json.row("quant_kernels", &format!("qcs_spmv_b1_{pct:.0}pct"), us_qcs1, "gflops", gflops(2.0 * csr.nnz() as f64, us_qcs1));
        }
    }

    // --- telemetry overhead: the serving forward with per-layer
    // profiling always on, structured tracing off vs on. The
    // observability contract is "near-zero cost disabled, bounded cost
    // enabled" — enforce the enabled side staying under 5% on the
    // lenet-s conv forward (the shape `proxcomp serve` runs).
    common::section("telemetry overhead: lenet-s forward B=4, tracing off vs on");
    {
        use proxcomp::inference::{Engine, WeightMode};
        use proxcomp::runtime::{Manifest, ParamBundle};

        let manifest = Manifest::native();
        let entry = manifest
            .model("lenet-s")
            .ok_or_else(|| anyhow::anyhow!("native manifest lost lenet-s"))?;
        let mut bundle = ParamBundle::he_init(&entry.params, 17);
        for (s, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
            if s.prunable {
                prox::soft_threshold_inplace(v, 0.05);
            }
        }
        let engine = Engine::builder("lenet-s").bundle(&bundle).mode(WeightMode::Csr).build()?;
        let (ci, h, w) = (entry.input_shape[0], entry.input_shape[1], entry.input_shape[2]);
        let x = Tensor::new(vec![4, ci, h, w], rng.normal_vec(4 * ci * h * w, 1.0));

        engine.forward(&x)?; // warm both arms through the same caches
        let treps = reps.max(40); // medians tight enough for a 5% budget
        let us_off = common::time_median_us(treps, || {
            engine.forward(&x).unwrap();
        });
        let trace_path = std::env::temp_dir().join("proxcomp_bench_trace.jsonl");
        proxcomp::telemetry::enable_trace(&trace_path)?;
        let us_on = common::time_median_us(treps, || {
            engine.forward(&x).unwrap();
        });
        let events = proxcomp::telemetry::disable_trace();
        let _ = std::fs::remove_file(&trace_path);
        let ratio = us_on / us_off;
        println!(
            "forward B=4: {us_off:.0} µs tracing off, {us_on:.0} µs on ({ratio:.3}×, {events} events)"
        );
        json.row("telemetry_overhead", "forward_trace_off", us_off, "ratio_vs_off", 1.0);
        json.row("telemetry_overhead", "forward_trace_on", us_on, "ratio_vs_off", ratio);
        anyhow::ensure!(
            ratio < 1.05,
            "tracing overhead {:.1}% exceeds the 5% budget ({us_off:.0} µs → {us_on:.0} µs)",
            (ratio - 1.0) * 100.0
        );
    }

    // --- Figure-1 format storage comparison on a prox-trained-style matrix
    common::section("Figure 1 formats: storage on a 97%-sparse 500×800 weight matrix");
    let (dense, csr) = sparse_matrix(&mut rng, 500, 800, 0.97);
    let coo = CooMatrix::from_dense(&dense, 500, 800);
    let ell = EllMatrix::from_dense(&dense, 500, 800);
    let dia = DiaMatrix::from_dense(&dense, 500, 800);
    println!("{:<8} {:>12} {:>10}", "format", "bytes", "vs dense");
    let dense_bytes = 500 * 800 * 4;
    for (name, bytes) in [
        ("dense", dense_bytes),
        ("CSR", csr.storage_bytes()),
        ("COO", coo.storage_bytes()),
        ("ELL", ell.storage_bytes()),
        ("DIA", dia.storage_bytes()),
    ] {
        println!("{:<8} {:>12} {:>9.2}×", name, bytes, dense_bytes as f64 / bytes as f64);
    }
    println!(
        "\npaper Section 3.1 ordering (CSR < COO ≪ ELL/DIA for unstructured): {}",
        if csr.storage_bytes() < coo.storage_bytes()
            && coo.storage_bytes() < ell.storage_bytes()
            && coo.storage_bytes() < dia.storage_bytes()
        {
            "HOLDS"
        } else {
            "DOES NOT HOLD"
        }
    );
    json.write(
        "bench_kernels.json",
        &[
            "dxct_forward",
            "prox_soft_threshold",
            "conv_kernels",
            "thread_sweep_b1",
            "blocked_kernels",
            "quant_kernels",
            "telemetry_overhead",
        ],
    )?;
    Ok(())
}
