//! Shared helpers for the bench harness (no criterion offline — each
//! bench is a `harness = false` binary that prints the paper's rows and
//! writes JSON/CSV under `reports/`).
//!
//! Scaling: benches default to testbed-sized runs (minutes, not hours).
//! `PROXCOMP_BENCH_SCALE` multiplies step counts (e.g. `=4` for longer,
//! more paper-faithful curves); `PROXCOMP_BENCH_MODELS` overrides the
//! model list (e.g. `=lenet,vgg_s`).

#![allow(dead_code)]

use proxcomp::config::RunConfig;
use proxcomp::metrics::RunResult;
use proxcomp::util::json::Json;

/// Step-count multiplier from the environment.
pub fn scale() -> f64 {
    std::env::var("PROXCOMP_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse::<f64>().ok())
        .unwrap_or(1.0)
        .max(0.05)
}

pub fn scaled(steps: usize) -> usize {
    ((steps as f64 * scale()).round() as usize).max(10)
}

/// Models to bench (default: the fast pair; set
/// `PROXCOMP_BENCH_MODELS=mlp,lenet,alexnet_s,vgg_s,resnet_s` for all).
pub fn bench_models(default: &[&str]) -> Vec<String> {
    match std::env::var("PROXCOMP_BENCH_MODELS") {
        Ok(v) => v.split(',').filter(|s| !s.is_empty()).map(String::from).collect(),
        Err(_) => default.iter().map(|s| s.to_string()).collect(),
    }
}

/// Baseline per-model run configuration tuned for the CPU testbed: short
/// but long enough that SpC separates from Pru and curves are non-trivial.
pub fn base_config(model: &str) -> RunConfig {
    let mut cfg = RunConfig {
        model: model.to_string(),
        train_examples: 2048,
        test_examples: 512,
        ..RunConfig::default()
    };
    match model {
        "mlp" => {
            cfg.steps = scaled(150);
            cfg.lr = 1e-3;
            cfg.lambda = 0.4;
        }
        "lenet" => {
            cfg.steps = scaled(150);
            cfg.lr = 2e-3;
            cfg.lambda = 0.4;
        }
        "alexnet_s" | "vgg_s" | "resnet_s" => {
            cfg.steps = scaled(80);
            cfg.lr = 1e-3;
            cfg.lambda = 0.1;
            cfg.train_examples = 1024;
            cfg.test_examples = 256;
        }
        _ => {}
    }
    cfg.retrain_lr = cfg.lr * 0.1;
    cfg
}

/// λ grid per model (paper Figure 6 sweeps λ around the accuracy knee).
pub fn lambda_grid(model: &str) -> Vec<f32> {
    match model {
        "mlp" => vec![0.0, 0.05, 0.1, 0.2, 0.4, 0.8],
        "lenet" => vec![0.0, 0.1, 0.2, 0.4, 0.8, 1.2],
        _ => vec![0.0, 0.025, 0.05, 0.1, 0.25, 0.5],
    }
}

/// MM hyperparameters (ℓ0-constraint C-step; the target rate plays the
/// role of the paper's κ). μ ramps ×1.5 per C-step with the L-step rate
/// decaying as 1/(1+lr·μ) — the LC reference schedule.
pub fn mm_config(cfg: &mut RunConfig) {
    cfg.pru_target_rate = 0.9;
    cfg.mm_mu0 = 0.1;
    cfg.mm_mu_growth = 1.5;
    cfg.mm_compress_every = (cfg.steps / 16).max(5);
    cfg.lr = 0.02; // SGD-momentum L-step rate
}

/// Pretty separator + section header.
pub fn section(title: &str) {
    println!("\n{}", "=".repeat(74));
    println!("{title}");
    println!("{}", "=".repeat(74));
}

/// One result row in the shared table format.
pub fn result_row(r: &RunResult) {
    println!(
        "{:<14} {:<10} λ/rate {:<8.3} acc {:<7.4} comp {:<7.4} ({:>4.0}×) nnz {:>9} [{:.0}s]",
        r.method, r.model, r.lambda, r.accuracy, r.compression_rate, r.times_factor(), r.nnz, r.wall_secs
    );
}

/// Write results as a JSON report.
pub fn write_results(name: &str, results: &[RunResult]) {
    let arr = Json::Arr(results.iter().map(|r| r.to_json()).collect());
    match proxcomp::metrics::write_json_report(name, &arr) {
        Ok(p) => println!("[report] wrote {}", p.display()),
        Err(e) => eprintln!("[report] failed: {e}"),
    }
}

/// Simple wallclock measurement helper: median of `reps` runs in µs.
pub fn time_median_us<F: FnMut()>(reps: usize, mut f: F) -> f64 {
    let mut samples = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = std::time::Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64() * 1e6);
    }
    proxcomp::util::stats::median(&samples)
}

/// Repetition count from the environment (`PROXCOMP_BENCH_REPS`), so CI
/// smoke runs can dial measurement cost down without touching code.
pub fn reps(default: usize) -> usize {
    std::env::var("PROXCOMP_BENCH_REPS")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .unwrap_or(default)
        .max(1)
}

/// Machine-readable bench summary: `(section, name, µs, metric)` rows
/// accumulated during a run and written as one JSON report — the
/// artifact the CI perf-trajectory step (`BENCH_PR<n>.json`) uploads and
/// the `bench-compare` gate consumes.
///
/// Validation is strict because the gate trusts this file: a panic or a
/// broken timer must yield *no* report (nonzero bench exit) rather than
/// a partial JSON the gate would happily accept. Timed rows reject
/// non-finite / non-positive timings and NaN metrics at insertion;
/// [`BenchJson::write`] is fallible and checks that every expected
/// section actually emitted rows.
pub struct BenchJson {
    rows: Vec<Json>,
    errors: Vec<String>,
}

impl BenchJson {
    pub fn new() -> BenchJson {
        BenchJson { rows: Vec::new(), errors: Vec::new() }
    }

    /// Record one measurement. `metric` is the row's headline derived
    /// number (GFLOP/s, speedup, …) under the given label.
    pub fn row(&mut self, section: &str, name: &str, us: f64, metric_name: &str, metric: f64) {
        if !(us.is_finite() && us > 0.0) {
            self.errors.push(format!("row {section}/{name}: invalid median_us {us}"));
            return;
        }
        if !metric.is_finite() {
            self.errors.push(format!("row {section}/{name}: non-finite {metric_name} {metric}"));
            return;
        }
        let mut j = Json::obj();
        j.set("section", Json::from(section))
            .set("name", Json::from(name))
            .set("median_us", Json::from(us))
            .set(metric_name, Json::from(metric));
        self.rows.push(j);
    }

    /// Record a timing-free derived metric (storage ratios and the like).
    /// No `median_us` key, so the perf gate never treats it as a timing.
    pub fn metric(&mut self, section: &str, name: &str, metric_name: &str, metric: f64) {
        if !metric.is_finite() {
            self.errors.push(format!("row {section}/{name}: non-finite {metric_name} {metric}"));
            return;
        }
        let mut j = Json::obj();
        j.set("section", Json::from(section))
            .set("name", Json::from(name))
            .set(metric_name, Json::from(metric));
        self.rows.push(j);
    }

    /// Write the accumulated rows to `reports/<name>`, failing (so the
    /// bench binary exits nonzero) when any row was invalid or any of
    /// `expect_sections` never produced a row — both are the
    /// partial-run symptoms the CI gate must not mistake for a pass.
    pub fn write(self, name: &str, expect_sections: &[&str]) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.errors.is_empty(),
            "bench produced invalid rows:\n  {}",
            self.errors.join("\n  ")
        );
        for want in expect_sections {
            let found = self.rows.iter().any(|r| {
                r.get("section").and_then(|s| s.as_str()) == Some(*want)
            });
            anyhow::ensure!(found, "bench section {want:?} emitted no rows — partial run?");
        }
        let p = proxcomp::metrics::write_json_report(name, &Json::Arr(self.rows))?;
        println!("[report] wrote {}", p.display());
        Ok(())
    }
}
