//! Figure 5 — Prox-RMSProp vs Prox-ADAM seed variance.
//!
//! The paper trains VGGNet/CIFAR-10 multiple times with different random
//! seeds and finds Prox-ADAM "produced more stable trained models in
//! terms of test accuracy and compression rate" (smaller scatter) than
//! Prox-RMSProp. We regenerate the scatter for each benched model: N
//! seeds × {Prox-RMSProp, Prox-ADAM}, reporting per-optimizer mean ± std
//! of test accuracy and compression rate.
//!
//! Paper expectation: std(Prox-ADAM) < std(Prox-RMSProp) on both axes.
//!
//! Default models: mlp, lenet (set PROXCOMP_BENCH_MODELS=vgg_s for the
//! paper's exact network — slower).

#[path = "common.rs"]
mod common;

use proxcomp::config::Optimizer;
use proxcomp::coordinator::sweep;
use proxcomp::runtime::{Manifest, Runtime};
use proxcomp::util::stats;

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let mut rt = Runtime::cpu()?;
    let seeds: Vec<u64> = (0..4).collect();

    common::section("Figure 5: Prox-RMSProp vs Prox-ADAM seed variance");
    let mut all = Vec::new();
    for model in common::bench_models(&["mlp", "lenet"]) {
        println!("\n--- {model}, seeds {seeds:?} ---");
        println!(
            "{:<14} {:>9} {:>9} {:>11} {:>11}",
            "optimizer", "acc mean", "acc std", "rate mean", "rate std"
        );
        let mut rows = Vec::new();
        for opt in [Optimizer::ProxRmsprop, Optimizer::ProxAdam] {
            let mut cfg = common::base_config(&model);
            cfg.optimizer = opt;
            let results = sweep::seed_sweep(&mut rt, &manifest, &cfg, &seeds)?;
            let accs: Vec<f64> = results.iter().map(|r| r.accuracy).collect();
            let rates: Vec<f64> = results.iter().map(|r| r.compression_rate).collect();
            println!(
                "{:<14} {:>9.4} {:>9.4} {:>11.4} {:>11.4}",
                opt.step_name(),
                stats::mean(&accs),
                stats::std_dev(&accs),
                stats::mean(&rates),
                stats::std_dev(&rates)
            );
            rows.push((opt, stats::std_dev(&accs), stats::std_dev(&rates)));
            all.extend(results);
        }
        // The paper's claim, checked on our scatter:
        let (_, rms_acc_std, rms_rate_std) = rows[0];
        let (_, adam_acc_std, adam_rate_std) = rows[1];
        let holds = adam_acc_std <= rms_acc_std || adam_rate_std <= rms_rate_std;
        println!(
            "paper claim (Prox-ADAM stabler): {}",
            if holds { "HOLDS" } else { "DOES NOT HOLD on this scatter (N=4 seeds)" }
        );
    }
    common::write_results("bench_fig5_variance.json", &all);
    Ok(())
}
