//! Table 3 — inference speedups by model compression (Lenet-5).
//!
//! The paper reports, for Lenet-5/MNIST:
//!
//! | GPU            | GTX 1080 Ti       | ARM Mali-T860      |
//! | Compression    | Yes    | No       | Yes     | No       |
//! | Model size     | 148 KB | 5.0 MB   | 148 KB  | 5.0 MB   |
//! | Inference time | 8572ms | 16977ms  | 506067ms| 606699ms |
//! | Speedup        | 1.98×  |          | 1.20×   |          |
//!
//! We regenerate the table twice (DESIGN.md §4 substitution):
//! 1. **measured** — the rust CSR engine vs the dense engine on this
//!    host (real wallclock, the honest number), and
//! 2. **modeled** — the roofline device model with Mali-T860 and
//!    GTX 1080 Ti parameters (the paper's devices).
//!
//! The shape to reproduce: compressed model ~30× smaller but only
//! ~1.2-2× faster, because irregular sparsity runs at low efficiency.

#[path = "common.rs"]
mod common;

use std::sync::Arc;
use std::time::Duration;

use proxcomp::config::RunConfig;
use proxcomp::coordinator::{trainer::StepScalars, Trainer};
use proxcomp::data;
use proxcomp::device::{estimate_speedup, DeviceModel, GTX_1080TI, MALI_T860};
use proxcomp::inference::{BatchConfig, BatchServer, Engine, WeightMode};
use proxcomp::runtime::{Manifest, ParamBundle, ParamSpec, Runtime};
use proxcomp::sparse::prox;
use proxcomp::tensor::Tensor;
use proxcomp::util::rng::Rng;

fn train_compressed_lenet(rt: &mut Runtime, manifest: &Manifest) -> anyhow::Result<ParamBundle> {
    // SpC + debias to the paper's Table-3 operating point: λ high enough
    // that the *conv* layers also compress hard (paper Table A1: conv1
    // ~70%, conv2 ~93%) — the Mali-T860 balance depends on it.
    let cfg = RunConfig {
        model: "lenet".into(),
        lambda: 0.8,
        lr: 2e-3,
        steps: common::scaled(250),
        train_examples: 4096,
        test_examples: 512,
        ..RunConfig::default()
    };
    let mut trainer = Trainer::new(manifest, &cfg)?;
    let scalars = StepScalars { lambda: cfg.lambda, lr: cfg.lr, mu: 0.0 };
    trainer.run_steps(rt, "train_prox_adam", cfg.steps, scalars, 0)?;
    proxcomp::compress::debias::retrain(rt, &mut trainer, common::scaled(60), 2e-4)?;
    for (layer, nnz, total) in trainer.state.params.layer_stats() {
        println!("  {layer:<8} {:.1}% compressed", 100.0 * (1.0 - nnz as f64 / total as f64));
    }
    Ok(trainer.state.params)
}

/// Synthetic 97%-sparse MLP bundle (manifest shapes) for the serving
/// sweeps — lets this bench's serving groups run without AOT artifacts.
fn synthetic_sparse_mlp(seed: u64, rate: f64) -> ParamBundle {
    let specs = vec![
        ParamSpec::new("fc1_w", "fc_w", vec![256, 784], true),
        ParamSpec::new("fc1_b", "fc_b", vec![256], false),
        ParamSpec::new("fc2_w", "fc_w", vec![128, 256], true),
        ParamSpec::new("fc2_b", "fc_b", vec![128], false),
        ParamSpec::new("fc3_w", "fc_w", vec![10, 128], true),
        ParamSpec::new("fc3_b", "fc_b", vec![10], false),
    ];
    let mut bundle = ParamBundle::he_init(&specs, seed);
    for (s, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
        if s.prunable {
            let t = prox::magnitude_quantile(v, rate);
            prox::hard_threshold_inplace(v, t);
        }
    }
    bundle
}

/// Serving sweeps: thread-count × batch-size forward throughput, then the
/// `BatchServer` coalescing path under concurrent clients. Runs offline
/// (synthetic weights — no AOT artifacts needed).
fn serving_sweeps() -> anyhow::Result<()> {
    let mut rng = Rng::new(400);
    let bundle = synthetic_sparse_mlp(401, 0.97);
    let engine = Arc::new(Engine::builder("mlp").bundle(&bundle).mode(WeightMode::Csr).build()?);

    common::section("serving sweep: PROXCOMP_THREADS × batch (97% sparse MLP, CSR engine)");
    let saved_threads = std::env::var("PROXCOMP_THREADS").ok();
    println!("{:<9} {:>9} {:>12} {:>12} {:>12}", "threads", "batch", "µs/forward", "samples/s", "µs/sample");
    for threads in [1usize, 2, 4, 8] {
        std::env::set_var("PROXCOMP_THREADS", threads.to_string());
        for batch in [1usize, 8, 64] {
            let x = Tensor::new(vec![batch, 1, 28, 28], rng.normal_vec(batch * 784, 1.0));
            engine.forward(&x)?; // warmup
            let us = common::time_median_us(20, || {
                engine.forward(&x).unwrap();
            });
            println!(
                "{:<9} {:>9} {:>12.0} {:>12.0} {:>12.1}",
                threads,
                batch,
                us,
                batch as f64 / (us * 1e-6),
                us / batch as f64
            );
        }
    }
    match saved_threads {
        Some(v) => std::env::set_var("PROXCOMP_THREADS", v),
        None => std::env::remove_var("PROXCOMP_THREADS"),
    }

    common::section("BatchServer: coalescing micro-batches under 4 concurrent clients");
    println!(
        "{:<22} {:>9} {:>9} {:>11} {:>13} {:>11}",
        "max_batch / max_wait", "batches", "mean", "mean lat µs", "fwd µs/batch", "req/s"
    );
    for (max_batch, wait_ms) in [(1usize, 0u64), (8, 2), (32, 2)] {
        let server = BatchServer::start(
            Arc::clone(&engine),
            BatchConfig::new(max_batch, Duration::from_millis(wait_ms), (1, 28, 28)),
        );
        let per_client = 128usize;
        std::thread::scope(|scope| {
            for c in 0..4u64 {
                let server = &server;
                let sample = {
                    let mut r = Rng::new(500 + c);
                    r.normal_vec(784, 1.0)
                };
                scope.spawn(move || {
                    for _ in 0..per_client {
                        server.infer(&sample).unwrap();
                    }
                });
            }
        });
        let stats = server.stats();
        println!(
            "{:<22} {:>9} {:>9.1} {:>11.0} {:>13.0} {:>11.0}",
            format!("{max_batch} / {wait_ms} ms"),
            stats.batches,
            stats.mean_batch,
            stats.mean_latency_us,
            stats.mean_forward_us,
            stats.throughput_rps
        );
    }
    Ok(())
}

fn main() -> anyhow::Result<()> {
    serving_sweeps()?;

    let manifest = match Manifest::load("artifacts") {
        Ok(m) => m,
        Err(e) => {
            println!("\n[skip] trained Table-3 section needs AOT artifacts (`make artifacts`): {e}");
            return Ok(());
        }
    };
    let mut rt = Runtime::cpu()?;

    common::section("Table 3: inference speedups by model compression (Lenet-5)");
    let params = train_compressed_lenet(&mut rt, &manifest)?;
    println!("trained LeNet-5 at compression rate {:.4}", params.compression_rate());

    let dense = Engine::builder("lenet").bundle(&params).mode(WeightMode::Dense).build()?;
    let sparse = Engine::builder("lenet").bundle(&params).mode(WeightMode::Csr).build()?;
    let auto = Engine::builder("lenet").bundle(&params).mode(WeightMode::Auto).build()?;

    // --- model size row
    println!("\nmodel size:");
    println!("  compressed {:>7.1} KB   dense {:>7.1} KB   ({:.0}× smaller)",
        sparse.model_size_bytes() as f64 / 1024.0,
        dense.model_size_bytes() as f64 / 1024.0,
        dense.model_size_bytes() as f64 / sparse.model_size_bytes() as f64,
    );
    println!("  paper:     148 KB vs 5.0 MB (34×)");
    println!(
        "  dispatch   {:>7.1} KB — per-layer formats: {}",
        auto.model_size_bytes() as f64 / 1024.0,
        auto.layer_formats()
            .iter()
            .map(|(l, f)| format!("{l}:{f}"))
            .collect::<Vec<_>>()
            .join(" ")
    );

    // --- measured on this host
    let test = data::generate("synth-mnist", 512, 99)?;
    println!("\nmeasured (rust engines, this host), batched inference over {} images:", test.n);
    println!("{:<14} {:>12} {:>14}", "engine", "total ms", "images/s");
    let mut times = [0.0f64; 3];
    for (i, (name, engine)) in [("dense", &dense), ("compressed", &sparse), ("dispatch", &auto)]
        .iter()
        .enumerate()
    {
        // Warmup + 3 reps, take the best (steady-state cache behaviour).
        let mut xs = Vec::with_capacity(test.n * 784);
        for j in 0..test.n {
            xs.extend_from_slice(test.image(j));
        }
        let x = Tensor::new(vec![test.n, 1, 28, 28], xs);
        engine.forward(&x)?;
        let us = common::time_median_us(3, || {
            engine.forward(&x).unwrap();
        });
        times[i] = us / 1e3;
        println!("{:<14} {:>12.1} {:>14.0}", name, us / 1e3, test.n as f64 / (us / 1e6));
    }
    println!("measured speedup: {:.2}×   (paper: 1.98× desktop, 1.20× embedded)", times[0] / times[1]);
    println!("dispatch vs fixed-CSR: {:.2}×", times[1] / times[2]);

    // --- modeled on the paper's devices (batch 64, the steady-state
    // regime the paper's whole-test-set timings reflect)
    println!("\nmodeled (roofline device model, batch 64):");
    println!("{:<20} {:>13} {:>13} {:>9}", "device", "dense ms", "compressed ms", "speedup");
    let dense_work = dense.work_profile(64, 1, 28, 28);
    let sparse_work = sparse.work_profile(64, 1, 28, 28);
    for dev in [&GTX_1080TI as &DeviceModel, &MALI_T860] {
        let est = estimate_speedup(dev, &dense, &sparse, &dense_work, &sparse_work);
        println!(
            "{:<20} {:>13.4} {:>13.4} {:>8.2}×",
            est.device,
            est.dense_seconds * 1e3,
            est.sparse_seconds * 1e3,
            est.speedup()
        );
    }
    println!("\npaper speedups: GTX 1080 Ti 1.98×, Mali-T860 1.20×");
    println!(
        "shape check: speedup far below the ~{:.0}× size reduction on every\n\
         device (irregular sparsity runs at low kernel efficiency) — the\n\
         paper's closing observation.",
        dense.model_size_bytes() as f64 / sparse.model_size_bytes() as f64
    );

    // Accuracy parity (compression must not corrupt the model).
    let acc_d = dense.accuracy(&test, 128)?;
    let acc_s = sparse.accuracy(&test, 128)?;
    let acc_a = auto.accuracy(&test, 128)?;
    println!("\naccuracy parity: dense {acc_d:.4} vs compressed {acc_s:.4} vs dispatch {acc_a:.4}");
    assert!((acc_d - acc_s).abs() < 1e-9, "CSR engine must be numerically identical");
    // Dispatch may reorder float accumulation per format; predictions must
    // still agree to well under a percent.
    assert!((acc_d - acc_a).abs() < 5e-3, "dispatch engine accuracy drifted: {acc_d} vs {acc_a}");
    Ok(())
}
