//! Tables A1-A4 — layer-wise compression rates for SpC and SpC(Retrain).
//!
//! The paper's appendix reports, per layer of each network, NNZ / total
//! weights and the compression factor, at the λ that keeps ≥99% of the
//! reference accuracy. Two qualitative shapes to reproduce:
//!
//! * layers near the input and output compress *less* than the middle
//!   layers (paper: "one could use such information to redesign the
//!   architecture");
//! * the large FC layers dominate the compression budget.
//!
//! LeNet-5 runs at the paper's exact layer sizes (Table A1: 500 / 25,000
//! / 400,000 / 5,000 weights).

#[path = "common.rs"]
mod common;

use proxcomp::config::Method;
use proxcomp::coordinator::sweep;
use proxcomp::metrics::RunResult;
use proxcomp::runtime::{Manifest, Runtime};

fn print_table(r: &RunResult) {
    println!("\n{} @ λ={} (accuracy {:.4})", r.method, r.lambda, r.accuracy);
    println!("{:<12} {:>11} {:>12} {:>9} {:>7}", "layer", "NNZ", "total", "rate", "factor");
    for (layer, nnz, total) in &r.layer_stats {
        let rate = 1.0 - *nnz as f64 / *total as f64;
        let factor = if *nnz > 0 { *total as f64 / *nnz as f64 } else { f64::INFINITY };
        println!("{:<12} {:>11} {:>12} {:>8.2}% {:>6.0}×", layer, nnz, total, rate * 100.0, factor);
    }
    println!(
        "{:<12} {:>11} {:>12} {:>8.2}% {:>6.0}×",
        "Total", r.nnz, r.total_weights, r.compression_rate * 100.0, r.times_factor()
    );
}

/// Middle layers should compress at least as much as the boundary layers
/// (paper: "layers near the input and the output are compressed less").
fn boundary_effect(r: &RunResult) -> bool {
    if r.layer_stats.len() < 3 {
        return true;
    }
    let rate = |i: usize| {
        let (_, nnz, total) = &r.layer_stats[i];
        1.0 - *nnz as f64 / *total as f64
    };
    let n = r.layer_stats.len();
    let first = rate(0);
    let last = rate(n - 1);
    let mid_max = (1..n - 1).map(rate).fold(0.0f64, f64::max);
    mid_max >= first.min(last)
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let mut rt = Runtime::cpu()?;

    let mut all = Vec::new();
    for model in common::bench_models(&["lenet", "mlp"]) {
        common::section(&format!("Tables A1-A4 ({model}): layer-wise compression"));
        let base = common::base_config(&model);

        for retrain in [0usize, common::scaled(60)] {
            let mut cfg = base.clone();
            cfg.method = Method::SpC;
            cfg.retrain_steps = retrain;
            let r = sweep::run_method(&mut rt, &manifest, &cfg)?;
            print_table(&r);
            println!(
                "boundary-layer effect (middle ≥ min(first, last) rate): {}",
                if boundary_effect(&r) { "HOLDS" } else { "DOES NOT HOLD" }
            );
            all.push(r);
        }

        if model == "lenet" {
            println!("\npaper Table A1 (for reference, 60k-step full-MNIST run):");
            println!("  conv1  158/500      68.40% (3×)");
            println!("  conv2  2101/25000   91.60% (11×)");
            println!("  fc1    10804/400000 97.30% (37×)");
            println!("  fc2    270/5000     94.60% (18×)");
            println!("  Total  13333/430500 96.90% (32×)  @ acc 0.9778 (ref 0.9861)");
        }
    }
    common::write_results("bench_tablea_layerwise.json", &all);
    Ok(())
}
