//! Figure 8 + Table 2 — SpC vs the state-of-the-art MM baseline.
//!
//! Table 2 compares final accuracy/compression; Figure 8 compares
//! *convergence*: SpC compresses every update and reaches its top
//! accuracy + compression much earlier, while MM (which needs a
//! pretrained model, doubles training memory with (θ, λ), and compresses
//! only every few thousand steps) converges later and is sensitive to
//! the μ schedule. The paper also notes MM ran 2× the iterations.
//!
//! We train both with eval checkpoints and print the convergence series
//! plus the final Table-2 row. MM gets the same total step budget ×2
//! (as in the paper: SpC 60k vs MM 120k iterations).

#[path = "common.rs"]
mod common;

use proxcomp::compress;
use proxcomp::config::{Method, RunConfig};
use proxcomp::coordinator::sweep;
use proxcomp::metrics::RunResult;
use proxcomp::runtime::{Manifest, Runtime};

fn print_curve(tag: &str, r: &RunResult) {
    println!("\n{tag} convergence (eval checkpoints):");
    println!("{:>6} {:>9} {:>9}", "step", "acc", "rate");
    for rec in r.history.records.iter().filter(|rec| !rec.accuracy.is_nan()) {
        println!("{:>6} {:>9.4} {:>9.4}", rec.step, rec.accuracy, rec.compression_rate);
    }
}

/// First eval step at which the run reaches 95% of its final accuracy
/// AND 90% of its final compression rate — the "reaches top much faster"
/// comparison from Figure 8.
fn convergence_step(r: &RunResult) -> Option<usize> {
    let evals: Vec<_> = r.history.records.iter().filter(|rec| !rec.accuracy.is_nan()).collect();
    let last = evals.last()?;
    evals
        .iter()
        .find(|rec| {
            rec.accuracy >= 0.95 * last.accuracy && rec.compression_rate >= 0.9 * last.compression_rate
        })
        .map(|rec| rec.step)
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let mut rt = Runtime::cpu()?;

    let mut all = Vec::new();
    for model in common::bench_models(&["mlp", "lenet"]) {
        common::section(&format!("Figure 8 / Table 2 ({model}): SpC vs MM"));
        let base = common::base_config(&model);
        let eval_every = (base.steps / 8).max(5);

        // SpC from random weights.
        let mut spc_cfg = RunConfig { eval_every, ..base.clone() };
        spc_cfg.method = Method::SpC;
        let spc = compress::spc::run(&mut rt, &manifest, &spc_cfg)?;

        // MM with 2× the budget (pretrain half + MM half), as in the paper.
        let mut mm_cfg = RunConfig { eval_every, ..base.clone() };
        mm_cfg.method = Method::MM;
        mm_cfg.steps = base.steps * 2;
        common::mm_config(&mut mm_cfg);
        let mm = sweep::run_method(&mut rt, &manifest, &mm_cfg)?;

        print_curve("SpC", &spc);
        print_curve("MM", &mm);

        println!("\nTable 2 row ({model}):");
        println!("{:<14} {:>10} {:>9} {:>9} {:>12}", "method", "pretrained", "acc", "rate", "steps");
        println!(
            "{:<14} {:>10} {:>9.4} {:>9.4} {:>12}",
            "SpC", "-", spc.accuracy, spc.compression_rate, spc_cfg.steps
        );
        println!(
            "{:<14} {:>10} {:>9.4} {:>9.4} {:>12}",
            "MM", "required", mm.accuracy, mm.compression_rate,
            format!("{} (2×)", mm_cfg.steps)
        );

        let s_conv = convergence_step(&spc);
        let m_conv = convergence_step(&mm);
        println!(
            "\nconvergence step (95% final acc & 90% final rate): SpC {:?} vs MM {:?}",
            s_conv, m_conv
        );
        if let (Some(s), Some(m)) = (s_conv, m_conv) {
            println!(
                "paper claim (SpC reaches top compression/accuracy faster): {}",
                if s <= m { "HOLDS" } else { "DOES NOT HOLD at this step budget" }
            );
        }
        println!(
            "memory: SpC state = (w, m, v); MM state = (w, mom, θ, λ) → ~2× (paper Section 4.4)"
        );
        all.push(spc);
        all.push(mm);
    }
    common::write_results("bench_fig8_table2_mm.json", &all);
    Ok(())
}
