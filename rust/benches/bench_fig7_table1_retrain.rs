//! Figure 7 + Table 1 — the effect of retraining.
//!
//! Four methods per network, as in the paper's summary table:
//!
//! * `Pru`          — magnitude pruning, no retraining
//! * `Pru(Retrain)` — pruning + retraining (Han et al. 2015)
//! * `SpC`          — sparse coding, no retraining (ours)
//! * `SpC(Retrain)` — sparse coding + debiasing
//!
//! Paper expectations: Pru without retraining collapses at high rates;
//! Pru(Retrain) ≈ SpC at moderate rates but SpC wins at very high rates;
//! retraining lets SpC compress further at matched accuracy.

#[path = "common.rs"]
mod common;

use proxcomp::config::Method;
use proxcomp::coordinator::sweep;
use proxcomp::runtime::{Manifest, Runtime};

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let mut rt = Runtime::cpu()?;

    let mut all = Vec::new();
    for model in common::bench_models(&["mlp", "lenet"]) {
        common::section(&format!("Figure 7 / Table 1 ({model}): retraining effect"));
        let base = common::base_config(&model);
        let retrain = common::scaled(60);
        // Target a high rate so the Pru-collapse regime is visible.
        let target_rate = 0.95;

        // Reference accuracy for context (λ=0).
        let mut ref_cfg = base.clone();
        ref_cfg.method = Method::Reference;
        let reference = sweep::run_method(&mut rt, &manifest, &ref_cfg)?;
        println!("reference accuracy: {:.4}\n", reference.accuracy);

        println!(
            "{:<14} {:>9} {:>9} {:>7}",
            "method", "accuracy", "rate", "factor"
        );
        let mut rows = Vec::new();
        for (method, retrain_steps) in [
            (Method::Pru, 0),
            (Method::Pru, retrain),
            (Method::SpC, 0),
            (Method::SpC, retrain),
        ] {
            let mut cfg = base.clone();
            cfg.method = method;
            cfg.retrain_steps = retrain_steps;
            cfg.pru_target_rate = target_rate;
            if method == Method::SpC {
                // Push SpC toward a comparable (high) compression rate.
                cfg.lambda = base.lambda * 2.0;
            }
            let r = sweep::run_method(&mut rt, &manifest, &cfg)?;
            println!(
                "{:<14} {:>9.4} {:>9.4} {:>6.0}×",
                r.method, r.accuracy, r.compression_rate, r.times_factor()
            );
            rows.push(r);
        }

        // Paper shape checks.
        let pru = &rows[0];
        let pru_r = &rows[1];
        let spc = &rows[2];
        let spc_r = &rows[3];
        println!("\npaper claims at high compression:");
        println!(
            "  retraining rescues Pru (acc {:.3} → {:.3}): {}",
            pru.accuracy,
            pru_r.accuracy,
            verdict(pru_r.accuracy > pru.accuracy)
        );
        println!(
            "  SpC (no retrain, acc {:.3}) ≥ raw Pru (acc {:.3}): {}",
            spc.accuracy,
            pru.accuracy,
            verdict(spc.accuracy >= pru.accuracy)
        );
        println!(
            "  retraining preserves/improves SpC accuracy ({:.3} → {:.3}): {}",
            spc.accuracy,
            spc_r.accuracy,
            verdict(spc_r.accuracy >= spc.accuracy - 0.02)
        );
        all.push(reference);
        all.extend(rows);
    }
    common::write_results("bench_fig7_table1_retrain.json", &all);
    Ok(())
}

fn verdict(b: bool) -> &'static str {
    if b {
        "HOLDS"
    } else {
        "DOES NOT HOLD at this step budget"
    }
}
