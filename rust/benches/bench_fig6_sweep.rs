//! Figure 6 — accuracy & compression vs λ: SpC (a) against Pru (b).
//!
//! Paper expectations encoded here:
//! * SpC sweeps λ: compression rises with λ; accuracy stays near (or at
//!   small λ *above*) the reference until high compression, with ~90%
//!   of weights removable at reference-level accuracy.
//! * Pru sweeps the pruning rate: accuracy drops much faster with
//!   compression than SpC when there is no retraining.
//!
//! We print both series per model and mark, as the paper's vertical
//! dotted lines do, the highest-compression point whose accuracy still
//! reaches ≥99% of the reference.

#[path = "common.rs"]
mod common;

use proxcomp::config::Method;
use proxcomp::coordinator::sweep;
use proxcomp::metrics::RunResult;
use proxcomp::runtime::{Manifest, Runtime};

fn knee(results: &[RunResult], reference: f64) -> Option<&RunResult> {
    results
        .iter()
        .filter(|r| r.accuracy >= 0.99 * reference)
        .max_by(|a, b| a.compression_rate.partial_cmp(&b.compression_rate).unwrap())
}

fn main() -> anyhow::Result<()> {
    let manifest = Manifest::load("artifacts")?;
    let mut rt = Runtime::cpu()?;

    let mut all = Vec::new();
    for model in common::bench_models(&["mlp", "lenet"]) {
        common::section(&format!("Figure 6 ({model}): accuracy vs compression"));
        let cfg = common::base_config(&model);

        // (a) SpC: λ sweep (λ=0 is the reference model).
        let lambdas = common::lambda_grid(&model);
        println!("\n(a) SpC — λ sweep");
        println!("{:>8} {:>9} {:>9}", "λ", "accuracy", "rate");
        let spc = sweep::lambda_sweep(&mut rt, &manifest, &cfg, &lambdas)?;
        let reference = spc[0].accuracy;
        for r in &spc {
            let above = if r.lambda > 0.0 && r.accuracy > reference { "  > ref" } else { "" };
            println!("{:>8.3} {:>9.4} {:>9.4}{}", r.lambda, r.accuracy, r.compression_rate, above);
        }
        if let Some(k) = knee(&spc[1..], reference) {
            println!("SpC knee (≥99% ref acc): rate {:.4} at λ={:.3}", k.compression_rate, k.lambda);
        }

        // (b) Pru: target-rate sweep, no retraining (paper Fig. 6b).
        let rates = [0.2, 0.4, 0.6, 0.8, 0.9, 0.95];
        println!("\n(b) Pru — pruning-rate sweep (no retraining)");
        println!("{:>8} {:>9} {:>9}", "target", "accuracy", "rate");
        let mut pru_cfg = cfg.clone();
        pru_cfg.method = Method::Pru;
        pru_cfg.retrain_steps = 0;
        let pru = sweep::pru_rate_sweep(&mut rt, &manifest, &pru_cfg, &rates)?;
        for r in &pru {
            println!("{:>8} {:>9.4} {:>9.4}", r.lambda, r.accuracy, r.compression_rate);
        }
        if let Some(k) = knee(&pru, reference) {
            println!("Pru knee (≥99% ref acc): rate {:.4}", k.compression_rate);
        }

        // Paper shape check: SpC should sustain ≥99%-ref accuracy at a
        // higher compression rate than raw Pru.
        let spc_knee = knee(&spc[1..], reference).map(|r| r.compression_rate).unwrap_or(0.0);
        let pru_knee = knee(&pru, reference).map(|r| r.compression_rate).unwrap_or(0.0);
        println!(
            "\npaper claim (SpC compresses more at matched accuracy): SpC {:.3} vs Pru {:.3} → {}",
            spc_knee,
            pru_knee,
            if spc_knee >= pru_knee { "HOLDS" } else { "DOES NOT HOLD at this step budget" }
        );
        all.extend(spc);
        all.extend(pru);
    }
    common::write_results("bench_fig6_sweep.json", &all);
    Ok(())
}
