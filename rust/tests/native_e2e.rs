//! Offline end-to-end tests over the native CPU training backend — the
//! feature-less twins of the `pjrt`-gated suite in `integration.rs`.
//!
//! Every test here runs in the default offline build: the built-in
//! native manifest (`Manifest::native()`) registers the MLP family with
//! `native/<model>/<step>` artifacts, `Runtime::native()` executes them
//! through `runtime::native`, and the trainer / compression controllers
//! are the exact same code paths the PJRT build drives. The `mlp-s`
//! model (784→32→16→10 on `synth-blobs`) keeps each test in debug-build
//! seconds.
//!
//! Hyperparameters were chosen with margin to spare (λ=1.0 at lr 2e-3
//! reaches ~0.9 zero-rate with ~0.9+ accuracy on synth-blobs; debiasing
//! then drops eval loss by ~3× — verified across 16 seeds), so the
//! assertions are robust, and the run itself is bit-deterministic per
//! seed for any `PROXCOMP_THREADS`.

use proxcomp::compress::{self, debias};
use proxcomp::config::{Method, Optimizer, RunConfig};
use proxcomp::coordinator::{trainer::StepScalars, Trainer};
use proxcomp::inference::{BatchConfig, BatchServer, Engine, WeightMode};
use proxcomp::runtime::{Backend, Manifest, Runtime};
use proxcomp::tensor::Tensor;
use proxcomp::util::json::Json;
use std::sync::Arc;
use std::time::Duration;

fn manifest() -> Manifest {
    Manifest::native()
}

fn small_cfg() -> RunConfig {
    RunConfig {
        model: "mlp-s".into(),
        steps: 60,
        lambda: 1.0,
        lr: 2e-3,
        retrain_lr: 1e-3,
        train_examples: 512,
        test_examples: 256,
        artifacts_dir: "native".into(),
        ..RunConfig::default()
    }
}

#[test]
fn native_manifest_covers_all_models_and_steps() {
    let m = manifest();
    for name in ["mlp", "mlp-s"] {
        let entry = m.model(name).unwrap();
        for step in [
            "train_prox_adam",
            "train_prox_rmsprop",
            "train_prox_sgd",
            "train_masked",
            "train_mm",
            "eval",
            "infer",
        ] {
            let a = entry.artifact(step).unwrap();
            assert!(!a.inputs.is_empty() && !a.outputs.is_empty(), "{name}/{step}");
        }
    }
}

#[test]
fn native_training_decreases_loss_and_creates_exact_zeros() {
    let m = manifest();
    let mut rt = Runtime::native();
    assert_eq!(rt.backend(), Backend::Native);
    let cfg = small_cfg();
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let scalars = StepScalars { lambda: 1.0, lr: 2e-3, mu: 0.0 };
    let first = trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    let mut last = first;
    for _ in 0..24 {
        last = trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    assert!(last < first, "loss did not decrease: {first} -> {last}");
    // The prox writes exact zeros during training (Section 2.2).
    assert!(trainer.state.params.zero_weights() > 100, "prox produced no zeros");
    // Timestep advanced through the OptT role round-trip.
    assert_eq!(trainer.state.t, 25.0);
}

#[test]
fn native_rmsprop_and_sgd_artifacts_run() {
    let m = manifest();
    let mut rt = Runtime::native();
    for step in ["train_prox_rmsprop", "train_prox_sgd"] {
        let cfg = small_cfg();
        let mut trainer = Trainer::new(&m, &cfg).unwrap();
        let scalars = StepScalars { lambda: 0.5, lr: 1e-3, mu: 0.0 };
        let loss = trainer.step(&mut rt, step, scalars).unwrap();
        assert!(loss.is_finite(), "{step} produced {loss}");
    }
}

#[test]
fn native_lambda_zero_never_zeroes_weights() {
    let m = manifest();
    let mut rt = Runtime::native();
    let cfg = small_cfg();
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let scalars = StepScalars { lambda: 0.0, lr: 1e-3, mu: 0.0 };
    for _ in 0..5 {
        trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    assert_eq!(trainer.state.params.zero_weights(), 0);
}

#[test]
fn native_masked_step_never_resurrects_zeros() {
    let m = manifest();
    let mut rt = Runtime::native();
    let cfg = small_cfg();
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    // Sparsify hard, then retrain.
    let scalars = StepScalars { lambda: 2.0, lr: 2e-3, mu: 0.0 };
    for _ in 0..10 {
        trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    let zeros_before = trainer.state.params.zero_weights();
    assert!(zeros_before > 1000, "only {zeros_before} zeros after sparsification");
    debias::retrain(&mut rt, &mut trainer, 10, 1e-4).unwrap();
    assert!(
        trainer.state.params.zero_weights() >= zeros_before,
        "retraining resurrected zeros"
    );
}

#[test]
fn native_higher_lambda_compresses_more() {
    let m = manifest();
    let mut rt = Runtime::native();
    let mut rates = Vec::new();
    for lam in [0.25f32, 1.0, 4.0] {
        let cfg = small_cfg();
        let mut trainer = Trainer::new(&m, &cfg).unwrap();
        let scalars = StepScalars { lambda: lam, lr: 2e-3, mu: 0.0 };
        for _ in 0..15 {
            trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
        }
        rates.push(trainer.state.params.compression_rate());
    }
    assert!(rates[0] < rates[1] && rates[1] < rates[2], "{rates:?}");
}

#[test]
fn native_seeds_reproduce_and_differ() {
    let m = manifest();
    let mut rt = Runtime::native();
    let run = |rt: &mut Runtime, seed: u64| {
        let mut cfg = small_cfg();
        cfg.seed = seed;
        let mut trainer = Trainer::new(&m, &cfg).unwrap();
        let scalars = StepScalars { lambda: 0.5, lr: 1e-3, mu: 0.0 };
        let mut loss = 0.0;
        for _ in 0..5 {
            loss = trainer.step(rt, "train_prox_adam", scalars).unwrap();
        }
        loss
    };
    let a = run(&mut rt, 7);
    let b = run(&mut rt, 7);
    let c = run(&mut rt, 8);
    assert_eq!(a, b, "same seed must reproduce bit-exactly");
    assert_ne!(a, c, "different seeds must differ");
}

#[test]
fn native_evaluate_returns_sane_metrics() {
    let m = manifest();
    let mut rt = Runtime::native();
    let cfg = small_cfg();
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let eval = trainer.evaluate(&mut rt).unwrap();
    assert_eq!(eval.n, cfg.test_examples);
    assert!(eval.accuracy >= 0.0 && eval.accuracy <= 1.0);
    // Untrained net: random-logit CE on synth-blobs (looser than the
    // synth-mnist band — blob inputs are larger-scale).
    assert!(eval.loss > 1.5 && eval.loss < 10.0, "loss {}", eval.loss);
    // Training improves accuracy.
    let scalars = StepScalars { lambda: 0.0, lr: 2e-3, mu: 0.0 };
    for _ in 0..25 {
        trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    let eval2 = trainer.evaluate(&mut rt).unwrap();
    assert!(eval2.accuracy > eval.accuracy + 0.1, "{} -> {}", eval.accuracy, eval2.accuracy);
}

#[test]
fn native_spc_controller_end_to_end() {
    let m = manifest();
    let mut rt = Runtime::native();
    let mut cfg = small_cfg();
    cfg.steps = 40;
    cfg.retrain_steps = 10;
    let r = compress::spc::run(&mut rt, &m, &cfg).unwrap();
    assert_eq!(r.method, "SpC(Retrain)");
    assert!(r.compression_rate > 0.3, "rate {}", r.compression_rate);
    assert!(r.accuracy > 0.5, "accuracy {}", r.accuracy);
    assert!(r.nnz < r.total_weights, "no zeros: nnz {} of {}", r.nnz, r.total_weights);
}

#[test]
fn native_pru_controller_hits_target_rate() {
    let m = manifest();
    let mut rt = Runtime::native();
    let mut cfg = small_cfg();
    cfg.method = Method::Pru;
    cfg.steps = 20;
    cfg.pru_target_rate = 0.8;
    cfg.retrain_steps = 5;
    let r = compress::pruning::run(&mut rt, &m, &cfg).unwrap();
    assert!((r.compression_rate - 0.8).abs() < 0.02, "rate {}", r.compression_rate);
}

#[test]
fn native_mm_controller_produces_sparse_model() {
    let m = manifest();
    let mut rt = Runtime::native();
    let mut cfg = small_cfg();
    cfg.method = Method::MM;
    cfg.steps = 60;
    cfg.pru_target_rate = 0.8; // ℓ0-constraint C-step target (κ)
    cfg.mm_mu0 = 0.1;
    cfg.mm_mu_growth = 1.5;
    cfg.mm_compress_every = 6;
    cfg.lr = 0.02;
    let r = compress::mm::run(&mut rt, &m, &cfg).unwrap();
    // The ℓ0 C-step pins the rate exactly.
    assert!((r.compression_rate - 0.8).abs() < 0.02, "MM rate {}", r.compression_rate);
    assert!(r.accuracy > 0.5, "MM accuracy collapsed: {}", r.accuracy);
}

#[test]
fn native_optimizer_selection_routes_to_artifact() {
    let m = manifest();
    let mut rt = Runtime::native();
    let mut cfg = small_cfg();
    cfg.optimizer = Optimizer::ProxRmsprop;
    cfg.steps = 10;
    let r = compress::spc::run(&mut rt, &m, &cfg).unwrap();
    assert!(r.accuracy > 0.0);
}

#[test]
fn native_batch_server_serves_trained_model() {
    // The serving front-end over a natively trained engine: per-request
    // logits must match the engine's own answers bit-for-bit.
    let m = manifest();
    let mut rt = Runtime::native();
    let cfg = small_cfg();
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let scalars = StepScalars { lambda: 1.0, lr: 2e-3, mu: 0.0 };
    for _ in 0..10 {
        trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    let engine = Arc::new(
        Engine::builder("mlp-s")
            .bundle(&trainer.state.params)
            .mode(WeightMode::Csr)
            .build()
            .unwrap(),
    );
    let server = BatchServer::start(
        Arc::clone(&engine),
        BatchConfig::new(8, Duration::from_millis(20), (1, 28, 28)),
    );
    let pending: Vec<_> = (0..12)
        .map(|i| {
            let sample = trainer.test_data.image(i % trainer.test_data.n).to_vec();
            (sample.clone(), server.submit(&sample).unwrap())
        })
        .collect();
    for (sample, p) in pending {
        let got = p.wait().unwrap();
        let x = Tensor::new(vec![1, 1, 28, 28], sample);
        assert_eq!(got, engine.forward(&x).unwrap().data);
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 12);
    assert!(stats.batches >= 2);
}

#[test]
fn native_checkpoint_roundtrip_through_trained_model() {
    let m = manifest();
    let mut rt = Runtime::native();
    let cfg = small_cfg();
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let scalars = StepScalars { lambda: 2.0, lr: 2e-3, mu: 0.0 };
    for _ in 0..10 {
        trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    let dir = std::env::temp_dir().join("proxcomp_native_e2e");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("m.pxcp");
    let mut meta = Json::obj();
    meta.set("model", Json::from("mlp-s"));
    proxcomp::checkpoint::save(&path, &trainer.state.params, &meta).unwrap();
    let ck = proxcomp::checkpoint::load(&path).unwrap();
    assert_eq!(ck.params.values, trainer.state.params.values);
    // The engine accepts the loaded bundle (mlp family by name prefix).
    let engine =
        Engine::builder("mlp-s").bundle(&ck.params).mode(WeightMode::Csr).build().unwrap();
    assert!(engine.model_size_bytes() > 0);
}

/// The conv acceptance pipeline (paper Table 3 / Figs. 6-8 track):
/// `lenet-s` SpC from random init passes the finite-difference gradient
/// preflight and decreases eval loss, debiasing preserves-or-improves
/// accuracy, and the compressed conv model serves bit-exactly through
/// the dispatch engine + `BatchServer` at compression factor > 1.
#[test]
fn native_lenet_pipeline_spc_debias_compress_serve() {
    use proxcomp::runtime::native;
    let m = manifest();
    let mut rt = Runtime::native();
    let cfg = RunConfig {
        model: "lenet-s".into(),
        steps: 120,
        retrain_steps: 40,
        lambda: 0.4,
        lr: 2e-3,
        retrain_lr: 1e-3,
        train_examples: 1024,
        test_examples: 256,
        artifacts_dir: "native".into(),
        ..RunConfig::default()
    };
    let t0 = std::time::Instant::now();

    // Phase 0: the conv backward must pass the FD check before we trust
    // its training signal (the same preflight `proxcomp pipeline` gates on).
    let (ok, total) = native::gradient_check(m.model("lenet-s").unwrap(), cfg.seed, 4).unwrap();
    assert!(ok >= native::FD_MIN_AGREE, "gradient check: {ok}/{total}");

    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let eval0 = trainer.evaluate(&mut rt).unwrap();

    // Phase 1: SpC — ℓ1 sparse coding with Prox-ADAM from random init.
    let scalars = StepScalars { lambda: cfg.lambda, lr: cfg.lr, mu: 0.0 };
    compress::spc::run_with_evals(&mut rt, &mut trainer, "train_prox_adam", cfg.steps, scalars, 0)
        .unwrap();
    let eval_sparse = trainer.evaluate(&mut rt).unwrap();
    let rate_sparse = trainer.state.params.compression_rate();
    assert!(
        eval_sparse.loss < eval0.loss,
        "SpC did not decrease eval loss: {} -> {}",
        eval0.loss,
        eval_sparse.loss
    );
    assert!(rate_sparse > 0.05, "SpC produced almost no conv-net zeros: {rate_sparse}");
    assert!(rate_sparse < 0.999, "SpC collapsed the network: {rate_sparse}");

    // Phase 2: debias — masked retraining must preserve-or-improve
    // accuracy (Section 2.4) and never resurrect zeros (checked inside
    // `debias::retrain`).
    debias::retrain(&mut rt, &mut trainer, cfg.retrain_steps, cfg.retrain_lr).unwrap();
    let eval_debias = trainer.evaluate(&mut rt).unwrap();
    assert!(
        eval_debias.accuracy >= eval_sparse.accuracy - 0.05,
        "debias lost accuracy: {} -> {}",
        eval_sparse.accuracy,
        eval_debias.accuracy
    );
    assert!(
        eval_debias.loss < eval0.loss,
        "debiased loss {} did not beat untrained {}",
        eval_debias.loss,
        eval0.loss
    );
    assert!(eval_debias.accuracy > 0.3, "final conv accuracy too low: {}", eval_debias.accuracy);

    // Phase 3: compress + deploy through the dispatch engine.
    let result =
        compress::finish_run(&mut rt, &mut trainer, "SpC(Retrain)", cfg.lambda as f64, t0).unwrap();
    assert!(result.times_factor() > 1.0, "compression factor {} not > 1", result.times_factor());

    let engine = Arc::new(
        Engine::builder("lenet-s")
            .bundle(&trainer.state.params)
            .mode(WeightMode::Auto)
            .build()
            .unwrap(),
    );
    let formats = engine.layer_formats();
    assert!(!formats.is_empty(), "layer_formats() report is empty");
    assert_eq!(formats.len(), 4, "conv1/conv2/fc1/fc2 expected: {formats:?}");

    let server = BatchServer::start(
        Arc::clone(&engine),
        BatchConfig::new(8, Duration::from_millis(20), (1, 16, 16)),
    );
    let pending: Vec<_> = (0..16)
        .map(|i| {
            let sample = trainer.test_data.image(i % trainer.test_data.n).to_vec();
            (sample.clone(), server.submit(&sample).unwrap())
        })
        .collect();
    for (sample, p) in pending {
        let got = p.wait().unwrap();
        assert_eq!(got.len(), 10);
        let x = Tensor::new(vec![1, 1, 16, 16], sample);
        assert_eq!(got, engine.forward(&x).unwrap().data, "served conv logits diverge");
    }
    assert_eq!(server.stats().requests, 16);
}

/// The native trainer must drive every conv artifact family end to end
/// (prox optimizers, masked debias, MM L-step) — the same role-driven
/// code paths the MLP family exercises.
#[test]
fn native_lenet_all_step_kinds_run() {
    let m = manifest();
    let mut rt = Runtime::native();
    let cfg = RunConfig {
        model: "lenet-s".into(),
        steps: 4,
        train_examples: 64,
        test_examples: 32,
        artifacts_dir: "native".into(),
        ..RunConfig::default()
    };
    for step in ["train_prox_adam", "train_prox_rmsprop", "train_prox_sgd"] {
        let mut trainer = Trainer::new(&m, &cfg).unwrap();
        let scalars = StepScalars { lambda: 0.5, lr: 1e-3, mu: 0.0 };
        let loss = trainer.step(&mut rt, step, scalars).unwrap();
        assert!(loss.is_finite(), "{step} produced {loss}");
    }
    // Masked debias on a conv net.
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let scalars = StepScalars { lambda: 2.0, lr: 2e-3, mu: 0.0 };
    for _ in 0..4 {
        trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    debias::retrain(&mut rt, &mut trainer, 4, 1e-4).unwrap();
    // MM on a conv net: pretrain-free smoke of the L-step machinery.
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let mut mm_cfg = cfg.clone();
    mm_cfg.method = Method::MM;
    mm_cfg.pru_target_rate = 0.5;
    mm_cfg.mm_mu0 = 0.1;
    mm_cfg.mm_compress_every = 2;
    mm_cfg.lr = 0.01;
    compress::mm::run_mm_phase(&mut rt, &mut trainer, &mm_cfg, 4, 0).unwrap();
    assert!((trainer.state.params.compression_rate() - 0.5).abs() < 0.05);
}

/// The acceptance pipeline: SpC from random init decreases eval loss,
/// debiasing improves (or preserves) eval accuracy while strictly
/// improving eval loss, and the compressed model serves through the
/// dispatch engine + `BatchServer` with compression factor > 1.
#[test]
fn native_full_pipeline_spc_debias_compress_serve() {
    let m = manifest();
    let mut rt = Runtime::native();
    let mut cfg = small_cfg();
    cfg.steps = 60;
    cfg.retrain_steps = 40;
    let t0 = std::time::Instant::now();
    let mut trainer = Trainer::new(&m, &cfg).unwrap();

    // Phase 0: untrained baseline.
    let eval0 = trainer.evaluate(&mut rt).unwrap();

    // Phase 1: SpC — ℓ1 sparse coding with Prox-ADAM from random init.
    let scalars = StepScalars { lambda: cfg.lambda, lr: cfg.lr, mu: 0.0 };
    compress::spc::run_with_evals(&mut rt, &mut trainer, "train_prox_adam", cfg.steps, scalars, 0)
        .unwrap();
    let eval_sparse = trainer.evaluate(&mut rt).unwrap();
    let rate_sparse = trainer.state.params.compression_rate();
    assert!(
        eval_sparse.loss < eval0.loss,
        "SpC did not decrease eval loss: {} -> {}",
        eval0.loss,
        eval_sparse.loss
    );
    assert!(rate_sparse > 0.5, "SpC rate too low: {rate_sparse}");
    assert!(rate_sparse < 0.999, "SpC collapsed the network: {rate_sparse}");

    // Phase 2: debias (Section 2.4) — masked retraining without the ℓ1
    // term recovers the shrinkage bias: eval loss strictly improves and
    // accuracy improves or is preserved.
    debias::retrain(&mut rt, &mut trainer, cfg.retrain_steps, cfg.retrain_lr).unwrap();
    let eval_debias = trainer.evaluate(&mut rt).unwrap();
    assert!(
        eval_debias.loss < eval_sparse.loss,
        "debias did not improve eval loss: {} -> {}",
        eval_sparse.loss,
        eval_debias.loss
    );
    assert!(
        eval_debias.accuracy >= eval_sparse.accuracy - 0.02,
        "debias lost accuracy: {} -> {}",
        eval_sparse.accuracy,
        eval_debias.accuracy
    );
    assert!(eval_debias.accuracy > 0.75, "final accuracy too low: {}", eval_debias.accuracy);

    // Phase 3: compress + deploy. finish_run assembles the RunResult
    // (compression factor > 1×), the dispatch engine picks per-layer
    // formats, and the batch server serves with bit-exact parity.
    let result = compress::finish_run(&mut rt, &mut trainer, "SpC(Retrain)", cfg.lambda as f64, t0)
        .unwrap();
    assert!(result.times_factor() > 1.0, "compression factor {} not > 1", result.times_factor());
    assert!(result.compression_rate > 0.5);

    let engine = Arc::new(
        Engine::builder("mlp-s")
            .bundle(&trainer.state.params)
            .mode(WeightMode::Auto)
            .build()
            .unwrap(),
    );
    let formats = engine.layer_formats();
    assert!(!formats.is_empty(), "layer_formats() report is empty");
    assert!(formats.iter().all(|(_, f)| *f != "dense"), "dense leak in deployment: {formats:?}");

    let server = BatchServer::start(
        Arc::clone(&engine),
        BatchConfig::new(8, Duration::from_millis(20), (1, 28, 28)),
    );
    let pending: Vec<_> = (0..16)
        .map(|i| {
            let sample = trainer.test_data.image(i % trainer.test_data.n).to_vec();
            (sample.clone(), server.submit(&sample).unwrap())
        })
        .collect();
    let ncls = m.model("mlp-s").unwrap().num_classes;
    for (sample, p) in pending {
        let got = p.wait().unwrap();
        assert_eq!(got.len(), ncls);
        let x = Tensor::new(vec![1, 1, 28, 28], sample);
        assert_eq!(got, engine.forward(&x).unwrap().data, "served logits diverge");
    }
    assert_eq!(server.stats().requests, 16);
}

/// The quantized deployment stage (`pipeline --quantize` twin): train +
/// debias a small model, codebook-quantize it, and require the gates
/// the CLI enforces — quantized checkpoint strictly smaller than CSR,
/// quantized accuracy within tolerance, and bit-faithful serving after
/// a checkpoint-v2 round trip (engine logits identical pre/post save).
#[test]
fn native_quantized_pipeline_spc_debias_quantize_serve() {
    use proxcomp::quant::{self, QuantConfig};
    let m = manifest();
    let mut rt = Runtime::native();
    let mut cfg = small_cfg();
    cfg.steps = 60;
    cfg.retrain_steps = 30;
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let scalars = StepScalars { lambda: cfg.lambda, lr: cfg.lr, mu: 0.0 };
    for _ in 0..cfg.steps {
        trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    debias::retrain(&mut rt, &mut trainer, cfg.retrain_steps, cfg.retrain_lr).unwrap();
    let eval_debias = trainer.evaluate(&mut rt).unwrap();

    // Quantize at the default 16-entry codebooks.
    let (qm, reports) = quant::quantize_bundle(&trainer.state.params, &QuantConfig::default());
    assert!(reports.iter().any(|r| r.quantized), "no leaf quantized: {reports:?}");
    for r in reports.iter().filter(|r| r.quantized) {
        assert!(r.stored_bytes < r.csr_bytes, "{}: {} >= {}", r.name, r.stored_bytes, r.csr_bytes);
        assert!(r.stats.rmse.is_finite() && r.stats.rmse >= 0.0);
    }

    // Checkpoints: quantized strictly smaller than CSR.
    let dir = std::env::temp_dir().join("proxcomp_native_e2e_quant");
    std::fs::create_dir_all(&dir).unwrap();
    let mut meta = Json::obj();
    meta.set("model", Json::from("mlp-s"));
    meta.set("dataset", Json::from(trainer.entry.dataset.as_str()));
    let csr_bytes =
        proxcomp::checkpoint::save(&dir.join("f32.pxcp"), &trainer.state.params, &meta).unwrap();
    let q_bytes =
        proxcomp::checkpoint::save_quantized(&dir.join("quant.pxcp"), &qm, &meta).unwrap();
    assert!(q_bytes < csr_bytes, "quantized {q_bytes} >= csr {csr_bytes}");

    // Quantized serving: accuracy within a generous tolerance of the
    // debiased f32 model (k=16 codebooks on a trained sparse net).
    let qengine = Arc::new(Engine::builder("mlp-s").quantized(&qm).build().unwrap());
    let quant_acc = qengine.accuracy(&trainer.test_data, 64).unwrap();
    assert!(
        quant_acc >= eval_debias.accuracy - 0.1,
        "quantized accuracy collapsed: {} vs debiased {}",
        quant_acc,
        eval_debias.accuracy
    );

    // Bit-faithful after reload: the served logits of the reloaded
    // checkpoint equal the in-memory quantized engine's exactly.
    let ck = proxcomp::checkpoint::load(&dir.join("quant.pxcp")).unwrap();
    assert!(ck.is_quantized());
    let reloaded = Engine::builder("mlp-s").quantized(&ck.to_quantized_model()).build().unwrap();
    for i in 0..8 {
        let sample = trainer.test_data.image(i).to_vec();
        let x = Tensor::new(vec![1, 1, 28, 28], sample);
        assert_eq!(
            qengine.forward(&x).unwrap().data,
            reloaded.forward(&x).unwrap().data,
            "sample {i}: reloaded quantized serving diverges"
        );
    }

    // BatchServer over the quantized engine: bit-exact request parity.
    let server = BatchServer::start(
        Arc::clone(&qengine),
        BatchConfig::new(8, Duration::from_millis(20), (1, 28, 28)),
    );
    let pending: Vec<_> = (0..12)
        .map(|i| {
            let sample = trainer.test_data.image(i % trainer.test_data.n).to_vec();
            (sample.clone(), server.submit(&sample).unwrap())
        })
        .collect();
    for (sample, p) in pending {
        let got = p.wait().unwrap();
        let x = Tensor::new(vec![1, 1, 28, 28], sample);
        assert_eq!(got, qengine.forward(&x).unwrap().data, "served quantized logits diverge");
    }
    assert_eq!(server.stats().requests, 12);
}

/// The trained-quantization pass: per-code gradient accumulation on the
/// native backend is deterministic, touches only codebooks (codes and
/// the sparsity pattern are frozen), and keeps the loss finite.
#[test]
fn native_codebook_finetune_is_deterministic_and_structure_preserving() {
    use proxcomp::quant::{self, QuantConfig, QuantLeaf};
    let m = manifest();
    let mut rt = Runtime::native();
    let mut cfg = small_cfg();
    cfg.steps = 30;
    let mut trainer = Trainer::new(&m, &cfg).unwrap();
    let scalars = StepScalars { lambda: cfg.lambda, lr: cfg.lr, mu: 0.0 };
    for _ in 0..cfg.steps {
        trainer.step(&mut rt, "train_prox_adam", scalars).unwrap();
    }
    let (qm0, _) = quant::quantize_bundle(&trainer.state.params, &QuantConfig::default());

    let run = |mut qm: proxcomp::quant::QuantizedModel| {
        let rep =
            quant::finetune_codebooks(&mut qm, &trainer.train_data, 5, 16, 1e-4, 7).unwrap();
        (qm, rep)
    };
    let (qm_a, rep_a) = run(qm0.clone());
    let (qm_b, rep_b) = run(qm0.clone());
    assert!(rep_a.loss_first.is_finite() && rep_a.loss_last.is_finite());
    assert_eq!(rep_a.loss_first.to_bits(), rep_b.loss_first.to_bits(), "fine-tune not deterministic");
    assert_eq!(rep_a.loss_last.to_bits(), rep_b.loss_last.to_bits(), "fine-tune not deterministic");

    let mut any_changed = false;
    for ((a, b), orig) in qm_a.leaves.iter().zip(&qm_b.leaves).zip(&qm0.leaves) {
        match ((a, b), orig) {
            ((QuantLeaf::Qcs(x), QuantLeaf::Qcs(y)), QuantLeaf::Qcs(o)) => {
                // Deterministic: both runs land on identical codebooks.
                assert_eq!(x.codebook(), y.codebook());
                // Structure frozen: same codes/pattern as before tuning.
                assert_eq!(x.nnz(), o.nnz());
                assert_eq!(x.ptr, o.ptr);
                for k in 0..x.nnz() {
                    assert_eq!(x.code_at(k), o.code_at(k));
                    assert_eq!(x.index_at(k), o.index_at(k));
                }
                if x.codebook() != o.codebook() {
                    any_changed = true;
                }
            }
            ((QuantLeaf::Dense(x), QuantLeaf::Dense(y)), QuantLeaf::Dense(o)) => {
                assert_eq!(x, y);
                assert_eq!(x, o, "fine-tune must not touch f32 leaves");
            }
            _ => panic!("leaf encoding changed during fine-tune"),
        }
    }
    assert!(any_changed, "fine-tune updated no codebook");
}
