//! Integration tests for the framed-TCP serving front-end
//! (`inference::net`) and its closed-loop load generator
//! (`inference::loadgen`): the over-the-wire determinism contract
//! (served logits bit-identical to a local `Engine::forward`), the
//! error taxonomy (wrong-length, overloaded, engine-error,
//! shutting-down, bad-frame), bounded admission instead of unbounded
//! queueing, and graceful shutdown that drains in-flight requests.
//!
//! Every server binds `127.0.0.1:0` (ephemeral port), so the tests run
//! concurrently without colliding.

use std::sync::Arc;
use std::time::Duration;

use proxcomp::inference::loadgen::{self, LoadConfig, LoadTarget};
use proxcomp::inference::net::OP_STATS;
use proxcomp::inference::{BatchConfig, Engine, ErrorCode, NetClient, NetConfig, NetServer, WeightMode};
use proxcomp::runtime::{Manifest, ParamBundle};
use proxcomp::sparse::prox;
use proxcomp::tensor::Tensor;
use proxcomp::util::json;
use proxcomp::util::rng::Rng;

/// The same deterministic synthetic engine `proxcomp serve` builds:
/// He-init from the native manifest, soft-threshold prune, CSR deploy.
fn synthetic_engine(model: &str, seed: u64) -> (Arc<Engine>, (usize, usize, usize)) {
    let manifest = Manifest::native();
    let entry = manifest.model(model).unwrap();
    let shape = (entry.input_shape[0], entry.input_shape[1], entry.input_shape[2]);
    let mut bundle = ParamBundle::he_init(&entry.params, seed);
    for (s, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
        if s.prunable {
            prox::soft_threshold_inplace(v, 0.05);
        }
    }
    (Arc::new(Engine::builder(model).bundle(&bundle).mode(WeightMode::Csr).build().unwrap()), shape)
}

fn start_server(model: &str, seed: u64, batch_cfg: BatchConfig, net_cfg: NetConfig) -> (NetServer, Arc<Engine>) {
    let (engine, _) = synthetic_engine(model, seed);
    let server = NetServer::start(Arc::clone(&engine), batch_cfg, net_cfg).unwrap();
    (server, engine)
}

fn ephemeral() -> NetConfig {
    NetConfig { addr: "127.0.0.1:0".to_string(), ..NetConfig::default() }
}

fn connect(server: &NetServer) -> NetClient {
    NetClient::connect(&server.local_addr().to_string(), Duration::from_secs(5)).unwrap()
}

#[test]
fn served_logits_bit_identical_to_engine_forward() {
    // lenet-s exercises the conv path end to end over the wire.
    let batch = BatchConfig::new(4, Duration::from_millis(2), (1, 16, 16));
    let (mut server, engine) = start_server("lenet-s", 1, batch, ephemeral());
    let mut client = connect(&server);
    let mut rng = Rng::new(7);
    for _ in 0..12 {
        let sample = rng.normal_vec(256, 1.0);
        let logits = client.infer(&sample).unwrap().unwrap();
        let x = Tensor::new(vec![1, 1, 16, 16], sample);
        let want = engine.forward(&x).unwrap().data;
        assert_eq!(want.len(), logits.len());
        for (a, b) in want.iter().zip(logits.iter()) {
            assert_eq!(a.to_bits(), b.to_bits(), "served logits diverged from local forward");
        }
    }
    let stats = server.stats();
    assert_eq!(stats.requests, 12);
    assert!(stats.p99_latency_us >= stats.p50_latency_us);
    server.shutdown();
}

#[test]
fn wrong_length_is_recoverable_on_the_same_connection() {
    let batch = BatchConfig::new(4, Duration::from_millis(2), (1, 28, 28));
    let (mut server, _) = start_server("mlp-s", 2, batch, ephemeral());
    let mut client = connect(&server);
    let (code, msg) = client.infer(&[0.5; 10]).unwrap().unwrap_err();
    assert_eq!(code, ErrorCode::WrongLength);
    assert!(msg.contains("784"), "message should name the expected length: {msg}");
    // The connection survives a recoverable error.
    let logits = client.infer(&[0.25; 784]).unwrap().unwrap();
    assert_eq!(logits.len(), 10);
    assert_eq!(server.net_counters().wrong_length, 1);
    server.shutdown();
}

#[test]
fn overloaded_server_rejects_instead_of_queueing() {
    // max_inflight = 1 and a long coalescing window: the first request
    // is admitted and parks in the open batch, so the second must be
    // rejected with `overloaded` — bounded admission, not a deep queue.
    let batch = BatchConfig::new(8, Duration::from_millis(500), (1, 28, 28));
    let net = NetConfig { max_inflight: 1, ..ephemeral() };
    let (mut server, _) = start_server("mlp-s", 3, batch, net);
    let mut held = connect(&server);
    held.send_infer(&[0.5; 784]).unwrap();
    // Let the handler admit the held request before offering more load.
    std::thread::sleep(Duration::from_millis(150));
    let mut probe = connect(&server);
    let (code, msg) = probe.infer(&[0.5; 784]).unwrap().unwrap_err();
    assert_eq!(code, ErrorCode::Overloaded, "{msg}");
    // The held request completes once the batch window closes…
    let (status, body) = held.recv_response().unwrap();
    assert_eq!(status, 0);
    assert_eq!(body.len(), 10 * 4);
    // …and the rejected client succeeds on retry: backpressure, not loss.
    let logits = probe.infer(&[0.5; 784]).unwrap().unwrap();
    assert_eq!(logits.len(), 10);
    assert!(server.net_counters().overloaded >= 1);
    server.shutdown();
}

#[test]
fn connection_cap_rejects_with_overloaded_frame() {
    let batch = BatchConfig::new(4, Duration::from_millis(2), (1, 28, 28));
    let net = NetConfig { max_conns: 1, ..ephemeral() };
    let (mut server, _) = start_server("mlp-s", 4, batch, net);
    let mut first = connect(&server);
    first.ping().unwrap(); // round trip ⇒ the accept loop registered it
    let mut second = connect(&server);
    let (status, body) = second.recv_response().unwrap();
    assert_eq!(ErrorCode::from_u8(status), Some(ErrorCode::Overloaded));
    assert!(String::from_utf8_lossy(&body).contains("connections"));
    assert_eq!(server.net_counters().rejected_conns, 1);
    // The admitted connection is unaffected.
    first.ping().unwrap();
    server.shutdown();
}

#[test]
fn engine_error_crosses_the_wire_and_keeps_the_connection() {
    // The batch config lies about the model (8 floats vs mlp-s's 784):
    // the forward blows up inside a kernel assert, the BatchServer fans
    // the panic back as an error, and the wire reports `engine-error`
    // without dropping the connection.
    let batch = BatchConfig::new(2, Duration::from_millis(2), (1, 1, 8));
    let (mut server, _) = start_server("mlp-s", 5, batch, ephemeral());
    let mut client = connect(&server);
    for _ in 0..2 {
        let (code, msg) = client.infer(&[0.5; 8]).unwrap().unwrap_err();
        assert_eq!(code, ErrorCode::EngineError, "{msg}");
        assert!(msg.contains("engine forward"), "{msg}");
    }
    assert_eq!(server.net_counters().engine_error, 2);
    server.shutdown();
}

#[test]
fn stats_ping_and_bad_frame() {
    let batch = BatchConfig::new(4, Duration::from_millis(2), (1, 28, 28));
    let (mut server, _) = start_server("mlp-s", 6, batch, ephemeral());
    let mut client = connect(&server);
    client.ping().unwrap();
    client.infer(&[0.1; 784]).unwrap().unwrap();
    let stats = json::parse(&client.stats_json().unwrap()).unwrap();
    assert_eq!(stats.get("serving").unwrap().get("requests").unwrap().as_usize(), Some(1));
    assert!(stats.get("net").is_some());
    // An unknown opcode is a protocol violation: bad-frame, then close.
    let mut bad = connect(&server);
    bad.send_request(0xEE, &[]).unwrap();
    let (status, _) = bad.recv_response().unwrap();
    assert_eq!(ErrorCode::from_u8(status), Some(ErrorCode::BadFrame));
    assert!(bad.ping().is_err(), "connection must be closed after a protocol violation");
    // STATS with a body is also a violation.
    let mut bad2 = connect(&server);
    bad2.send_request(OP_STATS, &[1, 2, 3]).unwrap();
    let (status, _) = bad2.recv_response().unwrap();
    assert_eq!(ErrorCode::from_u8(status), Some(ErrorCode::BadFrame));
    server.shutdown();
}

#[test]
fn graceful_shutdown_drains_in_flight_then_rejects() {
    // A request parks in the 400 ms batch window; a second client sends
    // SHUTDOWN. The parked request must still be answered (drained),
    // and the next request on the old connection must see
    // `shutting-down`, not a hang or silent drop.
    let batch = BatchConfig::new(8, Duration::from_millis(400), (1, 28, 28));
    let (mut server, engine) = start_server("mlp-s", 8, batch, ephemeral());
    let mut worker = connect(&server);
    let sample = vec![0.75f32; 784];
    worker.send_infer(&sample).unwrap();
    std::thread::sleep(Duration::from_millis(100)); // admission, not just accept
    let mut admin = connect(&server);
    admin.shutdown_server().unwrap();
    assert!(server.shutdown_requested());
    let (status, body) = worker.recv_response().unwrap();
    assert_eq!(status, 0, "in-flight request must be drained, not dropped");
    let x = Tensor::new(vec![1, 1, 28, 28], sample);
    let want = engine.forward(&x).unwrap().data;
    let got: Vec<f32> = body.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect();
    assert_eq!(want.len(), got.len());
    for (a, b) in want.iter().zip(got.iter()) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // The draining server refuses new work on the surviving connection.
    match worker.infer(&[0.5; 784]) {
        Ok(Err((code, _))) => assert_eq!(code, ErrorCode::ShuttingDown),
        Ok(Ok(_)) => panic!("draining server accepted new work"),
        Err(_) => {} // the handler may already have closed the socket
    }
    server.shutdown();
    assert_eq!(server.stats().requests, 1);
}

#[test]
fn loadgen_closed_loop_reports_and_verifies() {
    let batch = BatchConfig::new(8, Duration::from_millis(1), (1, 28, 28));
    let (mut server, engine) = start_server("mlp-s", 9, batch, ephemeral());
    let cfg = LoadConfig {
        addr: server.local_addr().to_string(),
        clients: 8,
        duration: Duration::from_millis(400),
        targets: vec![LoadTarget::new(None, (1, 28, 28), Some(engine))],
        seed: 42,
        connect_timeout: Duration::from_secs(5),
        retry_budget: 8,
        retry_base: Duration::from_micros(200),
        fetch_server_stats: true,
    };
    let report = loadgen::run(&cfg).unwrap();
    assert!(report.ok > 0, "closed loop completed no requests");
    assert_eq!(report.mismatches, 0, "wire responses diverged from local forward");
    assert_eq!(report.verified, report.ok);
    assert!(report.throughput_rps > 0.0);
    assert!(report.p50_latency_us > 0.0);
    assert!(report.p99_latency_us >= report.p50_latency_us);
    let server_stats = report.server_stats.as_ref().expect("server stats fetched");
    let serving = server_stats.get("serving").unwrap();
    assert!(serving.get("requests").unwrap().as_usize().unwrap() >= report.ok as usize);
    assert!(serving.get("p99_latency_us").is_some(), "server-side percentiles in the artifact");
    // The report JSON carries the full taxonomy table.
    let j = report.to_json();
    for code in ErrorCode::all() {
        assert!(j.get("errors").unwrap().get(code.name()).is_some(), "missing {}", code.name());
    }
    server.shutdown();
}
