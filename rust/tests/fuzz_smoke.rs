//! Deterministic fuzz-lite for the untrusted-bytes parsers, running
//! under plain `cargo test -q` with no cargo-fuzz / nightly toolchain.
//!
//! Two halves:
//!   1. The committed corpora under `fuzz/corpus/` — every `valid_*`
//!      seed must decode, every `repro_*` / `bad_*` / malformed seed
//!      must be a clean `Err` (these are the minimized reproducers for
//!      the decode bugs this PR fixed; on pre-fix code they aborted,
//!      panicked, or silently mis-loaded).
//!   2. A seeded-RNG mutation sweep: byte flips, truncations, and
//!      length-field overwrites with adversarial values over valid
//!      checkpoint / wire / body bytes. The only acceptable outcomes
//!      are `Ok` or `Err` — a panic or abort fails the suite.
//!
//! The real coverage-guided fuzzing lives in `fuzz/` (CI `fuzz-smoke`
//! job); this file is the offline regression floor.

use proxcomp::checkpoint;
use proxcomp::inference::net::{decode_frame, parse_infer_model_body, MAX_FRAME_BYTES};
use proxcomp::util::rng::Rng;
use std::path::{Path, PathBuf};

fn corpus_dir(target: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("../fuzz/corpus").join(target)
}

fn corpus_files(target: &str) -> Vec<(String, Vec<u8>)> {
    let dir = corpus_dir(target);
    let mut out = Vec::new();
    for entry in std::fs::read_dir(&dir)
        .unwrap_or_else(|e| panic!("missing committed corpus {}: {e}", dir.display()))
    {
        let path = entry.unwrap().path();
        if path.is_file() {
            let name = path.file_name().unwrap().to_string_lossy().into_owned();
            out.push((name, std::fs::read(&path).unwrap()));
        }
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    assert!(!out.is_empty(), "empty corpus at {}", dir.display());
    out
}

/// The v2 envelope the `checkpoint_v2` fuzz target prepends to its
/// leaf-body corpus (one prunable [2,3] leaf) — keep in sync with
/// fuzz/fuzz_targets/checkpoint_v2.rs.
fn v2_envelope(body: &[u8]) -> Vec<u8> {
    let header = r#"{"meta":{},"specs":[{"name":"fc1_w","kind":"fc_w","shape":[2,3],"prunable":true,"layer":"fc1"}]}"#;
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"PXCP");
    bytes.extend_from_slice(&2u32.to_le_bytes());
    bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
    bytes.extend_from_slice(header.as_bytes());
    bytes.extend_from_slice(body);
    bytes
}

#[test]
fn checkpoint_corpus_valid_seeds_decode_and_repros_fail() {
    for (name, bytes) in corpus_files("checkpoint_v1") {
        let result = checkpoint::decode(&bytes);
        if name.starts_with("valid_") {
            assert!(result.is_ok(), "{name}: {}", result.unwrap_err());
        } else {
            assert!(result.is_err(), "{name}: corrupt seed decoded successfully");
        }
    }
    for (name, body) in corpus_files("checkpoint_v2") {
        let result = checkpoint::decode(&v2_envelope(&body));
        if name.starts_with("valid_") {
            assert!(result.is_ok(), "{name}: {}", result.unwrap_err());
        } else {
            assert!(result.is_err(), "{name}: corrupt seed decoded successfully");
        }
    }
}

#[test]
fn wire_corpus_valid_seeds_decode_and_repros_fail() {
    for (name, bytes) in corpus_files("wire_frame") {
        let result = decode_frame(&bytes, MAX_FRAME_BYTES);
        if name.starts_with("valid_") {
            assert!(result.is_ok(), "{name}: {:?}", result.unwrap_err());
        } else {
            assert!(result.is_err(), "{name}: corrupt frame decoded successfully");
        }
    }
    for (name, bytes) in corpus_files("infer_model_body") {
        let result = parse_infer_model_body(&bytes);
        if name.starts_with("valid_") || name.starts_with("max_") {
            assert!(result.is_ok(), "{name}: {}", result.unwrap_err());
        } else {
            assert!(result.is_err(), "{name}: malformed body parsed successfully");
        }
    }
}

/// Named reproducers for this PR's decode bugs must stay in the
/// corpus and stay red — each maps to a unit test next to the fix.
#[test]
fn named_bug_reproducers_are_present_and_rejected() {
    let cases = [
        ("checkpoint_v1", "repro_nnz_u32_truncation.pxcp", "u32 row-pointer encoding"),
        ("checkpoint_v1", "repro_sparse_expansion_oom.pxcp", "implausibly large to expand"),
        ("checkpoint_v1", "repro_sparse_on_1d_spec.pxcp", "no 2-D matrix view"),
        ("checkpoint_v1", "deep_json_header.pxcp", "nesting deeper than"),
    ];
    for (target, name, needle) in cases {
        let bytes = std::fs::read(corpus_dir(target).join(name))
            .unwrap_or_else(|e| panic!("{target}/{name} missing from corpus: {e}"));
        let err = checkpoint::decode(&bytes).expect_err(name).to_string();
        assert!(err.contains(needle), "{name}: error {err:?} lacks {needle:?}");
    }
    let body_cases = [
        ("repro_dim_product_wrap.bin", "does not match the spec's"),
        ("repro_truncated_ptr.bin", "truncated checkpoint"),
    ];
    for (name, needle) in body_cases {
        let body = std::fs::read(corpus_dir("checkpoint_v2").join(name))
            .unwrap_or_else(|e| panic!("checkpoint_v2/{name} missing from corpus: {e}"));
        let err = checkpoint::decode(&v2_envelope(&body)).expect_err(name).to_string();
        assert!(err.contains(needle), "{name}: error {err:?} lacks {needle:?}");
    }
}

/// One deterministic mutation step: flip bytes, truncate, or stamp an
/// adversarial value over a little-endian length/dimension field.
fn mutate(rng: &mut Rng, bytes: &mut Vec<u8>) {
    const EXTREMES: [u64; 8] = [
        0,
        1,
        u32::MAX as u64,
        u32::MAX as u64 + 1,
        u64::MAX,
        u64::MAX / 2 + 3, // wraps small when doubled
        1 << 40,
        255,
    ];
    if bytes.is_empty() {
        return;
    }
    match rng.below(4) {
        // Flip 1-4 random bytes.
        0 => {
            for _ in 0..=rng.below(4) {
                let i = rng.below(bytes.len());
                bytes[i] ^= (rng.next_u64() & 0xFF) as u8;
            }
        }
        // Truncate at a random boundary.
        1 => bytes.truncate(rng.below(bytes.len())),
        // Overwrite 8 bytes with an extreme length/dimension value.
        2 if bytes.len() >= 8 => {
            let v = EXTREMES[rng.below(EXTREMES.len())].to_le_bytes();
            let at = rng.below(bytes.len() - 7);
            bytes[at..at + 8].copy_from_slice(&v);
        }
        // Overwrite 4 bytes (u32 fields: frame length prefix, version…).
        _ => {
            let v = (EXTREMES[rng.below(EXTREMES.len())] as u32).to_le_bytes();
            if bytes.len() >= 4 {
                let at = rng.below(bytes.len() - 3);
                bytes[at..at + 4].copy_from_slice(&v);
            }
        }
    }
}

#[test]
fn checkpoint_decode_survives_seeded_mutations() {
    let seeds: Vec<Vec<u8>> = corpus_files("checkpoint_v1")
        .into_iter()
        .map(|(_, b)| b)
        .chain(corpus_files("checkpoint_v2").into_iter().map(|(_, b)| v2_envelope(&b)))
        .collect();
    let mut rng = Rng::new(0x5EED_CAFE);
    for round in 0..400 {
        let mut bytes = seeds[round % seeds.len()].clone();
        for _ in 0..=rng.below(3) {
            mutate(&mut rng, &mut bytes);
        }
        // Ok or Err are both fine; panics/aborts/OOMs are the bug.
        let _ = checkpoint::decode(&bytes);
    }
}

#[test]
fn wire_decode_survives_seeded_mutations() {
    let frame_seeds: Vec<Vec<u8>> =
        corpus_files("wire_frame").into_iter().map(|(_, b)| b).collect();
    let body_seeds: Vec<Vec<u8>> =
        corpus_files("infer_model_body").into_iter().map(|(_, b)| b).collect();
    let mut rng = Rng::new(0xF00D_F00D);
    for round in 0..400 {
        let mut frame = frame_seeds[round % frame_seeds.len()].clone();
        mutate(&mut rng, &mut frame);
        let _ = decode_frame(&frame, MAX_FRAME_BYTES);
        let _ = decode_frame(&frame, 64);
        let mut body = body_seeds[round % body_seeds.len()].clone();
        mutate(&mut rng, &mut body);
        let _ = parse_infer_model_body(&body);
    }
}
