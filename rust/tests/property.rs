//! Property-based tests (hand-rolled generator loops on our PRNG — no
//! proptest crate in the offline set): randomized invariants over the
//! sparse formats, kernels, prox operators, checkpoints, and data
//! pipeline. Each property runs against many random instances.

use proxcomp::runtime::{ParamBundle, ParamSpec};
use proxcomp::sparse::{ops, prox, BlockEllMatrix, CooMatrix, CsrMatrix, DiaMatrix, EllMatrix};
use proxcomp::tensor::{matmul, matmul_nt, Tensor};
use proxcomp::util::rng::Rng;

const CASES: usize = 40;

fn random_dense(rng: &mut Rng, rows: usize, cols: usize, density: f64) -> Vec<f32> {
    (0..rows * cols)
        .map(|_| {
            if rng.uniform() < density {
                rng.normal() as f32
            } else {
                0.0
            }
        })
        .collect()
}

#[test]
fn prop_all_formats_roundtrip_dense() {
    let mut rng = Rng::new(100);
    for case in 0..CASES {
        let rows = 1 + rng.below(24);
        let cols = 1 + rng.below(24);
        let density = rng.uniform();
        let dense = random_dense(&mut rng, rows, cols, density);
        assert_eq!(CsrMatrix::from_dense(&dense, rows, cols).to_dense(), dense, "csr case {case}");
        assert_eq!(CooMatrix::from_dense(&dense, rows, cols).to_dense(), dense, "coo case {case}");
        assert_eq!(EllMatrix::from_dense(&dense, rows, cols).to_dense(), dense, "ell case {case}");
        assert_eq!(DiaMatrix::from_dense(&dense, rows, cols).to_dense(), dense, "dia case {case}");
    }
}

#[test]
fn prop_format_conversions_commute() {
    let mut rng = Rng::new(101);
    for _ in 0..CASES {
        let rows = 1 + rng.below(16);
        let cols = 1 + rng.below(16);
        let dense = random_dense(&mut rng, rows, cols, 0.3);
        let csr = CsrMatrix::from_dense(&dense, rows, cols);
        // csr -> coo -> csr is the identity.
        assert_eq!(CooMatrix::from_csr(&csr).to_csr(), csr);
        // ell built from csr or dense agree.
        assert_eq!(EllMatrix::from_csr(&csr), EllMatrix::from_dense(&dense, rows, cols));
    }
}

#[test]
fn prop_csr_transpose_involution_and_validity() {
    let mut rng = Rng::new(102);
    for _ in 0..CASES {
        let rows = 1 + rng.below(20);
        let cols = 1 + rng.below(20);
        let dense = random_dense(&mut rng, rows, cols, 0.25);
        let csr = CsrMatrix::from_dense(&dense, rows, cols);
        let t = csr.transpose();
        t.validate().unwrap();
        assert_eq!(t.transpose(), csr);
        assert_eq!(t.nnz(), csr.nnz());
    }
}

#[test]
fn prop_dxct_equals_dense_matmul() {
    let mut rng = Rng::new(103);
    for _ in 0..CASES {
        let b = 1 + rng.below(12);
        let n = 1 + rng.below(30);
        let k = 1 + rng.below(40);
        let wd = random_dense(&mut rng, n, k, 0.3);
        let csr = CsrMatrix::from_dense(&wd, n, k);
        let d = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
        let got = ops::dxct(&d, &csr);
        let want = matmul_nt(&d, &Tensor::new(vec![n, k], wd));
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-3);
        }
    }
}

#[test]
fn prop_dxc_equals_dense_matmul() {
    let mut rng = Rng::new(104);
    for _ in 0..CASES {
        let b = 1 + rng.below(12);
        let n = 1 + rng.below(30);
        let k = 1 + rng.below(40);
        let wd = random_dense(&mut rng, n, k, 0.3);
        let csr = CsrMatrix::from_dense(&wd, n, k);
        let g = Tensor::new(vec![b, n], rng.normal_vec(b * n, 1.0));
        let got = ops::dxc(&g, &csr);
        let want = matmul(&g, &Tensor::new(vec![n, k], wd));
        for (a, w) in got.data.iter().zip(&want.data) {
            assert!((a - w).abs() < 1e-3);
        }
    }
}

#[test]
fn prop_forward_backward_adjoint() {
    // <dxct(x, W), g> == <x, dxc(g, W)> — the VJP identity that makes the
    // Figure-2/Figure-3 pair a valid forward/backward couple.
    let mut rng = Rng::new(105);
    for _ in 0..CASES {
        let b = 1 + rng.below(8);
        let n = 1 + rng.below(20);
        let k = 1 + rng.below(20);
        let wd = random_dense(&mut rng, n, k, 0.4);
        let csr = CsrMatrix::from_dense(&wd, n, k);
        let x = Tensor::new(vec![b, k], rng.normal_vec(b * k, 1.0));
        let g = Tensor::new(vec![b, n], rng.normal_vec(b * n, 1.0));
        let fwd = ops::dxct(&x, &csr);
        let bwd = ops::dxc(&g, &csr);
        let lhs: f64 = fwd.data.iter().zip(&g.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let rhs: f64 = x.data.iter().zip(&bwd.data).map(|(a, b)| (*a as f64) * (*b as f64)).sum();
        let denom = lhs.abs().max(rhs.abs()).max(1.0);
        assert!((lhs - rhs).abs() / denom < 1e-4, "{lhs} vs {rhs}");
    }
}

#[test]
fn prop_blockell_matmul_equals_dense() {
    let mut rng = Rng::new(106);
    for _ in 0..20 {
        let n_br = 1 + rng.below(5);
        let n_bc = 1 + rng.below(5);
        let (bh, bw) = (4, 8);
        let (rows, cols) = (n_br * bh, n_bc * bw);
        let dense = random_dense(&mut rng, rows, cols, 0.3);
        let bell = BlockEllMatrix::from_dense(&dense, rows, cols, bh, bw);
        assert_eq!(bell.to_dense(), dense);
        let b = 1 + rng.below(10);
        let d = Tensor::new(vec![b, cols], rng.normal_vec(b * cols, 1.0));
        let got = bell.dxct(&d);
        let want = matmul_nt(&d, &Tensor::new(vec![rows, cols], dense));
        for (g, w) in got.data.iter().zip(&want.data) {
            assert!((g - w).abs() < 1e-3);
        }
    }
}

#[test]
fn prop_prox_shrinkage_and_zero_band() {
    let mut rng = Rng::new(107);
    for _ in 0..CASES {
        let n = 1 + rng.below(500);
        let t = rng.range(0.0, 1.5);
        let xs: Vec<f32> = rng.normal_vec(n, 1.0);
        let mut out = xs.clone();
        prox::soft_threshold_inplace(&mut out, t);
        for (x, y) in xs.iter().zip(&out) {
            if x.abs() <= t {
                assert_eq!(*y, 0.0);
            } else {
                assert!((y.abs() - (x.abs() - t)).abs() < 1e-5);
                assert_eq!(y.signum(), x.signum());
            }
        }
    }
}

#[test]
fn prop_hard_threshold_subset_of_soft_zeros() {
    // Hard and soft thresholding zero exactly the same entries; soft
    // additionally shrinks survivors.
    let mut rng = Rng::new(108);
    for _ in 0..CASES {
        let xs: Vec<f32> = rng.normal_vec(200, 1.0);
        let t = rng.range(0.0, 1.0);
        let mut soft = xs.clone();
        let mut hard = xs.clone();
        prox::soft_threshold_inplace(&mut soft, t);
        prox::hard_threshold_inplace(&mut hard, t);
        for (s, h) in soft.iter().zip(&hard) {
            assert_eq!(*s == 0.0, *h == 0.0);
        }
    }
}

#[test]
fn prop_compression_rate_equals_explicit_zero_count() {
    let mut rng = Rng::new(109);
    for _ in 0..CASES {
        let n = 10 + rng.below(500);
        let spec = ParamSpec {
            name: "w".into(),
            kind: "fc_w".into(),
            shape: vec![n],
            prunable: true,
            layer: "fc".into(),
        };
        let mut values = rng.normal_vec(n, 1.0);
        let t = rng.range(0.0, 1.0);
        prox::soft_threshold_inplace(&mut values, t);
        let explicit = values.iter().filter(|&&v| v == 0.0).count();
        let bundle = ParamBundle { specs: vec![spec], values: vec![values] };
        assert_eq!(bundle.zero_weights(), explicit);
        assert!((bundle.compression_rate() - explicit as f64 / n as f64).abs() < 1e-12);
    }
}

#[test]
fn prop_checkpoint_roundtrip_random_sparsity() {
    let mut rng = Rng::new(110);
    let dir = std::env::temp_dir().join("proxcomp_prop_ckpt");
    std::fs::create_dir_all(&dir).unwrap();
    for case in 0..15 {
        let n = 2 + rng.below(20);
        let k = 2 + rng.below(20);
        let spec = ParamSpec {
            name: "w".into(),
            kind: "fc_w".into(),
            shape: vec![n, k],
            prunable: true,
            layer: "fc".into(),
        };
        let mut values = rng.normal_vec(n * k, 1.0);
        let t = rng.range(0.0, 2.5);
        prox::soft_threshold_inplace(&mut values, t);
        let bundle = ParamBundle { specs: vec![spec], values: vec![values] };
        let path = dir.join(format!("c{case}.pxcp"));
        proxcomp::checkpoint::save(&path, &bundle, &proxcomp::util::json::Json::obj()).unwrap();
        let ck = proxcomp::checkpoint::load(&path).unwrap();
        assert_eq!(ck.params.values, bundle.values, "case {case}");
    }
}

#[test]
fn prop_json_roundtrip_random_documents() {
    use proxcomp::util::json::{self, Json};
    let mut rng = Rng::new(111);

    fn gen(rng: &mut Rng, depth: usize) -> Json {
        match if depth == 0 { rng.below(4) } else { rng.below(6) } {
            0 => Json::Null,
            1 => Json::Bool(rng.uniform() < 0.5),
            2 => Json::Num((rng.normal() * 100.0 * 128.0).round() / 128.0),
            3 => Json::Str(format!("s{}✓\n\"{}\"", rng.below(1000), rng.below(10))),
            4 => Json::Arr((0..rng.below(5)).map(|_| gen(rng, depth - 1)).collect()),
            _ => Json::Obj(
                (0..rng.below(5))
                    .map(|i| (format!("k{i}"), gen(rng, depth - 1)))
                    .collect(),
            ),
        }
    }

    for _ in 0..60 {
        let doc = gen(&mut rng, 3);
        let compact = json::parse(&doc.to_string_compact()).unwrap();
        let pretty = json::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(doc, compact);
        assert_eq!(doc, pretty);
    }
}

#[test]
fn prop_dataset_batches_always_in_range() {
    use proxcomp::data::{self, Batcher};
    let mut rng = Rng::new(112);
    for _ in 0..8 {
        let n = 10 + rng.below(60);
        let d = data::synth_mnist(n, rng.next_u64());
        let mut b = Batcher::new(d.n, rng.next_u64());
        for _ in 0..5 {
            let batch = 1 + rng.below(17);
            let (xs, ys) = b.next_batch(&d, batch);
            assert_eq!(xs.len(), batch * 784);
            assert_eq!(ys.len(), batch);
            assert!(ys.iter().all(|&y| (0..10).contains(&y)));
            assert!(xs.iter().all(|v| v.is_finite()));
        }
    }
}

#[test]
fn prop_engine_dense_sparse_parity_random_weights() {
    use proxcomp::inference::Engine;
    let mut rng = Rng::new(113);
    for _ in 0..6 {
        // Random sparse MLP bundle at the manifest shapes.
        let specs = vec![
            ParamSpec { name: "fc1_w".into(), kind: "fc_w".into(), shape: vec![256, 784], prunable: true, layer: "fc1".into() },
            ParamSpec { name: "fc1_b".into(), kind: "fc_b".into(), shape: vec![256], prunable: false, layer: "fc1".into() },
            ParamSpec { name: "fc2_w".into(), kind: "fc_w".into(), shape: vec![128, 256], prunable: true, layer: "fc2".into() },
            ParamSpec { name: "fc2_b".into(), kind: "fc_b".into(), shape: vec![128], prunable: false, layer: "fc2".into() },
            ParamSpec { name: "fc3_w".into(), kind: "fc_w".into(), shape: vec![10, 128], prunable: true, layer: "fc3".into() },
            ParamSpec { name: "fc3_b".into(), kind: "fc_b".into(), shape: vec![10], prunable: false, layer: "fc3".into() },
        ];
        let mut bundle = ParamBundle::he_init(&specs, rng.next_u64());
        let t = rng.range(0.0, 0.08);
        for (spec, v) in bundle.specs.iter().zip(bundle.values.iter_mut()) {
            if spec.prunable {
                prox::soft_threshold_inplace(v, t);
            }
        }
        let dense = Engine::from_bundle("mlp", &bundle, false).unwrap();
        let sparse = Engine::from_bundle("mlp", &bundle, true).unwrap();
        let x = Tensor::new(vec![3, 1, 28, 28], rng.normal_vec(3 * 784, 1.0));
        let a = dense.forward(&x).unwrap();
        let b = sparse.forward(&x).unwrap();
        for (u, v) in a.data.iter().zip(&b.data) {
            assert!((u - v).abs() < 1e-3, "dense/sparse engines diverge: {u} vs {v}");
        }
    }
}
